"""Section 6: the paper's prefix sum — operation/barrier counts + timing.

Validates the paper's complexity claims exactly (N-1 upward updates, N-h
downward, 2h-3 barriers vs Blelloch's 2h) and times the jnp implementation
against jnp.cumsum. The Pallas VMEM kernel is correctness-checked in tests
(timing it in interpret mode would time the interpreter).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import blelloch_counts, operation_counts, paper_prefix_sum
from repro.core.prefix import paper_height

from .common import time_fn


def run(csv: bool = True):
    rows = []
    if csv:
        print("name,us_per_call,derived")
    for n in (64, 256, 1024, 4096, 16384):
        up, down, barriers = operation_counts(n)
        b_up, b_down, b_bar = blelloch_counts(n)
        h = paper_height(n)
        x = jnp.asarray(np.random.randint(0, 100, n), jnp.int32)
        f = jax.jit(paper_prefix_sum)
        secs, _ = time_fn(f, x)
        ref = jax.jit(lambda v: jnp.cumsum(v))
        secs_ref, _ = time_fn(ref, x)
        derived = (f"updates={up}+{down};barriers={barriers};"
                   f"blelloch_barriers={b_bar};paper_claims="
                   f"up==N-1:{up == n - 1},down==N-h:{down == n - h},"
                   f"bar==2h-3:{barriers == 2 * h - 3};"
                   f"cumsum_us={secs_ref * 1e6:.1f}")
        rows.append({"n": n, "up": up, "down": down, "barriers": barriers,
                     "seconds": secs, "cumsum_seconds": secs_ref})
        if csv:
            print(f"prefix/n{n},{secs * 1e6:.1f},{derived}")
    return rows


if __name__ == "__main__":
    run()
