"""Packed figure: packed-row (CSR) layout speedup vs particles per cell.

The occupancy-compacted path (``fig_sparse``) removes empty *pencils*; the
packed-row layout (``plan(..., layout="packed")``) removes the slot padding
*inside* active cells — the paper's "few particles per cell" tail, where
every active cell still pays for all ``m_c`` sublane-aligned slots. This
benchmark sweeps ppc ∈ {1, 2, 4, 8} on the gaussian-blob scenario and
reports

    speedup = t(compacted xpencil, dense layout) / t(compacted xpencil,
                                                     packed layout)

per case, with the measured ``m_c``/``row_cap`` alongside (their ratio —
times nx — is the padding the packed layout refuses to touch). Expectation:
the win grows as the slot-padding waste ``nx * m_c / row_cap`` grows, i.e.
toward *low* global ppc on clustered scenes.

Both plans are executed once on the same positions and checked bit-for-bit
against the plain dense schedule before anything is timed — a benchmark
that silently drifted from the oracle would be worse than no benchmark.

``--json PATH`` writes the timings as BENCH_*.json perf records (with a
``layout`` tag and ppc/m_c/row_cap/speedup extras); the committed
``benchmarks/BENCH_packed.json`` is this module's output on the reference
container and is diffed (report-only) by the CI docs job.
"""

from __future__ import annotations

import argparse
import sys
from typing import List, Optional, Sequence

import jax
import numpy as np

from repro.core import (Domain, ParticleState, make_lennard_jones, plan,
                        scenarios, suggest_m_c)

from .common import bench_record, time_fn, write_bench_json

DEFAULT_PPCS = (1, 2, 4, 8)


def run(csv: bool = True, json_path: Optional[str] = None,
        record_sink: Optional[List[dict]] = None, division: int = 12,
        ppcs: Sequence[int] = DEFAULT_PPCS, sigma_frac: float = 0.18,
        seed: int = 0, budget_s: float = 1.0) -> List[dict]:
    dom = Domain.cubic(division, cutoff=1.0)
    kern = make_lennard_jones()
    rows: List[dict] = []
    records: List[dict] = []
    if csv:
        print("name,us_per_call,derived")
    for ppc in ppcs:
        case = f"packed/blob_ppc{ppc}"
        n = ppc * dom.n_cells
        pos = scenarios.sample_gaussian_blob(
            dom, jax.random.PRNGKey(seed), n, sigma_frac=sigma_frac)
        m_c = suggest_m_c(dom, pos)
        state = ParticleState(pos)
        p_dense = plan(dom, kern, m_c=m_c, strategy="xpencil",
                       backend="reference")
        p_comp = plan(dom, kern, m_c=m_c, strategy="xpencil",
                      backend="reference", compact=True, positions=pos)
        p_pack = plan(dom, kern, m_c=m_c, strategy="xpencil",
                      backend="reference", compact=True, layout="packed",
                      positions=pos)

        # correctness gate: both timed paths must agree with the dense
        # schedule bit-for-bit on the scene they are about to be timed on
        f_d, q_d = p_dense.execute(state)
        ok = True
        for name, p in (("compact", p_comp), ("packed", p_pack)):
            f, q = p.execute(state)
            if not (np.array_equal(np.asarray(f_d), np.asarray(f))
                    and np.array_equal(np.asarray(q_d), np.asarray(q))):
                print(f"fig_packed: {case}: {name} result DIVERGED from "
                      "dense — not timing a wrong answer", file=sys.stderr)
                ok = False
        if not ok:
            continue

        t_c, r_c = time_fn(p_comp.execute, state, budget_s=budget_s)
        t_p, r_p = time_fn(p_pack.execute, state, budget_s=budget_s)
        speedup = t_c / t_p
        row = {"case": case, "ppc": ppc, "m_c": m_c,
               "row_cap": p_pack.row_cap, "max_active": p_comp.max_active,
               "compact_s": t_c, "packed_s": t_p, "speedup": speedup}
        rows.append(row)
        records.append(dict(bench_record(case, "xpencil_compact",
                                         "reference", t_c, r_c,
                                         layout="compact"),
                            ppc=ppc, m_c=m_c))
        records.append(dict(bench_record(case, "xpencil_packed",
                                         "reference", t_p, r_p,
                                         layout="packed"),
                            ppc=ppc, m_c=m_c, row_cap=p_pack.row_cap,
                            speedup_vs_compact=speedup))
        if csv:
            print(f"{case}/xpencil_compact,{t_c * 1e6:.1f},m_c={m_c}")
            print(f"{case}/xpencil_packed,{t_p * 1e6:.1f},"
                  f"row_cap={p_pack.row_cap};speedup={speedup:.2f}")
    if json_path:
        write_bench_json(json_path, records)
    if record_sink is not None:
        record_sink.extend(records)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--division", type=int, default=12,
                    help="cells per axis")
    ap.add_argument("--ppc", type=int, nargs="+", default=list(DEFAULT_PPCS),
                    help="global particles-per-cell sweep")
    ap.add_argument("--sigma", type=float, default=0.18,
                    help="gaussian blob sigma as a fraction of the box")
    ap.add_argument("--budget", type=float, default=1.0,
                    help="stopwatch budget per case (seconds)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write BENCH_*.json perf records to PATH")
    args = ap.parse_args()
    run(division=args.division, ppcs=tuple(args.ppc),
        sigma_frac=args.sigma, budget_s=args.budget, json_path=args.json)


if __name__ == "__main__":
    main()
