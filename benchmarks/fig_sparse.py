"""Sparse figure: compacted-schedule speedup vs active-pencil fill fraction.

The dense schedules pay for every (z, y) pencil whether or not it holds
particles; the occupancy-compacted path (``plan(..., compact=True)``)
iterates only active pencils. This benchmark sweeps the inhomogeneous
scenario family (``repro.core.scenarios``) from fully uniform down to a few
percent active pencils and reports

    speedup = t(dense xpencil) / t(compacted xpencil)

per case, with the measured fill fraction as the x-axis. Expectation: ~1x
at fill 1.0 (compaction is bounded overhead), approaching 1/fill as the
scene empties.

Both plans are executed once on the same positions and checked for exact
agreement before anything is timed — a benchmark that silently drifted from
the oracle would be worse than no benchmark.

``--json PATH`` writes the timings as BENCH_*.json perf records (case,
strategy, backend, us_per_call, reps, platform + fill/speedup extras);
the committed ``benchmarks/BENCH_sparse.json`` is this module's output on
the reference container.
"""

from __future__ import annotations

import argparse
import sys
from typing import List, Optional

import jax
import numpy as np

from repro.core import (Domain, ParticleState, active_unit_count,
                        make_lennard_jones, plan, scenarios, suggest_m_c)
from repro.core.api import n_units

from .common import bench_record, time_fn, write_bench_json

# (case name, scenario kwargs) — ordered roughly densest to sparsest; the
# gaussian sigma sweep is the controlled fill-fraction axis, the two-phase
# droplet and power-law cluster are the "realistic" inhomogeneous scenes.
CASES = [
    ("uniform", dict(name="uniform")),
    ("two_phase", dict(name="two_phase", droplet_frac=0.9,
                       radius_frac=0.12)),
    ("power_law", dict(name="power_law_cluster", n_clusters=3, alpha=2.0,
                       r_min_frac=0.04)),
    ("blob_wide", dict(name="gaussian_blob", sigma_frac=0.10)),
    ("blob_tight", dict(name="gaussian_blob", sigma_frac=0.05)),
    ("blob_point", dict(name="gaussian_blob", sigma_frac=0.035)),
]


def run(csv: bool = True, json_path: Optional[str] = None,
        record_sink: Optional[List[dict]] = None, division: int = 16,
        n: int = 500, seed: int = 0) -> List[dict]:
    dom = Domain.cubic(division, cutoff=1.0)
    kern = make_lennard_jones()
    rows: List[dict] = []
    records: List[dict] = []
    if csv:
        print("name,us_per_call,derived")
    for case, knobs in CASES:
        pos = scenarios.sample(domain=dom, key=jax.random.PRNGKey(seed),
                               n=n, **knobs)
        m_c = suggest_m_c(dom, pos)
        fill = active_unit_count(dom, pos, "xpencil") / n_units(dom,
                                                                "xpencil")
        state = ParticleState(pos)
        p_dense = plan(dom, kern, m_c=m_c, strategy="xpencil",
                       backend="reference")
        p_comp = plan(dom, kern, m_c=m_c, strategy="xpencil",
                      backend="reference", compact=True, positions=pos)

        # correctness gate: the compacted path must agree with the dense
        # schedule bit-for-bit on the scene it is about to be timed on
        f_d, q_d = p_dense.execute(state)
        f_c, q_c = p_comp.execute(state)
        if not (np.array_equal(np.asarray(f_d), np.asarray(f_c))
                and np.array_equal(np.asarray(q_d), np.asarray(q_c))):
            print(f"fig_sparse: {case}: compacted result DIVERGED from "
                  "dense — not timing a wrong answer", file=sys.stderr)
            continue

        t_d, r_d = time_fn(p_dense.execute, state)
        t_c, r_c = time_fn(p_comp.execute, state)
        speedup = t_d / t_c
        row = {"case": case, "fill": fill, "m_c": m_c,
               "max_active": p_comp.max_active, "dense_s": t_d,
               "compact_s": t_c, "speedup": speedup}
        rows.append(row)
        records.append(dict(bench_record(f"sparse/{case}", "xpencil",
                                         "reference", t_d, r_d),
                            fill=fill))
        records.append(dict(bench_record(f"sparse/{case}",
                                         "xpencil_compact", "reference",
                                         t_c, r_c),
                            fill=fill, speedup_vs_dense=speedup))
        if csv:
            print(f"sparse/xpencil/{case},{t_d * 1e6:.1f},"
                  f"fill={fill:.3f}")
            print(f"sparse/xpencil_compact/{case},{t_c * 1e6:.1f},"
                  f"fill={fill:.3f};speedup={speedup:.2f}")
    if json_path:
        write_bench_json(json_path, records)
    if record_sink is not None:
        record_sink.extend(records)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--division", type=int, default=16,
                    help="cells per axis (division^2 pencils)")
    ap.add_argument("--n", type=int, default=500, help="particles")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write BENCH_*.json perf records to PATH")
    args = ap.parse_args()
    run(division=args.division, n=args.n, json_path=args.json)


if __name__ == "__main__":
    main()
