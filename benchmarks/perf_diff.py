"""Diff two BENCH_*.json perf-record files (the perf trajectory's delta).

Every timed benchmark runner emits records of the shape

    {"case": ..., "strategy": ..., "backend": ..., "us_per_call": ...,
     "reps": ..., "platform": ...}

(``benchmarks.common.bench_record``). This tool joins two such files on
``(case, strategy, backend)`` and reports the per-case us_per_call delta,
flagging regressions past a threshold::

    python -m benchmarks.perf_diff BASELINE.json FRESH.json \
        [--threshold 1.5] [--fail-on-regression] [--fail-threshold 1.5]

Exit code is 0 unless ``--fail-on-regression`` (or its one-flag spelling
``--fail-threshold RATIO``, which sets the threshold *and* arms the gate)
is given and at least one matched case regressed. Timing on shared CI
runners is noisy, so the default is report-only with a generous
threshold — the point is a visible per-commit trajectory, not a flaky
gate; the hard gate is reserved for the low-noise smoke cases.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Tuple

Key = Tuple[str, str, str]


def load_records(path: str) -> Dict[Key, dict]:
    """BENCH_*.json -> {(case, strategy, backend): record}. Duplicate keys
    keep the *fastest* record: autotune_bench emits one record per timed
    candidate, and several candidates (m_c / batch_size / box variants)
    share a key — diffing best-known times avoids flagging a regression
    just because a different slow variant survived pruning."""
    with open(path) as f:
        data = json.load(f)
    if not isinstance(data, list):
        raise ValueError(f"{path}: expected a JSON array of perf records")
    out: Dict[Key, dict] = {}
    for rec in data:
        key = (rec["case"], rec["strategy"], rec["backend"])
        if key not in out or rec["us_per_call"] < out[key]["us_per_call"]:
            out[key] = rec
    return out


def diff_records(baseline: Dict[Key, dict], fresh: Dict[Key, dict],
                 threshold: float = 1.5) -> dict:
    """-> {"rows": [...], "regressions": [...], "only_baseline": [...],
    "only_fresh": [...]}. A row regresses when fresh us_per_call exceeds
    baseline * threshold."""
    rows: List[dict] = []
    regressions: List[dict] = []
    for key in sorted(set(baseline) & set(fresh)):
        b, f = baseline[key], fresh[key]
        base_us, fresh_us = b["us_per_call"], f["us_per_call"]
        ratio = fresh_us / base_us if base_us > 0 else float("inf")
        row = {"case": key[0], "strategy": key[1], "backend": key[2],
               "baseline_us": base_us, "fresh_us": fresh_us,
               "ratio": ratio, "delta_pct": (ratio - 1.0) * 100.0,
               "regressed": ratio > threshold}
        rows.append(row)
        if row["regressed"]:
            regressions.append(row)
    return {
        "rows": rows,
        "regressions": regressions,
        "only_baseline": sorted(set(baseline) - set(fresh)),
        "only_fresh": sorted(set(fresh) - set(baseline)),
    }


def format_report(diff: dict, threshold: float) -> str:
    lines = ["case,strategy,backend,baseline_us,fresh_us,delta_pct,flag"]
    for r in diff["rows"]:
        flag = "REGRESSED" if r["regressed"] else ""
        lines.append(f"{r['case']},{r['strategy']},{r['backend']},"
                     f"{r['baseline_us']:.1f},{r['fresh_us']:.1f},"
                     f"{r['delta_pct']:+.1f}%,{flag}")
    for key in diff["only_baseline"]:
        lines.append(f"{key[0]},{key[1]},{key[2]},-,-,-,DROPPED")
    for key in diff["only_fresh"]:
        lines.append(f"{key[0]},{key[1]},{key[2]},-,-,-,NEW")
    n_reg = len(diff["regressions"])
    lines.append(f"# {len(diff['rows'])} matched, {n_reg} regressed "
                 f"(threshold {threshold:g}x), "
                 f"{len(diff['only_fresh'])} new, "
                 f"{len(diff['only_baseline'])} dropped")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline", help="committed BENCH_*.json baseline")
    ap.add_argument("fresh", help="freshly produced BENCH_*.json")
    ap.add_argument("--threshold", type=float, default=1.5,
                    help="regression ratio: fresh > baseline * threshold "
                         "(default 1.5 — CI timing is noisy)")
    ap.add_argument("--fail-on-regression", action="store_true",
                    help="exit 1 if any matched case regressed")
    ap.add_argument("--fail-threshold", type=float, default=None,
                    metavar="RATIO",
                    help="shorthand: set --threshold to RATIO and exit "
                         "nonzero on any regression past it (the CI soft "
                         "gate for smoke cases)")
    args = ap.parse_args(argv)
    threshold = args.threshold
    fail = args.fail_on_regression
    if args.fail_threshold is not None:
        threshold = args.fail_threshold
        fail = True

    diff = diff_records(load_records(args.baseline),
                        load_records(args.fresh),
                        threshold=threshold)
    print(format_report(diff, threshold))
    if fail and diff["regressions"]:
        print(f"perf_diff: {len(diff['regressions'])} regression(s) past "
              f"{threshold:g}x", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
