"""Halo figure: weak scaling of the distributed backend over Z-slab shards.

Weak scaling holds the *per-shard* problem fixed and grows the domain with
the shard count: at ``n_shards = s`` the grid is ``division x division x
(division * s)`` cells with ``ppc`` particles per cell, so every shard owns
the same ``division^3 * ppc`` particles and the same slab of pencils. Ideal
weak scaling keeps time-per-step flat (efficiency ``t(1) / t(s) = 1``); the
gap is the ghost-exchange plus partition overhead the distributed engine
pays for crossing chips.

Before anything is timed, each case's halo forces are checked against the
single-device reference schedule on the same positions — a benchmark that
silently drifted from the oracle would be worse than no benchmark.

On emulated host devices (``--devices N`` respawns the process with
``XLA_FLAGS=--xla_force_host_platform_device_count=N``) all shards share
one physical core, so absolute efficiency is pessimistic — the committed
``benchmarks/BENCH_halo.json`` is the *record structure* the perf
trajectory tracks per commit, not a hardware claim. On a real mesh the
same module runs unchanged.

``--json PATH`` writes BENCH_*.json perf records (case, strategy, backend,
us_per_call, reps, platform + n_shards/n_particles/weak_efficiency extras).
"""

from __future__ import annotations

import argparse
import os
import subprocess
import sys
from typing import List, Optional, Sequence

import numpy as np


def _viable_shard_counts(device_count: int) -> List[int]:
    """1, 2, 4, ... up to the device count (weak scaling doubles shards;
    the grid is built per case as ``division^2 x (division * s)`` cells,
    so every count divides its own nz by construction)."""
    out, s = [], 1
    while s <= device_count:
        out.append(s)
        s *= 2
    return out


def run(csv: bool = True, json_path: Optional[str] = None,
        record_sink: Optional[List[dict]] = None, division: int = 6,
        ppc: int = 4, seed: int = 0, strategy: str = "xpencil",
        shard_counts: Optional[Sequence[int]] = None,
        rtol: float = 3e-4) -> List[dict]:
    import jax

    from repro.core import (Domain, ParticleState, make_lennard_jones,
                            plan)

    from .common import bench_record, time_fn, write_bench_json

    kern = make_lennard_jones()
    if shard_counts is None:
        shard_counts = _viable_shard_counts(jax.device_count())
    rows: List[dict] = []
    records: List[dict] = []
    if csv:
        print("name,us_per_call,derived")
    t1 = None
    for ns in shard_counts:
        dom = Domain(box=(float(division), float(division),
                          float(division * ns)),
                     ncells=(division, division, division * ns),
                     cutoff=1.0, periodic=True)
        n = division ** 3 * ns * ppc
        pos = dom.sample_uniform(jax.random.PRNGKey(seed), n)
        state = ParticleState(pos)
        p_halo = plan(dom, kern, positions=pos, strategy=strategy,
                      backend="halo", n_shards=ns)

        # correctness gate: the distributed result must match the
        # single-device schedule on the scene it is about to be timed on
        p_ref = plan(dom, kern, m_c=p_halo.m_c, strategy=strategy)
        f_r, _ = p_ref.execute(state)
        f_h, _ = p_halo.execute(state)
        scale = max(float(np.abs(np.asarray(f_r)).max()), 1.0)
        err = float(np.abs(np.asarray(f_h) - np.asarray(f_r)).max())
        if err > rtol * scale:
            print(f"fig_halo: ns={ns}: halo result DIVERGED from the "
                  f"reference (|dF|={err:.2e}) — not timing a wrong "
                  "answer", file=sys.stderr)
            continue

        t, r = time_fn(p_halo.execute, state)
        if ns == 1:
            t1 = t
        # weak efficiency is defined as t(1)/t(s): without a timed
        # single-shard baseline the ratio would silently mean something
        # else, so it is omitted rather than rebased
        eff = t1 / t if t1 is not None else None
        row = {"n_shards": ns, "n_particles": n, "ncells": dom.ncells,
               "shard_cap": p_halo.shard_cap, "seconds": t,
               "weak_efficiency": eff}
        rows.append(row)
        rec = dict(bench_record(f"halo/weak/ns{ns}", strategy, "halo",
                                t, r),
                   n_shards=ns, n_particles=n)
        if eff is not None:
            rec["weak_efficiency"] = eff
        records.append(rec)
        if csv:
            derived = f"N={n}"
            if eff is not None:
                derived += f";efficiency={eff:.3f}"
            print(f"halo/weak/{strategy}/ns{ns},{t * 1e6:.1f},{derived}")
    if json_path:
        write_bench_json(json_path, records)
    if record_sink is not None:
        record_sink.extend(records)
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=0,
                    help="emulated host devices (respawns the process with "
                         "XLA_FLAGS; 0 = use the devices already visible)")
    ap.add_argument("--division", type=int, default=6,
                    help="cells per axis of one shard's slab")
    ap.add_argument("--ppc", type=int, default=4, help="particles per cell")
    ap.add_argument("--strategy", default="xpencil")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write BENCH_*.json perf records to PATH")
    args = ap.parse_args(argv)

    shard_counts = None
    if args.devices:
        import jax

        if jax.device_count() < args.devices:
            # too late to grow this process's device set: respawn with the
            # flag in place and without --devices (so the child runs)
            env = dict(os.environ)
            env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                                f" --xla_force_host_platform_device_count="
                                f"{args.devices}")
            cmd = [sys.executable, "-m", "benchmarks.fig_halo",
                   "--division", str(args.division), "--ppc", str(args.ppc),
                   "--strategy", args.strategy]
            if args.json:
                cmd += ["--json", args.json]
            raise SystemExit(subprocess.run(cmd, env=env).returncode)
        # more devices visible than asked for: honour the request anyway
        # by capping the sweep instead of silently using them all
        shard_counts = _viable_shard_counts(args.devices)
    run(division=args.division, ppc=args.ppc, strategy=args.strategy,
        shard_counts=shard_counts, json_path=args.json)


if __name__ == "__main__":
    main()
