"""SFC figure: space-filling-curve cluster layout vs packed rows by fill.

The packed-row layout (``fig_packed``) strips slot padding inside active
pencils but still visits every pencil window dense in the stencil; the SFC
cluster layout (``plan(..., strategy="cell_dense", layout="sfc")``) bins
cells into Morton-ordered clusters and compresses the *schedule* itself — a
static ``pair_cap``-bounded list of (cluster, stencil-slot) codes that only
names cluster pairs where both sides hold particles. On clustered scenes
the kept-pair list collapses with the occupied fraction, so the win grows
as the blob tightens. This benchmark sweeps ppc ∈ {1, 2, 4, 8} on the
gaussian-blob scenario and reports

    speedup = t(compacted packed xpencil) / t(sfc cell_dense)

per case, with the measured ``pair_cap`` / kept-pair count alongside, plus
the model-vs-measured traffic drift of the sfc candidate (``repro.obs
.audit``) so the perf history renders the sfc rows with their audit.

Both timed paths are executed once on the same positions and checked
bit-for-bit against their own strategy's dense schedule before anything is
timed — a benchmark that silently drifted from the oracle would be worse
than no benchmark.

``--json PATH`` writes the timings as BENCH_*.json perf records (with a
``layout`` tag and ppc/m_c/pair_cap/speedup/drift extras); the committed
``benchmarks/BENCH_sfc.json`` is this module's output on the reference
container and is diffed (report-only) by the CI docs job.
"""

from __future__ import annotations

import argparse
import sys
from typing import List, Optional, Sequence

import jax
import numpy as np

from repro.core import (Domain, ParticleState, make_lennard_jones, plan,
                        scenarios, suggest_m_c)
from repro.obs import audit

from .common import bench_record, time_fn, write_bench_json

DEFAULT_PPCS = (1, 2, 4, 8)


def run(csv: bool = True, json_path: Optional[str] = None,
        record_sink: Optional[List[dict]] = None, division: int = 12,
        ppcs: Sequence[int] = DEFAULT_PPCS, sigma_frac: float = 0.18,
        seed: int = 0, budget_s: float = 1.0) -> List[dict]:
    dom = Domain.cubic(division, cutoff=1.0)
    kern = make_lennard_jones()
    rows: List[dict] = []
    records: List[dict] = []
    if csv:
        print("name,us_per_call,derived")
    for ppc in ppcs:
        case = f"sfc/blob_ppc{ppc}"
        n = ppc * dom.n_cells
        pos = scenarios.sample_gaussian_blob(
            dom, jax.random.PRNGKey(seed), n, sigma_frac=sigma_frac)
        m_c = suggest_m_c(dom, pos)
        state = ParticleState(pos)
        p_cell = plan(dom, kern, m_c=m_c, strategy="cell_dense",
                      backend="reference")
        p_sfc = plan(dom, kern, m_c=m_c, strategy="cell_dense",
                     backend="reference", layout="sfc", positions=pos)
        p_pack = plan(dom, kern, m_c=m_c, strategy="xpencil",
                      backend="reference", compact=True, layout="packed",
                      positions=pos)
        p_xp = plan(dom, kern, m_c=m_c, strategy="xpencil",
                    backend="reference")

        # correctness gate: each timed path must agree with its own
        # strategy's dense schedule bit-for-bit on the scene it is about
        # to be timed on
        anchors = {"cell_dense": p_cell.execute(state),
                   "xpencil": p_xp.execute(state)}
        ok = True
        for name, p in (("sfc", p_sfc), ("packed", p_pack)):
            f_a, q_a = anchors[p.strategy]
            f, q = p.execute(state)
            if not (np.array_equal(np.asarray(f_a), np.asarray(f))
                    and np.array_equal(np.asarray(q_a), np.asarray(q))):
                print(f"fig_sfc: {case}: {name} result DIVERGED from its "
                      "dense anchor — not timing a wrong answer",
                      file=sys.stderr)
                ok = False
        if not ok:
            continue

        t_p, r_p = time_fn(p_pack.execute, state, budget_s=budget_s)
        t_s, r_s = time_fn(p_sfc.execute, state, budget_s=budget_s)
        speedup = t_p / t_s
        drift = audit.audit_candidate(dom, pos, strategy="cell_dense",
                                      m_c=m_c, layout="sfc")["drift"]
        row = {"case": case, "ppc": ppc, "m_c": m_c,
               "pair_cap": p_sfc.pair_cap, "packed_s": t_p, "sfc_s": t_s,
               "speedup": speedup, "drift": drift}
        rows.append(row)
        records.append(dict(bench_record(case, "xpencil_packed",
                                         "reference", t_p, r_p,
                                         layout="packed"),
                            ppc=ppc, m_c=m_c, row_cap=p_pack.row_cap))
        records.append(dict(bench_record(case, "cell_sfc", "reference",
                                         t_s, r_s, layout="sfc",
                                         drift=drift),
                            ppc=ppc, m_c=m_c, pair_cap=p_sfc.pair_cap,
                            speedup_vs_packed=speedup))
        if csv:
            print(f"{case}/xpencil_packed,{t_p * 1e6:.1f},"
                  f"row_cap={p_pack.row_cap}")
            print(f"{case}/cell_sfc,{t_s * 1e6:.1f},"
                  f"pair_cap={p_sfc.pair_cap};speedup={speedup:.2f};"
                  f"drift={drift:+.2f}")
    if json_path:
        write_bench_json(json_path, records)
    if record_sink is not None:
        record_sink.extend(records)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--division", type=int, default=12,
                    help="cells per axis")
    ap.add_argument("--ppc", type=int, nargs="+", default=list(DEFAULT_PPCS),
                    help="global particles-per-cell sweep")
    ap.add_argument("--sigma", type=float, default=0.18,
                    help="gaussian blob sigma as a fraction of the box")
    ap.add_argument("--budget", type=float, default=1.0,
                    help="stopwatch budget per case (seconds)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write BENCH_*.json perf records to PATH")
    args = ap.parse_args()
    run(division=args.division, ppcs=tuple(args.ppc),
        sigma_frac=args.sigma, budget_s=args.budget, json_path=args.json)


if __name__ == "__main__":
    main()
