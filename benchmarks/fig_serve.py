"""Serving figure: open-loop latency/throughput of the batching front door.

Drives :class:`repro.serve.ServingEngine` with a synthetic open-loop
workload: Poisson arrivals (seeded PRNG — the schedule is reproducible)
over a request mix sampled from the scenario family, each request its own
``(N, scenario)`` draw. Arrivals run on a VirtualClock — the schedule is
simulated, but every dispatched batch advances the clock by its *measured*
wall time, so queueing and service compose into honest latencies on any
container.

Two committed mixes:

* ``uniform``  — homogeneous scenes, N drawn from two adjacent shape
  classes (the steady-state best case: few classes, high batch fill).
* ``clustered`` — skewed N distribution (power-law-ish over four classes)
  and inhomogeneous scenes (blobs, two-phase droplets), the shape-class
  fragmentation stress case.

Per mix the engine is warmed on one full pass (plans built, executors
traced, autotune winners cached), then re-measured on a fresh clock +
fresh metrics; the steady-state pass asserts **zero recompiles** via the
core counters. Before anything is timed, a parity gate executes a probe
request per shape class and compares the engine's response bit-for-bit
against an unbatched ``plan.execute`` of the same state — a serving tier
that changed answers would be worse than a slow one.

``--json PATH`` writes BENCH_*.json perf records (us_per_call = mean
total latency; rps / p50_ms / p99_ms / batch_fill extras); the committed
``benchmarks/BENCH_serve.json`` is this module's output on the reference
container.

``--chaos`` runs the resilience variant instead: the same open-loop
workload re-driven under a seeded ``repro.testing.chaos`` fault schedule
(transient dispatch errors, stragglers, non-finite outputs). The gate is
liveness, not latency: the queue must fully drain with every request
reaching a definite terminal status. Records carry the fault / retry /
shed counters (rendered by ``perf_history``'s resilience column) and the
chaos registry snapshot.
"""

from __future__ import annotations

import argparse
import sys
from typing import List, Optional

import jax
import numpy as np

from repro.core import Domain, ParticleState, scenarios
from repro.core import api, autotune as at
from repro.serve import ServeMetrics, ServingEngine, VirtualClock, classify

from .common import bench_record, write_bench_json

# Each mix: (name, [(weight, n, scenario knobs), ...]).  N values straddle
# shape-class boundaries on purpose: 50/60 share the n_cap-64 class,
# 100/200/250 spread across 128/256.
MIXES = [
    ("uniform", [
        (0.5, 50, dict(name="uniform")),
        (0.3, 60, dict(name="uniform")),
        (0.2, 100, dict(name="uniform")),
    ]),
    ("clustered", [
        (0.55, 50, dict(name="gaussian_blob", sigma_frac=0.15)),
        (0.25, 100, dict(name="two_phase", droplet_frac=0.7,
                         radius_frac=0.2)),
        (0.15, 200, dict(name="gaussian_blob", sigma_frac=0.10)),
        (0.05, 250, dict(name="uniform")),
    ]),
]


def _sample_requests(dom: Domain, mix, n_requests: int, rate: float,
                     seed: int):
    """The open-loop schedule: (arrival_time, state) pairs, Poisson
    arrivals at ``rate`` req/s, mix sampled by weight — all from one
    seeded PRNG so every run replays the identical workload."""
    rng = np.random.default_rng(seed)
    weights = np.array([w for w, _, _ in mix], float)
    weights /= weights.sum()
    t = 0.0
    out = []
    for i in range(n_requests):
        t += rng.exponential(1.0 / rate)
        _, n, knobs = mix[rng.choice(len(mix), p=weights)]
        key = jax.random.PRNGKey(int(rng.integers(1 << 30)))
        pos = scenarios.sample(domain=dom, key=key, n=n, **knobs)
        out.append((t, ParticleState(pos)))
    return out


def _drive(eng: ServingEngine, dom: Domain, requests) -> None:
    clock = eng.clock
    for t_arrival, state in requests:
        clock.advance_to(t_arrival)
        eng.poll()                       # dispatch overdue buckets first
        eng.submit(dom, state)
    clock.advance(eng.max_wait)
    eng.flush()


def _parity_gate(eng: ServingEngine, dom: Domain, requests) -> bool:
    """One probe per shape class through the warm engine, checked
    bit-for-bit against the unbatched reference executor."""
    probes = {}
    for _, state in requests:
        sc = classify(dom, eng.kernel, state.positions.shape[0],
                      tuple(state.fields), eng.min_n_cap)
        probes.setdefault(sc, state)
    ok = True
    for sc, state in probes.items():
        rid = eng.submit(dom, state)
        eng.flush()
        resp = {r.req_id: r for r in eng.take_responses()}[rid]
        f_ref, u_ref = eng.class_plan(sc).execute(state)
        if not (np.array_equal(np.asarray(resp.forces), np.asarray(f_ref))
                and np.array_equal(np.asarray(resp.potential),
                                   np.asarray(u_ref))):
            print(f"fig_serve: {sc.label()}: batched response DIVERGED "
                  "from plan.execute — not timing a wrong answer",
                  file=sys.stderr)
            ok = False
    return ok


def run(csv: bool = True, json_path: Optional[str] = None,
        record_sink: Optional[List[dict]] = None, division: int = 4,
        n_requests: int = 200, rate: float = 200.0, max_batch: int = 8,
        seed: int = 0) -> List[dict]:
    dom = Domain.cubic(division, cutoff=1.0)
    rows: List[dict] = []
    records: List[dict] = []
    if csv:
        print("mix,rps,p50_ms,p99_ms,batch_fill,recompiles")
    for mix_name, mix in MIXES:
        requests = _sample_requests(dom, mix, n_requests, rate, seed)
        eng = ServingEngine(max_batch=max_batch, max_wait=2.0 / rate,
                            max_queue=4 * n_requests)

        # warmup: drive once (plans + autotune winners), then prewarm
        # every (class, batch-size) executor shape the dispatcher could
        # form — bucket composition varies with service time, and an
        # untraced part-full batch would be a steady-state recompile
        _drive(eng, dom, requests)
        eng.take_responses()
        probes = {}
        for _, state in requests:
            sc = classify(dom, eng.kernel, state.positions.shape[0],
                          tuple(state.fields), eng.min_n_cap)
            probes.setdefault(sc, state)
        for state in probes.values():
            eng.prewarm(dom, state)
        if not _parity_gate(eng, dom, requests):
            continue

        # steady-state pass: fresh clock + metrics, warm executors
        eng.clock = VirtualClock()
        eng.metrics = ServeMetrics()
        rc0, tr0 = api.recompile_count(), at.timing_run_count()
        _drive(eng, dom, requests)
        eng.take_responses()
        snap = eng.metrics.snapshot()
        if (api.recompile_count() != rc0 or at.timing_run_count() != tr0
                or snap["served"] != n_requests):
            print(f"fig_serve: {mix_name}: steady state violated "
                  f"(recompiles={api.recompile_count() - rc0}, "
                  f"timing_runs={at.timing_run_count() - tr0}, "
                  f"served={snap['served']}/{n_requests}) — not recording",
                  file=sys.stderr)
            continue

        total = snap["total_latency"]
        row = {"mix": mix_name, "rps": snap["rps"],
               "p50_ms": total["p50_s"] * 1e3,
               "p99_ms": total["p99_s"] * 1e3,
               "batch_fill": snap["batch_fill"],
               "batches": snap["batches"], "served": snap["served"]}
        rows.append(row)
        records.append(dict(
            bench_record(f"serve/{mix_name}", "serve", "reference",
                         total["mean_s"], snap["served"]),
            rps=row["rps"], p50_ms=row["p50_ms"], p99_ms=row["p99_ms"],
            batch_fill=row["batch_fill"], max_batch=max_batch,
            arrival_rate=rate))
        if csv:
            print(f"serve/{mix_name},{row['rps']:.1f},"
                  f"{row['p50_ms']:.2f},{row['p99_ms']:.2f},"
                  f"{row['batch_fill']:.3f},0")
    if json_path:
        write_bench_json(json_path, records)
    if record_sink is not None:
        record_sink.extend(records)
    return rows


def run_chaos(csv: bool = True, json_path: Optional[str] = None,
              record_sink: Optional[List[dict]] = None, division: int = 4,
              n_requests: int = 100, rate: float = 200.0,
              max_batch: int = 8, seed: int = 0,
              fault_seed: int = 1234) -> List[dict]:
    """The resilience figure: the uniform/clustered workloads re-driven
    under a seeded fault schedule. Asserts the queue drains and every
    request terminates with a definite status; returns per-mix rows and
    (optionally) BENCH records carrying the fault/retry/shed counters."""
    from collections import Counter

    from repro.serve import RESPONSE_STATUSES
    from repro.testing import chaos

    dom = Domain.cubic(division, cutoff=1.0)
    rows: List[dict] = []
    records: List[dict] = []
    if csv:
        print("mix,served,failed,deadline,faults,retries,breaker_opens")
    for mix_name, mix in MIXES:
        requests = _sample_requests(dom, mix, n_requests, rate, seed)
        eng = ServingEngine(max_batch=max_batch, max_wait=2.0 / rate,
                            max_queue=4 * n_requests)
        _drive(eng, dom, requests)          # fault-free warmup pass
        eng.take_responses()

        eng.clock = VirtualClock()
        eng.metrics = ServeMetrics()
        specs = (
            chaos.FaultSpec("serve.dispatch", "error", p=0.15),
            chaos.FaultSpec("serve.dispatch", "delay", p=0.10, param=0.02),
            chaos.FaultSpec("serve.dispatch", "nonfinite", p=0.05),
        )
        with chaos.inject(*specs, seed=fault_seed):
            _drive(eng, dom, requests)
            # drain the retry backlog: advance past backoff holdbacks and
            # flush until nothing is pending (bounded — every retry has a
            # finite attempt budget, so this terminates)
            for _ in range(100 * n_requests):
                if eng.pending() == 0:
                    break
                eng.clock.advance(eng.retry_cap_s)
                eng.flush()
            fault_log = chaos.snapshot()

        responses = eng.take_responses()
        statuses = Counter(r.status for r in responses)
        snap = eng.metrics.snapshot()
        if eng.pending() != 0 or len(responses) != n_requests or not all(
                s in RESPONSE_STATUSES for s in statuses):
            print(f"fig_serve: {mix_name}: chaos workload did NOT drain "
                  f"(pending={eng.pending()}, responses={len(responses)}/"
                  f"{n_requests}, statuses={dict(statuses)}) — not "
                  "recording", file=sys.stderr)
            continue

        total = snap["total_latency"]
        row = {"mix": mix_name, "served": snap["served"],
               "failed": snap["failed"],
               "deadline_expired": snap["deadline_expired"],
               "faults": snap["faults"], "retries": snap["retries"],
               "shed": snap["shed"],
               "breaker_opens": snap["breaker_opens"],
               "statuses": dict(statuses)}
        rows.append(row)
        mean_s = total["mean_s"] if snap["served"] else 0.0
        records.append(dict(
            bench_record(f"serve_chaos/{mix_name}", "serve", "reference",
                         mean_s, max(snap["served"], 1)),
            rps=snap["rps"], faults=snap["faults"],
            retries=snap["retries"], shed=snap["shed"],
            failed=snap["failed"],
            deadline_expired=snap["deadline_expired"],
            breaker_opens=snap["breaker_opens"],
            nonfinite_batches=snap["nonfinite_batches"],
            fault_seed=fault_seed, fault_log=fault_log))
        if csv:
            print(f"serve_chaos/{mix_name},{row['served']},{row['failed']},"
                  f"{row['deadline_expired']},{row['faults']},"
                  f"{row['retries']},{row['breaker_opens']}")
    if json_path:
        write_bench_json(json_path, records)
    if record_sink is not None:
        record_sink.extend(records)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--division", type=int, default=4,
                    help="cells per axis")
    ap.add_argument("--requests", type=int, default=200,
                    help="requests per mix")
    ap.add_argument("--rate", type=float, default=200.0,
                    help="Poisson arrival rate (req/s, virtual)")
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write BENCH_*.json perf records to PATH")
    ap.add_argument("--chaos", action="store_true",
                    help="run the fault-injection resilience variant")
    ap.add_argument("--fault-seed", type=int, default=1234,
                    help="chaos schedule seed (with --chaos)")
    args = ap.parse_args()
    if args.chaos:
        rows = run_chaos(division=args.division, n_requests=args.requests,
                         rate=args.rate, max_batch=args.max_batch,
                         json_path=args.json, fault_seed=args.fault_seed)
        if len(rows) != len(MIXES):
            sys.exit(1)                  # a mix failed to drain
    else:
        run(division=args.division, n_requests=args.requests,
            rate=args.rate, max_batch=args.max_batch, json_path=args.json)


if __name__ == "__main__":
    main()
