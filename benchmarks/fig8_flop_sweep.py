"""Figure 8: execution time vs arithmetic intensity (5 / 21 / 168 FLOP).

The paper's claim under test: X-pencil wins in the memory-bound (low-FLOP)
regime and loses its edge as FLOP/interaction grows — the staged-byte
savings become negligible against compute. Same kernels as the paper:
low_flop (~5), Lennard-Jones (21), high_flop (LJ + 150).
"""

from __future__ import annotations

import argparse
from typing import List

from repro.core import make_high_flop, make_lennard_jones, make_low_flop

from .common import paper_case, time_fn

KERNELS = [("low_flop", make_low_flop), ("lj", make_lennard_jones),
           ("high_flop", make_high_flop)]
STRATEGIES = ["par_part", "cell_dense", "xpencil"]


def run(division: int = 8, ppc: int = 10, csv: bool = True) -> List[dict]:
    rows = []
    if csv:
        print("name,us_per_call,derived")
    for kname, kmk in KERNELS:
        kern = kmk()
        base = None
        for strat in STRATEGIES:
            dom, pos, eng = paper_case(division, ppc, strategy=strat,
                                       kernel=kern)
            secs, reps = time_fn(eng.compute, pos)
            if strat == "par_part":
                base = secs
            rows.append({"kernel": kname, "flops": kern.flops,
                         "strategy": strat, "seconds": secs,
                         "speedup_vs_par_part": base / secs})
            if csv:
                print(f"fig8/{kname}/{strat}/d{division}_p{ppc},"
                      f"{secs * 1e6:.1f},"
                      f"flops={kern.flops};speedup={base / secs:.3f}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--division", type=int, default=8)
    ap.add_argument("--ppc", type=int, default=10)
    args = ap.parse_args()
    run(args.division, args.ppc)


if __name__ == "__main__":
    main()
