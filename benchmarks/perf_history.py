"""Perf-trajectory renderer: the time series across BENCH_*.json records.

``perf_diff`` gives the pairwise delta between two record files; this tool
ingests a *directory* of successive ``BENCH_*.json`` snapshots (the CI
artifacts the benchmark runners emit per commit) and renders the per-case
trajectory::

    python -m benchmarks.perf_history DIR [--case SUBSTR] [--order name]
        [--json PATH]

Snapshots are ordered by filename by default (name your artifacts
``BENCH_0017_<sha>.json`` and lexicographic order is commit order) or by
mtime with ``--order mtime``. Output is one row per (case, strategy,
backend) series: first/last us_per_call, total delta, a unicode sparkline
of the whole trajectory, and the execution-layout tag (dense / compact /
packed — from the record's ``layout`` field, inferred from the strategy
suffix for older records) — the visible per-commit perf record the
ROADMAP asks for. Trajectory records (``fig_traj``) additionally render
their ``rebin`` rate (rebins / n_steps of the fused Verlet-skin engine)
and chaos records their resilience counters. ``--json`` additionally dumps the raw series for
downstream plotting.

Record files use the ``benchmarks.common.bench_record`` schema; duplicate
keys inside one snapshot keep the fastest record (same join rule as
``perf_diff``).
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
from typing import Dict, List, Optional, Tuple

from .perf_diff import Key, load_records

_SPARK = "▁▂▃▄▅▆▇█"
_GAP = "·"                       # case absent from that snapshot


def collect(directory: str | pathlib.Path, pattern: str = "BENCH_*.json",
            order: str = "name") -> List[Tuple[str, Dict[Key, dict]]]:
    """-> ordered [(snapshot label, {(case, strategy, backend): record})].

    Unreadable or schema-violating files are skipped with a warning — a
    single corrupt artifact must not take down the whole trajectory.
    """
    root = pathlib.Path(directory)
    files = sorted(root.glob(pattern),
                   key=(lambda p: p.stat().st_mtime) if order == "mtime"
                   else (lambda p: p.name))
    out: List[Tuple[str, Dict[Key, dict]]] = []
    for f in files:
        try:
            out.append((f.name, load_records(str(f))))
        except (OSError, ValueError, KeyError, json.JSONDecodeError) as e:
            print(f"perf_history: skipping {f.name}: {e!r}",
                  file=sys.stderr)
    return out


def series(snapshots: List[Tuple[str, Dict[Key, dict]]],
           case_filter: Optional[str] = None
           ) -> Dict[Key, List[Optional[float]]]:
    """-> {key: [us_per_call or None per snapshot]}, keys sorted."""
    keys = set()
    for _, recs in snapshots:
        keys.update(recs)
    if case_filter:
        keys = {k for k in keys if case_filter in k[0]}
    return {k: [recs.get(k, {}).get("us_per_call") for _, recs in snapshots]
            for k in sorted(keys)}


def layout_of(snapshots: List[Tuple[str, Dict[Key, dict]]],
              key: Key) -> str:
    """Execution-layout tag of a series: the latest record's ``layout``
    field **verbatim**, else inferred from the strategy suffix (records
    predating the tag), so the trajectory distinguishes dense / compact /
    packed rows. An explicit field always wins — second-guessing it from
    the strategy name would silently mislabel layouts the suffix rule
    doesn't know (e.g. a future ``sfc`` layout rendering as ``dense``)."""
    for _, recs in reversed(snapshots):
        rec = recs.get(key)
        if rec is not None and "layout" in rec:
            return rec["layout"]
    return _infer_layout(key[1])


def serving_of(snapshots: List[Tuple[str, Dict[Key, dict]]],
               key: Key) -> Tuple[str, str]:
    """Serving-tier columns of a series: the latest record's throughput
    (``rps``) and tail latency (``p99_ms``) extras, as rendered strings.
    Non-serving records (no ``rps`` field) render as ``-`` so the columns
    stay aligned across the whole table."""
    for _, recs in reversed(snapshots):
        rec = recs.get(key)
        if rec is not None and "rps" in rec:
            p99 = rec.get("p99_ms")
            return (f"{rec['rps']:.1f}",
                    "-" if p99 is None else f"{p99:.2f}")
    return "-", "-"


def resilience_of(snapshots: List[Tuple[str, Dict[Key, dict]]],
                  key: Key) -> str:
    """Resilience column of a series: the latest record's fault/retry/shed
    counters as ``f<faults>/r<retries>/s<shed>``. Records predating the
    counters (or with all three at zero) render as ``-`` so ordinary perf
    tables stay uncluttered — the column only lights up for chaos runs."""
    for _, recs in reversed(snapshots):
        rec = recs.get(key)
        if rec is not None and any(k in rec
                                   for k in ("faults", "retries", "shed")):
            f = int(rec.get("faults", 0))
            r = int(rec.get("retries", 0))
            s = int(rec.get("shed", 0))
            if f == 0 and r == 0 and s == 0:
                return "-"
            return f"f{f}/r{r}/s{s}"
    return "-"


def rebin_of(snapshots: List[Tuple[str, Dict[Key, dict]]],
             key: Key) -> str:
    """Rebin-rate column of a series: the latest record's ``rebin_rate``
    extra (rebins / n_steps of a fused trajectory run, ``fig_traj``) —
    the visible cost of the Verlet-skin contract. Non-trajectory records
    render as ``-``."""
    for _, recs in reversed(snapshots):
        rec = recs.get(key)
        if rec is not None and "rebin_rate" in rec:
            return f"{float(rec['rebin_rate']):.3f}"
    return "-"


def drift_of(snapshots: List[Tuple[str, Dict[Key, dict]]],
             key: Key) -> str:
    """Model-drift column of a series: the latest record's ``drift`` field
    (relative model-vs-measured traffic error from ``repro.obs.audit``,
    attached by benchmarks that run the audit). Records without an audit
    render as ``-``."""
    for _, recs in reversed(snapshots):
        rec = recs.get(key)
        if rec is not None and "drift" in rec:
            return f"{float(rec['drift']):+.2f}"
    return "-"


def _infer_layout(strategy: str) -> str:
    for suffix, tag in (("_packed", "packed"), ("_compact", "compact"),
                        ("_sfc", "sfc")):
        if strategy.endswith(suffix):
            return tag
    return "dense"


def sparkline(values: List[Optional[float]]) -> str:
    """Unicode trajectory; gaps (absent snapshots) render as ``·``."""
    present = [v for v in values if v is not None]
    if not present:
        return _GAP * len(values)
    lo, hi = min(present), max(present)
    span = (hi - lo) or 1.0
    out = []
    for v in values:
        if v is None:
            out.append(_GAP)
        else:
            out.append(_SPARK[int((v - lo) / span * (len(_SPARK) - 1))])
    return "".join(out)


def format_table(snapshots: List[Tuple[str, Dict[Key, dict]]],
                 ss: Dict[Key, List[Optional[float]]]) -> str:
    lines = [f"# {len(snapshots)} snapshots: "
             + " -> ".join(label for label, _ in snapshots),
             "case,strategy,backend,first_us,last_us,delta_pct,trajectory,"
             "rebin,rps,p99_ms,resilience,drift,layout"]
    for key, vals in ss.items():
        present = [(i, v) for i, v in enumerate(vals) if v is not None]
        if not present:
            continue
        first, last = present[0][1], present[-1][1]
        delta = (last / first - 1.0) * 100.0 if first > 0 else float("inf")
        rps, p99 = serving_of(snapshots, key)
        lines.append(f"{key[0]},{key[1]},{key[2]},{first:.1f},{last:.1f},"
                     f"{delta:+.1f}%,{sparkline(vals)},"
                     f"{rebin_of(snapshots, key)},{rps},{p99},"
                     f"{resilience_of(snapshots, key)},"
                     f"{drift_of(snapshots, key)},"
                     f"{layout_of(snapshots, key)}")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("directory", help="directory of BENCH_*.json snapshots")
    ap.add_argument("--pattern", default="BENCH_*.json")
    ap.add_argument("--case", default=None,
                    help="only series whose case contains this substring")
    ap.add_argument("--order", choices=("name", "mtime"), default="name",
                    help="snapshot ordering (default: filename)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also dump the raw series as JSON")
    args = ap.parse_args(argv)

    snapshots = collect(args.directory, pattern=args.pattern,
                        order=args.order)
    if not snapshots:
        print(f"perf_history: no {args.pattern} files in "
              f"{args.directory}", file=sys.stderr)
        return 1
    ss = series(snapshots, case_filter=args.case)
    print(format_table(snapshots, ss))
    if args.json:
        payload = {
            "snapshots": [label for label, _ in snapshots],
            "series": [{"case": k[0], "strategy": k[1], "backend": k[2],
                        "layout": layout_of(snapshots, k),
                        "rebin": rebin_of(snapshots, k),
                        "rps": serving_of(snapshots, k)[0],
                        "p99_ms": serving_of(snapshots, k)[1],
                        "resilience": resilience_of(snapshots, k),
                        "drift": drift_of(snapshots, k),
                        "us_per_call": v} for k, v in ss.items()],
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"wrote {len(ss)} series to {args.json}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
