"""Roofline table reader: formats experiments/ dry-run + cost-run JSONs.

Not a timing benchmark — renders §Roofline of EXPERIMENTS.md from the
artifacts produced by ``repro.launch.dryrun`` and ``repro.launch.costrun``.
"""

from __future__ import annotations

import json
import pathlib

ROOT = pathlib.Path(__file__).resolve().parents[1] / "experiments"


def load_records(sub: str = "dryrun"):
    recs = []
    d = ROOT / sub
    if not d.exists():
        return recs
    for p in sorted(d.glob("*.json")):
        try:
            recs.append(json.loads(p.read_text()))
        except Exception:
            pass
    return recs


def run(csv: bool = True, sub: str = "dryrun"):
    recs = load_records(sub)
    if csv:
        print("name,us_per_call,derived")
    for r in recs:
        key = f"{sub}/{r.get('arch')}/{r.get('shape')}/{r.get('mesh')}"
        if r.get("tag"):
            key += f"/{r['tag']}"
        if "skipped" in r:
            print(f"{key},0.0,SKIP:{r['skipped'][:80]}")
            continue
        if "error" in r:
            print(f"{key},0.0,ERROR:{r['error'][:80]}")
            continue
        rl = r["roofline"]
        mem = r.get("memory_analysis", {})
        dom_t = max(rl["compute_s"], rl["memory_s"], rl["collective_s"])
        print(f"{key},{dom_t * 1e6:.1f},"
              f"dom={rl['dominant']};compute_s={rl['compute_s']:.4f};"
              f"memory_s={rl['memory_s']:.4f};"
              f"collective_s={rl['collective_s']:.4f};"
              f"useful={rl['useful_ratio']:.3f};"
              f"temp_GiB={mem.get('temp_size_in_bytes', 0) / 2**30:.1f}")
    return recs


if __name__ == "__main__":
    run()
