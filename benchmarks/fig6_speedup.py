"""Figure 6: strategy speedup vs Par-Part (the paper's PPNL baseline).

Paper grid: box division d in {2,4,8,16,32} x avg particles/cell in
{1,10,100}, uniform particles, LJ kernel, single precision. The y-value is
speedup = t(par_part) / t(strategy); the x-axis is measured interactions per
particle. CPU sizing note: the largest cases are capped unless --full
(1-core container; the paper's trend region is fully covered).

``--json PATH`` additionally emits the timings as BENCH_*.json perf records
(case, strategy, backend, us_per_call, reps, platform).
"""

from __future__ import annotations

import argparse
import math
import sys
from typing import List, Optional

from .common import (bench_record, interactions_per_particle, paper_plan,
                     time_fn, write_bench_json)

STRATEGIES = ["par_part", "cell_dense", "xpencil", "allin"]

DEFAULT_GRID = [(2, 1), (4, 1), (8, 1), (16, 1), (32, 1),
                (2, 10), (4, 10), (8, 10), (16, 10),
                (2, 100), (4, 100), (8, 100)]
FULL_GRID = [(d, p) for p in (1, 10, 100) for d in (2, 4, 8, 16, 32)]


def run(full: bool = False, csv: bool = True, backend: str = "reference",
        json_path: Optional[str] = None,
        record_sink: Optional[List[dict]] = None) -> List[dict]:
    grid = FULL_GRID if full else DEFAULT_GRID
    rows = []
    records = []
    if csv:
        print("name,us_per_call,derived")
    for division, ppc in grid:
        times = {}
        reps = {}
        backends = {strat: backend if strat in ("xpencil", "allin")
                    else "reference" for strat in STRATEGIES}
        for strat in STRATEGIES:
            strat_backend = backends[strat]
            try:
                _, state, _, execute = paper_plan(division, ppc,
                                                  strategy=strat,
                                                  backend=strat_backend)
                times[strat], reps[strat] = time_fn(execute, state)
            except Exception as e:   # allin needs >= 27 cells etc. — but a
                # real failure (bad backend registration, shape bug) must
                # not silently become a NaN row:
                print(f"fig6: strategy {strat!r} (backend {strat_backend!r})"
                      f" failed on d{division}_p{ppc}: {e!r}",
                      file=sys.stderr)
                times[strat] = float("nan")
        ipp = interactions_per_particle(division, ppc)
        base = times["par_part"]
        for strat in STRATEGIES:
            failed = math.isnan(times[strat])
            speedup = float("nan") if failed else base / times[strat]
            row = {"division": division, "ppc": ppc, "strategy": strat,
                   "seconds": times[strat], "speedup_vs_par_part": speedup,
                   "interactions_per_particle": ipp}
            rows.append(row)
            if not failed:
                records.append(bench_record(
                    f"fig6/d{division}_p{ppc}", strat, backends[strat],
                    times[strat], reps[strat]))
            if csv:
                print(f"fig6/{strat}/d{division}_p{ppc},"
                      f"{times[strat] * 1e6:.1f},"
                      f"speedup={speedup:.3f};ipp={ipp:.1f}")
    if json_path:
        write_bench_json(json_path, records)
    if record_sink is not None:
        record_sink.extend(records)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--backend", default="reference",
                    choices=["reference", "pallas"],
                    help="pallas times the TPU kernels (native on TPU; "
                         "interpret mode elsewhere benchmarks the "
                         "interpreter, so keep reference on CPU)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write BENCH_*.json perf records to PATH")
    args = ap.parse_args()
    run(full=args.full, backend=args.backend, json_path=args.json)


if __name__ == "__main__":
    main()
