"""Figure 6: strategy speedup vs Par-Part (the paper's PPNL baseline).

Paper grid: box division d in {2,4,8,16,32} x avg particles/cell in
{1,10,100}, uniform particles, LJ kernel, single precision. The y-value is
speedup = t(par_part) / t(strategy); the x-axis is measured interactions per
particle. CPU sizing note: the largest cases are capped unless --full
(1-core container; the paper's trend region is fully covered).
"""

from __future__ import annotations

import argparse
from typing import List

from .common import interactions_per_particle, paper_plan, time_fn

STRATEGIES = ["par_part", "cell_dense", "xpencil", "allin"]

DEFAULT_GRID = [(2, 1), (4, 1), (8, 1), (16, 1), (32, 1),
                (2, 10), (4, 10), (8, 10), (16, 10),
                (2, 100), (4, 100), (8, 100)]
FULL_GRID = [(d, p) for p in (1, 10, 100) for d in (2, 4, 8, 16, 32)]


def run(full: bool = False, csv: bool = True,
        backend: str = "reference") -> List[dict]:
    grid = FULL_GRID if full else DEFAULT_GRID
    rows = []
    if csv:
        print("name,us_per_call,derived")
    for division, ppc in grid:
        times = {}
        for strat in STRATEGIES:
            try:
                strat_backend = backend if strat in ("xpencil", "allin") \
                    else "reference"
                _, state, _, execute = paper_plan(division, ppc,
                                                  strategy=strat,
                                                  backend=strat_backend)
                secs, reps = time_fn(execute, state)
                times[strat] = secs
            except Exception:   # allin needs >= 27 cells etc.
                times[strat] = float("nan")
        ipp = interactions_per_particle(division, ppc)
        base = times["par_part"]
        for strat in STRATEGIES:
            speedup = base / times[strat] if times[strat] == times[strat] \
                else float("nan")
            row = {"division": division, "ppc": ppc, "strategy": strat,
                   "seconds": times[strat], "speedup_vs_par_part": speedup,
                   "interactions_per_particle": ipp}
            rows.append(row)
            if csv:
                print(f"fig6/{strat}/d{division}_p{ppc},"
                      f"{times[strat] * 1e6:.1f},"
                      f"speedup={speedup:.3f};ipp={ipp:.1f}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--backend", default="reference",
                    choices=["reference", "pallas"],
                    help="pallas times the TPU kernels (native on TPU; "
                         "interpret mode elsewhere benchmarks the "
                         "interpreter, so keep reference on CPU)")
    args = ap.parse_args()
    run(full=args.full, backend=args.backend)


if __name__ == "__main__":
    main()
