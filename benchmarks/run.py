"""Benchmark runner: one section per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--full] [--skip-timing]
[--json PATH]`` prints ``name,us_per_call,derived`` CSV blocks:

  fig6/*      strategy speedups vs Par-Part (paper Fig. 6)
  table1/*    PPNL vs X-pencil seconds (paper Table 1)
  fig8/*      arithmetic-intensity sweep (paper Fig. 8)
  sparse/*    compacted-schedule speedup vs fill fraction (clustered scenes)
  packed/*    packed-row (CSR) layout speedup vs particles per cell
  sfc/*       SFC cluster layout (compressed pair list) vs packed rows
  traj/*      fused trajectory engine vs per-step execute loop (skin reuse)
  serve/*     serving-tier open-loop latency/throughput (batching front door)
  halo/*      distributed-backend weak scaling (smoke: whatever devices
              this process sees; full sweeps via ``benchmarks.fig_halo``)
  prefix/*    §6 prefix-sum op/barrier counts + timing
  traffic/*   Fig. 7 analogue (TPU staging-traffic model)
  autotune/*  measured winner vs the traffic model's pick
  dryrun/*    LM roofline terms from the multi-pod dry-run artifacts

``--json PATH`` additionally writes every timed section's perf records
(case, strategy, backend, us_per_call, reps, platform) as one BENCH_*.json
file — the per-commit record the perf trajectory accumulates (CI uploads it
as an artifact).
"""

from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="the complete paper grid (slow on 1 CPU core)")
    ap.add_argument("--skip-timing", action="store_true",
                    help="only the analytical/artifact-reading sections")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write all perf records to one BENCH_*.json file")
    args = ap.parse_args()

    from . import (autotune_bench, fig6_speedup, fig8_flop_sweep,
                   fig_halo, fig_packed, fig_serve, fig_sfc, fig_sparse,
                   fig_traj, lm_roofline, prefix_bench, table1_timing,
                   traffic_model)

    print("# traffic model (paper Fig. 7 analogue)", flush=True)
    traffic_model.run()
    print("# LM roofline (dry-run artifacts)", flush=True)
    lm_roofline.run()
    lm_roofline.run(sub="costrun")
    if args.skip_timing:
        if args.json:
            import sys
            print("run: --skip-timing produces no perf records; writing an "
                  f"empty {args.json}", file=sys.stderr)
            from .common import write_bench_json
            write_bench_json(args.json, [])
        return
    records: list = []
    print("# prefix sum (paper §6)", flush=True)
    prefix_bench.run()
    print("# fig6 speedups", flush=True)
    fig6_speedup.run(full=args.full, record_sink=records)
    print("# table1 PPNL vs X-pencil", flush=True)
    table1_timing.run(full=args.full, record_sink=records)
    print("# fig8 FLOP sweep", flush=True)
    fig8_flop_sweep.run()
    print("# sparse: compacted speedup vs fill fraction", flush=True)
    fig_sparse.run(record_sink=records, division=8, n=300)
    print("# packed: CSR-row layout speedup vs ppc", flush=True)
    fig_packed.run(record_sink=records, division=8, ppcs=(1, 2),
                   budget_s=0.3)
    print("# sfc: cluster pair-list layout vs packed rows", flush=True)
    fig_sfc.run(record_sink=records, division=6, ppcs=(1, 2),
                budget_s=0.3)
    print("# halo: distributed-backend smoke (local device set)",
          flush=True)
    fig_halo.run(record_sink=records, division=4, ppc=3)
    print("# traj: fused trajectory vs per-step execute loop", flush=True)
    fig_traj.run(record_sink=records, division=4,
                 ppcs=(2, 4) if not args.full else (2, 4, 8),
                 n_steps=24 if not args.full else 60)
    print("# serve: batching front door, open-loop workload", flush=True)
    fig_serve.run(record_sink=records, n_requests=60 if not args.full
                  else 200)
    print("# autotune: measured winner vs model pick", flush=True)
    autotune_bench.run(record_sink=records)
    if args.json:
        from .common import write_bench_json
        write_bench_json(args.json, records)


if __name__ == "__main__":
    main()
