"""Shared benchmark machinery.

Timing convention (paper §7.1): jit + warm-up call, then ``reps`` timed
calls, report mean microseconds. The paper uses 200 async calls; on this
1-core CPU container reps are adaptive (big cases get 3, small get 50) —
reps are printed so the CSV is self-describing. Strategies are the pure-JAX
schedule bodies (the Pallas kernels are TPU-targeted and validated in
interpret mode; timing interpret mode would benchmark the interpreter).
"""

from __future__ import annotations

import json
import pathlib
from typing import List

import jax
import jax.numpy as jnp

from repro.core import (CellListEngine, Domain, ParticleState,
                        make_lennard_jones, plan, suggest_m_c)
# The stopwatch moved into the library so the measured autotuner
# (repro.core.autotune) shares it; re-exported here for benchmark code.
from repro.core.timing import time_fn  # noqa: F401


def bench_record(case: str, strategy: str, backend: str, seconds: float,
                 reps: int, layout: str | None = None,
                 drift: float | None = None) -> dict:
    """One BENCH_*.json perf record — the schema the perf trajectory
    accumulates across PRs (CI uploads these files as artifacts).
    ``layout`` tags the execution layout (dense / compact / packed) so
    ``perf_history`` can render it; older records without the key are
    inferred from the strategy suffix. ``drift`` is the model-vs-measured
    traffic audit's relative error for this case (repro.obs.audit), when
    the benchmark computed one."""
    rec = {"case": case, "strategy": strategy, "backend": backend,
           "us_per_call": seconds * 1e6, "reps": reps,
           "platform": jax.default_backend()}
    if layout is not None:
        rec["layout"] = layout
    if drift is not None:
        rec["drift"] = float(drift)
    return rec


def write_bench_json(path: str | pathlib.Path, records: List[dict]) -> None:
    """Write perf records as a JSON array (one BENCH_*.json file).

    When tracing is on (``obs.enable()`` / ``REPRO_OBS_TRACE=1``), also
    emits the observability sidecars next to the file: the span buffer as
    ``<stem>.trace.jsonl`` + Chrome ``<stem>.trace.json``, and the metrics
    registry snapshot as ``<stem>.metrics.json`` — one traced benchmark
    run leaves its whole story on disk alongside its numbers."""
    p = pathlib.Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    with open(p, "w") as f:
        json.dump(records, f, indent=1)
    print(f"wrote {len(records)} perf records to {p}")
    from repro import obs
    if obs.tracing_enabled():
        stem = p.with_suffix("")
        n = obs.export_jsonl(stem.with_suffix(".trace.jsonl"))
        obs.export_chrome_trace(stem.with_suffix(".trace.json"))
        with open(stem.with_suffix(".metrics.json"), "w") as f:
            json.dump(obs.snapshot(), f, indent=1, default=str)
        print(f"wrote {n} spans + metrics sidecars to {stem}.*")


def paper_case(division: int, ppc: int, seed: int = 0,
               strategy: str = "xpencil", kernel=None,
               batch_size: int = 64):
    """One paper benchmark case: division^3 cells, ppc particles/cell avg,
    uniform positions (paper §7.1). Engine-shim flavour (legacy call sites)."""
    dom = Domain.cubic(division, cutoff=1.0)
    n = division ** 3 * ppc
    pos = dom.sample_uniform(jax.random.PRNGKey(seed), n)
    m_c = suggest_m_c(dom, pos)
    eng = CellListEngine(dom, kernel or make_lennard_jones(), m_c=m_c,
                         strategy=strategy, batch_size=batch_size)
    return dom, pos, eng


def paper_plan(division: int, ppc: int, seed: int = 0,
               strategy: str = "xpencil", kernel=None,
               batch_size: int = 64, backend: str = "reference"):
    """Plan/execute flavour of ``paper_case``: returns
    ``(dom, state, plan, execute)`` where ``execute(state)`` is the timed
    hot path (static planning excluded, as the paper excludes setup)."""
    dom = Domain.cubic(division, cutoff=1.0)
    n = division ** 3 * ppc
    pos = dom.sample_uniform(jax.random.PRNGKey(seed), n)
    p = plan(dom, kernel or make_lennard_jones(), positions=pos,
             strategy=strategy, backend=backend, batch_size=batch_size)
    return dom, ParticleState(pos), p, p.execute


_COUNT_KERNEL = None


def count_kernel():
    """Pair kernel whose potential channel counts interactions (x-axis of
    the paper's figures is measured, not estimated)."""
    global _COUNT_KERNEL
    if _COUNT_KERNEL is None:
        from repro.core.interactions import PairKernel
        _COUNT_KERNEL = PairKernel(
            "count", lambda r2: jnp.zeros_like(r2),
            lambda r2: jnp.ones_like(r2), flops=2)
    return _COUNT_KERNEL


def interactions_per_particle(division: int, ppc: int, seed: int = 0) -> float:
    """Measured interactions / particle for a paper case (paper's x-axis)."""
    dom, pos, eng = paper_case(division, ppc, seed, strategy="xpencil",
                               kernel=count_kernel())
    _, counts = eng.compute(pos)
    return float(jnp.sum(counts)) / pos.shape[0]
