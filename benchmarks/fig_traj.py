"""Trajectory figure: fused multi-step engine vs a per-step execute loop.

The trajectory tentpole's claim: at the paper's few-particles-per-cell
operating point, fusing bin -> force -> integrate under one jitted
``lax.scan`` with Verlet-skin neighbor reuse beats driving the same
physics as ``n_steps`` independent ``plan.execute`` dispatches — the skin
plan re-bins only when the accumulated drift demands it, so the steady
state pays one binning pass per *many* steps instead of one per step.

Sweep: gaussian-blob scenes at ppc ∈ {2, 4, 8}. Per case:

* **parity gate** (pre-timing): a short ``skin=0`` fused run must match
  the per-step ``reference_step`` loop *bit for bit* — a fused engine
  that drifted from the eager baseline is not timed, it is reported.
* the headline: fused ``skin=0`` vs the **deployed** pre-trajectory path
  (``traj_execute_api``) — an eager per-step loop where every step pays
  ``plan.execute``'s own dispatch (separate binning + force programs,
  Python glue), which is what ``physics.integrators.run`` cost before
  this engine. Bit-identical arithmetic per the parity gate.
* the tight baselines, same plan on both sides: the fused engine on the
  skin plan vs a fully-jitted one-step-per-call loop on the *same* skin
  plan (``traj_per_step``), and fused ``skin=0`` vs that loop on the
  base cutoff grid (``traj_per_step_cutoff``). Against a whole-step
  jitted loop the remaining delta is per-step binning (skipped on
  non-rebin steps) + one dispatch per step — on this CPU backend that
  is a wash at tiny n and grows with it (ppc 8: ~1.4×); the rebin
  counts riding along are the acceptance bar (rebins ≪ n_steps).
* a small skin sweep records how the rebin rate falls as the skin grows
  (the skin/rebin trade the ARCHITECTURE contract table documents).

Caveat, stated rather than hidden: on this CPU reference backend the
force pass dominates and binning is cheap, so *coarsening* the grid for
a skin costs more force work than the skipped binning saves — the
coarse-vs-fine trade only pays on accelerators where neighbor rebuilds
are the expensive part (the paper's regime). The api-loop comparison is
the backend-independent one: fusion removes per-step program dispatch
and re-binning whatever the grid.

``--chaos`` additionally runs the fused engine under an injected mid-run
NaN (``repro.testing.chaos`` site ``traj.step``) and records the
rollback-recovery counters — the resilience column of ``perf_history``.

The bounded ``low_flop`` kernel drives the dynamics: blob scenes overlap
particles, and a stiff kernel would measure float-overflow recovery
instead of scheduling. ``--json`` writes BENCH records (with
``rebin_rate`` extras); the committed ``benchmarks/BENCH_traj.json`` is
this module's output on the reference container.
"""

from __future__ import annotations

import argparse
import shutil
import sys
import tempfile
import time as _time
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Domain, plan, scenarios
from repro.core.interactions import make_low_flop
from repro.physics.integrators import init_state
from repro.testing import chaos
from repro.traj import reference_step, run_trajectory, trajectory_plan

from .common import bench_record, write_bench_json

DEFAULT_PPCS = (2, 4, 8)
SKIN_SWEEP = (0.1, 0.25, 0.5)


def _case(division: int, ppc: int, seed: int, sigma_frac: float):
    dom = Domain.cubic(division, cutoff=1.0, periodic=True)
    n = ppc * dom.n_cells
    pos = scenarios.sample_gaussian_blob(
        dom, jax.random.PRNGKey(seed), n, sigma_frac=sigma_frac)
    vel = 0.05 * jax.random.normal(jax.random.PRNGKey(seed + 1),
                                   (n, 3), jnp.float32)
    p = plan(dom, make_low_flop(), positions=pos)
    return dom, pos, vel, p


def _parity_gate(p, md0, dt: float, steps: int = 8) -> bool:
    """skin=0 fused vs eager per-step loop, bit for bit."""
    res = run_trajectory(p, md0, steps, dt, skin=0.0, segment_len=steps)
    step = jax.jit(reference_step(p))
    md = md0
    for _ in range(steps):
        md = step(md, dt)
    return all(np.array_equal(np.asarray(getattr(res.state, f)),
                              np.asarray(getattr(md, f)))
               for f in ("positions", "velocities", "forces", "potential"))


REPS = 3            # best-of-N timing: the box is 1 core, single shots flip


def _time_traj(p, md0, n_steps, dt, reps: int = REPS, **kw) -> tuple:
    """-> (best-of-reps seconds, result); first warm run pays compile."""
    run_trajectory(p, md0, n_steps, dt, **kw)          # warm the traces
    best = float("inf")
    for _ in range(reps):
        t0 = _time.perf_counter()
        res = run_trajectory(p, md0, n_steps, dt, **kw)
        jax.block_until_ready(res.state.positions)
        best = min(best, _time.perf_counter() - t0)
    return best, res


def _time_loop(p, md0, n_steps, dt, reps: int = REPS) -> float:
    step = jax.jit(reference_step(p))
    md = step(md0, dt)                                 # compile
    jax.block_until_ready(md.positions)
    best = float("inf")
    for _ in range(reps):
        t0 = _time.perf_counter()
        md = md0
        for _ in range(n_steps):
            md = step(md, dt)
        jax.block_until_ready(md.positions)
        best = min(best, _time.perf_counter() - t0)
    return best


def _time_api_loop(p, md0, n_steps, dt, reps: int = REPS) -> float:
    """The pre-trajectory API path: an *eager* per-step loop where every
    step pays ``plan.execute``'s own dispatch — a separate binning + force
    program plus the Python glue between them — which is what
    ``physics.integrators.run`` cost per step before it routed through
    the fused engine. The jitted ``_time_loop`` above is the *tight*
    baseline (whole step in one program); this is the *deployed* one."""
    step = reference_step(p)          # NOT jitted: execute dispatches per call
    md = step(md0, dt)                # warm plan.execute's executors
    jax.block_until_ready(md.positions)
    best = float("inf")
    for _ in range(reps):
        t0 = _time.perf_counter()
        md = md0
        for _ in range(n_steps):
            md = step(md, dt)
        jax.block_until_ready(md.positions)
        best = min(best, _time.perf_counter() - t0)
    return best


def run(csv: bool = True, json_path: Optional[str] = None,
        record_sink: Optional[List[dict]] = None, division: int = 6,
        ppcs: Sequence[int] = DEFAULT_PPCS, sigma_frac: float = 0.25,
        n_steps: int = 60, dt: float = 1e-3, seed: int = 0,
        chaos_run: bool = False) -> List[dict]:
    rows: List[dict] = []
    records: List[dict] = []
    if csv:
        print("name,us_per_call,derived")
    for ppc in ppcs:
        case = f"traj/blob_ppc{ppc}"
        dom, pos, vel, p = _case(division, ppc, seed, sigma_frac)
        md0 = init_state(p, pos, vel)

        if not _parity_gate(p, md0, dt):
            print(f"fig_traj: {case}: fused skin=0 run DIVERGED from the "
                  "per-step loop — not timing a wrong answer",
                  file=sys.stderr)
            continue

        tp = trajectory_plan(p, 0.25, pos)
        md0_t = init_state(tp, pos, vel)
        t_fused, res = _time_traj(p, md0, n_steps, dt, segment_len=16,
                                  traj_plan=tp)
        t_loop = _time_loop(tp, md0_t, n_steps, dt)   # same skin plan
        t_fused0, _ = _time_traj(p, md0, n_steps, dt, segment_len=16,
                                 skin=0.0)
        t_loop0 = _time_loop(p, md0, n_steps, dt)     # base cutoff grid
        t_api = _time_api_loop(p, md0, n_steps, dt)   # pre-trajectory path
        sps_fused = n_steps / t_fused
        rebin_rate = res.rebins / n_steps
        row = {"case": case, "ppc": ppc, "n": pos.shape[0],
               "fused_steps_per_s": sps_fused,
               "loop_steps_per_s": n_steps / t_loop,
               "speedup": t_loop / t_fused,
               "speedup_skin0": t_loop0 / t_fused0,
               "speedup_vs_api": t_api / t_fused0,
               "rebins": res.rebins,
               "rebin_rate": rebin_rate, "status": res.status}
        rows.append(row)
        records.append(dict(
            bench_record(case, "traj_fused", "reference",
                         t_fused / n_steps, n_steps, layout=p.layout),
            ppc=ppc, steps_per_s=sps_fused, rebins=res.rebins,
            rebin_rate=rebin_rate, speedup_vs_loop=t_loop / t_fused))
        records.append(dict(
            bench_record(case, "traj_execute_api", "reference",
                         t_api / n_steps, n_steps, layout=p.layout),
            ppc=ppc, steps_per_s=n_steps / t_api,
            speedup_fused_vs_api=t_api / t_fused0))
        records.append(dict(
            bench_record(case, "traj_per_step", "reference",
                         t_loop / n_steps, n_steps, layout=p.layout),
            ppc=ppc, steps_per_s=n_steps / t_loop))
        records.append(dict(
            bench_record(case, "traj_fused_skin0", "reference",
                         t_fused0 / n_steps, n_steps, layout=p.layout),
            ppc=ppc, rebin_rate=1.0,
            speedup_vs_loop=t_loop0 / t_fused0))
        records.append(dict(
            bench_record(case, "traj_per_step_cutoff", "reference",
                         t_loop0 / n_steps, n_steps, layout=p.layout),
            ppc=ppc))
        if csv:
            print(f"{case}/traj_fused,{t_fused / n_steps * 1e6:.1f},"
                  f"steps_per_s={sps_fused:.1f};rebins={res.rebins}"
                  f"/{n_steps};speedup={t_loop / t_fused:.2f}")
            print(f"{case}/traj_per_step,{t_loop / n_steps * 1e6:.1f},"
                  f"steps_per_s={n_steps / t_loop:.1f}")
            print(f"{case}/traj_fused_skin0,"
                  f"{t_fused0 / n_steps * 1e6:.1f},"
                  f"speedup={t_loop0 / t_fused0:.2f}")
            print(f"{case}/traj_per_step_cutoff,"
                  f"{t_loop0 / n_steps * 1e6:.1f},base_grid")
            print(f"{case}/traj_execute_api,{t_api / n_steps * 1e6:.1f},"
                  f"fused_skin0_speedup={t_api / t_fused0:.2f}")

        # skin sweep: rebin count vs skin (not timed; short runs)
        for skin in SKIN_SWEEP:
            r = run_trajectory(p, md0, n_steps, dt, skin=skin,
                               segment_len=16)
            rows.append({"case": f"{case}/skin{skin}", "skin": skin,
                         "rebins": r.rebins,
                         "rebin_rate": r.rebins / n_steps})
            if csv:
                print(f"{case}/skin{skin},0.0,"
                      f"rebins={r.rebins}/{n_steps}")

    if chaos_run:
        case = "traj/chaos_nan"
        dom, pos, vel, p = _case(division, ppcs[0], seed, sigma_frac)
        md0 = init_state(p, pos, vel)
        spec = chaos.FaultSpec("traj.step", "nonfinite", p=1.0, after=1,
                               max_fires=1)
        # checkpointed run: the rollback recovers through the checkpoint
        # path, so a traced run records the full segment / rebin /
        # rollback / checkpoint span set (the obs-smoke contract)
        ckpt = tempfile.mkdtemp(prefix="fig_traj_ckpt_")
        kw = dict(segment_len=16, checkpoint_dir=ckpt, checkpoint_every=16,
                  resume=False)
        run_trajectory(p, md0, n_steps, dt, **kw)           # warm, no fault
        with chaos.inject(spec, seed=seed):
            # single timed run INSIDE the fault window: a warm run in here
            # would consume the one-shot fault and time a clean run instead
            t0 = _time.perf_counter()
            res = run_trajectory(p, md0, n_steps, dt, **kw)
            jax.block_until_ready(res.state.positions)
            t = _time.perf_counter() - t0
        shutil.rmtree(ckpt, ignore_errors=True)
        finite = bool(jnp.all(jnp.isfinite(res.state.positions)))
        records.append(dict(
            bench_record(case, "traj_fused", "reference", t / n_steps,
                         n_steps, layout=p.layout),
            faults=len(res.faults), retries=res.retries,
            rollbacks=res.rollbacks, recovered=finite,
            rebin_rate=res.rebins / n_steps))
        rows.append({"case": case, "status": res.status,
                     "rollbacks": res.rollbacks, "recovered": finite})
        if csv:
            print(f"{case}/traj_fused,{t / n_steps * 1e6:.1f},"
                  f"rollbacks={res.rollbacks};recovered={finite};"
                  f"status={res.status}")
        if not finite:
            print("fig_traj: chaos run did NOT recover to a finite state",
                  file=sys.stderr)

    if json_path:
        write_bench_json(json_path, records)
    if record_sink is not None:
        record_sink.extend(records)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--division", type=int, default=6)
    ap.add_argument("--ppc", type=int, nargs="+",
                    default=list(DEFAULT_PPCS))
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--sigma", type=float, default=0.25,
                    help="gaussian blob sigma as a fraction of the box")
    ap.add_argument("--chaos", action="store_true",
                    help="also run the injected-NaN recovery case")
    ap.add_argument("--json", metavar="PATH", default=None)
    args = ap.parse_args()
    run(division=args.division, ppcs=tuple(args.ppc), n_steps=args.steps,
        sigma_frac=args.sigma, chaos_run=args.chaos, json_path=args.json)


if __name__ == "__main__":
    main()
