"""Figure 7 analogue: staging-traffic model per strategy (DESIGN.md §2).

GPU occupancy / L2-hit metrics have no TPU meaning; this table reports what
the shared-memory strategies actually trade on TPU: HBM bytes per
interaction, staged VMEM bytes per grid step (double-buffer head-room), and
byte reuse — for each paper configuration. This is the quantitative form of
the paper's §5.1 argument for why All-in-SM loses and X-pencil wins.
"""

from __future__ import annotations

from repro.core import Domain
from repro.core.traffic import model


def run(csv: bool = True):
    rows = []
    if csv:
        print("name,us_per_call,derived")
    for division in (4, 8, 16, 32):
        for ppc in (1, 10, 100):
            dom = Domain.cubic(division, cutoff=1.0)
            m_c = max(8, int(ppc * 1.6))
            for strat, rep in model(dom, m_c, ppc).items():
                rows.append(rep)
                if csv:
                    print(f"traffic/{strat}/d{division}_p{ppc},0.0,"
                          f"hbmB_per_inter={rep.hbm_bytes_per_interaction:.2f};"
                          f"vmem_step_B={rep.staged_bytes_per_step};"
                          f"reuse={rep.reuse_factor:.2f};"
                          f"padded_waste={rep.padded_work_fraction:.3f};"
                          f"grid={rep.grid_steps}")
    return rows


if __name__ == "__main__":
    run()
