"""Table 1: Par-Part-NoLoop vs X-pencil execution times per configuration.

The paper's summary table (execution seconds, one row per (division, ppc)).
Covers the same rows as Figure 6 but in the paper's two-column PPNL/X-pencil
format, with the measured interactions-per-particle first column.
"""

from __future__ import annotations

import argparse
from typing import Optional

from .common import (bench_record, interactions_per_particle, paper_plan,
                     time_fn, write_bench_json)

DEFAULT_GRID = [(2, 1), (4, 1), (8, 1), (16, 1), (32, 1),
                (2, 10), (4, 10), (8, 10), (16, 10),
                (2, 100), (4, 100), (8, 100)]
FULL_GRID = [(d, p) for p in (1, 10, 100) for d in (2, 4, 8, 16, 32)]


def run(full: bool = False, csv: bool = True, backend: str = "reference",
        json_path: Optional[str] = None,
        record_sink: Optional[list] = None):
    rows = []
    records = []
    if csv:
        print("name,us_per_call,derived")
    for division, ppc in (FULL_GRID if full else DEFAULT_GRID):
        ipp = interactions_per_particle(division, ppc)
        _, state, _, ex_pp = paper_plan(division, ppc, strategy="par_part")
        t_pp, r_pp = time_fn(ex_pp, state)
        _, _, _, ex_xp = paper_plan(division, ppc, strategy="xpencil",
                                    backend=backend)
        t_xp, r_xp = time_fn(ex_xp, state)
        rows.append({"division": division, "ppc": ppc, "ipp": ipp,
                     "ppnl_s": t_pp, "xpencil_s": t_xp})
        case = f"table1/d{division}_p{ppc}"
        records.append(bench_record(case, "par_part", "reference",
                                    t_pp, r_pp))
        records.append(bench_record(case, "xpencil", backend, t_xp, r_xp))
        if csv:
            print(f"table1/d{division}_p{ppc},{t_pp * 1e6:.1f},"
                  f"ipp={ipp:.1f};ppnl_s={t_pp:.3e};xpencil_s={t_xp:.3e};"
                  f"ratio={t_pp / t_xp:.3f}")
    if json_path:
        write_bench_json(json_path, records)
    if record_sink is not None:
        record_sink.extend(records)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--backend", default="reference",
                    choices=["reference", "pallas"])
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write BENCH_*.json perf records to PATH")
    args = ap.parse_args()
    run(full=args.full, backend=args.backend, json_path=args.json)


if __name__ == "__main__":
    main()
