"""Autotune benchmark: does the stopwatch beat the traffic model?

For each paper case this times every candidate the tuner keeps, then
reports the analytical model's pick (what ``strategy="auto"`` would run),
the measured winner (what ``strategy="autotune"`` runs), and the *regret*
of trusting the model — t(model pick) / t(measured best). Regret 1.0 means
the model named the winner; the paper's Fig. 6/7 point is that it cannot
be trusted to on every hardware x fill-ratio cell.

    PYTHONPATH=src python -m benchmarks.autotune_bench [--json PATH]

``--json PATH`` emits the per-candidate timings as BENCH_*.json records.
"""

from __future__ import annotations

import argparse
from typing import List, Optional, Tuple

import jax

from repro.core import Domain, choose_strategy, make_lennard_jones, tune
from repro.core.engine import suggest_m_c

from .common import bench_record, write_bench_json

DEFAULT_CASES: List[Tuple[int, int]] = [(2, 4), (4, 2), (4, 10), (6, 4)]


def run(cases: List[Tuple[int, int]] = DEFAULT_CASES, csv: bool = True,
        json_path: Optional[str] = None, top_k: int = 8,
        record_sink: Optional[List[dict]] = None) -> List[dict]:
    rows = []
    records = []
    if csv:
        print("name,us_per_call,derived")
    for division, ppc in cases:
        dom = Domain.cubic(division, cutoff=1.0)
        n = division ** 3 * ppc
        pos = dom.sample_uniform(jax.random.PRNGKey(0), n)
        res = tune(dom, make_lennard_jones(), pos, top_k=top_k,
                   use_cache=False)
        model_pick = choose_strategy(dom, suggest_m_c(dom, pos),
                                     n / dom.n_cells)
        best_s = res.timings[res.candidate]
        # the model pick is a *dense* schedule (strategy="auto" knows
        # nothing of compaction) — regret compares against its dense runs
        model_best = min((s for c, s in res.timings.items()
                          if c.strategy == model_pick and not c.compact),
                         default=float("nan"))
        regret = model_best / best_s
        case = f"autotune/d{division}_p{ppc}"
        for cand, secs in sorted(res.timings.items(), key=lambda kv: kv[1]):
            # compacted twins share the strategy name; keep their perf
            # records distinguishable for the perf_diff join key
            strat = cand.strategy + ("_compact" if cand.compact else "")
            records.append(bench_record(case, strat, cand.backend,
                                        secs, res.reps[cand]))
        winner = res.candidate.strategy + (
            "_compact" if res.candidate.compact else "")
        row = {"division": division, "ppc": ppc,
               "measured_winner": winner,
               "model_pick": model_pick, "best_s": best_s,
               "model_pick_best_s": model_best, "regret": regret,
               "n_timed": len(res.timings), "n_pruned": len(res.pruned)}
        rows.append(row)
        if csv:
            print(f"{case},{best_s * 1e6:.1f},"
                  f"winner={winner};model={model_pick};"
                  f"regret={regret:.3f};timed={len(res.timings)};"
                  f"pruned={len(res.pruned)}")
    if json_path:
        write_bench_json(json_path, records)
    if record_sink is not None:
        record_sink.extend(records)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write BENCH_*.json perf records to PATH")
    ap.add_argument("--top-k", type=int, default=8,
                    help="candidates surviving model pruning")
    args = ap.parse_args()
    run(json_path=args.json, top_k=args.top_k)


if __name__ == "__main__":
    main()
