"""Perf-trajectory renderer (``benchmarks.perf_history``)."""

import json
import pathlib
import sys

import pytest

BENCH = pathlib.Path(__file__).resolve().parents[1] / "benchmarks"
if str(BENCH.parent) not in sys.path:
    sys.path.insert(0, str(BENCH.parent))

from benchmarks import perf_history as PH  # noqa: E402


def _snap(tmp_path, name, rows):
    p = tmp_path / name
    p.write_text(json.dumps(rows))
    return p


def _rec(case, us, strategy="xpencil", backend="reference"):
    return {"case": case, "strategy": strategy, "backend": backend,
            "us_per_call": us, "reps": 3, "platform": "cpu"}


def test_collect_orders_by_name_and_skips_corrupt(tmp_path, capsys):
    _snap(tmp_path, "BENCH_002.json", [_rec("a", 30.0)])
    _snap(tmp_path, "BENCH_001.json", [_rec("a", 10.0)])
    (tmp_path / "BENCH_000.json").write_text("{not json")
    snaps = PH.collect(tmp_path)
    assert [s[0] for s in snaps] == ["BENCH_001.json", "BENCH_002.json"]
    assert "skipping BENCH_000.json" in capsys.readouterr().err


def test_series_tracks_gaps_and_values(tmp_path):
    _snap(tmp_path, "BENCH_001.json", [_rec("a", 10.0), _rec("b", 5.0)])
    _snap(tmp_path, "BENCH_002.json", [_rec("a", 20.0)])
    _snap(tmp_path, "BENCH_003.json", [_rec("a", 40.0), _rec("b", 6.0)])
    snaps = PH.collect(tmp_path)
    ss = PH.series(snaps)
    assert ss[("a", "xpencil", "reference")] == [10.0, 20.0, 40.0]
    assert ss[("b", "xpencil", "reference")] == [5.0, None, 6.0]
    only_a = PH.series(snaps, case_filter="a")
    assert list(only_a) == [("a", "xpencil", "reference")]


def test_sparkline_shape_and_gaps():
    assert PH.sparkline([1.0, None, 8.0]) == "▁·█"
    assert PH.sparkline([None, None]) == "··"
    assert PH.sparkline([3.0, 3.0]) == "▁▁"    # flat series doesn't divide 0


def test_format_table_reports_delta(tmp_path):
    _snap(tmp_path, "BENCH_001.json", [_rec("a", 10.0)])
    _snap(tmp_path, "BENCH_002.json", [_rec("a", 15.0)])
    snaps = PH.collect(tmp_path)
    out = PH.format_table(snaps, PH.series(snaps))
    assert "a,xpencil,reference,10.0,15.0,+50.0%" in out


def test_main_end_to_end(tmp_path, capsys):
    _snap(tmp_path, "BENCH_001.json", [_rec("a", 10.0)])
    _snap(tmp_path, "BENCH_002.json", [_rec("a", 12.0)])
    out_json = tmp_path / "series.json"
    rc = PH.main([str(tmp_path), "--json", str(out_json)])
    assert rc == 0
    assert "2 snapshots" in capsys.readouterr().out
    payload = json.loads(out_json.read_text())
    assert payload["snapshots"] == ["BENCH_001.json", "BENCH_002.json"]
    assert payload["series"][0]["us_per_call"] == [10.0, 12.0]


def test_main_empty_dir_fails_cleanly(tmp_path, capsys):
    assert PH.main([str(tmp_path)]) == 1
    assert "no BENCH_*.json" in capsys.readouterr().err


def test_fastest_duplicate_wins_within_snapshot(tmp_path):
    _snap(tmp_path, "BENCH_001.json", [_rec("a", 30.0), _rec("a", 12.0)])
    snaps = PH.collect(tmp_path)
    assert PH.series(snaps)[("a", "xpencil", "reference")] == [12.0]


def test_layout_column_distinguishes_dense_compact_packed(tmp_path):
    """The trajectory renders an execution-layout tag per series: from the
    record's ``layout`` field when present, inferred from the strategy
    suffix for records predating the tag."""
    tagged = dict(_rec("p", 7.0, strategy="xpencil_packed"),
                  layout="packed")
    _snap(tmp_path, "BENCH_001.json",
          [_rec("a", 10.0),                                  # dense, untagged
           _rec("c", 5.0, strategy="xpencil_compact"),       # inferred
           tagged])
    snaps = PH.collect(tmp_path)
    ss = PH.series(snaps)
    assert PH.layout_of(snaps, ("a", "xpencil", "reference")) == "dense"
    assert PH.layout_of(snaps, ("c", "xpencil_compact",
                                "reference")) == "compact"
    assert PH.layout_of(snaps, ("p", "xpencil_packed",
                                "reference")) == "packed"
    out = PH.format_table(snaps, ss)
    assert out.splitlines()[1].endswith(",drift,layout")
    assert any(line.endswith(",packed") for line in out.splitlines())
    # --json payload carries the tag too
    import json as _json
    rc = PH.main([str(tmp_path), "--json", str(tmp_path / "s.json")])
    assert rc == 0
    payload = _json.loads((tmp_path / "s.json").read_text())
    by_case = {s["case"]: s["layout"] for s in payload["series"]}
    assert by_case == {"a": "dense", "c": "compact", "p": "packed"}


def test_layout_field_wins_over_suffix_inference(tmp_path):
    """An explicit ``layout`` field is trusted verbatim — suffix inference
    is only a fallback for untagged records, so a future layout (``sfc``)
    on a suffix-less strategy doesn't silently render as ``dense``."""
    sfc = dict(_rec("s", 9.0, strategy="xpencil"), layout="sfc")
    _snap(tmp_path, "BENCH_001.json",
          [sfc, _rec("z", 4.0, strategy="cell_sfc")])
    snaps = PH.collect(tmp_path)
    assert PH.layout_of(snaps, ("s", "xpencil", "reference")) == "sfc"
    # untagged records with a known suffix still infer
    assert PH.layout_of(snaps, ("z", "cell_sfc", "reference")) == "sfc"
    assert PH._infer_layout("xpencil_packed") == "packed"
    assert PH._infer_layout("xpencil") == "dense"


def test_drift_column_renders_model_audit(tmp_path):
    """Records carrying the model-vs-measured audit's ``drift`` field
    render it as a column; audit-less records render ``-``. The latest
    tagged snapshot wins, mirroring the other extras columns."""
    drifted = dict(_rec("d", 8.0), drift=-0.021)
    _snap(tmp_path, "BENCH_001.json", [_rec("a", 10.0), _rec("d", 7.0)])
    _snap(tmp_path, "BENCH_002.json", [drifted])
    snaps = PH.collect(tmp_path)
    assert PH.drift_of(snaps, ("d", "xpencil", "reference")) == "-0.02"
    assert PH.drift_of(snaps, ("a", "xpencil", "reference")) == "-"
    out = PH.format_table(snaps, PH.series(snaps))
    assert any(",-0.02," in line for line in out.splitlines())
    rc = PH.main([str(tmp_path), "--json", str(tmp_path / "s.json")])
    assert rc == 0
    payload = json.loads((tmp_path / "s.json").read_text())
    by_case = {s["case"]: s["drift"] for s in payload["series"]}
    assert by_case == {"a": "-", "d": "-0.02"}


def test_serving_columns_render_rps_and_p99(tmp_path):
    """Serving-tier records (fig_serve) carry rps/p99_ms extras; the
    trajectory renders them as columns, ``-`` for non-serving series."""
    serving = dict(_rec("serve/uniform", 6000.0, strategy="serve"),
                   rps=150.0, p99_ms=28.126)
    _snap(tmp_path, "BENCH_001.json", [_rec("a", 10.0), serving])
    snaps = PH.collect(tmp_path)
    assert PH.serving_of(snaps, ("serve/uniform", "serve",
                                 "reference")) == ("150.0", "28.13")
    assert PH.serving_of(snaps, ("a", "xpencil", "reference")) == ("-", "-")
    out = PH.format_table(snaps, PH.series(snaps))
    assert out.splitlines()[1].endswith(",rps,p99_ms,resilience,drift,layout")
    assert any(",150.0,28.13," in line for line in out.splitlines())


def test_resilience_column_renders_fault_counters(tmp_path):
    """Chaos-run records carry faults/retries/shed counters; the
    trajectory renders them compactly and keeps older records (or
    fault-free runs) as ``-`` — fully backward compatible."""
    chaos = dict(_rec("serve/chaos", 7000.0, strategy="serve"),
                 rps=120.0, p99_ms=31.0, faults=4, retries=9, shed=2)
    clean = dict(_rec("serve/clean", 6000.0, strategy="serve"),
                 rps=150.0, p99_ms=28.0, faults=0, retries=0, shed=0)
    _snap(tmp_path, "BENCH_001.json", [_rec("a", 10.0), chaos, clean])
    snaps = PH.collect(tmp_path)
    assert PH.resilience_of(snaps, ("serve/chaos", "serve",
                                    "reference")) == "f4/r9/s2"
    assert PH.resilience_of(snaps, ("serve/clean", "serve",
                                    "reference")) == "-"     # all-zero
    assert PH.resilience_of(snaps, ("a", "xpencil",
                                    "reference")) == "-"     # predates
    out = PH.format_table(snaps, PH.series(snaps))
    assert any(",f4/r9/s2," in line for line in out.splitlines())
    # --json payload carries it too
    rc = PH.main([str(tmp_path), "--json", str(tmp_path / "s.json")])
    assert rc == 0
    payload = json.loads((tmp_path / "s.json").read_text())
    by_case = {s["case"]: s["resilience"] for s in payload["series"]}
    assert by_case["serve/chaos"] == "f4/r9/s2"
    assert by_case["a"] == "-"
