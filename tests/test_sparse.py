"""Occupancy-compacted execution path: summaries, parity, replan, tooling.

The correctness bar (ISSUE 3): the compacted schedules must be *bit-parity*
with their dense oracles on uniform and clustered scenes — compaction may
only change which work units run, never a computed value.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Domain, ParticleState, active_unit_count,
                        bin_particles, make_lennard_jones, pencil_occupancy,
                        plan, scenarios, subbox_occupancy, suggest_m_c,
                        suggest_max_active, supports_compact)
from repro.core import strategies as S
from repro.core import traffic
from repro.core.api import n_units
from repro.core.binning import gather_pencil_rows


def _blob(division=6, n=300, seed=0, sigma_frac=0.08):
    dom = Domain.cubic(division, cutoff=1.0)
    pos = scenarios.sample_gaussian_blob(
        dom, jax.random.PRNGKey(seed), n, sigma_frac=sigma_frac)
    return dom, pos


# ---------------------------------------------------------------------------
# occupancy summaries
# ---------------------------------------------------------------------------

def test_pencil_occupancy_matches_numpy():
    dom, pos = _blob()
    bins = bin_particles(dom, pos, m_c=suggest_m_c(dom, pos))
    occ = pencil_occupancy(dom, bins.counts, max_active=dom.nz * dom.ny)

    counts3 = np.asarray(bins.counts).reshape(dom.nz, dom.ny, dom.nx)
    pc = counts3.sum(-1).reshape(-1)
    np.testing.assert_array_equal(np.asarray(occ.unit_counts), pc)
    want_active = np.nonzero(pc > 0)[0]
    assert int(occ.n_active) == len(want_active)
    np.testing.assert_array_equal(
        np.asarray(occ.active)[:len(want_active)], want_active)
    assert not bool(occ.overflowed)
    assert 0 < float(occ.fill_fraction) < 1.0      # the blob is clustered


def test_subbox_occupancy_matches_numpy():
    dom, pos = _blob(division=4, n=150)
    m_c = suggest_m_c(dom, pos)
    bins = bin_particles(dom, pos, m_c=m_c)
    box = S.shrink_to_divisors(dom, (2, 2, 2))
    bx, by, bz = box
    gx, gy, gz = dom.nx // bx, dom.ny // by, dom.nz // bz
    occ = subbox_occupancy(dom, bins.counts, box, max_active=gx * gy * gz)

    counts3 = np.asarray(bins.counts).reshape(dom.nz, dom.ny, dom.nx)
    bc = counts3.reshape(gz, bz, gy, by, gx, bx).sum(axis=(1, 3, 5))
    np.testing.assert_array_equal(np.asarray(occ.unit_counts),
                                  bc.reshape(-1))
    assert int(occ.n_active) == int((bc > 0).sum())


def test_occupancy_overflow_flag_and_scatter_padding():
    dom, pos = _blob()
    bins = bin_particles(dom, pos, m_c=suggest_m_c(dom, pos))
    occ = pencil_occupancy(dom, bins.counts, max_active=2)
    assert bool(occ.overflowed)

    full = pencil_occupancy(dom, bins.counts, max_active=dom.nz * dom.ny)
    idx = np.asarray(full.scatter_indices())
    n_act = int(full.n_active)
    # real entries in range, padding pushed out of range (drop scatters)
    assert (idx[:n_act] < full.n_units).all()
    assert (idx[n_act:] == full.n_units).all()


def test_gather_pencil_rows_matches_plane_rows():
    dom, pos = _blob(division=4, n=200)
    m_c = suggest_m_c(dom, pos)
    bins = bin_particles(dom, pos, m_c=m_c)
    act = jnp.asarray([0, 5, 9, 14], dtype=jnp.int32)   # z*ny + y ids
    for dz, dy in ((0, 0), (-1, 1), (1, -1)):
        rows = gather_pencil_rows(bins.planes["x"], act, dom.ny, dz, dy)
        for a, zy in enumerate(np.asarray(act)):
            z, y = zy // dom.ny, zy % dom.ny
            np.testing.assert_array_equal(
                np.asarray(rows[a]),
                np.asarray(bins.planes["x"][z + 1 + dz, y + 1 + dy]))


# (compact-vs-dense parity across scenes/strategies/backends lives in
# test_layout_matrix.py — the shared cross-layout differential harness)


# ---------------------------------------------------------------------------
# the max_active replan contract
# ---------------------------------------------------------------------------

def test_max_active_overflow_detected_and_replanned():
    dom, pos = _blob()
    kern = make_lennard_jones()
    state = ParticleState(pos)
    f_d, _ = plan(dom, kern, positions=pos, strategy="xpencil").execute(
        state)

    p0 = plan(dom, kern, positions=pos, strategy="xpencil", compact=True,
              max_active=2)
    assert p0.check_overflow(state)
    (f1, _), p1 = p0.execute_or_replan(state)
    assert p1.max_active > p0.max_active
    assert p1.m_c == p0.m_c                      # only the tight bound grew
    assert not p1.check_overflow(state)
    np.testing.assert_array_equal(np.asarray(f1), np.asarray(f_d))

    # an overflowed bound really does drop pencils (the thing replan
    # protects against): forces under the tiny bound are wrong
    f_bad, _ = p0.execute(state)
    assert not np.array_equal(np.asarray(f_bad), np.asarray(f_d))


def test_suggest_max_active_bounds_and_clipping():
    dom, pos = _blob()
    n_act = active_unit_count(dom, pos, "xpencil")
    bound = suggest_max_active(dom, pos, "xpencil")
    assert n_act <= bound <= n_units(dom, "xpencil")
    # huge slack clips to the total unit count, never beyond
    assert suggest_max_active(dom, pos, "xpencil",
                              slack=100.0) == n_units(dom, "xpencil")


def test_compact_plan_validation():
    dom, pos = _blob()
    with pytest.raises(ValueError, match="compact"):
        plan(dom, make_lennard_jones(), positions=pos, strategy="par_part",
             compact=True)
    with pytest.raises(ValueError, match="max_active|positions"):
        plan(dom, make_lennard_jones(), m_c=16, strategy="xpencil",
             compact=True)                       # no positions, no bound
    assert supports_compact("reference", "xpencil")
    assert supports_compact("pallas", "xpencil")
    assert not supports_compact("pallas", "allin")
    assert not supports_compact("reference", "par_part")


def test_compact_plans_hash_and_cache_separately():
    dom, pos = _blob()
    kern = make_lennard_jones()
    pd = plan(dom, kern, positions=pos, strategy="xpencil")
    pc = plan(dom, kern, positions=pos, strategy="xpencil", compact=True)
    assert pd != pc and hash(pd) != hash(pc)
    pc2 = plan(dom, kern, positions=pos, strategy="xpencil", compact=True)
    assert pc == pc2                             # same measured bound


# ---------------------------------------------------------------------------
# fill-fraction-aware traffic costs
# ---------------------------------------------------------------------------

def test_traffic_compact_cost_scales_with_fill():
    dom = Domain.cubic(8, cutoff=1.0)
    dense = traffic.candidate_cost(dom, 16, 2.0, "xpencil")
    half = traffic.candidate_cost(dom, 16, 2.0, "xpencil", compact=True,
                                  fill=0.5)
    tenth = traffic.candidate_cost(dom, 16, 2.0, "xpencil", compact=True,
                                   fill=0.1)
    assert tenth < half < dense
    np.testing.assert_allclose(half, dense * 0.5, rtol=1e-6)
    # fill 1.0 compact == dense (compaction changes which units run only)
    full = traffic.candidate_cost(dom, 16, 2.0, "xpencil", compact=True,
                                  fill=1.0)
    np.testing.assert_allclose(full, dense, rtol=1e-6)


def test_traffic_compact_report_fields():
    dom = Domain.cubic(8, cutoff=1.0)
    report = traffic.model(dom, 16, 2.0)["xpencil"]
    comp = traffic.compact_report(report, 0.25)
    assert comp.strategy == "xpencil_compact"
    assert comp.grid_steps == max(1, round(report.grid_steps * 0.25))
    assert comp.staged_bytes_per_step == report.staged_bytes_per_step


# ---------------------------------------------------------------------------
# scenario family
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(scenarios.SCENARIOS))
def test_scenarios_inside_box(name):
    dom = Domain.cubic(5, cutoff=1.0)
    pos = scenarios.sample(name, dom, jax.random.PRNGKey(7), 200)
    assert pos.shape == (200, 3)
    box = np.asarray(dom.box)
    p = np.asarray(pos)
    assert (p > 0).all() and (p < box).all()


def test_scenarios_fill_ordering():
    """The blob family spans the fill axis: tighter sigma, fewer active
    pencils; every clustered scene is sparser than uniform."""
    dom = Domain.cubic(8, cutoff=1.0)
    key = jax.random.PRNGKey(8)
    n = 400
    uni = active_unit_count(dom, scenarios.sample("uniform", dom, key, n))
    wide = active_unit_count(dom, scenarios.sample_gaussian_blob(
        dom, key, n, sigma_frac=0.12))
    tight = active_unit_count(dom, scenarios.sample_gaussian_blob(
        dom, key, n, sigma_frac=0.04))
    assert tight < wide < uni


def test_unknown_scenario_rejected():
    with pytest.raises(ValueError, match="unknown scenario"):
        scenarios.sample("nope", Domain.cubic(3), jax.random.PRNGKey(0), 10)


# ---------------------------------------------------------------------------
# perf_diff tooling
# ---------------------------------------------------------------------------

def test_perf_diff_flags_regressions(tmp_path):
    from benchmarks import perf_diff
    base = [{"case": "a", "strategy": "s", "backend": "b",
             "us_per_call": 100.0, "reps": 3, "platform": "cpu"},
            {"case": "gone", "strategy": "s", "backend": "b",
             "us_per_call": 10.0, "reps": 3, "platform": "cpu"}]
    fresh = [{"case": "a", "strategy": "s", "backend": "b",
              "us_per_call": 260.0, "reps": 3, "platform": "cpu"},
             {"case": "new", "strategy": "s", "backend": "b",
              "us_per_call": 5.0, "reps": 3, "platform": "cpu"}]
    bp, fp = tmp_path / "base.json", tmp_path / "fresh.json"
    bp.write_text(__import__("json").dumps(base))
    fp.write_text(__import__("json").dumps(fresh))

    diff = perf_diff.diff_records(perf_diff.load_records(str(bp)),
                                  perf_diff.load_records(str(fp)),
                                  threshold=2.0)
    assert len(diff["rows"]) == 1 and diff["rows"][0]["regressed"]
    assert diff["only_baseline"] == [("gone", "s", "b")]
    assert diff["only_fresh"] == [("new", "s", "b")]
    assert perf_diff.main([str(bp), str(fp), "--threshold", "2.0"]) == 0
    assert perf_diff.main([str(bp), str(fp), "--threshold", "2.0",
                           "--fail-on-regression"]) == 1
    # below threshold: clean exit even with the gate on
    assert perf_diff.main([str(bp), str(fp), "--threshold", "3.0",
                           "--fail-on-regression"]) == 0


def test_committed_bench_sparse_meets_acceptance():
    """The committed BENCH_sparse.json must contain a <= 10%-fill case
    with >= 2x measured compacted speedup (ISSUE 3 acceptance)."""
    import json
    import pathlib
    path = pathlib.Path(__file__).parent.parent / "benchmarks" / \
        "BENCH_sparse.json"
    records = json.loads(path.read_text())
    wins = [r for r in records
            if r["strategy"] == "xpencil_compact"
            and r.get("fill", 1.0) <= 0.10
            and r.get("speedup_vs_dense", 0.0) >= 2.0]
    assert wins, ("no committed <=10%-fill case with >=2x compacted "
                  f"speedup in {path}")
