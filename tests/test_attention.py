"""Flash / window / decode attention: values + gradients vs dense reference."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (decode_attention, flash_attention,
                                    window_attention_blocked)


def dense_ref(q, k, v, causal=True, softcap=0.0, window=0):
    b, h, s, d = q.shape
    kh = k.shape[1]
    g = h // kh
    kf = jnp.repeat(k, g, axis=1).astype(jnp.float32)
    vf = jnp.repeat(v, g, axis=1).astype(jnp.float32)
    sc = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kf) / d ** 0.5
    if softcap > 0:
        sc = softcap * jnp.tanh(sc / softcap)
    qp = jnp.arange(s)[:, None]
    kp = jnp.arange(s)[None, :]
    m = jnp.ones((s, s), bool)
    if causal:
        m = m & (kp <= qp)
    if window > 0:
        m = m & (qp - kp < window)
    sc = jnp.where(m, sc, -1e30)
    return jnp.einsum("bhqk,bhkd->bhqd", jax.nn.softmax(sc, -1),
                      vf).astype(q.dtype)


@pytest.mark.parametrize("h,kh", [(8, 8), (8, 2), (4, 1)])
@pytest.mark.parametrize("qc,kc", [(64, 64), (32, 128), (128, 32)])
def test_flash_values(h, kh, qc, kc):
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (2, h, 256, 32), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (2, kh, 256, 32))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, kh, 256, 32))
    o = flash_attention(q, k, v, True, 0.0, qc, kc)
    np.testing.assert_allclose(np.asarray(o), np.asarray(dense_ref(q, k, v)),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("softcap", [0.0, 15.0])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_grads(softcap, causal):
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 4, 128, 16), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 2, 128, 16))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 2, 128, 16))
    f = lambda *a: (flash_attention(*a, causal, softcap, 32, 32) ** 2).sum()
    fr = lambda *a: (dense_ref(*a, causal, softcap) ** 2).sum()
    g = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(fr, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-3, atol=5e-3)


@pytest.mark.parametrize("window", [8, 32, 64])
def test_window_blocked_values_and_grads(window):
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (2, 4, 128, 16), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 2, 128, 16))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 2, 128, 16))
    o = window_attention_blocked(q, k, v, window=window)
    np.testing.assert_allclose(
        np.asarray(o), np.asarray(dense_ref(q, k, v, window=window)),
        rtol=2e-4, atol=2e-4)
    g = jax.grad(lambda q: (window_attention_blocked(
        q, k, v, window=window) ** 2).sum())(q)
    gr = jax.grad(lambda q: (dense_ref(q, k, v, window=window) ** 2).sum())(q)
    np.testing.assert_allclose(np.asarray(g), np.asarray(gr),
                               rtol=5e-3, atol=5e-3)


def test_decode_window_flag():
    """window_flag False must reproduce full-cache attention."""
    key = jax.random.PRNGKey(0)
    B, KH, S, D = 2, 2, 64, 16
    q = jax.random.normal(key, (B, 4, 1, D), jnp.float32)
    kc = jax.random.normal(jax.random.PRNGKey(1), (B, KH, S, D))
    vc = jax.random.normal(jax.random.PRNGKey(2), (B, KH, S, D))
    idx = jnp.int32(40)
    full = decode_attention(q, kc, vc, idx)
    flag_off = decode_attention(q, kc, vc, idx, window=16,
                                window_flag=jnp.bool_(False))
    np.testing.assert_allclose(np.asarray(flag_off), np.asarray(full),
                               rtol=1e-6)
    flag_on = decode_attention(q, kc, vc, idx, window=16,
                               window_flag=jnp.bool_(True))
    hard = decode_attention(q, kc, vc, idx, window=16)
    np.testing.assert_allclose(np.asarray(flag_on), np.asarray(hard),
                               rtol=1e-6)
    assert not np.allclose(np.asarray(flag_on), np.asarray(full))


def test_bf16_inputs_fp32_accumulation():
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 4, 128, 16)).astype(jnp.bfloat16)
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 4, 128, 16)
                          ).astype(jnp.bfloat16)
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 4, 128, 16)
                          ).astype(jnp.bfloat16)
    o = flash_attention(q, k, v, True, 0.0, 32, 32)
    o_ref = dense_ref(q, k, v)
    assert o.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32),
                               rtol=2e-2, atol=2e-2)
