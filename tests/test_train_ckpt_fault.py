"""Training loop, checkpointing, fault tolerance, gradient compression."""

import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint as C
from repro.configs import get_smoke_config
from repro.data.pipeline import DataConfig, batch_at
from repro.dist.compress import (compress_with_feedback, init_residual)
from repro.dist.fault import (FaultConfig, StragglerDetected,
                              StragglerWatchdog, run_with_restarts)
from repro.models import model as M
from repro.optim import AdamConfig, init_opt_state
from repro.train import make_train_step


def _setup(arch="qwen1.5-0.5b", steps=4):
    cfg = get_smoke_config(arch)
    opt_cfg = AdamConfig(lr=1e-3, total_steps=64, warmup_steps=2)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params, opt_cfg)
    step = jax.jit(make_train_step(cfg, opt_cfg))
    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4)
    return cfg, step, params, opt, data


def test_loss_decreases():
    cfg, step, params, opt, data = _setup()
    losses = []
    for i in range(30):
        tokens, labels = batch_at(data, 0)   # memorize one batch
        m, params, opt = step(params, opt,
                              {"tokens": tokens, "labels": labels})
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses[::10]


def test_microbatched_step_matches_grads_direction():
    cfg, _, params, opt, data = _setup()
    opt_cfg = AdamConfig(lr=1e-3, total_steps=64, warmup_steps=2)
    s1 = jax.jit(make_train_step(cfg, opt_cfg, microbatches=1))
    s2 = jax.jit(make_train_step(cfg, opt_cfg, microbatches=2))
    tokens, labels = batch_at(data, 0)
    batch = {"tokens": tokens, "labels": labels}
    m1, p1, _ = s1(params, opt, batch)
    m2, p2, _ = s2(params, opt, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-3)
    l1, l2 = jax.tree.leaves(p1)[3], jax.tree.leaves(p2)[3]
    np.testing.assert_allclose(np.asarray(l1, np.float32),
                               np.asarray(l2, np.float32),
                               rtol=2e-2, atol=2e-4)


def test_checkpoint_roundtrip(tmp_path):
    cfg, step, params, opt, data = _setup()
    path = C.save(tmp_path, 3, (params, opt), extra={"data_step": 7})
    assert path.name == "step_00000003"
    (p2, o2), extra = C.restore(tmp_path, (params, opt))
    assert extra["data_step"] == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restart_is_bitwise_deterministic(tmp_path):
    """5 steps straight == 3 steps + checkpoint + restore + 2 steps."""
    cfg, step, params0, opt0, data = _setup()

    def run_n(params, opt, start, n):
        for i in range(start, start + n):
            tokens, labels = batch_at(data, i)
            m, params, opt = step(params, opt,
                                  {"tokens": tokens, "labels": labels})
        return params, opt

    pa, oa = run_n(params0, opt0, 0, 5)

    pb, ob = run_n(params0, opt0, 0, 3)
    C.save(tmp_path, 3, (pb, ob), extra={"data_step": 3})
    (pb, ob), extra = C.restore(tmp_path, (pb, ob))
    pb, ob = run_n(pb, ob, int(extra["data_step"]), 2)

    for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_no_partial_checkpoint_on_crash(tmp_path, monkeypatch):
    cfg, step, params, opt, data = _setup()
    import numpy as _np
    orig = _np.save
    calls = {"n": 0}

    def exploding_save(path, arr):
        calls["n"] += 1
        if calls["n"] > 3:
            raise RuntimeError("disk died")
        return orig(path, arr)

    monkeypatch.setattr(_np, "save", exploding_save)
    with pytest.raises(RuntimeError):
        C.save(tmp_path, 1, (params, opt))
    monkeypatch.undo()
    assert C.latest_step(tmp_path) is None       # nothing committed
    leftovers = [d for d in pathlib.Path(tmp_path).iterdir()
                 if d.name.startswith("step_")]
    assert not leftovers


def test_watchdog_and_restart_driver(tmp_path):
    wd = StragglerWatchdog(deadline_s=0.05)
    wd.observe(0.01)
    with pytest.raises(StragglerDetected):
        wd.observe(0.2)

    state = {"fail_at": 2, "restarts": 0}

    def train_loop(start):
        for step in range(start, 5):
            if step == state["fail_at"]:
                state["fail_at"] = -1
                state["restarts"] += 1
                C.save(tmp_path, step, {"x": jnp.ones(3)})
                raise StragglerDetected("simulated straggler")
        return 5

    out = run_with_restarts(train_loop,
                            FaultConfig(ckpt_dir=str(tmp_path)))
    assert out == 5 and state["restarts"] == 1


def test_watchdog_history_is_bounded():
    wd = StragglerWatchdog(deadline_s=10.0, history_len=16)
    for i in range(100):
        wd.observe(0.001 * i)
    assert len(wd.history) == 16
    np.testing.assert_allclose(list(wd.history),
                               [0.001 * i for i in range(84, 100)])


def test_restart_driver_catches_runtime_error_with_backoff(tmp_path):
    """run_with_restarts recovers from *any* RuntimeError (per its
    docstring), sleeping an exponentially-backed-off, capped interval."""
    sleeps = []
    state = {"failures": 3}

    def train_loop(start):
        if state["failures"] > 0:
            state["failures"] -= 1
            raise RuntimeError("transient backend error")
        return "done"

    cfg = FaultConfig(ckpt_dir=str(tmp_path), backoff_s=0.1,
                      backoff_cap_s=0.25)
    out = run_with_restarts(train_loop, cfg, sleep=sleeps.append)
    assert out == "done"
    np.testing.assert_allclose(sleeps, [0.1, 0.2, 0.25])  # capped at 3rd

    # budget exhaustion still propagates the error
    cfg2 = FaultConfig(ckpt_dir=str(tmp_path), max_restarts=2)
    with pytest.raises(RuntimeError, match="always"):
        run_with_restarts(
            lambda start: (_ for _ in ()).throw(RuntimeError("always")),
            cfg2, sleep=sleeps.append)


def test_corrupt_manifest_rejected_and_skipped(tmp_path):
    """A truncated manifest.json in the newest step_<N> must be rejected
    by restore and skipped by latest_step (fall back to last intact)."""
    tree = {"x": jnp.arange(4.0)}
    C.save(tmp_path, 1, tree, extra={"data_step": 1})
    C.save(tmp_path, 2, tree, extra={"data_step": 2})
    mpath = pathlib.Path(tmp_path) / "step_00000002" / "manifest.json"
    mpath.write_text(mpath.read_text()[:10])            # truncate mid-JSON

    assert not C.is_intact(mpath.parent)
    with pytest.raises(C.CheckpointCorrupt, match="manifest"):
        C.restore(tmp_path, tree, step=2)
    assert C.latest_step(tmp_path) == 1                 # falls back
    (restored, extra) = C.restore(tmp_path, tree)       # newest intact
    assert extra["data_step"] == 1
    np.testing.assert_array_equal(np.asarray(restored["x"]),
                                  np.asarray(tree["x"]))


def test_missing_leaf_rejected_and_skipped(tmp_path):
    """A step dir whose manifest lists a leaf whose .npy is gone is
    corrupt, not silently restorable."""
    tree = {"x": jnp.arange(4.0), "y": jnp.ones(2)}
    C.save(tmp_path, 1, tree)
    C.save(tmp_path, 2, tree)
    (pathlib.Path(tmp_path) / "step_00000002" / "y.npy").unlink()

    with pytest.raises(C.CheckpointCorrupt, match="missing leaf"):
        C.restore(tmp_path, tree, step=2)
    assert C.latest_step(tmp_path) == 1

    # and the restart driver rides over it: a loop that trips once on the
    # corrupt checkpoint restarts from the intact one
    calls = []

    def train_loop(start):
        calls.append(start)
        if len(calls) == 1:
            C.restore(tmp_path, tree, step=2)   # raises CheckpointCorrupt
        return start

    out = run_with_restarts(train_loop,
                            FaultConfig(ckpt_dir=str(tmp_path)))
    assert out == 1 and calls == [1, 1]


def test_grad_compression_error_feedback_converges():
    """SGD on a quadratic with int8-compressed grads + error feedback."""
    key = jax.random.PRNGKey(0)
    target = jax.random.normal(key, (32,))
    x = {"w": jnp.zeros(32)}
    residual = init_residual(x)
    for i in range(300):
        g = {"w": 2 * (x["w"] - target)}
        g, residual = compress_with_feedback(g, residual)
        x = {"w": x["w"] - 0.05 * g["w"]}
    np.testing.assert_allclose(np.asarray(x["w"]), np.asarray(target),
                               atol=1e-2)


def test_data_pipeline_determinism_and_sharding():
    data = DataConfig(vocab_size=100, seq_len=16, global_batch=8)
    t1, l1 = batch_at(data, 5)
    t2, l2 = batch_at(data, 5)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
    np.testing.assert_array_equal(np.asarray(t1[:, 1:]),
                                  np.asarray(l1[:, :-1]))
    # per-host slices differ and are stable
    a, _ = batch_at(data, 5, host_index=0, n_hosts=2)
    b, _ = batch_at(data, 5, host_index=1, n_hosts=2)
    assert a.shape == (4, 16)
    assert not np.array_equal(np.asarray(a), np.asarray(b))
