"""Resilience layer under seeded fault injection (``repro.testing.chaos``).

The acceptance contract of the resilient execution layer: under any
injected fault schedule (overflow, NaN, straggler, transient backend
error, shard loss) ``plan.execute_checked`` and the ``ServingEngine``
never raise to the caller, every request terminates with a definite
status, retry counts respect the bound, and every degraded-path output is
parity-checked against the healthy path. With injection disabled, the
fault points are no-ops and all bit-identical guarantees (including the
serving steady-state zero-recompile assertion) still hold.
"""

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Domain, ParticleState, degradation_ladder,
                        fallback_plan, make_lennard_jones, plan, plan_health,
                        recompile_count, reset_health, scenarios)
from repro.core import api, autotune as at
from repro.serve import (RESPONSE_STATUSES, ServeMetrics, ServingEngine,
                         VirtualClock, classify)
from repro.testing import chaos


def _dom(division=4):
    return Domain.cubic(division, cutoff=1.0)


def _state(dom, n=80, seed=0, scenario="uniform"):
    pos = scenarios.sample(scenario, dom, jax.random.PRNGKey(seed), n)
    return ParticleState(pos)


def _assert_bitwise(a, b):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.fixture(autouse=True)
def _fresh_health():
    reset_health()
    yield
    reset_health()


# ---------------------------------------------------------------------------
# the fault registry itself
# ---------------------------------------------------------------------------

def test_fault_spec_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        chaos.FaultSpec("core.dispatch", "explode")
    with pytest.raises(ValueError, match="p must be"):
        chaos.FaultSpec("core.dispatch", "error", p=1.5)


def test_schedule_is_deterministic_per_seed():
    def pattern(seed):
        with chaos.inject(chaos.FaultSpec("s", "error", p=0.3),
                          seed=seed) as st:
            return [st.fire("s", "error") is not None for _ in range(200)]

    a, b, c = pattern(7), pattern(7), pattern(8)
    assert a == b                       # same seed replays the schedule
    assert a != c                       # different seed differs
    assert 20 < sum(a) < 100            # p=0.3 actually thins the firings


def test_after_and_max_fires_window():
    with chaos.inject(chaos.FaultSpec("s", "error", after=2, max_fires=3)):
        fired = [chaos.fire("s", "error") is not None for _ in range(8)]
    assert fired == [False, False, True, True, True, False, False, False]


def test_inactive_fault_points_are_noops():
    assert not chaos.active()
    assert chaos.fire("s", "error") is None
    chaos.maybe_raise("s")                        # must not raise
    assert chaos.maybe_delay("s") == 0.0
    x = jnp.ones((3, 3))
    assert chaos.corrupt("s", x) is x             # identity, not a copy
    assert not chaos.forced_overflow("s")
    assert chaos.snapshot()["total_fires"] == 0


def test_contexts_nest_and_restore():
    with chaos.inject(chaos.FaultSpec("outer", "error")) as outer:
        with chaos.inject(chaos.FaultSpec("inner", "error")) as inner:
            assert chaos.state() is inner
            assert chaos.fire("outer", "error") is None   # outer masked
        assert chaos.state() is outer
        assert chaos.fire("outer", "error") is not None
    assert chaos.state() is None


def test_snapshot_counts_fires_per_point():
    with chaos.inject(chaos.FaultSpec("a", "error", max_fires=2),
                      chaos.FaultSpec("b", "delay", param=0.0)) as st:
        for _ in range(4):
            st.fire("a", "error")
        st.fire("b", "delay")
        snap = st.snapshot()
    assert snap["fires"] == {"a/error": 2, "b/delay": 1}
    assert snap["total_fires"] == 3 and snap["total_visits"] == 5


# ---------------------------------------------------------------------------
# guarded dispatch: plan.execute_checked
# ---------------------------------------------------------------------------

def test_execute_checked_clean_path_bit_identical():
    dom = _dom()
    state = _state(dom)
    p = plan(dom, make_lennard_jones(), positions=state.positions)
    f_ref, u_ref = p.execute(state)
    (f, u), report = p.execute_checked(state)
    _assert_bitwise(f, f_ref)
    _assert_bitwise(u, u_ref)
    assert report.status == "ok" and report.ladder_level == 0
    assert report.retries == 0 and not report.faults
    assert report.nonfinite == 0 and report.overflow is None


def test_nonfinite_output_detected_and_retried():
    dom = _dom()
    state = _state(dom)
    p = plan(dom, make_lennard_jones(), positions=state.positions)
    f_ref, u_ref = p.execute(state)
    with chaos.inject(chaos.FaultSpec("core.dispatch", "nonfinite",
                                      max_fires=1)):
        (f, u), report = p.execute_checked(state)
    assert report.nonfinite > 0 and report.retries == 1
    assert any("NonFinite" in s for s in report.faults)
    _assert_bitwise(f, f_ref)            # the retry produced clean output
    _assert_bitwise(u, u_ref)


def test_always_failing_dispatch_is_bounded_and_never_raises():
    dom = _dom()
    state = _state(dom)
    p = plan(dom, make_lennard_jones(), positions=state.positions)
    with chaos.inject(chaos.FaultSpec("core.dispatch", "error")):
        (f, u), report = p.execute_checked(state, max_retries=5)
    assert report.status == "failed"
    assert report.retries == 6                    # bound + the final check
    assert not np.any(np.asarray(f)) and not np.any(np.asarray(u))


def test_straggler_delay_is_simulated_not_burned():
    dom = _dom()
    state = _state(dom)
    p = plan(dom, make_lennard_jones(), positions=state.positions)
    f_ref, u_ref = p.execute(state)
    clock = VirtualClock()
    with chaos.inject(chaos.FaultSpec("core.dispatch", "delay",
                                      param=1.5, max_fires=1)) as st:
        (f, u), report = p.execute_checked(state, sleep=clock.advance)
    assert clock.now() == 1.5 and st.fire_count(kind="delay") == 1
    assert report.status == "ok"                  # latency is not an error
    _assert_bitwise(f, f_ref)
    _assert_bitwise(u, u_ref)


def test_forced_overflow_replans_are_bounded():
    dom = _dom()
    state = _state(dom)
    p = plan(dom, make_lennard_jones(), positions=state.positions)
    f_ref, u_ref = p.execute(state)
    with chaos.inject(chaos.FaultSpec("core.binning", "overflow")):
        (f, u), report = p.execute_checked(state, max_replans=3)
    assert report.overflow == "injected"
    assert report.replans <= 3                    # no replan storm
    assert report.status == "ok"
    _assert_bitwise(f, f_ref)
    _assert_bitwise(u, u_ref)


def test_degradation_ladder_construction():
    dom = _dom()
    state = _state(dom)
    p_pal = plan(dom, make_lennard_jones(), positions=state.positions,
                 strategy="xpencil", backend="pallas", interpret=True)
    rungs = degradation_ladder(p_pal)
    assert [r.backend for r in rungs] == ["pallas", "reference"]
    assert fallback_plan(p_pal).backend == "reference"

    p_packed = plan(dom, make_lennard_jones(), positions=state.positions,
                    strategy="xpencil", layout="packed")
    assert [r.layout for r in degradation_ladder(p_packed)] == [
        "packed", "dense"]

    p_compact = plan(dom, make_lennard_jones(), positions=state.positions,
                     strategy="xpencil", compact=True)
    assert [r.compact for r in degradation_ladder(p_compact)] == [
        True, False]

    p_ref = plan(dom, make_lennard_jones(), positions=state.positions,
                 strategy="xpencil")
    assert degradation_ladder(p_ref) == (p_ref,)   # nowhere left to go


def test_breaker_trips_down_ladder_and_parity_holds():
    dom = _dom()
    state = _state(dom)
    p = plan(dom, make_lennard_jones(), positions=state.positions,
             strategy="xpencil", layout="packed")
    f_ref, u_ref = p.execute(state)
    # exactly _FAILURE_THRESHOLD transient errors: the breaker trips one
    # rung down (packed -> dense) and the next attempt succeeds there
    with chaos.inject(chaos.FaultSpec("core.dispatch", "error",
                                      max_fires=api._FAILURE_THRESHOLD)):
        (f, u), report = p.execute_checked(state)
    assert report.breaker_trips == 1
    assert report.status == "degraded" and report.layout == "dense"
    assert plan_health(p).level == 1
    _assert_bitwise(f, f_ref)             # degraded rung is bit-identical
    _assert_bitwise(u, u_ref)


def test_breaker_recovers_after_clean_streak():
    dom = _dom()
    state = _state(dom)
    p = plan(dom, make_lennard_jones(), positions=state.positions,
             strategy="xpencil", layout="packed")
    with chaos.inject(chaos.FaultSpec("core.dispatch", "error",
                                      max_fires=api._FAILURE_THRESHOLD)):
        p.execute_checked(state)
    assert plan_health(p).level == 1
    recovered = False
    for _ in range(api._RECOVERY_THRESHOLD):
        (_, _), report = p.execute_checked(state)
        recovered = recovered or report.recovered
    assert recovered and plan_health(p).level == 0
    (_, _), report = p.execute_checked(state)
    assert report.status == "ok" and report.ladder_level == 0


def test_health_key_survives_replan():
    dom = _dom()
    state = _state(dom)
    p = plan(dom, make_lennard_jones(), positions=state.positions)
    health = plan_health(p)
    health.level = 0
    health.consec_failures = 2
    grown = dataclasses.replace(p, m_c=p.m_c + 8)
    assert plan_health(grown) is health   # replan keeps breaker state


def test_shard_loss_triggers_elastic_shrink_with_parity():
    dom = _dom()
    state = _state(dom)
    p_ref = plan(dom, make_lennard_jones(), positions=state.positions,
                 strategy="xpencil")
    f_ref, u_ref = p_ref.execute(state)
    p2 = plan(dom, make_lennard_jones(), positions=state.positions,
              strategy="xpencil", backend="halo", n_shards=2)
    with chaos.inject(chaos.FaultSpec("dist.exchange", "shard_loss",
                                      max_fires=1)):
        (f, u), report = p2.execute_checked(state)
    assert report.shard_shrinks == 1
    assert report.plan.n_shards == 1      # rebuilt at the survivor count
    assert report.status in ("ok", "degraded")
    _assert_bitwise(f, f_ref)
    _assert_bitwise(u, u_ref)


def test_execute_checked_survives_arbitrary_schedule():
    """The headline guarantee: any mixed schedule -> no exception, a
    definite status, bounded retries."""
    dom = _dom()
    state = _state(dom)
    p = plan(dom, make_lennard_jones(), positions=state.positions)
    specs = (
        chaos.FaultSpec("core.dispatch", "error", p=0.4),
        chaos.FaultSpec("core.dispatch", "nonfinite", p=0.2),
        chaos.FaultSpec("core.dispatch", "delay", p=0.3, param=0.01),
        chaos.FaultSpec("core.binning", "overflow", p=0.2),
    )
    clock = VirtualClock()
    for seed in range(5):
        with chaos.inject(*specs, seed=seed):
            (f, u), report = p.execute_checked(state, sleep=clock.advance)
        assert report.status in ("ok", "degraded", "failed")
        assert report.retries <= api._FAILURE_THRESHOLD * len(
            degradation_ladder(p)) + 1
        assert np.all(np.isfinite(np.asarray(f)))


# ---------------------------------------------------------------------------
# serving tier: deadlines, retries, per-class breaker
# ---------------------------------------------------------------------------

def _drain(eng, max_rounds=500):
    """Advance past every backoff holdback until the queue is empty."""
    for _ in range(max_rounds):
        if eng.pending() == 0:
            return
        eng.clock.advance(eng.retry_cap_s)
        eng.flush()
    raise AssertionError(f"queue did not drain ({eng.pending()} pending)")


def test_deadline_expired_requests_never_dispatch():
    dom = _dom()
    eng = ServingEngine(max_batch=4, max_wait=0.5)
    # already expired at submit
    r0 = eng.submit(dom, _state(dom, 40), deadline_s=0.0)
    # expires while queued: the sweep runs before any dispatch
    r1 = eng.submit(dom, _state(dom, 40), deadline_s=0.1)
    r2 = eng.submit(dom, _state(dom, 40))          # no deadline
    eng.clock.advance(1.0)
    eng.flush()
    by_id = {r.req_id: r for r in eng.take_responses()}
    assert by_id[r0].status == "deadline" and by_id[r0].forces is None
    assert by_id[r1].status == "deadline" and by_id[r1].forces is None
    assert by_id[r2].status == "ok"
    assert eng.metrics.deadline_expired == 2
    assert eng.metrics.batches == 1                # one real dispatch


def test_serving_retries_are_bounded_and_terminal():
    dom = _dom()
    eng = ServingEngine(max_batch=2, max_wait=0.01, max_retries=3)
    with chaos.inject(chaos.FaultSpec("serve.dispatch", "error")):
        ids = [eng.submit(dom, _state(dom, 40, seed=i)) for i in range(4)]
        _drain(eng)
        responses = eng.take_responses()
    assert {r.req_id for r in responses} == set(ids)
    assert all(r.status == "failed" for r in responses)
    assert all(r.attempts == eng.max_retries + 1 for r in responses)
    assert eng.metrics.failed == 4
    assert eng.metrics.retries > 0
    assert eng.pending() == 0


def test_transient_fault_recovers_with_parity():
    dom = _dom()
    eng = ServingEngine(max_batch=2, max_wait=0.01)
    state = _state(dom, 40)
    with chaos.inject(chaos.FaultSpec("serve.dispatch", "error",
                                      max_fires=1)):
        rid = eng.submit(dom, state)
        _drain(eng)
        resp = {r.req_id: r for r in eng.take_responses()}[rid]
    assert resp.status == "ok" and resp.attempts == 1
    sc = classify(dom, eng.kernel, 40, (), eng.min_n_cap)
    f_ref, u_ref = eng.class_plan(sc).execute(state)
    _assert_bitwise(resp.forces, f_ref)
    _assert_bitwise(resp.potential, u_ref)
    assert eng.metrics.retries == 1 and eng.metrics.failed == 0


def test_class_breaker_quarantines_then_restores():
    dom = _dom()
    eng = ServingEngine(max_batch=1, max_wait=0.01, max_retries=0,
                        breaker_threshold=2, breaker_recovery=2)
    state = _state(dom, 40)
    sc = classify(dom, eng.kernel, 40, (), eng.min_n_cap)
    with chaos.inject(chaos.FaultSpec("serve.dispatch", "error",
                                      max_fires=2)):
        for i in range(2):
            eng.submit(dom, _state(dom, 40, seed=i))
            eng.flush()
    assert eng.class_breaker(sc).open
    assert eng.metrics.breaker_opens == 1
    assert eng.metrics.breaker_open_classes == 1
    primary = eng.class_primary(sc)
    quarantined = eng.class_plan(sc)
    assert quarantined == api.fallback_plan(primary)
    assert quarantined.backend == "reference"

    # the quarantined class still answers — and bit-identically, because
    # the fallback rung computes the same forces
    rid = eng.submit(dom, state)
    eng.flush()
    resp = {r.req_id: r for r in eng.take_responses()}[rid]
    assert resp.status == "ok"
    f_ref, u_ref = primary.execute(state)
    _assert_bitwise(resp.forces, f_ref)
    _assert_bitwise(resp.potential, u_ref)

    # one more clean dispatch closes the breaker and restores the primary
    eng.submit(dom, _state(dom, 40, seed=9))
    eng.flush()
    eng.take_responses()
    assert not eng.class_breaker(sc).open
    assert eng.metrics.breaker_closes == 1
    assert eng.metrics.breaker_open_classes == 0
    assert eng.class_plan(sc) == primary


def test_quarantine_does_not_poison_other_classes():
    dom = _dom()
    eng = ServingEngine(max_batch=1, max_wait=0.01, max_retries=0,
                        breaker_threshold=1, breaker_recovery=100)
    sc_small = classify(dom, eng.kernel, 40, (), eng.min_n_cap)
    sc_big = classify(dom, eng.kernel, 200, (), eng.min_n_cap)
    assert sc_small != sc_big
    with chaos.inject(chaos.FaultSpec("serve.dispatch", "error",
                                      max_fires=1)):
        eng.submit(dom, _state(dom, 40))       # trips sc_small's breaker
        eng.flush()
    eng.submit(dom, _state(dom, 200))
    eng.flush()
    eng.take_responses()
    assert eng.class_breaker(sc_small).open
    br_big = eng.class_breaker(sc_big)
    assert br_big is None or not br_big.open
    assert eng.class_primary(sc_big) is None   # never quarantined


def test_serving_survives_mixed_fault_schedule():
    """The serving headline: a mixed seeded schedule over a real workload
    -> the queue drains, every request gets a definite status, nothing
    raises, and the fault counters are visible in the snapshot."""
    dom = _dom()
    eng = ServingEngine(max_batch=4, max_wait=0.01, max_retries=3)
    specs = (
        chaos.FaultSpec("serve.dispatch", "error", p=0.3),
        chaos.FaultSpec("serve.dispatch", "delay", p=0.2, param=0.02),
        chaos.FaultSpec("serve.dispatch", "nonfinite", p=0.1),
    )
    n = 30
    with chaos.inject(*specs, seed=42) as st:
        for i in range(n):
            eng.submit(dom, _state(dom, 40 + 10 * (i % 3), seed=i),
                       deadline_s=None if i % 5 else 30.0)
            eng.clock.advance(0.005)
            eng.poll()
        _drain(eng)
        assert st.fire_count() > 0             # the schedule actually bit
        responses = eng.take_responses()
    assert len(responses) == n
    assert all(r.status in RESPONSE_STATUSES for r in responses)
    ok = [r for r in responses if r.status == "ok"]
    assert ok                                  # some requests succeeded
    assert all(np.all(np.isfinite(np.asarray(r.forces))) for r in ok)
    snap = eng.metrics.snapshot()
    assert snap["faults"] > 0
    assert snap["served"] + snap["failed"] + snap["deadline_expired"] == n
    assert eng.pending() == 0


def test_fault_free_serving_keeps_zero_recompile_steady_state():
    """With injection disabled the resilience layer must be invisible:
    the PR 6 steady-state guarantee (warm second pass -> zero recompiles,
    zero timing runs) still holds, and responses stay bit-identical."""
    dom = _dom()
    eng = ServingEngine(max_batch=4, max_wait=0.01)
    states = [_state(dom, 50, seed=i) for i in range(8)]

    def one_pass():
        out = {}
        for s in states:
            rid = eng.submit(dom, s)
            eng.clock.advance(0.02)
            eng.poll()
        eng.flush()
        for r in eng.take_responses():
            out[r.req_id] = r
        return out

    first = one_pass()
    eng.clock = VirtualClock()
    eng.metrics = ServeMetrics()
    rc0, tr0 = recompile_count(), at.timing_run_count()
    second = one_pass()
    assert recompile_count() == rc0
    assert at.timing_run_count() == tr0
    assert all(r.status == "ok" for r in second.values())
    f1 = [first[k].forces for k in sorted(first)]
    f2 = [second[k].forces for k in sorted(second)]
    for a, b in zip(f1, f2):
        _assert_bitwise(a, b)
    snap = eng.metrics.snapshot()
    assert snap["faults"] == 0 and snap["retries"] == 0
    assert snap["breaker_opens"] == 0 and snap["failed"] == 0
