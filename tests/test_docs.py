"""Docs gate (``tools/check_docs.py``) + the ISSUE 5 docs acceptance:
ARCHITECTURE.md exists, is linked from the README, and no intra-repo
markdown link is dead."""

import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
if str(ROOT) not in sys.path:
    sys.path.insert(0, str(ROOT))

from tools import check_docs as CD  # noqa: E402


def test_architecture_doc_exists_and_linked_from_readme():
    assert (ROOT / "ARCHITECTURE.md").exists()
    links = CD.markdown_links(ROOT / "README.md")
    assert any(t.split("#")[0] == "ARCHITECTURE.md" for t in links), \
        "README must link ARCHITECTURE.md"


def test_repo_docs_have_no_dead_links():
    broken = CD.check_links([ROOT / "README.md", ROOT / "ARCHITECTURE.md"])
    assert broken == [], f"dead intra-repo links: {broken}"


def test_check_links_catches_dead_target(tmp_path):
    md = tmp_path / "doc.md"
    md.write_text("see [here](missing.md) and [ok](real.md) and "
                  "[web](https://example.com) and [anchor](#section)")
    (tmp_path / "real.md").write_text("x")
    broken = CD.check_links([md])
    assert broken == [(str(md), "missing.md")]


def test_quickstart_block_extracted_and_sane():
    code = CD.first_python_block(ROOT / "README.md")
    # the quickstart must exercise the plan/execute front door
    assert "plan(" in code and "execute" in code


def test_quickstart_runner_propagates_failure(tmp_path):
    md = tmp_path / "bad.md"
    md.write_text("```python\nraise RuntimeError('boom')\n```")
    assert CD.main(["--quickstart", str(md)]) == 1
    good = tmp_path / "good.md"
    good.write_text("```python\nx = 1 + 1\n```")
    assert CD.main(["--quickstart", str(good)]) == 0


def test_main_link_mode_exit_codes(tmp_path):
    md = tmp_path / "doc.md"
    md.write_text("[dead](nope.md)")
    assert CD.main(["--links", str(md)]) == 1
    md.write_text("[live](doc.md)")
    assert CD.main(["--links", str(md)]) == 0
