"""MD/SPH integration: conservation properties over real trajectories."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CellListEngine, Domain, make_lennard_jones, suggest_m_c
from repro.physics import (init_state, run, total_energy, total_momentum)
from repro.physics.sph import SPHParams, density


@pytest.fixture(scope="module")
def md_setup():
    dom = Domain.cubic(4, cutoff=1.0, periodic=True)
    key = jax.random.PRNGKey(0)
    pos = dom.sample_uniform(key, 200)
    kern = make_lennard_jones(sigma=0.25, eps=1.0, softening=1e-4)
    eng = CellListEngine(dom, kern, m_c=max(16, suggest_m_c(dom, pos)),
                         strategy="xpencil")
    # relax overlaps first (uniform-random placement puts particles inside
    # the LJ core; conservation only holds on a physical trajectory) —
    # clipped-force descent, same recipe as examples/md_lennard_jones.py
    box = jnp.asarray(dom.box)
    for _ in range(120):
        f, _ = eng.compute(pos)
        pos = jnp.mod(pos + jnp.clip(f, -1.0, 1.0) * 2e-3, box)
    vel = 0.05 * jax.random.normal(jax.random.PRNGKey(1), pos.shape)
    state = init_state(eng, pos, vel)
    return dom, eng, state


def test_energy_conservation(md_setup):
    """Velocity-Verlet: total energy drift stays small over 200 steps."""
    dom, eng, state = md_setup
    final, traces = run(eng, state, n_steps=200, dt=1e-4)
    e = np.asarray(traces["total"])
    drift = abs(e[-1] - e[0]) / (abs(e[0]) + 1e-9)
    assert drift < 5e-2, f"energy drift {drift:.3e}"
    assert np.isfinite(np.asarray(final.positions)).all()


def test_momentum_conservation(md_setup):
    dom, eng, state = md_setup
    p0 = np.asarray(total_momentum(state.velocities))
    final, _ = run(eng, state, n_steps=100, dt=1e-4)
    p1 = np.asarray(total_momentum(final.velocities))
    np.testing.assert_allclose(p1, p0, atol=5e-3)


def test_particles_stay_in_box(md_setup):
    dom, eng, state = md_setup
    final, _ = run(eng, state, n_steps=50, dt=1e-4)
    pos = np.asarray(final.positions)
    assert (pos >= 0).all() and (pos <= np.asarray(dom.box)).all()


def test_sph_density_positive_and_near_uniform():
    """Uniform particles -> near-uniform density away from borders."""
    dom = Domain.cubic(6, cutoff=1.0, periodic=True)
    pos = dom.sample_uniform(jax.random.PRNGKey(2), 6 ** 3 * 20)
    m_c = suggest_m_c(dom, pos)
    params = SPHParams(h=1.0, mass=1.0)
    rho = np.asarray(density(dom, pos, params, m_c))
    assert (rho > 0).all()
    cv = rho.std() / rho.mean()
    assert cv < 0.5, f"density CV {cv:.3f} too high for uniform input"


def test_integrator_reversibility():
    """Verlet is time-reversible: forward n steps, negate v, return."""
    dom = Domain.cubic(3, cutoff=1.0, periodic=True)
    pos = dom.sample_uniform(jax.random.PRNGKey(4), 80)
    kern = make_lennard_jones(sigma=0.2, softening=1e-4)
    eng = CellListEngine(dom, kern, m_c=24, strategy="cell_dense")
    state = init_state(eng, pos, 0.02 * jax.random.normal(
        jax.random.PRNGKey(5), pos.shape))
    fwd, _ = run(eng, state, n_steps=20, dt=5e-5)
    back = init_state(eng, fwd.positions, -fwd.velocities)
    rev, _ = run(eng, back, n_steps=20, dt=5e-5)
    np.testing.assert_allclose(np.asarray(rev.positions),
                               np.asarray(state.positions),
                               rtol=1e-3, atol=1e-3)
