"""Pallas kernels vs ref.py oracles — shape/dtype sweeps (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CellListEngine, Domain, bin_particles,
                        make_lennard_jones, make_low_flop, suggest_m_c)
from repro.kernels import (allin_interactions, window_attention,
                           xpencil_interactions)
from repro.kernels import ref as KR


def _bins(division, n, seed=0, periodic=False, kernel=None):
    dom = Domain.cubic(division, cutoff=1.0, periodic=periodic)
    pos = dom.sample_uniform(jax.random.PRNGKey(seed), n)
    m_c = suggest_m_c(dom, pos)
    bins = bin_particles(dom, pos, m_c=m_c)
    kern = kernel or make_lennard_jones()
    f_ref, p_ref = CellListEngine(dom, kern, m_c=m_c,
                                  strategy="naive_n2").compute(pos)
    return dom, pos, bins, kern, f_ref, p_ref


@pytest.mark.parametrize("division,n", [(2, 60), (3, 200), (4, 500),
                                        (5, 700)])
def test_xpencil_kernel_sweep(division, n):
    dom, pos, bins, kern, f_ref, p_ref = _bins(division, n)
    f, p = xpencil_interactions(dom, bins, kern, interpret=True)
    np.testing.assert_allclose(np.asarray(f), np.asarray(f_ref),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(p), np.asarray(p_ref),
                               rtol=3e-4, atol=3e-5)


def test_xpencil_kernel_periodic():
    dom, pos, bins, kern, f_ref, p_ref = _bins(4, 300, seed=3, periodic=True)
    f, p = xpencil_interactions(dom, bins, kern, interpret=True)
    np.testing.assert_allclose(np.asarray(f), np.asarray(f_ref),
                               rtol=3e-4, atol=3e-4)


def test_xpencil_kernel_low_flop():
    dom, pos, bins, kern, f_ref, p_ref = _bins(3, 150, kernel=make_low_flop())
    f, p = xpencil_interactions(dom, bins, kern, interpret=True)
    np.testing.assert_allclose(np.asarray(f), np.asarray(f_ref),
                               rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("division,n,box", [(4, 400, (2, 2, 2)),
                                            (4, 300, (4, 2, 1)),
                                            (6, 800, (3, 3, 2))])
def test_allin_kernel_sweep(division, n, box):
    dom, pos, bins, kern, f_ref, p_ref = _bins(division, n)
    f, p = allin_interactions(dom, bins, kern, box, interpret=True)
    np.testing.assert_allclose(np.asarray(f), np.asarray(f_ref),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(p), np.asarray(p_ref),
                               rtol=3e-4, atol=3e-5)


def test_kernel_matches_jnp_strategy_planes():
    """Pallas xpencil output == the jnp xpencil schedule, slot for slot."""
    dom, pos, bins, kern, _, _ = _bins(4, 500, seed=8)
    ref_planes = KR.xpencil_ref(dom, bins, kern)
    from repro.kernels.xpencil import xpencil_forces
    got = xpencil_forces(bins.planes, bins.slot_id, nx=dom.nx, m_c=bins.m_c,
                         kernel=kern, cutoff2=1.0, interpret=True)
    for g, r in zip(got, ref_planes):
        np.testing.assert_allclose(np.asarray(g), np.asarray(r),
                                   rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# window attention kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("h,kh", [(4, 4), (8, 2), (6, 1)])
@pytest.mark.parametrize("window,blk", [(16, 8), (32, 16), (64, 8)])
def test_window_attention_sweep(h, kh, window, blk):
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (2, h, 64, 16), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (2, kh, 64, 16), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (2, kh, 64, 16), jnp.float32)
    o = window_attention(q, k, v, window=window, blk=blk, interpret=True)
    o_ref = KR.window_attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_window_attention_dtypes(dtype):
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 4, 32, 8)).astype(dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 2, 32, 8)).astype(dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 2, 32, 8)).astype(dtype)
    o = window_attention(q, k, v, window=8, blk=8, interpret=True)
    o_ref = KR.window_attention_ref(q, k, v, window=8)
    tol = 3e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32),
                               rtol=tol, atol=tol)
    assert o.dtype == dtype


def test_window_attention_softcap():
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 4, 32, 8), jnp.float32) * 3
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 4, 32, 8), jnp.float32) * 3
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 4, 32, 8), jnp.float32)
    o = window_attention(q, k, v, window=16, blk=8, softcap=20.0,
                         interpret=True)
    o_ref = KR.window_attention_ref(q, k, v, window=16, softcap=20.0)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=3e-4, atol=3e-4)
