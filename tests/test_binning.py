"""Binning pipeline invariants (paper §2 preprocessing)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import Domain, bin_particles, gather_to_particles, suggest_m_c
from repro.core.binning import EMPTY_POS, interior


def _random_case(seed, division, n):
    dom = Domain.cubic(division, cutoff=1.0)
    pos = dom.sample_uniform(jax.random.PRNGKey(seed), n)
    return dom, pos


@given(st.integers(0, 10_000), st.sampled_from([2, 3, 4, 6]),
       st.integers(1, 400))
@settings(max_examples=25, deadline=None)
def test_every_particle_lands_in_its_cell(seed, division, n):
    dom, pos = _random_case(seed, division, n)
    m_c = suggest_m_c(dom, pos)
    bins = bin_particles(dom, pos, m_c=m_c)

    # counts sum to N, offsets are the exclusive scan of counts
    counts = np.asarray(bins.counts)
    assert counts.sum() == n
    np.testing.assert_array_equal(
        np.asarray(bins.offsets), np.concatenate([[0], np.cumsum(counts)[:-1]]))

    # the slot of each particle holds its coordinates, in its own cell
    sid = np.asarray(bins.slot_id).reshape(-1)
    xs = np.asarray(bins.planes["x"]).reshape(-1)
    pslot = np.asarray(bins.particle_slot)
    pnp = np.asarray(pos)
    cells = np.asarray(dom.cell_coords(pos))
    nx, ny, nz = dom.ncells
    row = (nx + 2) * m_c
    for i in range(n):
        s = pslot[i]
        assert sid[s] == i
        assert xs[s] == pytest.approx(pnp[i, 0], rel=1e-6)
        z = s // ((ny + 2) * row)
        y = (s // row) % (ny + 2)
        x = (s % row) // m_c
        assert (x - 1, y - 1, z - 1) == tuple(cells[i])

    # every filled slot belongs to exactly one particle (bijection)
    filled = sid[sid >= 0]
    assert len(filled) == n and len(set(filled.tolist())) == n


def test_gather_inverts_scatter():
    dom, pos = _random_case(7, 4, 300)
    m_c = suggest_m_c(dom, pos)
    bins = bin_particles(dom, pos, m_c=m_c)
    for k, col in (("x", 0), ("y", 1), ("z", 2)):
        back = gather_to_particles(bins, bins.planes[k])
        np.testing.assert_allclose(np.asarray(back), np.asarray(pos[:, col]),
                                   rtol=1e-6)


def test_overflow_drops_not_corrupts():
    """m_c smaller than a cell's population: extras are dropped cleanly."""
    dom = Domain.cubic(2, cutoff=1.0)
    pos = jnp.asarray(np.full((40, 3), 0.5, np.float32))  # all in one cell
    bins = bin_particles(dom, pos, m_c=8)
    sid = np.asarray(bins.slot_id)
    assert (sid >= 0).sum() == 8            # capacity respected
    assert int(bins.max_count) == 40        # caller can detect overflow


def test_ghost_ring_empty_when_open():
    dom, pos = _random_case(3, 4, 200)
    m_c = suggest_m_c(dom, pos)
    bins = bin_particles(dom, pos, m_c=m_c)
    sid = np.asarray(bins.slot_id)
    nx, ny, nz = dom.ncells
    assert (sid[0] == -1).all() and (sid[-1] == -1).all()
    assert (sid[:, 0] == -1).all() and (sid[:, ny + 1] == -1).all()
    assert (sid[:, :, :m_c] == -1).all()
    assert (sid[:, :, (nx + 1) * m_c:] == -1).all()
    x = np.asarray(bins.planes["x"])
    assert (x[0] == EMPTY_POS).all()


def test_periodic_ghosts_are_shifted_images():
    dom = Domain.cubic(4, cutoff=1.0, periodic=True)
    pos = dom.sample_uniform(jax.random.PRNGKey(5), 300)
    m_c = suggest_m_c(dom, pos)
    bins = bin_particles(dom, pos, m_c=m_c)
    x = np.asarray(bins.planes["x"])
    m = x[:, :, :m_c] < 1e7                 # filled left ghosts
    # left ghost = rightmost interior cell shifted by -Lx
    src = x[:, :, 4 * m_c:5 * m_c]
    np.testing.assert_allclose(x[:, :, :m_c][m], (src - dom.box[0])[m],
                               rtol=1e-6)
    sid = np.asarray(bins.slot_id)
    ghost_ids = sid[:, :, :m_c][sid[:, :, :m_c] >= 0]
    assert (ghost_ids >= 1_000_000_000).all()   # image ids offset


def test_interior_view_shape():
    dom, pos = _random_case(1, 3, 100)
    m_c = suggest_m_c(dom, pos)
    bins = bin_particles(dom, pos, m_c=m_c)
    v = interior(dom, bins.planes["x"], m_c)
    assert v.shape == (3, 3, 3, m_c)


# ---------------------------------------------------------------------------
# periodic ghost slot-id bumping (_fill_periodic_ghosts) on a 1-cell-thick
# axis: the ghost ring of the single x-cell holds that same cell's own
# particles as periodic images. Their slot ids must be bumped (id + 1e9) so
# the schedules' self-mask (sid != tid) excludes only the *true* self-pair,
# never a particle's periodic image.
# ---------------------------------------------------------------------------

def _thin_domain():
    # one cell along x (width 1.2 >= cutoff 1.0), periodic in x only
    return Domain(box=(1.2, 4.0, 4.0), ncells=(1, 4, 4), cutoff=1.0,
                  periodic=(True, False, False))


def test_thin_axis_ghost_ids_are_bumped_images():
    dom = _thin_domain()
    pos = jnp.asarray(np.random.RandomState(0).uniform(
        [0, 0, 0], [1.2, 4, 4], (60, 3)), jnp.float32)
    m_c = suggest_m_c(dom, pos)
    bins = bin_particles(dom, pos, m_c=m_c)
    sid = np.asarray(bins.slot_id)
    interior_ids = sid[:, :, m_c:2 * m_c]
    left, right = sid[:, :, :m_c], sid[:, :, 2 * m_c:]
    # with nx == 1 both ghost columns mirror the single interior column
    filled = interior_ids >= 0
    assert filled.any()
    np.testing.assert_array_equal(left[filled],
                                  interior_ids[filled] + 1_000_000_000)
    np.testing.assert_array_equal(right[filled],
                                  interior_ids[filled] + 1_000_000_000)
    # interior ids themselves are never bumped
    assert (interior_ids[filled] < 1_000_000_000).all()
    # ghost coordinates are the interior shifted by exactly +-Lx
    x = np.asarray(bins.planes["x"])
    np.testing.assert_allclose(x[:, :, :m_c][filled],
                               x[:, :, m_c:2 * m_c][filled] - 1.2,
                               rtol=1e-6)
    # the bumped id passes the schedules' self-mask (a particle interacts
    # with its own periodic image); the raw id does not (never with itself)
    assert (left[filled] != interior_ids[filled]).all()


def test_thin_axis_double_periodic_ghosts_bump_once():
    # corner ghosts crossing two periodic axes must not double-bump (the
    # bump() guard): ids stay in [1e9, 2e9)
    dom = Domain(box=(1.2, 1.2, 4.0), ncells=(1, 1, 4), cutoff=1.0,
                 periodic=(True, True, False))
    pos = jnp.asarray(np.random.RandomState(1).uniform(
        [0, 0, 0], [1.2, 1.2, 4], (30, 3)), jnp.float32)
    m_c = suggest_m_c(dom, pos)
    bins = bin_particles(dom, pos, m_c=m_c)
    sid = np.asarray(bins.slot_id)
    ghosts = sid[sid >= 1_000_000_000]
    assert len(ghosts) > 0
    assert (ghosts < 2_000_000_000).all()


def test_thin_axis_forces_match_minimum_image_oracle():
    """A pair interacting only *through* the periodic boundary of the
    1-cell-thick axis: the cell engine must reproduce the minimum-image
    oracle (the interaction lives entirely in the bumped ghost slots)."""
    from repro.core import ParticleState, make_lennard_jones, plan
    dom = _thin_domain()
    pos = jnp.asarray([[0.05, 1.5, 1.5],        # A
                       [1.15, 1.5, 1.5]],       # B: direct dist 1.1 (> r_c),
                      jnp.float32)              # image dist 0.1 (< r_c)
    kern = make_lennard_jones()
    state = ParticleState(pos)
    f_o, q_o = plan(dom, kern, m_c=8, strategy="naive_n2").execute(state)
    assert float(jnp.abs(q_o).max()) > 0        # the pair really interacts
    for strategy in ("xpencil", "cell_dense", "par_part", "allin"):
        f, q = plan(dom, kern, m_c=8, strategy=strategy).execute(state)
        np.testing.assert_allclose(np.asarray(f), np.asarray(f_o),
                                   rtol=3e-4, atol=3e-4,
                                   err_msg=strategy)
        np.testing.assert_allclose(np.asarray(q), np.asarray(q_o),
                                   rtol=3e-4, atol=3e-5, err_msg=strategy)


def test_thin_axis_single_particle_sees_no_self_force():
    """A lone particle's own periodic images sit exactly one box length
    away (>= cutoff by the domain invariant): zero force, zero potential —
    and crucially not NaN, which a broken self-mask would produce."""
    from repro.core import ParticleState, make_lennard_jones, plan
    dom = _thin_domain()
    state = ParticleState(jnp.asarray([[0.6, 2.0, 2.0]], jnp.float32))
    f, q = plan(dom, make_lennard_jones(), m_c=8,
                strategy="xpencil").execute(state)
    np.testing.assert_array_equal(np.asarray(f), np.zeros((1, 3)))
    np.testing.assert_array_equal(np.asarray(q), np.zeros((1,)))
