"""Binning pipeline invariants (paper §2 preprocessing)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import Domain, bin_particles, gather_to_particles, suggest_m_c
from repro.core.binning import EMPTY_POS, interior


def _random_case(seed, division, n):
    dom = Domain.cubic(division, cutoff=1.0)
    pos = dom.sample_uniform(jax.random.PRNGKey(seed), n)
    return dom, pos


@given(st.integers(0, 10_000), st.sampled_from([2, 3, 4, 6]),
       st.integers(1, 400))
@settings(max_examples=25, deadline=None)
def test_every_particle_lands_in_its_cell(seed, division, n):
    dom, pos = _random_case(seed, division, n)
    m_c = suggest_m_c(dom, pos)
    bins = bin_particles(dom, pos, m_c=m_c)

    # counts sum to N, offsets are the exclusive scan of counts
    counts = np.asarray(bins.counts)
    assert counts.sum() == n
    np.testing.assert_array_equal(
        np.asarray(bins.offsets), np.concatenate([[0], np.cumsum(counts)[:-1]]))

    # the slot of each particle holds its coordinates, in its own cell
    sid = np.asarray(bins.slot_id).reshape(-1)
    xs = np.asarray(bins.planes["x"]).reshape(-1)
    pslot = np.asarray(bins.particle_slot)
    pnp = np.asarray(pos)
    cells = np.asarray(dom.cell_coords(pos))
    nx, ny, nz = dom.ncells
    row = (nx + 2) * m_c
    for i in range(n):
        s = pslot[i]
        assert sid[s] == i
        assert xs[s] == pytest.approx(pnp[i, 0], rel=1e-6)
        z = s // ((ny + 2) * row)
        y = (s // row) % (ny + 2)
        x = (s % row) // m_c
        assert (x - 1, y - 1, z - 1) == tuple(cells[i])

    # every filled slot belongs to exactly one particle (bijection)
    filled = sid[sid >= 0]
    assert len(filled) == n and len(set(filled.tolist())) == n


def test_gather_inverts_scatter():
    dom, pos = _random_case(7, 4, 300)
    m_c = suggest_m_c(dom, pos)
    bins = bin_particles(dom, pos, m_c=m_c)
    for k, col in (("x", 0), ("y", 1), ("z", 2)):
        back = gather_to_particles(bins, bins.planes[k])
        np.testing.assert_allclose(np.asarray(back), np.asarray(pos[:, col]),
                                   rtol=1e-6)


def test_overflow_drops_not_corrupts():
    """m_c smaller than a cell's population: extras are dropped cleanly."""
    dom = Domain.cubic(2, cutoff=1.0)
    pos = jnp.asarray(np.full((40, 3), 0.5, np.float32))  # all in one cell
    bins = bin_particles(dom, pos, m_c=8)
    sid = np.asarray(bins.slot_id)
    assert (sid >= 0).sum() == 8            # capacity respected
    assert int(bins.max_count) == 40        # caller can detect overflow


def test_ghost_ring_empty_when_open():
    dom, pos = _random_case(3, 4, 200)
    m_c = suggest_m_c(dom, pos)
    bins = bin_particles(dom, pos, m_c=m_c)
    sid = np.asarray(bins.slot_id)
    nx, ny, nz = dom.ncells
    assert (sid[0] == -1).all() and (sid[-1] == -1).all()
    assert (sid[:, 0] == -1).all() and (sid[:, ny + 1] == -1).all()
    assert (sid[:, :, :m_c] == -1).all()
    assert (sid[:, :, (nx + 1) * m_c:] == -1).all()
    x = np.asarray(bins.planes["x"])
    assert (x[0] == EMPTY_POS).all()


def test_periodic_ghosts_are_shifted_images():
    dom = Domain.cubic(4, cutoff=1.0, periodic=True)
    pos = dom.sample_uniform(jax.random.PRNGKey(5), 300)
    m_c = suggest_m_c(dom, pos)
    bins = bin_particles(dom, pos, m_c=m_c)
    x = np.asarray(bins.planes["x"])
    m = x[:, :, :m_c] < 1e7                 # filled left ghosts
    # left ghost = rightmost interior cell shifted by -Lx
    src = x[:, :, 4 * m_c:5 * m_c]
    np.testing.assert_allclose(x[:, :, :m_c][m], (src - dom.box[0])[m],
                               rtol=1e-6)
    sid = np.asarray(bins.slot_id)
    ghost_ids = sid[:, :, :m_c][sid[:, :, :m_c] >= 0]
    assert (ghost_ids >= 1_000_000_000).all()   # image ids offset


def test_interior_view_shape():
    dom, pos = _random_case(1, 3, 100)
    m_c = suggest_m_c(dom, pos)
    bins = bin_particles(dom, pos, m_c=m_c)
    v = interior(dom, bins.planes["x"], m_c)
    assert v.shape == (3, 3, 3, m_c)
