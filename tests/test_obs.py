"""Observability layer: tracer, metrics registry, audit, profile, shims.

The contracts under test, in the order the module docstrings state them:

* tracing is **off by default** and a disabled tracer is a no-op — zero
  recorded spans and unchanged ``dispatch_count`` semantics;
* enabled tracing records spans/events with attrs and exports both JSONL
  and Chrome ``trace_event`` JSON that parse and carry the span names the
  instrumented subsystems emit;
* the metrics registry is the one counter store: the historical
  ``dispatch_count`` / ``recompile_count`` / ``replan_count`` /
  ``timing_run_count`` functions are shims over it, ``render_prom``
  exposes the families with (backend, strategy, layout) labels, and **one
  ``reset_counters()`` clears every steady-state counter** (the footgun
  this PR closes);
* the traffic audit reports near-zero drift where the uniform model is
  honest and surfaces a deliberately mis-modelled candidate as nonzero
  drift;
* serving metrics edge cases: percentile interpolation, NaN-on-empty,
  VirtualClock monotonicity, LatencyStats snapshot stability.
"""

import json
import math

import jax
import numpy as np
import pytest

from repro import obs
from repro.core import Domain, make_lennard_jones, plan, scenarios
from repro.core import api, autotune
from repro.core.api import ParticleState
from repro.serve.metrics import LatencyStats, ServeMetrics, VirtualClock, \
    percentile


@pytest.fixture(autouse=True)
def _clean_tracer():
    """Tracing off + empty buffer around every test (process-global)."""
    obs.disable()
    obs.clear()
    yield
    obs.disable()
    obs.clear()


@pytest.fixture(scope="module")
def tiny():
    dom = Domain.cubic(3, cutoff=1.0)
    pos = dom.sample_uniform(jax.random.PRNGKey(0), 60)
    p = plan(dom, make_lennard_jones(), positions=pos)
    return dom, pos, p, ParticleState(pos)


# ---------------------------------------------------------------- tracer

def test_tracing_disabled_records_nothing(tiny):
    _, _, p, state = tiny
    before = api.dispatch_count()
    with obs.trace("should.not.appear", k=1):
        pass
    obs.event("also.not.recorded")
    p.execute(state)
    assert obs.stats()["recorded"] == 0
    assert obs.spans() == []
    # counting semantics are unchanged by the (disabled) tracer
    assert api.dispatch_count() == before + 1


def test_tracing_records_spans_events_and_errors():
    obs.enable()
    with obs.trace("outer", layer="test") as sp:
        sp.set(extra=7)
        obs.event("tick", n=1)
    with pytest.raises(ValueError):
        with obs.trace("boom"):
            raise ValueError("x")
    recs = obs.spans()
    names = [r["name"] for r in recs]
    assert names == ["tick", "outer", "boom"]   # spans close after events
    outer = recs[1]
    assert outer["ph"] == "X" and outer["dur"] >= 0.0
    assert outer["attrs"] == {"layer": "test", "extra": 7}
    assert recs[0]["ph"] == "i"
    assert recs[2]["attrs"]["error"] == "ValueError"
    assert obs.stats()["recorded"] == 3


def test_tracing_context_manager_restores_state():
    assert not obs.tracing_enabled()
    with obs.tracing():
        assert obs.tracing_enabled()
        obs.event("inside")
    assert not obs.tracing_enabled()
    assert [r["name"] for r in obs.spans()] == ["inside"]


def test_ring_buffer_caps_and_counts_drops():
    obs.enable(capacity=4)
    for i in range(10):
        obs.event("e", i=i)
    st = obs.stats()
    assert st["recorded"] == 4 and st["dropped"] == 6
    assert [r["attrs"]["i"] for r in obs.spans()] == [6, 7, 8, 9]


def test_execute_emits_plan_spans(tiny):
    _, _, p, state = tiny
    obs.enable()
    p.execute(state)
    by_name = {r["name"]: r for r in obs.spans()}
    assert "plan.execute" in by_name
    at = by_name["plan.execute"]["attrs"]
    assert at["strategy"] == p.strategy and at["layout"] == p.layout
    assert at["backend"] == p.backend


def test_exports_parse_and_convert(tiny, tmp_path):
    _, _, p, state = tiny
    obs.enable()
    p.execute(state)
    obs.event("marker", k="v")
    jl = tmp_path / "t.trace.jsonl"
    ch = tmp_path / "t.trace.json"
    n_jl = obs.export_jsonl(jl)
    n_ch = obs.export_chrome_trace(ch)
    assert n_jl == n_ch == obs.stats()["recorded"]
    lines = [json.loads(l) for l in jl.read_text().splitlines()]
    assert {r["name"] for r in lines} >= {"plan.execute", "marker"}
    payload = json.loads(ch.read_text())
    evs = payload["traceEvents"]
    assert payload["displayTimeUnit"] == "ms" and len(evs) == n_ch
    for e in evs:
        assert {"name", "ph", "ts", "pid", "tid"} <= set(e)
        assert e["ph"] in ("X", "i")
        if e["ph"] == "X":
            assert e["dur"] >= 0                      # microseconds
        else:
            assert e["s"] == "t"
    # the CLI summarizes the JSONL form
    import subprocess, sys, pathlib
    root = pathlib.Path(__file__).resolve().parents[1]
    out = subprocess.run(
        [sys.executable, str(root / "tools" / "trace_view.py"), str(jl)],
        capture_output=True, text=True)
    assert out.returncode == 0 and "plan.execute" in out.stdout


# -------------------------------------------------------------- registry

def test_registry_counter_labels_and_total():
    reg = obs.MetricsRegistry()
    reg.counter("hits", kind="a").inc()
    reg.counter("hits", kind="a").inc(2)
    reg.counter("hits", kind="b").inc()
    assert reg.total("hits") == 4.0
    assert reg.get("hits", kind="a").value == 3.0
    assert reg.get("hits", kind="zzz") is None
    assert reg.total("absent") == 0.0
    snap = reg.snapshot()
    assert snap["hits"] == {'{kind="a"}': 3.0, '{kind="b"}': 1.0}


def test_registry_kind_conflict_rejected():
    reg = obs.MetricsRegistry()
    reg.counter("x").inc()
    with pytest.raises(ValueError):
        reg.gauge("x")


def test_render_prom_families_and_labels(tiny):
    _, _, p, state = tiny
    api.reset_counters()
    p.execute(state)
    text = obs.render_prom()
    assert "# TYPE repro_dispatch_total counter" in text
    want = (f'repro_dispatch_total{{backend="{p.backend}",'
            f'layout="{p.layout}",strategy="{p.strategy}"}} 1')
    assert want in text
    # the recompile family carries the same label set
    assert "# TYPE repro_recompile_total counter" in text
    assert f'strategy="{p.strategy}"' in text


def test_histogram_renders_summary():
    reg = obs.MetricsRegistry()
    h = reg.histogram("lat")
    for v in (1.0, 3.0):
        h.observe(v)
    text = reg.render_prom()
    assert "lat_count 2" in text and "lat_sum 4" in text
    assert "lat_min 1" in text and "lat_max 3" in text
    # an empty (freshly reset) histogram renders NaN min/max, not a crash
    reg.reset()
    assert "lat_min nan" in reg.render_prom()


def test_one_reset_clears_every_steady_state_counter(tiny, tmp_path,
                                                     monkeypatch):
    """The counter-reset footgun: ``reset_counters()`` must clear the
    dispatch / recompile / replan / rebin / autotune families in one call
    — a test that resets 'the counters' and then asserts steady-state
    zero must not be lied to by a family living elsewhere."""
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "cache"))
    dom, pos, p, state = tiny
    p.execute(state)
    autotune.tune(dom, make_lennard_jones(), pos, top_k=2, reps=1,
                  budget_s=0.01)
    reg = obs.registry
    assert api.dispatch_count() > 0
    assert api.recompile_count() > 0
    assert autotune.timing_run_count() > 0
    api.reset_counters()
    for fn in (api.dispatch_count, api.recompile_count, api.replan_count,
               autotune.timing_run_count):
        assert fn() == 0, fn.__name__
    for fam in (api.DISPATCH_TOTAL, api.RECOMPILE_TOTAL, api.REPLAN_TOTAL,
                autotune.TIMING_RUNS_TOTAL, autotune.CACHE_TOTAL):
        assert reg.total(fam) == 0.0, fam
    # cached Counter handles keep working after the in-place reset
    p.execute(state)
    assert api.dispatch_count() == 1


def test_serve_counters_mirror_into_registry():
    m = ServeMetrics()
    m.submitted = 5
    m.served = 3
    assert obs.registry.get("serve_submitted").value == 5.0
    assert obs.registry.get("serve_served").value == 3.0
    assert "serve_submitted 5" in obs.render_prom()


# ----------------------------------------------------------------- audit

@pytest.fixture(scope="module")
def uniform():
    """A periodic uniform scene big enough for the uniform traffic model
    to be honest (open 3^3 boxes are all boundary, and boundary is
    exactly what the uniform model ignores)."""
    dom = Domain.cubic(6, cutoff=1.0, periodic=True)
    pos = dom.sample_uniform(jax.random.PRNGKey(1), 4 * dom.n_cells)
    return dom, pos


def test_audit_uniform_scene_has_small_drift(uniform):
    dom, pos = uniform
    rep = obs.audit_candidate(dom, pos, strategy="xpencil", m_c=12)
    assert math.isfinite(rep["drift"])
    assert abs(rep["drift"]) < 0.25          # uniform model, uniform scene
    assert rep["interactions"] > 0


def test_audit_flags_deliberately_mismodelled_candidate(uniform):
    """A candidate whose modelled cost is 10x the honest model must
    surface drift ~= -0.9 — the audit is the tripwire for a cost model
    that silently rots away from what the schedules actually move."""
    dom, pos = uniform
    honest = obs.audit_candidate(dom, pos, strategy="xpencil", m_c=12)
    lied = obs.audit_candidate(dom, pos, strategy="xpencil", m_c=12,
                               modelled=10.0 * honest["modelled_bpi"])
    assert lied["drift"] == pytest.approx(
        (honest["drift"] + 1.0) / 10.0 - 1.0, rel=1e-6)
    assert lied["drift"] < -0.8
    # recorded as the per-(strategy, layout) gauge
    g = obs.registry.get("repro_traffic_model_drift",
                         strategy="xpencil", layout="dense")
    assert g.value == pytest.approx(lied["drift"])


def test_model_drift_math():
    assert obs.model_drift(2.0, 2.0) == 0.0
    assert obs.model_drift(1.0, 1.5) == pytest.approx(0.5)
    assert math.isnan(obs.model_drift(0.0, 1.0))


def test_tune_audits_pruned_candidates(tmp_path, monkeypatch):
    """Every pruned candidate gets a model-vs-measured audit: on a
    clustered scene the uniform model undersells the interaction count,
    so the recorded drift is decisively nonzero."""
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "cache"))
    obs.registry.reset("repro_traffic_model_drift")
    dom = Domain.cubic(4, cutoff=1.0)
    pos = scenarios.sample_gaussian_blob(dom, jax.random.PRNGKey(2), 128,
                                         sigma_frac=0.15)
    autotune.tune(dom, make_lennard_jones(), pos, top_k=2, reps=1,
                  budget_s=0.01)
    snap = obs.registry.snapshot().get("repro_traffic_model_drift", {})
    assert snap, "tune() recorded no audits"
    assert any(abs(v) > 0.3 for v in snap.values()), snap


# --------------------------------------------------------------- profile

def test_profile_times_and_audits(tiny):
    _, _, p, state = tiny
    rep = obs.profile(p, state, budget_s=0.02)
    assert rep.seconds_per_call > 0 and rep.reps >= 1
    assert rep.strategy == p.strategy and rep.layout == p.layout
    assert math.isfinite(rep.drift)
    # one histogram observation per profile() call (seconds_per_call)
    assert obs.registry.total("repro_execute_seconds") >= 1


# ------------------------------------------------------------- sidecars

def test_write_bench_json_emits_sidecars_only_when_traced(tmp_path, tiny):
    import sys, pathlib
    root = pathlib.Path(__file__).resolve().parents[1]
    if str(root) not in sys.path:
        sys.path.insert(0, str(root))
    from benchmarks.common import bench_record, write_bench_json
    _, _, p, state = tiny
    rec = [bench_record("t", "xpencil", "reference", 1e-3, 3,
                        layout="dense", drift=-0.02)]
    assert rec[0]["drift"] == -0.02
    off = tmp_path / "BENCH_off.json"
    write_bench_json(off, rec)
    assert not list(tmp_path.glob("*.trace.*"))
    obs.enable()
    p.execute(state)
    on = tmp_path / "BENCH_on.json"
    write_bench_json(on, rec)
    assert json.loads((tmp_path / "BENCH_on.trace.json").read_text())[
        "traceEvents"]
    assert (tmp_path / "BENCH_on.trace.jsonl").exists()
    metrics = json.loads((tmp_path / "BENCH_on.metrics.json").read_text())
    assert "repro_dispatch_total" in metrics


# -------------------------------------------- serve metrics edge cases

def test_percentile_two_sample_interpolation():
    assert percentile([1.0, 3.0], 50.0) == pytest.approx(2.0)
    assert percentile([1.0, 3.0], 0.0) == 1.0
    assert percentile([1.0, 3.0], 100.0) == 3.0
    assert percentile([1.0, 3.0], 75.0) == pytest.approx(2.5)
    assert percentile([5.0], 99.0) == 5.0


def test_percentile_nan_on_empty():
    assert math.isnan(percentile([], 50.0))
    s = LatencyStats()
    assert math.isnan(s.mean) and math.isnan(s.p(99.0))
    assert math.isnan(s.summary()["max_s"])


def test_virtual_clock_monotone_under_out_of_order_arrivals():
    clk = VirtualClock()
    clk.advance_to(5.0)
    # a late-scheduled arrival must not rewind the clock
    assert clk.advance_to(3.0) == 5.0
    assert clk.now() == 5.0
    with pytest.raises(ValueError):
        clk.advance(-0.1)
    clk.advance(0.5)
    assert clk() == 5.5


def test_latency_stats_snapshot_stable_under_interleaved_records():
    m = ServeMetrics()
    m.note_submit(0.0)
    m.note_submit(1.0)
    # completions land out of submission order
    m.note_served(t_submit=1.0, t_dispatch=1.5, t_done=2.0)
    snap1 = m.snapshot()
    m.note_served(t_submit=0.0, t_dispatch=0.5, t_done=3.0)
    snap2 = m.snapshot()
    assert snap1["served"] == 1 and snap2["served"] == 2
    # first snapshot unchanged by later records (it is a copy, not a view)
    assert snap1["served"] == 1
    assert snap1["total_latency"]["count"] == 1
    assert snap2["total_latency"]["count"] == 2
    assert snap2["total_latency"]["max_s"] == pytest.approx(3.0)
    assert m.t_last_done == 3.0
    assert m.rps == pytest.approx(2 / 3.0)
