"""All scheduling strategies against the naive oracle + physics properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (CellListEngine, Domain, make_gravity,
                        make_high_flop, make_lennard_jones, make_low_flop,
                        suggest_m_c)

ALL = ["par_part", "cell_dense", "xpencil", "allin"]


def _case(division, n, seed=0, periodic=False):
    dom = Domain.cubic(division, cutoff=1.0, periodic=periodic)
    pos = dom.sample_uniform(jax.random.PRNGKey(seed), n)
    return dom, pos, suggest_m_c(dom, pos)


@pytest.mark.parametrize("strategy", ALL)
@pytest.mark.parametrize("division,n", [(2, 40), (3, 150), (4, 500), (6, 900)])
def test_matches_naive(strategy, division, n):
    dom, pos, m_c = _case(division, n)
    f_ref, p_ref = CellListEngine(dom, m_c=m_c,
                                  strategy="naive_n2").compute(pos)
    f, p = CellListEngine(dom, m_c=m_c, strategy=strategy).compute(pos)
    np.testing.assert_allclose(np.asarray(f), np.asarray(f_ref),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(p), np.asarray(p_ref),
                               rtol=3e-4, atol=3e-5)


@pytest.mark.parametrize("strategy", ALL)
def test_matches_naive_periodic(strategy):
    dom, pos, m_c = _case(4, 400, seed=2, periodic=True)
    f_ref, p_ref = CellListEngine(dom, m_c=m_c,
                                  strategy="naive_n2").compute(pos)
    f, p = CellListEngine(dom, m_c=m_c, strategy=strategy).compute(pos)
    np.testing.assert_allclose(np.asarray(f), np.asarray(f_ref),
                               rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("make", [make_low_flop, make_gravity,
                                  make_high_flop])
def test_other_kernels(make):
    dom, pos, m_c = _case(3, 200, seed=4)
    kern = make()
    f_ref, p_ref = CellListEngine(dom, kern, m_c=m_c,
                                  strategy="naive_n2").compute(pos)
    f, p = CellListEngine(dom, kern, m_c=m_c,
                          strategy="xpencil").compute(pos)
    np.testing.assert_allclose(np.asarray(f), np.asarray(f_ref),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(p), np.asarray(p_ref),
                               rtol=3e-4, atol=3e-5)


@given(seed=st.integers(0, 10_000), division=st.sampled_from([2, 3, 4]),
       n=st.integers(2, 300))
@settings(max_examples=15, deadline=None)
def test_newtons_third_law(seed, division, n):
    """Central pair forces: total internal force is 0 (open boundaries)."""
    dom, pos, m_c = _case(division, n, seed)
    f, _ = CellListEngine(dom, m_c=m_c, strategy="xpencil").compute(pos)
    total = np.asarray(jnp.sum(f, axis=0))
    scale = float(jnp.max(jnp.abs(f))) + 1e-9
    np.testing.assert_allclose(total / scale, np.zeros(3), atol=5e-4)


@given(seed=st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_potential_pair_symmetry(seed):
    """Per-particle potential sums each pair twice: total = 2 * pair sum."""
    dom, pos, m_c = _case(2, 30, seed)
    _, pot = CellListEngine(dom, m_c=m_c, strategy="cell_dense").compute(pos)
    kern = make_lennard_jones()
    pnp = np.asarray(pos)
    total = 0.0
    for i in range(len(pnp)):
        for j in range(i + 1, len(pnp)):
            r2 = float(((pnp[i] - pnp[j]) ** 2).sum())
            if r2 < 1.0:
                total += float(kern.potential(jnp.float32(r2)))
    np.testing.assert_allclose(float(jnp.sum(pot)), 2 * total,
                               rtol=1e-3, atol=1e-4)


def test_permutation_invariance():
    """Shuffling particle order must not change each particle's force."""
    dom, pos, m_c = _case(3, 120, seed=9)
    eng = CellListEngine(dom, m_c=m_c, strategy="xpencil")
    f1, _ = eng.compute(pos)
    perm = jax.random.permutation(jax.random.PRNGKey(1), pos.shape[0])
    f2, _ = eng.compute(pos[perm])
    np.testing.assert_allclose(np.asarray(f2), np.asarray(f1)[np.asarray(perm)],
                               rtol=1e-4, atol=1e-5)


def test_subbox_dims_respects_budget():
    from repro.core.strategies import subbox_dims
    dom = Domain.cubic(8, cutoff=1.0)
    bx, by, bz = subbox_dims(dom, m_c=16, vmem_budget_bytes=64 * 1024)
    halo = (bx + 2) * (by + 2) * (bz + 2)
    assert halo * 16 * 16 <= 64 * 1024 or (bx, by, bz) == (1, 1, 1)


def test_engine_rejects_unknown_strategy():
    dom = Domain.cubic(2)
    with pytest.raises(ValueError):
        CellListEngine(dom, strategy="nope")
