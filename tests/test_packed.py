"""Packed-row (CSR) execution layout: pack/unpack, parity, replan, tuning.

The correctness bar (ISSUE 5): the packed schedules must be *bit-parity*
with their dense oracles on uniform and clustered scenes — packing may only
change where bytes live, never a computed value. Edge cases named by the
issue: empty pencil rows, a row hitting ``row_cap`` exactly, ``row_cap``
overflow growing only that bound, and periodic 1-cell-thick axes.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Domain, ParticleState, bin_particles,
                        full_pencil_occupancy, make_lennard_jones,
                        pack_rows, padded_row_counts, plan, scenarios,
                        suggest_m_c, suggest_row_cap, supports_compact,
                        supports_layout, unpack_scatter)
from repro.core import traffic
from repro.core.binning import cell_counts

KERN = make_lennard_jones()


def _blob(division=6, n=300, seed=0, sigma_frac=0.08, periodic=False):
    dom = Domain.cubic(division, cutoff=1.0, periodic=periodic)
    pos = scenarios.sample_gaussian_blob(
        dom, jax.random.PRNGKey(seed), n, sigma_frac=sigma_frac)
    return dom, pos


# ---------------------------------------------------------------------------
# pack_rows / unpack_scatter algebra
# ---------------------------------------------------------------------------

def test_pack_rows_matches_dense_layout():
    dom, pos = _blob()
    m_c = suggest_m_c(dom, pos)
    bins = bin_particles(dom, pos, m_c=m_c)
    rc = suggest_row_cap(dom, pos)
    pk = pack_rows(dom, bins, rc)
    assert not bool(pk.overflowed)

    # row counts match the occupied dense slots per padded row
    occ = np.asarray(bins.slot_id) >= 0
    np.testing.assert_array_equal(np.asarray(pk.row_counts),
                                  occ.sum(axis=-1))
    # packed order is dense order minus the sentinels, per row
    for (z, y) in [(1, 1), (3, 3), (0, 0)]:
        dense_row = np.asarray(bins.planes["x"][z, y])
        dense_ids = np.asarray(bins.slot_id[z, y])
        packed_row = np.asarray(pk.planes["x"][z, y])
        n_row = int(pk.row_counts[z, y])
        np.testing.assert_array_equal(packed_row[:n_row],
                                      dense_row[dense_ids >= 0])
        assert (packed_row[n_row:] > 1e7).all()        # sentinel padding
    # per-cell offsets are the prefix sum of per-cell occupancy
    cellocc = occ.reshape(*occ.shape[:2], dom.nx + 2, bins.m_c).sum(-1)
    np.testing.assert_array_equal(
        np.asarray(pk.cell_offsets)[..., :-1],
        np.concatenate([np.zeros_like(cellocc[..., :1]),
                        np.cumsum(cellocc, axis=-1)[..., :-1]], axis=-1))


def test_unpack_scatter_roundtrip():
    dom, pos = _blob()
    bins = bin_particles(dom, pos, m_c=suggest_m_c(dom, pos))
    pk = pack_rows(dom, bins, suggest_row_cap(dom, pos))
    interior = pk.planes["y"][1:dom.nz + 1, 1:dom.ny + 1, :]
    back = unpack_scatter(dom, pk, interior)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(pos[:, 1]))


def test_empty_rows_pack_to_zero_counts():
    """Empty pencil rows (the issue's first edge case): everything lands in
    one pencil, every other row packs to count 0 and sentinel slots."""
    dom = Domain.cubic(4, cutoff=1.0)
    pos = jnp.stack([jnp.linspace(0.1, 3.9, 7),
                     jnp.full((7,), 0.5), jnp.full((7,), 0.5)], axis=-1)
    bins = bin_particles(dom, pos, m_c=8)
    pk = pack_rows(dom, bins, row_cap=8)
    counts = np.asarray(pk.row_counts)
    assert counts[1, 1] == 7                     # the one occupied pencil
    mask = np.ones_like(counts, bool)
    mask[1, 1] = False
    assert (counts[mask] == 0).all()
    assert (np.asarray(pk.slot_id[2, 2]) == -1).all()
    # and the packed schedule still matches dense on this scene
    state = ParticleState(pos)
    f_d, _ = plan(dom, KERN, m_c=8, strategy="xpencil").execute(state)
    f_p, _ = plan(dom, KERN, m_c=8, strategy="xpencil", layout="packed",
                  row_cap=8).execute(state)
    np.testing.assert_array_equal(np.asarray(f_p), np.asarray(f_d))


def test_row_cap_hit_exactly_no_overflow():
    """A grid where one row holds exactly ``row_cap`` particles: full, not
    overflowed, still bit-identical (the fencepost the drop-scatter must
    not eat)."""
    dom, pos = _blob()
    exact = int(jnp.max(padded_row_counts(dom, cell_counts(dom, pos))))
    bins = bin_particles(dom, pos, m_c=suggest_m_c(dom, pos))
    pk = pack_rows(dom, bins, row_cap=exact)
    assert int(jnp.max(pk.row_counts)) == exact
    assert not bool(pk.overflowed)
    state = ParticleState(pos)
    p = plan(dom, KERN, positions=pos, strategy="xpencil", layout="packed",
             row_cap=exact)
    assert not p.check_overflow(state)
    f_d, _ = plan(dom, KERN, positions=pos, strategy="xpencil").execute(
        state)
    f_p, _ = p.execute(state)
    np.testing.assert_array_equal(np.asarray(f_p), np.asarray(f_d))


# ---------------------------------------------------------------------------
# layout-specific edge geometry (generic packed-vs-dense parity across
# scenes/backends/compaction lives in test_layout_matrix.py)
# ---------------------------------------------------------------------------

def test_packed_periodic_thin_axes_bit_parity():
    """Periodic 1-cell-thick axes (the issue's hardest ghost case): the
    single cell's particles appear three times per row as ghost copies,
    and the packed row must reproduce the dense window exactly."""
    dom = Domain(box=(1.0, 5.0, 5.0), ncells=(1, 5, 5), cutoff=1.0,
                 periodic=(True, True, False))
    pos = dom.sample_uniform(jax.random.PRNGKey(7), 120)
    state = ParticleState(pos)
    f_d, q_d = plan(dom, KERN, positions=pos, strategy="xpencil").execute(
        state)
    f_p, q_p = plan(dom, KERN, positions=pos, strategy="xpencil",
                    layout="packed").execute(state)
    np.testing.assert_array_equal(np.asarray(f_p), np.asarray(f_d))
    np.testing.assert_array_equal(np.asarray(q_p), np.asarray(q_d))

    dom2 = Domain(box=(5.0, 1.0, 1.0), ncells=(5, 1, 1), cutoff=1.0,
                  periodic=True)
    pos2 = dom2.sample_uniform(jax.random.PRNGKey(9), 80)
    state2 = ParticleState(pos2)
    f_d2, _ = plan(dom2, KERN, positions=pos2, strategy="xpencil").execute(
        state2)
    f_p2, _ = plan(dom2, KERN, positions=pos2, strategy="xpencil",
                   layout="packed").execute(state2)
    np.testing.assert_array_equal(np.asarray(f_p2), np.asarray(f_d2))


def test_packed_with_fields_binned():
    """Extra per-particle fields ride through the packed planes."""
    dom, pos = _blob()
    mass = jnp.arange(pos.shape[0], dtype=jnp.float32)
    state = ParticleState(pos, {"mass": mass})
    bins = bin_particles(dom, pos, {"mass": mass},
                         m_c=suggest_m_c(dom, pos))
    pk = pack_rows(dom, bins, suggest_row_cap(dom, pos))
    back = unpack_scatter(dom, pk,
                          pk.planes["mass"][1:dom.nz + 1, 1:dom.ny + 1, :])
    np.testing.assert_array_equal(np.asarray(back), np.asarray(mass))
    f_d, _ = plan(dom, KERN, positions=pos, strategy="xpencil").execute(
        state)
    f_p, _ = plan(dom, KERN, positions=pos, strategy="xpencil",
                  layout="packed").execute(state)
    np.testing.assert_array_equal(np.asarray(f_p), np.asarray(f_d))


# ---------------------------------------------------------------------------
# the row_cap replan contract
# ---------------------------------------------------------------------------

def test_row_cap_overflow_detected_and_replanned():
    dom, pos = _blob()
    state = ParticleState(pos)
    f_d, _ = plan(dom, KERN, positions=pos, strategy="xpencil").execute(
        state)

    p0 = plan(dom, KERN, positions=pos, strategy="xpencil",
              layout="packed", row_cap=8)
    assert p0.check_overflow(state)
    (f1, _), p1 = p0.execute_or_replan(state)
    assert p1.row_cap > p0.row_cap
    assert p1.m_c == p0.m_c                       # only row_cap grew
    assert p1.max_active == p0.max_active
    assert not p1.check_overflow(state)
    np.testing.assert_array_equal(np.asarray(f1), np.asarray(f_d))

    # an overflowed bound really does drop particles (the thing replan
    # protects against): forces under the tiny bound are wrong
    f_bad, _ = p0.execute(state)
    assert not np.array_equal(np.asarray(f_bad), np.asarray(f_d))


def test_suggest_row_cap_covers_periodic_x_ghosts():
    dom, pos = _blob()
    counts = cell_counts(dom, pos)
    mx = int(jnp.max(padded_row_counts(dom, counts)))
    assert suggest_row_cap(dom, pos) >= mx
    assert suggest_row_cap(dom, pos) % 8 == 0     # sublane aligned

    # a 1-cell-thick periodic X axis counts its single cell three times
    dom1 = Domain(box=(1.0, 3.0, 3.0), ncells=(1, 3, 3), cutoff=1.0,
                  periodic=(True, False, False))
    pos1 = jnp.full((5, 3), 0.5)
    rows = padded_row_counts(dom1, cell_counts(dom1, pos1))
    assert int(jnp.max(rows)) == 15


def test_packed_plan_validation():
    dom, pos = _blob()
    with pytest.raises(ValueError, match="packed"):
        plan(dom, KERN, positions=pos, strategy="par_part",
             layout="packed")
    with pytest.raises(ValueError, match="row_cap|positions"):
        plan(dom, KERN, m_c=16, strategy="xpencil", layout="packed")
    with pytest.raises(ValueError, match="layout"):
        plan(dom, KERN, positions=pos, strategy="xpencil", layout="csr")
    assert supports_layout("reference", "xpencil", "packed")
    assert supports_layout("pallas", "xpencil", "packed")
    assert not supports_layout("reference", "cell_dense", "packed")
    assert not supports_layout("pallas", "allin", "packed")
    assert supports_compact("reference", "xpencil", "packed")
    assert supports_compact("pallas", "xpencil", "packed")


def test_packed_plans_hash_and_trace_separately():
    dom, pos = _blob()
    pd = plan(dom, KERN, positions=pos, strategy="xpencil")
    pp = plan(dom, KERN, positions=pos, strategy="xpencil",
              layout="packed")
    assert pd != pp and hash(pd) != hash(pp)
    pp2 = plan(dom, KERN, positions=pos, strategy="xpencil",
               layout="packed")
    assert pp == pp2                              # same measured bound


def test_full_pencil_occupancy_identity():
    dom = Domain.cubic(3, cutoff=1.0)
    occ = full_pencil_occupancy(dom)
    np.testing.assert_array_equal(np.asarray(occ.active), np.arange(9))
    assert int(occ.n_active) == 9 and occ.max_active == 9
    idx = np.asarray(occ.scatter_indices())
    np.testing.assert_array_equal(idx, np.arange(9))   # no padding to drop


# ---------------------------------------------------------------------------
# traffic model + autotuner layout axis
# ---------------------------------------------------------------------------

def test_traffic_packed_cost_scales_with_ppc():
    dom = Domain.cubic(8, cutoff=1.0)
    dense = traffic.candidate_cost(dom, 16, 2.0, "xpencil")
    packed = traffic.candidate_cost(dom, 16, 2.0, "xpencil",
                                    layout="packed")
    assert packed < dense                         # ppc 2 vs m_c 16 slots
    # full cells: the byte factor clips at 1 — packing never *costs* bytes
    dense_full = traffic.candidate_cost(dom, 16, 16.0, "xpencil")
    packed_full = traffic.candidate_cost(dom, 16, 16.0, "xpencil",
                                         layout="packed")
    np.testing.assert_allclose(packed_full, dense_full, rtol=1e-6)
    # the layout and compact axes compose multiplicatively
    both = traffic.candidate_cost(dom, 16, 2.0, "xpencil", compact=True,
                                  fill=0.5, layout="packed")
    np.testing.assert_allclose(both, packed * 0.5, rtol=1e-6)


def test_autotune_packed_twins_and_safety():
    from repro.core import autotune as at
    dom, pos = _blob()
    cands = at.enumerate_candidates(dom, [suggest_m_c(dom, pos)],
                                    backends=("reference",),
                                    batch_sizes=(32,),
                                    strategies=("xpencil", "par_part"))
    cands = list(cands) + at.compact_twins(dom, pos, cands)
    twins = at.packed_twins(dom, pos, cands)
    # one packed twin per (dense, compact) xpencil candidate; none for
    # par_part (no packed path)
    assert {("xpencil", False), ("xpencil", True)} == {
        (c.strategy, c.compact) for c in twins}
    assert all(c.layout == "packed" and c.row_cap
               and c.row_cap % 8 == 0 for c in twins)
    # candidate json roundtrip keeps the layout axis
    c = twins[0]
    assert at.Candidate.from_json(c.to_json()) == c
    # a too-small cached row_cap must be re-measured, not trusted
    res = at.tune(dom, KERN, pos, strategies=("xpencil",), top_k=4,
                  reps=2, budget_s=0.01, batch_sizes=(32,),
                  candidates=[dataclasses.replace(c, row_cap=8),
                              dataclasses.replace(c, layout="dense",
                                                  row_cap=None)])
    assert res.candidate.layout == "dense"        # the unsafe twin filtered


def test_autotune_packed_candidate_requires_row_cap():
    from repro.core import autotune as at
    dom, pos = _blob()
    bad = at.Candidate("xpencil", "reference", 32,
                       suggest_m_c(dom, pos), layout="packed")
    with pytest.raises(ValueError, match="row_cap"):
        at.tune(dom, KERN, pos, candidates=[bad], use_cache=False)


# ---------------------------------------------------------------------------
# committed benchmark acceptance
# ---------------------------------------------------------------------------

def test_committed_bench_packed_meets_acceptance():
    """The committed BENCH_packed.json must contain a ppc <= 2 gaussian-blob
    case with >= 1.5x measured packed-over-compacted speedup (ISSUE 5)."""
    import json
    import pathlib
    path = pathlib.Path(__file__).parent.parent / "benchmarks" / \
        "BENCH_packed.json"
    records = json.loads(path.read_text())
    wins = [r for r in records
            if r["strategy"] == "xpencil_packed"
            and r.get("ppc", 99) <= 2
            and r.get("speedup_vs_compact", 0.0) >= 1.5]
    assert wins, ("no committed ppc<=2 case with >=1.5x packed speedup "
                  f"in {path}")
    assert all(r.get("layout") == "packed" for r in records
               if r["strategy"] == "xpencil_packed")
