"""Paper §6 prefix sum: correctness, complexity claims, Pallas kernel."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import blelloch_counts, operation_counts, paper_prefix_sum
from repro.core.prefix import exclusive_prefix_sum, paper_height
from repro.kernels import prefix_sum as pallas_prefix_sum


@given(st.lists(st.integers(0, 1000), min_size=1, max_size=600))
@settings(max_examples=60, deadline=None)
def test_paper_scan_matches_cumsum(xs):
    x = jnp.asarray(np.asarray(xs, np.int64))
    np.testing.assert_array_equal(np.asarray(paper_prefix_sum(x)),
                                  np.cumsum(xs))


@given(st.lists(st.integers(0, 1000), min_size=1, max_size=300))
@settings(max_examples=30, deadline=None)
def test_exclusive_scan(xs):
    x = jnp.asarray(np.asarray(xs, np.int64))
    got = np.asarray(exclusive_prefix_sum(x))
    want = np.concatenate([[0], np.cumsum(xs)[:-1]])
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("n", [8, 16, 32, 64, 128, 256, 1024])
def test_paper_complexity_claims_at_powers_of_two(n):
    """Paper: N-1 upward updates, N-h downward, 2h-3 barriers (< Blelloch)."""
    up, down, barriers = operation_counts(n)
    h = paper_height(n)
    assert up == n - 1
    assert down == n - h
    assert barriers == 2 * h - 3
    _, _, blelloch_barriers = blelloch_counts(n)
    assert barriers < blelloch_barriers


@pytest.mark.parametrize("n", [3, 5, 13, 100, 255, 1000])
def test_general_lengths(n):
    x = jnp.asarray(np.random.randint(0, 50, n), jnp.int32)
    np.testing.assert_array_equal(np.asarray(paper_prefix_sum(x)),
                                  np.cumsum(np.asarray(x)))


@pytest.mark.parametrize("n", [1, 2, 4, 8, 37, 128, 255, 1024])
@pytest.mark.parametrize("dtype", [jnp.int32, jnp.float32])
def test_pallas_prefix_kernel(n, dtype):
    x = jnp.asarray(np.random.randint(0, 9, n)).astype(dtype)
    got = np.asarray(pallas_prefix_sum(x, interpret=True))
    want = np.cumsum(np.asarray(x)).astype(np.asarray(x).dtype)
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_batched_leading_dims():
    x = jnp.asarray(np.random.randint(0, 9, (4, 33)), jnp.int32)
    got = np.asarray(paper_prefix_sum(x))
    np.testing.assert_array_equal(got, np.cumsum(np.asarray(x), axis=-1))
