"""Cross-layout differential test harness (ISSUE 10).

Every registered ``(backend, strategy, layout)`` combination — dense and
occupancy-compacted, open and periodic — runs on the same clustered scene
and is held to two bars at once:

* **bit-identity within a strategy**: every backend / layout / compaction
  of a strategy must reproduce the strategy's reference dense runner
  bit-for-bit — a layout may move bytes, never change a value;
* **correctness across strategies**: everything must match the naive
  O(n^2) oracle to float tolerance.

The combination list is enumerated from the live backend registry, so a
newly registered layout or backend is covered by adding nothing here.
This file replaces the per-layout parity tests that used to live in
test_packed.py / test_sparse.py (compact parity, packed parity, naive
oracle cross-checks) with one shared fixture.
"""

import jax
import numpy as np
import pytest

from repro.core import (Domain, ParticleState, make_lennard_jones, plan,
                        scenarios, supports_compact)
from repro.core.api import _BACKENDS
import repro.kernels  # noqa: F401  (register the pallas backends)

KERN = make_lennard_jones()
N = 280
DIVISION = 6

# the full matrix: every registered triple, with a compacted twin whenever
# the triple implements the compacted path
COMBOS = [
    (backend, strategy, layout, compact)
    for (backend, strategy, layout) in sorted(_BACKENDS)
    for compact in ((False, True)
                    if supports_compact(backend, strategy, layout)
                    else (False,))
]

_ids = [f"{b}-{s}-{lay}{'-compact' if c else ''}"
        for (b, s, lay, c) in COMBOS]

# per-session caches: the baselines are shared by every matrix entry
_scenes = {}
_baselines = {}
_oracles = {}


def _scene(periodic):
    if periodic not in _scenes:
        dom = Domain.cubic(DIVISION, cutoff=1.0, periodic=periodic)
        pos = scenarios.sample_gaussian_blob(
            dom, jax.random.PRNGKey(3), N, sigma_frac=0.08)
        _scenes[periodic] = (dom, pos)
    return _scenes[periodic]


def _baseline(strategy, periodic):
    """The strategy's reference dense result — the bit-identity anchor."""
    if (strategy, periodic) not in _baselines:
        dom, pos = _scene(periodic)
        f, q = plan(dom, KERN, positions=pos, strategy=strategy,
                    backend="reference").execute(ParticleState(pos))
        _baselines[(strategy, periodic)] = (np.asarray(f), np.asarray(q))
    return _baselines[(strategy, periodic)]


def _oracle(periodic):
    """The naive O(n^2) all-pairs result — the correctness anchor."""
    if periodic not in _oracles:
        dom, pos = _scene(periodic)
        f, q = plan(dom, KERN, positions=pos,
                    strategy="naive_n2").execute(ParticleState(pos))
        _oracles[periodic] = (np.asarray(f), np.asarray(q))
    return _oracles[periodic]


@pytest.mark.parametrize("periodic", [False, True],
                         ids=["open", "periodic"])
@pytest.mark.parametrize("backend,strategy,layout,compact", COMBOS,
                         ids=_ids)
def test_layout_matrix(backend, strategy, layout, compact, periodic):
    dom, pos = _scene(periodic)
    p = plan(dom, KERN, positions=pos, strategy=strategy, backend=backend,
             layout=layout, compact=compact, interpret=True)
    f, q = p.execute(ParticleState(pos))
    f, q = np.asarray(f), np.asarray(q)

    f_ref, q_ref = _baseline(strategy, periodic)
    np.testing.assert_array_equal(f, f_ref)
    np.testing.assert_array_equal(q, q_ref)

    f_o, q_o = _oracle(periodic)
    np.testing.assert_allclose(f, f_o, rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(q, q_o, rtol=3e-4, atol=3e-5)


def test_matrix_covers_every_registered_layout():
    """The harness only proves what it enumerates: the registry must
    contain the dense, packed, and sfc layouts on both backends."""
    triples = set(_BACKENDS)
    assert ("reference", "xpencil", "packed") in triples
    assert ("pallas", "xpencil", "packed") in triples
    assert ("reference", "cell_dense", "sfc") in triples
    assert ("pallas", "cell_dense", "sfc") in triples
    layouts = {lay for (_, _, lay) in triples}
    assert layouts == {"dense", "packed", "sfc"}


def test_sfc_layouts_agree_across_backends():
    """Reference sfc and pallas sfc are bit-identical to each other (both
    anchor to the dense cell_dense sweep, so transitivity already implies
    it — asserted directly so a failure names the sfc pair, not an
    anchor)."""
    for periodic in (False, True):
        dom, pos = _scene(periodic)
        state = ParticleState(pos)
        f_r, q_r = plan(dom, KERN, positions=pos, strategy="cell_dense",
                        layout="sfc", backend="reference",
                        interpret=True).execute(state)
        f_p, q_p = plan(dom, KERN, positions=pos, strategy="cell_dense",
                        layout="sfc", backend="pallas",
                        interpret=True).execute(state)
        np.testing.assert_array_equal(np.asarray(f_r), np.asarray(f_p))
        np.testing.assert_array_equal(np.asarray(q_r), np.asarray(q_p))
