"""Lock the assigned architecture configs to their exact assignment values."""

import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config

# (arch, layers, d_model, heads, kv, d_ff, vocab) straight from the
# assignment block — a failing row means someone edited a config.
ASSIGNED = {
    "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
    "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
    "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
    "mamba2-130m": (24, 768, None, None, 0, 50280),
    "codeqwen1.5-7b": (32, 4096, 32, 32, 13440, 92416),
    "starcoder2-3b": (30, 3072, 24, 2, 12288, 49152),
    "qwen1.5-0.5b": (24, 1024, 16, 16, 2816, 151936),
    "gemma2-2b": (26, 2304, 8, 4, 9216, 256000),
    "phi-3-vision-4.2b": (32, 3072, 32, 32, 8192, 32064),
    "whisper-base": (6, 512, 8, 8, 2048, 51865),
}

EXTRAS = {
    "grok-1-314b": dict(n_experts=8, top_k=2),
    "arctic-480b": dict(n_experts=128, top_k=2, moe_dense_residual=True),
    "zamba2-1.2b": dict(ssm_state=64, family="hybrid"),
    "mamba2-130m": dict(ssm_state=128, family="ssm"),
    "qwen1.5-0.5b": dict(qkv_bias=True),
    "codeqwen1.5-7b": dict(qkv_bias=True),
    "gemma2-2b": dict(local_global=True, logit_softcap=30.0),
    "whisper-base": dict(n_enc_layers=6),
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_assigned_values(arch):
    cfg = get_config(arch)
    l, d, h, kv, ff, v = ASSIGNED[arch]
    assert cfg.n_layers == l
    assert cfg.d_model == d
    if h is not None:
        assert cfg.n_heads == h and cfg.n_kv_heads == kv
    assert cfg.d_ff == ff and cfg.vocab_size == v
    for k, want in EXTRAS.get(arch, {}).items():
        assert getattr(cfg, k) == want, (arch, k)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_is_reduced_same_family(arch):
    full, smoke = get_config(arch), get_smoke_config(arch)
    assert smoke.family == full.family
    assert smoke.n_layers < full.n_layers
    assert smoke.d_model < full.d_model
    assert smoke.vocab_size < full.vocab_size
    if full.n_experts:
        assert 0 < smoke.n_experts < full.n_experts


def test_param_counts_are_assigned_scale():
    """Names carry the scale — check the configs actually hit it."""
    sizes = {"grok-1-314b": (280e9, 350e9), "arctic-480b": (420e9, 520e9),
             "zamba2-1.2b": (0.9e9, 1.6e9), "mamba2-130m": (0.1e9, 0.17e9),
             # the assigned d_ff=13440 (vs the checkpoint's 11008) puts
             # codeqwen above its nameplate — assignment values win
             "codeqwen1.5-7b": (6e9, 8.5e9), "starcoder2-3b": (2.5e9, 3.6e9),
             "qwen1.5-0.5b": (0.4e9, 0.7e9), "gemma2-2b": (2e9, 3.3e9),
             "phi-3-vision-4.2b": (3.3e9, 4.6e9),
             "whisper-base": (0.05e9, 0.12e9)}
    for arch, (lo, hi) in sizes.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, (arch, f"{n / 1e9:.2f}B not in "
                               f"[{lo / 1e9}B, {hi / 1e9}B]")


def test_long500k_eligibility():
    from repro.configs import cell_is_runnable, shape_by_name
    long = shape_by_name("long_500k")
    eligible = {a for a in ARCH_IDS
                if cell_is_runnable(get_config(a), long)[0]}
    assert eligible == {"mamba2-130m", "zamba2-1.2b"}
