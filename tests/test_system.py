"""End-to-end behaviour tests for the paper's system."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CellListEngine, Domain, bin_particles,
                        make_lennard_jones, suggest_m_c)
from repro.kernels import xpencil_interactions
from repro.physics import init_state, run


def test_full_pipeline_paper_configuration():
    """The paper's benchmark scene end to end: bin -> schedule -> forces ->
    integrate, with the Pallas kernel cross-checked in the loop."""
    domain = Domain.cubic(4, cutoff=1.0)
    key = jax.random.PRNGKey(0)
    positions = domain.sample_uniform(key, 640)          # ppc = 10
    kernel = make_lennard_jones(sigma=0.25, softening=1e-4)
    m_c = suggest_m_c(domain, positions)

    eng = CellListEngine(domain, kernel, m_c=m_c, strategy="xpencil")
    f_jnp, pot_jnp = eng.compute(positions)

    bins = bin_particles(domain, positions, m_c=m_c)
    f_pl, pot_pl = xpencil_interactions(domain, bins, kernel, interpret=True)
    np.testing.assert_allclose(np.asarray(f_pl), np.asarray(f_jnp),
                               rtol=3e-4, atol=3e-4)

    state = init_state(eng, positions,
                       0.02 * jax.random.normal(jax.random.PRNGKey(1),
                                                positions.shape))
    final, traces = run(eng, state, n_steps=50, dt=1e-4)
    e = np.asarray(traces["total"])
    assert np.isfinite(e).all()
    assert abs(e[-1] - e[0]) / (abs(e[0]) + 1e-9) < 0.05


def test_lm_end_to_end_train_then_serve():
    """Tiny LM: train until loss drops, then greedy-decode consistently."""
    from repro.configs import get_smoke_config
    from repro.models import model as M
    from repro.optim import AdamConfig, init_opt_state
    from repro.train import make_train_step
    from repro.train.serve import generate

    cfg = get_smoke_config("starcoder2-3b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt_cfg = AdamConfig(lr=2e-3, total_steps=40, warmup_steps=2)
    opt = init_opt_state(params, opt_cfg)
    step = jax.jit(make_train_step(cfg, opt_cfg))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                                cfg.vocab_size, jnp.int32)
    batch = {"tokens": tokens, "labels": tokens}
    first = None
    for _ in range(25):
        m, params, opt = step(params, opt, batch)
        if first is None:
            first = float(m["loss"])
    assert float(m["loss"]) < first

    out, _ = generate(cfg, params, tokens[:, :8], n_tokens=4)
    assert out.shape == (4, 4)
    assert np.isfinite(np.asarray(out)).all()


def test_traffic_model_encodes_paper_claims():
    import pathlib
    import sys
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
    from benchmarks.traffic_model import run as traffic_run
    rows = traffic_run(csv=False)
    assert len(rows) > 0
    xp = [r for r in rows if r.strategy == "xpencil"]
    ai = [r for r in rows if r.strategy == "allin"]
    pp = [r for r in rows if r.strategy == "par_part"]
    # the paper's qualitative claims as model relations:
    for a, b in zip(xp, ai):   # X-pencil stages less per step than All-in-SM
        assert a.staged_bytes_per_step <= b.staged_bytes_per_step
    for a, b in zip(xp, pp):   # Par-Part moves the most HBM bytes (no reuse)
        assert a.hbm_bytes_per_interaction <= b.hbm_bytes_per_interaction


def test_examples_importable():
    import importlib.util
    import pathlib
    root = pathlib.Path(__file__).resolve().parents[1] / "examples"
    for name in ("quickstart", "md_lennard_jones", "sph_demo", "lm_serve",
                 "lm_train"):
        spec = importlib.util.spec_from_file_location(
            name, root / f"{name}.py")
        assert spec is not None
