"""Fault-tolerant fused trajectory engine (``repro.traj``).

The acceptance contracts of the trajectory tentpole:

* **parity** — with ``skin=0`` the fused scan is bit-identical to a
  per-step ``plan.execute`` loop (the fig_traj pre-timing gate);
* **skin reuse** — with a positive skin, rebins are rare (``<< n_steps``)
  and the physics stays within float tolerance of the baseline;
* **resume** — an interrupted checkpointed run, resumed, lands on a final
  state bit-identical to the uninterrupted run (dense AND packed);
* **resilience** — injected NaN rolls back to the last checkpointed
  anchor and recovers finite; transient errors retry; stragglers finish;
  a crashed checkpoint write never corrupts the directory.
"""

import dataclasses
import os
import pathlib
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import api, recompile_count, reset_health
from repro.core.api import ParticleState
from repro.core.domain import Domain, effective_skin, skin_domain
from repro.core.interactions import make_lennard_jones
from repro.ckpt import checkpoint as ckpt
from repro.physics.integrators import MDState, init_state, run as integ_run
from repro.serve import TrajectoryRequest, TrajectoryService
from repro.testing import chaos
from repro.traj import (classify_breach, init_monitors, reference_step,
                        run_trajectory, trajectory_plan)
from repro.traj import monitors as M

DT = 1e-3


@pytest.fixture(autouse=True)
def _fresh_health():
    reset_health()
    yield
    reset_health()


@pytest.fixture(scope="module")
def setup():
    dom = Domain.cubic(6, cutoff=1.0, periodic=True)
    pos = dom.sample_uniform(jax.random.PRNGKey(0), 200)
    vel = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (200, 3),
                                  jnp.float32)
    kern = make_lennard_jones(sigma=0.3, eps=1e-4)
    p = api.plan(dom, kern, positions=pos)
    return dom, pos, vel, kern, p


def _baseline(p, md0, n_steps, integrator="velocity_verlet"):
    step = jax.jit(reference_step(p, integrator=integrator))
    md = md0
    for _ in range(n_steps):
        md = step(md, DT)
    return md


def _bitwise(a: MDState, b: MDState):
    for f in ("positions", "velocities", "forces", "potential"):
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)), err_msg=f)


# ---------------------------------------------------------------------------
# parity + skin contract
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("integrator", ["velocity_verlet", "leapfrog"])
def test_skin0_bitwise_parity(setup, integrator):
    """skin=0 forces a rebin every step; the fused scan must then match
    the eager per-step plan.execute loop bit for bit."""
    dom, pos, vel, kern, p = setup
    md0 = init_state(p, pos, vel)
    res = run_trajectory(p, md0, 24, DT, integrator=integrator, skin=0.0,
                         segment_len=8)
    assert res.status == "ok"
    assert res.rebins == 24            # every step re-binned
    assert res.steps == 24
    _bitwise(res.state, _baseline(p, md0, 24, integrator))


def test_skin_reuse_few_rebins(setup):
    dom, pos, vel, kern, p = setup
    md0 = init_state(p, pos, vel)
    res = run_trajectory(p, md0, 200, DT, skin=0.25, segment_len=16)
    assert res.status == "ok"
    assert res.rebins < 200 // 10      # rebins << n_steps
    assert res.eff_skin > 0
    md = _baseline(p, md0, 200)
    np.testing.assert_allclose(res.state.positions, md.positions,
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(res.state.velocities, md.velocities,
                               atol=1e-4, rtol=1e-4)
    assert len(res.traces["total"]) == 200


def test_trajectory_plan_coarsens(setup):
    dom, pos, vel, kern, p = setup
    tp = trajectory_plan(p, 0.25, pos)
    assert all(a <= b for a, b in zip(tp.domain.ncells, dom.ncells))
    assert tp.domain.cutoff == dom.cutoff
    assert effective_skin(tp.domain) >= 0.25 - 1e-6
    assert tp.m_c >= p.m_c             # coarser cells hold more particles
    assert skin_domain(dom, 0.0) is dom


def test_langevin_gamma0_matches_verlet(setup):
    dom, pos, vel, kern, p = setup
    md0 = init_state(p, pos, vel)
    ra = run_trajectory(p, md0, 20, DT, integrator="langevin", gamma=0.0,
                        skin=0.0, segment_len=8)
    rb = run_trajectory(p, md0, 20, DT, skin=0.0, segment_len=8)
    np.testing.assert_allclose(ra.state.positions, rb.state.positions,
                               atol=1e-5)


# ---------------------------------------------------------------------------
# checkpoint / resume
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("plan_kw", [
    {},                                               # dense allin/auto
    {"strategy": "xpencil", "layout": "packed"},      # packed CSR rows
], ids=["dense", "packed"])
def test_resume_bit_identical(setup, tmp_path, plan_kw):
    """Interrupt at step 32 of 64, resume from the checkpoint: the final
    state must be bit-identical to the uninterrupted run."""
    dom, pos, vel, kern, _ = setup
    p = api.plan(dom, kern, positions=pos, **plan_kw)
    md0 = init_state(p, pos, vel)
    kw = dict(skin=0.25, segment_len=8, checkpoint_every=16, seed=7)

    full = run_trajectory(p, md0, 64, DT, **kw)       # uninterrupted
    assert full.status == "ok"

    d = tmp_path / "ck"
    part = run_trajectory(p, md0, 32, DT, checkpoint_dir=d, **kw)
    assert part.status == "ok" and part.checkpoints >= 1
    assert ckpt.latest_step(d) == 32

    res = run_trajectory(p, md0, 64, DT, checkpoint_dir=d, resume=True,
                         **kw)
    assert res.resumed_from == 32
    assert res.steps == 64
    _bitwise(res.state, full.state)
    # resumed traces cover only the replayed half
    assert len(res.traces["total"]) == 32


def test_resume_refuses_mismatched_config(setup, tmp_path):
    dom, pos, vel, kern, p = setup
    md0 = init_state(p, pos, vel)
    d = tmp_path / "ck"
    run_trajectory(p, md0, 16, DT, skin=0.25, segment_len=8,
                   checkpoint_dir=d, checkpoint_every=8)
    with pytest.raises(ValueError, match="refusing to resume"):
        run_trajectory(p, md0, 32, DT, skin=0.25, segment_len=8,
                       checkpoint_dir=d, integrator="leapfrog")


# ---------------------------------------------------------------------------
# fault injection: rollback, retry, straggler, checkpoint crash
# ---------------------------------------------------------------------------

def test_injected_nan_rolls_back_and_recovers(setup):
    dom, pos, vel, kern, p = setup
    md0 = init_state(p, pos, vel)
    clean = run_trajectory(p, md0, 32, DT, skin=0.25, segment_len=8)
    with chaos.inject(chaos.FaultSpec("traj.step", "nonfinite", p=1.0,
                                      after=1, max_fires=1), seed=3):
        res = run_trajectory(p, md0, 32, DT, skin=0.25, segment_len=8)
    assert res.status == "ok"
    assert res.rollbacks >= 1
    assert res.forced_rebins >= 1      # recovery rebins counted apart
    assert any(f.startswith("breach:nonfinite") for f in res.faults)
    assert res.steps == 32
    assert bool(jnp.all(jnp.isfinite(res.state.positions)))
    assert bool(jnp.all(jnp.isfinite(res.state.velocities)))
    np.testing.assert_allclose(res.state.positions, clean.state.positions,
                               atol=1e-5, rtol=1e-5)


def test_transient_error_retries_bitwise(setup):
    dom, pos, vel, kern, p = setup
    md0 = init_state(p, pos, vel)
    clean = run_trajectory(p, md0, 24, DT, skin=0.25, segment_len=8)
    with chaos.inject(chaos.FaultSpec("traj.step", "error", p=1.0,
                                      after=1, max_fires=2), seed=5):
        res = run_trajectory(p, md0, 24, DT, skin=0.25, segment_len=8)
    assert res.status in ("ok", "degraded")
    assert res.retries == 2
    assert res.steps == 24
    # a retried segment replays identical arithmetic
    _bitwise(res.state, clean.state)


def test_straggler_delay_completes(setup):
    dom, pos, vel, kern, p = setup
    md0 = init_state(p, pos, vel)
    naps = []
    with chaos.inject(chaos.FaultSpec("traj.step", "delay", p=1.0,
                                      max_fires=2, param=0.5), seed=1):
        res = run_trajectory(p, md0, 16, DT, skin=0.25, segment_len=8,
                             sleep=naps.append)
    assert res.status == "ok" and res.steps == 16
    assert naps == [0.5, 0.5]          # delays observed, run unharmed


def test_checkpoint_crash_never_kills_run(setup, tmp_path):
    dom, pos, vel, kern, p = setup
    md0 = init_state(p, pos, vel)
    d = tmp_path / "ck"
    with chaos.inject(chaos.FaultSpec("traj.checkpoint", "error", p=1.0,
                                      max_fires=1), seed=2):
        res = run_trajectory(p, md0, 32, DT, skin=0.25, segment_len=8,
                             checkpoint_dir=d, checkpoint_every=8)
    assert res.status == "ok" and res.steps == 32
    assert any(f.startswith("checkpoint:") for f in res.faults)
    # later checkpoints still landed
    assert res.checkpoints >= 1
    assert ckpt.latest_step(d) == 32


def test_forced_overflow_recorded(setup):
    dom, pos, vel, kern, p = setup
    md0 = init_state(p, pos, vel)
    with chaos.inject(chaos.FaultSpec("traj.rebin", "overflow", p=1.0,
                                      max_fires=1), seed=4):
        res = run_trajectory(p, md0, 16, DT, skin=0.25, segment_len=8)
    assert res.status == "ok" and res.steps == 16
    assert "overflow:injected" in res.faults


def test_initial_overflow_replans(setup):
    """A skin plan measured on sparse positions must grow its bounds when
    handed a clustered initial state (the grow-only replan contract)."""
    from repro.core.interactions import make_low_flop
    dom, pos, vel, kern, p = setup
    # bounded kernel: overlapping blob particles must not blow up the
    # dynamics (this test is about bounds, not LJ stiffness)
    base = api.plan(dom, make_low_flop(), positions=pos)
    sparse = trajectory_plan(base, 0.25, pos)
    # center the blob mid-cell of the coarsened grid so one cell takes
    # the bulk of it (a boundary-centered blob splits eight ways)
    blob = (0.45 * jax.random.normal(jax.random.PRNGKey(2), (200, 3),
                                     jnp.float32) + 2.25) % 6.0
    assert sparse.check_overflow(ParticleState(blob))   # premise
    res = run_trajectory(base, blob, 8, 1e-6, segment_len=8, skin=0.25,
                         traj_plan=sparse)
    assert res.status == "ok"
    assert res.replans >= 1
    assert res.plan.m_c > sparse.m_c


def test_energy_budget_breach_fails_to_anchor(setup):
    dom, pos, vel, kern, p = setup
    md0 = init_state(p, pos, vel)
    res = run_trajectory(p, md0, 16, DT, skin=0.25, segment_len=8,
                         energy_budget=0.0, max_rollbacks=1)
    assert res.status == "failed"
    assert res.steps < 16
    assert any(f.startswith("breach:energy") for f in res.faults)
    # the reported state is the last committed healthy anchor
    assert bool(jnp.all(jnp.isfinite(res.state.positions)))


# ---------------------------------------------------------------------------
# monitors
# ---------------------------------------------------------------------------

def test_monitor_energy_convention_matches_e0():
    """The drift monitor must use the same halved-PE (pair-counted-twice)
    convention as the ``e0`` seed: identical state in, zero drift out.
    Regression: update() once re-summed the raw per-particle potential
    un-halved, so any nonzero-PE run breached a finite energy budget."""
    pot = jnp.array([2.0, 4.0], jnp.float32)           # pair-counted twice
    vel = jnp.ones((2, 3), jnp.float32)
    ke = 0.5 * jnp.sum(vel ** 2)
    pe = 0.5 * jnp.sum(pot)
    assert float(pe) != 0.0                             # premise
    mon = init_monitors(ke + pe)
    mon2 = M.update(mon, positions=jnp.zeros((2, 3)), velocities=vel,
                    forces=jnp.zeros((2, 3)), potential=pot, valid=None,
                    kinetic=ke, potential_energy=pe,
                    step_disp=jnp.float32(0.0), eff_skin=0.5,
                    cell_max=jnp.int32(1), row_max=jnp.int32(0),
                    units=jnp.int32(0))
    assert float(mon2.max_drift) == 0.0


def test_energy_budget_healthy_run_not_breached(setup):
    """A healthy run with nonzero PE and a generous finite budget must
    complete without spurious energy breaches or rollbacks."""
    dom, pos, vel, kern, p = setup
    md0 = init_state(p, pos, vel)
    assert float(jnp.sum(md0.potential)) != 0.0         # premise
    res = run_trajectory(p, md0, 32, DT, skin=0.25, segment_len=8,
                         energy_budget=1e-2)
    assert res.status == "ok"
    assert res.rollbacks == 0
    assert not any(f.startswith("breach:energy") for f in res.faults)


def test_classify_breach_ordering():
    prev = jax.device_get(init_monitors(jnp.float32(1.0)))
    cur = dataclasses.replace(prev, nonfinite_steps=np.int32(1),
                              skin_steps=np.int32(1),
                              max_drift=np.float32(9.0))
    assert classify_breach(prev, cur, energy_budget=0.1) == "nonfinite"
    cur2 = dataclasses.replace(cur, nonfinite_steps=np.int32(0))
    assert classify_breach(prev, cur2, energy_budget=0.1) == "skin"
    cur3 = dataclasses.replace(cur2, skin_steps=np.int32(0))
    assert classify_breach(prev, cur3, energy_budget=0.1) == "energy"
    assert classify_breach(prev, cur3, energy_budget=None) is None
    assert classify_breach(prev, prev, energy_budget=0.1) is None


# ---------------------------------------------------------------------------
# ckpt.save atomicity audit
# ---------------------------------------------------------------------------

def test_ckpt_crash_before_commit_preserves_old(tmp_path):
    """A crash inside save (before the atomic rename) must leave the
    previous checkpoint of the same step intact and restorable."""
    d = tmp_path / "ck"
    tree = {"x": jnp.arange(8.0)}
    ckpt.save(d, 5, tree, extra={"gen": 1})
    with chaos.inject(chaos.FaultSpec("ckpt.save", "error", p=1.0),
                      seed=0):
        with pytest.raises(chaos.TransientBackendError):
            ckpt.save(d, 5, {"x": jnp.arange(8.0) * 2}, extra={"gen": 2})
    assert ckpt.latest_step(d) == 5
    restored, extra = ckpt.restore(d, tree)
    np.testing.assert_array_equal(restored["x"], np.arange(8.0))
    assert extra == {"gen": 1}
    # no temp litter survives the failed save's cleanup
    assert not [f for f in os.listdir(d) if f.startswith(".tmp_")]


def test_ckpt_sweep_repairs_dead_writers(tmp_path):
    """Hard-kill debris: a dead writer's .tmp dir is deleted and its
    .old_<pid>_<step> move-aside is renamed back when the new step never
    committed."""
    d = tmp_path / "ck"
    ckpt.save(d, 3, {"x": jnp.zeros(4)})
    dead = 2 ** 22 + 12345             # no such pid
    # emulate a kill after the move-aside, before the commit rename
    os.replace(d / "step_00000003", d / f".old_{dead}_00000003")
    (d / f".tmp_{dead}_junk").mkdir()
    assert ckpt.latest_step(d) is None
    handled = ckpt.sweep_stale(d)
    assert handled == 2
    assert ckpt.latest_step(d) == 3    # old checkpoint restored
    assert not (d / f".tmp_{dead}_junk").exists()
    # live writers' temp dirs are left alone
    mine = d / f".tmp_{os.getpid()}_busy"
    mine.mkdir()
    assert ckpt.sweep_stale(d) == 0
    assert mine.exists()


def test_ckpt_resave_over_stale_old_dir(tmp_path):
    """A leftover .old_<pid>_<step> dir (partial cleanup / pid reuse) must
    not make a later save of the same step fail with ENOTEMPTY on the
    move-aside rename."""
    d = tmp_path / "ck"
    ckpt.save(d, 7, {"x": jnp.zeros(4)})
    stale = d / f".old_{os.getpid()}_00000007"   # own pid: sweep skips it
    stale.mkdir()
    (stale / "junk.npy").write_bytes(b"x")
    ckpt.save(d, 7, {"x": jnp.ones(4)})
    restored, _ = ckpt.restore(d, {"x": jnp.zeros(4)})
    np.testing.assert_array_equal(restored["x"], np.ones(4))
    assert not stale.exists()


def test_pid_alive_eperm_means_alive(monkeypatch):
    """EPERM from kill(pid, 0) means the process exists (another user's):
    sweep_stale must not treat a live foreign writer as dead."""
    from repro.ckpt.checkpoint import _pid_alive

    def eperm(pid, sig):
        raise PermissionError

    def esrch(pid, sig):
        raise ProcessLookupError

    monkeypatch.setattr(os, "kill", eperm)
    assert _pid_alive(12345) is True
    monkeypatch.setattr(os, "kill", esrch)
    assert _pid_alive(12345) is False


def test_ckpt_kill_mid_save_subprocess(tmp_path):
    """Actual SIGKILL mid-save: whatever instant the writer dies at,
    latest_step/restore only ever see intact checkpoints."""
    import subprocess
    import sys
    d = tmp_path / "ck"
    code = (
        "import sys, numpy as np, jax.numpy as jnp, os\n"
        "sys.path.insert(0, %r)\n"
        "from repro.ckpt import checkpoint as ckpt\n"
        "tree = {'x': jnp.arange(200000.0)}\n"
        "ckpt.save(%r, 1, tree)\n"
        "print('committed', flush=True)\n"
        "for i in range(2, 50):\n"
        "    ckpt.save(%r, i, tree)\n"
    ) % (str(pathlib.Path("src").resolve()), str(d), str(d))
    proc = subprocess.Popen([sys.executable, "-c", code],
                            stdout=subprocess.PIPE)
    proc.stdout.readline()             # first checkpoint committed
    proc.kill()
    proc.wait()
    last = ckpt.latest_step(d)
    assert last is not None and last >= 1
    restored, _ = ckpt.restore(d, {"x": jnp.arange(200000.0)})
    assert restored["x"].shape == (200000,)
    ckpt.sweep_stale(d)                # and the debris sweeps clean
    assert not [f for f in os.listdir(d) if f.startswith(".tmp_")]


# ---------------------------------------------------------------------------
# integrators.run port
# ---------------------------------------------------------------------------

def test_integrators_run_routes_through_trajectory(setup):
    dom, pos, vel, kern, p = setup
    md0 = init_state(p, pos, vel)
    state, traces = integ_run(p, md0, 24, DT, skin=0.0, segment_len=8)
    assert traces["total"].shape == (24,)
    _bitwise(state, _baseline(p, md0, 24))


def test_integrators_run_legacy_rejects_traj_opts(setup):
    from repro.core.engine import CellListEngine
    dom, pos, vel, kern, p = setup
    eng = CellListEngine(dom, kern, m_c=8)
    md0 = init_state(eng, pos, vel)
    with pytest.raises(ValueError, match="legacy per-step scan"):
        integ_run(eng, md0, 4, DT, skin=0.25)
    # integrators the legacy scan does not implement must raise, not
    # silently fall back to leapfrog
    with pytest.raises(ValueError, match="legacy per-step scan"):
        integ_run(eng, md0, 4, DT, integrator="langevin")
    with pytest.raises(ValueError, match="legacy per-step scan"):
        integ_run(eng, md0, 4, DT, integrator="nope")
    state, traces = integ_run(eng, md0, 4, DT)   # legacy path still runs
    assert traces["total"].shape == (4,)


# ---------------------------------------------------------------------------
# serving front door
# ---------------------------------------------------------------------------

def test_trajectory_service_warm_class_and_padding(setup):
    dom, pos, vel, kern, p = setup
    svc = TrajectoryService(skin=0.25)
    req = TrajectoryRequest("job-a", dom, kern,
                            ParticleState(pos[:150]), 16, DT,
                            velocities=vel[:150],
                            opts={"segment_len": 8})
    ra = svc.submit(req)
    assert ra.status == "ok" and ra.n == 150
    assert ra.state.positions.shape == (150, 3)

    # same shape class (150 and 180 both pad to 256): zero recompiles
    before = recompile_count()
    rb = svc.submit(TrajectoryRequest(
        "job-b", dom, kern, ParticleState(pos[:180]), 16, DT,
        velocities=vel[:180], opts={"segment_len": 8}))
    assert rb.status == "ok"
    assert recompile_count() == before
    assert svc.jobs_served == 2

    # padded execution matches the unpadded engine (masked pad rows bin
    # to nothing; real rows see identical pair sets)
    base150 = api.plan(dom, kern, positions=pos[:150])
    direct = run_trajectory(base150, ParticleState(pos[:150]), 16, DT,
                            velocities=vel[:150], skin=0.25,
                            segment_len=8)
    np.testing.assert_allclose(ra.state.positions, direct.state.positions,
                               atol=1e-6, rtol=1e-6)


def test_trajectory_service_resume(setup, tmp_path):
    dom, pos, vel, kern, p = setup
    svc = TrajectoryService(skin=0.25, checkpoint_root=tmp_path / "jobs")
    req = TrajectoryRequest("job-r", dom, kern, ParticleState(pos), 32,
                            DT, velocities=vel,
                            opts={"segment_len": 8,
                                  "checkpoint_every": 16})
    first = svc.submit(req)
    assert first.status == "ok" and first.result.checkpoints >= 1
    again = svc.submit(req)            # resubmission resumes, no rerun
    assert again.result.resumed_from == 32
    assert again.result.steps == 32
    _bitwise(again.state, first.state)
