"""Scenario registry (``repro.core.scenarios``): lookup errors,
determinism under a fixed seed, and domain-box containment."""

import jax
import numpy as np
import pytest

from repro.core import Domain, scenarios

DOM = Domain.cubic(12, cutoff=1.0)
NAMES = sorted(scenarios.SCENARIOS)


def test_registry_lists_expected_family():
    assert {"uniform", "gaussian_blob", "two_phase",
            "power_law_cluster"} <= set(NAMES)


def test_unknown_name_raises_with_inventory():
    with pytest.raises(ValueError, match="unknown scenario"):
        scenarios.sample("no_such_scene", DOM, jax.random.PRNGKey(0), 10)
    with pytest.raises(ValueError, match="gaussian_blob"):
        # the error names the available scenarios
        scenarios.sample("no_such_scene", DOM, jax.random.PRNGKey(0), 10)


@pytest.mark.parametrize("name", NAMES)
def test_samplers_deterministic_under_fixed_seed(name):
    a = scenarios.sample(name, DOM, jax.random.PRNGKey(7), 300)
    b = scenarios.sample(name, DOM, jax.random.PRNGKey(7), 300)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    c = scenarios.sample(name, DOM, jax.random.PRNGKey(8), 300)
    assert not np.array_equal(np.asarray(a), np.asarray(c)), \
        "different seeds must produce different scenes"


@pytest.mark.parametrize("name", NAMES)
def test_samples_respect_domain_box(name):
    pos = np.asarray(scenarios.sample(name, DOM, jax.random.PRNGKey(3),
                                      1000))
    assert pos.shape == (1000, 3)
    box = np.asarray(DOM.box)
    assert (pos > 0.0).all() and (pos < box).all(), \
        f"{name} produced positions outside the open box"
    assert np.isfinite(pos).all()


def test_samplers_respect_anisotropic_box():
    dom = Domain(box=(4.0, 8.0, 16.0), ncells=(4, 8, 16), cutoff=1.0)
    for name in NAMES:
        pos = np.asarray(scenarios.sample(name, dom,
                                          jax.random.PRNGKey(1), 400))
        assert (pos > 0.0).all()
        assert (pos < np.asarray(dom.box)).all(), name


def test_sampler_knobs_change_the_scene():
    tight = scenarios.sample("gaussian_blob", DOM, jax.random.PRNGKey(0),
                             500, sigma_frac=0.03)
    wide = scenarios.sample("gaussian_blob", DOM, jax.random.PRNGKey(0),
                            500, sigma_frac=0.2)
    assert float(np.std(np.asarray(tight))) < float(
        np.std(np.asarray(wide)))
