"""MoE dispatch: routing correctness, capacity semantics, ssm parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.moe import init_moe, moe_capacity, moe_mlp
from repro.models.ssm import ssd_chunked


def _dense_moe_ref(x, p, top_k, act="silu"):
    """Per-token explicit expert evaluation (no capacity limit)."""
    b, s, d = x.shape
    e = p["router"].shape[-1]
    xt = np.asarray(x.reshape(-1, d), np.float32)
    probs = np.asarray(jax.nn.softmax(xt @ np.asarray(p["router"]), -1))
    order = np.argsort(-probs, axis=-1)[:, :top_k]
    out = np.zeros_like(xt)
    act_fn = lambda z: z / (1.0 + np.exp(-z))
    wg = np.asarray(p["w_gate"], np.float32)
    wu = np.asarray(p["w_up"], np.float32)
    wd = np.asarray(p["w_down"], np.float32)
    for t in range(xt.shape[0]):
        ws = probs[t, order[t]]
        ws = ws / ws.sum()
        for j, ex in enumerate(order[t]):
            h = act_fn(xt[t] @ wg[ex]) * (xt[t] @ wu[ex])
            out[t] += ws[j] * (h @ wd[ex])
    return out.reshape(b, s, d)


@pytest.mark.parametrize("e,top_k", [(4, 1), (4, 2), (8, 2)])
def test_moe_matches_dense_reference(e, top_k):
    key = jax.random.PRNGKey(0)
    d, f = 16, 32
    p = init_moe(key, d, f, e, jnp.float32)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (2, 8, d), jnp.float32)
    out, aux = moe_mlp(x, p, top_k=top_k, capacity_factor=8.0)  # ample cap
    ref = _dense_moe_ref(x, p, top_k)
    np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-3, atol=2e-3)
    assert float(aux) >= 1.0 - 1e-3   # >= 1 by Cauchy-Schwarz, = 1 balanced


def test_capacity_drops_overflow():
    """All tokens route to one expert; tiny capacity drops the excess."""
    key = jax.random.PRNGKey(0)
    d, f, e = 8, 16, 4
    p = init_moe(key, d, f, e, jnp.float32)
    # bias router so expert 0 always wins (positive inputs + positive column)
    p["router"] = p["router"].at[:, 0].add(100.0)
    x = 0.1 + jnp.abs(jax.random.normal(jax.random.PRNGKey(1), (1, 64, d),
                                        jnp.float32))
    out_full, _ = moe_mlp(x, p, top_k=1, capacity_factor=8.0)
    out_tiny, _ = moe_mlp(x, p, top_k=1, capacity_factor=0.1)
    # overflowed tokens produce zero expert output
    zeros = np.isclose(np.asarray(out_tiny), 0.0).all(-1).sum()
    cap = moe_capacity(64, e, 1, 0.1)
    assert zeros == 64 - cap
    assert not np.allclose(np.asarray(out_full), 0.0)


def test_moe_grad_flows_to_router_and_experts():
    key = jax.random.PRNGKey(0)
    p = init_moe(key, 8, 16, 4, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 8), jnp.float32)

    def loss(p):
        out, aux = moe_mlp(x, p, top_k=2, capacity_factor=2.0)
        return (out ** 2).sum() + 0.01 * aux

    g = jax.grad(loss)(p)
    for name in ("router", "w_gate", "w_up", "w_down"):
        assert float(jnp.abs(g[name]).sum()) > 0, name


def test_ssd_equals_naive_recurrence():
    key = jax.random.PRNGKey(0)
    B, S, H, P, N = 1, 32, 2, 4, 8
    x = jax.random.normal(key, (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1), (B, S, H)))
    a = -jnp.exp(0.3 * jax.random.normal(jax.random.PRNGKey(2), (H,)))
    bm = jax.random.normal(jax.random.PRNGKey(3), (B, S, N))
    cm = jax.random.normal(jax.random.PRNGKey(4), (B, S, N))
    h = np.zeros((B, H, P, N))
    ys = []
    for t in range(S):
        decay = np.exp(np.asarray(dt[:, t]) * np.asarray(a))
        dx = np.asarray(x[:, t]) * np.asarray(dt[:, t])[..., None]
        h = h * decay[..., None, None] + np.einsum(
            "bhp,bn->bhpn", dx, np.asarray(bm[:, t]))
        ys.append(np.einsum("bhpn,bn->bhp", h, np.asarray(cm[:, t])))
    ref = np.stack(ys, 1)
    for chunk in (4, 8, 32):
        got = np.asarray(ssd_chunked(x, dt, a, bm, cm, chunk))
        np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4)
