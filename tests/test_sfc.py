"""SFC cluster layout: curve properties, pair-list algebra, replan, tuning.

The correctness bar (ISSUE 10): the sfc schedules must be *bit-parity*
with their dense ``cell_dense`` oracle — the compressed pair list may only
change which cluster tiles run, never a computed value. (Generic
sfc-vs-dense parity across scenes/backends lives in test_layout_matrix.py;
this file holds the curve/codec properties and the ``pair_cap``
fenceposts named by the issue: exact-cap, cap-overflow growing only that
bound, empty clusters, and periodic 1-cell-thick axes.)

Property tests use hypothesis when available and the deterministic
conftest stand-in otherwise.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (Domain, ParticleState, bin_particles,
                        build_sfc_clusters, decode_pair_codes,
                        encode_pair_masks, hilbert_decode, hilbert_encode,
                        make_lennard_jones, morton_decode, morton_encode,
                        plan, scenarios, sfc_cluster_tables, sfc_pair_count,
                        suggest_m_c, suggest_pair_cap, supports_compact,
                        supports_layout)
from repro.core import traffic
from repro.core.binning import cell_counts, sfc_n_clusters

KERN = make_lennard_jones()


def _blob(division=6, n=300, seed=0, sigma_frac=0.08, periodic=False):
    dom = Domain.cubic(division, cutoff=1.0, periodic=periodic)
    pos = scenarios.sample_gaussian_blob(
        dom, jax.random.PRNGKey(seed), n, sigma_frac=sigma_frac)
    return dom, pos


# ---------------------------------------------------------------------------
# curve properties (satellite: encode <-> decode round-trip + locality)
# ---------------------------------------------------------------------------

@settings(max_examples=25)
@given(bits=st.integers(1, 6), seed=st.integers(0, 1 << 20))
def test_curve_roundtrip(bits, seed):
    """decode(encode(p)) == p for random coordinates, both curves."""
    rng = np.random.RandomState(seed)
    side = 1 << bits
    ix, iy, iz = rng.randint(0, side, size=(3, 32))
    for enc, dec in ((morton_encode, morton_decode),
                     (hilbert_encode, hilbert_decode)):
        jx, jy, jz = dec(enc(ix, iy, iz, bits), bits)
        np.testing.assert_array_equal(jx, ix)
        np.testing.assert_array_equal(jy, iy)
        np.testing.assert_array_equal(jz, iz)


@pytest.mark.parametrize("bits", [1, 2, 3])
@pytest.mark.parametrize("enc", [morton_encode, hilbert_encode],
                         ids=["morton", "hilbert"])
def test_curve_is_a_bijection_on_the_cube(bits, enc):
    side = 1 << bits
    g = np.arange(side)
    ix, iy, iz = np.meshgrid(g, g, g, indexing="ij")
    codes = enc(ix.ravel(), iy.ravel(), iz.ravel(), bits)
    np.testing.assert_array_equal(np.sort(codes), np.arange(side ** 3))


@pytest.mark.parametrize("bits", [1, 2, 3])
def test_hilbert_locality_beats_morton(bits):
    """Consecutive Hilbert codes are face-adjacent cells (Manhattan step
    exactly 1); Morton jumps farther on average — the locality ordering
    the layout relies on is a measured fact, not folklore."""
    side = 1 << bits
    codes = np.arange(side ** 3)
    hx, hy, hz = hilbert_decode(codes, bits)
    h_step = (np.abs(np.diff(hx)) + np.abs(np.diff(hy))
              + np.abs(np.diff(hz)))
    np.testing.assert_array_equal(h_step, np.ones(side ** 3 - 1))
    mx, my, mz = morton_decode(codes, bits)
    m_step = (np.abs(np.diff(mx)) + np.abs(np.diff(my))
              + np.abs(np.diff(mz)))
    if bits > 1:
        assert m_step.mean() > 1.0                 # morton is not gapless
    assert h_step.mean() <= m_step.mean()


def test_morton_clusters_are_compact_blocks():
    """On a power-of-two grid, csize=4 Morton clusters are 2x2x1 blocks —
    the geometric compactness the cluster tile banks on."""
    dom = Domain.cubic(4, cutoff=1.0)
    t = sfc_cluster_tables(dom, 4, "morton")
    nx, ny = dom.nx, dom.ny
    for cells in t.cluster_cells:
        ix, iy, iz = cells % nx, (cells // nx) % ny, cells // (nx * ny)
        assert ix.max() - ix.min() <= 1
        assert iy.max() - iy.min() <= 1
        assert iz.max() == iz.min()


# ---------------------------------------------------------------------------
# pair-list codec properties (satellite: encode <-> decode inverse)
# ---------------------------------------------------------------------------

@settings(max_examples=25)
@given(n_clusters=st.integers(1, 8), seed=st.integers(0, 1 << 20),
       slack=st.integers(0, 16))
def test_pair_codec_roundtrip(n_clusters, seed, slack):
    """decode(encode(masks)) == masks whenever pair_cap holds every kept
    pair, regardless of padding slack."""
    rng = np.random.RandomState(seed)
    masks = rng.rand(n_clusters, 27) < 0.3
    cap = int(masks.sum()) + slack
    codes = encode_pair_masks(masks, max(cap, 1))
    back = decode_pair_codes(codes, n_clusters)
    np.testing.assert_array_equal(back, masks)
    # padding is the sentinel, and codes are sorted ascending
    assert (np.diff(codes) >= 0).all()
    assert (codes[int(masks.sum()):] == n_clusters * 32).all()


@settings(max_examples=15)
@given(seed=st.integers(0, 1 << 20))
def test_pair_codec_truncation_keeps_a_sorted_prefix(seed):
    """Overflow truncates: the decoded mask is a subset of the input with
    exactly pair_cap survivors — the lowest codes, never garbage."""
    rng = np.random.RandomState(seed)
    masks = rng.rand(6, 27) < 0.5
    total = int(masks.sum())
    if total < 2:
        return
    cap = total // 2
    back = decode_pair_codes(encode_pair_masks(masks, cap), 6)
    assert back.sum() == cap
    assert not (back & ~masks).any()               # subset
    a, k = np.nonzero(masks)
    kept = np.sort(a * 32 + k)[:cap]
    ba, bk = np.nonzero(back)
    np.testing.assert_array_equal(np.sort(ba * 32 + bk), kept)


def test_build_sfc_clusters_matches_host_probe():
    """The traced pair list equals the host probe's count and decodes to
    the exact occupancy bitmask rule."""
    dom, pos = _blob()
    bins = bin_particles(dom, pos, m_c=suggest_m_c(dom, pos))
    n_pairs = sfc_pair_count(dom, pos)
    sfc = build_sfc_clusters(dom, bins, pair_cap=n_pairs + 8)
    assert int(sfc.n_pairs) == n_pairs
    assert not bool(sfc.overflowed)
    masks = decode_pair_codes(np.asarray(sfc.codes),
                              sfc_n_clusters(dom))
    assert int(masks.sum()) == n_pairs
    # every kept pair's target cluster holds at least one particle
    cc = np.asarray(sfc.cluster_counts)
    assert (cc[np.nonzero(masks)[0]] > 0).all()


# ---------------------------------------------------------------------------
# the pair_cap replan contract (satellite: fenceposts)
# ---------------------------------------------------------------------------

def test_pair_cap_hit_exactly_no_overflow():
    """pair_cap == measured pair count: full, not overflowed, still
    bit-identical (the fencepost the truncation must not eat)."""
    dom, pos = _blob()
    exact = sfc_pair_count(dom, pos)
    state = ParticleState(pos)
    p = plan(dom, KERN, positions=pos, strategy="cell_dense",
             layout="sfc", pair_cap=exact)
    assert not p.check_overflow(state)
    f_d, q_d = plan(dom, KERN, positions=pos,
                    strategy="cell_dense").execute(state)
    f_s, q_s = p.execute(state)
    np.testing.assert_array_equal(np.asarray(f_s), np.asarray(f_d))
    np.testing.assert_array_equal(np.asarray(q_s), np.asarray(q_d))


def test_pair_cap_overflow_detected_and_replanned():
    """pair_cap one short of the measured count: overflow detected, replan
    grows *only* pair_cap, and the replanned result is bit-identical."""
    dom, pos = _blob()
    exact = sfc_pair_count(dom, pos)
    state = ParticleState(pos)
    f_d, _ = plan(dom, KERN, positions=pos,
                  strategy="cell_dense").execute(state)

    p0 = plan(dom, KERN, positions=pos, strategy="cell_dense",
              layout="sfc", pair_cap=exact - 1)
    assert p0.check_overflow(state)
    (f1, _), p1 = p0.execute_or_replan(state)
    assert p1.pair_cap > p0.pair_cap
    assert p1.pair_cap >= exact
    assert p1.m_c == p0.m_c                       # only pair_cap grew
    assert p1.max_active == p0.max_active
    assert p1.row_cap == p0.row_cap
    assert not p1.check_overflow(state)
    np.testing.assert_array_equal(np.asarray(f1), np.asarray(f_d))

    # an overflowed bound really does drop cluster pairs (the thing replan
    # protects against): forces under the short list are wrong
    f_bad, _ = p0.execute(state)
    assert not np.array_equal(np.asarray(f_bad), np.asarray(f_d))


def test_empty_clusters_cost_no_pairs():
    """Everything in one cell: exactly one cluster holds particles, the
    pair list stays tiny, and the schedule is still bit-identical."""
    dom = Domain.cubic(4, cutoff=1.0)
    pos = jnp.full((7, 3), 0.5)
    bins = bin_particles(dom, pos, m_c=8)
    sfc = build_sfc_clusters(dom, bins, pair_cap=32)
    cc = np.asarray(sfc.cluster_counts)
    assert (cc > 0).sum() == 1
    assert int(sfc.n_pairs) <= 27
    state = ParticleState(pos)
    f_d, _ = plan(dom, KERN, m_c=8, strategy="cell_dense").execute(state)
    f_s, _ = plan(dom, KERN, m_c=8, strategy="cell_dense", layout="sfc",
                  pair_cap=32).execute(state)
    np.testing.assert_array_equal(np.asarray(f_s), np.asarray(f_d))


def test_sfc_periodic_thin_axes_bit_parity():
    """Periodic 1-cell-thick axes (the issue's hardest ghost case): the
    single cell's ghost copies drive the occupancy bitmask, and the sfc
    schedule must reproduce the dense sweep exactly."""
    dom = Domain(box=(1.0, 5.0, 5.0), ncells=(1, 5, 5), cutoff=1.0,
                 periodic=(True, True, False))
    pos = dom.sample_uniform(jax.random.PRNGKey(7), 120)
    state = ParticleState(pos)
    f_d, q_d = plan(dom, KERN, positions=pos,
                    strategy="cell_dense").execute(state)
    f_s, q_s = plan(dom, KERN, positions=pos, strategy="cell_dense",
                    layout="sfc").execute(state)
    np.testing.assert_array_equal(np.asarray(f_s), np.asarray(f_d))
    np.testing.assert_array_equal(np.asarray(q_s), np.asarray(q_d))

    dom2 = Domain(box=(5.0, 1.0, 1.0), ncells=(5, 1, 1), cutoff=1.0,
                  periodic=True)
    pos2 = dom2.sample_uniform(jax.random.PRNGKey(9), 80)
    state2 = ParticleState(pos2)
    f_d2, _ = plan(dom2, KERN, positions=pos2,
                   strategy="cell_dense").execute(state2)
    f_s2, _ = plan(dom2, KERN, positions=pos2, strategy="cell_dense",
                   layout="sfc").execute(state2)
    np.testing.assert_array_equal(np.asarray(f_s2), np.asarray(f_d2))


def test_suggest_pair_cap_bounds_and_clipping():
    dom, pos = _blob()
    exact = sfc_pair_count(dom, pos)
    cap = suggest_pair_cap(dom, pos)
    assert exact <= cap <= sfc_n_clusters(dom) * 27
    assert cap % 8 == 0                           # aligned
    # huge slack clips to the dense stencil total, never beyond
    assert suggest_pair_cap(dom, pos,
                            slack=1e6) == sfc_n_clusters(dom) * 27
    # counts shortcut agrees with the positions path
    assert suggest_pair_cap(dom, counts=cell_counts(dom, pos)) == cap


def test_sfc_plan_validation():
    dom, pos = _blob()
    with pytest.raises(ValueError, match="sfc"):
        plan(dom, KERN, positions=pos, strategy="xpencil", layout="sfc")
    with pytest.raises(ValueError, match="pair_cap|positions"):
        plan(dom, KERN, m_c=16, strategy="cell_dense", layout="sfc")
    assert supports_layout("reference", "cell_dense", "sfc")
    assert supports_layout("pallas", "cell_dense", "sfc")
    assert not supports_layout("reference", "xpencil", "sfc")
    assert not supports_layout("pallas", "allin", "sfc")
    assert supports_compact("reference", "cell_dense", "sfc")


def test_sfc_plans_hash_and_trace_separately():
    dom, pos = _blob()
    pd = plan(dom, KERN, positions=pos, strategy="cell_dense")
    ps = plan(dom, KERN, positions=pos, strategy="cell_dense",
              layout="sfc")
    assert pd != ps and hash(pd) != hash(ps)
    ps2 = plan(dom, KERN, positions=pos, strategy="cell_dense",
               layout="sfc")
    assert ps == ps2                              # same measured bound


# ---------------------------------------------------------------------------
# traffic model + autotuner layout axis
# ---------------------------------------------------------------------------

def test_traffic_sfc_cost_scales_with_fill():
    dom = Domain.cubic(8, cutoff=1.0)
    dense = traffic.candidate_cost(dom, 16, 2.0, "cell_dense")
    sparse = traffic.candidate_cost(dom, 16, 2.0, "cell_dense",
                                    layout="sfc", fill=0.1)
    full = traffic.candidate_cost(dom, 16, 2.0, "cell_dense",
                                  layout="sfc", fill=1.0)
    assert sparse < full                          # the pair list shrinks
    assert sparse < dense                         # and undercuts dense
    rep = traffic.sfc_report(dom, 16, 2.0, fill=0.25)
    assert rep.strategy == "cell_dense_sfc"
    assert rep.hbm_bytes_per_interaction > 0


def test_autotune_sfc_twins_and_safety():
    from repro.core import autotune as at
    dom, pos = _blob()
    cands = at.enumerate_candidates(dom, [suggest_m_c(dom, pos)],
                                    backends=("reference",),
                                    batch_sizes=(32,),
                                    strategies=("cell_dense", "par_part"))
    twins = at.sfc_twins(dom, pos, cands)
    # one sfc twin per dense cell_dense candidate; none for par_part (no
    # sfc path)
    assert {c.strategy for c in twins} == {"cell_dense"}
    assert all(c.layout == "sfc" and c.pair_cap
               and c.pair_cap % 8 == 0 for c in twins)
    # candidate json roundtrip keeps the pair_cap axis
    c = twins[0]
    assert at.Candidate.from_json(c.to_json()) == c
    # a too-small cached pair_cap must be re-measured, not trusted
    res = at.tune(dom, KERN, pos, strategies=("cell_dense",), top_k=4,
                  reps=2, budget_s=0.01, batch_sizes=(32,),
                  candidates=[dataclasses.replace(c, pair_cap=8),
                              dataclasses.replace(c, layout="dense",
                                                  pair_cap=None)])
    assert res.candidate.layout == "dense"        # the unsafe twin filtered


def test_autotune_sfc_candidate_requires_pair_cap():
    from repro.core import autotune as at
    dom, pos = _blob()
    bad = at.Candidate("cell_dense", "reference", 32,
                       suggest_m_c(dom, pos), layout="sfc")
    with pytest.raises(ValueError, match="pair_cap"):
        at.tune(dom, KERN, pos, candidates=[bad], use_cache=False)


# ---------------------------------------------------------------------------
# committed benchmark acceptance + perf-history rendering
# ---------------------------------------------------------------------------

def _bench_sfc_path():
    import pathlib
    return pathlib.Path(__file__).parent.parent / "benchmarks" / \
        "BENCH_sfc.json"


def test_committed_bench_sfc_meets_acceptance():
    """The committed BENCH_sfc.json must contain a clustered case where
    the sfc layout beats the packed layout (ISSUE 10 acceptance)."""
    import json
    records = json.loads(_bench_sfc_path().read_text())
    wins = [r for r in records
            if r["strategy"] == "cell_sfc"
            and r.get("speedup_vs_packed", 0.0) >= 1.0]
    assert wins, ("no committed case where sfc beats packed in "
                  f"{_bench_sfc_path()}")
    assert all(r.get("layout") == "sfc" and "drift" in r
               and r.get("pair_cap") for r in records
               if r["strategy"] == "cell_sfc")


def test_perf_history_renders_committed_sfc_records():
    """The real committed BENCH_sfc.json rendered through perf_history:
    sfc rows carry their layout tag verbatim plus the audit drift."""
    from benchmarks import perf_history
    snapshots = perf_history.collect(_bench_sfc_path().parent,
                                     pattern="BENCH_sfc.json")
    assert len(snapshots) == 1
    ss = perf_history.series(snapshots)
    sfc_keys = [k for k in ss if k[1] == "cell_sfc"]
    assert sfc_keys
    for k in sfc_keys:
        assert perf_history.layout_of(snapshots, k) == "sfc"
        assert perf_history.drift_of(snapshots, k) != "-"
    table = perf_history.format_table(snapshots, ss)
    lines = [ln for ln in table.splitlines() if ",cell_sfc," in ln]
    assert lines and all(ln.endswith(",sfc") for ln in lines)
