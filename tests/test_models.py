"""Per-architecture smoke tests (reduced configs) + model-level properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import model as M
from repro.models.attention import flash_attention, window_attention_blocked
from repro.optim import AdamConfig, init_opt_state
from repro.train import make_train_step


def _batch(cfg, b=2, s=16, key=jax.random.PRNGKey(0)):
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_size, jnp.int32)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.family == "vlm":
        batch["patch_embeds"] = 0.02 * jax.random.normal(
            key, (b, cfg.n_img_tokens, cfg.d_model), jnp.float32)
    if cfg.n_enc_layers:
        batch["frame_embeds"] = 0.02 * jax.random.normal(
            key, (b, cfg.enc_seq, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_train_step(arch):
    """Reduced config: one forward/train step, output shapes, no NaNs."""
    cfg = get_smoke_config(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    step = jax.jit(make_train_step(cfg, AdamConfig(total_steps=4)))
    opt = init_opt_state(params, AdamConfig())
    metrics, params2, opt2 = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    # a weight actually moved
    before = np.asarray(jax.tree.leaves(params)[0])
    after = np.asarray(jax.tree.leaves(params2)[0])
    assert not np.allclose(before, after)

    logits, aux = M.forward(cfg, params, batch["tokens"], remat=False,
                            **{k: v for k, v in batch.items()
                               if k not in ("tokens", "labels")})
    s_out = 16 + (cfg.n_img_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (2, s_out, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_instantiates(arch):
    """The assigned (full-size) config is structurally valid — eval_shape
    only (no allocation of 314B params on this box)."""
    cfg = get_config(arch)
    shapes = jax.eval_shape(lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
    n_params = sum(np.prod(s.shape) for s in jax.tree.leaves(shapes))
    assert n_params > 0
    # analytic count within 20% of the traced count (analytic feeds roofline)
    assert abs(n_params - cfg.param_count()) / cfg.param_count() < 0.2, \
        (arch, int(n_params), cfg.param_count())


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "gemma2-2b",
                                  "whisper-base", "grok-1-314b"])
def test_prefill_matches_forward(arch):
    cfg = get_smoke_config(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    extras = {k: v for k, v in batch.items() if k not in ("tokens", "labels")}
    logits, _ = M.forward(cfg, params, batch["tokens"], remat=False, **extras)
    lg, cache = M.prefill(cfg, params, batch["tokens"], max_len=24, **extras)
    np.testing.assert_allclose(np.asarray(lg, np.float32),
                               np.asarray(logits, np.float32),
                               rtol=2e-3, atol=2e-3)
    if cfg.family not in ("ssm", "hybrid"):
        assert cache["k"].shape[3] == 24


@pytest.mark.parametrize("arch", ["mamba2-130m", "zamba2-1.2b"])
def test_ssm_decode_matches_forward(arch):
    """Sequential decode replays to the same last-token logits as forward."""
    cfg = get_smoke_config(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                                cfg.vocab_size, jnp.int32)
    logits, _ = M.forward(cfg, params, tokens, remat=False)
    cache = M.init_cache(cfg, 2, 16)
    decode = jax.jit(lambda c, t, i: M.decode_step(cfg, params, c, t, i))
    for t in range(12):
        lg, cache = decode(cache, tokens[:, t:t + 1], jnp.int32(t))
    np.testing.assert_allclose(np.asarray(lg[:, 0], np.float32),
                               np.asarray(logits[:, -1], np.float32),
                               rtol=6e-3, atol=6e-3)


def test_attention_decode_matches_forward():
    """KV-cache decode continues a prefilled prompt consistently."""
    cfg = get_smoke_config("qwen1.5-0.5b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                                cfg.vocab_size, jnp.int32)
    full, _ = M.forward(cfg, params, tokens, remat=False)
    lg, cache = M.prefill(cfg, params, tokens[:, :8], max_len=16)
    out = None
    for t in range(8, 12):
        out, cache = M.decode_step(cfg, params, cache, tokens[:, t:t + 1],
                                   jnp.int32(t))
    np.testing.assert_allclose(np.asarray(out[:, 0], np.float32),
                               np.asarray(full[:, -1], np.float32),
                               rtol=6e-3, atol=6e-3)


def test_gemma_local_equals_global_when_window_covers():
    """window >= S: the pencil-window path must equal full causal attention."""
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (2, 4, 32, 8), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 2, 32, 8), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 2, 32, 8), jnp.float32)
    ow = window_attention_blocked(q, k, v, window=32)
    of = flash_attention(q, k, v, True, 0.0, 8, 8)
    np.testing.assert_allclose(np.asarray(ow), np.asarray(of),
                               rtol=2e-4, atol=2e-4)


def test_logit_softcap_bounds():
    cfg = get_smoke_config("gemma2-2b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                cfg.vocab_size, jnp.int32)
    logits, _ = M.forward(cfg, params, tokens, remat=False)
    assert float(jnp.max(jnp.abs(logits))) <= cfg.logit_softcap + 1e-3


def test_remat_does_not_change_values():
    cfg = get_smoke_config("starcoder2-3b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                cfg.vocab_size, jnp.int32)
    a, _ = M.forward(cfg, params, tokens, remat=False)
    b, _ = M.forward(cfg, params, tokens, remat=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)
