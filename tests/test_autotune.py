"""Measured autotuner: overflow-safe winners, disk cache, honest pruning."""

import json
import pathlib

import jax
import pytest

from repro.core import (Domain, ParticleState, make_lennard_jones, plan,
                        tune)
from repro.core import autotune as at
from repro.core.api import STRATEGY_NAMES, get_backend
from repro.core.engine import suggest_m_c

# keep tuner runs cheap: 2 reps, tiny budget — correctness, not precision
FAST = dict(reps=2, budget_s=0.01)


@pytest.fixture()
def cache_dir(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path))
    return tmp_path


def _case(division=4, n=300, seed=0):
    dom = Domain.cubic(division, cutoff=1.0)
    pos = dom.sample_uniform(jax.random.PRNGKey(seed), n)
    return dom, pos


# ---------------------------------------------------------------------------
# the winner is a real plan
# ---------------------------------------------------------------------------

def test_tune_returns_registered_overflow_safe_plan(cache_dir):
    dom, pos = _case()
    res = tune(dom, make_lennard_jones(), pos, top_k=4, **FAST)
    p = res.plan
    assert p.strategy in STRATEGY_NAMES
    get_backend(p.backend, p.strategy)          # registered, or raises
    assert not p.check_overflow(ParticleState(pos))
    # the winner really is the measured minimum among timed candidates
    assert res.timings[res.candidate] == min(res.timings.values())
    # and it executes
    forces, pot = p.execute(ParticleState(pos))
    assert forces.shape == (pos.shape[0], 3)


def test_tune_requires_positions():
    with pytest.raises(ValueError, match="positions"):
        tune(Domain.cubic(3))
    with pytest.raises(ValueError, match="autotune"):
        plan(Domain.cubic(3), m_c=8, strategy="autotune")


def test_pinned_m_c_below_occupancy_is_rejected(cache_dir):
    dom, pos = _case(3, 400)
    with pytest.raises(ValueError, match="overflow-safe"):
        tune(dom, make_lennard_jones(), pos, m_c=1, **FAST)


# ---------------------------------------------------------------------------
# disk cache
# ---------------------------------------------------------------------------

def test_cache_round_trips_through_disk(cache_dir, monkeypatch):
    dom, pos = _case()
    res1 = tune(dom, make_lennard_jones(), pos, top_k=4, **FAST)
    assert not res1.cache_hit and res1.timings

    cfile = pathlib.Path(res1.cache_file)
    assert cfile.exists() and cfile.parent == cache_dir
    data = json.loads(cfile.read_text())
    [entry] = data.values()
    assert entry["version"] == at.CACHE_VERSION
    assert entry["candidate"]["strategy"] == res1.candidate.strategy

    # second call: zero timing runs — a stopwatch call would blow up here
    def bomb(*a, **k):
        raise AssertionError("cache hit must not time anything")
    monkeypatch.setattr(at, "time_fn", bomb)
    res2 = tune(dom, make_lennard_jones(), pos, top_k=4, **FAST)
    assert res2.cache_hit and not res2.timings
    assert res2.plan == res1.plan


def test_plan_autotune_front_door_reuses_cache(cache_dir, monkeypatch):
    dom, pos = _case()
    p1 = plan(dom, make_lennard_jones(), positions=pos, strategy="autotune")

    def bomb(*a, **k):
        raise AssertionError("cached plan() must not time anything")
    monkeypatch.setattr(at, "time_fn", bomb)
    p2 = plan(dom, make_lennard_jones(), positions=pos, strategy="autotune")
    assert p2 == p1
    assert p1.strategy in STRATEGY_NAMES


def test_cache_hit_respects_restricted_candidate_space(cache_dir):
    """A cached winner from an unrestricted run must not answer a call
    that explicitly excludes it."""
    dom, pos = _case()
    res1 = tune(dom, make_lennard_jones(), pos, **FAST)
    other = [s for s in STRATEGY_NAMES if s != res1.candidate.strategy]
    res2 = tune(dom, make_lennard_jones(), pos, strategies=tuple(other),
                **FAST)
    assert not res2.cache_hit                  # space changed: re-measured
    assert res2.candidate.strategy != res1.candidate.strategy
    # the restricted run got its own entry: the unrestricted regime still
    # hits its original winner, unclobbered
    res3 = tune(dom, make_lennard_jones(), pos, **FAST)
    assert res3.cache_hit and res3.plan == res1.plan


def test_cache_entry_ignored_when_bound_overflows(cache_dir):
    """A bucket collision must never hand back an overflow-unsafe plan."""
    dom, pos = _case(3, 120)
    res1 = tune(dom, make_lennard_jones(), pos, top_k=2, **FAST)
    # forge the cached bound down below this scene's occupancy
    cfile = pathlib.Path(res1.cache_file)
    data = json.loads(cfile.read_text())
    [key] = data
    data[key]["candidate"]["m_c"] = 0
    cfile.write_text(json.dumps(data))
    res2 = tune(dom, make_lennard_jones(), pos, top_k=2, **FAST)
    assert not res2.cache_hit                   # re-measured, not trusted
    assert not res2.plan.check_overflow(ParticleState(pos))


def test_cache_key_separates_same_name_kernels(cache_dir):
    """Two kernels sharing a name but differing in params/FLOPs must not
    share a cached winner (PairKernel identity is value-based)."""
    from repro.core import make_high_flop
    dom = Domain.cubic(4)
    k_small = make_high_flop(extra_terms=5)
    k_big = make_high_flop(extra_terms=200)
    assert k_small.name == k_big.name and k_small != k_big
    key_small = at.cache_key("cpu", dom, 16, 1.0, k_small, ("reference",))
    key_big = at.cache_key("cpu", dom, 16, 1.0, k_big, ("reference",))
    assert key_small != key_big


def test_cache_key_separates_regimes():
    dom = Domain.cubic(4)
    kern = make_lennard_jones()
    k1 = at.cache_key("cpu", dom, 16, 1.0, kern, ("reference",))
    assert k1 != at.cache_key("tpu", dom, 16, 1.0, kern, ("reference",))
    assert k1 != at.cache_key("cpu", dom, 32, 1.0, kern, ("reference",))
    assert k1 != at.cache_key("cpu", dom, 16, 100.0, kern, ("reference",))
    assert k1 != at.cache_key("cpu", Domain.cubic(8), 16, 1.0, kern,
                              ("reference",))
    # nearby fill ratios share a bucket (and therefore a tuning decision)
    assert at.ppc_bucket(9.0) == at.ppc_bucket(10.0)
    assert at.ppc_bucket(1.0) != at.ppc_bucket(10.0)


# ---------------------------------------------------------------------------
# pruning
# ---------------------------------------------------------------------------

def test_pruning_never_drops_measured_winner_on_seeded_case(cache_dir):
    """Time the *whole* candidate space, then check the default model
    pruning would have kept the measured winner in the field."""
    dom, pos = _case(4, 300)
    m_c = suggest_m_c(dom, pos)
    cands = at.enumerate_candidates(dom, [m_c], backends=("reference",),
                                    batch_sizes=(64, 128))
    full = tune(dom, make_lennard_jones(), pos, candidates=cands,
                top_k=len(cands), use_cache=False, **FAST)
    assert len(full.timings) == len(cands) and not full.pruned
    kept, pruned = at.prune_candidates(
        dom, pos.shape[0] / dom.n_cells, cands, top_k=at.DEFAULT_TOP_K)
    assert full.candidate in kept
    assert set(kept) | set(pruned) == set(cands)


def test_prune_is_deterministic_and_ranked():
    dom, pos = _case(4, 300)
    m_c = suggest_m_c(dom, pos)
    cands = at.enumerate_candidates(dom, [m_c, 2 * m_c])
    ppc = pos.shape[0] / dom.n_cells
    kept1, _ = at.prune_candidates(dom, ppc, cands, top_k=5)
    kept2, _ = at.prune_candidates(dom, ppc, cands, top_k=5)
    assert kept1 == kept2 and len(kept1) == 5


def test_prune_cannot_eliminate_a_whole_strategy():
    """The model ranks, the stopwatch decides: with top_k >= #strategies,
    every strategy keeps at least one timed candidate — identical-cost
    batch-size duplicates of the model's favourite must not crowd the
    others out of the field."""
    dom, pos = _case(4, 300)
    m_c = suggest_m_c(dom, pos)
    cands = at.enumerate_candidates(dom, [m_c])
    ppc = pos.shape[0] / dom.n_cells
    kept, _ = at.prune_candidates(dom, ppc, cands, top_k=at.DEFAULT_TOP_K)
    assert {c.strategy for c in kept} == {c.strategy for c in cands}


def test_enumerate_naive_n2_when_requested(cache_dir):
    dom = Domain.cubic(3)
    cands = at.enumerate_candidates(dom, [8], strategies=("naive_n2",))
    assert cands and all(c.strategy == "naive_n2" for c in cands)
    # and it is timeable end-to-end
    pos = dom.sample_uniform(jax.random.PRNGKey(0), 50)
    res = tune(dom, make_lennard_jones(), pos, candidates=cands,
               use_cache=False, **FAST)
    assert res.candidate.strategy == "naive_n2"


def test_enumerate_only_registered_pairs():
    dom = Domain.cubic(4)
    cands = at.enumerate_candidates(dom, [16],
                                    backends=("reference", "pallas"))
    for c in cands:
        get_backend(c.backend, c.strategy)      # must not raise
    # pallas implements only the paper's two proposed schedules
    assert {c.strategy for c in cands if c.backend == "pallas"} == {
        "xpencil", "allin"}


# ---------------------------------------------------------------------------
# dense-vs-compact candidate axis
# ---------------------------------------------------------------------------

def _blob_case(division=5, n=200, seed=0, sigma_frac=0.08):
    from repro.core import scenarios
    dom = Domain.cubic(division, cutoff=1.0)
    pos = scenarios.sample_gaussian_blob(
        dom, jax.random.PRNGKey(seed), n, sigma_frac=sigma_frac)
    return dom, pos


def test_compact_twins_cover_compactable_strategies():
    dom, pos = _blob_case()
    cands = at.enumerate_candidates(dom, [16], backends=("reference",),
                                    batch_sizes=(64,))
    twins = at.compact_twins(dom, pos, cands)
    assert twins and all(c.compact and c.max_active for c in twins)
    assert {c.strategy for c in twins} == {"xpencil", "cell_dense", "allin"}
    # par_part has no empty work units to skip: no twin
    assert all(c.strategy != "par_part" for c in twins)
    # twins survive the JSON round trip (disk cache)
    for c in twins:
        assert at.Candidate.from_json(c.to_json()) == c


def test_tune_times_compact_candidates_and_winner_executes(cache_dir):
    from repro.core import ParticleState, plan as make_plan
    dom, pos = _blob_case()
    res = tune(dom, make_lennard_jones(), pos, **FAST)
    timed_compact = [c for c in res.timings if c.compact]
    # round-robin queues per (strategy, compact): the compact variants
    # cannot be crowded out of the timed field
    assert timed_compact
    f, _ = res.plan.execute(ParticleState(pos))
    f_ref, _ = make_plan(dom, make_lennard_jones(), positions=pos,
                         strategy="xpencil").execute(ParticleState(pos))
    import numpy as np
    np.testing.assert_allclose(np.asarray(f), np.asarray(f_ref),
                               rtol=3e-4, atol=3e-4)


def test_cache_key_includes_occupancy_bucket():
    dom = Domain.cubic(6)
    kern = make_lennard_jones()
    k_dense = at.cache_key("cpu", dom, 16, 1.0, kern, ("reference",),
                           pencil_fill=1.0)
    k_sparse = at.cache_key("cpu", dom, 16, 1.0, kern, ("reference",),
                            pencil_fill=0.05)
    assert k_dense != k_sparse                   # blob != gas, same ppc
    # nearby fills share a bucket (and therefore a tuning decision)
    assert at.occupancy_bucket(0.9) == at.occupancy_bucket(1.0)
    assert at.occupancy_bucket(0.05) != at.occupancy_bucket(1.0)


def test_cached_compact_winner_with_stale_bound_is_rejected(cache_dir):
    """A cached compacted winner whose max_active no longer covers the
    scene must be re-measured, never trusted (mirrors the m_c contract)."""
    dom, pos = _blob_case()
    res1 = tune(dom, make_lennard_jones(), pos, **FAST)
    cfile = pathlib.Path(res1.cache_file)
    data = json.loads(cfile.read_text())
    [key] = data
    # forge the entry into a compacted candidate with a 1-pencil bound
    data[key]["candidate"]["compact"] = True
    data[key]["candidate"]["max_active"] = 1
    data[key]["candidate"]["strategy"] = "xpencil"
    data[key]["candidate"]["backend"] = "reference"
    cfile.write_text(json.dumps(data))
    res2 = tune(dom, make_lennard_jones(), pos, **FAST)
    assert not res2.cache_hit                   # stale bound: re-measured
    if res2.candidate.compact:
        from repro.core import active_unit_count
        assert res2.candidate.max_active >= active_unit_count(
            dom, pos, res2.candidate.strategy, box=res2.candidate.box)
