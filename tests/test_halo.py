"""Distributed halo execution subsystem (``backend="halo"``).

Three tiers:
  * single-device tests — partition/scatter algebra, plan validation,
    the bit-identical single-shard fallback, bound probes, the autotuner's
    shard-count twin axis (pure enumeration, no devices needed);
  * in-process multi-device tests — run when the pytest process itself
    sees >= 2 devices (the CI halo job sets
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``), skipped in
    the single-device tier-1 run;
  * subprocess multi-device tests — spawn a fresh python with emulated
    devices so the tier-1 run exercises real shard_map/ppermute execution
    without contaminating this process's device count.
"""

import dataclasses
import os
import pathlib
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Domain, ParticleState, make_lennard_jones, plan
from repro.core.binning import shard_pencil_active, shard_slab_counts
from repro.core.domain import slab_domain
from repro.dist import halo as H

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")


def run_sub(body: str, n_dev: int = 4, timeout: int = 600) -> str:
    code = ("import os\n"
            f"os.environ['XLA_FLAGS'] = "
            f"'--xla_force_host_platform_device_count={n_dev}'\n"
            + textwrap.dedent(body))
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


# --------------------------------------------------------------------------
# single-device: geometry, partition, plan contract
# --------------------------------------------------------------------------

def test_slab_domain_geometry():
    dom = Domain.cubic(8, cutoff=1.0, periodic=True)
    loc = slab_domain(dom, 4)
    assert loc.ncells == (8, 8, 2)
    assert loc.box == (8.0, 8.0, 2.0)
    assert loc.periodic_axes == (True, True, False)   # Z ghosts come from
    with pytest.raises(ValueError):                   # the exchange
        slab_domain(dom, 3)


def test_partition_scatter_roundtrip():
    dom = Domain.cubic(8, cutoff=1.0)
    pos = dom.sample_uniform(jax.random.PRNGKey(0), 500)
    cap = int(H.suggest_shard_cap(dom, pos, 2))
    gidx, pos_part, _ = H.partition_by_shard(dom, pos, {}, 2, cap)
    assert pos_part.shape == (2 * cap, 3)
    # every real row belongs to its shard's slab; pads are sentinels
    valid = np.asarray(pos_part[:, 0] < H.VALID_MAX)
    zs = np.asarray(pos_part[:, 2])
    assert valid[:cap].sum() + valid[cap:].sum() == 500
    assert (zs[:cap][valid[:cap]] < 4.0).all()
    assert (zs[cap:][valid[cap:]] >= 4.0).all()
    # scatter-back restores particle order
    back = H.scatter_from_shards(gidx, 500, pos_part)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(pos))


def test_partition_drops_overflow_rows():
    dom = Domain.cubic(4, cutoff=1.0)
    pos = dom.sample_uniform(jax.random.PRNGKey(1), 200)
    gidx, pos_part, _ = H.partition_by_shard(dom, pos, {}, 2, cap=10)
    valid = np.asarray(pos_part[:, 0] < H.VALID_MAX)
    assert valid.sum() <= 20          # truncated, never out of bounds
    # and the plan layer detects exactly this situation
    p = plan(dom, make_lennard_jones(), positions=pos, strategy="xpencil",
             backend="halo", n_shards=2, shard_cap=10)
    assert p.check_overflow(ParticleState(pos))


def test_shard_probes_match_bincount():
    dom = Domain.cubic(8, cutoff=1.0)
    pos = dom.sample_uniform(jax.random.PRNGKey(2), 700)
    loads = np.asarray(H.shard_loads(dom, pos, 4))
    zc = np.asarray(dom.cell_coords(pos))[:, 2]
    expect = np.bincount(zc // 2, minlength=4)
    np.testing.assert_array_equal(loads, expect)
    assert loads.sum() == 700
    cap = H.suggest_shard_cap(dom, pos, 4)
    assert cap >= loads.max() and cap % 8 == 0
    ma = H.suggest_shard_max_active(dom, pos, 4)
    counts = jax.ops.segment_sum(jnp.ones((700,), jnp.int32),
                                 dom.cell_ids(pos),
                                 num_segments=dom.n_cells)
    assert ma >= int(np.asarray(shard_pencil_active(dom, counts, 4)).max())
    assert ma <= 2 * 8                # clipped to the slab's pencil count
    np.testing.assert_array_equal(
        np.asarray(shard_slab_counts(dom, counts, 4)), expect)


def test_single_shard_fallback_bit_identical():
    dom = Domain.cubic(6, cutoff=1.0, periodic=True)
    pos = dom.sample_uniform(jax.random.PRNGKey(3), 600)
    state = ParticleState(pos)
    kern = make_lennard_jones()
    p_ref = plan(dom, kern, positions=pos, strategy="xpencil")
    p_halo = dataclasses.replace(p_ref, backend="halo", n_shards=1)
    f_r, q_r = p_ref.execute(state)
    f_h, q_h = p_halo.execute(state)
    np.testing.assert_array_equal(np.asarray(f_r), np.asarray(f_h))
    np.testing.assert_array_equal(np.asarray(q_r), np.asarray(q_h))


def test_halo_plan_validation():
    dom = Domain.cubic(8, cutoff=1.0)
    pos = dom.sample_uniform(jax.random.PRNGKey(0), 100)
    kern = make_lennard_jones()
    with pytest.raises(ValueError, match="cell schedule"):
        plan(dom, kern, positions=pos, strategy="par_part", backend="halo")
    with pytest.raises(ValueError, match="divisible"):
        plan(dom, kern, positions=pos, strategy="xpencil", backend="halo",
             n_shards=3)
    with pytest.raises(ValueError, match="pencil schedules"):
        plan(dom, kern, positions=pos, strategy="allin", backend="halo",
             n_shards=2, compact=True)
    with pytest.raises(ValueError, match="shard_cap"):
        plan(dom, kern, m_c=8, strategy="xpencil", backend="halo",
             n_shards=2)               # no positions, no cap
    with pytest.raises(ValueError, match="concrete per-shard backend"):
        plan(dom, kern, positions=pos, strategy="xpencil", backend="halo",
             n_shards=2, halo_inner="halo")


def test_plan_defaults_follow_device_count():
    dom = Domain.cubic(8, cutoff=1.0)
    pos = dom.sample_uniform(jax.random.PRNGKey(0), 400)
    p = plan(dom, make_lennard_jones(), positions=pos, strategy="xpencil",
             backend="halo")
    from repro.dist.engine import default_n_shards
    assert p.n_shards == default_n_shards(dom)
    assert p.n_shards <= jax.device_count() and 8 % p.n_shards == 0
    if p.n_shards > 1:
        assert p.shard_cap is not None and p.shard_cap >= 1


def test_distribute_builds_halo_twin():
    dom = Domain.cubic(8, cutoff=1.0, periodic=True)
    pos = dom.sample_uniform(jax.random.PRNGKey(4), 900)
    p = plan(dom, make_lennard_jones(), positions=pos, strategy="xpencil",
             compact=True)
    d = p.distribute(n_shards=4, positions=pos)
    assert d.backend == "halo" and d.halo_inner == "reference"
    assert d.n_shards == 4 and d.shard_cap >= 1
    # compact bound re-measured per shard: never larger than the global one
    assert d.compact and d.max_active <= p.max_active
    # replan grows only the shard capacity when only it overflows
    tight = dataclasses.replace(d, shard_cap=2)
    grown = tight.replan(ParticleState(pos))
    assert grown.shard_cap > 2 and grown.m_c == d.m_c
    assert grown.max_active == d.max_active


def test_autotune_halo_twins_enumeration():
    from repro.core.autotune import Candidate, halo_twins, prune_candidates
    dom = Domain.cubic(8, cutoff=1.0)
    pos = dom.sample_uniform(jax.random.PRNGKey(5), 600)
    base = [Candidate("xpencil", "reference", 64, 16),
            Candidate("xpencil", "reference", 64, 16, compact=True,
                      max_active=64),
            Candidate("par_part", "reference", 64, 16),
            Candidate("allin", "reference", 64, 16, box=(2, 2, 2),
                      compact=True, max_active=64)]
    twins = halo_twins(dom, pos, base, (2, 3, 4, 16), device_count=4)
    # 3 doesn't divide nz=8, 16 exceeds the injected device count,
    # par_part has no slab meaning, compact allin is excluded
    assert {t.n_shards for t in twins} == {2, 4}
    assert all(t.shard_cap and t.shard_cap >= 1 for t in twins)
    assert {t.strategy for t in twins} == {"xpencil"}
    comp = [t for t in twins if t.compact]
    assert comp and all(t.max_active <= 64 for t in comp)
    # round-robin pruning keeps distributed twins in the timed field
    kept, _ = prune_candidates(dom, 600 / dom.n_cells, base[:1] + twins,
                               top_k=3)
    assert any(c.distributed for c in kept)
    # and a JSON round trip preserves the distributed axis
    rt = Candidate.from_json(twins[0].to_json())
    assert rt == twins[0]


def test_cache_key_is_mesh_aware():
    from repro.core.autotune import cache_key
    dom = Domain.cubic(4, cutoff=1.0)
    kern = make_lennard_jones()
    k1 = cache_key("cpu", dom, 8, 4.0, kern, ("reference",),
                   device_count=1)
    k8 = cache_key("cpu", dom, 8, 4.0, kern, ("reference",),
                   device_count=8)
    assert k1 != k8 and "dev8" in k8


# --------------------------------------------------------------------------
# in-process multi-device (CI halo job: 8 emulated devices)
# --------------------------------------------------------------------------

multi = pytest.mark.skipif(jax.device_count() < 2,
                           reason="needs >= 2 devices (CI halo job)")


@multi
def test_halo_parity_in_process():
    ndev = jax.device_count()
    ns = max(n for n in range(1, min(ndev, 8) + 1) if 8 % n == 0)
    dom = Domain.cubic(8, cutoff=1.0, periodic=True)
    pos = dom.sample_uniform(jax.random.PRNGKey(7), 1000)
    state = ParticleState(pos)
    kern = make_lennard_jones()
    p_ref = plan(dom, kern, positions=pos, strategy="xpencil")
    p_halo = plan(dom, kern, m_c=p_ref.m_c, positions=pos,
                  strategy="xpencil", backend="halo", n_shards=ns)
    f_r, q_r = p_ref.execute(state)
    f_h, q_h = p_halo.execute(state)
    scale = float(np.abs(np.asarray(f_r)).max())
    np.testing.assert_allclose(np.asarray(f_h), np.asarray(f_r),
                               rtol=3e-4, atol=3e-4 * max(scale, 1.0))


@multi
def test_halo_compact_bit_identical_in_process():
    ndev = jax.device_count()
    ns = max(n for n in (2, 4) if n <= ndev)
    dom = Domain.cubic(8, cutoff=1.0)
    pos = np.array(Domain.cubic(8).sample_uniform(
        jax.random.PRNGKey(8), 400))
    pos[:, 2] = pos[:, 2] * 0.5       # cluster low in Z: uneven shards
    pos = jnp.asarray(pos)
    state = ParticleState(pos)
    kern = make_lennard_jones()
    pd = plan(dom, kern, positions=pos, strategy="xpencil", backend="halo",
              n_shards=ns)
    pc = plan(dom, kern, m_c=pd.m_c, positions=pos, strategy="xpencil",
              backend="halo", n_shards=ns, compact=True)
    f_d, q_d = pd.execute(state)
    f_c, q_c = pc.execute(state)
    np.testing.assert_array_equal(np.asarray(f_d), np.asarray(f_c))
    np.testing.assert_array_equal(np.asarray(q_d), np.asarray(q_c))


# --------------------------------------------------------------------------
# subprocess multi-device (tier-1: fresh python, emulated devices)
# --------------------------------------------------------------------------

def test_halo_backend_parity_subprocess():
    """Acceptance gate: on 4 emulated devices the halo backend matches the
    single-device schedule for dense and compacted shards, periodic and
    open Z — and compacted shards are bit-identical to dense shards."""
    out = run_sub("""
        import jax, numpy as np
        import jax.numpy as jnp
        from repro.core import Domain, ParticleState, make_lennard_jones, \\
            plan
        kern = make_lennard_jones()
        for periodic in (False, True):
            dom = Domain.cubic(8, cutoff=1.0, periodic=periodic)
            pos = dom.sample_uniform(jax.random.PRNGKey(3), 1500)
            state = ParticleState(pos)
            p_ref = plan(dom, kern, positions=pos, strategy="xpencil")
            f_r, q_r = p_ref.execute(state)
            scale = max(float(np.abs(np.asarray(f_r)).max()), 1.0)
            p_h = plan(dom, kern, m_c=p_ref.m_c, positions=pos,
                       strategy="xpencil", backend="halo", n_shards=4)
            f_h, q_h = p_h.execute(state)
            np.testing.assert_allclose(np.asarray(f_h), np.asarray(f_r),
                                       rtol=3e-4, atol=3e-4 * scale)
            p_c = plan(dom, kern, m_c=p_ref.m_c, positions=pos,
                       strategy="xpencil", backend="halo", n_shards=4,
                       compact=True)
            f_c, q_c = p_c.execute(state)
            assert np.array_equal(np.asarray(f_h), np.asarray(f_c))
            assert np.array_equal(np.asarray(q_h), np.asarray(q_c))
        print("PARITY_OK")
    """)
    assert "PARITY_OK" in out


def test_halo_boundary_pair_vs_minimum_image_oracle():
    """Regression (non-periodic Z halo fill): a pair straddling the global
    Z boundary interacts through the wrap iff Z is periodic — checked
    against the O(N^2) minimum-image oracle on both axis settings."""
    out = run_sub("""
        import jax, numpy as np
        import jax.numpy as jnp
        from repro.core import Domain, ParticleState, make_lennard_jones, \\
            plan
        kern = make_lennard_jones()
        pos = jnp.asarray([[2.1, 2.1, 0.15], [2.1, 2.1, 3.85]],
                          jnp.float32)
        state = ParticleState(pos)
        for periodic_z in (True, False):
            dom = Domain(box=(4., 4., 4.), ncells=(4, 4, 4), cutoff=1.0,
                         periodic=(False, False, periodic_z))
            f_n2, _ = plan(dom, kern, m_c=8,
                           strategy="naive_n2").execute(state)
            f_h, _ = plan(dom, kern, m_c=8, positions=pos,
                          strategy="xpencil", backend="halo",
                          n_shards=2).execute(state)
            np.testing.assert_allclose(np.asarray(f_h), np.asarray(f_n2),
                                       rtol=1e-5, atol=1e-6)
            if periodic_z:
                assert np.abs(np.asarray(f_h)).max() > 0
            else:
                assert np.abs(np.asarray(f_h)).max() == 0, \\
                    "open Z boundary leaked ghost particles"
        print("BOUNDARY_OK")
    """, n_dev=2)
    assert "BOUNDARY_OK" in out


def test_halo_batch_replan_and_fields_subprocess():
    out = run_sub("""
        import dataclasses
        import jax, numpy as np
        import jax.numpy as jnp
        from repro.core import Domain, ParticleState, make_lennard_jones, \\
            plan
        from repro.core.api import dispatch_count
        kern = make_lennard_jones()
        dom = Domain.cubic(4, cutoff=1.0, periodic=True)
        pos = dom.sample_uniform(jax.random.PRNGKey(0), 300)
        state = ParticleState(pos)
        p = plan(dom, kern, positions=pos, strategy="xpencil",
                 backend="halo", n_shards=2)
        f0, q0 = p.execute(state)

        # batched: one dispatch, bit-identical to the per-state loop
        B = 3
        stack = ParticleState(jnp.stack([pos + 0.002 * i
                                         for i in range(B)]))
        before = dispatch_count()
        fb, qb = p.execute_batch(stack)
        assert dispatch_count() == before + 1
        for i in range(B):
            fi, qi = p.execute(ParticleState(stack.positions[i]))
            assert np.array_equal(np.asarray(fb[i]), np.asarray(fi)), i
            assert np.array_equal(np.asarray(qb[i]), np.asarray(qi)), i

        # overflow -> replan grows only the shard capacity
        tight = dataclasses.replace(p, shard_cap=8)
        assert tight.check_overflow(state)
        (f2, _), grown = tight.execute_or_replan(state)
        assert grown.shard_cap > 8 and grown.m_c == p.m_c
        assert np.array_equal(np.asarray(f2), np.asarray(f0))

        # per-particle fields ride through partition + ghost exchange
        sf = ParticleState(pos, {"mass": jnp.ones((300,))})
        ff, qf = p.execute(sf)
        assert np.array_equal(np.asarray(ff), np.asarray(f0))
        print("BATCH_REPLAN_OK")
    """, n_dev=2)
    assert "BATCH_REPLAN_OK" in out


@multi
def test_halo_packed_bit_identical_in_process():
    """Packed per-shard execution (ghost planes exchanged packed) is
    bit-identical to the dense-layout halo path, with and without
    per-shard compaction."""
    ndev = jax.device_count()
    ns = max(n for n in (2, 4) if n <= ndev)
    dom = Domain.cubic(8, cutoff=1.0)
    pos = dom.sample_uniform(jax.random.PRNGKey(9), 500)
    state = ParticleState(pos)
    kern = make_lennard_jones()
    pd = plan(dom, kern, positions=pos, strategy="xpencil", backend="halo",
              n_shards=ns)
    f_d, q_d = pd.execute(state)
    for compact in (False, True):
        pp = plan(dom, kern, m_c=pd.m_c, positions=pos, strategy="xpencil",
                  backend="halo", n_shards=ns, layout="packed",
                  compact=compact)
        f_p, q_p = pp.execute(state)
        np.testing.assert_array_equal(np.asarray(f_p), np.asarray(f_d))
        np.testing.assert_array_equal(np.asarray(q_p), np.asarray(q_d))


def test_halo_packed_parity_subprocess():
    """On 4 emulated devices the packed halo path (per-shard CSR packing +
    packed ghost-plane exchange) is bit-identical to the dense halo path
    on periodic and open Z, and its row_cap replan grows only that
    bound."""
    out = run_sub("""
        import dataclasses
        import jax, numpy as np
        from repro.core import Domain, ParticleState, make_lennard_jones, \\
            plan
        kern = make_lennard_jones()
        for periodic in (False, True):
            dom = Domain.cubic(8, cutoff=1.0, periodic=periodic)
            pos = dom.sample_uniform(jax.random.PRNGKey(5), 1200)
            state = ParticleState(pos)
            p_d = plan(dom, kern, positions=pos, strategy="xpencil",
                       backend="halo", n_shards=4)
            f_d, q_d = p_d.execute(state)
            p_p = plan(dom, kern, m_c=p_d.m_c, positions=pos,
                       strategy="xpencil", backend="halo", n_shards=4,
                       layout="packed", compact=True)
            f_p, q_p = p_p.execute(state)
            assert np.array_equal(np.asarray(f_p), np.asarray(f_d)), periodic
            assert np.array_equal(np.asarray(q_p), np.asarray(q_d)), periodic

            tight = dataclasses.replace(p_p, row_cap=8)
            assert tight.check_overflow(state)
            (f2, _), grown = tight.execute_or_replan(state)
            assert grown.row_cap > 8 and grown.m_c == p_p.m_c
            assert grown.shard_cap == p_p.shard_cap
            assert np.array_equal(np.asarray(f2), np.asarray(f_d))
        print("PACKED_HALO_OK")
    """)
    assert "PACKED_HALO_OK" in out
