import pathlib
import sys

SRC = pathlib.Path(__file__).resolve().parents[1] / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))

# ---------------------------------------------------------------------------
# hypothesis gate: the container doesn't ship hypothesis and nothing may be
# pip-installed, so provide a minimal deterministic stand-in with the same
# surface the tests use (@given + st.integers/sampled_from, @settings).
# Property tests then run as seeded example sweeps instead of shrinking
# searches — strictly weaker, but the properties still execute.
# ---------------------------------------------------------------------------
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    import random
    import types

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    def _integers(min_value=0, max_value=1 << 30):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def _sampled_from(seq):
        seq = list(seq)
        return _Strategy(lambda rng: rng.choice(seq))

    def _floats(min_value=0.0, max_value=1.0, **_):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    def _booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)

    def _lists(elem, min_size=0, max_size=None):
        hi = max_size if max_size is not None else min_size + 10
        return _Strategy(lambda rng: [elem.draw(rng) for _ in
                                      range(rng.randint(min_size, hi))])

    def _settings(max_examples=10, deadline=None, **_):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def _given(*pos_strategies, **kw_strategies):
        def deco(fn):
            # no functools.wraps: pytest must not follow __wrapped__ and
            # mistake the drawn parameters for fixtures.
            def wrapper():
                rng = random.Random(0)
                for _ in range(getattr(wrapper, "_max_examples", 10)):
                    args = [s.draw(rng) for s in pos_strategies]
                    kwargs = {k: s.draw(rng)
                              for k, s in kw_strategies.items()}
                    fn(*args, **kwargs)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__dict__.update(fn.__dict__)
            return wrapper
        return deco

    st_mod = types.ModuleType("hypothesis.strategies")
    st_mod.integers = _integers
    st_mod.sampled_from = _sampled_from
    st_mod.floats = _floats
    st_mod.booleans = _booleans
    st_mod.lists = _lists

    hyp = types.ModuleType("hypothesis")
    hyp.given = _given
    hyp.settings = _settings
    hyp.strategies = st_mod
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st_mod
