"""Serving tier: shape-class bucketing, padded execution bit-identity,
the steady-state zero-recompile/zero-retune guarantee, admission control,
overflow replan isolation, executor-LRU behavior under many classes, and
the packed execute_batch fast path."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Domain, ParticleState, clear_executor_cache,
                        executor_cache_info, make_lennard_jones,
                        make_low_flop, plan, recompile_count,
                        reset_counters, set_executor_cache_size)
from repro.core import api, autotune as at, scenarios
from repro.serve import (MIN_N_CAP, Response, ServingEngine, ShapeClass,
                         VirtualClock, classify, pad_state, percentile,
                         quantize_batch, quantize_n, split_batch,
                         stack_states)


def _dom(division=4):
    return Domain.cubic(division, cutoff=1.0)


def _state(dom, n, seed=0, scenario="uniform", with_fields=False):
    pos = scenarios.sample(scenario, dom, jax.random.PRNGKey(seed), n)
    fields = {}
    if with_fields:
        fields["mass"] = jnp.abs(jax.random.normal(
            jax.random.PRNGKey(seed + 7), (n,))) + 0.5
    return ParticleState(pos, fields)


def _assert_bitwise(a, b):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# bucketing
# ---------------------------------------------------------------------------

def test_quantize_n_rounds_up_with_floor():
    assert quantize_n(3) == MIN_N_CAP
    assert quantize_n(MIN_N_CAP) == MIN_N_CAP
    assert quantize_n(MIN_N_CAP + 1) == 2 * MIN_N_CAP
    assert quantize_n(1000) == 1024
    with pytest.raises(ValueError):
        quantize_n(0)


def test_quantize_batch_pow2_capped():
    assert quantize_batch(1, 8) == 1
    assert quantize_batch(3, 8) == 4
    assert quantize_batch(5, 8) == 8
    assert quantize_batch(5, 6) == 6   # cap wins over pow2


def test_classify_buckets_compatible_requests_together():
    dom = _dom()
    lj = make_lennard_jones()
    a = classify(dom, lj, 50, ())
    b = classify(dom, lj, 60, ())
    assert a == b and hash(a) == hash(b)
    # different kernel identity -> different class
    assert classify(dom, make_low_flop(), 50, ()) != a
    # different grid -> different class
    assert classify(_dom(3), lj, 50, ()) != a
    # different field set -> different class
    assert classify(dom, lj, 50, ("mass",)) != a
    # N crossing the pow2 boundary -> different class
    assert classify(dom, lj, MIN_N_CAP + 1, ()) != a
    assert isinstance(a, ShapeClass) and a.label()


def test_pad_state_preserves_real_rows_and_masks_pads():
    dom = _dom()
    st = _state(dom, 50, with_fields=True)
    padded = pad_state(st, 64)
    assert padded.positions.shape == (64, 3)
    assert padded.fields["mass"].shape == (64,)
    assert padded.valid.shape == (64,)
    _assert_bitwise(padded.positions[:50], st.positions)
    _assert_bitwise(padded.fields["mass"][:50], st.fields["mass"])
    assert bool(padded.valid[:50].all()) and not bool(padded.valid[50:].any())
    with pytest.raises(ValueError):
        pad_state(st, 32)


def test_stack_states_rejects_mixed_field_sets():
    dom = _dom()
    with pytest.raises(ValueError, match="mixed field sets"):
        stack_states([_state(dom, 10), _state(dom, 10, with_fields=True)],
                     64)


# ---------------------------------------------------------------------------
# padded execution is bit-identical (the mechanism everything rests on)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("opts", [
    {},
    {"layout": "packed", "strategy": "xpencil"},
    {"compact": True, "strategy": "xpencil"},
])
def test_padded_masked_state_is_bit_identical(opts):
    dom = _dom()
    st = _state(dom, 100, with_fields=True)
    p = plan(dom, positions=st.positions, **opts)
    f0, u0 = p.execute(st)
    fp, up = p.execute(pad_state(st, 256))
    _assert_bitwise(fp[:100], f0)
    _assert_bitwise(up[:100], u0)


def test_fully_invalid_row_is_inert_in_batch():
    dom = _dom()
    st = _state(dom, 60)
    p = plan(dom, positions=st.positions)
    f0, u0 = p.execute(st)
    batched = stack_states([st], 64, b_cap=4)  # 3 ghost rows
    bf, bu = p.execute_batch(batched)
    _assert_bitwise(bf[0, :60], f0)
    _assert_bitwise(bu[0, :60], u0)
    assert not bool(batched.valid[1:].any())


# ---------------------------------------------------------------------------
# packed execute_batch fast path (pack_rows fused under the vmapped jit)
# ---------------------------------------------------------------------------

def test_packed_batch_parity_vs_per_state_loop():
    dom = _dom()
    states = [_state(dom, 60, seed=i, scenario=s)
              for i, s in enumerate(["uniform", "gaussian_blob",
                                     "two_phase", "uniform"])]
    ref_pos = jnp.concatenate([s.positions for s in states])
    p = plan(dom, positions=ref_pos, layout="packed", strategy="xpencil")
    bf, bu = p.execute_batch(stack_states(states, 64, 4))
    for s, (f, u) in zip(states, split_batch(bf, bu, [60] * 4)):
        f1, u1 = p.execute(s)
        _assert_bitwise(f, f1)
        _assert_bitwise(u, u1)


# ---------------------------------------------------------------------------
# the steady-state guarantee (ISSUE 6 acceptance)
# ---------------------------------------------------------------------------

def _wave(eng, dom, seed0, with_fields=False):
    """One fixed request mix: two classes (n_cap 64 and 256), 8 requests."""
    ids = []
    for i in range(8):
        n = [50, 60, 200, 250][i % 4]
        st = _state(dom, n, seed=seed0 + i, with_fields=with_fields)
        ids.append((eng.submit(dom, st), st, n))
    eng.flush()
    resp = {r.req_id: r for r in eng.take_responses()}
    return [(resp[rid], st, n) for rid, st, n in ids]


def test_steady_state_zero_recompiles_zero_retuning_bit_identical():
    dom = _dom()
    eng = ServingEngine(max_batch=4, max_wait=0.0)
    _wave(eng, dom, 0)                      # warmup: traces + plans built
    assert eng.metrics.recompiles > 0       # warmup did compile something

    reset_counters()
    at.reset_timing_runs()
    served = _wave(eng, dom, 100)           # same classes, fresh particles

    assert recompile_count() == 0           # core counter: no new traces
    assert at.timing_run_count() == 0       # no autotune stopwatch runs
    for r, st, n in served:
        assert r.status == "ok"
        sc = classify(dom, eng.kernel, n, ())
        p = eng.class_plan(sc)
        f1, u1 = p.execute(st)              # unbatched reference
        _assert_bitwise(r.forces, f1)
        _assert_bitwise(r.potential, u1)


def test_prewarm_makes_first_requests_steady_state():
    dom = _dom()
    eng = ServingEngine(max_batch=4, max_wait=0.0)
    eng.prewarm(dom, _state(dom, 60, seed=0))
    reset_counters()
    at.reset_timing_runs()
    # every bucket composition the dispatcher can form: full batch (4),
    # then a timeout-drained part-full batch (3)
    for i in range(7):
        eng.submit(dom, _state(dom, 60, seed=1 + i))
    eng.flush()
    assert recompile_count() == 0
    assert at.timing_run_count() == 0
    assert all(r.status == "ok" for r in eng.take_responses())


def test_responses_trimmed_to_true_n():
    dom = _dom()
    eng = ServingEngine(max_batch=4, max_wait=0.0)
    st = _state(dom, 37)
    rid = eng.submit(dom, st)
    eng.flush()
    (r,) = eng.take_responses()
    assert r.req_id == rid and r.status == "ok"
    assert r.forces.shape == (37, 3) and r.potential.shape == (37,)


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------

def test_reject_policy_refuses_newcomer_when_full():
    dom = _dom()
    eng = ServingEngine(max_batch=100, max_queue=3, admission="reject",
                        max_wait=1e9)
    ids = [eng.submit(dom, _state(dom, 20, seed=i)) for i in range(5)]
    resp = {r.req_id: r for r in eng.take_responses()}
    assert [resp[i].status for i in ids[3:]] == ["rejected", "rejected"]
    assert eng.metrics.rejected == 2
    eng.flush()
    resp = {r.req_id: r for r in eng.take_responses()}
    assert all(resp[i].status == "ok" for i in ids[:3])


def test_shed_oldest_policy_evicts_head_of_line():
    dom = _dom()
    clock = VirtualClock()
    eng = ServingEngine(max_batch=100, max_queue=2,
                        admission="shed_oldest", max_wait=1e9, clock=clock)
    first = eng.submit(dom, _state(dom, 20, seed=0))
    clock.advance(1.0)
    second = eng.submit(dom, _state(dom, 20, seed=1))
    clock.advance(1.0)
    third = eng.submit(dom, _state(dom, 20, seed=2))  # queue full -> shed
    resp = {r.req_id: r for r in eng.take_responses()}
    assert resp[first].status == "shed"
    assert eng.metrics.shed == 1
    eng.flush()
    resp = {r.req_id: r for r in eng.take_responses()}
    assert resp[second].status == "ok" and resp[third].status == "ok"


def test_poll_dispatches_only_timed_out_buckets():
    dom = _dom()
    clock = VirtualClock()
    eng = ServingEngine(max_batch=100, max_wait=0.5, clock=clock)
    eng.submit(dom, _state(dom, 20))
    assert eng.poll() == 0                  # too young
    clock.advance(0.6)
    assert eng.poll() == 1                  # now overdue
    (r,) = eng.take_responses()
    assert r.status == "ok"
    assert r.queue_latency >= 0.6


# ---------------------------------------------------------------------------
# overflow -> per-class replan
# ---------------------------------------------------------------------------

def test_overflow_replans_only_that_class():
    dom = _dom()
    eng = ServingEngine(max_batch=2, max_wait=0.0)
    # class A: uniform, plans with tight measured bounds
    for i in range(2):
        eng.submit(dom, _state(dom, 60, seed=i))
    # class B warmed separately
    for i in range(2):
        eng.submit(dom, _state(dom, 200, seed=i))
    eng.flush()
    eng.take_responses()
    sc_a = classify(dom, eng.kernel, 60, ())
    sc_b = classify(dom, eng.kernel, 200, ())
    plan_a0, plan_b0 = eng.class_plan(sc_a), eng.class_plan(sc_b)

    # a heavily clustered request in class A overflows its uniform m_c
    clustered = _state(dom, 60, seed=99, scenario="gaussian_blob")
    assert plan_a0.check_overflow(clustered)
    rid = eng.submit(dom, clustered)
    eng.submit(dom, _state(dom, 60, seed=3))
    eng.flush()
    resp = {r.req_id: r for r in eng.take_responses()}

    assert eng.metrics.replans >= 1
    plan_a1 = eng.class_plan(sc_a)
    assert plan_a1.m_c > plan_a0.m_c            # class A bounds grew
    assert eng.class_plan(sc_b) is plan_b0      # class B untouched
    f1, u1 = plan_a1.execute(clustered)
    _assert_bitwise(resp[rid].forces, f1)
    _assert_bitwise(resp[rid].potential, u1)


# ---------------------------------------------------------------------------
# executor LRU under many shape classes
# ---------------------------------------------------------------------------

@pytest.fixture()
def small_batch_cache():
    clear_executor_cache()
    set_executor_cache_size(batch=2)
    yield
    set_executor_cache_size(single=128, batch=32)
    clear_executor_cache()


def test_lru_eviction_and_readmission_bit_identical(small_batch_cache):
    # Three grids -> three distinct plans -> three batch-executor entries.
    # (Same-grid classes can legitimately *share* an executor when their
    # measured bounds coincide — the LRU key is the plan, not the class.)
    eng = ServingEngine(max_batch=2, max_wait=0.0)
    mixes = [(_dom(3), 40, 0), (_dom(4), 100, 1), (_dom(5), 300, 2)]

    def run_round():
        out = {}
        for dom, n, seed in mixes:
            sts = [_state(dom, n, seed=seed + 10 * j) for j in range(2)]
            ids = [eng.submit(dom, s) for s in sts]
            eng.flush()
            resp = {r.req_id: r for r in eng.take_responses()}
            out[n] = [(resp[i].forces, resp[i].potential) for i in ids]
        return out

    first = run_round()
    info = executor_cache_info()["batch"]
    assert info.maxsize == 2 and info.currsize == 2    # one class evicted
    reset_counters()
    second = run_round()                               # re-admission recompiles
    assert recompile_count() > 0
    for n in first:                                    # ... bit-identically
        for (f0, u0), (f1, u1) in zip(first[n], second[n]):
            _assert_bitwise(f0, f1)
            _assert_bitwise(u0, u1)


def test_clear_executor_cache_mid_stream_costs_latency_only():
    dom = _dom()
    eng = ServingEngine(max_batch=2, max_wait=0.0)
    sts = [_state(dom, 60, seed=i) for i in range(2)]
    ids = [eng.submit(dom, s) for s in sts]
    eng.flush()
    first = {r.req_id: r for r in eng.take_responses()}

    clear_executor_cache()                  # ops event mid-stream
    reset_counters()
    ids2 = [eng.submit(dom, s) for s in sts]
    eng.flush()
    second = {r.req_id: r for r in eng.take_responses()}

    assert recompile_count() > 0            # re-trace happened ...
    for a, b in zip(ids, ids2):             # ... results identical
        _assert_bitwise(first[a].forces, second[b].forces)
        _assert_bitwise(first[a].potential, second[b].potential)


def test_set_executor_cache_size_validates():
    with pytest.raises(ValueError):
        set_executor_cache_size(batch=0)


# ---------------------------------------------------------------------------
# autotuned serving: timing runs happen once, cache hits after
# ---------------------------------------------------------------------------

def test_autotuned_class_plan_times_once_then_serves_warm(
        tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path))
    def fake_time(fn, *args, reps=None, budget_s=3.0):
        fn(*args)                           # still trace + run once
        return 1e-3, reps or 1
    monkeypatch.setattr(at, "time_fn", fake_time)

    dom = _dom()
    eng = ServingEngine(max_batch=2, max_wait=0.0, autotune=True,
                        tune_opts=dict(reps=1, budget_s=0.01, top_k=2))
    for i in range(2):
        eng.submit(dom, _state(dom, 60, seed=i))
    eng.flush()
    eng.take_responses()
    assert eng.metrics.autotune_timing_runs > 0      # cold: stopwatch ran

    at.reset_timing_runs()
    tr0 = eng.metrics.autotune_timing_runs
    for i in range(2):
        eng.submit(dom, _state(dom, 60, seed=100 + i))
    eng.flush()
    assert eng.metrics.autotune_timing_runs == tr0   # warm: zero re-timing
    assert at.timing_run_count() == 0


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

def test_percentile_and_latency_summaries():
    xs = list(map(float, range(1, 101)))
    assert percentile(xs, 50) == pytest.approx(50.5)
    assert percentile(xs, 99) == pytest.approx(99.01)
    assert math.isnan(percentile([], 50))


def test_virtual_clock_is_monotonic():
    c = VirtualClock()
    c.advance(2.0)
    assert c.now() == 2.0
    c.advance_to(1.0)                       # never backward
    assert c.now() == 2.0
    with pytest.raises(ValueError):
        c.advance(-1.0)


def test_metrics_snapshot_counts_and_fill():
    dom = _dom()
    eng = ServingEngine(max_batch=4, max_wait=0.0)
    for i in range(3):                      # 3 live in a 4-slot batch
        eng.submit(dom, _state(dom, 60, seed=i))
    eng.flush()
    snap = eng.metrics.snapshot()
    assert snap["served"] == 3 and snap["batches"] == 1
    assert snap["batch_fill"] == pytest.approx(3 / 4)
    assert snap["total_latency"]["count"] == 3
    assert snap["rps"] > 0


# ---------------------------------------------------------------------------
# LM serving relocation shim
# ---------------------------------------------------------------------------

def test_lm_serving_shim_keeps_old_import_path():
    from repro.models import serving as new
    from repro.train import serve as old
    assert old.generate is new.generate
    assert old.make_prefill_step is new.make_prefill_step
    assert old.make_decode_step is new.make_decode_step
