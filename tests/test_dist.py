"""Distribution layer: subprocess multi-device tests + sharding rules.

Multi-device tests spawn a fresh python with XLA_FLAGS so the main pytest
process keeps its single CPU device (the dry-run is the only place 512
devices are allowed, per the assignment).
"""

import json
import os
import pathlib
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")


def run_sub(body: str, n_dev: int = 4, timeout: int = 600) -> str:
    code = ("import os\n"
            f"os.environ['XLA_FLAGS'] = "
            f"'--xla_force_host_platform_device_count={n_dev}'\n"
            + textwrap.dedent(body))
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_main_process_sees_one_device():
    assert jax.device_count() == 1


def test_halo_engine_matches_single_device():
    """The halo execution engine on an explicit user mesh (the
    ``plan.distribute(mesh)`` path; the default-mesh path is covered in
    tests/test_halo.py)."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import Domain, ParticleState, make_lennard_jones, \\
            plan
        mesh = jax.make_mesh((4,), ("data",))
        kern = make_lennard_jones()
        for periodic in (False, True):
            dom = Domain.cubic(8, cutoff=1.0, periodic=periodic)
            pos = dom.sample_uniform(jax.random.PRNGKey(3), 1500)
            state = ParticleState(pos)
            p_ref = plan(dom, kern, positions=pos, strategy="xpencil")
            f_ref, _ = p_ref.execute(state)
            p_dist = p_ref.distribute(mesh, positions=pos)
            assert p_dist.n_shards == 4 and p_dist.shard_axis == "data"
            f, _ = p_dist.execute(state)
            scale = max(float(np.abs(np.asarray(f_ref)).max()), 1.0)
            np.testing.assert_allclose(np.asarray(f), np.asarray(f_ref),
                                       rtol=3e-4, atol=3e-4 * scale)
        print("HALO_OK")
    """)
    assert "HALO_OK" in out


def test_spmd_train_step_on_debug_mesh():
    """2x2 mesh: sharded train step runs and matches the 1-device loss."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_smoke_config
        from repro.models import model as M
        from repro.optim import AdamConfig, init_opt_state
        from repro.train import make_train_step
        from repro.dist import sharding as SH

        cfg = get_smoke_config("qwen1.5-0.5b")
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        opt_cfg = AdamConfig(total_steps=8)
        opt = init_opt_state(params, opt_cfg)
        tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                                    cfg.vocab_size, jnp.int32)
        batch = {"tokens": tokens, "labels": tokens}

        m0, _, _ = jax.jit(make_train_step(cfg, opt_cfg))(params, opt, batch)

        p_sh = SH.params_shardings(cfg, mesh, params)
        o_sh = SH.opt_shardings(cfg, mesh, opt, params)
        b_sh = SH.batch_shardings(cfg, mesh, batch)
        params_s = jax.device_put(params, p_sh)
        opt_s = jax.device_put(opt, o_sh)
        batch_s = jax.device_put(batch, b_sh)
        with SH.use_mesh(mesh):   # resolves in-model constrain role specs
            step = jax.jit(make_train_step(cfg, opt_cfg),
                           in_shardings=(p_sh, o_sh, b_sh))
            m1, p1, o1 = step(params_s, opt_s, batch_s)
        np.testing.assert_allclose(float(m0["loss"]), float(m1["loss"]),
                                   rtol=2e-3)
        print("SPMD_OK", float(m0["loss"]), float(m1["loss"]))
    """)
    assert "SPMD_OK" in out


def test_elastic_remesh_restore(tmp_path):
    """Checkpoint on a 4-device mesh, restore + step on a 2-device mesh."""
    ckpt = str(tmp_path / "ck")
    out = run_sub(f"""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke_config
        from repro.models import model as M
        from repro.optim import AdamConfig, init_opt_state
        from repro.train import make_train_step
        from repro.dist import sharding as SH
        from repro.ckpt import checkpoint as C

        cfg = get_smoke_config("starcoder2-3b")
        opt_cfg = AdamConfig(total_steps=8)
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        opt = init_opt_state(params, opt_cfg)

        mesh4 = jax.make_mesh((2, 2), ("data", "model"))
        p4 = jax.device_put(params, SH.params_shardings(cfg, mesh4, params))
        C.save({ckpt!r}, 1, p4)

        # "failure": restart on half the devices
        mesh2 = jax.make_mesh((1, 2), ("data", "model"))
        from repro.dist.fault import elastic_restore
        p2, _ = elastic_restore({ckpt!r}, params,
                                lambda: SH.params_shardings(cfg, mesh2,
                                                            params))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                    cfg.vocab_size, jnp.int32)
        logits, _ = M.forward(cfg, p2, tokens, remat=False)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        print("ELASTIC_OK")
    """)
    assert "ELASTIC_OK" in out


def test_sanitize_drops_nondividing_axes():
    out = run_sub("""
        import jax
        from jax.sharding import PartitionSpec as P
        from repro.dist.sharding import sanitize
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        s = sanitize(mesh, P("data", "model"), (6, 7))
        assert s == P("data", None), s
        s = sanitize(mesh, P(("data", "model"),), (8,))
        assert s == P(("data", "model")), s
        s = sanitize(mesh, P(("data", "model"),), (6,))
        assert s == P(None), s
        print("SANITIZE_OK")
    """, n_dev=4)
    assert "SANITIZE_OK" in out


def test_dryrun_machinery_on_debug_mesh():
    """The dryrun lower/compile path works on a small mesh with a smoke
    config — the structural test for deliverable (e) without 512 devices."""
    out = run_sub("""
        import jax, json
        from repro.configs import get_smoke_config
        from repro.launch.dryrun import lower_cell
        cfg = get_smoke_config("gemma2-2b")
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        import dataclasses
        compiled, lowered, shape, nd = lower_cell(cfg, "train_4k", mesh)
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        assert cost.get("flops", 0) > 0
        from repro.launch.roofline import collective_bytes
        cb = collective_bytes(compiled.as_text())
        assert sum(cb.values()) > 0
        print("DRYRUN_OK", int(cost["flops"]))
    """, n_dev=4, timeout=900)
    assert "DRYRUN_OK" in out


def test_collective_parser_unit():
    from repro.launch.roofline import collective_bytes
    hlo = """
  %all-reduce.1 = f32[1024]{0} all-reduce(f32[1024]{0} %add.5), replica_groups={}
  %all-gather.2 = bf16[4,256]{1,0} all-gather(bf16[2,256]{1,0} %p0), dimensions={0}
  %foo = f32[8]{0} add(f32[8]{0} %a, f32[8]{0} %b)
  %reduce-scatter.3 = f32[128]{0} reduce-scatter(f32[512]{0} %x), dimensions={0}
  %cp = bf16[64]{0} collective-permute(bf16[64]{0} %y), source_target_pairs={{0,1}}
"""
    got = collective_bytes(hlo)
    assert got["all-reduce"] == 1024 * 4 * 2      # 2x wire multiplier
    assert got["all-gather"] == 2 * 256 * 2
    assert got["reduce-scatter"] == 512 * 4
    assert got["collective-permute"] == 64 * 2
    assert got["all-to-all"] == 0
