"""Plan/execute API: backend parity, overflow->replan, auto strategy,
batched execution, shims."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CellListEngine, Domain, InteractionPlan,
                        ParticleState, backend_matrix, choose_strategy,
                        clear_executor_cache, compute_interactions,
                        dispatch_count, make_lennard_jones,
                        make_low_flop, plan, suggest_m_c)
from repro.core import api, traffic


def _case(division, n, seed=0, periodic=False):
    dom = Domain.cubic(division, cutoff=1.0, periodic=periodic)
    pos = dom.sample_uniform(jax.random.PRNGKey(seed), n)
    return dom, pos, suggest_m_c(dom, pos)


# ---------------------------------------------------------------------------
# backend parity: pallas == reference == naive oracle through plan.execute
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("strategy", ["xpencil", "allin"])
@pytest.mark.parametrize("division,n", [(3, 200), (4, 500)])
def test_pallas_backend_parity(strategy, division, n):
    dom, pos, m_c = _case(division, n)
    kern = make_lennard_jones()
    state = ParticleState(pos)
    f_oracle, p_oracle = plan(dom, kern, m_c=m_c,
                              strategy="naive_n2").execute(state)
    f_ref, p_ref = plan(dom, kern, m_c=m_c, strategy=strategy,
                        backend="reference").execute(state)
    f_pl, p_pl = plan(dom, kern, m_c=m_c, strategy=strategy,
                      backend="pallas", interpret=True).execute(state)
    for f, p in ((f_ref, p_ref), (f_pl, p_pl)):
        np.testing.assert_allclose(np.asarray(f), np.asarray(f_oracle),
                                   rtol=3e-4, atol=3e-4)
        np.testing.assert_allclose(np.asarray(p), np.asarray(p_oracle),
                                   rtol=3e-4, atol=3e-5)
    np.testing.assert_allclose(np.asarray(f_pl), np.asarray(f_ref),
                               rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("strategy", ["xpencil", "allin"])
def test_pallas_backend_parity_periodic(strategy):
    dom, pos, m_c = _case(4, 300, seed=3, periodic=True)
    kern = make_low_flop()
    state = ParticleState(pos)
    f_ref, _ = plan(dom, kern, m_c=m_c, strategy=strategy).execute(state)
    f_pl, _ = plan(dom, kern, m_c=m_c, strategy=strategy,
                   backend="pallas", interpret=True).execute(state)
    np.testing.assert_allclose(np.asarray(f_pl), np.asarray(f_ref),
                               rtol=3e-4, atol=3e-4)


def test_backend_matrix_covers_paper_kernels():
    m = backend_matrix()
    assert set(m["pallas"]) == {"xpencil", "allin", "cell_dense"}
    assert set(m["reference"]) == {"par_part", "cell_dense", "xpencil",
                                   "allin"}


def test_unknown_backend_fails_at_plan_time():
    dom = Domain.cubic(3)
    with pytest.raises(ValueError, match="no backend"):
        plan(dom, m_c=8, strategy="xpencil", backend="cuda")
    with pytest.raises(ValueError, match="unknown strategy"):
        plan(dom, m_c=8, strategy="ypencil")


# ---------------------------------------------------------------------------
# overflow -> replan
# ---------------------------------------------------------------------------

def test_overflow_detection_and_replan():
    dom, pos, _ = _case(4, 400, seed=1)
    # cluster a quarter of the particles into one corner cell
    clustered = jnp.concatenate([pos[:100] * 0.04 + 0.3, pos[100:]])
    state = ParticleState(clustered)
    p0 = plan(dom, make_lennard_jones(), m_c=8, strategy="xpencil")
    assert p0.check_overflow(state)

    (forces, pot), p1 = p0.execute_or_replan(state)
    assert p1.m_c > p0.m_c
    assert p1.m_c % 8 == 0                     # sublane alignment preserved
    assert not p1.check_overflow(state)
    f_oracle, _ = plan(dom, make_lennard_jones(), m_c=p1.m_c,
                       strategy="naive_n2").execute(state)
    np.testing.assert_allclose(np.asarray(forces), np.asarray(f_oracle),
                               rtol=3e-4, atol=3e-4)


def test_no_replan_when_bound_holds():
    dom, pos, m_c = _case(3, 150)
    p0 = plan(dom, make_lennard_jones(), m_c=m_c, strategy="xpencil")
    state = ParticleState(pos)
    assert not p0.check_overflow(state)
    _, p1 = p0.execute_or_replan(state)
    assert p1 is p0                            # same plan object: no retrace


def test_replan_resizes_allin_subbox():
    dom, pos, _ = _case(4, 300)
    clustered = jnp.concatenate([pos[:150] * 0.04 + 0.3, pos[150:]])
    state = ParticleState(clustered)
    p0 = plan(dom, make_lennard_jones(), m_c=8, strategy="allin")
    (forces, _), p1 = p0.execute_or_replan(state)
    assert p1.m_c > 8 and p1.box is not None
    f_oracle, _ = plan(dom, make_lennard_jones(), m_c=p1.m_c,
                       strategy="naive_n2").execute(state)
    np.testing.assert_allclose(np.asarray(forces), np.asarray(f_oracle),
                               rtol=3e-4, atol=3e-4)


# ---------------------------------------------------------------------------
# strategy="auto" (traffic-model driven)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("division,ppc", [(4, 2), (6, 10), (8, 1)])
def test_auto_strategy_follows_cost_model(division, ppc):
    dom = Domain.cubic(division, cutoff=1.0)
    n = division ** 3 * ppc
    pos = dom.sample_uniform(jax.random.PRNGKey(0), n)
    p = plan(dom, make_lennard_jones(), positions=pos, strategy="auto")
    m_c = suggest_m_c(dom, pos)
    reports = traffic.model(dom, m_c, n / dom.n_cells)
    best = min(reports.values(),
               key=lambda r: r.hbm_bytes_per_interaction)
    assert p.strategy == best.strategy
    # and the auto plan actually runs + matches the oracle
    f, _ = p.execute(ParticleState(pos))
    f_oracle, _ = plan(dom, make_lennard_jones(), m_c=p.m_c,
                       strategy="naive_n2").execute(ParticleState(pos))
    np.testing.assert_allclose(np.asarray(f), np.asarray(f_oracle),
                               rtol=3e-4, atol=3e-4)


def test_auto_needs_positions():
    with pytest.raises(ValueError, match="auto"):
        plan(Domain.cubic(4), m_c=8, strategy="auto")


def test_choose_strategy_is_deterministic():
    dom = Domain.cubic(8, cutoff=1.0)
    assert choose_strategy(dom, 8, 10.0) == choose_strategy(dom, 8, 10.0)


# ---------------------------------------------------------------------------
# batched execution
# ---------------------------------------------------------------------------

def _stacked(dom, b, n, seed=0):
    keys = jax.random.split(jax.random.PRNGKey(seed), b)
    return jnp.stack([dom.sample_uniform(k, n) for k in keys])


def test_execute_batch_bit_identical_to_loop_single_dispatch():
    dom = Domain.cubic(3, cutoff=1.0)
    b, n = 8, 120
    pos = _stacked(dom, b, n)
    p = plan(dom, make_lennard_jones(), m_c=16, strategy="xpencil")

    c0 = dispatch_count()
    fb, pb = p.execute_batch(ParticleState(pos))
    batch_dispatches = dispatch_count() - c0

    c1 = dispatch_count()
    loop = [p.execute(ParticleState(pos[i])) for i in range(b)]
    loop_dispatches = dispatch_count() - c1

    assert batch_dispatches == 1                 # one jitted vmapped call
    assert loop_dispatches == b and batch_dispatches < b
    f_loop = jnp.stack([f for f, _ in loop])
    p_loop = jnp.stack([q for _, q in loop])
    assert fb.shape == (b, n, 3) and pb.shape == (b, n)
    np.testing.assert_array_equal(np.asarray(fb), np.asarray(f_loop))
    np.testing.assert_array_equal(np.asarray(pb), np.asarray(p_loop))


@pytest.mark.parametrize("strategy,backend", [
    ("par_part", "reference"), ("allin", "reference"), ("xpencil", "pallas")])
def test_execute_batch_parity_across_backends(strategy, backend):
    dom = Domain.cubic(3, cutoff=1.0)
    pos = _stacked(dom, 4, 100, seed=2)
    p = plan(dom, make_lennard_jones(), m_c=16, strategy=strategy,
             backend=backend, interpret=True)
    fb, _ = p.execute_batch(ParticleState(pos))
    f_loop = jnp.stack([p.execute(ParticleState(pos[i]))[0]
                        for i in range(4)])
    np.testing.assert_allclose(np.asarray(fb), np.asarray(f_loop),
                               rtol=3e-4, atol=3e-4)


def test_execute_batch_carries_fields():
    dom = Domain.cubic(3, cutoff=1.0)
    pos = _stacked(dom, 3, 80)
    mass = jnp.ones(pos.shape[:2])
    p = plan(dom, make_lennard_jones(), m_c=16, strategy="xpencil")
    fb, _ = p.execute_batch(ParticleState(pos, {"mass": mass}))
    f0, _ = p.execute_batch(ParticleState(pos))
    np.testing.assert_array_equal(np.asarray(fb), np.asarray(f0))


def test_executor_caches_are_bounded_and_clearable():
    # the autotuner churns through throwaway plans; traces must be evictable
    assert api._executor.cache_info().maxsize == 128
    assert api._batch_executor.cache_info().maxsize == 32
    dom = Domain.cubic(3)
    p = plan(dom, make_lennard_jones(), m_c=8, strategy="xpencil")
    p.execute(ParticleState(dom.sample_uniform(jax.random.PRNGKey(0), 50)))
    assert api._executor.cache_info().currsize >= 1
    clear_executor_cache()
    assert api._executor.cache_info().currsize == 0
    assert api._batch_executor.cache_info().currsize == 0


# ---------------------------------------------------------------------------
# static caching / shims
# ---------------------------------------------------------------------------

def test_plans_are_hashable_and_cache_by_value():
    dom = Domain.cubic(3)
    p1 = plan(dom, make_lennard_jones(), m_c=8, strategy="xpencil")
    p2 = plan(Domain.cubic(3), make_lennard_jones(), m_c=8,
              strategy="xpencil")
    assert p1 == p2 and hash(p1) == hash(p2)
    assert hash(make_lennard_jones()) == hash(make_lennard_jones())
    assert make_lennard_jones(sigma=0.3) != make_lennard_jones()


def test_particle_state_carries_fields_through_binning():
    dom, pos, m_c = _case(3, 100)
    state = ParticleState(pos, {"mass": jnp.ones(pos.shape[0])})
    p = plan(dom, make_lennard_jones(), m_c=m_c, strategy="xpencil")
    bins = p.bin(state)
    assert "mass" in bins.planes
    f, _ = p.execute(state)
    f_ref, _ = p.execute(ParticleState(pos))
    np.testing.assert_allclose(np.asarray(f), np.asarray(f_ref))


def test_engine_shim_delegates_to_plan():
    dom, pos, m_c = _case(3, 150)
    eng = CellListEngine(dom, m_c=m_c, strategy="xpencil")
    assert isinstance(eng.plan, InteractionPlan)
    f_eng, p_eng = eng.compute(pos)
    f_plan, p_plan = eng.plan.execute(ParticleState(pos))
    np.testing.assert_allclose(np.asarray(f_eng), np.asarray(f_plan))
    f_fn, _ = compute_interactions(dom, pos, m_c=m_c, strategy="xpencil")
    np.testing.assert_allclose(np.asarray(f_fn), np.asarray(f_plan))


def test_engine_shim_pallas_backend():
    dom, pos, m_c = _case(3, 150)
    eng = CellListEngine(dom, m_c=m_c, strategy="xpencil", backend="pallas")
    f, _ = eng.compute(pos)
    f_ref, _ = CellListEngine(dom, m_c=m_c, strategy="xpencil").compute(pos)
    np.testing.assert_allclose(np.asarray(f), np.asarray(f_ref),
                               rtol=3e-4, atol=3e-4)


def test_suggest_m_c_always_sublane_aligned():
    # regression: values <= align used to be returned unrounded, violating
    # the alignment assumption documented in kernels/xpencil.py
    dom = Domain.cubic(6, cutoff=1.0)
    pos = dom.sample_uniform(jax.random.PRNGKey(0), 40)   # sparse: tiny max
    m_c = suggest_m_c(dom, pos)
    assert m_c % 8 == 0 and m_c >= 8
    pos2 = dom.sample_uniform(jax.random.PRNGKey(0), 4000)
    assert suggest_m_c(dom, pos2) % 8 == 0
