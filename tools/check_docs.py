"""Docs gate: dead intra-repo links + runnable README quickstart.

Two checks, both used by the CI docs job and unit-tested in
``tests/test_docs.py``:

  ``--links FILE...``       every relative markdown link target
                            (``[text](path)`` / ``[text](path#anchor)``)
                            must exist on disk. External links
                            (http/https/mailto) are skipped — the gate is
                            about *intra-repo* rot, not the internet.
  ``--quickstart FILE``     extract the first fenced ```python block and
                            ``exec`` it — the README's quickstart must
                            actually run, not just read well.

Exit code 0 when every requested check passes, 1 otherwise, with one line
per failure on stderr.
"""

from __future__ import annotations

import argparse
import pathlib
import re
import sys
from typing import List, Tuple

# [text](target) — target captured up to the closing paren; images too
_LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)\)")
_EXTERNAL = ("http://", "https://", "mailto:")
_PY_BLOCK_RE = re.compile(r"```python\n(.*?)```", re.DOTALL)


def markdown_links(path: pathlib.Path) -> List[str]:
    """All link targets in a markdown file (anchors kept)."""
    return _LINK_RE.findall(path.read_text())


def check_links(paths: List[pathlib.Path]) -> List[Tuple[str, str]]:
    """-> [(file, broken target)] for every relative link whose file part
    does not exist (resolved against the linking file's directory)."""
    broken: List[Tuple[str, str]] = []
    for path in paths:
        for target in markdown_links(path):
            if target.startswith(_EXTERNAL):
                continue
            file_part = target.split("#", 1)[0]
            if not file_part:          # pure in-page anchor (#section)
                continue
            if not (path.parent / file_part).exists():
                broken.append((str(path), target))
    return broken


def first_python_block(path: pathlib.Path) -> str:
    """The first fenced ```python block of a markdown file."""
    m = _PY_BLOCK_RE.search(path.read_text())
    if not m:
        raise ValueError(f"{path}: no ```python block found")
    return m.group(1)


def run_quickstart(path: pathlib.Path) -> None:
    """Exec the first python block (raises on failure)."""
    code = first_python_block(path)
    exec(compile(code, f"{path}:quickstart", "exec"), {"__name__": "__qs__"})


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--links", nargs="+", metavar="FILE", default=[],
                    help="markdown files whose relative links must resolve")
    ap.add_argument("--quickstart", metavar="FILE", default=None,
                    help="markdown file whose first ```python block must run")
    args = ap.parse_args(argv)

    rc = 0
    if args.links:
        broken = check_links([pathlib.Path(p) for p in args.links])
        for src, target in broken:
            print(f"check_docs: DEAD LINK {target!r} in {src}",
                  file=sys.stderr)
        if broken:
            rc = 1
        else:
            print(f"check_docs: links OK in {len(args.links)} file(s)")
    if args.quickstart:
        try:
            run_quickstart(pathlib.Path(args.quickstart))
            print(f"check_docs: quickstart OK ({args.quickstart})")
        except Exception as e:  # noqa: BLE001 — report, fail the gate
            print(f"check_docs: QUICKSTART FAILED ({args.quickstart}): "
                  f"{e!r}", file=sys.stderr)
            rc = 1
    return rc


if __name__ == "__main__":
    sys.exit(main())
