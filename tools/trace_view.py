"""Trace viewer: JSONL span exports -> Chrome trace_event + a summary.

The tracer (``repro.obs.trace``) exports its ring buffer two ways: raw
JSONL (one span/event record per line, seconds since enable) and Chrome's
``trace_event`` JSON (microseconds, loadable in ``chrome://tracing`` /
Perfetto). Benchmarks emit both as sidecars; this tool works on the JSONL
form after the fact::

    python tools/trace_view.py RUN.trace.jsonl                 # summary
    PYTHONPATH=src python tools/trace_view.py RUN.trace.jsonl \
        --chrome OUT.json                  # needs repro for the converter
    python tools/trace_view.py RUN.trace.jsonl --name serve.dispatch

The summary aggregates complete spans (``ph == "X"``) per name: count,
total/mean/max duration in ms — the quick "where did the time go" read
without leaving the terminal. ``--name`` filters both the summary and the
conversion to spans whose name contains the substring. Instant events
(``ph == "i"``) are listed by count only; they carry no duration.

Exit code 0 on success, 1 on an unreadable or empty input file.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
from typing import Dict, List


def load_jsonl(path: str | pathlib.Path) -> List[dict]:
    """-> span/event records; malformed lines are skipped with a warning
    (a truncated trace from a killed run should still mostly render)."""
    records: List[dict] = []
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                records.append(json.loads(line))
            except json.JSONDecodeError:
                print(f"trace_view: {path}:{lineno}: skipping malformed "
                      "line", file=sys.stderr)
    return records


def summarize(records: List[dict]) -> str:
    """-> per-name duration table (spans) + event counts, as text."""
    spans: Dict[str, List[float]] = {}
    events: Dict[str, int] = {}
    for r in records:
        name = r.get("name", "?")
        if r.get("ph") == "X":
            spans.setdefault(name, []).append(float(r.get("dur", 0.0)))
        else:
            events[name] = events.get(name, 0) + 1
    lines = ["name,count,total_ms,mean_ms,max_ms"]
    for name in sorted(spans, key=lambda n: -sum(spans[n])):
        ds = spans[name]
        total = sum(ds)
        lines.append(f"{name},{len(ds)},{total * 1e3:.3f},"
                     f"{total / len(ds) * 1e3:.3f},{max(ds) * 1e3:.3f}")
    if events:
        lines.append("# events")
        for name in sorted(events):
            lines.append(f"{name},{events[name]},-,-,-")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("trace", help="JSONL span export (obs.export_jsonl)")
    ap.add_argument("--chrome", metavar="PATH", default=None,
                    help="also write the Chrome trace_event conversion")
    ap.add_argument("--name", default=None,
                    help="only spans/events whose name contains this")
    args = ap.parse_args(argv)

    try:
        records = load_jsonl(args.trace)
    except OSError as e:
        print(f"trace_view: {e}", file=sys.stderr)
        return 1
    if args.name:
        records = [r for r in records if args.name in r.get("name", "")]
    if not records:
        print(f"trace_view: no records in {args.trace}"
              + (f" matching {args.name!r}" if args.name else ""),
              file=sys.stderr)
        return 1

    print(summarize(records))
    if args.chrome:
        from repro.obs import chrome_events
        payload = {"traceEvents": chrome_events(records),
                   "displayTimeUnit": "ms"}
        with open(args.chrome, "w") as f:
            json.dump(payload, f)
        print(f"wrote {len(payload['traceEvents'])} trace events to "
              f"{args.chrome}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
