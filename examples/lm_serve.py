"""Serve a small model with batched requests: prefill + greedy decode.

    PYTHONPATH=src python examples/lm_serve.py --arch mamba2-130m

Shows the serving path the decode_32k / long_500k dry-run cells lower:
batched prefill, KV/state cache, one-token decode steps.
"""

import argparse
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models import model as M
from repro.models.serving import generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--new-tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size, jnp.int32)
    extras = {}
    if cfg.family == "vlm":
        extras["patch_embeds"] = 0.02 * jax.random.normal(
            jax.random.PRNGKey(2),
            (args.batch, cfg.n_img_tokens, cfg.d_model), jnp.float32)
    if cfg.n_enc_layers:
        extras["frame_embeds"] = 0.02 * jax.random.normal(
            jax.random.PRNGKey(2), (args.batch, cfg.enc_seq, cfg.d_model),
            jnp.float32)

    t0 = time.time()
    tokens, _ = generate(cfg, params, prompts, args.new_tokens, **extras)
    dt = time.time() - t0
    print(f"arch={cfg.name} batch={args.batch} "
          f"prompt={args.prompt_len} new={args.new_tokens}")
    print(f"generated in {dt:.2f}s "
          f"({args.batch * args.new_tokens / dt:.1f} tok/s batched)")
    for b in range(args.batch):
        print(f"  req {b}: {tokens[b].tolist()}")


if __name__ == "__main__":
    main()
