"""Measured autotuning + batched execution through the plan/execute API.

    PYTHONPATH=src python examples/autotune_batch.py

Part 1 — autotune: instead of trusting the analytical traffic model
(``strategy="auto"``), ``strategy="autotune"`` enumerates candidate
(strategy, backend, batch_size, m_c, sub-box) configurations, prunes them
with the model, *times* the survivors with a compile-excluded stopwatch,
and returns the empirically fastest plan. The winner is cached on disk, so
the second planning call does zero timing runs.

Part 2 — batched execution: ``execute_batch`` vmaps one plan over B
independent stacked systems (the paper's few-particles-per-cell regime) in
a single jitted dispatch instead of B.
"""

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (Domain, ParticleState, dispatch_count,
                        make_lennard_jones, plan, tune)


def main():
    domain = Domain.cubic(division=4, cutoff=1.0)
    kernel = make_lennard_jones(sigma=0.2)
    positions = domain.sample_uniform(jax.random.PRNGKey(0), 500)

    # -- part 1: measured autotuning -------------------------------------
    result = tune(domain, kernel, positions)
    print(f"timed {len(result.timings)} candidates "
          f"({len(result.pruned)} pruned by the traffic model):")
    for cand, secs in sorted(result.timings.items(), key=lambda kv: kv[1]):
        mark = "  <- winner" if cand == result.candidate else ""
        print(f"  {cand.strategy:11s} {cand.backend:9s} "
              f"bs={cand.batch_size:<4d} m_c={cand.m_c:<4d} "
              f"{secs * 1e6:9.1f} us{mark}")

    # same regime through the front door: backend="all" defers to the same
    # platform-default backend set tune() used, so this is served from the
    # on-disk cache — zero timing runs this time
    p = plan(domain, kernel, positions=positions, strategy="autotune",
             backend="all")
    assert p == result.plan
    print(f'plan(strategy="autotune") -> "{p.strategy}" '
          f"(cached in {result.cache_file})")

    # -- part 2: batched execution ---------------------------------------
    B, N = 8, 200
    keys = jax.random.split(jax.random.PRNGKey(1), B)
    stacked = jnp.stack([domain.sample_uniform(k, N) for k in keys])
    pbatch = plan(domain, kernel, positions=stacked[0], strategy="xpencil")

    before = dispatch_count()
    forces, pot = pbatch.execute_batch(ParticleState(stacked))
    batched_dispatches = dispatch_count() - before

    loop = [pbatch.execute(ParticleState(stacked[i])) for i in range(B)]
    f_loop = jnp.stack([f for f, _ in loop])
    np.testing.assert_array_equal(np.asarray(forces), np.asarray(f_loop))
    print(f"execute_batch: {B} systems x {N} particles in "
          f"{batched_dispatches} dispatch (loop: {B}), bit-identical.")


if __name__ == "__main__":
    main()
