"""Quickstart: cutoff pair interactions through every schedule.

    PYTHONPATH=src python examples/quickstart.py

Builds the paper's benchmark scene (uniform particles, LJ kernel, cell width
= cutoff), runs all five schedules including the two proposed in the paper
(All-in-SM, X-pencil) and the Pallas TPU kernels (interpret mode on CPU),
and cross-checks them against the O(N^2) oracle.
"""

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (CellListEngine, Domain, bin_particles,
                        make_lennard_jones, suggest_m_c)
from repro.kernels import allin_interactions, xpencil_interactions


def main():
    domain = Domain.cubic(division=6, cutoff=1.0)
    key = jax.random.PRNGKey(0)
    positions = domain.sample_uniform(key, 2_000)
    kernel = make_lennard_jones(sigma=0.2)
    m_c = suggest_m_c(domain, positions)
    print(f"grid {domain.ncells}, N={positions.shape[0]}, M_C={m_c}")

    f_ref, pot_ref = CellListEngine(domain, kernel, m_c=m_c,
                                    strategy="naive_n2").compute(positions)
    e_ref = 0.5 * float(jnp.sum(pot_ref))
    fscale = float(jnp.max(jnp.abs(f_ref)))
    print(f"naive_n2      : E = {e_ref:+.4e} (oracle)")

    for strategy in ("par_part", "cell_dense", "xpencil", "allin"):
        eng = CellListEngine(domain, kernel, m_c=m_c, strategy=strategy)
        forces, pot = eng.compute(positions)
        err = float(jnp.max(jnp.abs(forces - f_ref))) / fscale
        print(f"{strategy:14s}: E = {0.5 * float(jnp.sum(pot)):+.4e} "
              f"rel|dF| = {err:.2e}")

    bins = bin_particles(domain, positions, m_c=m_c)
    f, pot = xpencil_interactions(domain, bins, kernel)
    print(f"pallas xpencil: E = {0.5 * float(jnp.sum(pot)):+.4e} "
          f"rel|dF| = {float(jnp.max(jnp.abs(f - f_ref))) / fscale:.2e} "
          f"(interpret mode)")
    f, pot = allin_interactions(domain, bins, kernel, (2, 2, 2))
    print(f"pallas allin  : E = {0.5 * float(jnp.sum(pot)):+.4e} "
          f"rel|dF| = {float(jnp.max(jnp.abs(f - f_ref))) / fscale:.2e} "
          f"(interpret mode)")

    np.testing.assert_allclose(np.asarray(f) / fscale,
                               np.asarray(f_ref) / fscale,
                               rtol=3e-4, atol=3e-4)
    print("all schedules agree.")


if __name__ == "__main__":
    main()
