"""Quickstart: cutoff pair interactions through the plan/execute API.

    PYTHONPATH=src python examples/quickstart.py

Builds the paper's benchmark scene (uniform particles, LJ kernel, cell width
= cutoff), plans every schedule x backend combination — including the two
proposed in the paper (All-in-SM, X-pencil) as Pallas TPU kernels (interpret
mode on CPU) — and cross-checks all of them against the O(N^2) oracle
through the same ``plan(...).execute(state)`` front door.
"""

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (Domain, ParticleState, backend_matrix,
                        make_lennard_jones, plan, supports_layout)


def main():
    domain = Domain.cubic(division=6, cutoff=1.0)
    key = jax.random.PRNGKey(0)
    positions = domain.sample_uniform(key, 2_000)
    kernel = make_lennard_jones(sigma=0.2)
    state = ParticleState(positions)

    # one-off static planning: measures M_C, and "auto" picks the schedule
    # with the least modelled HBM traffic per interaction
    auto = plan(domain, kernel, positions=positions, strategy="auto")
    print(f"grid {domain.ncells}, N={positions.shape[0]}, M_C={auto.m_c}, "
          f'auto -> "{auto.strategy}"')

    oracle = plan(domain, kernel, m_c=auto.m_c, strategy="naive_n2")
    f_ref, pot_ref = oracle.execute(state)
    e_ref = 0.5 * float(jnp.sum(pot_ref))
    fscale = float(jnp.max(jnp.abs(f_ref)))
    print(f"naive_n2 oracle          : E = {e_ref:+.4e}")

    for backend, strategies in sorted(backend_matrix().items()):
        for strategy in strategies:
            # some pairs exist only under a non-dense layout (the pallas
            # cell_dense runner is the sfc cluster kernel)
            layout = ("dense" if supports_layout(backend, strategy, "dense")
                      else "sfc")
            p = plan(domain, kernel, m_c=auto.m_c, strategy=strategy,
                     backend=backend, layout=layout, positions=positions,
                     interpret=True)
            forces, pot = p.execute(state)
            err = float(jnp.max(jnp.abs(forces - f_ref))) / fscale
            tag = strategy if layout == "dense" else f"{strategy}/{layout}"
            print(f"{backend:9s} {tag:14s}: "
                  f"E = {0.5 * float(jnp.sum(pot)):+.4e} rel|dF| = {err:.2e}")
            np.testing.assert_allclose(np.asarray(forces) / fscale,
                                       np.asarray(f_ref) / fscale,
                                       rtol=3e-4, atol=3e-4)

    # the M_C safety net: many executes, replan only when a cell overflows
    (forces, _), p2 = auto.execute_or_replan(state)
    assert p2 is auto, "uniform scene should not need a replan"
    print("all schedules x backends agree; overflow check passed.")


if __name__ == "__main__":
    main()
