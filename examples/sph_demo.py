"""SPH demo: weakly-compressible settling column (paper §8's target domain).

    PYTHONPATH=src python examples/sph_demo.py

SPH is the paper's motivating application (30-40 neighbors/particle = few
particles per cell). The density loop and pressure forces both run through
the plan/execute API's X-pencil schedule (``repro.physics.sph`` plans once
per static config and executes per step; pass ``backend="pallas"`` to the
sph functions to serve the sums from the Pallas kernels).
"""

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp

from repro.core import Domain, suggest_m_c
from repro.physics.sph import SPHParams, density, pressure, sph_step


def main():
    domain = Domain.cubic(6, cutoff=1.0)
    key = jax.random.PRNGKey(0)
    # a block of fluid in the lower half of the box
    n = 4_000
    pos = domain.sample_uniform(key, n)
    pos = pos.at[:, 2].multiply(0.5)
    vel = jnp.zeros_like(pos)
    params = SPHParams(h=1.0, rho0=float(n) / (6 ** 3 / 2), c0=10.0,
                       mass=1.0)
    m_c = max(24, suggest_m_c(domain, pos))

    rho = density(domain, pos, params, m_c)
    print(f"N={n}, M_C={m_c}")
    print(f"initial density: mean={float(rho.mean()):.3f} "
          f"min={float(rho.min()):.3f} max={float(rho.max()):.3f}")
    p = pressure(rho, params)
    print(f"initial pressure: mean={float(p.mean()):.3f}")

    step = jax.jit(lambda pos, vel: sph_step(domain, pos, vel, params, m_c,
                                             dt=2e-3))
    for it in range(30):
        pos, vel, rho = step(pos, vel)
        if it % 5 == 0:
            print(f"  step {it:3d}: <rho>={float(rho.mean()):8.3f}  "
                  f"max|v|={float(jnp.max(jnp.abs(vel))):.4f}  "
                  f"z-center={float(pos[:, 2].mean()):.3f}")
    print("done (densities stay finite and bounded -> neighbor loops are "
          "consistent under motion)")


if __name__ == "__main__":
    main()
