"""Multi-device MD: spatial domain decomposition with halo exchange.

    PYTHONPATH=src python examples/distributed_md.py [--devices 4]

Runs the distributed particle engine (shard_map + ppermute ghost planes, the
multi-pod version of the paper's grid) on emulated host devices and checks
it against the single-device engine. On a real pod the same code shards over
the physical mesh.
"""

import argparse
import os
import pathlib
import sys

ap = argparse.ArgumentParser()
ap.add_argument("--devices", type=int, default=4)
args = ap.parse_args()
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           f" --xla_force_host_platform_device_count="
                           f"{args.devices}")
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CellListEngine, Domain, make_lennard_jones, suggest_m_c
from repro.dist.halo import make_distributed_compute, partition_by_z


def main():
    n_dev = args.devices
    mesh = jax.make_mesh((n_dev,), ("data",))
    domain = Domain.cubic(8, cutoff=1.0, periodic=True)
    key = jax.random.PRNGKey(0)
    positions = domain.sample_uniform(key, 4_000)
    kernel = make_lennard_jones()
    m_c = suggest_m_c(domain, positions)

    print(f"{n_dev} devices, grid {domain.ncells} split along Z "
          f"({domain.nz // n_dev} planes/shard), N={positions.shape[0]}")

    f_ref, _ = CellListEngine(domain, kernel, m_c=m_c,
                              strategy="xpencil").compute(positions)
    pos_part = partition_by_z(domain, positions, n_dev)
    dist_fn = make_distributed_compute(domain, kernel, m_c, mesh)
    forces, pot = dist_fn(pos_part)

    ref = {tuple(np.round(np.asarray(positions)[i], 5)): i
           for i in range(positions.shape[0])}
    pp, fn = np.asarray(pos_part), np.asarray(forces)
    err = 0.0
    checked = 0
    for j in range(pp.shape[0]):
        if pp[j, 0] > 1e7:
            continue
        i = ref[tuple(np.round(pp[j], 5))]
        err = max(err, float(np.abs(fn[j] - np.asarray(f_ref)[i]).max()))
        checked += 1
    print(f"checked {checked} particles across shards; "
          f"max |F_dist - F_single| = {err:.2e}")
    assert checked == positions.shape[0] and err < 1e-3
    print("halo-exchange engine matches the single-device engine.")


if __name__ == "__main__":
    main()
