"""Multi-device MD: the distributed halo backend through the plan API.

    PYTHONPATH=src python examples/distributed_md.py [--devices 4]

``plan(..., backend="halo")`` Z-slab-partitions the domain across the
devices, exchanges ghost planes via ppermute (the multi-pod version of the
paper's grid), runs the chosen schedule per shard, and returns forces in
ordinary particle order — same contract as every other backend. On a real
pod the same code shards over the physical mesh; here the devices are
emulated host devices. Compare against the single-device reference and
against the compacted per-shard path.
"""

import argparse
import os
import pathlib
import sys

ap = argparse.ArgumentParser()
ap.add_argument("--devices", type=int, default=4)
args = ap.parse_args()
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           f" --xla_force_host_platform_device_count="
                           f"{args.devices}")
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import numpy as np

from repro.core import Domain, ParticleState, make_lennard_jones, plan


def main():
    n_dev = jax.device_count()
    domain = Domain.cubic(8, cutoff=1.0, periodic=True)
    key = jax.random.PRNGKey(0)
    positions = domain.sample_uniform(key, 4_000)
    kernel = make_lennard_jones()
    state = ParticleState(positions)

    p_halo = plan(domain, kernel, positions=positions, strategy="xpencil",
                  backend="halo")
    print(f"{n_dev} devices, grid {domain.ncells} split into "
          f"{p_halo.n_shards} Z-slabs ({domain.nz // p_halo.n_shards} "
          f"planes/shard, cap {p_halo.shard_cap}), N={positions.shape[0]}")

    p_ref = plan(domain, kernel, m_c=p_halo.m_c, strategy="xpencil")
    f_ref, _ = p_ref.execute(state)
    forces, pot = p_halo.execute(state)

    err = float(np.abs(np.asarray(forces) - np.asarray(f_ref)).max())
    scale = float(np.abs(np.asarray(f_ref)).max())
    print(f"max |F_halo - F_single| = {err:.2e} (|F|_max = {scale:.2e})")
    assert err <= 3e-4 * max(scale, 1.0)

    # the compacted per-shard path: same forces, only active pencils staged
    p_comp = p_halo if p_halo.n_shards == 1 else plan(
        domain, kernel, m_c=p_halo.m_c, positions=positions,
        strategy="xpencil", backend="halo", compact=True)
    f_comp, _ = p_comp.execute(state)
    same = np.array_equal(np.asarray(forces), np.asarray(f_comp))
    print(f"compacted shards (max_active={p_comp.max_active}) "
          f"bit-identical to dense shards: {same}")
    assert same

    # overflow contract survives distribution: shrink the shard capacity
    # and let execute_or_replan grow it back
    if p_halo.n_shards > 1:
        import dataclasses
        tight = dataclasses.replace(p_halo, shard_cap=8)
        assert tight.check_overflow(state)
        (f2, _), grown = tight.execute_or_replan(state)
        print(f"shard_cap overflow replanned: 8 -> {grown.shard_cap}; "
              f"forces match: "
              f"{np.array_equal(np.asarray(f2), np.asarray(forces))}")

    print("halo backend matches the single-device engine.")


if __name__ == "__main__":
    main()
