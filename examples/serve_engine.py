"""Serving-tier demo: the continuous-batching front door end to end.

    PYTHONPATH=src python examples/serve_engine.py [--requests 40]

Feeds a ServingEngine a stream of interaction requests of varying size and
scene (drawn from the scenario family), lets the engine bucket them into
shape classes and dispatch batched executions, then prints the per-class
routing and the latency/throughput snapshot. The stream runs twice: the
first pass builds plans and traces executors (and grows bounds for the
clustered scenes), the second demonstrates the steady state — the
recompile counter stays at zero.
"""

import argparse
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import numpy as np

from repro.core import Domain, ParticleState, recompile_count, scenarios
from repro.serve import ServeMetrics, ServingEngine

SCENES = ["uniform", "gaussian_blob", "two_phase"]
SIZES = [50, 60, 100, 200]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=40)
    ap.add_argument("--division", type=int, default=4)
    ap.add_argument("--max-batch", type=int, default=4)
    args = ap.parse_args()

    dom = Domain.cubic(args.division, cutoff=1.0)
    eng = ServingEngine(max_batch=args.max_batch, max_wait=0.0)

    rng = np.random.default_rng(0)
    stream = []
    for i in range(args.requests):
        n = SIZES[rng.integers(len(SIZES))]
        scene = SCENES[rng.integers(len(SCENES))]
        pos = scenarios.sample(scene, dom, jax.random.PRNGKey(1000 + i), n)
        stream.append(ParticleState(pos))

    def run_stream():
        for state in stream:
            eng.submit(dom, state)
        eng.flush()
        return eng.take_responses()

    run_stream()                              # warmup: plans, traces, bounds
    for state in stream:
        eng.prewarm(dom, state)               # cover part-full batch shapes
    rc_warm = recompile_count()
    eng.metrics = ServeMetrics()              # report the steady state only
    responses = run_stream()

    by_class = {}
    for r in responses:
        by_class.setdefault(r.shape_class, []).append(r)
    print(f"{args.requests} requests -> {len(by_class)} shape classes:")
    for label, rs in sorted(by_class.items()):
        print(f"  {label}: {len(rs)} served")
    snap = eng.metrics.snapshot()
    print(f"batches={snap['batches']} "
          f"batch_fill={snap['batch_fill']:.2f} "
          f"replans={snap['replans']}")
    print(f"p50={snap['total_latency']['p50_s'] * 1e3:.2f}ms "
          f"p99={snap['total_latency']['p99_s'] * 1e3:.2f}ms "
          f"rps={snap['rps']:.1f}")
    print(f"recompiles in steady state: {recompile_count() - rc_warm}")


if __name__ == "__main__":
    main()
