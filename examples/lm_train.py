"""Train a reduced LM config for a few hundred steps (CPU-runnable).

    PYTHONPATH=src python examples/lm_train.py --arch gemma2-2b --steps 200

Uses the same launcher internals as the production path (checkpoint every K
steps, deterministic data cursor, restart-safe); pick any of the 10 assigned
architectures — the smoke-sized variant of that family is trained.
"""

import argparse
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()
    train_main(["--arch", args.arch, "--smoke", "--steps", str(args.steps),
                "--batch", "8", "--seq", "64", "--ckpt-dir",
                "/tmp/repro_lm_ckpt", "--log-every", "20"])


if __name__ == "__main__":
    main()
