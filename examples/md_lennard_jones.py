"""End-to-end driver: Lennard-Jones MD, a few hundred steps.

    PYTHONPATH=src python examples/md_lennard_jones.py [--steps 300]

The paper's kind of workload run end to end: plan once -> bin -> X-pencil
interactions -> velocity-Verlet, under jit (lax.scan over steps), reporting
energy conservation — the physical correctness check for the whole stack.
"""

import argparse
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp

from repro.core import (Domain, ParticleState, make_lennard_jones, plan,
                        suggest_m_c)
from repro.physics import init_state, run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--division", type=int, default=5)
    ap.add_argument("--ppc", type=int, default=8)
    ap.add_argument("--dt", type=float, default=1e-4)
    ap.add_argument("--strategy", default="xpencil")
    ap.add_argument("--backend", default="reference",
                    choices=["reference", "pallas"])
    args = ap.parse_args()

    domain = Domain.cubic(args.division, cutoff=1.0, periodic=True)
    n = args.division ** 3 * args.ppc
    key = jax.random.PRNGKey(0)
    positions = domain.sample_uniform(key, n)
    velocities = 0.05 * jax.random.normal(jax.random.PRNGKey(1),
                                          positions.shape)

    kernel = make_lennard_jones(sigma=0.25, eps=1.0, softening=1e-4)
    m_c = max(16, suggest_m_c(domain, positions))
    p = plan(domain, kernel, m_c=m_c, strategy=args.strategy,
             backend=args.backend)

    # relaxation: uniform-random placement overlaps particles inside the LJ
    # core; descend along clipped forces first (standard MD minimization)
    # so the dynamics start from a physical configuration.
    box = jnp.asarray(domain.box)
    for _ in range(60):
        f, _ = p.execute(ParticleState(positions))
        step_vec = jnp.clip(f, -1.0, 1.0) * 2e-3
        positions = jnp.mod(positions + step_vec, box)
    state = init_state(p, positions, velocities)

    print(f"N={n} particles, grid {domain.ncells}, M_C={m_c}, "
          f"strategy={args.strategy}, backend={args.backend}")
    t0 = time.time()
    final, traces = run(p, state, n_steps=args.steps, dt=args.dt)
    jax.block_until_ready(final.positions)
    dt_wall = time.time() - t0

    e = traces["total"]
    e0, e1 = float(e[0]), float(e[-1])
    drift = abs(e1 - e0) / (abs(e0) + 1e-12)
    print(f"{args.steps} steps in {dt_wall:.2f}s "
          f"({args.steps * n / dt_wall:,.0f} particle-steps/s)")
    for i in range(0, args.steps, max(1, args.steps // 10)):
        print(f"  step {i:4d}: E_tot={float(e[i]):+.5f} "
              f"KE={float(traces['kinetic'][i]):.5f} "
              f"PE={float(traces['potential'][i]):+.5f}")
    print(f"energy drift over run: {drift:.3e} "
          f"({'OK' if drift < 0.05 else 'HIGH'})")


if __name__ == "__main__":
    main()
