from .adam import AdamConfig, adam_update, global_norm, init_opt_state, lr_at

__all__ = ["AdamConfig", "adam_update", "global_norm", "init_opt_state",
           "lr_at"]
