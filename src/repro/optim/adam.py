"""AdamW in pure JAX (no optax in this environment).

Moments live in a pytree mirroring params; ``moment_dtype`` is a config knob
(fp32 default; the 314B/480B configs use bf16 moments so params+moments+grads
fit v5e HBM — recorded in DESIGN.md). The update math always runs in fp32.
Optimizer state is sharded exactly like the params (dist.sharding), i.e.
ZeRO-style: no replica holds a full moment tensor.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

Array = jnp.ndarray
PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"
    schedule: str = "cosine"         # cosine | constant
    warmup_steps: int = 100
    total_steps: int = 10_000


def init_opt_state(params: PyTree, cfg: AdamConfig) -> PyTree:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def lr_at(cfg: AdamConfig, step: Array) -> Array:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / max(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        return cfg.lr * warm
    frac = jnp.clip((s - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree: PyTree) -> Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def adam_update(params: PyTree, grads: PyTree, state: PyTree,
                cfg: AdamConfig) -> Tuple[PyTree, PyTree]:
    """-> (new_params, new_state). Everything fp32 internally."""
    step = state["step"] + 1
    if cfg.grad_clip > 0:
        gn = global_norm(grads)
        scale = jnp.minimum(1.0, cfg.grad_clip / (gn + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

    lr = lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        mf = m.astype(jnp.float32) * b1 + gf * (1.0 - b1)
        vf = v.astype(jnp.float32) * b2 + gf * gf * (1.0 - b2)
        update = (mf / c1) / (jnp.sqrt(vf / c2) + cfg.eps)
        pf = p.astype(jnp.float32)
        if cfg.weight_decay > 0 and p.ndim >= 2:   # no decay on norms/scalars
            update = update + cfg.weight_decay * pf
        return ((pf - lr * update).astype(p.dtype),
                mf.astype(mdt), vf.astype(mdt))

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}
