"""Fault-tolerant fused trajectory engine (ROADMAP item 1).

Front door: ``InteractionPlan.trajectory(state, n_steps, dt, ...)`` —
see :mod:`repro.traj.engine` for the Verlet-skin / checkpoint / rollback
contract and :mod:`repro.traj.monitors` for the invariant glossary.
"""

from .engine import (DEFAULT_SKIN_FRACTION, INTEGRATORS, TRAJ_STRATEGIES,
                     TrajCarry, TrajectoryResult, reference_step,
                     run_trajectory, trajectory_plan)
from .monitors import MonitorState, classify_breach, init_monitors

__all__ = [
    "DEFAULT_SKIN_FRACTION", "INTEGRATORS", "TRAJ_STRATEGIES",
    "TrajCarry", "TrajectoryResult", "MonitorState", "classify_breach",
    "init_monitors", "reference_step", "run_trajectory", "trajectory_plan",
]
