"""Fault-tolerant trajectory engine: fused multi-step simulation.

The tentpole of ROADMAP item 1. A per-step ``plan.execute`` loop pays a
full binning (and pack) pass plus a Python dispatch every timestep; this
engine fuses bin -> force -> integrate under one jitted ``lax.scan`` and
amortizes the binning with a Verlet-skin contract:

* the trajectory runs on a *skin-padded* grid (``domain.skin_domain``:
  cell width >= cutoff + skin, same cutoff — pair masks are unchanged, so
  results stay pair-complete for the true cutoff),
* bins are built once and their slot assignment reused; each step only
  *refreshes* slot contents in place (``binning.refresh_bins``),
* a traced predicate (``binning.max_displacement`` against the measured
  ``skin / 2``) re-bins inside the scan (``lax.cond``) only when drift
  has eaten the margin.

``skin = 0`` is the always-rebin limit: the grid is the plan's own and a
rebin fires whenever anything moved, which makes the fused path
*bit-identical* to the per-step ``plan.execute`` loop (``reference_step``
shares the integrator arithmetic) — the parity gate
``benchmarks/fig_traj.py`` runs before timing anything.

Robustness (the reason this lives in one subsystem): the scan runs in
host-bounded *segments* cut on a fixed absolute grid. Each segment
carries the invariant monitors of ``traj.monitors`` in the scan carry;
at the segment boundary the host

1. classifies breaches (non-finite state, skin thrash, energy drift past
   budget — ``monitors.classify_breach``) and **rolls back** to the last
   committed anchor with a forced rebin, stepping the plan's degradation
   ladder via the PR 7 circuit breaker (``api.plan_health``) on repeated
   failure;
2. applies the grow-only static-bound replan contract when a rebin
   overflowed ``m_c`` / ``row_cap`` / ``max_active`` (a scan cannot
   change static shapes, so overflow is *recorded* by the monitors and
   the bounds are grown between segments, then the segment replayed from
   the anchor — the overflowed segment's results are never committed);
3. checkpoints the whole scan carry ``(MDState, bins, ref, rng,
   monitors)`` through ``repro.ckpt`` (atomic step-dir publish), so a
   killed run resumes **bit-identically**: the carry is checkpointed
   whole and the segment grid is absolute, so a resumed process replays
   exactly the jitted segments the uninterrupted one would have run.

Chaos fault points (``repro.testing.chaos``): ``traj.step`` (error /
delay before a segment, nonfinite on its committed positions),
``traj.checkpoint`` (error — a failed save must never kill the run),
``traj.rebin`` (overflow — forces the replan path), plus ``ckpt.save``
inside the checkpoint writer itself.

Restrictions: trajectories need a cell schedule whose force inputs are
bins (``cell_dense`` / ``xpencil`` / ``allin``) on a single shard —
``par_part`` reads raw positions (stale bins would silently drop its
interactions), ``naive_n2`` bypasses binning, and multi-shard halo plans
re-partition per call; all three raise up front.
"""

from __future__ import annotations

import dataclasses
import functools
import pathlib
from typing import Any, Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..core import api
from ..core.api import InteractionPlan, ParticleState
from ..obs import metrics as _obs_metrics
from ..obs.trace import event as _obs_event, trace as _obs_trace
from ..core.binning import (bin_particles, image_positions, max_displacement,
                            pack_rows, padded_row_counts, pencil_counts,
                            refresh_bins, subbox_counts)
from ..core.domain import Domain, effective_skin, skin_domain
from ..physics.integrators import MDState
from ..testing import chaos
from ..ckpt import checkpoint as _ckpt
from . import monitors as M

# skin-contract + fault-recovery rebins, registry family next to the
# dispatch/recompile/replan counters of core.api
REBIN_TOTAL = "repro_rebin_total"

Array = jnp.ndarray

# Schedules whose backends consume bins (dense or packed) — the only ones
# whose force evaluation can reuse a stale-but-covering bin structure.
TRAJ_STRATEGIES = ("cell_dense", "xpencil", "allin")

INTEGRATORS = ("velocity_verlet", "leapfrog", "langevin")

# Default skin: a quarter cutoff. Small enough that m_c on the coarsened
# grid stays modest in the paper's few-particles-per-cell regime, large
# enough that a cold LJ/SPH system drifts for tens of steps before a rebin.
DEFAULT_SKIN_FRACTION = 0.25

_ALIGN = 8


def _round_up(n: int, align: int = _ALIGN) -> int:
    return -(-int(n) // align) * align


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrajCarry:
    """Everything the fused scan needs between steps — and therefore
    everything a checkpoint must capture for bit-identical resume."""

    md: MDState               # positions/velocities/forces/potential/step
    bins: Any                 # CellBins on the skin grid (slot-reuse anchor)
    ref: Array                # (N, 3) positions the bins were built at
    rng: Array                # jax PRNG key (langevin noise stream)
    rebins: Array             # () int32 in-scan rebin events so far
    mon: M.MonitorState


@dataclasses.dataclass
class TrajectoryResult:
    """What a trajectory run produced and what it took to produce it."""

    state: MDState                     # final committed MD state
    traces: Dict[str, np.ndarray]      # per-step energies (since resume)
    plan: InteractionPlan              # traj plan with any grown bounds
    status: str = "ok"                 # ok | degraded | failed
    steps: int = 0                     # committed steps
    rebins: int = 0                    # in-scan (skin-contract) rebins
    forced_rebins: int = 0             # host-forced rebins (rollback/replan)
    replans: int = 0                   # bound-growth events
    rollbacks: int = 0                 # breach-triggered rollbacks
    retries: int = 0                   # segment re-executions after faults
    checkpoints: int = 0               # committed checkpoint dirs
    resumed_from: Optional[int] = None  # checkpoint step resumed from
    faults: List[str] = dataclasses.field(default_factory=list)
    ladder_level: int = 0              # rung that produced the final state
    eff_skin: float = 0.0              # measured skin margin of the grid


# --------------------------------------------------------------------------
# plan derivation: the skin-padded twin + observed-bound growth
# --------------------------------------------------------------------------


def _check_supported(p: InteractionPlan) -> None:
    if p.strategy not in TRAJ_STRATEGIES:
        raise ValueError(
            f"plan.trajectory needs a cell schedule {TRAJ_STRATEGIES}, got "
            f"{p.strategy!r}: par_part reads raw positions (stale bins "
            "would silently drop its interactions) and naive_n2 bypasses "
            "binning, so neither can reuse a Verlet-skin bin structure")
    if p._multi_shard:
        raise ValueError(
            "plan.trajectory does not run on multi-shard halo plans yet: "
            "the per-call Z-slab re-partition is exactly the cost the "
            "skin contract amortizes away (single-shard halo plans fall "
            "back to their inner backend and work fine)")


def trajectory_plan(base: InteractionPlan, skin: float,
                    positions: Optional[Array] = None,
                    valid: Optional[Array] = None) -> InteractionPlan:
    """The skin-padded twin of ``base``: same kernel / backend / layout on
    the coarsened ``skin_domain`` grid, with static bounds re-measured for
    it (coarser cells hold more particles, so ``m_c`` / ``row_cap`` /
    ``max_active`` must be re-derived, not inherited). Without positions
    to measure against, bounds are scaled by the cell-volume ratio; with
    positions, the replan contract takes over."""
    _check_supported(base)
    dom = skin_domain(base.domain, skin)
    if dom == base.domain:
        return base
    grown = dataclasses.replace(
        base, domain=dom, box=None,
        m_c=_volume_scaled(base.m_c, base.domain, dom),
        row_cap=(None if base.row_cap is None
                 else _volume_scaled(base.row_cap, base.domain, dom)),
        max_active=(None if base.max_active is None
                    else min(base.max_active,
                             api.n_units(dom, base.strategy))))
    if positions is not None:
        state = ParticleState(positions, valid=valid)
        while grown.check_overflow(state):
            grown = grown.replan(state)
    return grown


def _volume_scaled(bound: int, old: Domain, new: Domain) -> int:
    ratio = (float(np.prod(np.asarray(new.cell_width)))
             / max(float(np.prod(np.asarray(old.cell_width))), 1e-30))
    return _round_up(max(1, int(np.ceil(bound * max(ratio, 1.0)))))


def _grow_bounds(p: InteractionPlan, cell_max: int, row_max: int,
                 units: int) -> InteractionPlan:
    """Observed-maxima flavor of the replan contract (see
    ``InteractionPlan.replan`` for the canonical statement): grow only the
    bound the monitors saw exceeded, with slack, aligned, strictly past
    the old value. Used between segments — the scan itself cannot change
    static shapes."""
    q = p
    if cell_max > p.m_c:
        measured = _round_up(max(1, int(cell_max * 1.5 + 0.999)))
        q = dataclasses.replace(q, m_c=max(measured, _round_up(p.m_c + 1)),
                                box=None)
    if p.layout == "packed" and row_max > (p.row_cap or 0):
        measured = _round_up(max(1, int(row_max * 1.25 + 0.999)))
        q = dataclasses.replace(
            q, row_cap=max(measured, _round_up((p.row_cap or 0) + 1)))
    if p.compact and units > (p.max_active or 0):
        total = api.n_units(p.domain, p.strategy, box=q.box)
        measured = _round_up(max(1, int(units * 1.25 + 0.999)))
        grown = max(measured, _round_up((p.max_active or 0) + 1))
        q = dataclasses.replace(q, max_active=min(grown, total))
    return q


# --------------------------------------------------------------------------
# traced pieces: forces against given bins, integrators, fused segment
# --------------------------------------------------------------------------


def _forces(p: InteractionPlan, bins, positions: Array,
            fields: Dict[str, Array], valid: Optional[Array]
            ) -> Tuple[Array, Array]:
    """Backend dispatch against *given* bins — the one divergence from
    ``api._impl``, which always re-bins from the positions."""
    backend = p.halo_inner if p.backend == "halo" else p.backend
    state = ParticleState(positions, fields, valid)
    if p.layout == "packed":
        packed = pack_rows(p.domain, bins, row_cap=p.row_cap)
        return api.get_backend(backend, p.strategy, "packed")(p, packed,
                                                              state)
    return api.get_backend(backend, p.strategy)(p, bins, state)


def _wrap(domain: Domain, positions: Array) -> Array:
    if not domain.any_periodic:
        return positions
    box = jnp.asarray(domain.box, dtype=positions.dtype)
    per = jnp.asarray(domain.periodic_axes)
    return jnp.where(per, jnp.mod(positions, box), positions)


def _bound_probes(p: InteractionPlan, bins) -> Tuple[Array, Array, Array]:
    """Traced maxima the static bounds must cover (monitor inputs)."""
    cell_max = jnp.max(bins.counts)
    row_max = (jnp.max(padded_row_counts(p.domain, bins.counts))
               if p.layout == "packed" else jnp.int32(0))
    if p.compact:
        uc = (subbox_counts(p.domain, bins.counts, p.box)
              if p.strategy == "allin"
              else pencil_counts(p.domain, bins.counts))
        units = jnp.sum(uc > 0).astype(jnp.int32)
    else:
        units = jnp.int32(0)
    return cell_max, row_max, units


def _masked_energies(vel: Array, pot: Array, valid: Optional[Array],
                     mass: float) -> Tuple[Array, Array]:
    if valid is None:
        ke = 0.5 * mass * jnp.sum(vel ** 2)
        pe = 0.5 * jnp.sum(pot)              # pair-counted-twice convention
    else:
        ke = 0.5 * mass * jnp.sum(jnp.where(valid[:, None], vel, 0.0) ** 2)
        pe = 0.5 * jnp.sum(jnp.where(valid, pot, 0.0))
    return ke, pe


def _nofma(x: Array) -> Array:
    """Pin a product so XLA cannot contract it into an FMA with the
    following add. The fused scan body and the per-step baseline compile
    in different surrounding programs; without this, the compiler fuses
    ``v + c*f`` differently in each (observed: 1-ulp velocity drift on
    CPU), breaking the skin=0 bit-parity contract."""
    return jax.lax.optimization_barrier(x)


def _integ_drift(integrator: str, dom: Domain, mass: float, md: MDState,
                 rng: Array, dt: Array, gamma: Array, kT: Array
                 ) -> Tuple[Array, Array, Array]:
    """First half of a step: new positions + staged velocity + rng."""
    half, inv_m = 0.5 / mass, 1.0 / mass
    if integrator == "velocity_verlet":
        v_half = md.velocities + _nofma((half * dt) * md.forces)
        pos = _wrap(dom, md.positions + _nofma(dt * v_half))
        return pos, v_half, rng
    if integrator == "leapfrog":
        vel = md.velocities + _nofma((dt * inv_m) * md.forces)
        pos = _wrap(dom, md.positions + _nofma(dt * vel))
        return pos, vel, rng
    # langevin (BAOAB): B(dt/2) A(dt/2) O(dt) A(dt/2); trailing B(dt/2)
    # happens in _integ_kick. gamma=0 reduces to velocity-Verlet drift.
    v1 = md.velocities + _nofma((half * dt) * md.forces)
    x1 = md.positions + _nofma((0.5 * dt) * v1)
    rng, sub = jax.random.split(rng)
    c1 = jnp.exp(-gamma * dt)
    c2 = jnp.sqrt(jnp.maximum(kT * inv_m, 0.0)
                  * jnp.maximum(1.0 - c1 * c1, 0.0))
    noise = jax.random.normal(sub, md.velocities.shape, md.velocities.dtype)
    v2 = c1 * v1 + _nofma(c2 * noise)
    pos = _wrap(dom, x1 + _nofma((0.5 * dt) * v2))
    return pos, v2, rng


def _integ_kick(integrator: str, mass: float, v_staged: Array,
                forces: Array, dt: Array) -> Array:
    if integrator == "leapfrog":
        return v_staged
    return v_staged + _nofma(((0.5 / mass) * dt) * forces)


@functools.lru_cache(maxsize=64)
def _segment_exec(p: InteractionPlan, integrator: str, seg_len: int,
                  eff_skin: float, mass: float,
                  field_names: Tuple[str, ...], has_valid: bool):
    """The jitted fused segment:
    ``run(carry, dt, gamma, kT, fields, valid) -> (carry, traces)`` over
    ``seg_len`` steps. Cached per static configuration, so a long run —
    and a warm serving class — compiles each segment shape exactly once."""
    del field_names, has_valid      # cache-key components only
    dom = p.domain

    def make_body(dt, gamma, kT, fields, valid):
        def body(carry: TrajCarry, _):
            md = carry.md
            pos, v_staged, rng = _integ_drift(integrator, dom, mass, md,
                                              carry.rng, dt, gamma, kT)

            disp = max_displacement(dom, pos, carry.ref, valid)
            step_disp = max_displacement(dom, pos, md.positions, valid)
            need_rebin = disp > eff_skin * 0.5

            def do_rebin(_):
                return bin_particles(dom, pos, fields, m_c=p.m_c,
                                     valid=valid), pos

            def do_refresh(_):
                img = image_positions(dom, pos, carry.ref)
                return refresh_bins(dom, carry.bins, img, fields,
                                    valid), carry.ref

            bins, ref = jax.lax.cond(need_rebin, do_rebin, do_refresh, None)
            # positions as the (possibly stale) bins see them: the image
            # nearest the binned reference — exactly ``pos`` after a rebin
            img = image_positions(dom, pos, ref)
            forces, pot = _forces(p, bins, img, fields, valid)
            vel = _integ_kick(integrator, mass, v_staged, forces, dt)

            md2 = MDState(pos, vel, forces, pot, md.step + 1)
            ke, pe = _masked_energies(vel, pot, valid, mass)
            cell_max, row_max, units = _bound_probes(p, bins)
            mon = M.update(carry.mon, positions=pos, velocities=vel,
                           forces=forces, potential=pot, valid=valid,
                           kinetic=ke, potential_energy=pe,
                           step_disp=step_disp,
                           eff_skin=eff_skin, cell_max=cell_max,
                           row_max=row_max, units=units)
            rebinned = need_rebin.astype(jnp.int32)
            out = TrajCarry(md=md2, bins=bins, ref=ref, rng=rng,
                            rebins=carry.rebins + rebinned, mon=mon)
            return out, {"kinetic": ke, "potential": pe, "total": ke + pe,
                         "rebinned": rebinned}
        return body

    @jax.jit
    def run(carry: TrajCarry, dt: Array, gamma: Array, kT: Array,
            fields: Dict[str, Array], valid: Optional[Array]):
        api._count_recompile(p)         # runs at trace time only
        body = make_body(dt, gamma, kT, fields, valid)
        return jax.lax.scan(body, carry, None, length=seg_len)

    return run


@functools.lru_cache(maxsize=64)
def _init_exec(p: InteractionPlan, mass: float,
               field_names: Tuple[str, ...], has_valid: bool,
               has_forces: bool):
    """Jitted cold start: bin, evaluate (or adopt) forces, seed the
    monitors. An MDState input's committed forces are adopted, not
    recomputed — recomputing in a different program can shift them by an
    ulp, which would break the skin=0 parity contract against a baseline
    loop started from the same MDState."""
    del field_names, has_valid

    @jax.jit
    def init(positions, velocities, step0, fields, valid, rng,
             forces0, pot0):
        api._count_recompile(p)
        bins = bin_particles(p.domain, positions, fields, m_c=p.m_c,
                             valid=valid)
        if has_forces:
            forces, pot = forces0, pot0
        else:
            forces, pot = _forces(p, bins, positions, fields, valid)
        md = MDState(positions, velocities, forces, pot, step0)
        ke, pe = _masked_energies(velocities, pot, valid, mass)
        return TrajCarry(md=md, bins=bins, ref=positions, rng=rng,
                         rebins=jnp.int32(0), mon=M.init_monitors(ke + pe))

    return init


@functools.lru_cache(maxsize=64)
def _rebin_exec(p: InteractionPlan, field_names: Tuple[str, ...],
                has_valid: bool):
    """Jitted forced rebin: fresh bins + reference at the carried
    positions; the committed MD state and monitors are untouched. Used on
    rollback (perturb the FP path away from a breach) and after a bound
    replan (the grown ``m_c`` changes the bins' static shapes).

    Does NOT touch ``carry.rebins`` — that counter means skin-contract
    rebins inside the scan; fault-recovery rebins are counted host-side
    in ``TrajectoryResult.forced_rebins``."""
    del field_names, has_valid

    @jax.jit
    def rebin(carry: TrajCarry, fields, valid):
        api._count_recompile(p)
        bins = bin_particles(p.domain, carry.md.positions, fields,
                             m_c=p.m_c, valid=valid)
        return TrajCarry(md=carry.md, bins=bins, ref=carry.md.positions,
                         rng=carry.rng, rebins=carry.rebins,
                         mon=carry.mon)

    return rebin


def reference_step(p: InteractionPlan, integrator: str = "velocity_verlet",
                   mass: float = 1.0):
    """One per-step ``plan.execute`` baseline step, arithmetic-identical
    to the fused scan body — the other side of the fig_traj parity gate
    (with ``skin=0`` the fused path must match it bit for bit)."""
    def step(md: MDState, dt) -> MDState:
        dt = jnp.asarray(dt, md.positions.dtype)
        zero = jnp.zeros((), md.positions.dtype)
        pos, v_staged, _ = _integ_drift(integrator, p.domain, mass, md,
                                        jnp.zeros((2,), jnp.uint32),
                                        dt, zero, zero)
        forces, pot = p.execute(ParticleState(pos))
        vel = _integ_kick(integrator, mass, v_staged, forces, dt)
        return MDState(pos, vel, forces, pot, md.step + 1)
    return step


# --------------------------------------------------------------------------
# the host loop: segments, breaches, rollback, replan, checkpoint, resume
# --------------------------------------------------------------------------


def _normalize_state(state, velocities, plan) -> Tuple[
        Array, Array, Dict[str, Array], Optional[Array], int,
        Optional[Array], Optional[Array]]:
    """Accept MDState / ParticleState / raw (N, 3) positions. An MDState
    also contributes its committed (forces, potential), which the cold
    start adopts instead of recomputing (parity contract)."""
    if isinstance(state, MDState):
        return (state.positions, state.velocities, {}, None,
                int(state.step), state.forces, state.potential)
    if isinstance(state, ParticleState):
        pos = state.positions
        vel = (velocities if velocities is not None
               else jnp.zeros_like(pos))
        return pos, vel, dict(state.fields), state.valid, 0, None, None
    pos = jnp.asarray(state)
    vel = velocities if velocities is not None else jnp.zeros_like(pos)
    return pos, vel, {}, None, 0, None, None


def run_trajectory(base: InteractionPlan, state, n_steps: int, dt: float, *,
                   integrator: str = "velocity_verlet",
                   skin: Optional[float] = None,
                   mass: float = 1.0, gamma: float = 0.1, kT: float = 0.0,
                   velocities: Optional[Array] = None, seed: int = 0,
                   checkpoint_dir: Optional[Union[str, pathlib.Path]] = None,
                   checkpoint_every: Optional[int] = None,
                   resume: bool = True,
                   segment_len: int = 32,
                   energy_budget: Optional[float] = None,
                   max_rollbacks: int = 4, max_replans: int = 4,
                   max_retries: Optional[int] = None,
                   traj_plan: Optional[InteractionPlan] = None,
                   sleep=None) -> TrajectoryResult:
    """Run ``n_steps`` of fused, guarded simulation. See the module
    docstring for the contract; ``InteractionPlan.trajectory`` is the
    front door. Never raises for runtime faults — like
    ``execute_checked``, failures degrade/roll back and the worst case is
    ``status="failed"`` with the last committed state."""
    if integrator not in INTEGRATORS:
        raise ValueError(f"unknown integrator {integrator!r}; have "
                         f"{INTEGRATORS}")
    if n_steps < 0:
        raise ValueError(f"n_steps must be >= 0, got {n_steps}")
    _check_supported(base)

    positions, vels, fields, valid, step0, forces0, pot0 = _normalize_state(
        state, velocities, base)
    field_names = tuple(sorted(fields))
    has_valid = valid is not None
    has_forces = forces0 is not None
    if not has_forces:  # placeholders; the jitted init ignores them
        forces0 = jnp.zeros_like(positions)
        pot0 = jnp.zeros((positions.shape[0],), positions.dtype)

    # -- the skin plan ------------------------------------------------------
    if traj_plan is not None:
        _check_supported(traj_plan)
        p = traj_plan
    else:
        if skin is None:
            skin = DEFAULT_SKIN_FRACTION * base.domain.cutoff
        p = trajectory_plan(base, skin, positions, valid)
    eff_skin = 0.0 if (skin == 0 and traj_plan is None) else \
        effective_skin(p.domain)
    # initial bounds must cover the initial positions
    st0 = ParticleState(positions, fields, valid)
    replans = 0
    while p.check_overflow(st0) and replans < max_replans:
        p = p.replan(st0)
        replans += 1

    dtype = positions.dtype
    dt_arr = jnp.asarray(dt, dtype)
    gamma_arr = jnp.asarray(gamma, dtype)
    kT_arr = jnp.asarray(kT, dtype)
    rng0 = jax.random.PRNGKey(seed)

    seg = max(1, int(segment_len))
    ck_every = None
    if checkpoint_dir is not None:
        ck_every = _round_up(checkpoint_every or 4 * seg, seg)
        checkpoint_dir = pathlib.Path(checkpoint_dir)

    result = TrajectoryResult(state=None, traces={}, plan=p,
                              replans=replans, eff_skin=float(eff_skin))

    # -- resume or cold start ----------------------------------------------
    steps_done = 0
    carry = None
    if checkpoint_dir is not None and resume:
        last = _ckpt.latest_step(checkpoint_dir)
        if last is not None:
            extra = _ckpt.read_extra(checkpoint_dir, last)
            if (tuple(extra.get("ncells", ())) != p.domain.ncells
                    or extra.get("integrator") != integrator):
                raise ValueError(
                    f"checkpoint {checkpoint_dir}/step_{last:08d} was "
                    f"written by a different trajectory configuration "
                    f"({extra.get('ncells')}, {extra.get('integrator')}); "
                    "refusing to resume onto it")
            # bounds may have been grown before the checkpoint: the
            # template must match the saved static shapes
            p = dataclasses.replace(
                p, m_c=int(extra["m_c"]), box=None,
                row_cap=(int(extra["row_cap"]) if extra.get("row_cap")
                         else p.row_cap),
                max_active=(int(extra["max_active"])
                            if extra.get("max_active") else p.max_active))
            template = _init_exec(p, mass, field_names, has_valid,
                                  has_forces)(
                positions, vels, jnp.int32(step0), fields, valid, rng0,
                forces0, pot0)
            with _obs_trace("traj.checkpoint.load", step=last,
                            dir=str(checkpoint_dir)):
                carry, _ = _ckpt.restore(checkpoint_dir, template,
                                         step=last)
            steps_done = int(extra["steps_done"])
            result.resumed_from = last
            result.plan = p

    if carry is None:
        carry = _init_exec(p, mass, field_names, has_valid, has_forces)(
            positions, vels, jnp.int32(step0), fields, valid, rng0,
            forces0, pot0)
    # registry baseline: carry.rebins is cumulative across resumes, the
    # process counter must only count rebins this call performs
    rebins0 = int(carry.rebins)

    if n_steps == 0 or steps_done >= n_steps:
        result.state = carry.md
        result.steps = steps_done
        result.rebins = int(carry.rebins)
        result.traces = {k: np.zeros((0,), np.float32)
                         for k in ("kinetic", "potential", "total")}
        return result

    # -- the guarded segment loop ------------------------------------------
    rungs = api.degradation_ladder(p)
    health = api.plan_health(p)
    level = min(health.level, len(rungs) - 1)
    if max_retries is None:
        max_retries = api._FAILURE_THRESHOLD * len(rungs)

    segments: List[Dict[str, np.ndarray]] = []
    anchor = (carry, steps_done, 0)          # (carry, steps_done, n_segments)
    attempts = rollbacks = 0
    mon_prev = jax.device_get(carry.mon)
    failed = False

    def rebin_at(q, c):
        result.forced_rebins += 1
        with _obs_trace("traj.rebin", kind="forced", m_c=q.m_c,
                        strategy=q.strategy):
            return _rebin_exec(q, field_names, has_valid)(c, fields, valid)

    def grown_rungs(q):
        return api.degradation_ladder(q), api.plan_health(q)

    while steps_done < n_steps:
        boundary = (steps_done // seg + 1) * seg
        this_len = min(boundary, n_steps) - steps_done
        rung = rungs[min(level, len(rungs) - 1)]
        exec_fn = _segment_exec(rung, integrator, this_len,
                                float(eff_skin), mass, field_names,
                                has_valid)
        st = chaos.state()
        fires_before = (st.fire_count("traj.step", "nonfinite")
                        if st is not None else 0)
        try:
            if sleep is None:
                chaos.maybe_delay("traj.step")
            else:
                chaos.maybe_delay("traj.step", sleep=sleep)
            chaos.maybe_raise("traj.step")
            with _obs_trace("traj.segment", steps=this_len,
                            start=steps_done, backend=rung.backend,
                            strategy=rung.strategy, level=level):
                carry2, ys = exec_fn(carry, dt_arr, gamma_arr, kT_arr,
                                     fields, valid)
            # host-boundary corruption point (the scan itself is traced
            # and must never be poisoned at trace time)
            pos2 = chaos.corrupt("traj.step", carry2.md.positions)
            injected_nan = (st is not None and st.fire_count(
                "traj.step", "nonfinite") > fires_before)
            if injected_nan:
                carry2 = dataclasses.replace(
                    carry2, md=dataclasses.replace(carry2.md,
                                                   positions=pos2))
            mon_cur = jax.device_get(carry2.mon)
        except (chaos.TransientBackendError, RuntimeError, ValueError) as e:
            result.faults.append(f"{type(e).__name__}: {e}")
            attempts += 1
            result.retries += 1
            if health.note_failure(len(rungs)):
                level = health.level
            if attempts > max_retries:
                failed = True
                break
            continue

        # ---- overflow? grow bounds, roll back, replay --------------------
        forced = chaos.forced_overflow("traj.rebin")
        grown = _grow_bounds(p, int(mon_cur.max_cell_count),
                             int(mon_cur.max_row_count),
                             int(mon_cur.max_active_units))
        if grown != p or forced:
            if grown == p:
                # injected verdict with nothing to grow: record, move on
                result.faults.append("overflow:injected")
            elif result.replans >= max_replans:
                result.faults.append("overflow:replan-budget-exhausted")
                failed = True
                break
            else:
                result.replans += 1
                api._count_replan(p)
                _obs_event("traj.replan", m_c=grown.m_c, m_c_was=p.m_c,
                           row_cap=grown.row_cap,
                           max_active=grown.max_active)
                p = grown
                result.plan = p
                rungs, health = grown_rungs(p)
                level = min(health.level, len(rungs) - 1)
                # anchor bins were built under the old m_c: rebuild them
                # (and the executors) under the grown bounds
                carry, steps_done, nseg = anchor
                carry = rebin_at(rungs[min(level, len(rungs) - 1)], carry)
                del segments[nseg:]
                anchor = (carry, steps_done, nseg)
                mon_prev = jax.device_get(carry.mon)
                continue

        # ---- invariant breach? roll back + forced rebin ------------------
        breach = ("nonfinite" if injected_nan else
                  M.classify_breach(mon_prev, mon_cur, energy_budget))
        if breach is not None:
            result.faults.append(f"breach:{breach}@{steps_done}")
            rollbacks += 1
            result.rollbacks = rollbacks
            _obs_event("traj.rollback", breach=breach, step=steps_done,
                       anchor_step=anchor[1])
            if health.note_failure(len(rungs)):
                level = health.level
            if rollbacks > max_rollbacks:
                failed = True
                break
            carry, steps_done, nseg = anchor
            carry = rebin_at(rungs[min(level, len(rungs) - 1)], carry)
            del segments[nseg:]
            anchor = (carry, steps_done, nseg)
            mon_prev = jax.device_get(carry.mon)
            continue

        # ---- commit ------------------------------------------------------
        health.note_success()
        attempts = 0
        carry = carry2
        mon_prev = mon_cur
        steps_done += this_len
        segments.append(jax.device_get(ys))

        at_ck = ck_every is not None and steps_done % ck_every == 0
        if at_ck or steps_done >= n_steps or ck_every is None:
            if at_ck and checkpoint_dir is not None:
                try:
                    chaos.maybe_raise("traj.checkpoint")
                    with _obs_trace("traj.checkpoint.save",
                                    step=steps_done,
                                    dir=str(checkpoint_dir)):
                        _ckpt.save(checkpoint_dir, steps_done, carry,
                                   extra={"steps_done": steps_done,
                                          "ncells": list(p.domain.ncells),
                                          "integrator": integrator,
                                          "m_c": p.m_c,
                                          "row_cap": p.row_cap,
                                          "max_active": p.max_active,
                                          "segment_len": seg})
                    result.checkpoints += 1
                except (chaos.TransientBackendError, OSError) as e:
                    # a failed checkpoint must never kill the run; the
                    # in-memory anchor still advances
                    result.faults.append(f"checkpoint:{type(e).__name__}")
            anchor = (carry, steps_done, len(segments))

    # -- finalize ----------------------------------------------------------
    if failed:
        # the anchor is the last committed healthy state
        carry, steps_done, nseg = anchor
        del segments[nseg:]
        result.status = "failed"
    else:
        result.status = "ok" if level == 0 else "degraded"
    result.state = carry.md
    result.steps = steps_done
    result.rebins = int(carry.rebins)
    result.ladder_level = level
    _obs_metrics.registry.counter(
        REBIN_TOTAL, backend=p.backend, strategy=p.strategy,
        layout=p.layout).inc(max(0, result.rebins - rebins0)
                             + result.forced_rebins)
    if segments:
        result.traces = {k: np.concatenate([s[k] for s in segments])
                         for k in ("kinetic", "potential", "total")}
    else:
        result.traces = {k: np.zeros((0,), np.float32)
                         for k in ("kinetic", "potential", "total")}
    return result
