"""In-loop invariant monitors for the trajectory engine.

A multi-thousand-step `lax.scan` cannot raise, print, or branch to the
host mid-flight — everything the host needs to know about the health of a
segment has to ride in the scan carry as a handful of scalars. The
monitor state is that handful: cumulative counters updated by one fused
reduction per step (the trajectory-side sibling of `api._output_check`),
read back once per *segment* on the host, which then decides whether the
segment commits or rolls back (see `traj.engine`).

Monitor glossary
----------------
nonfinite_steps / nonfinite_elems
    Steps on which any position / velocity / force / potential entry of a
    valid particle was NaN or Inf, and the total count of such entries.
    Any increase across a segment is a breach: the segment's states are
    garbage and must not be committed or checkpointed.
skin_steps
    Steps whose *single-step* max displacement exceeded ``skin / 2``.
    Pair coverage is still exact — the rebin predicate fires on the same
    step and rebuilds the bins before forces are evaluated — but the
    configured skin no longer matches the dynamics (the engine is
    re-binning every step, and step sizes that large usually mean the
    trajectory is blowing up). Advisory when ``skin == 0`` (always-rebin
    mode, counter stays 0); a breach otherwise.
max_drift
    Running max of relative total-energy drift ``|E - E0| / max(|E0|,1)``
    against the energy captured at trajectory start (restored across
    checkpoints). A breach only when it exceeds the caller's
    ``energy_budget``.
max_cell_count / max_row_count / max_active_units
    Running maxima of the quantities the static bounds ``m_c`` /
    ``row_cap`` / ``max_active`` must cover. A rebin inside the scan
    cannot replan (shapes are static), so overflow is *recorded* here and
    the host grows the bounds and replays the segment — the grow-only
    replan contract, deferred to the segment boundary.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

Array = jnp.ndarray


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class MonitorState:
    """Cumulative invariant counters carried through the trajectory scan."""

    e0: Array                 # () reference total energy (trajectory start)
    nonfinite_steps: Array    # () int32
    nonfinite_elems: Array    # () int32
    skin_steps: Array         # () int32
    max_drift: Array          # () float32 relative energy drift
    max_cell_count: Array     # () int32 max particles in any cell seen
    max_row_count: Array      # () int32 max padded-row load seen (packed)
    max_active_units: Array   # () int32 max active work units seen (compact)


def init_monitors(e0: Array) -> MonitorState:
    z = jnp.int32(0)
    return MonitorState(
        e0=jnp.asarray(e0, jnp.float32), nonfinite_steps=z,
        nonfinite_elems=z, skin_steps=z,
        max_drift=jnp.float32(0.0), max_cell_count=z,
        max_row_count=z, max_active_units=z)


def count_nonfinite(positions: Array, velocities: Array, forces: Array,
                    potential: Array, valid: Optional[Array]) -> Array:
    """One fused reduction: non-finite entries across the MD state, with
    padding rows masked out (their values are by construction inert)."""
    def bad(a, mask):
        b = ~jnp.isfinite(a)
        if mask is not None:
            b = b & mask
        return jnp.sum(b, dtype=jnp.int32)

    m3 = None if valid is None else valid[:, None]
    return (bad(positions, m3) + bad(velocities, m3)
            + bad(forces, m3) + bad(potential, valid))


def update(mon: MonitorState, *, positions: Array, velocities: Array,
           forces: Array, potential: Array, valid: Optional[Array],
           kinetic: Array, potential_energy: Array, step_disp: Array,
           eff_skin: float, cell_max: Array, row_max: Array,
           units: Array) -> MonitorState:
    """Fold one step's observations into the carry (traced, branch-free).

    ``potential_energy`` must be the already-halved total PE (the
    pair-counted-twice convention of ``engine._masked_energies``) — the
    same quantity that seeds ``e0`` and fills the traces' ``total``, so
    drift compares like with like.
    """
    bad = count_nonfinite(positions, velocities, forces, potential, valid)
    energy = (kinetic + potential_energy).astype(jnp.float32)
    drift = jnp.abs(energy - mon.e0) / jnp.maximum(jnp.abs(mon.e0), 1.0)
    skin_hit = (jnp.int32(1) if eff_skin > 0 else jnp.int32(0)) * (
        step_disp > eff_skin * 0.5).astype(jnp.int32)
    return MonitorState(
        e0=mon.e0,
        nonfinite_steps=mon.nonfinite_steps + (bad > 0).astype(jnp.int32),
        nonfinite_elems=mon.nonfinite_elems + bad,
        skin_steps=mon.skin_steps + skin_hit,
        # drift of a non-finite energy is meaningless; don't fold NaN into
        # the running max (the nonfinite counter already flags the step)
        max_drift=jnp.where(jnp.isfinite(drift),
                            jnp.maximum(mon.max_drift, drift),
                            mon.max_drift),
        max_cell_count=jnp.maximum(mon.max_cell_count,
                                   cell_max.astype(jnp.int32)),
        max_row_count=jnp.maximum(mon.max_row_count,
                                  row_max.astype(jnp.int32)),
        max_active_units=jnp.maximum(mon.max_active_units,
                                     units.astype(jnp.int32)))


def classify_breach(prev: MonitorState, cur: MonitorState,
                    energy_budget: Optional[float]) -> Optional[str]:
    """Host-side segment verdict: compare the carry monitors before and
    after a segment (both fetched to host) and name the first breached
    invariant, or None when the segment is healthy.

    Order matters: non-finite values invalidate everything else, and an
    energy breach on a NaN segment is a symptom, not the cause.
    """
    if int(cur.nonfinite_steps) > int(prev.nonfinite_steps):
        return "nonfinite"
    if int(cur.skin_steps) > int(prev.skin_steps):
        return "skin"
    if (energy_budget is not None
            and float(cur.max_drift) > float(energy_budget)):
        return "energy"
    return None
