"""Production mesh construction (dry-run + launcher).

A function, not a module-level constant: importing this module must never
touch jax device state (smoke tests see 1 device; only dryrun.py sets the
512-device XLA flag before any jax import).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; 2 pods = 512 chips with the leading pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2):
    """Small mesh for subprocess tests (XLA_FLAGS host device count)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))
