import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
os.environ["REPRO_SCAN_UNROLL"] = "full"
os.environ["REPRO_DENSE_ATTN"] = "1"
"""Roofline *cost* runs: accurate per-device FLOPs/bytes/collective counts.

Why a second driver (methodology, EXPERIMENTS.md §Roofline): XLA's
HloCostAnalysis visits a while-loop body exactly once, so the production
program (scan-over-layers + chunk-scanned flash attention) under-counts
FLOPs/bytes by ~the layer count. Cost runs therefore compile with
  * layer scans fully unrolled (REPRO_SCAN_UNROLL=full),
  * dense-einsum attention (REPRO_DENSE_ATTN=1 — same FLOP count our masked
    flash performs, no inner scan),
and, for deep/expensive configs, at two reduced depths (one and two
homogeneity periods), extrapolating every counter linearly in depth:
counter(L) = a + b * L — exact for layer-homogeneous stacks, with the
intercept capturing embedding/logits/optimizer terms. memory_analysis is NOT
taken from these compiles (unrolling changes buffer liveness); the
production-program dry-run (dryrun.py) owns the memory numbers.
"""

import argparse
import dataclasses
import json
import pathlib
import time
import traceback

import jax

from ..configs import (ARCH_IDS, SHAPES, cell_is_runnable, get_config,
                       shape_by_name)
from . import roofline as RL
from .dryrun import OUT_DIR, lower_cell
from .mesh import make_production_mesh

COST_DIR = OUT_DIR.parent / "costrun"


def _period(cfg) -> int:
    if cfg.local_global:
        return 2
    if cfg.family == "hybrid" and cfg.hybrid_attn_every:
        return cfg.hybrid_attn_every
    return 1


def _counters(cfg, shape_name, mesh, n_layers, enc_layers=None):
    cfg2 = dataclasses.replace(cfg, n_layers=n_layers,
                               **({"n_enc_layers": enc_layers}
                                  if enc_layers is not None else {}))
    compiled, lowered, shape, n_dev = lower_cell(
        cfg2, shape_name, mesh,
        remat=not os.environ.get("REPRO_NO_REMAT"))
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    hlo = compiled.as_text()
    coll = RL.collective_bytes(hlo)
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": {k: float(v) for k, v in coll.items()},
    }


def measure(arch: str, shape_name: str, multi_pod: bool = False,
            direct_layer_cap: int = 8, tag: str = "") -> dict:
    """Counters for the full config, via direct compile or L-extrapolation."""
    cfg = get_config(arch)
    shape = shape_by_name(shape_name)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    COST_DIR.mkdir(parents=True, exist_ok=True)
    stem = f"{arch}__{shape_name}__{mesh_name}" + (f"__{tag}" if tag else "")
    out_path = COST_DIR / f"{stem}.json"

    ok, reason = cell_is_runnable(cfg, shape)
    if not ok:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "skipped": reason}
        out_path.write_text(json.dumps(rec, indent=2))
        return rec

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    per = _period(cfg)
    try:
        if cfg.n_layers <= direct_layer_cap * per and cfg.d_model <= 4096:
            c_full = _counters(cfg, shape_name, mesh, cfg.n_layers)
            method = "direct"
            flops, bts = c_full["flops"], c_full["bytes"]
            coll = c_full["coll"]
        else:
            l1, l2 = per, 2 * per
            enc = None
            if cfg.n_enc_layers:
                enc = 2
            c1 = _counters(cfg, shape_name, mesh, l1, enc)
            c2 = _counters(cfg, shape_name, mesh, l2, enc)
            L = cfg.n_layers
            slope = {
                "flops": (c2["flops"] - c1["flops"]) / (l2 - l1),
                "bytes": (c2["bytes"] - c1["bytes"]) / (l2 - l1),
            }
            flops = c1["flops"] + slope["flops"] * (L - l1)
            bts = c1["bytes"] + slope["bytes"] * (L - l1)
            coll = {}
            for k in c1["coll"]:
                s = (c2["coll"][k] - c1["coll"][k]) / (l2 - l1)
                coll[k] = max(0.0, c1["coll"][k] + s * (L - l1))
            if cfg.n_enc_layers:
                # add the remaining encoder layers' slope (enc scales like a
                # bidirectional decoder layer; reuse decoder slope as bound)
                flops += slope["flops"] * (cfg.n_enc_layers - 2) * 0.5
                bts += slope["bytes"] * (cfg.n_enc_layers - 2) * 0.5
            method = f"extrapolated(L={l1},{l2})"
    except Exception as e:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-3000:]}
        out_path.write_text(json.dumps(rec, indent=2))
        print(f"[costrun] FAIL {stem}: {type(e).__name__}: {str(e)[:160]}")
        return rec

    cost = {"flops": flops, "bytes accessed": bts}
    terms = RL.analyze(cost, "", RL.model_flops_for(cfg, shape, mesh.size))
    coll_total = sum(coll.values())
    terms.coll_bytes = coll_total
    terms.coll_breakdown = coll
    terms.collective_s = coll_total / (RL.LINK_BW * RL.LINKS_PER_CHIP)
    tdict = {"compute": terms.compute_s, "memory": terms.memory_s,
             "collective": terms.collective_s}
    terms.dominant = max(tdict, key=tdict.get)

    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "tag": tag,
        "method": method, "n_devices": mesh.size,
        "compile_seconds": round(time.time() - t0, 1),
        "roofline": terms.to_dict(),
    }
    out_path.write_text(json.dumps(rec, indent=2))
    r = rec["roofline"]
    print(f"[costrun] OK   {stem} [{method}]: flops/dev={r['flops']:.3e} "
          f"bytes/dev={r['hbm_bytes']:.3e} coll/dev={r['coll_bytes']:.3e} "
          f"dominant={r['dominant']} useful={r['useful_ratio']:.3f}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=[s.name for s in SHAPES])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    if args.all:
        for a in ARCH_IDS:
            for s in SHAPES:
                measure(a, s.name, args.multi_pod, tag=args.tag)
    else:
        measure(args.arch, args.shape, args.multi_pod, tag=args.tag)


if __name__ == "__main__":
    main()
