"""Render EXPERIMENTS.md tables from experiments/ artifacts.

    PYTHONPATH=src python -m repro.launch.report [--section dryrun|roofline]
"""

from __future__ import annotations

import argparse
import json
import pathlib

ROOT = pathlib.Path(__file__).resolve().parents[3] / "experiments"
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def _load(sub):
    out = {}
    d = ROOT / sub
    if not d.exists():
        return out
    for p in sorted(d.glob("*.json")):
        r = json.loads(p.read_text())
        if r.get("tag"):
            continue
        out[(r.get("arch"), r.get("shape"), r.get("mesh"))] = r
    return out


def dryrun_table() -> str:
    recs = _load("dryrun")
    lines = [
        "| arch | shape | mesh | status | compile_s | args GiB/dev | "
        "temp GiB/dev | collective GB/dev (production program) |",
        "|---|---|---|---|---|---|---|---|",
    ]
    archs = sorted({k[0] for k in recs})
    for arch in archs:
        for shape in SHAPE_ORDER:
            for mesh in ("pod16x16", "pod2x16x16"):
                r = recs.get((arch, shape, mesh))
                if r is None:
                    continue
                if "skipped" in r:
                    lines.append(f"| {arch} | {shape} | {mesh} | SKIP "
                                 f"({r['skipped'].split(';')[0]}) | | | | |")
                    continue
                if "error" in r:
                    lines.append(f"| {arch} | {shape} | {mesh} | "
                                 f"FAIL {r['error'][:60]} | | | | |")
                    continue
                m = r.get("memory_analysis", {})
                lines.append(
                    f"| {arch} | {shape} | {mesh} | OK | "
                    f"{r.get('compile_seconds', '')} | "
                    f"{m.get('argument_size_in_bytes', 0) / 2**30:.2f} | "
                    f"{m.get('temp_size_in_bytes', 0) / 2**30:.2f} | "
                    f"{r['roofline']['coll_bytes'] / 1e9:.2f} |")
    return "\n".join(lines)


def roofline_table() -> str:
    """Single-pod roofline: cost-run counters (accurate), dominant term."""
    cost = _load("costrun")
    dry = _load("dryrun")
    lines = [
        "| arch | shape | compute_s | memory_s | collective_s | dominant | "
        "MODEL/HLO flops | roofline fraction | bottleneck note |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    archs = sorted({k[0] for k in cost})
    for arch in archs:
        for shape in SHAPE_ORDER:
            r = cost.get((arch, shape, "pod16x16"))
            if r is None:
                continue
            if "skipped" in r:
                lines.append(f"| {arch} | {shape} | — | — | — | SKIP | | | "
                             f"{r['skipped'].split(';')[0]} |")
                continue
            if "error" in r:
                lines.append(f"| {arch} | {shape} | — | — | — | FAIL | | | "
                             f"{r['error'][:50]} |")
                continue
            rl = r["roofline"]
            terms = {"compute": rl["compute_s"], "memory": rl["memory_s"],
                     "collective": rl["collective_s"]}
            dom = rl["dominant"]
            tot = max(sum(terms.values()), 1e-30)
            frac = terms["compute"] / max(terms.values())
            lines.append(
                f"| {arch} | {shape} | {rl['compute_s']:.4f} | "
                f"{rl['memory_s']:.4f} | {rl['collective_s']:.4f} | {dom} | "
                f"{rl['useful_ratio']:.3f} | {frac:.3f} | |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--section", choices=["dryrun", "roofline", "all"],
                    default="all")
    args = ap.parse_args()
    if args.section in ("dryrun", "all"):
        print("## Dry-run table\n")
        print(dryrun_table())
    if args.section in ("roofline", "all"):
        print("\n## Roofline table (single-pod, cost-run counters)\n")
        print(roofline_table())


if __name__ == "__main__":
    main()
