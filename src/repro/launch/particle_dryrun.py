import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
"""Particle-engine dry-run: the paper's own system on the production mesh.

Extends deliverable (e) beyond the LM cells: the distributed cell-list
engine (shard_map + Z-plane halo exchange) is lowered and compiled for the
single-pod 16×16 and multi-pod 2×16×16 meshes at cluster-scale particle
counts. The grid splits along Z over ("pod","data") — 32 Z-slabs for the
multi-pod mesh, pod boundary = one ghost-plane exchange per step, exactly
the paper's ghost cells stretched across the slow links.

  PYTHONPATH=src python -m repro.launch.particle_dryrun [--multi-pod]
"""

import argparse
import json
import pathlib
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..core import Domain, ParticleState, make_lennard_jones
from ..core import api as A
from . import roofline as RL
from .mesh import make_production_mesh

OUT = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def run(multi_pod: bool, division: int = 128, ppc: int = 16,
        m_c: int = 32) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    # fold the whole dp hierarchy into the Z split: 16 (data) or 32 (pod*data)
    axis = ("pod", "data") if multi_pod else ("data",)

    # shard_map needs a single named axis; reuse "data" and put pods on Z too
    # by splitting over the flattened axis tuple via a wrapper mesh axis.
    domain = Domain.cubic(division, cutoff=1.0, periodic=True)
    n = division ** 3 * ppc
    kernel = make_lennard_jones()

    n_shards = int(mesh.shape["data"])
    # uniform benchmark load: the analytic per-shard capacity with the
    # usual slack + alignment (no positions exist at dry-run time)
    cap = -(-int(n / n_shards * 1.3) // 8) * 8
    p = A.plan(domain, kernel, m_c=m_c, strategy="xpencil", backend="halo",
               mesh=mesh, shard_axis="data", n_shards=n_shards,
               shard_cap=cap)
    fn = jax.jit(A._impl(p))
    spec = ParticleState(jax.ShapeDtypeStruct((n, 3), jnp.float32))
    t0 = time.time()
    lowered = fn.lower(spec)
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    coll = RL.collective_bytes(compiled.as_text())
    mem = {}
    try:
        m = compiled.memory_analysis()
        mem = {k: float(getattr(m, k)) for k in
               ("argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes") if getattr(m, k, None) is not None}
    except Exception:
        pass

    # roofline: interactions ~ N * 27ppc * pi/6ish; paper kernel = 21 FLOP
    inter = n * ppc * 27 * 0.52
    rec = {
        "arch": "particle-xpencil", "shape": f"d{division}_ppc{ppc}",
        "mesh": mesh_name, "n_devices": mesh.size,
        "particles": n, "m_c": m_c,
        "compile_seconds": round(time.time() - t0, 1),
        "memory_analysis": mem,
        "cost_analysis": {"flops": float(cost.get("flops", 0)),
                          "bytes accessed":
                          float(cost.get("bytes accessed", 0))},
        "roofline": RL.analyze(cost, compiled.as_text(),
                               inter * 21 / mesh.size).to_dict(),
    }
    out = OUT / f"particle-xpencil__d{division}_ppc{ppc}__{mesh_name}.json"
    OUT.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(rec, indent=2))
    r = rec["roofline"]
    print(f"[particle-dryrun] OK {mesh_name}: N={n:,} "
          f"compile={rec['compile_seconds']}s flops/dev={r['flops']:.3e} "
          f"coll/dev={r['coll_bytes']:.3e}B dominant={r['dominant']}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both", action="store_true")
    ap.add_argument("--division", type=int, default=128)
    ap.add_argument("--ppc", type=int, default=16)
    args = ap.parse_args()
    if args.both:
        run(False, args.division, args.ppc)
        run(True, args.division, args.ppc)
    else:
        run(args.multi_pod, args.division, args.ppc)


if __name__ == "__main__":
    main()
