"""Roofline terms from compiled dry-run artifacts.

Hardware model (TPU v5e-like, fixed by the assignment):
  197 TFLOP/s bf16 per chip | 819 GB/s HBM per chip | ~50 GB/s/link ICI.

Conventions (see EXPERIMENTS.md §Roofline):
  * ``compiled.cost_analysis()`` / ``as_text()`` describe the *per-device*
    SPMD program, so FLOPs/bytes are already per chip — the "/ chips" in the
    assignment formulas is therefore built in.
  * collective bytes: sum of operand bytes of every all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute in the post-SPMD HLO
    (operand types are inline in the HLO text). Wire-traffic multipliers:
    all-reduce 2x (ring = reduce-scatter + all-gather), others 1x.
  * links: v5e has 4 usable ICI links per chip on the 2-D torus; the pod axis
    of the multi-pod mesh crosses DCN-class links — we report the same 50
    GB/s for both and call this out where the pod axis dominates.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s / link
LINKS_PER_CHIP = 4

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_WIRE_MULT = {"all-reduce": 2.0}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-collective-kind operand bytes (wire-multiplied) from HLO text."""
    out: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*[^=]*?\b"
                     r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                     r"collective-permute)(?:-start)?\(", line)
        if not m:
            continue
        kind = m.group(1)
        # operand types are inline: op(  bf16[1,2]{..} %x, f32[3]{..} %y )
        args = line[line.index("(") + 1:]
        total = 0
        for dt, dims in _SHAPE_RE.findall(args):
            total += _shape_bytes(dt, dims)
        out[kind] += total * _WIRE_MULT.get(kind, 1.0)
    return out


@dataclasses.dataclass
class RooflineTerms:
    flops: float                    # per-device HLO FLOPs
    hbm_bytes: float                # per-device HLO bytes accessed
    coll_bytes: float               # per-device wire bytes (all kinds)
    coll_breakdown: Dict[str, float]
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float              # 6*N*D (6*N_active*D for MoE)
    useful_ratio: float             # model_flops / hlo_flops

    def to_dict(self):
        return dataclasses.asdict(self)


def analyze(cost: Dict[str, float], hlo_text: str,
            model_flops: float) -> RooflineTerms:
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes(hlo_text)
    coll_total = sum(coll.values())

    compute_s = flops / PEAK_FLOPS
    memory_s = hbm / HBM_BW
    collective_s = coll_total / (LINK_BW * LINKS_PER_CHIP)
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    return RooflineTerms(
        flops=flops, hbm_bytes=hbm, coll_bytes=coll_total,
        coll_breakdown=coll, compute_s=compute_s, memory_s=memory_s,
        collective_s=collective_s, dominant=dominant,
        model_flops=model_flops,
        useful_ratio=(model_flops / flops) if flops else 0.0)


def model_flops_for(cfg, shape, n_devices: int) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE), per device.

    D = tokens processed by the step: B*S for train (x3 for bwd is already
    the 6 in 6ND), B*S for prefill (2ND forward only -> we use 2ND), B*1
    for decode (2ND)."""
    n_active = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_active * tokens / n_devices
