import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be the first import in the process (jax locks the device count on first
init) — hence the os.environ lines above everything, including docstring
position be damned.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multipod-only|--single-only]

Per cell this produces experiments/dryrun/<arch>__<shape>__<mesh>.json with:
memory_analysis (fits/doesn't), cost_analysis (FLOPs/bytes for §Roofline),
per-kind collective bytes, and the roofline terms. Skipped cells (assignment
rules) get a JSON with ``skipped: reason``.
"""

import argparse
import json
import pathlib
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from ..configs import (ARCH_IDS, ModelConfig, SHAPES, cell_is_runnable,
                       get_config, input_specs, shape_by_name)
from ..dist import sharding as SH
from ..models import model as M
from ..optim.adam import AdamConfig, init_opt_state
from ..models.serving import make_decode_step, make_prefill_step
from ..train.trainer import make_train_step
from . import roofline as RL
from .mesh import make_production_mesh

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _param_structs(cfg: ModelConfig):
    return jax.eval_shape(lambda: M.init_params(cfg, jax.random.PRNGKey(0)))


def _cost_dict(compiled) -> Dict[str, float]:
    try:
        c = compiled.cost_analysis()
        if isinstance(c, (list, tuple)):
            c = c[0]
        return {k: float(v) for k, v in c.items()
                if isinstance(v, (int, float))}
    except Exception:
        return {}


def _memory_dict(compiled) -> Dict[str, float]:
    try:
        m = compiled.memory_analysis()
        out = {}
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
            v = getattr(m, k, None)
            if v is not None:
                out[k] = float(v)
        return out
    except Exception:
        return {}


def lower_cell(cfg: ModelConfig, shape_name: str, mesh,
               remat: bool = True, microbatches: int = 1):
    """Build + lower + compile one cell. Returns (compiled, lowered)."""
    shape = shape_by_name(shape_name)
    specs = input_specs(cfg, shape)
    n_dev = mesh.size
    SH.set_pure_dp(cfg.pure_dp)

    # in_shardings are explicit NamedShardings; the mesh context is what
    # lets the in-model ``constrain`` calls resolve role specs.
    with SH.use_mesh(mesh):
        if shape.kind == "train":
            params = _param_structs(cfg)
            opt_cfg = AdamConfig(moment_dtype=cfg.moment_dtype)
            opt = jax.eval_shape(lambda p: init_opt_state(p, opt_cfg), params)
            step = make_train_step(cfg, opt_cfg, microbatches=microbatches,
                                   remat=remat)
            p_sh = SH.params_shardings(cfg, mesh, params)
            o_sh = SH.opt_shardings(cfg, mesh, opt, params)
            b_sh = SH.batch_shardings(cfg, mesh, specs)
            fn = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                         donate_argnums=(0, 1))
            lowered = fn.lower(params, opt, specs)
        elif shape.kind == "prefill":
            params = _param_structs(cfg)
            step = make_prefill_step(cfg)
            p_sh = SH.params_shardings(cfg, mesh, params)
            b_sh = SH.batch_shardings(cfg, mesh, specs)
            fn = jax.jit(step, in_shardings=(p_sh, b_sh))
            lowered = fn.lower(params, specs)
        else:  # decode
            params = _param_structs(cfg)
            cache = M.cache_spec(cfg, shape.global_batch, shape.seq_len)
            step = make_decode_step(cfg)
            p_sh = SH.params_shardings(cfg, mesh, params)
            c_sh = SH.cache_shardings(cfg, mesh, cache)
            tok_sh = SH.batch_shardings(
                cfg, mesh, {"tokens": specs["tokens"]})["tokens"]
            idx = jax.ShapeDtypeStruct((), jnp.int32)
            fn = jax.jit(step,
                         in_shardings=(p_sh, c_sh, tok_sh, None),
                         donate_argnums=(1,))
            lowered = fn.lower(params, cache, specs["tokens"], idx)
        compiled = lowered.compile()
    return compiled, lowered, shape, n_dev


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             remat: bool = True, microbatches: int = 1,
             out_dir: Optional[pathlib.Path] = None,
             tag: str = "") -> Dict:
    cfg = get_config(arch)
    shape = shape_by_name(shape_name)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    out_dir = out_dir or OUT_DIR
    out_dir.mkdir(parents=True, exist_ok=True)
    stem = f"{arch}__{shape_name}__{mesh_name}" + (f"__{tag}" if tag else "")
    out_path = out_dir / f"{stem}.json"

    ok, reason = cell_is_runnable(cfg, shape)
    if not ok:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "skipped": reason}
        out_path.write_text(json.dumps(rec, indent=2))
        print(f"[dryrun] SKIP {stem}: {reason}")
        return rec

    if microbatches == 1 and shape.kind == "train":
        microbatches = cfg.dryrun_microbatches
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    try:
        compiled, lowered, shape, n_dev = lower_cell(
            cfg, shape_name, mesh, remat=remat, microbatches=microbatches)
    except Exception as e:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
        out_path.write_text(json.dumps(rec, indent=2))
        print(f"[dryrun] FAIL {stem}: {type(e).__name__}: {str(e)[:200]}")
        return rec

    cost = _cost_dict(compiled)
    memory = _memory_dict(compiled)
    hlo = compiled.as_text()
    mf = RL.model_flops_for(cfg, shape, n_dev)
    terms = RL.analyze(cost, hlo, mf)

    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "tag": tag,
        "n_devices": n_dev,
        "params_total": cfg.param_count(),
        "params_active": cfg.active_param_count(),
        "compile_seconds": round(time.time() - t0, 1),
        "memory_analysis": memory,
        "cost_analysis": {k: cost[k] for k in sorted(cost)
                          if k in ("flops", "bytes accessed",
                                   "transcendentals", "optimal_seconds")},
        "roofline": terms.to_dict(),
        "remat": remat, "microbatches": microbatches,
    }
    out_path.write_text(json.dumps(rec, indent=2))
    dom = terms.dominant
    print(f"[dryrun] OK   {stem}: {rec['compile_seconds']}s compile, "
          f"flops/dev={terms.flops:.3e}, coll={terms.coll_bytes:.3e}B, "
          f"dominant={dom}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=[s.name for s in SHAPES])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true",
                    help="use the 2x16x16 mesh (default: 16x16)")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    cells = []
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPES:
                cells.append((a, s.name))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    for arch, shape in cells:
        for mp in meshes:
            run_cell(arch, shape, mp, remat=not args.no_remat,
                     microbatches=args.microbatches, tag=args.tag)


if __name__ == "__main__":
    main()
