"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Small-scale-runnable version of the production loop: config -> mesh ->
sharded init -> train loop with checkpoint-every-K, restart-from-latest,
straggler watchdog, and the deterministic data pipeline. On this container
it runs the smoke configs on 1 device; on a real cluster the same file runs
the full configs (jax.distributed.initialize + the production mesh).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..ckpt import checkpoint as C
from ..configs import ARCH_IDS, get_config, get_smoke_config
from ..data.pipeline import DataConfig, batch_at
from ..dist import sharding as SH
from ..dist.fault import FaultConfig, StragglerWatchdog, run_with_restarts
from ..models import model as M
from ..optim.adam import AdamConfig, init_opt_state
from ..train.trainer import make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    opt_cfg = AdamConfig(lr=args.lr, total_steps=args.steps,
                         warmup_steps=max(1, args.steps // 20),
                         moment_dtype=cfg.moment_dtype)
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                          global_batch=args.batch)
    fault_cfg = FaultConfig(ckpt_dir=args.ckpt_dir,
                            ckpt_every=args.ckpt_every)

    step_fn = jax.jit(make_train_step(cfg, opt_cfg,
                                      microbatches=args.microbatches),
                      donate_argnums=(0, 1))

    def train_loop(start_step: int) -> int:
        key = jax.random.PRNGKey(0)
        params = M.init_params(cfg, key)
        opt = init_opt_state(params, opt_cfg)
        extra = {"data_step": 0}
        if start_step > 0:
            (params, opt), extra = C.restore(args.ckpt_dir,
                                             (params, opt))
        watchdog = StragglerWatchdog(fault_cfg.step_deadline_s)
        data_step = int(extra.get("data_step", 0))

        for step in range(start_step, args.steps):
            tokens, labels = batch_at(data_cfg, data_step)
            batch = {"tokens": tokens, "labels": labels}
            if cfg.family == "vlm":
                batch["patch_embeds"] = jnp.zeros(
                    (args.batch, cfg.n_img_tokens, cfg.d_model), cfg.dtype)
            if cfg.n_enc_layers:
                batch["frame_embeds"] = jnp.zeros(
                    (args.batch, cfg.enc_seq, cfg.d_model), cfg.dtype)
            t0 = time.time()
            metrics, params, opt = step_fn(params, opt, batch)
            jax.block_until_ready(metrics["loss"])
            watchdog.observe(time.time() - t0)
            data_step += 1
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                      f"ce {float(metrics['ce']):.4f} "
                      f"({time.time() - t0:.2f}s)")
            if (step + 1) % fault_cfg.ckpt_every == 0 or \
                    step == args.steps - 1:
                C.save(args.ckpt_dir, step + 1, (params, opt),
                       extra={"data_step": data_step})
        return args.steps

    run_with_restarts(train_loop, fault_cfg)


if __name__ == "__main__":
    main()
