"""repro: cutoff-radius particle interactions (Algis et al. 2024) as a
multi-pod JAX + Pallas framework. See DESIGN.md for the system inventory."""

__version__ = "0.1.0"
