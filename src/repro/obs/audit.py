"""Model-vs-measured traffic audit (the "model drift" metric).

``core/traffic.py`` models HBM bytes per interaction from *uniform*
assumptions — every cell holds ``avg_ppc`` particles, so interactions per
cell are ``27 * avg_ppc**2``. The autotuner prunes candidates by that
model, which means a mis-modelled regime (a blob the uniform model cannot
see, a packed row whose occupancy the per-cell average hides) silently
prunes the true winner. This module computes the **measured** counterpart
from the same occupancy probes the replan contract uses
(``core.binning.cell_counts`` / ``pencil_counts`` / ``subbox_counts`` /
``padded_row_counts``) and reports the relative error:

* measured interactions: the pseudo-Verlet accounting (arxiv 1804.06231's
  interaction-count bookkeeping) — candidate pair slots
  ``sum_c n_c * sum_{c' in 27-neighborhood(c)} n_c'`` from the real
  per-cell counts, the exact quantity ``n_cells * 27 * avg_ppc**2``
  approximates under uniformity;
* measured bytes: the model's staging structure per strategy, fed by
  measured occupancy — active pencils/sub-boxes instead of a fill guess,
  real packed-row populations instead of ``avg_ppc`` per cell;
* drift: ``measured_bpi / modelled_bpi - 1`` (0 = perfect model,
  positive = the model undersells the real traffic).

:func:`audit_candidate` records the drift as the
``repro_traffic_model_drift{strategy,layout}`` gauge (plus a cumulative
histogram) — the autotuner calls it for **every pruned candidate**, so a
wrong prune is visible in the registry instead of lost.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

import numpy as np

from ..core.domain import Domain
from ..core.traffic import FIELD_BYTES, candidate_cost
from . import metrics as _metrics
from .trace import event as _trace_event

__all__ = ["MeasuredTraffic", "measured_traffic", "neighbor_pair_count",
           "model_drift", "audit_candidate", "DRIFT_GAUGE"]

DRIFT_GAUGE = "repro_traffic_model_drift"
DRIFT_HIST = "repro_traffic_model_drift_abs"


@dataclasses.dataclass(frozen=True)
class MeasuredTraffic:
    """Measured interactions / bytes for one (strategy, layout) dispatch."""

    strategy: str
    layout: str
    compact: bool
    interactions: float        # candidate pair slots from real cell counts
    hbm_bytes: float           # staged bytes from measured occupancy
    bytes_per_interaction: float


def _counts_grid(domain: Domain, counts: np.ndarray) -> np.ndarray:
    return np.asarray(counts, dtype=np.float64).reshape(
        domain.nz, domain.ny, domain.nx)


def _shift(grid: np.ndarray, d: Tuple[int, int, int],
           periodic: Tuple[bool, bool, bool]) -> np.ndarray:
    """Shift the (z, y, x) counts grid by (dz, dy, dx): roll on periodic
    axes, zero-fill on open ones (border cells see fewer neighbors)."""
    out = grid
    # grid axis 0/1/2 = z/y/x; Domain.periodic_axes is (x, y, z)
    for axis, (dd, per) in enumerate(zip(d, (periodic[2], periodic[1],
                                             periodic[0]))):
        if dd == 0:
            continue
        out = np.roll(out, dd, axis=axis)
        if not per:
            sl = [slice(None)] * 3
            sl[axis] = slice(0, dd) if dd > 0 else slice(dd, None)
            out = out.copy()
            out[tuple(sl)] = 0.0
    return out


def neighbor_pair_count(domain: Domain, counts) -> float:
    """Measured candidate pair slots: ``sum_c n_c * W_c`` where ``W_c``
    sums the 27-neighborhood (self included) of real per-cell counts —
    what ``n_cells * 27 * avg_ppc**2`` approximates under uniformity."""
    grid = _counts_grid(domain, counts)
    w = np.zeros_like(grid)
    per = domain.periodic_axes
    for dz in (-1, 0, 1):
        for dy in (-1, 0, 1):
            for dx in (-1, 0, 1):
                w += _shift(grid, (dz, dy, dx), per)
    return float((grid * w).sum())


def measured_traffic(domain: Domain, positions=None, *, strategy: str,
                     m_c: int, layout: str = "dense", compact: bool = False,
                     subbox: Optional[Tuple[int, int, int]] = None,
                     counts=None, valid=None) -> MeasuredTraffic:
    """Measured interactions / bytes estimate for one dispatch shape.

    Mirrors ``core.traffic.model``'s staging structure per strategy, but
    feeds it the *measured* occupancy instead of uniform assumptions:
    pass either representative ``positions`` (one binning pass) or
    precomputed per-cell ``counts`` (the probe every bound check already
    ran — the autotuner reuses its own)."""
    if counts is None:
        if positions is None:
            raise ValueError("measured_traffic needs positions or counts")
        from ..core.binning import cell_counts
        counts = cell_counts(domain, positions, valid)
    grid = _counts_grid(domain, counts)
    n = float(grid.sum())
    nx, ny, nz = domain.ncells
    cell_bytes = m_c * FIELD_BYTES
    inter = neighbor_pair_count(domain, counts)

    if strategy == "naive_n2":
        hbm = n * n * FIELD_BYTES
    elif strategy == "par_part":
        hbm = n * 27 * cell_bytes + n * FIELD_BYTES
    elif strategy == "cell_dense":
        if layout == "sfc":
            # measured pair list: the exact kept-pair count the replan
            # probe uses, plus one target tile per cluster that holds any
            # particle (a cluster with no particles has no kept pairs)
            from ..core.binning import (DEFAULT_CSIZE, DEFAULT_CURVE,
                                        sfc_cluster_tables, sfc_pair_count)
            csize = DEFAULT_CSIZE
            tables = sfc_cluster_tables(domain, csize, DEFAULT_CURVE)
            pairs = float(sfc_pair_count(domain, counts=counts))
            occ_cells = (np.asarray(counts, np.float64).reshape(-1)
                         > 0).astype(np.float64)
            kept_clusters = float((np.bincount(
                np.asarray(tables.cell_cluster), weights=occ_cells,
                minlength=tables.n_clusters) > 0).sum())
            hbm = (kept_clusters * csize * cell_bytes
                   + pairs * (csize * cell_bytes + 4))
        else:
            units = float((grid > 0).sum()) if compact else float(grid.size)
            hbm = units * (27 + 1) * cell_bytes
    elif strategy == "xpencil":
        per_row = grid.sum(axis=2)                     # (nz, ny)
        active = per_row > 0
        n_rows = float(active.sum()) if compact else float(per_row.size)
        if layout == "packed":
            # measured packed rows: particles (+ periodic-X ghost copies)
            # and the (nx + 3) int32 prefix offsets, 10 staged windows per
            # pencil — bytes follow the real row populations, not avg_ppc
            padded = per_row.copy()
            if domain.periodic_axes[0]:
                padded += grid[..., 0] + grid[..., -1]
            if compact:
                padded = np.where(active, padded, 0.0)
            hbm = 10.0 * (padded.sum() * (FIELD_BYTES + 4)
                          + n_rows * (nx + 3) * 4)
        else:
            hbm = n_rows * 10.0 * (nx + 2) * cell_bytes
    elif strategy == "allin":
        if subbox is None:
            from ..core.strategies import subbox_dims
            subbox = subbox_dims(domain, m_c)
        bx, by, bz = subbox
        halo_cells = (bx + 2) * (by + 2) * (bz + 2)
        if compact:
            from ..core.binning import subbox_counts
            boxes = np.asarray(subbox_counts(domain, counts, subbox))
            units = float((boxes > 0).sum())
        else:
            units = float(-(-nx // bx) * (-(-ny // by)) * (-(-nz // bz)))
        hbm = units * halo_cells * cell_bytes
    else:
        raise ValueError(f"no measured-traffic estimate for {strategy!r}")

    return MeasuredTraffic(
        strategy=strategy, layout=layout, compact=compact,
        interactions=inter, hbm_bytes=float(hbm),
        bytes_per_interaction=float(hbm) / max(inter, 1e-9))


def model_drift(modelled_bpi: float, measured_bpi: float) -> float:
    """Relative model error: ``measured / modelled - 1`` (0 = perfect,
    NaN when either side is non-finite or the model predicts nothing)."""
    if (not math.isfinite(modelled_bpi) or not math.isfinite(measured_bpi)
            or modelled_bpi <= 0.0):
        return math.nan
    return measured_bpi / modelled_bpi - 1.0


def audit_candidate(domain: Domain, positions=None, *, strategy: str,
                    m_c: int, layout: str = "dense", compact: bool = False,
                    subbox: Optional[Tuple[int, int, int]] = None,
                    fill: float = 1.0, counts=None, valid=None,
                    modelled: Optional[float] = None) -> Dict[str, float]:
    """One model-vs-measured comparison, recorded in the registry.

    ``modelled`` defaults to ``traffic.candidate_cost`` at the given
    ``fill`` (pass the autotuner's own score to audit exactly what pruned
    the candidate). Returns ``{"modelled_bpi", "measured_bpi", "drift",
    "interactions"}`` and records the drift as the
    ``repro_traffic_model_drift{strategy,layout}`` gauge plus an
    ``|drift|`` histogram per (strategy, layout)."""
    if modelled is None:
        modelled = candidate_cost(domain, m_c,
                                  _avg_ppc(domain, positions, counts),
                                  strategy, subbox=subbox, compact=compact,
                                  fill=fill, layout=layout)
    meas = measured_traffic(domain, positions, strategy=strategy, m_c=m_c,
                            layout=layout, compact=compact, subbox=subbox,
                            counts=counts, valid=valid)
    drift = model_drift(float(modelled), meas.bytes_per_interaction)
    labels = dict(strategy=meas.strategy + ("_compact" if compact else ""),
                  layout=layout)
    _metrics.registry.gauge(DRIFT_GAUGE, **labels).set(
        0.0 if math.isnan(drift) else drift)
    if not math.isnan(drift):
        _metrics.registry.histogram(DRIFT_HIST, **labels).observe(
            abs(drift))
    _trace_event("traffic.audit", modelled_bpi=float(modelled),
                 measured_bpi=meas.bytes_per_interaction, drift=drift,
                 **labels)
    return {"modelled_bpi": float(modelled),
            "measured_bpi": meas.bytes_per_interaction,
            "drift": drift, "interactions": meas.interactions}


def _avg_ppc(domain: Domain, positions, counts) -> float:
    if counts is not None:
        return float(np.asarray(counts).sum()) / domain.n_cells
    return positions.shape[0] / domain.n_cells
