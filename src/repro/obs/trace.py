"""Structured tracing spans and events (the ``obs`` ring buffer).

One process-wide tracer, off by default. When enabled (``obs.enable()``,
the ``obs.tracing()`` context manager, or the ``REPRO_OBS_TRACE``
environment variable), instrumented code records *spans* — named,
attributed durations from ``with obs.trace(name, **attrs):`` — and
instantaneous *events* (``obs.event(name, **attrs)``) into a bounded
in-memory ring buffer. When disabled, ``trace()`` returns a shared no-op
span and ``event()`` returns immediately: the hot path
(``InteractionPlan.execute``) pays one predicate per dispatch and records
nothing — the zero-overhead contract ``tests/test_obs.py`` asserts.

Exports: :func:`export_jsonl` (one JSON object per record) and
:func:`export_chrome_trace` (Chrome ``trace_event`` JSON — load it at
``chrome://tracing`` or https://ui.perfetto.dev). ``tools/trace_view.py``
converts and summarizes the JSONL form offline.

Record schema (the JSONL form)::

    {"name": "plan.execute", "ph": "X",     # "X" span | "i" instant
     "ts": 0.0123,                          # seconds since enable()
     "dur": 0.0004,                         # seconds (spans only)
     "tid": 140023, "attrs": {...}}

The buffer is a ``collections.deque(maxlen=capacity)``: a long run keeps
the newest ``capacity`` records and counts what it dropped
(:func:`stats`), so tracing can stay on for a whole benchmark without
unbounded memory.
"""

from __future__ import annotations

import collections
import json
import os
import pathlib
import threading
import time
from typing import Deque, Dict, List, Optional

__all__ = ["trace", "event", "enable", "disable", "tracing",
           "tracing_enabled", "spans", "clear", "stats",
           "export_jsonl", "export_chrome_trace", "DEFAULT_CAPACITY"]

DEFAULT_CAPACITY = 65536

_enabled = False
_buf: Deque[dict] = collections.deque(maxlen=DEFAULT_CAPACITY)
_t0 = 0.0
_total = 0                 # records ever offered (drops = _total - len(_buf))


def tracing_enabled() -> bool:
    """True while the tracer records (the one predicate hot paths pay)."""
    return _enabled


def enable(capacity: Optional[int] = None) -> None:
    """Turn tracing on. ``capacity`` resizes the ring buffer (existing
    records are kept up to the new bound); the time origin is set on the
    first enable only, so re-enabling composes with earlier records."""
    global _enabled, _buf, _t0
    if capacity is not None and capacity != _buf.maxlen:
        _buf = collections.deque(_buf, maxlen=int(capacity))
    if not _enabled and _t0 == 0.0:
        _t0 = time.perf_counter()
    _enabled = True


def disable() -> None:
    """Turn tracing off (records are kept; ``clear()`` drops them)."""
    global _enabled
    _enabled = False


def clear() -> None:
    """Drop every recorded span/event and reset the drop accounting."""
    global _total, _t0
    _buf.clear()
    _total = 0
    _t0 = time.perf_counter() if _enabled else 0.0


def spans() -> List[dict]:
    """The recorded span/event dicts, oldest first (a copy)."""
    return list(_buf)


def stats() -> Dict[str, int]:
    """Ring-buffer accounting: recorded / capacity / dropped."""
    return {"recorded": len(_buf), "capacity": int(_buf.maxlen or 0),
            "dropped": _total - len(_buf), "enabled": int(_enabled)}


class tracing:
    """Context manager: tracing on inside, restored outside.

    >>> with obs.tracing():
    ...     plan.execute(state)
    ... obs.export_chrome_trace("trace.json")
    """

    def __init__(self, capacity: Optional[int] = None):
        self._capacity = capacity
        self._was = False

    def __enter__(self):
        self._was = _enabled
        enable(self._capacity)
        return self

    def __exit__(self, exc_type, exc, tb):
        if not self._was:
            disable()
        return False


def _record(rec: dict) -> None:
    global _total
    _total += 1
    _buf.append(rec)


class _Span:
    """A live span: ``with obs.trace(name, **attrs) as sp: sp.set(...)``.
    Recorded at exit; an exception inside marks ``attrs["error"]``."""

    __slots__ = ("name", "attrs", "_start")

    def __init__(self, name: str, attrs: dict):
        self.name = name
        self.attrs = attrs
        self._start = 0.0

    def set(self, **attrs) -> "_Span":
        """Annotate the span mid-flight (no-op on the disabled tracer)."""
        self.attrs.update(attrs)
        return self

    def __enter__(self) -> "_Span":
        self._start = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb):
        end = time.perf_counter()
        if exc_type is not None:
            self.attrs["error"] = exc_type.__name__
        _record({"name": self.name, "ph": "X", "ts": self._start - _t0,
                 "dur": end - self._start, "tid": threading.get_ident(),
                 "attrs": self.attrs})
        return False


class _NullSpan:
    """The shared disabled-tracer span: every operation is a no-op."""

    __slots__ = ()

    def set(self, **attrs) -> "_NullSpan":
        return self

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, exc_type, exc, tb):
        return False


_NULL = _NullSpan()


def trace(name: str, **attrs):
    """A span context manager around a named operation.

    Cheap by construction: when tracing is disabled this returns one
    shared no-op object — no allocation, no clock read, nothing recorded.
    Attribute values should be JSON-able scalars (str/int/float/bool)."""
    if not _enabled:
        return _NULL
    return _Span(name, attrs)


def event(name: str, **attrs) -> None:
    """Record one instantaneous event (Chrome ``ph: "i"``)."""
    if not _enabled:
        return
    _record({"name": name, "ph": "i", "ts": time.perf_counter() - _t0,
             "tid": threading.get_ident(), "attrs": attrs})


# --------------------------------------------------------------------------
# export
# --------------------------------------------------------------------------

def export_jsonl(path) -> int:
    """Write the buffer as JSON Lines (one record per line). -> count."""
    p = pathlib.Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    recs = spans()
    with open(p, "w") as f:
        for rec in recs:
            f.write(json.dumps(rec, default=str) + "\n")
    return len(recs)


def chrome_events(records: Optional[List[dict]] = None) -> List[dict]:
    """The buffer (or ``records`` in the JSONL schema) as Chrome
    ``trace_event`` dicts — ``ts``/``dur`` in microseconds, span records
    as complete ("X") events, instants as "i" (thread scope)."""
    pid = os.getpid()
    out = []
    for rec in (spans() if records is None else records):
        ev = {"name": rec["name"], "ph": rec["ph"],
              "ts": rec["ts"] * 1e6, "pid": pid, "tid": rec["tid"],
              "args": rec.get("attrs", {})}
        if rec["ph"] == "X":
            ev["dur"] = rec.get("dur", 0.0) * 1e6
        else:
            ev["s"] = "t"
        out.append(ev)
    return out


def export_chrome_trace(path, records: Optional[List[dict]] = None) -> int:
    """Write the buffer (or ``records``) as a Chrome ``trace_event`` file
    (``{"traceEvents": [...]}``) viewable at ``chrome://tracing`` or
    https://ui.perfetto.dev. -> event count."""
    p = pathlib.Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    evs = chrome_events(records)
    with open(p, "w") as f:
        json.dump({"traceEvents": evs,
                   "displayTimeUnit": "ms"}, f, default=str)
    return len(evs)


if os.environ.get("REPRO_OBS_TRACE", "").strip() not in ("", "0"):
    enable()
