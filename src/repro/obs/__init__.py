"""Unified observability: tracing spans, metrics registry, traffic audit.

One subsystem, three instruments, shared by every layer
(execute / autotune / serve / trajectory / dist):

* **Spans & events** (:mod:`repro.obs.trace`) — ``obs.trace(name,
  **attrs)`` context-manager spans and ``obs.event(...)`` instants in a
  bounded ring buffer; off by default, exportable as JSONL or Chrome
  ``trace_event`` JSON (``obs.export_chrome_trace`` /
  ``tools/trace_view.py``).
* **Metrics registry** (:mod:`repro.obs.metrics`) — labeled counters /
  gauges / histograms behind the historical counter shims
  (``core.api.dispatch_count`` etc.), rendered by ``obs.render_prom()``
  / ``obs.snapshot()``.
* **Profiling + traffic audit** (:mod:`repro.obs.profile`,
  :mod:`repro.obs.audit`) — ``obs.profile(plan, state)`` and the
  model-vs-measured "model drift" metric the autotuner records with
  every prune decision.

``trace``/``metrics`` import nothing from the library, so ``core.api``
can depend on them without cycles; ``audit``/``profile`` (which import
``core``) are loaded lazily on first attribute access (PEP 562).
"""

from __future__ import annotations

from .metrics import (Counter, Gauge, Histogram, MetricsRegistry, registry,
                      render_prom, snapshot)
from .trace import (DEFAULT_CAPACITY, chrome_events, clear, disable, enable,
                    event, export_chrome_trace, export_jsonl, spans, stats,
                    trace, tracing, tracing_enabled)

__all__ = [
    # trace
    "trace", "event", "enable", "disable", "tracing", "tracing_enabled",
    "spans", "clear", "stats", "export_jsonl", "export_chrome_trace",
    "chrome_events", "DEFAULT_CAPACITY",
    # metrics
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "registry",
    "render_prom", "snapshot",
    # lazy: audit + profile
    "MeasuredTraffic", "measured_traffic", "neighbor_pair_count",
    "model_drift", "audit_candidate", "profile", "ProfileReport",
]

_LAZY = {
    "MeasuredTraffic": "audit", "measured_traffic": "audit",
    "neighbor_pair_count": "audit", "model_drift": "audit",
    "audit_candidate": "audit",
    "profile": "profile", "ProfileReport": "profile",
}


def __getattr__(name):
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib
    value = getattr(importlib.import_module(f".{mod}", __name__), name)
    globals()[name] = value
    return value


def __dir__():
    return sorted(set(globals()) | set(_LAZY))
