"""Kernel profiling hooks: ``obs.profile(plan, state)``.

A one-call harness around a plan's hot path: times ``plan.execute`` with
the library stopwatch (compile excluded, ``core.timing.time_fn``), wraps
the timed region in ``jax.profiler.trace`` when a profiler trace
directory is requested (and the profiler is actually available — it is
optional at runtime, so the harness degrades to timing-only instead of
raising), and runs the model-vs-measured traffic audit
(:mod:`repro.obs.audit`) on the same positions. Results land in three
places at once: the returned :class:`ProfileReport`, a
``plan.profile`` span in the tracer, and the registry
(``repro_execute_seconds`` histogram + the model-drift gauge), so a
benchmark, a dashboard and an interactive session all read the same
numbers.
"""

from __future__ import annotations

import contextlib
import dataclasses
import math
from typing import Optional

from . import audit as _audit
from . import metrics as _metrics
from .trace import trace as _trace_span

__all__ = ["ProfileReport", "profile"]

EXEC_HIST = "repro_execute_seconds"


@dataclasses.dataclass(frozen=True)
class ProfileReport:
    """What one :func:`profile` call measured."""

    seconds_per_call: float
    reps: int
    backend: str
    strategy: str
    layout: str
    modelled_bpi: float          # traffic model's bytes / interaction
    measured_bpi: float          # occupancy-probe measured estimate
    drift: float                 # measured / modelled - 1
    interactions: float          # measured candidate pair slots
    profiler_dir: Optional[str]  # jax.profiler trace dir (None = not run)


def _jax_profiler(trace_dir: Optional[str]):
    """``jax.profiler.trace`` as an optional context manager: None
    ``trace_dir`` (or an unavailable profiler backend) degrades to a
    null context instead of failing the profile run."""
    if trace_dir is None:
        return contextlib.nullcontext(), None
    try:
        import jax.profiler
        return jax.profiler.trace(str(trace_dir)), str(trace_dir)
    except Exception:                       # pragma: no cover - env specific
        return contextlib.nullcontext(), None


def profile(plan, state, *, reps: Optional[int] = None,
            budget_s: float = 0.2,
            trace_dir: Optional[str] = None) -> ProfileReport:
    """Time one plan on one state, audit the traffic model, record both.

    ``trace_dir`` requests a ``jax.profiler`` trace of the timed region
    (viewable in TensorBoard / Perfetto); without it — or when the
    profiler cannot start in this environment — the harness still times
    and audits. The stopwatch excludes compile exactly as the autotuner's
    does."""
    from ..core.timing import time_fn

    ctx, prof_dir = _jax_profiler(trace_dir)
    with _trace_span("plan.profile", backend=plan.backend,
                     strategy=plan.strategy, layout=plan.layout) as sp:
        with ctx:
            secs, r = time_fn(plan.execute, state, reps=reps,
                              budget_s=budget_s)
        sp.set(seconds_per_call=secs, reps=r)

    fill = 1.0
    if plan.compact:
        from ..core.api import active_unit_count, n_units
        fill = (active_unit_count(plan.domain, state.positions,
                                  plan.strategy, box=plan.box)
                / max(n_units(plan.domain, plan.strategy, box=plan.box), 1))
    try:
        aud = _audit.audit_candidate(
            plan.domain, state.positions, strategy=plan.strategy,
            m_c=plan.m_c, layout=plan.layout, compact=plan.compact,
            subbox=plan.box, fill=fill, valid=state.valid)
    except ValueError:           # e.g. naive_n2 twins without an estimate
        aud = {"modelled_bpi": math.nan, "measured_bpi": math.nan,
               "drift": math.nan, "interactions": math.nan}

    _metrics.registry.histogram(
        EXEC_HIST, backend=plan.backend, strategy=plan.strategy,
        layout=plan.layout).observe(secs)
    return ProfileReport(
        seconds_per_call=secs, reps=r, backend=plan.backend,
        strategy=plan.strategy, layout=plan.layout,
        modelled_bpi=aud["modelled_bpi"], measured_bpi=aud["measured_bpi"],
        drift=aud["drift"], interactions=aud["interactions"],
        profiler_dir=prof_dir)
