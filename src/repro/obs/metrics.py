"""The metrics registry: named counters / gauges / histograms with labels.

One process-wide :class:`MetricsRegistry` (``obs.registry``) replaces the
ad-hoc per-module counter globals that grew across the subsystems
(``core.api._dispatches``/``_recompiles``, ``autotune._timing_runs``).
The public counter functions (``core.api.dispatch_count`` /
``recompile_count`` / ``reset_counters``, ``autotune.timing_run_count``)
are thin shims over it, so every pre-existing assertion keeps its
semantics while ``obs.render_prom()`` / ``obs.snapshot()`` expose the
same numbers — labeled by (backend, strategy, layout, n_shards, ...) —
to dashboards and benchmark sidecars.

Conventions (Prometheus-style):

* counter names end in ``_total`` and only go up (until ``reset()``);
* gauges are set to the current value (the serving tier mirrors its
  ``ServeMetrics`` counters here as ``serve_*`` gauges);
* histograms keep a bounded summary (count / sum / min / max), rendered
  as a Prometheus *summary* pair (``_count`` / ``_sum``) plus min/max
  gauges — serving benchmarks keep raw samples in ``LatencyStats``, so
  bucketed precision is not needed here.

``reset()`` zeroes every instrument **in place** — objects handed out by
``counter()``/``gauge()``/``histogram()`` stay live, so cached references
in hot paths survive a reset. ``reset(name)`` zeroes one metric family
(e.g. only the autotune timing-run counter).
"""

from __future__ import annotations

import math
from typing import Dict, Iterable, List, Optional, Tuple

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry", "registry",
           "render_prom", "snapshot"]

LabelKey = Tuple[Tuple[str, str], ...]


class Counter:
    """Monotonic counter (until a registry reset)."""

    __slots__ = ("value",)
    kind = "counter"

    def __init__(self):
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def _zero(self) -> None:
        self.value = 0.0

    def _render(self) -> float:
        return self.value


class Gauge:
    """A value that goes up and down; ``set()`` is last-writer-wins."""

    __slots__ = ("value",)
    kind = "gauge"

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def _zero(self) -> None:
        self.value = 0.0

    def _render(self) -> float:
        return self.value


class Histogram:
    """Bounded distribution summary: count / sum / min / max."""

    __slots__ = ("count", "total", "vmin", "vmax")
    kind = "histogram"

    def __init__(self):
        self._zero()

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        self.vmin = v if math.isnan(self.vmin) else min(self.vmin, v)
        self.vmax = v if math.isnan(self.vmax) else max(self.vmax, v)

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else math.nan

    def _zero(self) -> None:
        self.count = 0
        self.total = 0.0
        self.vmin = math.nan
        self.vmax = math.nan

    def _render(self) -> Dict[str, float]:
        return {"count": self.count, "sum": self.total,
                "min": self.vmin, "max": self.vmax}


def _label_key(labels: Dict[str, object]) -> LabelKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _label_str(key: LabelKey) -> str:
    if not key:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in key)
    return "{" + inner + "}"


class MetricsRegistry:
    """Named, labeled metric families (see module docstring).

    ``counter(name, **labels)`` (and ``gauge``/``histogram``) return the
    live instrument for that (name, label set), creating it on first use;
    re-registering a name under a different kind is an error — one name,
    one kind, any number of label sets.
    """

    def __init__(self):
        self._metrics: Dict[str, Dict[LabelKey, object]] = {}
        self._kinds: Dict[str, type] = {}

    def _get(self, cls, name: str, labels: Dict[str, object]):
        kind = self._kinds.setdefault(name, cls)
        if kind is not cls:
            raise ValueError(
                f"metric {name!r} already registered as {kind.kind}, "
                f"not {cls.kind}")
        family = self._metrics.setdefault(name, {})
        key = _label_key(labels)
        inst = family.get(key)
        if inst is None:
            inst = family[key] = cls()
        return inst

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get(Histogram, name, labels)

    # -- read side ---------------------------------------------------------

    def names(self) -> List[str]:
        return sorted(self._metrics)

    def total(self, name: str) -> float:
        """Sum of a counter/gauge family across all label sets (0.0 when
        the family does not exist yet — reads never create)."""
        family = self._metrics.get(name)
        if not family:
            return 0.0
        return sum(m.value if not isinstance(m, Histogram) else m.count
                   for m in family.values())

    def get(self, name: str, **labels):
        """The live instrument for one (name, labels), or None."""
        return self._metrics.get(name, {}).get(_label_key(labels))

    def snapshot(self) -> Dict[str, Dict[str, object]]:
        """Everything, JSON-able: ``{name: {label_str: value}}`` (scalar
        for counters/gauges, a count/sum/min/max dict for histograms)."""
        return {name: {_label_str(k): m._render()
                       for k, m in sorted(family.items())}
                for name, family in sorted(self._metrics.items())}

    def render_prom(self) -> str:
        """Prometheus text exposition of every family (histograms as the
        summary subset: ``_count``/``_sum`` plus min/max gauges)."""
        lines: List[str] = []
        for name in self.names():
            cls = self._kinds[name]
            family = self._metrics[name]
            if cls is Histogram:
                lines.append(f"# TYPE {name} summary")
                for key, m in sorted(family.items()):
                    ls = _label_str(key)
                    lines.append(f"{name}_count{ls} {m.count}")
                    lines.append(f"{name}_sum{ls} {_fmt(m.total)}")
                    lines.append(f"{name}_min{ls} {_fmt(m.vmin)}")
                    lines.append(f"{name}_max{ls} {_fmt(m.vmax)}")
            else:
                lines.append(f"# TYPE {name} {cls.kind}")
                for key, m in sorted(family.items()):
                    lines.append(f"{name}{_label_str(key)} {_fmt(m.value)}")
        return "\n".join(lines) + ("\n" if lines else "")

    # -- reset -------------------------------------------------------------

    def reset(self, name: Optional[str] = None) -> None:
        """Zero instruments in place (cached references stay live). With
        ``name``, only that family; otherwise everything — this is what
        ``core.api.reset_counters()`` calls, so one reset clears every
        steady-state counter (dispatches, recompiles, replans, autotune
        timing runs) at once."""
        families: Iterable[Dict[LabelKey, object]]
        if name is not None:
            families = ([self._metrics[name]] if name in self._metrics
                        else [])
        else:
            families = self._metrics.values()
        for family in families:
            for m in family.values():
                m._zero()


def _fmt(v: float) -> str:
    # NaN first: an empty histogram's min/max render as NaN, and int(nan)
    # raises
    if isinstance(v, float) and math.isfinite(v) and v == int(v) \
            and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


registry = MetricsRegistry()


def snapshot() -> Dict[str, Dict[str, object]]:
    """``obs.snapshot()`` — the process registry as one JSON-able dict."""
    return registry.snapshot()


def render_prom() -> str:
    """``obs.render_prom()`` — the process registry as Prometheus text."""
    return registry.render_prom()
