"""Time integrators driving the interaction engine (MD/SPH substrate).

Ported onto the fused trajectory engine (``repro.traj``): ``run`` with an
:class:`~repro.core.api.InteractionPlan` on a cell schedule routes through
``plan.trajectory`` — one jitted ``lax.scan`` per segment with Verlet-skin
neighbor reuse — so ``examples/`` and ``physics.sph`` stop paying a full
binning pass per step. The legacy per-step scan is kept for the
``CellListEngine`` shim and the non-cell schedules.

Deprecation note: ``velocity_verlet`` / ``leapfrog`` (single-step
factories) and the legacy ``run`` path recompute forces from scratch
every step. They remain for compatibility and for engines the trajectory
contract excludes; new code should call ``plan.trajectory`` (or ``run``,
which forwards to it) and get neighbor reuse, invariant monitors and
checkpoint/resume for free.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Tuple, Union

import jax
import jax.numpy as jnp

from ..core.api import InteractionPlan, ParticleState
from ..core.domain import Domain
from ..core.engine import CellListEngine

Array = jnp.ndarray
Engine = Union[InteractionPlan, CellListEngine]


def _forces_fn(engine: Engine) -> Callable[[Array], Tuple[Array, Array]]:
    if isinstance(engine, InteractionPlan):
        return lambda pos: engine.execute(ParticleState(pos))
    return engine.compute


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class MDState:
    positions: Array   # (N, 3)
    velocities: Array  # (N, 3)
    forces: Array      # (N, 3)
    potential: Array   # (N,)
    step: Array        # scalar int32


def init_state(engine: Engine, positions: Array,
               velocities: Array | None = None) -> MDState:
    if velocities is None:
        velocities = jnp.zeros_like(positions)
    forces, pot = _forces_fn(engine)(positions)
    return MDState(positions, velocities, forces, pot,
                   jnp.zeros((), jnp.int32))


def _wrap(domain: Domain, positions: Array) -> Array:
    if not domain.any_periodic:
        return positions
    box = jnp.asarray(domain.box, dtype=positions.dtype)
    per = jnp.asarray(domain.periodic_axes)
    return jnp.where(per, jnp.mod(positions, box), positions)


def velocity_verlet(engine: Engine, dt: float, mass: float = 1.0
                    ) -> Callable[[MDState], MDState]:
    """Symplectic velocity-Verlet step. One force evaluation per step.

    Deprecated for multi-step runs: each step re-bins from scratch. Use
    ``plan.trajectory`` / :func:`run`, which fuse the loop with
    Verlet-skin neighbor reuse; this factory remains for single-step use
    and non-plan engines."""
    inv_m = 1.0 / mass
    compute = _forces_fn(engine)

    def step(state: MDState) -> MDState:
        v_half = state.velocities + (0.5 * dt * inv_m) * state.forces
        pos = _wrap(engine.domain, state.positions + dt * v_half)
        forces, pot = compute(pos)
        vel = v_half + (0.5 * dt * inv_m) * forces
        return MDState(pos, vel, forces, pot, state.step + 1)

    return step


def leapfrog(engine: Engine, dt: float, mass: float = 1.0
             ) -> Callable[[MDState], MDState]:
    """Leapfrog (kick-drift) step. Same deprecation note as
    :func:`velocity_verlet`: prefer ``plan.trajectory`` for runs."""
    inv_m = 1.0 / mass
    compute = _forces_fn(engine)

    def step(state: MDState) -> MDState:
        vel = state.velocities + dt * inv_m * state.forces
        pos = _wrap(engine.domain, state.positions + dt * vel)
        forces, pot = compute(pos)
        return MDState(pos, vel, forces, pot, state.step + 1)

    return step


def run(engine: Engine, state: MDState, n_steps: int, dt: float,
        mass: float = 1.0, integrator: str = "velocity_verlet",
        **traj_opts) -> Tuple[MDState, dict]:
    """Run ``n_steps`` under jit; returns ``(final_state, traces)``.

    An :class:`InteractionPlan` on a cell schedule (single shard) runs on
    the fused trajectory engine — Verlet-skin neighbor reuse, invariant
    monitors, optional checkpointing via ``traj_opts`` (``skin=``,
    ``checkpoint_dir=``, ``energy_budget=``, ...; see
    :func:`repro.traj.engine.run_trajectory`). Everything else (the
    ``CellListEngine`` shim, ``par_part`` / ``naive_n2`` plans) keeps the
    legacy per-step scan, which recomputes forces from scratch each step.
    """
    from ..traj.engine import TRAJ_STRATEGIES

    if (isinstance(engine, InteractionPlan)
            and engine.strategy in TRAJ_STRATEGIES
            and not engine._multi_shard):
        res = engine.trajectory(state, n_steps, dt, integrator=integrator,
                                mass=mass, **traj_opts)
        traces = {k: jnp.asarray(res.traces[k])
                  for k in ("kinetic", "potential", "total")}
        return res.state, traces
    if traj_opts:
        raise ValueError(
            f"trajectory options {sorted(traj_opts)} need an "
            "InteractionPlan on a cell schedule; this engine runs the "
            "legacy per-step scan")

    if integrator not in ("velocity_verlet", "leapfrog"):
        raise ValueError(
            f"integrator {integrator!r} needs an InteractionPlan on a "
            "cell schedule (the fused trajectory path); the legacy "
            "per-step scan only supports 'velocity_verlet' and 'leapfrog'")
    step = (velocity_verlet if integrator == "velocity_verlet"
            else leapfrog)(engine, dt, mass)

    def body(state, _):
        new = step(state)
        ke = 0.5 * mass * jnp.sum(new.velocities ** 2)
        pe = 0.5 * jnp.sum(new.potential)
        return new, {"kinetic": ke, "potential": pe, "total": ke + pe}

    return jax.lax.scan(body, state, None, length=n_steps)
