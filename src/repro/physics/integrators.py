"""Time integrators driving the interaction engine (MD/SPH substrate).

Ported to the plan/execute API: every entry point accepts either an
:class:`~repro.core.api.InteractionPlan` (the front door) or the legacy
``CellListEngine`` shim — both expose the same ``(positions) -> (forces,
potential)`` hot path under jit.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Tuple, Union

import jax
import jax.numpy as jnp

from ..core.api import InteractionPlan, ParticleState
from ..core.domain import Domain
from ..core.engine import CellListEngine

Array = jnp.ndarray
Engine = Union[InteractionPlan, CellListEngine]


def _forces_fn(engine: Engine) -> Callable[[Array], Tuple[Array, Array]]:
    if isinstance(engine, InteractionPlan):
        return lambda pos: engine.execute(ParticleState(pos))
    return engine.compute


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class MDState:
    positions: Array   # (N, 3)
    velocities: Array  # (N, 3)
    forces: Array      # (N, 3)
    potential: Array   # (N,)
    step: Array        # scalar int32


def init_state(engine: Engine, positions: Array,
               velocities: Array | None = None) -> MDState:
    if velocities is None:
        velocities = jnp.zeros_like(positions)
    forces, pot = _forces_fn(engine)(positions)
    return MDState(positions, velocities, forces, pot,
                   jnp.zeros((), jnp.int32))


def _wrap(domain: Domain, positions: Array) -> Array:
    if not domain.any_periodic:
        return positions
    box = jnp.asarray(domain.box, dtype=positions.dtype)
    per = jnp.asarray(domain.periodic_axes)
    return jnp.where(per, jnp.mod(positions, box), positions)


def velocity_verlet(engine: Engine, dt: float, mass: float = 1.0
                    ) -> Callable[[MDState], MDState]:
    """Symplectic velocity-Verlet step. One force evaluation per step."""
    inv_m = 1.0 / mass
    compute = _forces_fn(engine)

    def step(state: MDState) -> MDState:
        v_half = state.velocities + (0.5 * dt * inv_m) * state.forces
        pos = _wrap(engine.domain, state.positions + dt * v_half)
        forces, pot = compute(pos)
        vel = v_half + (0.5 * dt * inv_m) * forces
        return MDState(pos, vel, forces, pot, state.step + 1)

    return step


def leapfrog(engine: Engine, dt: float, mass: float = 1.0
             ) -> Callable[[MDState], MDState]:
    inv_m = 1.0 / mass
    compute = _forces_fn(engine)

    def step(state: MDState) -> MDState:
        vel = state.velocities + dt * inv_m * state.forces
        pos = _wrap(engine.domain, state.positions + dt * vel)
        forces, pot = compute(pos)
        return MDState(pos, vel, forces, pot, state.step + 1)

    return step


def run(engine: Engine, state: MDState, n_steps: int, dt: float,
        mass: float = 1.0, integrator: str = "velocity_verlet",
        ) -> Tuple[MDState, dict]:
    """Run ``n_steps`` under jit (lax.scan); returns final state + traces."""
    step = (velocity_verlet if integrator == "velocity_verlet"
            else leapfrog)(engine, dt, mass)

    def body(state, _):
        new = step(state)
        ke = 0.5 * mass * jnp.sum(new.velocities ** 2)
        pe = 0.5 * jnp.sum(new.potential)
        return new, {"kinetic": ke, "potential": pe, "total": ke + pe}

    return jax.lax.scan(body, state, None, length=n_steps)
