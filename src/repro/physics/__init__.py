"""MD/SPH substrate on top of the cell-list engine."""

from .integrators import MDState, init_state, leapfrog, run, velocity_verlet
from .observables import (kinetic_energy, potential_energy, temperature,
                          total_energy, total_momentum)
from . import sph

__all__ = ["MDState", "init_state", "leapfrog", "run", "velocity_verlet",
           "kinetic_energy", "potential_energy", "temperature",
           "total_energy", "total_momentum", "sph"]
