"""Scalar observables for MD/SPH runs (conservation checks in tests)."""

from __future__ import annotations

import jax.numpy as jnp

Array = jnp.ndarray


def kinetic_energy(velocities: Array, mass: float = 1.0) -> Array:
    return 0.5 * mass * jnp.sum(velocities ** 2)


def potential_energy(per_particle_potential: Array) -> Array:
    """Pairs are counted twice across particles (paper's convention)."""
    return 0.5 * jnp.sum(per_particle_potential)


def total_energy(velocities: Array, per_particle_potential: Array,
                 mass: float = 1.0) -> Array:
    return kinetic_energy(velocities, mass) + potential_energy(
        per_particle_potential)


def total_momentum(velocities: Array, mass: float = 1.0) -> Array:
    return mass * jnp.sum(velocities, axis=0)


def temperature(velocities: Array, mass: float = 1.0) -> Array:
    n = velocities.shape[0]
    return 2.0 * kinetic_energy(velocities, mass) / (3.0 * n)
