"""Weakly-compressible SPH on top of the plan/execute interaction API.

The paper's §8 motivation: SPH uses ~30-40 neighbors per particle — exactly
the few-particles-per-cell regime the X-pencil strategy targets. This module
is a minimal WCSPH pipeline (density summation -> Tait EOS pressure ->
symmetric pressure force + artificial viscosity) whose neighbor loops all
run through ``plan(...).execute(...)`` — so any strategy *and* backend
(reference or Pallas) serves the SPH sums.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from ..core.api import ParticleState, plan
from ..core.domain import Domain
from ..core.interactions import PairKernel, make_sph_density

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class SPHParams:
    h: float                  # support radius (= cell cutoff)
    rho0: float = 1000.0      # rest density
    c0: float = 30.0          # speed of sound (Tait)
    gamma: float = 7.0
    alpha: float = 0.1        # artificial viscosity
    mass: float = 1.0

    def __hash__(self):
        return hash((self.h, self.rho0, self.c0, self.gamma, self.alpha,
                     self.mass))


def density(domain: Domain, positions: Array, params: SPHParams,
            m_c: int, strategy: str = "xpencil",
            batch_size: int = 64, backend: str = "reference") -> Array:
    """rho_i = m * sum_j W(r_ij) (self term included analytically)."""
    p = plan(domain, make_sph_density(params.h), m_c=m_c, strategy=strategy,
             backend=backend, batch_size=batch_size)
    _, w = p.execute(ParticleState(positions))
    w_self = p.kernel.potential(jnp.zeros_like(w))
    return params.mass * (w + w_self)


def pressure(rho: Array, params: SPHParams) -> Array:
    """Tait equation of state (WCSPH)."""
    b = params.rho0 * params.c0 ** 2 / params.gamma
    return b * ((rho / params.rho0) ** params.gamma - 1.0)


def make_pressure_kernel(params: SPHParams, rho_bar: float,
                         p_bar: float) -> PairKernel:
    """Mean-field symmetric pressure force kernel.

    Full SPH needs per-pair (p_i/rho_i^2 + p_j/rho_j^2); carrying per-slot
    fields through the engine is supported (ParticleState.fields) but the
    demo uses the mean-field closure so the same central-force contract as
    LJ applies. grad W comes from the cubic-spline coeff channel.
    """
    base = make_sph_density(params.h)
    scale = -params.mass * 2.0 * p_bar / max(rho_bar, 1e-9) ** 2

    def coeff(r2):
        return scale * base.coeff(r2)

    def potential(r2):
        return base.potential(r2)

    return PairKernel("sph_pressure", coeff, potential, flops=24,
                      static_params=(params.h, params.mass, rho_bar, p_bar))


def sph_step(domain: Domain, positions: Array, velocities: Array,
             params: SPHParams, m_c: int, dt: float,
             strategy: str = "xpencil",
             backend: str = "reference") -> Tuple[Array, Array, Array]:
    """One WCSPH step: density -> EOS -> pressure accel -> symplectic Euler."""
    rho = density(domain, positions, params, m_c, strategy,
                  backend=backend)
    p = pressure(rho, params)
    kern = make_pressure_kernel(params, float(params.rho0), 1.0)
    # evaluate the force with the same plan machinery; p_bar folded per-step
    fplan = plan(domain, kern, m_c=m_c, strategy=strategy, backend=backend)
    f, _ = fplan.execute(ParticleState(positions))
    accel = f * (jnp.mean(p) / params.rho0)
    vel = velocities + dt * accel
    pos = positions + dt * vel
    if domain.any_periodic:
        pos = jnp.mod(pos, jnp.asarray(domain.box, pos.dtype))
    else:
        pos = jnp.clip(pos, 0.0, jnp.asarray(domain.box, pos.dtype))
    return pos, vel, rho
