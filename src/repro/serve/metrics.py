"""Latency observability for the serving tier.

Everything the front door needs to answer "how is serving going" without
scraping JAX internals: per-request latency histograms (queue / dispatch /
total), throughput (requests per second), batch-fill fraction, and the two
staleness counters the steady-state guarantee is asserted against —
executor recompiles (``core.api.recompile_count``) and autotune stopwatch
runs (``core.autotune.timing_run_count``). The engine feeds these; tests
and ``benchmarks/fig_serve.py`` read ``snapshot()``.

Timestamps come from an injectable clock so benchmarks can drive an
open-loop simulated workload: :class:`VirtualClock` advances only when told
to (arrivals jump it to the schedule, dispatches advance it by the *real*
measured compute time), which makes queueing delay well-defined without
running wall-clock-long experiments.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional

from ..obs import metrics as _obs_metrics

__all__ = ["LatencyStats", "ServeMetrics", "VirtualClock", "percentile"]

# ServeMetrics int counter fields mirrored into the process metrics
# registry as ``serve_<field>`` gauges (gauges, not counters: the mirror is
# last-writer-wins across engines, and ``breaker_open_classes`` already has
# gauge semantics). The dataclass stays the serving tier's source of truth
# — the mirror only makes ``obs.render_prom()`` / ``obs.snapshot()`` show
# serving next to the core counters.
_MIRRORED_FIELDS = frozenset({
    "submitted", "served", "rejected", "shed", "batches", "recompiles",
    "replans", "autotune_timing_runs", "autotune_cache_hits",
    "deadline_expired", "failed", "faults", "nonfinite_batches", "retries",
    "breaker_opens", "breaker_closes", "breaker_open_classes",
})


def percentile(samples: List[float], p: float) -> float:
    """Linear-interpolated percentile (numpy's default rule) of raw
    samples; NaN on an empty list so a missing series is visible, not a
    silent zero."""
    if not samples:
        return math.nan
    xs = sorted(samples)
    if len(xs) == 1:
        return xs[0]
    rank = (p / 100.0) * (len(xs) - 1)
    lo = int(math.floor(rank))
    hi = min(lo + 1, len(xs) - 1)
    frac = rank - lo
    return xs[lo] * (1.0 - frac) + xs[hi] * frac


@dataclasses.dataclass
class LatencyStats:
    """One latency series: raw samples plus the summary the BENCH record
    wants (p50/p99/mean). Samples are kept raw — serving benchmarks run
    thousands of requests, not millions, and exact percentiles beat bucket
    error at that scale."""

    samples: List[float] = dataclasses.field(default_factory=list)

    def record(self, seconds: float) -> None:
        self.samples.append(float(seconds))

    @property
    def count(self) -> int:
        return len(self.samples)

    @property
    def mean(self) -> float:
        return (sum(self.samples) / len(self.samples) if self.samples
                else math.nan)

    def p(self, q: float) -> float:
        return percentile(self.samples, q)

    def summary(self) -> Dict[str, float]:
        return {"count": self.count, "mean_s": self.mean,
                "p50_s": self.p(50.0), "p99_s": self.p(99.0),
                "max_s": max(self.samples) if self.samples else math.nan}


class VirtualClock:
    """A monotonic clock that moves only when told to.

    ``now()`` reads; ``advance(dt)`` moves forward; ``advance_to(t)`` jumps
    (never backward). The serving engine calls ``advance`` with the *real*
    measured compute time of each dispatched batch, so simulated arrival
    schedules compose with measured service times into honest queueing
    latencies.
    """

    def __init__(self, start: float = 0.0):
        self._t = float(start)

    def __call__(self) -> float:
        return self._t

    def now(self) -> float:
        return self._t

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError(f"clock cannot run backward (dt={dt})")
        self._t += dt
        return self._t

    def advance_to(self, t: float) -> float:
        self._t = max(self._t, float(t))
        return self._t


@dataclasses.dataclass
class ServeMetrics:
    """The serving tier's observability surface (see module docstring).

    Counters move only through the engine; ``snapshot()`` is the one read
    path (tests, the benchmark, and the README example all consume it).
    """

    # latency histograms (seconds)
    queue_latency: LatencyStats = dataclasses.field(
        default_factory=LatencyStats)        # submit -> batch dispatch
    dispatch_latency: LatencyStats = dataclasses.field(
        default_factory=LatencyStats)        # batch dispatch -> results ready
    total_latency: LatencyStats = dataclasses.field(
        default_factory=LatencyStats)        # submit -> results ready
    batch_fill: LatencyStats = dataclasses.field(
        default_factory=LatencyStats)        # real reqs / padded batch slots

    # request accounting
    submitted: int = 0
    served: int = 0
    rejected: int = 0                        # admission refused (queue full)
    shed: int = 0                            # evicted by shed-oldest policy
    batches: int = 0                         # execute_batch dispatches
    # staleness accounting (deltas of the core counters, attributed to
    # serving work only)
    recompiles: int = 0                      # executor traces
    replans: int = 0                         # per-class bound growth events
    autotune_timing_runs: int = 0            # stopwatch candidate timings
    autotune_cache_hits: int = 0             # warm winner lookups
    # resilience accounting (the retry/deadline/breaker machinery)
    deadline_expired: int = 0                # "deadline" terminal responses
    failed: int = 0                          # retry budget exhausted
    faults: int = 0                          # failed dispatch attempts
    nonfinite_batches: int = 0               # dispatches with non-finite out
    retries: int = 0                         # re-queued request attempts
    breaker_opens: int = 0                   # class quarantined to fallback
    breaker_closes: int = 0                  # class restored to primary
    breaker_open_classes: int = 0            # gauge: currently quarantined

    # throughput window
    t_first_submit: Optional[float] = None
    t_last_done: Optional[float] = None

    def __setattr__(self, name: str, value) -> None:
        object.__setattr__(self, name, value)
        if name in _MIRRORED_FIELDS:
            _obs_metrics.registry.gauge(f"serve_{name}").set(value)

    def note_submit(self, t: float) -> None:
        self.submitted += 1
        if self.t_first_submit is None:
            self.t_first_submit = t

    def note_served(self, t_submit: float, t_dispatch: float,
                    t_done: float) -> None:
        self.served += 1
        self.queue_latency.record(t_dispatch - t_submit)
        self.dispatch_latency.record(t_done - t_dispatch)
        self.total_latency.record(t_done - t_submit)
        self.t_last_done = (t_done if self.t_last_done is None
                            else max(self.t_last_done, t_done))

    @property
    def rps(self) -> float:
        """Served requests per second over the first-submit .. last-done
        window (the open-loop benchmark's throughput figure)."""
        if (self.t_first_submit is None or self.t_last_done is None
                or self.t_last_done <= self.t_first_submit):
            return math.nan
        return self.served / (self.t_last_done - self.t_first_submit)

    def snapshot(self) -> Dict[str, object]:
        """The whole observability surface as one JSON-able dict."""
        return {
            "submitted": self.submitted,
            "served": self.served,
            "rejected": self.rejected,
            "shed": self.shed,
            "batches": self.batches,
            "recompiles": self.recompiles,
            "replans": self.replans,
            "autotune_timing_runs": self.autotune_timing_runs,
            "autotune_cache_hits": self.autotune_cache_hits,
            "deadline_expired": self.deadline_expired,
            "failed": self.failed,
            "faults": self.faults,
            "nonfinite_batches": self.nonfinite_batches,
            "retries": self.retries,
            "breaker_opens": self.breaker_opens,
            "breaker_closes": self.breaker_closes,
            "breaker_open_classes": self.breaker_open_classes,
            "rps": self.rps,
            "batch_fill": self.batch_fill.mean,
            "queue_latency": self.queue_latency.summary(),
            "dispatch_latency": self.dispatch_latency.summary(),
            "total_latency": self.total_latency.summary(),
        }
