"""Trajectory serving: multi-step simulation requests without per-dispatch
re-binning.

The point-interaction front door (:class:`~repro.serve.engine.ServingEngine`)
treats every dispatch as a one-shot ``execute_batch`` — fine for force
queries, wasteful for simulation traffic, which used to be served as
``n_steps`` independent requests, each paying a full binning pass and a
queue round-trip. :class:`TrajectoryService` gives simulation requests
their own request class: one submission runs the whole fused trajectory
(``repro.traj``) under a *cached pair of plans* per
:class:`~repro.serve.bucketing.ShapeClass`:

* the **base plan** (cutoff grid) answers the parity/force contract;
* the **skin plan** (coarsened grid, Verlet-skin reuse) is what actually
  runs — built once per class, then reused, so a warm class performs
  zero recompiles across requests (asserted via ``api.recompile_count``
  in ``tests/test_traj.py``).

Requests are padded onto the class cap exactly like point requests
(``pad_state`` — masked rows bin to nothing, results are bit-identical
to unpadded execution), so any N in a class shares the cached jit traces.
When a trajectory replans mid-run (static-bound overflow), the *grown*
plan from :class:`~repro.traj.engine.TrajectoryResult` replaces the
cached one — the class absorbs the growth once instead of re-learning it
per request. With a ``checkpoint_root``, each request gets its own
checkpoint directory keyed by a caller-stable ``job_id`` and resumes
automatically on resubmission (crash-resume contract of ``repro.traj``).
"""

from __future__ import annotations

import dataclasses
import pathlib
from typing import Dict, Optional, Tuple, Union

import jax.numpy as jnp

from ..core import api
from ..core.api import InteractionPlan, ParticleState, plan as make_plan
from ..core.domain import Domain
from ..core.interactions import PairKernel
from ..physics.integrators import MDState
from ..traj.engine import (DEFAULT_SKIN_FRACTION, TrajectoryResult,
                           run_trajectory, trajectory_plan)
from .bucketing import ShapeClass, classify, pad_state

__all__ = ["TrajectoryRequest", "TrajectoryResponse", "TrajectoryService"]


@dataclasses.dataclass
class TrajectoryRequest:
    """One multi-step simulation job. ``job_id`` keys the per-request
    checkpoint directory (stable across resubmissions = resumable)."""
    job_id: str
    domain: Domain
    kernel: PairKernel
    state: ParticleState
    n_steps: int
    dt: float
    velocities: Optional[jnp.ndarray] = None
    integrator: str = "velocity_verlet"
    opts: Dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class TrajectoryResponse:
    """Terminal outcome. ``state`` is trimmed back to the request's true
    N; ``result`` keeps the full engine bookkeeping (status, faults,
    rebins, rollbacks, resumed_from...)."""
    job_id: str
    status: str
    state: Optional[MDState]
    result: Optional[TrajectoryResult]
    shape_class: str
    n: int


class TrajectoryService:
    """Shape-class-cached front door for trajectory jobs.

    Args:
      skin: Verlet skin passed to the class's skin plan (default
        ``DEFAULT_SKIN_FRACTION * cutoff`` per domain).
      checkpoint_root: when given, request ``job_id`` J checkpoints under
        ``<root>/J`` and resubmitting J resumes from its latest step.
      plan_opts: forwarded to ``api.plan`` when a class builds its base
        plan (e.g. ``strategy=``, ``layout=``).
    """

    def __init__(self, skin: Optional[float] = None,
                 checkpoint_root: Optional[Union[str, pathlib.Path]] = None,
                 **plan_opts):
        self.skin = skin
        self.checkpoint_root = (pathlib.Path(checkpoint_root)
                                if checkpoint_root is not None else None)
        self.plan_opts = plan_opts
        # class -> (base plan, skin plan); the skin plan entry is replaced
        # by result.plan after a mid-run replan (growth sticks).
        self._plans: Dict[Tuple[ShapeClass, str],
                          Tuple[InteractionPlan, InteractionPlan]] = {}
        self.jobs_served = 0
        self.replans_absorbed = 0

    # -- class plan cache --------------------------------------------------

    def _class_plans(self, sc: ShapeClass, integrator: str,
                     kernel: PairKernel, raw: ParticleState,
                     padded: ParticleState
                     ) -> Tuple[InteractionPlan, InteractionPlan]:
        key = (sc, integrator)
        if key not in self._plans:
            # bounds are measured on the real rows; the padded corner of
            # masked zero rows never occupies slots (weight-0 binning)
            base = make_plan(sc.domain, kernel, positions=raw.positions,
                             **self.plan_opts)
            skin = (self.skin if self.skin is not None
                    else DEFAULT_SKIN_FRACTION * sc.domain.cutoff)
            traj = trajectory_plan(base, skin, padded.positions,
                                   padded.valid)
            self._plans[key] = (base, traj)
        return self._plans[key]

    # -- the front door ----------------------------------------------------

    def submit(self, req: TrajectoryRequest) -> TrajectoryResponse:
        n = req.state.positions.shape[0]
        sc = classify(req.domain, req.kernel, n, tuple(req.state.fields))
        padded = pad_state(req.state, sc.n_cap)
        vel = (req.velocities if req.velocities is not None
               else jnp.zeros_like(req.state.positions))
        pad = sc.n_cap - n
        if pad:
            vel = jnp.pad(vel, ((0, pad), (0, 0)))

        base, traj = self._class_plans(sc, req.integrator, req.kernel,
                                       req.state, padded)
        opts = dict(req.opts)
        if self.checkpoint_root is not None:
            opts.setdefault("checkpoint_dir",
                            self.checkpoint_root / req.job_id)
        res = run_trajectory(base, padded, req.n_steps, req.dt,
                             integrator=req.integrator, velocities=vel,
                             traj_plan=traj, **opts)
        self.jobs_served += 1
        if res.plan is not traj:      # mid-run replan grew the bounds
            self._plans[(sc, req.integrator)] = (base, res.plan)
            self.replans_absorbed += 1

        state = None
        if res.state is not None:
            md = res.state
            state = MDState(md.positions[:n], md.velocities[:n],
                            md.forces[:n], md.potential[:n], md.step)
        return TrajectoryResponse(job_id=req.job_id, status=res.status,
                                  state=state, result=res,
                                  shape_class=sc.label(), n=n)
