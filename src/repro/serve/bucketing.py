"""Shape-class bucketing: normalize ragged requests onto a few jit shapes.

The serving tier's whole compile-stability story lives here. An incoming
request carries arbitrary ``(N, domain, kernel, fields)``; executing it
directly would give every distinct N its own jit trace and the front door
would recompile forever. Instead each request is normalized to a
:class:`ShapeClass`:

* ``n_cap`` — N rounded **up** to a power of two (floored at
  ``MIN_N_CAP`` so tiny requests share one class instead of fragmenting
  into 1/2/4/8...). Rows past the real N are padding: positions zero,
  fields zero, ``ParticleState.valid`` False — ``bin_particles`` gives
  them weight 0 and sorts them past every real cell, so padded execution
  is bit-identical to unpadded (ARCHITECTURE.md "Serving tier").
* the domain grid (cells + box) — binning shapes depend on it.
* the kernel identity digest (``autotune._kernel_id``) — value-based, so
  two kernels sharing a name but differing in FLOPs/params never share a
  class (or its cached executor).
* the sorted field-name tuple — field *keys* are static in the trace.

Batches are padded the same way on the leading axis: B live requests are
stacked and topped up to ``quantize_batch(B)`` fully-invalid rows, so the
steady state sees one ``(B_cap, n_cap)`` shape per class and ``vmap``
never retraces. Fully-invalid pad rows are safe: every slot weight is 0,
bins come out empty, the kernel sees no pairs.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp

from ..core.api import ParticleState
from ..core.autotune import _kernel_id
from ..core.domain import Domain
from ..core.interactions import PairKernel

__all__ = ["MIN_N_CAP", "ShapeClass", "classify", "pad_state",
           "quantize_batch", "quantize_n", "stack_states", "split_batch"]

# Smallest particle cap a class may quantize to. Keeps the long tail of
# tiny requests (N = 3, 7, 12, ...) in ONE bucket — each extra class costs
# a jit trace and an executor-cache slot, and padding 3 -> 64 rows is
# cheaper than either.
MIN_N_CAP = 64


def _next_pow2(n: int) -> int:
    if n < 1:
        raise ValueError(f"need a positive size, got {n}")
    return 1 << (n - 1).bit_length()


def quantize_n(n: int, min_cap: int = MIN_N_CAP) -> int:
    """Particle cap for a request of N rows: next power of two, floored at
    ``min_cap``. Round-up bounds padding waste below 2x while collapsing
    the unbounded space of Ns onto ~log2(N_max) classes."""
    return max(int(min_cap), _next_pow2(int(n)))


def quantize_batch(b: int, max_batch: int) -> int:
    """Batch-slot count for b live requests: next power of two, capped at
    ``max_batch``. Same retrace argument as :func:`quantize_n`, on the
    leading axis."""
    if b < 1:
        raise ValueError(f"need a positive batch, got {b}")
    return min(_next_pow2(int(b)), int(max_batch))


@dataclasses.dataclass(frozen=True)
class ShapeClass:
    """The bucketing key: everything that decides jit-trace compatibility.

    Hashable and cheap to compare — the engine uses it as the dict key for
    queues, plans, and metrics attribution. Two requests in the same class
    are guaranteed to share one padded shape, one plan, one executor."""

    domain: Domain
    kernel_id: str
    n_cap: int
    field_names: Tuple[str, ...]

    def label(self) -> str:
        nx, ny, nz = self.domain.ncells
        fields = ",".join(self.field_names) or "-"
        return (f"{nx}x{ny}x{nz}/n{self.n_cap}/"
                f"{self.kernel_id}/{fields}")


def classify(domain: Domain, kernel: PairKernel, n: int,
             field_names: Sequence[str],
             min_cap: int = MIN_N_CAP) -> ShapeClass:
    """The ShapeClass a request of ``n`` particles lands in."""
    return ShapeClass(domain=domain, kernel_id=_kernel_id(kernel),
                      n_cap=quantize_n(n, min_cap),
                      field_names=tuple(sorted(field_names)))


def pad_state(state: ParticleState, n_cap: int) -> ParticleState:
    """Pad one request's state to ``n_cap`` rows with masked zeros.

    Zero positions are safe *only* because the mask excludes them from
    binning (an unmasked zero row would land in a real boundary cell —
    ``Domain.cell_coords`` clips out-of-box points inward). Real rows keep
    their original values bit-for-bit; an existing ``valid`` mask is
    honored and extended."""
    n = state.positions.shape[0]
    if n > n_cap:
        raise ValueError(f"state has {n} rows, class cap is {n_cap}")
    pad = n_cap - n
    base_valid = (state.valid if state.valid is not None
                  else jnp.ones((n,), bool))
    if pad == 0 and state.valid is not None:
        return state
    positions = jnp.pad(state.positions, ((0, pad), (0, 0)))
    fields = {k: jnp.pad(v, ((0, pad),)) for k, v in state.fields.items()}
    valid = jnp.pad(base_valid, ((0, pad),))  # pads with False
    return ParticleState(positions=positions, fields=fields, valid=valid)


def stack_states(states: Sequence[ParticleState], n_cap: int,
                 b_cap: Optional[int] = None) -> ParticleState:
    """Stack padded states into one batched ParticleState for
    ``execute_batch``: positions ``(B_cap, n_cap, 3)``, fields
    ``(B_cap, n_cap)``, valid ``(B_cap, n_cap)``. Slots past the live
    requests are fully-invalid rows (all-False valid -> empty bins)."""
    if not states:
        raise ValueError("cannot stack an empty batch")
    b_cap = len(states) if b_cap is None else int(b_cap)
    if b_cap < len(states):
        raise ValueError(f"{len(states)} states exceed batch cap {b_cap}")
    padded = [pad_state(s, n_cap) for s in states]
    names = {tuple(sorted(p.fields)) for p in padded}
    if len(names) != 1:
        raise ValueError(f"mixed field sets in one batch: {sorted(names)}")
    n_ghost = b_cap - len(padded)
    if n_ghost:
        ghost = ParticleState(
            positions=jnp.zeros((n_cap, 3), padded[0].positions.dtype),
            fields={k: jnp.zeros((n_cap,), v.dtype)
                    for k, v in padded[0].fields.items()},
            valid=jnp.zeros((n_cap,), bool))
        padded = padded + [ghost] * n_ghost
    return ParticleState(
        positions=jnp.stack([p.positions for p in padded]),
        fields={k: jnp.stack([p.fields[k] for p in padded])
                for k in padded[0].fields},
        valid=jnp.stack([p.valid for p in padded]))


def split_batch(forces: jnp.ndarray, potential: jnp.ndarray,
                sizes: Sequence[int]) -> List[Tuple[jnp.ndarray, jnp.ndarray]]:
    """Un-batch ``execute_batch`` output back into per-request results,
    trimming each to its request's true N (padding rows and ghost batch
    slots are dropped on the floor)."""
    out = []
    for i, n in enumerate(sizes):
        out.append((forces[i, :n], potential[i, :n]))
    return out
