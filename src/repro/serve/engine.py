"""ServingEngine: the continuous-batching front door.

Requests of varying ``(N, domain, kernel, fields)`` arrive one at a time;
the engine normalizes each onto a :class:`~repro.serve.bucketing.ShapeClass`
(padded N-cap + grid + kernel digest + field names), queues compatible
requests together, and dispatches each bucket — when it fills to
``max_batch`` or its oldest request has waited ``max_wait`` — through one
jitted ``plan.execute_batch`` call. Per class it keeps a plan (built once
from the first request, via the measured autotuner when ``autotune=True``)
and relies on the core executor LRU to keep that plan's traced executor
warm, so steady-state traffic performs **zero recompiles and zero autotune
timing runs** — the guarantee ``tests/test_serve.py`` asserts via
``core.recompile_count()`` / ``core.autotune.timing_run_count()``.

Admission control bounds the queue: when ``max_queue`` requests are
already waiting, policy ``"reject"`` refuses the newcomer and policy
``"shed_oldest"`` evicts the longest-waiting request to admit it (both
produce terminal Responses, counted in metrics). A request whose
particles overflow the class plan's static bounds triggers a per-class
replan (the :meth:`InteractionPlan.replan` contract) that replaces only
that class's plan — other classes keep their warm executors.

Time comes from an injectable clock (default: a fresh
:class:`~repro.serve.metrics.VirtualClock`). Arrival timestamps are
whatever the clock reads at ``submit``; each dispatch advances the clock
by the *measured* wall time of the batched execution, so queue/dispatch/
total latencies in :class:`~repro.serve.metrics.ServeMetrics` are honest
even under a simulated arrival schedule (``benchmarks/fig_serve.py``).

**Resilience** (ARCHITECTURE.md "Resilience"): every request terminates
with a definite status, whatever the backend does. Requests may carry a
deadline — an expired request is answered ``"deadline"`` and never takes
a dispatch slot. A dispatch that fails transiently (an exception from the
executor, injected chaos — ``repro.testing.chaos`` site
``serve.dispatch`` — or a non-finite output batch caught by the fused
``isfinite`` reduction) re-queues its requests with bounded exponential
backoff + deterministic per-request jitter; a request that exhausts
``max_retries`` is answered ``"failed"``. Repeated failures trip a
per-shape-class circuit breaker that quarantines the class onto its
fallback plan (``core.api.fallback_plan`` — reference backend, dense
layout) instead of poisoning the primary plan's warm traces; after
``breaker_recovery`` consecutive clean dispatches the primary plan (and
its still-warm executor) is restored. All of it is counted in
:class:`~repro.serve.metrics.ServeMetrics`.
"""

from __future__ import annotations

import dataclasses
import time as _time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax

from ..core import api
from ..core import autotune as at
from ..core.api import InteractionPlan, ParticleState, plan as make_plan
from ..core.domain import Domain
from ..core.interactions import PairKernel, make_lennard_jones
from ..obs.trace import event as _obs_event, trace as _obs_trace
from ..testing import chaos
from .bucketing import (MIN_N_CAP, ShapeClass, classify, quantize_batch,
                        split_batch, stack_states)
from .metrics import ServeMetrics, VirtualClock

__all__ = ["Request", "Response", "ServingEngine", "ADMISSION_POLICIES",
           "RESPONSE_STATUSES"]

ADMISSION_POLICIES = ("reject", "shed_oldest")

RESPONSE_STATUSES = ("ok", "rejected", "shed", "deadline", "failed")


@dataclasses.dataclass
class Request:
    """One admitted unit of work, as tracked internally."""
    req_id: int
    shape_class: ShapeClass
    state: ParticleState            # raw, unpadded (N rows)
    kernel: PairKernel
    t_submit: float
    deadline: Optional[float] = None   # absolute clock time; None = never
    attempts: int = 0                  # failed dispatch attempts so far
    not_before: float = 0.0            # retry backoff holdback


@dataclasses.dataclass
class Response:
    """Terminal outcome of a request. ``status`` is one of
    ``RESPONSE_STATUSES``: ``"ok"`` (results attached, trimmed to the
    request's true N), ``"rejected"`` (admission refused — queue full
    under the reject policy), ``"shed"`` (evicted by shed_oldest after
    admission), ``"deadline"`` (expired before results — never given a
    dispatch slot past its deadline) or ``"failed"`` (every retry of a
    faulting dispatch exhausted). Latencies are clock-seconds; None for
    requests that never dispatched."""
    req_id: int
    status: str
    forces: Optional[jax.Array] = None
    potential: Optional[jax.Array] = None
    shape_class: Optional[str] = None
    queue_latency: Optional[float] = None
    dispatch_latency: Optional[float] = None
    total_latency: Optional[float] = None
    attempts: int = 0


@dataclasses.dataclass
class _ClassBreaker:
    """Per-shape-class circuit breaker (hysteresis: consecutive counts)."""
    open: bool = False
    consec_failures: int = 0
    consec_clean: int = 0


class ServingEngine:
    """Continuous-batching front door over the plan/execute API.

    Args:
      kernel: default pair kernel for requests that don't bring their own.
      max_batch: bucket dispatch threshold and upper batch-shape cap; live
        batches are padded up to the next power of two below this, so the
        steady state sees a handful of batch shapes per class, not one per
        occupancy level.
      max_queue: admission bound on the total number of waiting requests.
      admission: ``"reject"`` (refuse the newcomer) or ``"shed_oldest"``
        (evict the longest-waiting request to make room).
      max_wait: clock-seconds a bucket's oldest request may wait before
        ``poll()`` dispatches the bucket part-full.
      autotune: build each class's plan with ``strategy="autotune"``
        (measured winners, persisted in the on-disk cache) instead of the
        analytical ``"auto"`` model.
      clock: injectable time source (``() -> float``); defaults to a fresh
        VirtualClock. Pass ``time.perf_counter`` for wall-clock serving.
      min_n_cap: smallest shape-class particle cap (see bucketing).
      plan_opts: extra keyword arguments forwarded to ``plan()``
        (e.g. ``backend="pallas"``); ignored when ``autotune=True``.
      tune_opts: extra keyword arguments forwarded to ``tune()`` when
        ``autotune=True`` (e.g. ``budget_s=0.05``).
      max_retries: failed dispatch attempts a request survives before a
        terminal ``"failed"`` response (the retry bound).
      retry_base_s / retry_cap_s: exponential-backoff schedule for
        re-queued requests — attempt k is held back
        ``base * 2**(k-1)`` seconds (capped at ``retry_cap_s``), scaled
        by a deterministic per-request jitter so retry waves decorrelate
        reproducibly.
      breaker_threshold / breaker_recovery: consecutive failed dispatches
        that quarantine a shape class onto its fallback plan, and
        consecutive clean dispatches that restore the primary.
    """

    def __init__(self, kernel: Optional[PairKernel] = None, *,
                 max_batch: int = 8, max_queue: int = 64,
                 admission: str = "reject", max_wait: float = 0.05,
                 autotune: bool = False,
                 clock: Optional[Callable[[], float]] = None,
                 min_n_cap: int = MIN_N_CAP,
                 plan_opts: Optional[dict] = None,
                 tune_opts: Optional[dict] = None,
                 max_retries: int = 3, retry_base_s: float = 0.005,
                 retry_cap_s: float = 0.5, breaker_threshold: int = 3,
                 breaker_recovery: int = 5):
        if admission not in ADMISSION_POLICIES:
            raise ValueError(f"unknown admission policy {admission!r}; "
                             f"have {ADMISSION_POLICIES}")
        if max_batch < 1 or max_queue < 1:
            raise ValueError("max_batch and max_queue must be positive")
        if max_retries < 0 or breaker_threshold < 1 or breaker_recovery < 1:
            raise ValueError("max_retries must be >= 0; breaker_threshold "
                             "and breaker_recovery must be >= 1")
        self.kernel = kernel or make_lennard_jones()
        self.max_batch = int(max_batch)
        self.max_queue = int(max_queue)
        self.admission = admission
        self.max_wait = float(max_wait)
        self.autotune = bool(autotune)
        self.clock = clock if clock is not None else VirtualClock()
        self.min_n_cap = int(min_n_cap)
        self.plan_opts = dict(plan_opts or {})
        self.tune_opts = dict(tune_opts or {})
        self.max_retries = int(max_retries)
        self.retry_base_s = float(retry_base_s)
        self.retry_cap_s = float(retry_cap_s)
        self.breaker_threshold = int(breaker_threshold)
        self.breaker_recovery = int(breaker_recovery)
        self.metrics = ServeMetrics()
        self._queues: Dict[ShapeClass, List[Request]] = {}
        self._plans: Dict[ShapeClass, InteractionPlan] = {}
        self._primary: Dict[ShapeClass, InteractionPlan] = {}
        self._breakers: Dict[ShapeClass, _ClassBreaker] = {}
        self._kernels: Dict[str, PairKernel] = {}
        self._responses: List[Response] = []
        self._next_id = 0

    # -- admission ---------------------------------------------------------

    def submit(self, domain: Domain, state: ParticleState,
               kernel: Optional[PairKernel] = None,
               deadline_s: Optional[float] = None) -> int:
        """Admit one request; returns its ``req_id``. The outcome arrives
        later as a :class:`Response` (drain with :meth:`take_responses`).
        A full queue resolves per the admission policy: ``"reject"``
        terminates the *newcomer* immediately; ``"shed_oldest"`` evicts
        the longest-waiting admitted request instead. Admission may also
        dispatch the request's bucket if it just filled.

        ``deadline_s`` (clock-seconds from now) bounds how long the
        request may wait: once expired it is answered ``"deadline"`` and
        never occupies a dispatch slot (an already-expired deadline
        terminates right here)."""
        kernel = kernel or self.kernel
        req_id = self._next_id
        self._next_id += 1
        now = self.clock()
        self.metrics.note_submit(now)
        deadline = None if deadline_s is None else now + float(deadline_s)
        if deadline is not None and deadline <= now:
            self.metrics.deadline_expired += 1
            self._responses.append(Response(req_id, "deadline"))
            _obs_event("serve.admission", req_id=req_id, outcome="deadline")
            return req_id
        if self._queued_total() >= self.max_queue:
            if self.admission == "reject":
                self.metrics.rejected += 1
                self._responses.append(Response(req_id, "rejected"))
                _obs_event("serve.admission", req_id=req_id,
                           outcome="rejected")
                return req_id
            self._shed_oldest()
        sc = classify(domain, kernel, state.positions.shape[0],
                      tuple(state.fields), self.min_n_cap)
        _obs_event("serve.admission", req_id=req_id, outcome="queued",
                   shape_class=sc.label())
        self._kernels.setdefault(sc.kernel_id, kernel)
        self._queues.setdefault(sc, []).append(
            Request(req_id, sc, state, kernel, now, deadline=deadline))
        if len(self._queues[sc]) >= self.max_batch:
            self._dispatch(sc)
        return req_id

    def _queued_total(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def _shed_oldest(self) -> None:
        sc, queue = min(((sc, q) for sc, q in self._queues.items() if q),
                        key=lambda item: item[1][0].t_submit)
        victim = queue.pop(0)
        if not queue:
            del self._queues[sc]
        self.metrics.shed += 1
        self._responses.append(Response(victim.req_id, "shed",
                                        shape_class=sc.label()))
        _obs_event("serve.shed", req_id=victim.req_id,
                   shape_class=sc.label())

    # -- dispatch ----------------------------------------------------------

    def poll(self) -> int:
        """Dispatch every bucket that is full or whose oldest request has
        waited ``max_wait`` clock-seconds. Returns batches dispatched.
        Call after advancing the clock (or on a timer under wall-clock).
        Expired deadlines are swept first — an expired request neither
        occupies a dispatch slot nor holds its bucket open."""
        self._sweep_deadlines()
        now = self.clock()
        due = [sc for sc, q in self._queues.items()
               if len(q) >= self.max_batch
               or (q and now - q[0].t_submit >= self.max_wait)]
        for sc in due:
            self._dispatch(sc)
        return len(due)

    def flush(self) -> int:
        """Dispatch every non-empty bucket regardless of age or fill
        (retry holdbacks included — a flush is the drain-everything call).
        Returns batches dispatched."""
        self._sweep_deadlines()
        due = [sc for sc, q in self._queues.items() if q]
        for sc in due:
            self._dispatch(sc, drain=True)
        return len(due)

    def _sweep_deadlines(self) -> None:
        now = self.clock()
        for sc in list(self._queues):
            alive = []
            for req in self._queues[sc]:
                if req.deadline is not None and req.deadline <= now:
                    self.metrics.deadline_expired += 1
                    self._responses.append(Response(
                        req.req_id, "deadline", shape_class=sc.label(),
                        attempts=req.attempts))
                else:
                    alive.append(req)
            if alive:
                self._queues[sc] = alive
            else:
                del self._queues[sc]

    def take_responses(self) -> List[Response]:
        """Drain and return all terminal responses produced so far."""
        out, self._responses = self._responses, []
        return out

    def class_plan(self, sc: ShapeClass) -> Optional[InteractionPlan]:
        """The plan currently serving a shape class (None before its
        first dispatch) — the reference executor for parity checks. While
        the class's breaker is open this is the quarantine fallback plan;
        the primary is parked in :meth:`class_primary`."""
        return self._plans.get(sc)

    def class_primary(self, sc: ShapeClass) -> Optional[InteractionPlan]:
        """The parked primary plan of a quarantined class (None unless
        the breaker is open)."""
        return self._primary.get(sc)

    def class_breaker(self, sc: ShapeClass) -> Optional[_ClassBreaker]:
        """The class's circuit-breaker state (None before any failure)."""
        return self._breakers.get(sc)

    def pending(self) -> int:
        """Requests currently queued (including retry holdbacks) — zero
        once the workload is fully drained."""
        return self._queued_total()

    def prewarm(self, domain: Domain, state: ParticleState,
                kernel: Optional[PairKernel] = None) -> ShapeClass:
        """Cold-start avoidance: given one representative request, build
        the class's plan and trace its batched executor at **every**
        quantized batch size up to ``max_batch``. After prewarming, no
        bucket composition the dispatcher can form for this class — full,
        part-full, or timeout-drained singleton — triggers a trace; the
        steady state starts at request one. Returns the shape class."""
        kernel = kernel or self.kernel
        sc = classify(domain, kernel, state.positions.shape[0],
                      tuple(state.fields), self.min_n_cap)
        self._kernels.setdefault(sc.kernel_id, kernel)
        if sc not in self._plans:
            self._plans[sc] = self._build_plan(
                sc, Request(-1, sc, state, kernel, self.clock()))
        p = self._plans[sc]
        if p.check_overflow(state):
            p = p.replan(state)
            self.metrics.replans += 1
            self._plans[sc] = p
        b = 1
        while True:
            cap = quantize_batch(b, self.max_batch)
            jax.block_until_ready(
                p.execute_batch(stack_states([state], sc.n_cap, cap)))
            if cap >= self.max_batch:
                return sc
            b = cap + 1                  # next quantized size up

    # -- internals ---------------------------------------------------------

    def _build_plan(self, sc: ShapeClass,
                    first: Request) -> InteractionPlan:
        """Class plan from the first request's raw particles. Bounds are
        measured with the replan contract's slack, so siblings in the
        class usually fit without replanning; autotune winners persist in
        the on-disk cache, so a re-created engine re-tunes nothing."""
        if self.autotune:
            result = at.tune(sc.domain, first.kernel,
                             first.state.positions, **self.tune_opts)
            self.metrics.autotune_cache_hits += int(result.cache_hit)
            return result.plan
        return make_plan(sc.domain, first.kernel,
                         positions=first.state.positions,
                         **self.plan_opts)

    def _dispatch(self, sc: ShapeClass, drain: bool = False) -> None:
        queue = self._queues.pop(sc, [])
        now = self.clock()
        # retry holdback: backed-off requests wait out their not_before
        # (except under flush(drain=True), the drain-everything call)
        ready = [r for r in queue if drain or r.not_before <= now]
        held = [r for r in queue if not (drain or r.not_before <= now)]
        if held:
            self._queues[sc] = held
        # a retry wave can leave more than max_batch ready requests in
        # the bucket — dispatch in batch-cap chunks, never one over-cap
        # batch (which would be a fresh executor shape)
        while ready:
            batch, ready = ready[:self.max_batch], ready[self.max_batch:]
            self._dispatch_batch(sc, batch)

    def _dispatch_batch(self, sc: ShapeClass, ready: List[Request]) -> None:
        with _obs_trace("serve.dispatch", shape_class=sc.label(),
                        requests=len(ready)) as sp:
            self._dispatch_batch_impl(sc, ready, sp)

    def _dispatch_batch_impl(self, sc: ShapeClass, ready: List[Request],
                             sp) -> None:
        rc0, tr0 = api.recompile_count(), at.timing_run_count()
        if sc not in self._plans:
            self._plans[sc] = self._build_plan(sc, ready[0])
        p = self._plans[sc]
        # Overflow safety net: grow this class's bounds to cover every
        # request in the bucket (replacing only this class's plan — the
        # new plan is a new executor-cache key; other classes stay warm).
        for req in ready:
            if p.check_overflow(req.state):
                p = p.replan(req.state)
                self.metrics.replans += 1
        self._plans[sc] = p

        b_cap = quantize_batch(len(ready), self.max_batch)
        batched = stack_states([r.state for r in ready], sc.n_cap, b_cap)
        t_dispatch = self.clock()
        t0 = _time.perf_counter()
        fault: Optional[BaseException] = None
        forces = potential = None
        try:
            # the serve-dispatch fault point: straggler latency rides the
            # engine clock, transient errors / shard loss raise, and a
            # non-finite output batch (injected or real) is caught by the
            # same fused isfinite reduction execute_checked uses
            chaos.maybe_delay(
                "serve.dispatch",
                sleep=(self.clock.advance
                       if isinstance(self.clock, VirtualClock)
                       else _time.sleep))
            chaos.maybe_raise("serve.dispatch")
            forces, potential = p.execute_batch(batched)
            jax.block_until_ready((forces, potential))
            forces = chaos.corrupt("serve.dispatch", forces)
            bad, _ = api._output_check(forces, potential, batched.positions,
                                       batched.valid, sc.domain.box)
            if int(bad):
                self.metrics.nonfinite_batches += 1
                raise chaos.TransientBackendError(
                    f"{int(bad)} non-finite output element(s)")
        except (chaos.TransientBackendError, RuntimeError, ValueError,
                FloatingPointError) as e:
            fault = e
        elapsed = _time.perf_counter() - t0
        if isinstance(self.clock, VirtualClock):
            self.clock.advance(elapsed)
        t_done = self.clock()
        self.metrics.recompiles += api.recompile_count() - rc0
        self.metrics.autotune_timing_runs += at.timing_run_count() - tr0

        if fault is not None:
            self.metrics.faults += 1
            sp.set(outcome="fault", fault=type(fault).__name__)
            self._note_class_failure(sc)
            self._requeue_failed(sc, ready, t_done)
            return

        self._note_class_success(sc)
        self.metrics.batches += 1
        self.metrics.batch_fill.record(len(ready) / b_cap)
        sp.set(outcome="ok", batch_cap=b_cap, fill=len(ready) / b_cap,
               seconds=elapsed)
        sizes = [r.state.positions.shape[0] for r in ready]
        for req, (f, pot) in zip(ready, split_batch(forces, potential,
                                                    sizes)):
            self.metrics.note_served(req.t_submit, t_dispatch, t_done)
            self._responses.append(Response(
                req.req_id, "ok", forces=f, potential=pot,
                shape_class=sc.label(),
                queue_latency=t_dispatch - req.t_submit,
                dispatch_latency=t_done - t_dispatch,
                total_latency=t_done - req.t_submit,
                attempts=req.attempts))

    # -- resilience internals ----------------------------------------------

    def _backoff(self, req: Request) -> float:
        """Exponential backoff with a cap and deterministic per-request
        jitter (a Knuth-hash fraction of ``req_id``): reproducible, and
        retry waves from one failed batch decorrelate instead of
        thundering back as one bucket."""
        base = self.retry_base_s * (2.0 ** max(req.attempts - 1, 0))
        jitter = 1.0 + 0.5 * (((req.req_id * 2654435761) & 0xFFFF)
                              / float(1 << 16))
        return min(base * jitter, self.retry_cap_s)

    def _requeue_failed(self, sc: ShapeClass, batch: List[Request],
                        now: float) -> None:
        """Route every request of a failed dispatch: bounded retry with
        backoff, or a terminal ``"failed"`` response past the bound."""
        retry: List[Request] = []
        for req in batch:
            req.attempts += 1
            if req.attempts > self.max_retries:
                self.metrics.failed += 1
                self._responses.append(Response(
                    req.req_id, "failed", shape_class=sc.label(),
                    attempts=req.attempts))
            else:
                self.metrics.retries += 1
                req.not_before = now + self._backoff(req)
                _obs_event("serve.retry", req_id=req.req_id,
                           attempts=req.attempts,
                           not_before=req.not_before)
                retry.append(req)
        if retry:
            # re-admit at the front: retried requests are the oldest and
            # keep their FIFO position for the shed/due bookkeeping
            self._queues.setdefault(sc, [])[:0] = retry

    def _note_class_failure(self, sc: ShapeClass) -> None:
        br = self._breakers.setdefault(sc, _ClassBreaker())
        br.consec_clean = 0
        br.consec_failures += 1
        if not br.open and br.consec_failures >= self.breaker_threshold:
            # quarantine: the class moves onto its fallback plan
            # (reference backend, dense layout). The primary plan object
            # is parked untouched, so its warm executor stays in the LRU
            # and restoration is a dict swap, not a retrace.
            br.open = True
            br.consec_failures = 0
            self.metrics.breaker_opens += 1
            self.metrics.breaker_open_classes += 1
            _obs_event("serve.breaker", transition="open",
                       shape_class=sc.label())
            primary = self._plans.get(sc)
            if primary is not None:
                self._primary[sc] = primary
                self._plans[sc] = api.fallback_plan(primary)

    def _note_class_success(self, sc: ShapeClass) -> None:
        br = self._breakers.get(sc)
        if br is None:
            return
        br.consec_failures = 0
        if br.open:
            br.consec_clean += 1
            if br.consec_clean >= self.breaker_recovery:
                br.open = False
                br.consec_clean = 0
                self.metrics.breaker_closes += 1
                self.metrics.breaker_open_classes -= 1
                _obs_event("serve.breaker", transition="close",
                           shape_class=sc.label())
                if sc in self._primary:
                    self._plans[sc] = self._primary.pop(sc)
