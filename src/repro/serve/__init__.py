"""Serving tier: continuous batching over the plan/execute API.

A :class:`ServingEngine` is the front door for interaction requests of
varying ``(N, grid, kernel, fields)``: each is normalized onto a padded
:class:`ShapeClass`, bucketed with compatible requests, and dispatched
through one jitted ``execute_batch`` call — keeping per-class plans and
executors warm so steady-state traffic never recompiles or re-times.
See ARCHITECTURE.md "Serving tier" for the shape-class anatomy and the
admission/overflow state machine.
"""

from .bucketing import (MIN_N_CAP, ShapeClass, classify, pad_state,
                        quantize_batch, quantize_n, split_batch,
                        stack_states)
from .engine import (ADMISSION_POLICIES, RESPONSE_STATUSES, Request,
                     Response, ServingEngine)
from .metrics import LatencyStats, ServeMetrics, VirtualClock, percentile
from .trajectory import (TrajectoryRequest, TrajectoryResponse,
                         TrajectoryService)

__all__ = [
    "ADMISSION_POLICIES", "LatencyStats", "MIN_N_CAP", "Request",
    "RESPONSE_STATUSES", "Response", "ServeMetrics", "ServingEngine",
    "ShapeClass", "TrajectoryRequest", "TrajectoryResponse",
    "TrajectoryService", "VirtualClock", "classify", "pad_state",
    "percentile", "quantize_batch", "quantize_n", "split_batch",
    "stack_states",
]
