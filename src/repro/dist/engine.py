"""Distributed halo execution engine: ``plan(..., backend="halo")``.

The paper's schedules are single-device; this module makes any cell-schedule
:class:`~repro.core.api.InteractionPlan` run on a JAX device mesh via domain
decomposition — the standard scale-out for cutoff interactions. One jitted
executor per plan does, end to end:

  1. **partition** — a traceable Z-slab gather groups particles by shard
     under the plan's static ``shard_cap`` (``dist.halo.partition_by_shard``),
  2. **per-shard binning** — under ``shard_map``, each shard bins its own
     rows into the slab's padded planes (sentinel rows masked out) and
     offsets slot ids by ``shard * cap`` so the self-pair exclusion stays
     exact across shard boundaries,
  3. **ghost exchange** — the two boundary Z-planes of every binned plane
     (coordinates, extra fields, slot ids) cross to the neighbouring shards
     via ``ppermute`` (``dist.halo.exchange_halo``); periodic Z wraps around
     the shard ring with the minimum-image shift, open Z boundaries get
     empty planes. ``layout="packed"`` plans pack the slab *first*
     (``binning.pack_rows``) and exchange the packed planes — each
     boundary plane crosses as ``row_cap`` slots plus its row-local
     prefix-sum offsets instead of ``(nx+2)*m_c`` dense slots,
  4. **local schedule** — the plan's strategy runs on the local slab through
     the same backend registry as single-device execution (reference or
     Pallas, dense or occupancy-compacted), so every schedule the registry
     knows is immediately distributed,
  5. **scatter-back** — per-shard results return to global particle order.

Overflow stays a *global* contract: ``InteractionPlan.check_overflow``
reduces the per-shard load and per-shard active-pencil counts across shards
(max) against the plan's static bounds, so ``execute_or_replan`` grows
exactly the bound that overflowed — ``m_c``, ``shard_cap``, or the
compacted ``max_active`` — never silently dropping work.

A single-shard halo plan degrades to the inner backend bit-identically (no
mesh, no exchange) — the single-device fallback the README documents.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from ..core.binning import (EMPTY_POS, bin_particles, build_sfc_clusters,
                            cell_counts, pack_rows, sfc_pair_count,
                            shard_pencil_active, shard_slab_counts)
from ..core.domain import Domain, slab_domain
from ..obs import metrics as _obs_metrics
from ..obs.trace import event as _obs_event, trace as _obs_trace
from . import halo as H

# ppermute ghost-plane exchanges *staged* per executor trace (the halo
# body is shard_mapped and traced once per compile, so — like
# ``core.api.recompile_count`` — this moves at trace time, not per step)
GHOST_EXCHANGE_TOTAL = "repro_ghost_exchange_total"

Array = jnp.ndarray

DEFAULT_SHARD_AXIS = "halo"


# --------------------------------------------------------------------------
# mesh resolution
# --------------------------------------------------------------------------

def default_n_shards(domain: Domain,
                     device_count: Optional[int] = None) -> int:
    """Largest divisor of ``nz`` that fits the available devices (>= 1)."""
    if device_count is None:
        device_count = jax.device_count()
    for n in range(min(device_count, domain.nz), 0, -1):
        if domain.nz % n == 0:
            return n
    return 1


def default_mesh(n_shards: int, axis: str = DEFAULT_SHARD_AXIS) -> Mesh:
    """A 1-D mesh over the first ``n_shards`` local devices."""
    devs = jax.devices()
    if len(devs) < n_shards:
        raise ValueError(
            f"halo plan wants {n_shards} shards but only {len(devs)} "
            "device(s) are visible (emulate with "
            "XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    return Mesh(np.asarray(devs[:n_shards]), (axis,))


def resolve_mesh(plan) -> Mesh:
    """The mesh a halo plan executes on: the plan's own, or a default 1-D
    mesh over the first ``n_shards`` local devices."""
    if plan.mesh is not None:
        if plan.shard_axis not in plan.mesh.axis_names:
            raise ValueError(
                f"plan.mesh has axes {plan.mesh.axis_names}, no "
                f"{plan.shard_axis!r} shard axis")
        if int(plan.mesh.shape[plan.shard_axis]) != plan.n_shards:
            raise ValueError(
                f"plan.mesh axis {plan.shard_axis!r} has size "
                f"{plan.mesh.shape[plan.shard_axis]}, plan expects "
                f"{plan.n_shards} shards")
        return plan.mesh
    return default_mesh(plan.n_shards, plan.shard_axis)


# --------------------------------------------------------------------------
# the sharded executor body
# --------------------------------------------------------------------------

def halo_impl(plan):
    """-> traced ``fn(state) -> (forces (N, 3), potential (N,))``.

    Built once per plan (under the plan executor's jit cache). ``plan``
    must be a halo plan with ``n_shards >= 2``; the single-shard fallback
    is handled by the plan layer (it routes straight to the inner backend).
    """
    from ..core.api import ParticleState, get_backend

    dom = plan.domain
    n_shards = plan.n_shards
    axis = plan.shard_axis
    cap = plan.shard_cap
    px, py, pz = dom.periodic_axes
    nz_loc = dom.nz // n_shards
    lz_loc = dom.box[2] / n_shards
    local_dom = slab_domain(dom, n_shards)

    # the per-shard plan: same schedule, same static bounds, slab domain,
    # the inner backend — dispatched through the normal registry so dense,
    # compacted, reference and Pallas shards all share one code path
    inner = dataclasses.replace(plan, domain=local_dom,
                                backend=plan.halo_inner, n_shards=None,
                                shard_cap=None, mesh=None)
    inner_fn = get_backend(inner.backend, inner.strategy, plan.layout)
    mesh = resolve_mesh(plan)

    def body(pos_blk: Array, fields_blk: Dict[str, Array]):
        idx = jax.lax.axis_index(axis)
        valid = pos_blk[:, 0] < H.VALID_MAX
        z_shift = jnp.asarray([0.0, 0.0, 1.0], pos_blk.dtype) * (
            idx.astype(pos_blk.dtype) * lz_loc)
        local_pos = pos_blk - z_shift
        bins = bin_particles(local_dom, local_pos, fields_blk,
                             m_c=plan.m_c, valid=valid)

        # globally unique slot ids: shard offset keeps the self-pair
        # exclusion exact when a pair straddles a shard boundary
        sid = bins.slot_id
        sid = jnp.where(sid >= 0, sid + idx * cap, sid)

        exchange = lambda plane, fill, coord_shift=0.0: H.exchange_halo(
            plane, axis=axis, n_shards=n_shards, nz_loc=nz_loc,
            shard_index=idx, periodic_z=pz, fill=fill,
            coord_shift=coord_shift)

        def exchange_planes(planes):
            # staging span: the body runs at trace time only, so this
            # records one span per compile, not per step
            with _obs_trace("dist.ghost_exchange", phase="trace",
                            n_shards=n_shards, layout=plan.layout,
                            planes=len(planes)):
                _obs_metrics.registry.counter(
                    GHOST_EXCHANGE_TOTAL,
                    n_shards=n_shards).inc(len(planes))
                out = {}
                for name, plane in planes.items():
                    if name == "z":
                        out[name] = exchange(plane, EMPTY_POS, lz_loc)
                    elif name in ("x", "y"):
                        out[name] = exchange(plane, EMPTY_POS)
                    else:                      # extra per-particle field
                        out[name] = exchange(plane, 0.0)
                return out

        safe_pos = jnp.where(valid[:, None], local_pos, 0.0)
        local_state = ParticleState(safe_pos, fields_blk)

        if plan.layout == "packed":
            # pack the local slab first, then exchange the *packed* ghost
            # planes: each boundary plane crosses as row_cap packed slots
            # plus its (nx+3) prefix-sum offsets — bytes proportional to
            # the boundary particles, not to m_c. No offset rebasing is
            # needed on arrival: cell offsets are row-local (a packed row
            # is self-describing), slot ids already carry the sender's
            # shard offset, and only the z coordinates are rebased into
            # this shard's frame (the usual minimum-image shift).
            packed = pack_rows(local_dom,
                               dataclasses.replace(bins, slot_id=sid),
                               row_cap=plan.row_cap)
            packed = dataclasses.replace(
                packed,
                planes=exchange_planes(packed.planes),
                slot_id=exchange(packed.slot_id, -1),
                slot_cell=exchange(packed.slot_cell, 1),
                cell_offsets=exchange(packed.cell_offsets, 0),
                row_counts=exchange(packed.row_counts[..., None],
                                    0)[..., 0])
            f, pot = inner_fn(inner, packed, local_state)
        elif plan.layout == "sfc":
            # exchange the dense binned planes first — the SFC pair-list
            # bitmask is occupancy-driven (built from slot_id), so ghost
            # planes arriving as dense slots feed the compressed pair
            # list with no extra bookkeeping; each shard then builds its
            # own slab-local cluster order under the plan's static
            # pair_cap (a per-shard bound, checked per shard by
            # halo_overflow_class)
            bins = dataclasses.replace(bins,
                                       planes=exchange_planes(bins.planes),
                                       slot_id=exchange(sid, -1))
            sfc = build_sfc_clusters(local_dom, bins,
                                     pair_cap=plan.pair_cap)
            f, pot = inner_fn(inner, sfc, local_state)
        else:
            bins = dataclasses.replace(bins,
                                       planes=exchange_planes(bins.planes),
                                       slot_id=exchange(sid, -1))
            f, pot = inner_fn(inner, bins, local_state)
        return (jnp.where(valid[:, None], f, 0.0),
                jnp.where(valid, pot, 0.0))

    def impl(state) -> Tuple[Array, Array]:
        # like the body, impl itself is traced once per compile: these
        # are staging spans (phase="trace"), not per-dispatch timings
        n = state.positions.shape[0]
        with _obs_trace("dist.partition", phase="trace",
                        n_shards=n_shards, shard_cap=cap, n=n):
            gather_idx, pos_part, fields_part = H.partition_by_shard(
                dom, state.positions, state.fields, n_shards, cap)
        in_specs = (P(axis), {k: P(axis) for k in fields_part})
        sharded = shard_map(body, mesh=mesh, in_specs=in_specs,
                            out_specs=(P(axis), P(axis)), check_rep=False)
        with _obs_trace("dist.shard_dispatch", phase="trace",
                        n_shards=n_shards, strategy=plan.strategy,
                        layout=plan.layout):
            f_part, pot_part = sharded(pos_part, fields_part)
        forces = H.scatter_from_shards(gather_idx, n, f_part)
        pot = H.scatter_from_shards(gather_idx, n, pot_part)
        return forces, pot

    return impl


# --------------------------------------------------------------------------
# the overflow contract, reduced across shards
# --------------------------------------------------------------------------

def halo_overflow(plan, counts: Array) -> bool:
    """Shard-level overflow: True when any shard's particle load exceeds
    ``shard_cap``, or (compacted plans) any shard's active-pencil count
    exceeds ``max_active``. ``counts`` are the global per-cell counts the
    caller already computed for the ``m_c`` check — the shard reductions
    (max across shards) derive from them, so the whole safety check stays
    one binning pass."""
    return halo_overflow_class(plan, counts) is not None


def halo_overflow_class(plan, counts: Array) -> Optional[str]:
    """Which shard-level bound overflowed — ``"shard_cap"`` /
    ``"max_active"`` / ``"pair_cap"`` — or None (:func:`halo_overflow`
    with the bound named, feeding ``InteractionPlan.overflow_class``)."""
    loads = shard_slab_counts(plan.domain, counts, plan.n_shards)
    if int(jnp.max(loads)) > plan.shard_cap:
        return "shard_cap"
    if plan.layout == "sfc":
        if max(shard_sfc_pairs(plan.domain, counts,
                               plan.n_shards)) > plan.pair_cap:
            return "pair_cap"
    if plan.compact:
        act = shard_pencil_active(plan.domain, counts, plan.n_shards)
        if int(jnp.max(act)) > plan.max_active:
            return "max_active"
    return None


def shard_sfc_pairs(domain: Domain, counts: Array, n_shards: int) -> list:
    """Per-shard compressed pair-list lengths of an SFC halo plan.

    Each shard builds its pair list over its *slab* domain's cluster
    order, with the Z ghost planes holding the neighbouring shard's
    boundary occupancy (periodic wrap across the ring, empty on open Z
    boundaries) — exactly the occupancy the exchanged planes carry at
    run time, so this probe bounds every shard's traced ``n_pairs``
    the way ``sfc_pair_count`` bounds the single-device one."""
    nx, ny, nz = domain.ncells
    nz_loc = nz // n_shards
    grid = np.asarray(counts).reshape(nz, ny, nx)
    local_dom = slab_domain(domain, n_shards)
    pz = domain.periodic_axes[2]
    empty = np.zeros((ny, nx), grid.dtype)
    out = []
    for s in range(n_shards):
        lo, hi = s * nz_loc - 1, (s + 1) * nz_loc
        below = grid[lo % nz] if (pz or lo >= 0) else empty
        above = grid[hi % nz] if (pz or hi < nz) else empty
        out.append(sfc_pair_count(
            local_dom, counts=grid[s * nz_loc:(s + 1) * nz_loc],
            ghost_z=(below, above)))
    return out


# --------------------------------------------------------------------------
# elastic shrink: survive a lost shard
# --------------------------------------------------------------------------

# Re-exported here because shard loss is a *distributed* failure mode even
# though the exception class lives with the injection registry: callers
# catching a lost shard should not need to know about repro.testing.
from ..testing.chaos import ShardLost  # noqa: E402  (re-export)


def surviving_shard_count(domain: Domain, n_shards: int,
                          lost: int = 1) -> int:
    """The shard count to rebuild at after ``lost`` shards die: the
    largest divisor of ``nz`` at most ``n_shards - lost`` (>= 1, so a
    mesh can always shrink to the bit-identical single-device
    fallback)."""
    target = max(1, int(n_shards) - int(lost))
    for n in range(target, 0, -1):
        if domain.nz % n == 0:
            return n
    return 1


def elastic_shrink(plan, state=None, lost: int = 1):
    """A twin of ``plan`` rebuilt at the surviving shard count.

    The shard-loss half of the resilience contract
    (``InteractionPlan.execute_checked`` calls this when a
    :class:`ShardLost` surfaces): the Z-slab decomposition is re-cut at
    :func:`surviving_shard_count` shards, the mesh is dropped (re-resolved
    over the surviving devices at next dispatch), and the per-shard static
    bounds are re-measured under the ordinary replan contract —
    ``suggest_shard_cap`` / ``suggest_shard_max_active`` when
    representative ``state`` positions are given, a conservative
    load-ratio scaling of the old bounds otherwise. Shrinking to one
    shard degrades to the inner backend bit-identically."""
    if not plan.n_shards or plan.n_shards <= 1:
        return plan
    ns = surviving_shard_count(plan.domain, plan.n_shards, lost)
    if ns <= 1:
        return dataclasses.replace(plan, n_shards=1, shard_cap=None,
                                   mesh=None, box=None)
    pos = state.positions if state is not None else None
    if pos is not None:
        shard_cap = H.suggest_shard_cap(plan.domain, pos, ns)
    else:
        # fewer shards -> each slab holds at least old_load * old/new
        ratio = plan.n_shards / ns
        shard_cap = -(-int(plan.shard_cap * ratio + 0.999) // 8) * 8
    max_active = plan.max_active
    if plan.compact:
        if pos is not None:
            max_active = H.suggest_shard_max_active(plan.domain, pos, ns)
        else:
            max_active = min(-(-int(max_active * ratio + 0.999) // 8) * 8,
                             plan.domain.nz * plan.domain.ny)
    return dataclasses.replace(plan, n_shards=ns, shard_cap=shard_cap,
                               max_active=max_active, mesh=None, box=None)


def halo_grown_bounds(plan, state, align: int = 8
                      ) -> Tuple[int, Optional[int]]:
    """-> ``(shard_cap, max_active)`` covering ``state``, growing only the
    bound(s) that actually overflowed (the replan contract)."""
    pos = state.positions
    counts = cell_counts(plan.domain, pos)           # one binning pass
    shard_cap = plan.shard_cap
    loads = H.shard_loads(plan.domain, pos, plan.n_shards, counts=counts)
    if int(jnp.max(loads)) > shard_cap:
        grow = -(-(shard_cap + 1) // align) * align      # aligned, > cap
        shard_cap = max(
            H.suggest_shard_cap(plan.domain, pos, plan.n_shards,
                                align=align), grow)
    max_active = plan.max_active
    if plan.compact:
        n_act = int(jnp.max(shard_pencil_active(plan.domain, counts,
                                                plan.n_shards)))
        if n_act > max_active:
            max_active = max(
                H.suggest_shard_max_active(plan.domain, pos, plan.n_shards,
                                           align=align, counts=counts),
                n_act)
    return shard_cap, max_active
