"""Role-based sharding rules.

Model code never names mesh axes; it names *roles*:

    x = constrain(x, "dp", None, "tp")     # batch over DP, last dim over TP

and this module resolves roles against the active mesh — "dp" is the data
hierarchy (``("pod", "data")``, plus "model" when the config runs pure-DP),
"tp" is the "model" axis. Outside any mesh context ``constrain`` is a no-op,
which is what lets the same model run in 1-device smoke tests and on the
production mesh unchanged.

``sanitize`` enforces GSPMD's divisibility rule: a spec entry whose axis
product does not divide the dimension is dropped (to ``None``) rather than
left to error at lowering.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any

_STATE = threading.local()


def set_pure_dp(flag: bool) -> None:
    """Small models fold the model axis into DP (no tensor parallelism)."""
    _STATE.pure_dp = bool(flag)


def _pure_dp() -> bool:
    return getattr(_STATE, "pure_dp", False)


@contextlib.contextmanager
def use_mesh(mesh: Mesh):
    """Make ``mesh`` the resolution target for in-model ``constrain`` calls.

    (jax 0.4.x has no public ``use_abstract_mesh``; this module-level context
    is what the dry-run and the SPMD tests wrap lowering in.)
    """
    prev = getattr(_STATE, "mesh", None)
    _STATE.mesh = mesh
    try:
        yield mesh
    finally:
        _STATE.mesh = prev


def current_mesh() -> Optional[Mesh]:
    return getattr(_STATE, "mesh", None)


def _axis_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    names = entry if isinstance(entry, tuple) else (entry,)
    return int(np.prod([mesh.shape[n] for n in names]))


def sanitize(mesh: Mesh, spec: P, shape) -> P:
    """Drop spec entries whose mesh-axis product doesn't divide the dim."""
    out = []
    for entry, dim in zip(spec, shape):
        if entry is not None and dim % _axis_size(mesh, entry) != 0:
            entry = None
        out.append(entry)
    return P(*out)


def _role_axes(mesh: Mesh, role: Optional[str]):
    names = mesh.axis_names
    if role is None:
        return None
    if role == "dp":
        axes = [a for a in ("pod", "data") if a in names]
        if _pure_dp() and "model" in names:
            axes.append("model")
        return tuple(axes) if axes else None
    if role == "tp":
        return "model" if ("model" in names and not _pure_dp()) else None
    if role in names:                      # raw axis name passes through
        return role
    raise ValueError(f"unknown sharding role {role!r}")


def constrain(x, *roles):
    """``with_sharding_constraint`` by role; no-op without a mesh context."""
    mesh = current_mesh()
    if mesh is None or not mesh.axis_names:
        return x
    entries = [_role_axes(mesh, r) for r in roles]
    spec = sanitize(mesh, P(*entries), x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# --------------------------------------------------------------------------
# NamedSharding trees (params / optimizer / batch / kv-cache)
# --------------------------------------------------------------------------

def _leaf_spec(mesh: Mesh, shape) -> P:
    """FSDP-flavoured default: biggest divisible dim over DP, and (2-D+
    leaves) the last other divisible dim over TP."""
    dp = _role_axes(mesh, "dp")
    tp = _role_axes(mesh, "tp")
    entries = [None] * len(shape)
    if shape:
        dp_dim = None
        if dp is not None:
            divisible = [i for i, d in enumerate(shape)
                         if d % _axis_size(mesh, dp) == 0 and d > 1]
            if divisible:
                dp_dim = max(divisible, key=lambda i: shape[i])
                entries[dp_dim] = dp
        if tp is not None and len(shape) >= 2:
            for i in range(len(shape) - 1, -1, -1):
                if i != dp_dim and shape[i] % _axis_size(mesh, tp) == 0 \
                        and shape[i] > 1:
                    entries[i] = tp
                    break
    return P(*entries)


def _shard_tree(mesh: Mesh, tree: PyTree) -> PyTree:
    def one(leaf):
        spec = _leaf_spec(mesh, tuple(leaf.shape)) if leaf.ndim else P()
        return NamedSharding(mesh, sanitize(mesh, spec, leaf.shape))
    return jax.tree.map(one, tree)


def params_shardings(cfg, mesh: Mesh, params: PyTree) -> PyTree:
    set_pure_dp(getattr(cfg, "pure_dp", False))
    return _shard_tree(mesh, params)


def opt_shardings(cfg, mesh: Mesh, opt: PyTree, params: PyTree) -> PyTree:
    """Optimizer moments shard exactly like the params (ZeRO)."""
    set_pure_dp(getattr(cfg, "pure_dp", False))
    return _shard_tree(mesh, opt)


def batch_shardings(cfg, mesh: Mesh, batch: Dict) -> PyTree:
    set_pure_dp(getattr(cfg, "pure_dp", False))
    dp = _role_axes(mesh, "dp")

    def one(leaf):
        spec = P(*([dp] + [None] * (leaf.ndim - 1))) if leaf.ndim else P()
        return NamedSharding(mesh, sanitize(mesh, spec, leaf.shape))
    return jax.tree.map(one, batch)


def cache_shardings(cfg, mesh: Mesh, cache: PyTree) -> PyTree:
    """KV caches: batch dim over DP, head dim (when present) over TP."""
    set_pure_dp(getattr(cfg, "pure_dp", False))
    return _shard_tree(mesh, cache)
