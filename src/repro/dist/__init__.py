"""Distribution layer: sharding rules, halo exchange, fault tolerance,
gradient compression.

Modules:
  sharding   role-based constraints ("dp"/"tp" -> mesh axes) + NamedSharding
             trees for params/opt/batch/cache; ``sanitize`` drops axes that
             don't divide.
  halo       halo-exchange primitives: traceable Z-slab partition,
             ppermute ghost-plane exchange, per-shard load/occupancy probes.
  engine     the distributed execution subsystem: ``backend="halo"`` routes
             ``plan.execute`` through shard_map over Z-slabs (per-shard
             binning + compaction, ghost exchange, any registered schedule
             per shard — the paper's grid stretched across chips).
  fault      straggler watchdog, restart-from-latest-checkpoint driver,
             elastic re-mesh restore.
  compress   int8 gradient compression with error feedback (slow inter-pod
             links).
"""

from . import compress, engine, fault, halo, sharding

__all__ = ["compress", "engine", "fault", "halo", "sharding"]
