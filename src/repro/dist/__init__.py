"""Distribution layer: sharding rules, halo exchange, fault tolerance,
gradient compression.

Modules:
  sharding   role-based constraints ("dp"/"tp" -> mesh axes) + NamedSharding
             trees for params/opt/batch/cache; ``sanitize`` drops axes that
             don't divide.
  halo       the distributed particle engine: shard_map over Z-slabs with
             ghost-plane exchange (the paper's grid stretched across chips).
  fault      straggler watchdog, restart-from-latest-checkpoint driver,
             elastic re-mesh restore.
  compress   int8 gradient compression with error feedback (slow inter-pod
             links).
"""

from . import compress, fault, halo, sharding

__all__ = ["compress", "fault", "halo", "sharding"]
