"""Fault tolerance: straggler detection, restart driver, elastic restore.

The production contract (ckpt/checkpoint.py provides the atomic-commit
half): a training loop that checkpoints every K steps can be killed at any
point — by a straggler watchdog or a real failure — and the driver restarts
it from the latest committed checkpoint, possibly on a *different* mesh
(elastic re-mesh restore: host arrays are device_put against the new mesh's
shardings).
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Callable, Optional, Tuple

from ..ckpt import checkpoint as C

PyTree = Any


class StragglerDetected(RuntimeError):
    """A step exceeded the deadline — treat the worker as failed."""


@dataclasses.dataclass
class FaultConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    step_deadline_s: float = 300.0   # watchdog deadline per step
    max_restarts: int = 10
    backoff_s: float = 0.0           # base sleep between restarts (0 in
                                     # tests); doubles per restart ...
    backoff_cap_s: float = 60.0      # ... up to this cap


class StragglerWatchdog:
    """Per-step deadline monitor (the TPU analogue of a straggling worker:
    one slow participant stalls every collective, so we fail fast and let
    the restart driver take over). ``history`` keeps the most recent
    ``history_len`` step times in a bounded deque — a long run must not
    grow watchdog state without bound."""

    def __init__(self, deadline_s: float, history_len: int = 1024):
        self.deadline_s = float(deadline_s)
        self.history: "collections.deque[float]" = collections.deque(
            maxlen=int(history_len))

    def observe(self, step_seconds: float) -> None:
        self.history.append(float(step_seconds))
        if step_seconds > self.deadline_s:
            raise StragglerDetected(
                f"step took {step_seconds:.3f}s > deadline "
                f"{self.deadline_s:.3f}s")


def run_with_restarts(train_loop: Callable[[int], Any],
                      cfg: FaultConfig,
                      sleep: Callable[[float], None] = time.sleep) -> Any:
    """Drive ``train_loop(start_step)`` to completion with restarts.

    On any ``RuntimeError`` — ``StragglerDetected``, a lost shard
    (``testing.chaos.ShardLost``), a corrupt checkpoint
    (``ckpt.checkpoint.CheckpointCorrupt``), a transient backend error —
    the loop is restarted from the latest committed *intact* checkpoint
    step; the loop itself is responsible for restoring state from
    ``cfg.ckpt_dir``. Restarts sleep ``cfg.backoff_s * 2**(k-1)`` seconds
    (capped at ``cfg.backoff_cap_s``) so a crash-looping cluster backs
    off instead of hammering; ``sleep`` is injectable for tests. After
    ``cfg.max_restarts`` restarts the last error propagates.
    """
    restarts = 0
    while True:
        start = C.latest_step(cfg.ckpt_dir) or 0
        try:
            return train_loop(start)
        except RuntimeError as e:
            restarts += 1
            if restarts > cfg.max_restarts:
                raise
            print(f"[fault] restart {restarts}/{cfg.max_restarts} "
                  f"from step {C.latest_step(cfg.ckpt_dir) or 0}: {e}")
            if cfg.backoff_s:
                sleep(min(cfg.backoff_s * 2.0 ** (restarts - 1),
                          cfg.backoff_cap_s))


def elastic_restore(ckpt_dir, tree_like: PyTree,
                    shardings_fn: Callable[[], PyTree],
                    step: Optional[int] = None) -> Tuple[PyTree, dict]:
    """Restore a checkpoint onto a *new* mesh (elastic re-mesh restart).

    ``shardings_fn`` is called after the new mesh exists and returns the
    sharding tree to device_put against; leaves come back resharded for the
    surviving device set. Returns ``(tree, extra)``.
    """
    return C.restore(ckpt_dir, tree_like, step=step,
                     shardings=shardings_fn())
