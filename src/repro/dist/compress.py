"""Gradient compression for slow inter-pod links: int8 + error feedback.

Per-tensor symmetric int8 quantization (scale = max|g| / 127). Error
feedback carries the quantization residual into the next step, which is
what keeps compressed SGD/Adam converging to the uncompressed optimum
(Karimireddy et al., 2019) — tested on a quadratic in
tests/test_train_ckpt_fault.py.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


def _compress_leaf(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    gf = g.astype(jnp.float32)
    scale = jnp.max(jnp.abs(gf)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_grads_int8(grads: PyTree) -> Tuple[PyTree, PyTree]:
    """-> (int8 tree, per-tensor fp32 scale tree). 4x wire bytes saved."""
    pairs = jax.tree.map(_compress_leaf, grads)
    is_pair = lambda t: isinstance(t, tuple)
    packed = jax.tree.map(lambda t: t[0], pairs, is_leaf=is_pair)
    scales = jax.tree.map(lambda t: t[1], pairs, is_leaf=is_pair)
    return packed, scales


def decompress_grads_int8(packed: PyTree, scales: PyTree) -> PyTree:
    return jax.tree.map(lambda q, s: q.astype(jnp.float32) * s,
                        packed, scales)


def init_residual(params: PyTree) -> PyTree:
    """Zero error-feedback residual matching the grad tree."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_with_feedback(grads: PyTree, residual: PyTree
                           ) -> Tuple[PyTree, PyTree]:
    """-> (decompressed grads to apply, new residual).

    Compresses ``grads + residual`` and carries the quantization error into
    the next step.
    """
    corrected = jax.tree.map(lambda g, r: g.astype(jnp.float32) + r,
                             grads, residual)
    packed, scales = compress_grads_int8(corrected)
    decompressed = decompress_grads_int8(packed, scales)
    new_residual = jax.tree.map(lambda c, d: c - d, corrected, decompressed)
    return decompressed, new_residual
