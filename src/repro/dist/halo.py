"""Halo-exchange primitives: Z-slab partition + ghost-plane ``ppermute``.

The low-level machinery of the distributed execution subsystem
(``repro.dist.engine``). The paper's (nz, ny, nx) cell grid is split into
Z-slabs, one per shard along a mesh axis; each shard bins its own particles
into the slab's padded planes and fills its two ghost Z-planes from the
neighbouring shards — the ghost ring of the paper's layout, crossing chips
instead of staying in HBM.

This module owns the pieces that are pure functions of arrays:

  ``partition_by_shard``    traceable per-shard gather under a static
                            ``cap`` (the shard-capacity analogue of the
                            paper's M_C bound — overloaded shards are
                            detectable, never silently wrong),
  ``exchange_halo``         the ``ppermute`` ghost-plane exchange (periodic
                            Z wraps around the shard ring with the
                            minimum-image coordinate shift; **non-periodic
                            Z boundaries are filled with empty planes** so
                            open boundaries contribute zero ghosts),
  ``shard_loads`` / ``suggest_shard_cap`` / ``suggest_shard_max_active``
                            the host-side occupancy probes behind the plan
                            layer's overflow/replan contract.

The executor that strings them together under ``shard_map`` lives in
``repro.dist.engine``; ``plan(..., backend="halo")`` is the front door.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core.binning import (EMPTY_POS, cell_counts, shard_pencil_active,
                            shard_slab_counts)
from ..core.domain import Domain

Array = jnp.ndarray

# anything beyond this is sentinel padding, far outside every real box
VALID_MAX = 1.0e7


# --------------------------------------------------------------------------
# shard assignment + load probes (host side, outside jit)
# --------------------------------------------------------------------------

def shard_ids(domain: Domain, positions: Array, n_shards: int) -> Array:
    """(N,) Z-slab shard index per particle (periodic-aware cell coords)."""
    if domain.nz % n_shards:
        raise ValueError(
            f"nz={domain.nz} not divisible by n_shards={n_shards}")
    zc = domain.cell_coords(positions)[:, 2]
    return zc // (domain.nz // n_shards)


def shard_loads(domain: Domain, positions: Array, n_shards: int,
                counts: Array | None = None) -> Array:
    """(n_shards,) particles per Z-slab shard. Pass precomputed per-cell
    ``counts`` (``binning.cell_counts``) to skip the binning pass."""
    if counts is None:
        counts = cell_counts(domain, positions)
    return shard_slab_counts(domain, counts, n_shards)


def suggest_shard_cap(domain: Domain, positions: Array, n_shards: int,
                      slack: float = 1.3, align: int = 8) -> int:
    """One-off static per-shard particle capacity: the busiest shard's load
    with slack, rounded up to ``align`` — the same measure-plus-slack
    contract as ``suggest_m_c``. Particles drift between slabs as they
    move; an exceeded cap is caught by ``InteractionPlan.check_overflow``.
    """
    mx = int(jnp.max(shard_loads(domain, positions, n_shards)))
    cap = max(1, int(mx * slack + 0.999))
    return -(-cap // align) * align


def suggest_shard_max_active(domain: Domain, positions: Array,
                             n_shards: int, slack: float = 1.25,
                             align: int = 8,
                             counts: Array | None = None) -> int:
    """Static per-shard active-pencil bound for the compacted halo path:
    the busiest shard's active (z, y) pencil count with slack, aligned,
    clipped to the slab's total pencil count."""
    if counts is None:
        counts = cell_counts(domain, positions)
    mx = int(jnp.max(shard_pencil_active(domain, counts, n_shards)))
    bound = max(1, int(mx * slack + 0.999))
    bound = -(-bound // align) * align
    return min(bound, (domain.nz // n_shards) * domain.ny)


# --------------------------------------------------------------------------
# traceable partition / scatter-back
# --------------------------------------------------------------------------

def partition_by_shard(domain: Domain, positions: Array,
                       fields: Optional[Dict[str, Array]], n_shards: int,
                       cap: int) -> Tuple[Array, Array, Dict[str, Array]]:
    """Group particles by Z-slab under a static per-shard ``cap``.

    Traceable (runs inside the jitted executor): per shard, a fixed-size
    ``nonzero`` gathers that shard's particle rows; pad rows point past the
    end of the particle array and read the ``EMPTY_POS`` sentinel. Returns
    ``(gather_idx (n_shards * cap,), pos_part (n_shards * cap, 3),
    fields_part)`` — ``gather_idx`` routes shard-local results back to
    particle order (pad entries index ``N`` and are dropped by a
    ``mode='drop'`` scatter).

    If a shard holds more than ``cap`` particles the extra rows are
    *dropped* — the plan layer detects that (``shard_loads`` vs the static
    cap) and replans, exactly like an overflowing ``m_c``.
    """
    n = positions.shape[0]
    shard = shard_ids(domain, positions, n_shards)
    idx = [jnp.nonzero(shard == s, size=cap, fill_value=n)[0]
           for s in range(n_shards)]
    gather_idx = jnp.stack(idx).astype(jnp.int32).reshape(-1)
    pad_pos = jnp.concatenate(
        [positions, jnp.full((1, 3), EMPTY_POS, positions.dtype)])
    pos_part = pad_pos[gather_idx]
    fields_part: Dict[str, Array] = {}
    for k, v in (fields or {}).items():
        fields_part[k] = jnp.concatenate(
            [v, jnp.zeros((1,), v.dtype)])[gather_idx]
    return gather_idx, pos_part, fields_part


def scatter_from_shards(gather_idx: Array, n: int, values: Array) -> Array:
    """Inverse of :func:`partition_by_shard` for per-row shard outputs:
    rows land back at their particle index, pad rows are dropped."""
    out_shape = (n,) + values.shape[1:]
    return jnp.zeros(out_shape, values.dtype).at[gather_idx].set(
        values, mode="drop")


# --------------------------------------------------------------------------
# the ghost-plane exchange (inside shard_map)
# --------------------------------------------------------------------------

def exchange_halo(plane: Array, *, axis: str, n_shards: int, nz_loc: int,
                  shard_index: Array, periodic_z: bool, fill,
                  coord_shift: float = 0.0) -> Array:
    """Fill a padded plane's two ghost Z-planes from the neighbouring shards.

    ``plane`` is any per-slot plane of the local ``CellBins`` layout —
    shape ``(nz_loc + 2, ny + 2, (nx + 2) * m_c)``. Each shard sends its
    last interior plane up the ring and its first interior plane down
    (``ppermute``); a periodic global Z wraps around the ring, with
    ``coord_shift`` applied so neighbour coordinates land in this shard's
    local frame (the minimum-image shift — pass the slab height for the
    "z" coordinate plane, 0 for everything else).

    At **non-periodic Z boundaries the ghost planes are filled with
    ``fill``** (the empty sentinel): the bottom shard's below-ghost and the
    top shard's above-ghost must contribute zero ghost particles, never the
    wrapped-around plane the ring permutation would otherwise deliver.
    """
    fwd = [(i, (i + 1) % n_shards) for i in range(n_shards)]
    bwd = [(i, (i - 1) % n_shards) for i in range(n_shards)]
    top = plane[nz_loc:nz_loc + 1]          # last interior plane
    bot = plane[1:2]                        # first interior plane
    from_below = jax.lax.ppermute(top, axis, fwd)
    from_above = jax.lax.ppermute(bot, axis, bwd)
    if coord_shift:                         # neighbour frame -> ours
        from_below = from_below - coord_shift
        from_above = from_above + coord_shift
    if not periodic_z:                      # open Z: border ghosts stay empty
        empty = jnp.full(bot.shape, fill, plane.dtype)
        from_below = jnp.where(shard_index == 0, empty, from_below)
        from_above = jnp.where(shard_index == n_shards - 1, empty,
                               from_above)
    plane = plane.at[0:1].set(from_below)
    return plane.at[nz_loc + 1:nz_loc + 2].set(from_above)
