"""Distributed cell-list engine: Z-slab decomposition + ghost-plane exchange.

The paper's grid, stretched across devices: the (nz, ny, nx) cell grid is
split into Z-slabs, one per shard along a mesh axis. Each shard

  1. bins its own particles into the slab's padded planes (the sentinel
     rows ``partition_by_z`` pads with are masked out of the binning),
  2. exchanges its boundary Z-planes with the two neighbouring shards via
     ``ppermute`` — the ghost ring of the paper's layout, crossing chips
     instead of staying in HBM (periodic Z wraps around the ring with the
     minimum-image coordinate shift),
  3. runs any dense schedule (X-pencil by default) on the local slab, whose
     ghost planes now hold the neighbours' border cells.

Slot ids are offset per shard so the self-pair exclusion mask stays exact
across shard boundaries.

    pos_part = partition_by_z(domain, positions, n_shards)
    fn = make_distributed_compute(domain, kernel, m_c, mesh)
    forces, potential = fn(pos_part)          # per-particle, sentinel rows 0
"""

from __future__ import annotations

import dataclasses
import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from ..core import strategies as S
from ..core.binning import (EMPTY_POS, bin_particles, gather_to_particles,
                            interior_to_padded)
from ..core.domain import Domain
from ..core.interactions import PairKernel

Array = jnp.ndarray

# anything beyond this is sentinel padding, far outside every real box
_VALID_MAX = 1.0e7


def partition_by_z(domain: Domain, positions: Array, n_shards: int,
                   cap: int | None = None) -> Array:
    """Group particles by Z-slab, padding each shard to a common length.

    Returns (n_shards * cap, 3); pad rows sit at ``EMPTY_POS`` (detectable
    via ``pos[:, 0] > 1e7``). Runs on host (one-off layout step).
    """
    nz = domain.nz
    if nz % n_shards:
        raise ValueError(f"nz={nz} not divisible by n_shards={n_shards}")
    pos = np.asarray(positions)
    zc = np.asarray(domain.cell_coords(positions))[:, 2]
    shard = zc // (nz // n_shards)
    counts = np.bincount(shard, minlength=n_shards)
    cap = int(cap or counts.max())
    if counts.max() > cap:
        raise ValueError(f"cap={cap} < max shard load {int(counts.max())}")
    out = np.full((n_shards, cap, 3), EMPTY_POS, dtype=pos.dtype)
    for s in range(n_shards):
        rows = pos[shard == s]
        out[s, :len(rows)] = rows
    return jnp.asarray(out.reshape(n_shards * cap, 3))


def _empty_like_plane(plane: Array, fill) -> Array:
    return jnp.full(plane.shape, fill, plane.dtype)


def make_distributed_compute(domain: Domain, kernel: PairKernel, m_c: int,
                             mesh, axis: str = "data",
                             strategy: str = "xpencil",
                             batch_size: int = 64):
    """-> jitted ``fn(pos_part) -> (forces (N, 3), potential (N,))``.

    ``pos_part`` must be laid out by :func:`partition_by_z` (equal-sized
    Z-slab groups, sentinel padded). ``strategy`` is any dense schedule
    (``xpencil``/``cell_dense``/``allin``). Output rows of sentinel
    particles are zero.
    """
    n_shards = int(mesh.shape[axis])
    nx, ny, nz = domain.ncells
    if nz % n_shards:
        raise ValueError(f"nz={nz} not divisible by {n_shards} shards")
    nz_loc = nz // n_shards
    px, py, pz = domain.periodic_axes
    lz_loc = domain.box[2] / n_shards
    local_dom = Domain(box=(domain.box[0], domain.box[1], lz_loc),
                       ncells=(nx, ny, nz_loc), cutoff=domain.cutoff,
                       periodic=(px, py, False))
    if strategy not in S.STRATEGIES or strategy == "par_part":
        raise ValueError(f"halo engine needs a dense strategy, got "
                         f"{strategy!r}")
    strat_fn = S.STRATEGIES[strategy]

    if n_shards == 1:
        # degenerate mesh: no exchange partner (and with periodic Z the ring
        # would alias a shard with itself) — run the single-device schedule.
        from ..core.api import ParticleState, plan
        p = plan(domain, kernel, m_c=m_c, strategy=strategy,
                 batch_size=batch_size)

        @jax.jit
        def single(pos_part):
            valid = pos_part[:, 0] < _VALID_MAX
            safe = jnp.where(valid[:, None], pos_part, 0.0)
            f, pot = p.execute(ParticleState(safe))
            return f * valid[:, None], pot * valid
        return single

    fwd = [(i, (i + 1) % n_shards) for i in range(n_shards)]
    bwd = [(i, (i - 1) % n_shards) for i in range(n_shards)]

    def body(pos_local):
        cap = pos_local.shape[0]
        idx = jax.lax.axis_index(axis)
        valid = pos_local[:, 0] < _VALID_MAX
        shift = jnp.asarray([0.0, 0.0, 1.0], pos_local.dtype) * \
            (idx.astype(pos_local.dtype) * lz_loc)
        bins = bin_particles(local_dom, pos_local - shift, m_c=m_c,
                             valid=valid)

        # globally unique slot ids: shard offset under the periodic bump
        sid = bins.slot_id
        sid = jnp.where(sid >= 0, sid + idx * cap, sid)

        def exchange(plane, fill, z_shift):
            """Fill the two ghost Z-planes from the neighbouring shards."""
            top = plane[nz_loc:nz_loc + 1]     # last interior plane
            bot = plane[1:2]                   # first interior plane
            from_below = jax.lax.ppermute(top, axis, fwd)
            from_above = jax.lax.ppermute(bot, axis, bwd)
            if z_shift:                        # neighbour frame -> ours
                from_below = from_below - lz_loc
                from_above = from_above + lz_loc
            empty = _empty_like_plane(bot, fill)
            if not pz:                         # open Z: border shards stay
                from_below = jnp.where(idx == 0, empty, from_below)
                from_above = jnp.where(idx == n_shards - 1, empty,
                                       from_above)
            plane = plane.at[0:1].set(from_below)
            return plane.at[nz_loc + 1:nz_loc + 2].set(from_above)

        planes = dict(bins.planes)
        planes["x"] = exchange(planes["x"], EMPTY_POS, z_shift=False)
        planes["y"] = exchange(planes["y"], EMPTY_POS, z_shift=False)
        planes["z"] = exchange(planes["z"], EMPTY_POS, z_shift=True)
        sid = exchange(sid, -1, z_shift=False)
        bins = dataclasses.replace(bins, planes=planes, slot_id=sid)

        kwargs = {"batch_size": batch_size}
        fx, fy, fz, pot = strat_fn(local_dom, bins, kernel, **kwargs)
        outs = [gather_to_particles(bins, interior_to_padded(
            local_dom, plane.reshape(nz_loc, local_dom.ny, local_dom.nx,
                                     m_c), m_c))
                for plane in (fx, fy, fz, pot)]
        forces = jnp.stack(outs[:3], axis=-1) * valid[:, None]
        return forces, outs[3] * valid

    sharded = shard_map(body, mesh=mesh, in_specs=P(axis),
                        out_specs=(P(axis), P(axis)), check_rep=False)
    return jax.jit(sharded)
