from .pipeline import DataConfig, DataState, Pipeline, batch_at

__all__ = ["DataConfig", "DataState", "Pipeline", "batch_at"]
