"""Deprecated shim — the LM prefill/decode helpers moved to
``repro.models.serving`` when the particle serving tier (``repro.serve``)
took over the "serve" name. Import from the new home; this module
re-exports for compatibility and will be removed in a future cleanup.
"""

from ..models.serving import (generate, make_decode_step,  # noqa: F401
                              make_prefill_step)

__all__ = ["generate", "make_decode_step", "make_prefill_step"]
