from .trainer import (cross_entropy, make_eval_step, make_loss_fn,
                      make_train_step)
from ..models.serving import generate, make_decode_step, make_prefill_step

__all__ = ["cross_entropy", "make_eval_step", "make_loss_fn",
           "make_train_step", "generate", "make_decode_step",
           "make_prefill_step"]
