"""Train-step / serve-step factories (what the dry-run lowers).

``make_train_step`` returns the canonical SPMD step:

    loss -> grad -> (optional int8 compression) -> AdamW -> new state

with: masked next-token CE in fp32 with z-loss, MoE aux loss, remat inside
the layer scan (model.py), microbatch gradient accumulation (scan over
microbatches, grads averaged — the FSDP all-gathers then amortize across
microbatches), and buffer donation so params/opt-state update in place.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models import model as M
from ..optim.adam import AdamConfig, adam_update
from ..dist.compress import compress_grads_int8, decompress_grads_int8
from ..dist.sharding import constrain

Array = jnp.ndarray
PyTree = Any


def cross_entropy(logits: Array, labels: Array, mask: Optional[Array] = None,
                  z_loss: float = 1e-4) -> Array:
    """Masked token-mean CE (+ z-loss) in fp32; handles padded/image slots
    via label == -1 masking and logits that are longer than labels (vlm
    prefix tokens score nothing).

    Vocab-sharding-friendly: the gold logit is extracted with an iota
    comparison + reduction instead of take_along_axis — a gather along a TP-
    sharded vocab axis makes GSPMD all-gather the full fp32 logits (measured
    +80 GB/device on qwen1.5-0.5b train_4k; EXPERIMENTS.md §Perf)."""
    if logits.shape[1] != labels.shape[1]:
        logits = logits[:, logits.shape[1] - labels.shape[1]:]
    lf = constrain(logits.astype(jnp.float32), "dp", None, "tp")
    # stable logsumexp with sharded-vocab reductions (max/sum partial-reduce
    # then all-reduce — no vocab gather)
    m = jax.lax.stop_gradient(jnp.max(lf, axis=-1, keepdims=True))
    lse = jnp.log(jnp.sum(jnp.exp(lf - m), axis=-1)) + m[..., 0]
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, lf.shape, lf.ndim - 1)
    gold = jnp.sum(jnp.where(vocab_iota == labels[..., None].clip(0), lf,
                             0.0), axis=-1)
    nll = lse - gold + z_loss * lse ** 2
    valid = (labels >= 0).astype(jnp.float32)
    if mask is not None:
        valid = valid * mask
    return jnp.sum(nll * valid) / jnp.maximum(valid.sum(), 1.0)


def chunked_cross_entropy(logits_fn: Callable, x: Array, labels: Array,
                          head: Array, n_chunks: int = 8,
                          z_loss: float = 1e-4) -> Array:
    """CE with the (B, S_chunk, V) logits materialized one sequence chunk at
    a time (scan) — the full (B, S, V) fp32 logits buffer never exists.
    ``logits_fn(x_chunk @ head)`` applies softcap etc."""
    b, s, d = x.shape
    n_chunks = min(n_chunks, s)
    while s % n_chunks:
        n_chunks -= 1
    cs = s // n_chunks
    xc = x.reshape(b, n_chunks, cs, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, n_chunks, cs).transpose(1, 0, 2)

    head = constrain(head, None, "tp")     # JIT weight-gather (ZeRO-3)

    def step(acc, inp):
        xch, lch = inp
        logits = logits_fn(jnp.einsum("bsd,dv->bsv", xch, head))
        lf = constrain(logits.astype(jnp.float32), "dp", None, "tp")
        m = jax.lax.stop_gradient(jnp.max(lf, axis=-1, keepdims=True))
        lse = jnp.log(jnp.sum(jnp.exp(lf - m), axis=-1)) + m[..., 0]
        iota = jax.lax.broadcasted_iota(jnp.int32, lf.shape, lf.ndim - 1)
        gold = jnp.sum(jnp.where(iota == lch[..., None].clip(0), lf, 0.0),
                       axis=-1)
        nll = lse - gold + z_loss * lse ** 2
        valid = (lch >= 0).astype(jnp.float32)
        return (acc[0] + jnp.sum(nll * valid), acc[1] + valid.sum()), None

    import os as _os
    unroll = _os.environ.get("REPRO_SCAN_UNROLL", "1")
    (total, count), _ = jax.lax.scan(
        step, (0.0, 0.0), (xc, lc),
        unroll=True if unroll == "full" else int(unroll))
    return total / jnp.maximum(count, 1.0)


def make_loss_fn(cfg: ModelConfig, aux_weight: float = 1e-2,
                 loss_chunks: int = 0, remat: bool = True) -> Callable:
    import os as _os
    loss_chunks = loss_chunks or int(_os.environ.get("REPRO_LOSS_CHUNKS", 8))
    def loss_fn(params: PyTree, batch: Dict[str, Array]) -> Tuple[Array, Dict]:
        extras = {k: v for k, v in batch.items()
                  if k not in ("tokens", "labels")}
        x, aux = M.forward_hidden(cfg, params, batch["tokens"], remat=remat,
                                  **extras)
        labels = batch["labels"]
        if x.shape[1] != labels.shape[1]:       # vlm prefix tokens: no loss
            x = x[:, x.shape[1] - labels.shape[1]:]
        ce = chunked_cross_entropy(M.logits_transform(cfg), x, labels,
                                   M.lm_head(cfg, params),
                                   n_chunks=loss_chunks)
        return ce + aux_weight * aux, {"ce": ce, "aux": aux}
    return loss_fn


def make_train_step(cfg: ModelConfig, opt_cfg: AdamConfig,
                    microbatches: int = 1,
                    compress_pod_grads: bool = False,
                    remat: bool = True) -> Callable:
    """-> train_step(params, opt_state, batch) -> (metrics, params, opt)."""
    loss_fn = make_loss_fn(cfg, remat=remat)

    def grads_of(params, batch):
        (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        return loss, parts, grads

    def train_step(params: PyTree, opt_state: PyTree,
                   batch: Dict[str, Array]):
        if microbatches > 1:
            def mb(carry, mb_batch):
                acc, loss_acc = carry
                loss, _, grads = grads_of(params, mb_batch)
                acc = jax.tree.map(jnp.add, acc, grads)
                return (acc, loss_acc + loss), None

            split = jax.tree.map(
                lambda x: x.reshape(microbatches, x.shape[0] // microbatches,
                                    *x.shape[1:]), batch)
            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params)
            (grads, loss), _ = jax.lax.scan(mb, (zeros, 0.0), split)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss / microbatches
            parts = {"ce": loss, "aux": jnp.zeros((), jnp.float32)}
        else:
            loss, parts, grads = grads_of(params, batch)

        if compress_pod_grads:
            # int8 + error feedback over the slow inter-pod links; XLA's
            # all-reduce of the *decompressed* values stays on fast links
            # because the pod axis reduction happens on the int8 tensors.
            packed, scales = compress_grads_int8(grads)
            grads = decompress_grads_int8(packed, scales)

        new_params, new_opt = adam_update(params, grads, opt_state, opt_cfg)
        metrics = {"loss": loss, **parts}
        return metrics, new_params, new_opt

    return train_step


def make_eval_step(cfg: ModelConfig) -> Callable:
    loss_fn = make_loss_fn(cfg)

    def eval_step(params, batch):
        loss, parts = loss_fn(params, batch)
        return {"loss": loss, **parts}

    return eval_step
