"""Analytical staging-traffic model — the TPU stand-in for the paper's Fig. 7.

Occupancy / L2-hit-rate / branch-efficiency are CUDA SM-scheduler metrics with
no TPU analogue (DESIGN.md §2). What *does* transfer is the quantity shared
memory exists to optimize: HBM bytes moved per interaction, the reuse factor
of each staged byte, and the fast-memory footprint per grid step (which on
TPU bounds double-buffering head-room instead of occupancy).

All formulas assume the dense slot layout (m_c slots/cell, 4 f32 fields:
x, y, z, slot_id) and a full 27-neighborhood (border effects ignored, as in
the paper's "aside from the border cells" argument).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

from .domain import Domain

FIELD_BYTES = 4 * 4  # x, y, z, slot_id as f32/i32


@dataclasses.dataclass(frozen=True)
class TrafficReport:
    strategy: str
    hbm_bytes_per_interaction: float   # global-memory traffic / interactions
    staged_bytes_per_step: int         # VMEM footprint of one grid step
    reuse_factor: float                # interactions per staged byte-load
    padded_work_fraction: float        # masked-lane waste (idle threads)
    grid_steps: int                    # number of pallas grid steps


def model(domain: Domain, m_c: int, avg_ppc: float,
          subbox: Tuple[int, int, int] | None = None) -> Dict[str, TrafficReport]:
    """Traffic model for each strategy at a given fill ratio.

    ``avg_ppc``: average particles per cell (paper: 1, 10, 100).
    Interactions per cell ~= avg_ppc * 27 * avg_ppc (cutoff filtering is the
    same for all strategies, so it cancels in comparisons).
    """
    nx, ny, nz = domain.ncells
    n_cells = domain.n_cells
    n_parts = n_cells * avg_ppc
    inter_per_cell = 27.0 * avg_ppc * avg_ppc
    total_inter = n_cells * inter_per_cell
    pad2 = (m_c / max(avg_ppc, 1e-9)) ** 2          # slot-padding waste, pairs
    cell_bytes = m_c * FIELD_BYTES

    out: Dict[str, TrafficReport] = {}

    # Par-Part: each particle loads its 27 neighbor cells; zero reuse across
    # particles (caches aside — the paper's point).
    loads = n_parts * 27 * cell_bytes + n_parts * FIELD_BYTES
    out["par_part"] = TrafficReport(
        "par_part", loads / total_inter, 0, 1.0 / max(avg_ppc, 1e-9),
        1.0 - 1.0 / pad2, int(n_parts))

    # Par-Cell(-SM): each cell stages its 27 neighbors once; every staged
    # byte is reused by the cell's m_c targets.
    loads = n_cells * (27 + 1) * cell_bytes
    out["cell_dense"] = TrafficReport(
        "cell_dense", loads / total_inter, 2 * cell_bytes,
        float(avg_ppc), 1.0 - 1.0 / pad2, n_cells)

    # X-pencil: per (z, y) pencil, the target row + 9 neighbor rows of
    # (nx + 2) cells each are staged; reuse = 3 cells' worth of targets per
    # staged cell (the X window).
    row_bytes = (nx + 2) * cell_bytes
    loads = (nz * ny) * (9 + 1) * row_bytes
    out["xpencil"] = TrafficReport(
        "xpencil", loads / total_inter, 2 * row_bytes,
        3.0 * avg_ppc, 1.0 - 1.0 / pad2, nz * ny)

    # All-in-SM: per sub-box, the (b+2)^3 halo block is staged once; interior
    # cells reuse 27x, the halo ring less (paper: between 9 and 1).
    if subbox is None:
        from .strategies import subbox_dims
        subbox = subbox_dims(domain, m_c)
    bx, by, bz = subbox
    halo_cells = (bx + 2) * (by + 2) * (bz + 2)
    n_boxes = -(-nx // bx) * (-(-ny // by)) * (-(-nz // bz))
    loads = n_boxes * halo_cells * cell_bytes
    inter_per_box = bx * by * bz * inter_per_cell
    reuse = inter_per_box / max(halo_cells * avg_ppc, 1e-9)
    out["allin"] = TrafficReport(
        "allin", loads / max(total_inter, 1e-9), halo_cells * cell_bytes,
        reuse, 1.0 - 1.0 / pad2, n_boxes)

    return out


def compact_report(report: TrafficReport, fill: float) -> TrafficReport:
    """Fill-fraction-aware cost of the occupancy-compacted variant.

    Compaction changes *which* work units run, not what each one costs:
    staged bytes per step and per-unit reuse are unchanged, but only the
    ``fill`` fraction of grid steps (and their HBM loads) happen at all.
    The interaction count is identical — empty units contribute none — so
    bytes-per-interaction scales linearly with the fill fraction. The
    masked-lane waste *within* active units (slot padding) also stays: the
    compacted path removes empty pencils, not empty slots.
    """
    fill = min(max(float(fill), 0.0), 1.0)
    return dataclasses.replace(
        report,
        strategy=f"{report.strategy}_compact",
        hbm_bytes_per_interaction=report.hbm_bytes_per_interaction * fill,
        grid_steps=max(1, int(round(report.grid_steps * fill))),
    )


def packed_report(report: TrafficReport, m_c: int,
                  avg_ppc: float) -> TrafficReport:
    """Packed-row (CSR) layout cost of a pencil schedule.

    The dense layout moves ``m_c * FIELD_BYTES`` per cell whatever the
    cell holds; the packed layout moves bytes proportional to the
    *particles*: per cell, ``ppc`` slots of the four fields plus the
    packed slot-cell index, plus one int32 prefix-sum offset. At ppc 1-4
    with m_c sublane-aligned to 8 that is the 2-8x byte cut the paper's
    few-particles-per-cell regime leaves on the table. Grid steps, per-step
    reuse and lane waste are unchanged — packing moves fewer bytes per
    step, it does not change which steps run (compose with
    :func:`compact_report` for that) or the dense shape compute is
    re-expanded to.
    """
    ppc = max(avg_ppc, 1e-3)
    dense_cell = m_c * FIELD_BYTES
    packed_cell = ppc * (FIELD_BYTES + 4) + 4
    factor = min(1.0, packed_cell / dense_cell)
    return dataclasses.replace(
        report,
        strategy=f"{report.strategy}_packed",
        hbm_bytes_per_interaction=report.hbm_bytes_per_interaction * factor,
        staged_bytes_per_step=max(1, int(report.staged_bytes_per_step
                                         * factor)),
    )


def sfc_report(domain: Domain, m_c: int, avg_ppc: float,
               csize: int | None = None, fill: float = 1.0) -> TrafficReport:
    """SFC cluster layout cost of the Par-Cell schedule.

    The SFC layout replaces the dense 27-stencil sweep with the compressed
    cluster-pair list: the grid iterates only the *kept* pairs (``fill``
    fraction of the ``27 * n_clusters`` stencil slots), so empty stencil
    work disappears from both the step count and the HBM loads — the same
    effect occupancy compaction has on pencils, but at cluster-pair
    granularity and paid for by one int32 pair code per step instead of a
    per-pencil occupancy scan. Per kept pair the kernel stages the
    ``csize`` source cells (the target tile stays resident across the
    cluster's consecutive pairs and is amortized over them); each staged
    source byte is reused by the cluster's ``csize * m_c`` targets.
    """
    if csize is None:
        from .binning import DEFAULT_CSIZE
        csize = DEFAULT_CSIZE
    fill = min(max(float(fill), 1e-3), 1.0)
    ppc = max(avg_ppc, 1e-3)
    n_cells = domain.n_cells
    n_clusters = -(-n_cells // csize)
    total_inter = n_cells * 27.0 * ppc * ppc
    pad2 = (m_c / ppc) ** 2
    cell_bytes = m_c * FIELD_BYTES
    kept_pairs = 27.0 * fill                      # kept pairs per cluster
    # target tile once per cluster + (sources + pair code) per kept pair
    loads = n_clusters * (csize * cell_bytes
                          + kept_pairs * (csize * cell_bytes + 4))
    return TrafficReport(
        "cell_dense_sfc", loads / max(total_inter, 1e-9),
        2 * csize * cell_bytes, csize * ppc, 1.0 - 1.0 / pad2,
        max(1, int(round(n_clusters * kept_pairs))))


def candidate_cost(domain: Domain, m_c: int, avg_ppc: float, strategy: str,
                   subbox: Tuple[int, int, int] | None = None,
                   compact: bool = False, fill: float = 1.0,
                   layout: str = "dense") -> float:
    """Pruning hook for the measured autotuner (``core.autotune``).

    Scores one candidate configuration by its modelled HBM bytes per
    interaction — the quantity ``strategy="auto"`` minimizes outright. The
    autotuner only uses it to *rank* candidates before timing the top-k, so
    the model's job here is softer: it must keep the true winner in the
    field, not name it. ``naive_n2`` has no staging and is modelled as one
    full pass over all pairs (it never survives pruning on real grids).

    ``compact=True`` scores the occupancy-compacted variant at the given
    active-work-unit ``fill`` fraction (see :func:`compact_report`);
    ``layout="packed"`` scores the packed-row layout
    (see :func:`packed_report`); the two axes compose multiplicatively.
    ``layout="sfc"`` scores the compressed cluster-pair list
    (see :func:`sfc_report`) — there ``fill`` is intrinsic to the pair
    list, and ``compact`` is a no-op, exactly as in the execution path.
    """
    if strategy == "naive_n2":
        n = domain.n_cells * max(avg_ppc, 1e-3)
        total_inter = domain.n_cells * 27.0 * max(avg_ppc, 1e-3) ** 2
        return n * n * FIELD_BYTES / max(total_inter, 1e-9)
    if layout == "sfc":
        return sfc_report(domain, m_c, max(avg_ppc, 1e-3),
                          fill=fill).hbm_bytes_per_interaction
    reports = model(domain, m_c, max(avg_ppc, 1e-3), subbox=subbox)
    report = reports[strategy]
    if layout == "packed":
        report = packed_report(report, m_c, avg_ppc)
    if compact:
        report = compact_report(report, fill)
    return report.hbm_bytes_per_interaction
