"""Binning pipeline: the paper's Section 2 preprocessing, TPU-shaped.

Pipeline (paper order, atomic-free):
  1. per-particle cell index (parallel),
  2. per-cell counts      -> ``jax.ops.segment_sum`` (replaces atomics),
  3. cell start offsets   -> the paper's prefix sum (``core.prefix``),
  4. out-of-place reorder -> stable argsort by cell id + rank-in-cell,
  5. **dense cell-slot layout**: every cell owns exactly ``m_c`` contiguous
     slots in SoA planes of shape ``(nz+2, ny+2, (nx+2)*m_c)``.

Step 5 is the TPU adaptation (DESIGN.md §2): X stays the fastest axis (the
paper's linearization), so an X-pencil of cells is one contiguous row and the
3-cell interaction window of a cell is one contiguous ``3*m_c`` slice — the
structural equivalent of what the paper builds in shared memory with its
local-offset prefix sums. The one-cell ghost ring (always empty for open
boundaries, wrapped copies for periodic domains) removes all border branching.

``m_c`` is the paper's M_C — the max particles per cell — and must be a
static (trace-time) bound. Overflowing particles are dropped by the scatter
(``mode='drop'``); ``CellBins.counts`` lets callers detect that and re-bin
with a larger bound (the engine does exactly what the paper does: track the
max while computing the prefix sum).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .domain import Domain
from .prefix import exclusive_prefix_sum

Array = jnp.ndarray

# Sentinel coordinate for empty slots: far outside any box, finite so that
# (sentinel - real) stays finite and (sentinel - sentinel) == 0; both cases
# are masked out by slot ids anyway (DESIGN: TPUs want masks, not NaN traps).
EMPTY_POS = 1.0e8

# Slot-id offset carried by periodic ghost *copies*: a particle must still
# interact with its own periodic image, so ghost slots mirror the interior
# ids bumped by this constant — never equal to any real id, so the
# self-pair exclusion (id equality) keeps excluding only the true self
# pair. Shared with the distributed halo layer, whose cross-shard ghost
# planes use per-shard id offsets for the same reason.
GHOST_ID_BUMP = 1_000_000_000


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CellBins:
    """Dense cell-slot state. All planes share shape (nz+2, ny+2, (nx+2)*m_c)."""

    planes: Dict[str, Array]      # SoA field planes ("x","y","z",...)
    slot_id: Array                # int32 particle index per slot, -1 if empty
    counts: Array                 # (n_cells,) particles per cell
    offsets: Array                # (n_cells,) exclusive prefix (paper Fig. 1)
    particle_slot: Array          # (N,) flat slot index of each particle
    m_c: int = dataclasses.field(metadata=dict(static=True))

    @property
    def max_count(self) -> Array:
        return jnp.max(self.counts)


def padded_shape(domain: Domain, m_c: int) -> Tuple[int, int, int]:
    nx, ny, nz = domain.ncells
    return (nz + 2, ny + 2, (nx + 2) * m_c)


def cell_counts(domain: Domain, positions: Array,
                valid: Array | None = None) -> Array:
    """(n_cells,) particles per cell — the one binning pass every static
    bound probe (``m_c``, shard loads, occupancy) derives from. ``valid``
    masks out padding rows (the serving tier pads requests to a shape
    class; padded rows must not inflate any bound probe)."""
    weights = (jnp.ones((positions.shape[0],), jnp.int32) if valid is None
               else valid.astype(jnp.int32))
    return jax.ops.segment_sum(
        weights, domain.cell_ids(positions), num_segments=domain.n_cells)


def bin_particles(domain: Domain, positions: Array,
                  fields: Dict[str, Array] | None = None, *,
                  m_c: int, valid: Array | None = None) -> CellBins:
    """Bin particles into the dense slot layout.

    Args:
      positions: (N, 3) float array.
      fields: optional extra per-particle scalars to bin alongside x/y/z.
      m_c: static max-particles-per-cell bound (paper's M_C).
      valid: optional (N,) bool mask; False rows (e.g. the sentinel padding a
        halo shard carries) are excluded from counts and never land in a slot.
    """
    n = positions.shape[0]
    nx, ny, nz = domain.ncells
    n_cells = domain.n_cells

    coords = domain.cell_coords(positions)          # (N, 3) int32
    cids = domain.linearize(coords)                 # (N,)

    if valid is None:
        weights = jnp.ones((n,), jnp.int32)
        sort_key = cids
    else:
        # invalid rows carry weight 0 in cell 0 and sort past every real cell
        weights = valid.astype(jnp.int32)
        cids = jnp.where(valid, cids, 0)
        sort_key = jnp.where(valid, cids, n_cells)

    counts = jax.ops.segment_sum(weights, cids, num_segments=n_cells)
    offsets = exclusive_prefix_sum(counts)          # (n_cells,)

    # Rank of each particle within its cell via one stable sort (the paper's
    # atomic slot-grab, determinized).
    order = jnp.argsort(sort_key, stable=True)      # (N,) particle ids, sorted
    sorted_key = sort_key[order]
    rank = jnp.arange(n, dtype=jnp.int32) - offsets[
        jnp.clip(sorted_key, 0, n_cells - 1)]

    # Flat index into the padded planes; ranks >= m_c fall off the end of the
    # cell's slot range — push them fully out of bounds so 'drop' removes them.
    cxyz = coords[order]
    row_len = (nx + 2) * m_c
    slot_col = (cxyz[:, 0] + 1) * m_c + rank
    flat = ((cxyz[:, 2] + 1) * (ny + 2) + (cxyz[:, 1] + 1)) * row_len + slot_col
    total = (nz + 2) * (ny + 2) * row_len
    keep = (rank < m_c) & (sorted_key < n_cells)
    flat = jnp.where(keep, flat, total)             # out of range -> dropped

    shape = padded_shape(domain, m_c)

    def scatter(values: Array, fill: float) -> Array:
        plane = jnp.full((total,), fill, dtype=values.dtype)
        plane = plane.at[flat].set(values[order], mode="drop")
        return plane.reshape(shape)

    planes = {
        "x": scatter(positions[:, 0], EMPTY_POS),
        "y": scatter(positions[:, 1], EMPTY_POS),
        "z": scatter(positions[:, 2], EMPTY_POS),
    }
    if fields:
        for k, v in fields.items():
            planes[k] = scatter(v, 0.0)

    slot_flat = jnp.full((total,), -1, dtype=jnp.int32)
    slot_flat = slot_flat.at[flat].set(order.astype(jnp.int32), mode="drop")
    slot_id = slot_flat.reshape(shape)

    particle_slot = jnp.zeros((n,), dtype=jnp.int32).at[order].set(
        flat.astype(jnp.int32), mode="drop")

    bins = CellBins(planes=planes, slot_id=slot_id, counts=counts,
                    offsets=offsets, particle_slot=particle_slot, m_c=m_c)
    if domain.any_periodic:
        bins = _fill_periodic_ghosts(domain, bins)
    return bins


def _fill_periodic_ghosts(domain: Domain, bins: CellBins) -> CellBins:
    """Copy wrapped interior slabs into the ghost ring (minimum image),
    per periodic axis."""
    nx, ny, nz = domain.ncells
    m_c = bins.m_c
    lx, ly, lz = domain.box
    px, py, pz = domain.periodic_axes

    def wrap(plane: Array, field: str) -> Array:
        if px:
            dx = lx if field == "x" else 0.0
            left_src = plane[:, :, nx * m_c:(nx + 1) * m_c]
            right_src = plane[:, :, m_c:2 * m_c]
            plane = plane.at[:, :, 0:m_c].set(left_src - dx)
            plane = plane.at[:, :, (nx + 1) * m_c:].set(right_src + dx)
        if py:
            dy = ly if field == "y" else 0.0
            plane = plane.at[:, 0, :].set(plane[:, ny, :] - dy)
            plane = plane.at[:, ny + 1, :].set(plane[:, 1, :] + dy)
        if pz:
            dz = lz if field == "z" else 0.0
            plane = plane.at[0, :, :].set(plane[nz, :, :] - dz)
            plane = plane.at[nz + 1, :, :].set(plane[1, :, :] + dz)
        return plane

    planes = {k: wrap(v, k) for k, v in bins.planes.items()}

    # Ghost slots mirror the interior particle ids so self-interaction
    # masking (slot_id equality) keeps excluding only the true self-pair; a
    # particle must still interact with its own periodic *image*, so ghost
    # copies carry offset ids (id + 1e9).
    sid = bins.slot_id

    def bump(s):
        return jnp.where((s >= 0) & (s < GHOST_ID_BUMP), s + GHOST_ID_BUMP, s)

    s = sid
    if px:
        big = bump(s)
        s = s.at[:, :, 0:m_c].set(big[:, :, nx * m_c:(nx + 1) * m_c])
        s = s.at[:, :, (nx + 1) * m_c:].set(big[:, :, m_c:2 * m_c])
    if py:
        big = bump(s)
        s = s.at[:, 0, :].set(big[:, ny, :])
        s = s.at[:, ny + 1, :].set(big[:, 1, :])
    if pz:
        big = bump(s)
        s = s.at[0, :, :].set(big[nz, :, :])
        s = s.at[nz + 1, :, :].set(big[1, :, :])

    return dataclasses.replace(bins, planes=planes, slot_id=s)


def gather_to_particles(bins: CellBins, plane: Array) -> Array:
    """Read a per-slot plane back to per-particle order (inverse of scatter)."""
    return plane.reshape(-1)[bins.particle_slot]


# --------------------------------------------------------------------------
# Verlet-skin trajectory support: displacement tracking + in-place refresh
# --------------------------------------------------------------------------
#
# The trajectory engine (repro.traj) bins once on a skin-padded grid
# (domain.skin_domain: cell width >= cutoff + skin) and then *reuses* the
# slot assignment across timesteps, refreshing slot contents in place each
# step. The reuse contract: as long as no particle has drifted more than
# skin/2 from the position it was binned at, the 27-cell neighborhood still
# covers every pair within the true cutoff, so forces are pair-complete.
# ``max_displacement`` is the traced predicate; ``refresh_bins`` is the
# cheap per-step scatter that replaces a full ``bin_particles`` pass on the
# steps where the predicate says the bins are still valid.


def max_displacement(domain: Domain, positions: Array, ref: Array,
                     valid: Array | None = None) -> Array:
    """Scalar max over particles of |positions - ref| (minimum image).

    The Verlet-skin rebin predicate: the trajectory engine re-bins when
    this crosses ``effective_skin / 2``. Padding rows (``valid`` False)
    contribute zero — they never move and never interact.
    """
    delta = domain.minimum_image(positions - ref)
    mag = jnp.sqrt(jnp.sum(delta * delta, axis=-1))
    if valid is not None:
        mag = jnp.where(valid, mag, 0.0)
    return jnp.max(mag, initial=0.0)


def image_positions(domain: Domain, positions: Array, ref: Array) -> Array:
    """Positions shifted to the periodic image nearest ``ref``.

    Stale bins store each particle near where it was binned; a particle
    that wrapped across a periodic face since then must be *presented* to
    its old neighborhood unwrapped, or pair distances against stale-cell
    neighbors would jump by a box length. The shift is an exact multiple
    of the box, so for particles that did not wrap it is exactly zero and
    the returned positions are bit-identical to the input.
    """
    if not domain.any_periodic:
        return positions
    box = jnp.asarray(domain.box, dtype=positions.dtype)
    per = jnp.asarray(domain.periodic_axes)
    delta = positions - ref
    shift = jnp.where(per, box * jnp.round(delta / box), 0.0)
    return positions - shift


def refresh_bins(domain: Domain, bins: CellBins, positions: Array,
                 fields: Dict[str, Array] | None = None,
                 valid: Array | None = None) -> CellBins:
    """Scatter current particle values into the *existing* slot layout.

    The Verlet-skin fast path: slot assignment (``particle_slot``,
    ``slot_id``, ``counts``, ``offsets``) is reused from the last full
    ``bin_particles`` pass; only the SoA value planes are rewritten, then
    the periodic ghost ring is refilled from the refreshed interior.
    ``positions`` must already be imaged next to the binned reference
    (:func:`image_positions`) so wrapped particles land in their old slots
    with consistent coordinates.

    Particles the original binning dropped (cell overflow past ``m_c``)
    carry ``particle_slot == 0``; their scatter lands in a ghost-corner
    slot that the ghost refill immediately rewrites (periodic) or that is
    masked by ``slot_id == -1`` (open boundaries) — harmless either way,
    and an overflowed binning is flagged for replan before results are
    trusted. Padding rows (``valid`` False) are routed out of range and
    dropped.
    """
    total = bins.slot_id.size
    idx = bins.particle_slot
    if valid is not None:
        idx = jnp.where(valid, idx, total)

    planes = {}
    for name, plane in bins.planes.items():
        if name == "x":
            vals = positions[:, 0]
        elif name == "y":
            vals = positions[:, 1]
        elif name == "z":
            vals = positions[:, 2]
        else:
            vals = (fields or {})[name]
        flat = plane.reshape(-1).at[idx].set(
            vals.astype(plane.dtype), mode="drop")
        planes[name] = flat.reshape(plane.shape)

    out = dataclasses.replace(bins, planes=planes)
    if domain.any_periodic:
        out = _fill_periodic_ghosts(domain, out)
    return out


# --------------------------------------------------------------------------
# occupancy: the sparsity summary behind the compacted schedules
# --------------------------------------------------------------------------
#
# The dense slot layout charges every strategy for the *global* worst case:
# all (z, y) pencils (or sub-boxes) are visited, each padded to m_c slots.
# On inhomogeneous distributions most of those work units are empty. The
# occupancy summary is the trace-time-safe sparsity map: per-unit particle
# counts plus a compacted list of the active unit indices, under a static
# ``max_active`` bound that mirrors the m_c replan contract (overflow is
# detectable, never silent — a too-small bound drops work units, so the
# plan layer re-plans with a larger bound instead of computing wrong
# forces).


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Occupancy:
    """Compacted active-work-unit summary (pencils or sub-boxes).

    ``active`` holds the linearized indices of the units with at least one
    particle, padded to the static bound ``max_active`` with index 0 (always
    a valid unit to *read*; padded entries are dropped on the write side via
    :meth:`scatter_indices`). ``n_active`` is the true count — when it
    exceeds ``max_active`` the summary has overflowed and results computed
    from it would silently miss units, exactly like a cell overflowing m_c.
    """

    unit_counts: Array            # (n_units,) int32 particles per work unit
    active: Array                 # (max_active,) int32 unit ids, 0-padded
    n_active: Array               # () int32 true number of active units
    max_active: int = dataclasses.field(metadata=dict(static=True))
    n_units: int = dataclasses.field(metadata=dict(static=True))

    @property
    def overflowed(self) -> Array:
        """True when active units were dropped from ``active`` (replan)."""
        return self.n_active > self.max_active

    def scatter_indices(self) -> Array:
        """(max_active,) write-side unit ids: padding slots are pushed out
        of range so a ``mode='drop'`` scatter discards them."""
        slot = jnp.arange(self.max_active, dtype=jnp.int32)
        return jnp.where(slot < self.n_active, self.active,
                         jnp.int32(self.n_units))

    @property
    def fill_fraction(self) -> Array:
        return self.n_active / max(self.n_units, 1)


def _compact_active(unit_counts: Array, max_active: int,
                    n_units: int) -> Occupancy:
    active = jnp.nonzero(unit_counts > 0, size=max_active,
                         fill_value=0)[0].astype(jnp.int32)
    n_active = jnp.sum(unit_counts > 0).astype(jnp.int32)
    return Occupancy(unit_counts=unit_counts, active=active,
                     n_active=n_active, max_active=max_active,
                     n_units=n_units)


def full_pencil_occupancy(domain: Domain) -> Occupancy:
    """The identity occupancy: every (z, y) pencil active, in order.

    Lets the packed (and any compacted-shaped) runners iterate *all* rows
    through the same chunked active-list machinery when a plan is not
    compacted — ``active`` is just ``arange(nz * ny)`` with no padding.
    """
    n = domain.nz * domain.ny
    return Occupancy(unit_counts=jnp.ones((n,), jnp.int32),
                     active=jnp.arange(n, dtype=jnp.int32),
                     n_active=jnp.asarray(n, jnp.int32),
                     max_active=n, n_units=n)


def counts_grid(domain: Domain, counts: Array) -> Array:
    """(n_cells,) linear cell counts -> (nz, ny, nx) grid (X fastest)."""
    return counts.reshape(domain.nz, domain.ny, domain.nx)


def pencil_counts(domain: Domain, counts: Array) -> Array:
    """(n_cells,) cell counts -> (nz*ny,) particles per (z, y) X-pencil.
    Unit id = z * ny + y — the pencil-schedule linearization. The single
    source of truth for pencil unit ids (occupancy summaries and the plan
    layer's overflow probes both derive from it)."""
    return counts_grid(domain, counts).sum(axis=-1).reshape(-1)


def subbox_counts(domain: Domain, counts: Array,
                  box: Tuple[int, int, int]) -> Array:
    """(n_cells,) cell counts -> (gz*gy*gx,) particles per sub-box of the
    All-in-SM tiling. ``box`` = (bx, by, bz) must divide the grid. Unit
    id = iz*(gy*gx) + iy*gx + ix, matching the allin block linearization."""
    nx, ny, nz = domain.ncells
    bx, by, bz = box
    gx, gy, gz = nx // bx, ny // by, nz // bz
    grid = counts_grid(domain, counts)
    return grid.reshape(gz, bz, gy, by, gx, bx).sum(axis=(1, 3, 5)).reshape(-1)


def shard_slab_counts(domain: Domain, counts: Array, n_shards: int) -> Array:
    """(n_cells,) cell counts -> (n_shards,) particles per Z-slab shard.

    The reduction behind the distributed engine's ``shard_cap`` overflow
    contract: a shard whose load exceeds the static capacity would silently
    drop particles, exactly like a cell overflowing ``m_c``.
    """
    if domain.nz % n_shards:
        raise ValueError(
            f"nz={domain.nz} not divisible by n_shards={n_shards}")
    per_plane = counts_grid(domain, counts).sum(axis=(1, 2))     # (nz,)
    return per_plane.reshape(n_shards, domain.nz // n_shards).sum(axis=1)


def shard_pencil_active(domain: Domain, counts: Array,
                        n_shards: int) -> Array:
    """(n_cells,) cell counts -> (n_shards,) active (z, y) pencils per
    Z-slab shard — the per-shard occupancy the distributed compacted path's
    ``max_active`` bound must cover (the bound is one static number shared
    by every shard, so it is checked against the *busiest* shard)."""
    if domain.nz % n_shards:
        raise ValueError(
            f"nz={domain.nz} not divisible by n_shards={n_shards}")
    pc = pencil_counts(domain, counts).reshape(domain.nz, domain.ny)
    active = (pc > 0).astype(jnp.int32)
    return active.reshape(n_shards, domain.nz // n_shards,
                          domain.ny).sum(axis=(1, 2))


def pencil_occupancy(domain: Domain, counts: Array,
                     max_active: int) -> Occupancy:
    """Active (z, y) X-pencils (see :func:`pencil_counts` for unit ids).
    Traceable: works on ``CellBins.counts`` inside jit."""
    return _compact_active(pencil_counts(domain, counts), max_active,
                           domain.nz * domain.ny)


def subbox_occupancy(domain: Domain, counts: Array,
                     box: Tuple[int, int, int], max_active: int) -> Occupancy:
    """Active sub-boxes (see :func:`subbox_counts` for unit ids)."""
    nx, ny, nz = domain.ncells
    bx, by, bz = box
    n_boxes = (nx // bx) * (ny // by) * (nz // bz)
    return _compact_active(subbox_counts(domain, counts, box), max_active,
                           n_boxes)


def gather_pencil_rows(plane: Array, active_zy: Array, ny: int,
                       dz: int = 0, dy: int = 0) -> Array:
    """Compacted pencil-row gather: one padded row per active pencil.

    ``active_zy`` holds interior pencil ids ``z * ny + y``; the returned
    array is ``(len(active_zy), (nx+2)*m_c)`` — row ``a`` is the padded
    ``(z + dz + 1, y + dy + 1)`` row of ``plane``. This is the sparse
    counterpart of the dense schedules' per-pencil ``dynamic_slice``: one
    vectorized gather instead of a loop over all nz*ny pencils.
    """
    z = active_zy // ny + 1 + dz
    y = active_zy % ny + 1 + dy
    return plane[z, y, :]


# --------------------------------------------------------------------------
# packed-row layout: CSR-style slot compaction per pencil row
# --------------------------------------------------------------------------
#
# The occupancy path (above) removes empty work *units*; inside an active
# cell the dense layout still pays for all m_c slots. In the paper's
# "few particles per cell" regime (ppc 1-4, m_c sublane-aligned to 8) that
# is 2-8x more bytes than the particles warrant. The packed layout is the
# CSR answer: each padded (z, y) pencil row stores its particles
# *contiguously* (cell order preserved), with per-cell start offsets from
# the paper's prefix-sum kernel, under a static ``row_cap`` bound that
# follows the same overflow/replan contract as ``m_c``/``max_active``
# (see ARCHITECTURE.md "Static bounds & the replan contract").


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PackedRows:
    """CSR cell layout: per-pencil packed rows + prefix-sum cell offsets.

    Every padded (z, y) pencil row — interior rows and the ghost ring —
    owns ``row_cap`` slots; the row's particles (including its X-ghost
    copies) sit contiguously at the front in cell-then-rank order, exactly
    the order the dense row stores them in minus the empty slots. The
    per-row exclusive prefix sum ``cell_offsets`` (built with the paper's
    §6 scan, ``core.prefix``) says where each padded cell's particles
    start, so the dense layout's contiguous 3-cell X-window becomes an
    (offset, length) pair: ``[cell_offsets[c-1], cell_offsets[c+2])``.

    Like every static bound, ``row_cap`` overflowing means particles were
    *dropped* by the pack — detectable (``overflowed`` /
    ``InteractionPlan.check_overflow``), never silently wrong.
    """

    planes: Dict[str, Array]      # (nz+2, ny+2, row_cap) packed SoA fields
    slot_id: Array                # (nz+2, ny+2, row_cap) int32, -1 padding
    slot_cell: Array              # (nz+2, ny+2, row_cap) int32 padded cell
    cell_offsets: Array           # (nz+2, ny+2, nx+3) int32 exclusive prefix
    row_counts: Array             # (nz+2, ny+2) int32 particles per row
    counts: Array                 # (n_cells,) pass-through from CellBins
    particle_slot: Array          # (N,) interior flat packed slot per particle
    row_cap: int = dataclasses.field(metadata=dict(static=True))
    m_c: int = dataclasses.field(metadata=dict(static=True))

    @property
    def overflowed(self) -> Array:
        """True when some row held more than ``row_cap`` particles (replan)."""
        return jnp.max(self.row_counts) > self.row_cap


def padded_row_counts(domain: Domain, counts: Array) -> Array:
    """(n_cells,) cell counts -> (nz, ny) particles per *padded* pencil row.

    A padded row holds the pencil's interior particles plus, under a
    periodic X axis, the ghost copies of its first and last cell (a
    1-cell-thick periodic X axis counts its single cell three times). The
    host-side probe behind ``suggest_row_cap`` and the packed
    ``check_overflow``: ghost Y/Z rows are wrapped copies of interior rows,
    so the interior maximum covers every padded row of the layout.
    """
    grid = counts_grid(domain, counts)
    per_row = grid.sum(axis=-1)
    if domain.periodic_axes[0]:
        per_row = per_row + grid[..., 0] + grid[..., -1]
    return per_row


def pack_rows(domain: Domain, bins: CellBins, row_cap: int) -> PackedRows:
    """Compact a dense :class:`CellBins` into the packed-row (CSR) layout.

    Traceable (runs inside the jitted executor). Per padded row: per-cell
    counts come from the occupied slots, the paper's prefix sum turns them
    into start offsets, and every occupied dense slot ``(cell c, rank r)``
    scatters to packed position ``cell_offsets[c] + r`` — a stable
    compaction, so packed order is dense order minus the sentinels and the
    dense 3-cell window survives as an (offset, length) range. Rows whose
    count exceeds ``row_cap`` drop their tail (``mode='drop'``), flagged by
    :attr:`PackedRows.overflowed` for the replan contract.
    """
    nx, ny, nz = domain.ncells
    m_c = bins.m_c
    nzp, nyp = nz + 2, ny + 2
    shape4 = (nzp, nyp, nx + 2, m_c)

    occupied = (bins.slot_id.reshape(shape4) >= 0)
    cell_counts_p = occupied.sum(axis=-1).astype(jnp.int32)  # (nzp,nyp,nx+2)
    offsets = exclusive_prefix_sum(cell_counts_p)            # paper §6 scan
    row_counts = cell_counts_p.sum(axis=-1)                  # (nzp, nyp)
    cell_offsets = jnp.concatenate(
        [offsets, row_counts[..., None]], axis=-1)           # (nzp,nyp,nx+3)

    # destination of dense slot (c, r): cell start + rank; unoccupied slots
    # and rows past row_cap are pushed out of range so 'drop' discards them
    rank = jnp.arange(m_c, dtype=jnp.int32)
    dest = offsets[..., None] + rank                         # (nzp,nyp,nx+2,m_c)
    dest = jnp.where(occupied & (dest < row_cap), dest, row_cap)
    row_base = (jnp.arange(nzp, dtype=jnp.int32)[:, None] * nyp
                + jnp.arange(nyp, dtype=jnp.int32)[None, :])
    flat = (row_base[..., None, None] * (row_cap + 1) + dest).reshape(-1)
    total = nzp * nyp * (row_cap + 1)

    def pack(plane: Array, fill) -> Array:
        out = jnp.full((total,), fill, dtype=plane.dtype)
        out = out.at[flat].set(plane.reshape(-1), mode="drop")
        return out.reshape(nzp, nyp, row_cap + 1)[..., :row_cap]

    planes = {}
    for name, plane in bins.planes.items():
        fill = EMPTY_POS if name in ("x", "y", "z") else 0.0
        planes[name] = pack(plane, jnp.asarray(fill, plane.dtype))
    slot_id = pack(bins.slot_id, jnp.int32(-1))

    # padded cell index of every packed slot; padding slots read cell 1 (a
    # valid interior cell) so window arithmetic stays in bounds — their
    # results are masked by slot_id == -1 and never unpacked
    cell_idx = jnp.broadcast_to(
        jnp.arange(nx + 2, dtype=jnp.int32)[None, None, :, None], shape4)
    slot_cell = pack(cell_idx.reshape(bins.slot_id.shape), jnp.int32(1))

    # per-particle packed slot (interior rows only): dense flat slot ->
    # (z, y, c, r) -> interior flat (z*ny + y) * row_cap + offset + rank
    row_len = (nx + 2) * m_c
    ds = bins.particle_slot
    zp = ds // ((nyp) * row_len)
    rem = ds % ((nyp) * row_len)
    yp = rem // row_len
    col = rem % row_len
    c = col // m_c
    r = col % m_c
    pos_in_row = offsets[zp, yp, c] + r
    pos_in_row = jnp.minimum(pos_in_row, row_cap)       # overflow-safe read
    particle_slot = (((zp - 1) * ny + (yp - 1)) * (row_cap + 1)
                     + pos_in_row).astype(jnp.int32)

    return PackedRows(planes=planes, slot_id=slot_id, slot_cell=slot_cell,
                      cell_offsets=cell_offsets, row_counts=row_counts,
                      counts=bins.counts, particle_slot=particle_slot,
                      row_cap=row_cap, m_c=m_c)


def unpack_scatter(domain: Domain, packed: PackedRows,
                   rows: Array) -> Array:
    """Packed per-slot values back to particle order (packed counterpart of
    :func:`gather_to_particles` / :func:`dense_to_particles`).

    ``rows`` holds one value per *interior* packed slot —
    ``(nz * ny, row_cap)`` (or any reshape of it) in pencil-id order
    ``z * ny + y``. Out-of-cap particles (an overflowed pack — caught by
    ``check_overflow`` before results are trusted) read a zero pad slot.
    """
    nz, ny = domain.nz, domain.ny
    per_row = rows.reshape(nz * ny, packed.row_cap)
    padded = jnp.concatenate(
        [per_row, jnp.zeros((nz * ny, 1), per_row.dtype)], axis=-1)
    return padded.reshape(-1)[packed.particle_slot]


def packed_to_particles(domain: Domain, packed: PackedRows, fx: Array,
                        fy: Array, fz: Array, pot: Array
                        ) -> Tuple[Array, Array]:
    """Normalize packed ``(nz * ny, row_cap)`` schedule outputs to
    per-particle ``(forces (N, 3), potential (N,))`` — the same output
    contract as :func:`dense_to_particles`."""
    out = [unpack_scatter(domain, packed, p) for p in (fx, fy, fz, pot)]
    return jnp.stack(out[:3], axis=-1), out[3]


def interior(domain: Domain, plane: Array, m_c: int) -> Array:
    """View of the non-ghost region, reshaped to (nz, ny, nx, m_c)."""
    nx, ny, nz = domain.ncells
    core = plane[1:nz + 1, 1:ny + 1, m_c:(nx + 1) * m_c]
    return core.reshape(nz, ny, nx, m_c)


def interior_to_padded(domain: Domain, plane: Array, m_c: int) -> Array:
    """(nz, ny, nx, m_c) interior tensor -> padded plane (ghosts zero).

    Inverse of ``interior`` up to the ghost ring; the step every dense
    schedule output goes through before ``gather_to_particles``.
    """
    nx, ny, nz = domain.ncells
    padded = jnp.zeros((nz + 2, ny + 2, (nx + 2) * m_c), dtype=plane.dtype)
    return padded.at[1:nz + 1, 1:ny + 1, m_c:(nx + 1) * m_c].set(
        plane.reshape(nz, ny, nx * m_c))


def dense_to_particles(domain: Domain, bins: CellBins, fx: Array, fy: Array,
                       fz: Array, pot: Array) -> Tuple[Array, Array]:
    """Normalize dense (nz, ny, nx, m_c) schedule outputs to per-particle
    (forces (N, 3), potential (N,)) — the backend-registry output contract."""
    out = []
    for plane in (fx, fy, fz, pot):
        shaped = plane.reshape(domain.nz, domain.ny, domain.nx, bins.m_c)
        out.append(gather_to_particles(
            bins, interior_to_padded(domain, shaped, bins.m_c)))
    return jnp.stack(out[:3], axis=-1), out[3]


# --------------------------------------------------------------------------
# SFC cluster layout: curve-ordered cell clusters + compressed pair list
# --------------------------------------------------------------------------
#
# The packed layout (above) compresses *storage*; the SFC layout compresses
# the *schedule*. Cells are ordered along a space-filling curve (Morton or
# Hilbert — the CSCS follow-up's locality trick) and grouped into fixed-size
# clusters of ``csize`` consecutive cells; the per-step work list is then a
# *compressed cluster-pair neighbor list*: a (cluster, stencil-offset)
# bitmask over the 27-cell stencil, delta/sort-encoded into a flat array of
# ``cluster * 32 + k`` codes under a static ``pair_cap`` bound. Empty
# neighborhoods never even appear in the list — the data-dependent
# counterpart of the occupancy path's active-unit list, one level finer.
#
# Bit-identity with the dense Par-Cell schedule is by construction: each
# kept (cluster, k) pair evaluates the *same* per-cell m_c x m_c masked
# reduction ``cell_dense`` evaluates for stencil slot k, accumulated in the
# same ascending-k order (codes are sorted, and k is the low bits), so the
# float sums associate identically. Dropping a pair is only possible via
# ``pair_cap`` overflow, which is detected (``SfcClusters.overflowed``) and
# grown by the standard replan contract — never silent.

DEFAULT_CSIZE = 4
DEFAULT_CURVE = "morton"
SFC_CURVES = ("morton", "hilbert")


def morton_encode(ix, iy, iz, bits: int) -> np.ndarray:
    """Interleave 3 coordinate arrays into Morton (Z-order) codes (host)."""
    ix = np.asarray(ix, np.int64)
    iy = np.asarray(iy, np.int64)
    iz = np.asarray(iz, np.int64)
    code = np.zeros(np.broadcast(ix, iy, iz).shape, np.int64)
    for b in range(bits):
        code |= ((ix >> b) & 1) << (3 * b)
        code |= ((iy >> b) & 1) << (3 * b + 1)
        code |= ((iz >> b) & 1) << (3 * b + 2)
    return code


def morton_decode(codes, bits: int) -> Tuple[np.ndarray, np.ndarray,
                                             np.ndarray]:
    """Inverse of :func:`morton_encode` (host)."""
    codes = np.asarray(codes, np.int64)
    ix = np.zeros(codes.shape, np.int64)
    iy = np.zeros(codes.shape, np.int64)
    iz = np.zeros(codes.shape, np.int64)
    for b in range(bits):
        ix |= ((codes >> (3 * b)) & 1) << b
        iy |= ((codes >> (3 * b + 1)) & 1) << b
        iz |= ((codes >> (3 * b + 2)) & 1) << b
    return ix, iy, iz


def _hilbert_axes_to_transpose(ix, iy, iz, bits: int):
    """Skilling's AxesToTranspose, vectorized over numpy arrays."""
    X = [np.array(ix, np.int64), np.array(iy, np.int64),
         np.array(iz, np.int64)]
    M = 1 << (bits - 1)
    Q = M
    while Q > 1:                       # inverse undo
        P = Q - 1
        for i in range(3):
            cond = (X[i] & Q) != 0
            t = (X[0] ^ X[i]) & P
            x0 = np.where(cond, X[0] ^ P, X[0] ^ t)
            X[i] = np.where(cond, X[i], X[i] ^ t)
            X[0] = x0
        Q >>= 1
    for i in range(1, 3):              # Gray encode
        X[i] = X[i] ^ X[i - 1]
    t = np.zeros_like(X[0])
    Q = M
    while Q > 1:
        t = np.where((X[2] & Q) != 0, t ^ (Q - 1), t)
        Q >>= 1
    return [x ^ t for x in X]


def _hilbert_transpose_to_axes(X, bits: int):
    """Skilling's TransposeToAxes (inverse of the above), vectorized."""
    X = [np.array(x, np.int64) for x in X]
    N = 2 << (bits - 1)
    t = X[2] >> 1                      # Gray decode by H ^ (H/2)
    for i in range(2, 0, -1):
        X[i] = X[i] ^ X[i - 1]
    X[0] = X[0] ^ t
    Q = 2
    while Q != N:                      # undo excess work
        P = Q - 1
        for i in range(2, -1, -1):
            cond = (X[i] & Q) != 0
            t = (X[0] ^ X[i]) & P
            x0 = np.where(cond, X[0] ^ P, X[0] ^ t)
            X[i] = np.where(cond, X[i], X[i] ^ t)
            X[0] = x0
        Q <<= 1
    return X


def hilbert_encode(ix, iy, iz, bits: int) -> np.ndarray:
    """Hilbert-curve codes for 3-D coordinates (host, Skilling 2004)."""
    X = _hilbert_axes_to_transpose(ix, iy, iz, bits)
    code = np.zeros_like(X[0])
    for b in range(bits - 1, -1, -1):  # X[0] most significant per bit-plane
        for i in range(3):
            code = (code << 1) | ((X[i] >> b) & 1)
    return code


def hilbert_decode(codes, bits: int) -> Tuple[np.ndarray, np.ndarray,
                                              np.ndarray]:
    """Inverse of :func:`hilbert_encode` (host)."""
    codes = np.asarray(codes, np.int64)
    X = [np.zeros(codes.shape, np.int64) for _ in range(3)]
    for b in range(bits):
        for i in range(3):
            shift = 3 * b + (2 - i)
            X[i] |= ((codes >> shift) & 1) << b
    ix, iy, iz = _hilbert_transpose_to_axes(X, bits)
    return ix, iy, iz


def _curve_bits(nx: int, ny: int, nz: int) -> int:
    return max(int(max(nx, ny, nz) - 1).bit_length(), 1)


@dataclasses.dataclass(frozen=True)
class SfcTables:
    """Static (host, geometry-only) cluster tables of an SFC layout.

    ``order`` lists the cell ids along the curve; cluster ``a`` owns cells
    ``order[a*csize:(a+1)*csize]`` (the last cluster is padded with the
    sentinel cell -1). ``tgt_pcell``/``src_pcell`` hold *padded-grid* flat
    cell indices — ``src_pcell[a, k, j]`` is cell j of cluster a shifted by
    stencil offset k (``domain.neighbor_offsets()`` order, k = 13 is self);
    sentinel cells map to ``n_pcells`` (one past the padded grid), where
    occupancy/slot gathers read an appended always-empty block.
    """

    order: np.ndarray           # (n_cells,) cell ids in curve order
    cell_cluster: np.ndarray    # (n_cells,) cluster id per cell
    cell_pos: np.ndarray        # (n_cells,) position of cell in its cluster
    cluster_cells: np.ndarray   # (n_clusters, csize) cell ids, -1 pad
    tgt_pcell: np.ndarray       # (n_clusters, csize) padded flat cell
    src_pcell: np.ndarray       # (n_clusters, 27, csize) padded flat cell
    n_clusters: int
    n_pcells: int


@functools.lru_cache(maxsize=None)
def sfc_cluster_tables(domain: Domain, csize: int = DEFAULT_CSIZE,
                       curve: str = DEFAULT_CURVE) -> SfcTables:
    """Build the static SFC cluster tables (cached per geometry)."""
    if curve not in SFC_CURVES:
        raise ValueError(f"unknown curve {curve!r}; have {SFC_CURVES}")
    if csize < 1:
        raise ValueError(f"csize must be >= 1, got {csize}")
    nx, ny, nz = domain.ncells
    n_cells = domain.n_cells
    cid = np.arange(n_cells, dtype=np.int64)
    ix, iy, iz = cid % nx, (cid // nx) % ny, cid // (nx * ny)
    bits = _curve_bits(nx, ny, nz)
    enc = morton_encode if curve == "morton" else hilbert_encode
    codes = enc(ix, iy, iz, bits)
    order = np.argsort(codes, kind="stable").astype(np.int32)

    n_clusters = -(-n_cells // csize)
    pos = np.arange(n_cells, dtype=np.int64)
    cell_cluster = np.empty(n_cells, np.int32)
    cell_pos = np.empty(n_cells, np.int32)
    cell_cluster[order] = (pos // csize).astype(np.int32)
    cell_pos[order] = (pos % csize).astype(np.int32)
    cluster_cells = np.full((n_clusters * csize,), -1, np.int32)
    cluster_cells[:n_cells] = order
    cluster_cells = cluster_cells.reshape(n_clusters, csize)

    n_pcells = (nz + 2) * (ny + 2) * (nx + 2)
    pad = cluster_cells < 0
    safe = np.where(pad, 0, cluster_cells).astype(np.int64)
    cx, cy, cz = safe % nx, (safe // nx) % ny, safe // (nx * ny)

    def pcell(jx, jy, jz):
        return ((jz + 1) * (ny + 2) + (jy + 1)) * (nx + 2) + (jx + 1)

    tgt_pcell = np.where(pad, n_pcells, pcell(cx, cy, cz)).astype(np.int32)
    offs = domain.neighbor_offsets()                      # (27, 3) (dx,dy,dz)
    src_pcell = np.empty((n_clusters, 27, csize), np.int64)
    for k, (dx, dy, dz) in enumerate(offs):
        src_pcell[:, k, :] = pcell(cx + dx, cy + dy, cz + dz)
    src_pcell = np.where(pad[:, None, :], n_pcells,
                         src_pcell).astype(np.int32)
    return SfcTables(order=order, cell_cluster=cell_cluster,
                     cell_pos=cell_pos, cluster_cells=cluster_cells,
                     tgt_pcell=tgt_pcell, src_pcell=src_pcell,
                     n_clusters=n_clusters, n_pcells=n_pcells)


def sfc_n_clusters(domain: Domain, csize: int = DEFAULT_CSIZE) -> int:
    return -(-domain.n_cells // csize)


@functools.lru_cache(maxsize=None)
def sfc_slot_tables(domain: Domain, m_c: int, csize: int = DEFAULT_CSIZE,
                    curve: str = DEFAULT_CURVE
                    ) -> Tuple[np.ndarray, np.ndarray]:
    """Flat *slot* base offsets of the cluster tables for a given ``m_c``:
    ``(tgt_base (n_clusters, csize), src_base (n_clusters, 27, csize))``,
    each ``pcell * m_c`` — directly indexing the flattened padded planes
    (sentinel cells land at ``n_pcells * m_c``, the appended sentinel
    block)."""
    t = sfc_cluster_tables(domain, csize, curve)
    tgt = (t.tgt_pcell.astype(np.int64) * m_c).astype(np.int32)
    src = (t.src_pcell.astype(np.int64) * m_c).astype(np.int32)
    return tgt, src


def encode_pair_masks(masks: np.ndarray, pair_cap: int) -> np.ndarray:
    """(n_clusters, 27) bool stencil bitmask -> sorted compressed codes.

    Each kept pair becomes ``cluster * 32 + k`` (5 bits for the stencil
    slot); codes are sorted ascending — cluster-major, k-minor, the exact
    accumulation order of the dense Par-Cell sweep — padded to ``pair_cap``
    with the sentinel ``n_clusters * 32`` and truncated on overflow (host
    twin of the traced encoder inside :func:`build_sfc_clusters`)."""
    masks = np.asarray(masks, bool)
    n_clusters = masks.shape[0]
    a, k = np.nonzero(masks)
    codes = np.sort(a.astype(np.int64) * 32 + k)
    out = np.full((pair_cap,), n_clusters * 32, np.int32)
    m = min(pair_cap, codes.size)
    out[:m] = codes[:m]
    return out


def decode_pair_codes(codes: np.ndarray, n_clusters: int) -> np.ndarray:
    """Sorted compressed codes -> (n_clusters, 27) bool bitmask (inverse
    of :func:`encode_pair_masks` whenever no pair was truncated)."""
    codes = np.asarray(codes, np.int64)
    masks = np.zeros((n_clusters, 27), bool)
    valid = (codes >= 0) & (codes < n_clusters * 32)
    masks[codes[valid] >> 5, codes[valid] & 31] = True
    return masks


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SfcClusters:
    """SFC cluster layout state: dense bins + the compressed pair list.

    ``codes`` is the sorted compressed cluster-pair list (see
    :func:`encode_pair_masks`) under the static ``pair_cap`` bound;
    ``n_pairs`` is the true pair count — exceeding ``pair_cap`` means
    pairs were truncated (:attr:`overflowed`, replan grows ``pair_cap``).
    The slot data itself stays the dense ``CellBins`` planes: the pair
    list compresses the *schedule* (which cluster-tile interactions run),
    so a cluster with no occupied stencil neighborhood costs nothing.
    """

    bins: CellBins                # dense slot planes the tiles are read from
    codes: Array                  # (pair_cap,) int32 sorted pair codes
    n_pairs: Array                # () int32 true (untruncated) pair count
    cluster_counts: Array         # (n_clusters,) int32 particles per cluster
    pair_cap: int = dataclasses.field(metadata=dict(static=True))
    csize: int = dataclasses.field(metadata=dict(static=True))
    curve: str = dataclasses.field(metadata=dict(static=True))

    @property
    def overflowed(self) -> Array:
        """True when pairs were truncated from ``codes`` (replan)."""
        return self.n_pairs > self.pair_cap


def build_sfc_clusters(domain: Domain, bins: CellBins, pair_cap: int,
                       csize: int = DEFAULT_CSIZE,
                       curve: str = DEFAULT_CURVE) -> SfcClusters:
    """Build the compressed cluster-pair list from binned occupancy.

    Traceable (runs inside the jitted executor). The bitmask is driven by
    *padded-cell slot occupancy* (``slot_id >= 0``), not interior counts —
    so periodic ghost copies, open (always-empty) ghosts and the halo
    engine's exchanged ghost planes are all handled by the same rule: a
    (cluster, k) pair is kept iff the cluster holds a particle and the
    k-shifted cells hold one (wherever it came from).
    """
    t = sfc_cluster_tables(domain, csize, curve)
    nx, ny, nz = domain.ncells
    m_c = bins.m_c
    occ = (bins.slot_id.reshape(nz + 2, ny + 2, nx + 2, m_c)
           >= 0).sum(-1).reshape(-1)
    occ_ext = jnp.concatenate([occ, jnp.zeros((1,), occ.dtype)])
    cluster_counts = occ_ext[jnp.asarray(t.tgt_pcell)].sum(-1)
    src_counts = occ_ext[jnp.asarray(t.src_pcell)].sum(-1)
    bits = (cluster_counts[:, None] > 0) & (src_counts > 0)
    n_pairs = jnp.sum(bits).astype(jnp.int32)
    a = jnp.arange(t.n_clusters, dtype=jnp.int32)[:, None]
    k = jnp.arange(27, dtype=jnp.int32)[None, :]
    sentinel = jnp.int32(t.n_clusters * 32)
    codes = jnp.sort(jnp.where(bits, a * 32 + k, sentinel).reshape(-1))
    if pair_cap > codes.size:
        codes = jnp.concatenate(
            [codes, jnp.full((pair_cap - codes.size,), sentinel, jnp.int32)])
    else:
        codes = codes[:pair_cap]
    return SfcClusters(bins=bins, codes=codes, n_pairs=n_pairs,
                       cluster_counts=cluster_counts.astype(jnp.int32),
                       pair_cap=pair_cap, csize=csize, curve=curve)


def sfc_pair_count(domain: Domain, positions: Array | None = None, *,
                   counts: Array | None = None, csize: int = DEFAULT_CSIZE,
                   curve: str = DEFAULT_CURVE,
                   ghost_z: Tuple[Array, Array] | None = None) -> int:
    """Host-side pair-list length probe (the ``pair_cap`` counterpart of
    ``padded_row_counts``): padded-cell occupancy rebuilt from interior
    cell counts (periodic ghosts copied in the same x->y->z order the
    binning ghost fill uses, so corners compose identically), then the
    same bitmask rule as :func:`build_sfc_clusters`. Counts-based, so it
    upper-bounds the traced ``n_pairs`` (slot occupancy is counts clipped
    to ``m_c``) — equal whenever no cell overflows ``m_c``.

    ``ghost_z``: optional ``(below, above)`` interior cell counts, each
    ``(ny, nx)``, that override the Z ghost planes — the halo engine's
    per-shard probe, where the Z ghosts arrive from neighbouring shards
    instead of this domain's own periodic wrap. Their X/Y ghost columns
    get the same periodic copies the exchanged planes carry."""
    if counts is None:
        if positions is None:
            raise ValueError("sfc_pair_count needs positions or counts")
        counts = cell_counts(domain, positions)
    nx, ny, nz = domain.ncells
    grid = np.asarray(counts).reshape(nz, ny, nx)
    occ = np.zeros((nz + 2, ny + 2, nx + 2), np.int64)
    occ[1:nz + 1, 1:ny + 1, 1:nx + 1] = grid
    px, py, pz = domain.periodic_axes
    if ghost_z is not None:
        below, above = ghost_z
        occ[0, 1:ny + 1, 1:nx + 1] = np.asarray(below).reshape(ny, nx)
        occ[nz + 1, 1:ny + 1, 1:nx + 1] = np.asarray(above).reshape(ny, nx)
    if px:
        occ[:, :, 0] = occ[:, :, nx]
        occ[:, :, nx + 1] = occ[:, :, 1]
    if py:
        occ[:, 0, :] = occ[:, ny, :]
        occ[:, ny + 1, :] = occ[:, 1, :]
    if pz and ghost_z is None:
        occ[0] = occ[nz]
        occ[nz + 1] = occ[1]
    t = sfc_cluster_tables(domain, csize, curve)
    occ_ext = np.concatenate([occ.reshape(-1), np.zeros((1,), np.int64)])
    cc = occ_ext[t.tgt_pcell].sum(-1)
    sc = occ_ext[t.src_pcell].sum(-1)
    return int(((cc[:, None] > 0) & (sc > 0)).sum())


def sfc_to_particles(domain: Domain, sfc: SfcClusters, fx: Array, fy: Array,
                     fz: Array, pot: Array) -> Tuple[Array, Array]:
    """Normalize SFC cluster-tile outputs ``(n_clusters, csize * m_c)`` to
    per-particle ``(forces (N, 3), potential (N,))`` — the backend-registry
    output contract (SFC counterpart of ``packed_to_particles``)."""
    bins = sfc.bins
    nx, ny, nz = domain.ncells
    m_c, csize = bins.m_c, sfc.csize
    t = sfc_cluster_tables(domain, csize, sfc.curve)

    # dense flat slot -> (z, y, cell x, rank) -> cluster-tile flat slot
    row_len = (nx + 2) * m_c
    ds = bins.particle_slot
    zp = ds // ((ny + 2) * row_len)
    rem = ds % ((ny + 2) * row_len)
    yp = rem // row_len
    col = rem % row_len
    cx = col // m_c - 1
    r = col % m_c
    iz, iy = zp - 1, yp - 1
    # dropped particles (slot 0 -> ghost corner) fall outside the interior
    valid = ((iz >= 0) & (iz < nz) & (iy >= 0) & (iy < ny)
             & (cx >= 0) & (cx < nx))
    cid = jnp.where(valid, (iz * ny + iy) * nx + cx, 0)
    cc = jnp.asarray(t.cell_cluster)[cid]
    cp = jnp.asarray(t.cell_pos)[cid]
    n_slots = t.n_clusters * csize * m_c
    flat = jnp.where(valid, cc * (csize * m_c) + cp * m_c + r, n_slots)

    out = []
    for plane in (fx, fy, fz, pot):
        ext = jnp.concatenate([plane.reshape(-1),
                               jnp.zeros((1,), plane.dtype)])
        out.append(ext[flat])
    return jnp.stack(out[:3], axis=-1), out[3]
