"""Binning pipeline: the paper's Section 2 preprocessing, TPU-shaped.

Pipeline (paper order, atomic-free):
  1. per-particle cell index (parallel),
  2. per-cell counts      -> ``jax.ops.segment_sum`` (replaces atomics),
  3. cell start offsets   -> the paper's prefix sum (``core.prefix``),
  4. out-of-place reorder -> stable argsort by cell id + rank-in-cell,
  5. **dense cell-slot layout**: every cell owns exactly ``m_c`` contiguous
     slots in SoA planes of shape ``(nz+2, ny+2, (nx+2)*m_c)``.

Step 5 is the TPU adaptation (DESIGN.md §2): X stays the fastest axis (the
paper's linearization), so an X-pencil of cells is one contiguous row and the
3-cell interaction window of a cell is one contiguous ``3*m_c`` slice — the
structural equivalent of what the paper builds in shared memory with its
local-offset prefix sums. The one-cell ghost ring (always empty for open
boundaries, wrapped copies for periodic domains) removes all border branching.

``m_c`` is the paper's M_C — the max particles per cell — and must be a
static (trace-time) bound. Overflowing particles are dropped by the scatter
(``mode='drop'``); ``CellBins.counts`` lets callers detect that and re-bin
with a larger bound (the engine does exactly what the paper does: track the
max while computing the prefix sum).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .domain import Domain
from .prefix import exclusive_prefix_sum

Array = jnp.ndarray

# Sentinel coordinate for empty slots: far outside any box, finite so that
# (sentinel - real) stays finite and (sentinel - sentinel) == 0; both cases
# are masked out by slot ids anyway (DESIGN: TPUs want masks, not NaN traps).
EMPTY_POS = 1.0e8

# Slot-id offset carried by periodic ghost *copies*: a particle must still
# interact with its own periodic image, so ghost slots mirror the interior
# ids bumped by this constant — never equal to any real id, so the
# self-pair exclusion (id equality) keeps excluding only the true self
# pair. Shared with the distributed halo layer, whose cross-shard ghost
# planes use per-shard id offsets for the same reason.
GHOST_ID_BUMP = 1_000_000_000


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CellBins:
    """Dense cell-slot state. All planes share shape (nz+2, ny+2, (nx+2)*m_c)."""

    planes: Dict[str, Array]      # SoA field planes ("x","y","z",...)
    slot_id: Array                # int32 particle index per slot, -1 if empty
    counts: Array                 # (n_cells,) particles per cell
    offsets: Array                # (n_cells,) exclusive prefix (paper Fig. 1)
    particle_slot: Array          # (N,) flat slot index of each particle
    m_c: int = dataclasses.field(metadata=dict(static=True))

    @property
    def max_count(self) -> Array:
        return jnp.max(self.counts)


def padded_shape(domain: Domain, m_c: int) -> Tuple[int, int, int]:
    nx, ny, nz = domain.ncells
    return (nz + 2, ny + 2, (nx + 2) * m_c)


def cell_counts(domain: Domain, positions: Array) -> Array:
    """(n_cells,) particles per cell — the one binning pass every static
    bound probe (``m_c``, shard loads, occupancy) derives from."""
    return jax.ops.segment_sum(
        jnp.ones((positions.shape[0],), jnp.int32),
        domain.cell_ids(positions), num_segments=domain.n_cells)


def bin_particles(domain: Domain, positions: Array,
                  fields: Dict[str, Array] | None = None, *,
                  m_c: int, valid: Array | None = None) -> CellBins:
    """Bin particles into the dense slot layout.

    Args:
      positions: (N, 3) float array.
      fields: optional extra per-particle scalars to bin alongside x/y/z.
      m_c: static max-particles-per-cell bound (paper's M_C).
      valid: optional (N,) bool mask; False rows (e.g. the sentinel padding a
        halo shard carries) are excluded from counts and never land in a slot.
    """
    n = positions.shape[0]
    nx, ny, nz = domain.ncells
    n_cells = domain.n_cells

    coords = domain.cell_coords(positions)          # (N, 3) int32
    cids = domain.linearize(coords)                 # (N,)

    if valid is None:
        weights = jnp.ones((n,), jnp.int32)
        sort_key = cids
    else:
        # invalid rows carry weight 0 in cell 0 and sort past every real cell
        weights = valid.astype(jnp.int32)
        cids = jnp.where(valid, cids, 0)
        sort_key = jnp.where(valid, cids, n_cells)

    counts = jax.ops.segment_sum(weights, cids, num_segments=n_cells)
    offsets = exclusive_prefix_sum(counts)          # (n_cells,)

    # Rank of each particle within its cell via one stable sort (the paper's
    # atomic slot-grab, determinized).
    order = jnp.argsort(sort_key, stable=True)      # (N,) particle ids, sorted
    sorted_key = sort_key[order]
    rank = jnp.arange(n, dtype=jnp.int32) - offsets[
        jnp.clip(sorted_key, 0, n_cells - 1)]

    # Flat index into the padded planes; ranks >= m_c fall off the end of the
    # cell's slot range — push them fully out of bounds so 'drop' removes them.
    cxyz = coords[order]
    row_len = (nx + 2) * m_c
    slot_col = (cxyz[:, 0] + 1) * m_c + rank
    flat = ((cxyz[:, 2] + 1) * (ny + 2) + (cxyz[:, 1] + 1)) * row_len + slot_col
    total = (nz + 2) * (ny + 2) * row_len
    keep = (rank < m_c) & (sorted_key < n_cells)
    flat = jnp.where(keep, flat, total)             # out of range -> dropped

    shape = padded_shape(domain, m_c)

    def scatter(values: Array, fill: float) -> Array:
        plane = jnp.full((total,), fill, dtype=values.dtype)
        plane = plane.at[flat].set(values[order], mode="drop")
        return plane.reshape(shape)

    planes = {
        "x": scatter(positions[:, 0], EMPTY_POS),
        "y": scatter(positions[:, 1], EMPTY_POS),
        "z": scatter(positions[:, 2], EMPTY_POS),
    }
    if fields:
        for k, v in fields.items():
            planes[k] = scatter(v, 0.0)

    slot_flat = jnp.full((total,), -1, dtype=jnp.int32)
    slot_flat = slot_flat.at[flat].set(order.astype(jnp.int32), mode="drop")
    slot_id = slot_flat.reshape(shape)

    particle_slot = jnp.zeros((n,), dtype=jnp.int32).at[order].set(
        flat.astype(jnp.int32), mode="drop")

    bins = CellBins(planes=planes, slot_id=slot_id, counts=counts,
                    offsets=offsets, particle_slot=particle_slot, m_c=m_c)
    if domain.any_periodic:
        bins = _fill_periodic_ghosts(domain, bins)
    return bins


def _fill_periodic_ghosts(domain: Domain, bins: CellBins) -> CellBins:
    """Copy wrapped interior slabs into the ghost ring (minimum image),
    per periodic axis."""
    nx, ny, nz = domain.ncells
    m_c = bins.m_c
    lx, ly, lz = domain.box
    px, py, pz = domain.periodic_axes

    def wrap(plane: Array, field: str) -> Array:
        if px:
            dx = lx if field == "x" else 0.0
            left_src = plane[:, :, nx * m_c:(nx + 1) * m_c]
            right_src = plane[:, :, m_c:2 * m_c]
            plane = plane.at[:, :, 0:m_c].set(left_src - dx)
            plane = plane.at[:, :, (nx + 1) * m_c:].set(right_src + dx)
        if py:
            dy = ly if field == "y" else 0.0
            plane = plane.at[:, 0, :].set(plane[:, ny, :] - dy)
            plane = plane.at[:, ny + 1, :].set(plane[:, 1, :] + dy)
        if pz:
            dz = lz if field == "z" else 0.0
            plane = plane.at[0, :, :].set(plane[nz, :, :] - dz)
            plane = plane.at[nz + 1, :, :].set(plane[1, :, :] + dz)
        return plane

    planes = {k: wrap(v, k) for k, v in bins.planes.items()}

    # Ghost slots mirror the interior particle ids so self-interaction
    # masking (slot_id equality) keeps excluding only the true self-pair; a
    # particle must still interact with its own periodic *image*, so ghost
    # copies carry offset ids (id + 1e9).
    sid = bins.slot_id

    def bump(s):
        return jnp.where((s >= 0) & (s < GHOST_ID_BUMP), s + GHOST_ID_BUMP, s)

    s = sid
    if px:
        big = bump(s)
        s = s.at[:, :, 0:m_c].set(big[:, :, nx * m_c:(nx + 1) * m_c])
        s = s.at[:, :, (nx + 1) * m_c:].set(big[:, :, m_c:2 * m_c])
    if py:
        big = bump(s)
        s = s.at[:, 0, :].set(big[:, ny, :])
        s = s.at[:, ny + 1, :].set(big[:, 1, :])
    if pz:
        big = bump(s)
        s = s.at[0, :, :].set(big[nz, :, :])
        s = s.at[nz + 1, :, :].set(big[1, :, :])

    return dataclasses.replace(bins, planes=planes, slot_id=s)


def gather_to_particles(bins: CellBins, plane: Array) -> Array:
    """Read a per-slot plane back to per-particle order (inverse of scatter)."""
    return plane.reshape(-1)[bins.particle_slot]


# --------------------------------------------------------------------------
# occupancy: the sparsity summary behind the compacted schedules
# --------------------------------------------------------------------------
#
# The dense slot layout charges every strategy for the *global* worst case:
# all (z, y) pencils (or sub-boxes) are visited, each padded to m_c slots.
# On inhomogeneous distributions most of those work units are empty. The
# occupancy summary is the trace-time-safe sparsity map: per-unit particle
# counts plus a compacted list of the active unit indices, under a static
# ``max_active`` bound that mirrors the m_c replan contract (overflow is
# detectable, never silent — a too-small bound drops work units, so the
# plan layer re-plans with a larger bound instead of computing wrong
# forces).


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Occupancy:
    """Compacted active-work-unit summary (pencils or sub-boxes).

    ``active`` holds the linearized indices of the units with at least one
    particle, padded to the static bound ``max_active`` with index 0 (always
    a valid unit to *read*; padded entries are dropped on the write side via
    :meth:`scatter_indices`). ``n_active`` is the true count — when it
    exceeds ``max_active`` the summary has overflowed and results computed
    from it would silently miss units, exactly like a cell overflowing m_c.
    """

    unit_counts: Array            # (n_units,) int32 particles per work unit
    active: Array                 # (max_active,) int32 unit ids, 0-padded
    n_active: Array               # () int32 true number of active units
    max_active: int = dataclasses.field(metadata=dict(static=True))
    n_units: int = dataclasses.field(metadata=dict(static=True))

    @property
    def overflowed(self) -> Array:
        """True when active units were dropped from ``active`` (replan)."""
        return self.n_active > self.max_active

    def scatter_indices(self) -> Array:
        """(max_active,) write-side unit ids: padding slots are pushed out
        of range so a ``mode='drop'`` scatter discards them."""
        slot = jnp.arange(self.max_active, dtype=jnp.int32)
        return jnp.where(slot < self.n_active, self.active,
                         jnp.int32(self.n_units))

    @property
    def fill_fraction(self) -> Array:
        return self.n_active / max(self.n_units, 1)


def _compact_active(unit_counts: Array, max_active: int,
                    n_units: int) -> Occupancy:
    active = jnp.nonzero(unit_counts > 0, size=max_active,
                         fill_value=0)[0].astype(jnp.int32)
    n_active = jnp.sum(unit_counts > 0).astype(jnp.int32)
    return Occupancy(unit_counts=unit_counts, active=active,
                     n_active=n_active, max_active=max_active,
                     n_units=n_units)


def counts_grid(domain: Domain, counts: Array) -> Array:
    """(n_cells,) linear cell counts -> (nz, ny, nx) grid (X fastest)."""
    return counts.reshape(domain.nz, domain.ny, domain.nx)


def pencil_counts(domain: Domain, counts: Array) -> Array:
    """(n_cells,) cell counts -> (nz*ny,) particles per (z, y) X-pencil.
    Unit id = z * ny + y — the pencil-schedule linearization. The single
    source of truth for pencil unit ids (occupancy summaries and the plan
    layer's overflow probes both derive from it)."""
    return counts_grid(domain, counts).sum(axis=-1).reshape(-1)


def subbox_counts(domain: Domain, counts: Array,
                  box: Tuple[int, int, int]) -> Array:
    """(n_cells,) cell counts -> (gz*gy*gx,) particles per sub-box of the
    All-in-SM tiling. ``box`` = (bx, by, bz) must divide the grid. Unit
    id = iz*(gy*gx) + iy*gx + ix, matching the allin block linearization."""
    nx, ny, nz = domain.ncells
    bx, by, bz = box
    gx, gy, gz = nx // bx, ny // by, nz // bz
    grid = counts_grid(domain, counts)
    return grid.reshape(gz, bz, gy, by, gx, bx).sum(axis=(1, 3, 5)).reshape(-1)


def shard_slab_counts(domain: Domain, counts: Array, n_shards: int) -> Array:
    """(n_cells,) cell counts -> (n_shards,) particles per Z-slab shard.

    The reduction behind the distributed engine's ``shard_cap`` overflow
    contract: a shard whose load exceeds the static capacity would silently
    drop particles, exactly like a cell overflowing ``m_c``.
    """
    if domain.nz % n_shards:
        raise ValueError(
            f"nz={domain.nz} not divisible by n_shards={n_shards}")
    per_plane = counts_grid(domain, counts).sum(axis=(1, 2))     # (nz,)
    return per_plane.reshape(n_shards, domain.nz // n_shards).sum(axis=1)


def shard_pencil_active(domain: Domain, counts: Array,
                        n_shards: int) -> Array:
    """(n_cells,) cell counts -> (n_shards,) active (z, y) pencils per
    Z-slab shard — the per-shard occupancy the distributed compacted path's
    ``max_active`` bound must cover (the bound is one static number shared
    by every shard, so it is checked against the *busiest* shard)."""
    if domain.nz % n_shards:
        raise ValueError(
            f"nz={domain.nz} not divisible by n_shards={n_shards}")
    pc = pencil_counts(domain, counts).reshape(domain.nz, domain.ny)
    active = (pc > 0).astype(jnp.int32)
    return active.reshape(n_shards, domain.nz // n_shards,
                          domain.ny).sum(axis=(1, 2))


def pencil_occupancy(domain: Domain, counts: Array,
                     max_active: int) -> Occupancy:
    """Active (z, y) X-pencils (see :func:`pencil_counts` for unit ids).
    Traceable: works on ``CellBins.counts`` inside jit."""
    return _compact_active(pencil_counts(domain, counts), max_active,
                           domain.nz * domain.ny)


def subbox_occupancy(domain: Domain, counts: Array,
                     box: Tuple[int, int, int], max_active: int) -> Occupancy:
    """Active sub-boxes (see :func:`subbox_counts` for unit ids)."""
    nx, ny, nz = domain.ncells
    bx, by, bz = box
    n_boxes = (nx // bx) * (ny // by) * (nz // bz)
    return _compact_active(subbox_counts(domain, counts, box), max_active,
                           n_boxes)


def gather_pencil_rows(plane: Array, active_zy: Array, ny: int,
                       dz: int = 0, dy: int = 0) -> Array:
    """Compacted pencil-row gather: one padded row per active pencil.

    ``active_zy`` holds interior pencil ids ``z * ny + y``; the returned
    array is ``(len(active_zy), (nx+2)*m_c)`` — row ``a`` is the padded
    ``(z + dz + 1, y + dy + 1)`` row of ``plane``. This is the sparse
    counterpart of the dense schedules' per-pencil ``dynamic_slice``: one
    vectorized gather instead of a loop over all nz*ny pencils.
    """
    z = active_zy // ny + 1 + dz
    y = active_zy % ny + 1 + dy
    return plane[z, y, :]


def interior(domain: Domain, plane: Array, m_c: int) -> Array:
    """View of the non-ghost region, reshaped to (nz, ny, nx, m_c)."""
    nx, ny, nz = domain.ncells
    core = plane[1:nz + 1, 1:ny + 1, m_c:(nx + 1) * m_c]
    return core.reshape(nz, ny, nx, m_c)


def interior_to_padded(domain: Domain, plane: Array, m_c: int) -> Array:
    """(nz, ny, nx, m_c) interior tensor -> padded plane (ghosts zero).

    Inverse of ``interior`` up to the ghost ring; the step every dense
    schedule output goes through before ``gather_to_particles``.
    """
    nx, ny, nz = domain.ncells
    padded = jnp.zeros((nz + 2, ny + 2, (nx + 2) * m_c), dtype=plane.dtype)
    return padded.at[1:nz + 1, 1:ny + 1, m_c:(nx + 1) * m_c].set(
        plane.reshape(nz, ny, nx * m_c))


def dense_to_particles(domain: Domain, bins: CellBins, fx: Array, fy: Array,
                       fz: Array, pot: Array) -> Tuple[Array, Array]:
    """Normalize dense (nz, ny, nx, m_c) schedule outputs to per-particle
    (forces (N, 3), potential (N,)) — the backend-registry output contract."""
    out = []
    for plane in (fx, fy, fz, pot):
        shaped = plane.reshape(domain.nz, domain.ny, domain.nx, bins.m_c)
        out.append(gather_to_particles(
            bins, interior_to_padded(domain, shaped, bins.m_c)))
    return jnp.stack(out[:3], axis=-1), out[3]
