"""Compatibility engine API over the plan/execute layer (``core.api``).

New code should use the plan/execute API directly:

    p = plan(domain, make_lennard_jones(), positions=pos,
             strategy="auto", backend="pallas")
    forces, potential = p.execute(ParticleState(pos))

``CellListEngine`` and ``compute_interactions`` below are thin shims kept so
pre-existing call sites keep working unchanged; each one owns exactly one
:class:`~repro.core.api.InteractionPlan` and delegates to it. ``m_c`` and
the strategy/backend are static; everything else is traced.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .api import InteractionPlan, ParticleState, plan as make_plan
from .binning import CellBins, bin_particles
from .domain import Domain
from .interactions import PairKernel, make_lennard_jones

Array = jnp.ndarray


def suggest_m_c(domain: Domain, positions, slack: float = 1.5,
                align: int = 8) -> int:
    """One-off (outside jit) M_C choice: max cell count rounded up with slack.

    The paper keeps the running max while building the prefix sum; we do the
    same but add slack so the bound survives a few integration steps before a
    re-bin with a larger M_C is needed, and round *up* to a sublane multiple
    — unconditionally, since ``kernels/xpencil.py`` documents sublane-aligned
    slices as an invariant (small maxima used to leak through unrounded).
    """
    counts = jax.ops.segment_sum(
        jnp.ones((positions.shape[0],), jnp.int32),
        domain.cell_ids(positions), num_segments=domain.n_cells)
    mx = int(jnp.max(counts))
    m_c = max(1, int(mx * slack + 0.999))
    return -(-m_c // align) * align


class CellListEngine:
    """Cutoff pair-interaction engine over a uniform cell grid (shim)."""

    def __init__(self, domain: Domain, kernel: Optional[PairKernel] = None,
                 m_c: int = 8, strategy: str = "xpencil",
                 batch_size: int = 64, jit: bool = True,
                 backend: str = "reference"):
        self.plan = make_plan(domain, kernel or make_lennard_jones(),
                              m_c=m_c, strategy=strategy, backend=backend,
                              batch_size=batch_size)
        self._jit = jit

    # -- plan attributes, mirrored for old call sites ------------------------

    @property
    def domain(self) -> Domain:
        return self.plan.domain

    @property
    def kernel(self) -> PairKernel:
        return self.plan.kernel

    @property
    def m_c(self) -> int:
        return self.plan.m_c

    @property
    def strategy(self) -> str:
        return self.plan.strategy

    @property
    def batch_size(self) -> int:
        return self.plan.batch_size

    # -- pipeline ------------------------------------------------------------

    def bin(self, positions: Array, fields: Dict[str, Array] | None = None
            ) -> CellBins:
        return bin_particles(self.domain, positions, fields, m_c=self.m_c)

    def compute(self, positions: Array) -> Tuple[Array, Array]:
        """-> (forces (N, 3), per-particle potential (N,)).

        Total potential energy = 0.5 * potential.sum() (each pair counted
        twice, the paper's convention)."""
        state = ParticleState(positions)
        if not self._jit:
            with jax.disable_jit():
                return self.plan.execute(state)
        return self.plan.execute(state)

    def check_m_c(self, positions: Array) -> bool:
        """True if the current M_C bound still holds for these positions."""
        return not self.plan.check_overflow(ParticleState(positions))


def _interior_to_padded(domain: Domain, plane: Array, m_c: int) -> Array:
    """Deprecated alias; see ``binning.interior_to_padded``."""
    from .binning import interior_to_padded
    return interior_to_padded(domain, plane, m_c)


@functools.lru_cache(maxsize=None)
def _cached_plan(domain: Domain, kernel: PairKernel, m_c: int,
                 strategy: str, batch_size: int) -> InteractionPlan:
    return make_plan(domain, kernel, m_c=m_c, strategy=strategy,
                     batch_size=batch_size)


def compute_interactions(domain: Domain, positions: Array,
                         kernel: Optional[PairKernel] = None,
                         m_c: Optional[int] = None,
                         strategy: str = "xpencil",
                         batch_size: int = 64) -> Tuple[Array, Array]:
    """Functional one-shot API (plans cached by static config)."""
    kernel = kernel or make_lennard_jones()
    if m_c is None:
        m_c = suggest_m_c(domain, positions)
    p = _cached_plan(domain, kernel, m_c, strategy, batch_size)
    return p.execute(ParticleState(positions))
