"""Public API: the cell-list interaction engine.

    engine = CellListEngine(domain, kernel=make_lennard_jones(), strategy="xpencil")
    forces, potential = engine.compute(positions)

The engine owns: the static M_C bound (paper's M_C, tracked like the paper
does while computing the prefix sum), strategy dispatch, the bin -> compute ->
scatter-back sequence, and jit caching. ``m_c`` and the strategy are static;
everything else is traced.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import strategies as S
from .binning import CellBins, bin_particles, gather_to_particles
from .domain import Domain
from .interactions import PairKernel, make_lennard_jones

Array = jnp.ndarray


def suggest_m_c(domain: Domain, positions, slack: float = 1.5,
                align: int = 8) -> int:
    """One-off (outside jit) M_C choice: max cell count rounded up with slack.

    The paper keeps the running max while building the prefix sum; we do the
    same but add slack so the bound survives a few integration steps before a
    re-bin with a larger M_C is needed, and round to a sublane multiple.
    """
    counts = jax.ops.segment_sum(
        jnp.ones((positions.shape[0],), jnp.int32),
        domain.cell_ids(positions), num_segments=domain.n_cells)
    mx = int(jnp.max(counts))
    m_c = max(1, int(mx * slack + 0.999))
    return -(-m_c // align) * align if m_c > align else m_c


class CellListEngine:
    """Cutoff pair-interaction engine over a uniform cell grid."""

    def __init__(self, domain: Domain, kernel: Optional[PairKernel] = None,
                 m_c: int = 8, strategy: str = "xpencil",
                 batch_size: int = 64, jit: bool = True):
        if strategy not in ("naive_n2", *S.STRATEGIES):
            raise ValueError(f"unknown strategy {strategy!r}; "
                             f"have {sorted(S.STRATEGIES)} + ['naive_n2']")
        self.domain = domain
        self.kernel = kernel or make_lennard_jones()
        self.m_c = m_c
        self.strategy = strategy
        self.batch_size = batch_size
        self._compute = jax.jit(self._compute_impl) if jit else self._compute_impl

    # -- pipeline ------------------------------------------------------------

    def bin(self, positions: Array, fields: Dict[str, Array] | None = None
            ) -> CellBins:
        return bin_particles(self.domain, positions, fields, m_c=self.m_c)

    def _compute_impl(self, positions: Array) -> Tuple[Array, Array]:
        if self.strategy == "naive_n2":
            fx, fy, fz, pot = S.naive_n2(self.domain, positions, self.kernel)
            return jnp.stack([fx, fy, fz], axis=-1), pot

        bins = self.bin(positions)
        if self.strategy == "par_part":
            fx, fy, fz, pot = S.par_part(self.domain, bins, positions,
                                         self.kernel, self.batch_size)
            return jnp.stack([fx, fy, fz], axis=-1), pot

        fn = S.STRATEGIES[self.strategy]
        fx, fy, fz, pot = fn(self.domain, bins, self.kernel,
                             batch_size=self.batch_size)
        # dense interior (nz, ny, nx, m_c) -> per-particle via slot mapping
        out = []
        for plane in (fx, fy, fz, pot):
            padded = _interior_to_padded(self.domain, plane, self.m_c)
            out.append(gather_to_particles(bins, padded))
        return jnp.stack(out[:3], axis=-1), out[3]

    def compute(self, positions: Array) -> Tuple[Array, Array]:
        """-> (forces (N, 3), per-particle potential (N,)).

        Total potential energy = 0.5 * potential.sum() (each pair counted
        twice, the paper's convention)."""
        return self._compute(positions)

    def check_m_c(self, positions: Array) -> bool:
        """True if the current M_C bound still holds for these positions."""
        counts = jax.ops.segment_sum(
            jnp.ones((positions.shape[0],), jnp.int32),
            self.domain.cell_ids(positions), num_segments=self.domain.n_cells)
        return bool(jnp.max(counts) <= self.m_c)


def _interior_to_padded(domain: Domain, plane: Array, m_c: int) -> Array:
    """(nz, ny, nx, m_c) interior tensor -> padded plane (ghosts zero)."""
    nx, ny, nz = domain.ncells
    padded = jnp.zeros((nz + 2, ny + 2, (nx + 2) * m_c), dtype=plane.dtype)
    return padded.at[1:nz + 1, 1:ny + 1, m_c:(nx + 1) * m_c].set(
        plane.reshape(nz, ny, nx * m_c))


@functools.lru_cache(maxsize=None)
def _cached_engine(domain: Domain, kernel: PairKernel, m_c: int,
                   strategy: str, batch_size: int) -> CellListEngine:
    return CellListEngine(domain, kernel, m_c, strategy, batch_size)


def compute_interactions(domain: Domain, positions: Array,
                         kernel: Optional[PairKernel] = None,
                         m_c: Optional[int] = None,
                         strategy: str = "xpencil",
                         batch_size: int = 64) -> Tuple[Array, Array]:
    """Functional one-shot API (engines cached by static config)."""
    kernel = kernel or make_lennard_jones()
    if m_c is None:
        m_c = suggest_m_c(domain, positions)
    eng = _cached_engine(domain, kernel, m_c, strategy, batch_size)
    return eng.compute(positions)
