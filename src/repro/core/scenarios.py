"""Inhomogeneous particle scenarios — the clustered-workload family.

The paper benchmarks uniform particles, but the regimes its "few particles
per cell" premise actually comes from — SPH free surfaces, astrophysical
clustering, droplets — are *inhomogeneous*: most cells (and therefore most
pencils / sub-boxes of the dense schedules) are empty. These samplers
produce such scenes at controllable fill fractions; they drive the
occupancy-compacted execution path's tests and the ``fig_sparse``
speedup-vs-fill benchmark.

Every sampler has the same signature ``(domain, key, n, **knobs) ->
(n, 3) positions`` strictly inside the box (clipping keeps the samplers
simple; the distributions are benchmarks, not physics).
"""

from __future__ import annotations

from typing import Callable, Dict

import jax
import jax.numpy as jnp

from .domain import Domain

Array = jnp.ndarray

# margin keeping clipped samples strictly inside the open box
_EDGE = 1e-4


def _clip(domain: Domain, pos: Array) -> Array:
    box = jnp.asarray(domain.box, dtype=pos.dtype)
    return jnp.clip(pos, _EDGE, box - _EDGE)


def sample_uniform(domain: Domain, key, n: int) -> Array:
    """The paper's homogeneous baseline (fill fraction ~1 at useful N)."""
    return domain.sample_uniform(key, n)


def sample_gaussian_blob(domain: Domain, key, n: int, *,
                         sigma_frac: float = 0.08,
                         center_frac: float = 0.5) -> Array:
    """One Gaussian cluster: ``sigma = sigma_frac * min(box)`` around
    ``center_frac * box``. Small ``sigma_frac`` -> few active pencils."""
    box = jnp.asarray(domain.box, dtype=jnp.float32)
    sigma = sigma_frac * float(min(domain.box))
    pos = box * center_frac + sigma * jax.random.normal(key, (n, 3))
    return _clip(domain, pos)


def sample_two_phase(domain: Domain, key, n: int, *,
                     droplet_frac: float = 0.9,
                     radius_frac: float = 0.15) -> Array:
    """A dense spherical droplet in a thin vapor (SPH free-surface regime).

    ``droplet_frac`` of the particles fill a ball of radius
    ``radius_frac * min(box)`` at the box center; the rest spread uniformly
    (so no pencil is *guaranteed* empty — the realistic hard case for
    compaction, as opposed to the blob's clean zeros).
    """
    k_d, k_v, k_r = jax.random.split(key, 3)
    n_drop = int(n * droplet_frac)
    box = jnp.asarray(domain.box, dtype=jnp.float32)
    radius = radius_frac * float(min(domain.box))
    # uniform-in-ball: direction on the sphere times cbrt-distributed radius
    d = jax.random.normal(k_d, (n_drop, 3))
    d = d / jnp.linalg.norm(d, axis=-1, keepdims=True)
    r = radius * jax.random.uniform(k_r, (n_drop, 1)) ** (1.0 / 3.0)
    drop = box * 0.5 + d * r
    vapor = domain.sample_uniform(k_v, n - n_drop)
    return _clip(domain, jnp.concatenate([drop, vapor.astype(drop.dtype)]))


def sample_power_law_cluster(domain: Domain, key, n: int, *,
                             n_clusters: int = 4, alpha: float = 2.5,
                             r_min_frac: float = 0.01,
                             r_max_frac: float = 0.25) -> Array:
    """Hierarchical clustering: particles around ``n_clusters`` centers
    with power-law radial falloff ``p(r) ~ r^-alpha`` between
    ``r_min_frac`` and ``r_max_frac`` of the box (astrophysical regime:
    dense cores, sparse halos, steep cell-count inhomogeneity)."""
    k_c, k_a, k_d, k_r = jax.random.split(key, 4)
    box = jnp.asarray(domain.box, dtype=jnp.float32)
    centers = jax.random.uniform(k_c, (n_clusters, 3)) * box
    assign = jax.random.randint(k_a, (n,), 0, n_clusters)
    d = jax.random.normal(k_d, (n, 3))
    d = d / jnp.linalg.norm(d, axis=-1, keepdims=True)
    scale = float(min(domain.box))
    r_min, r_max = r_min_frac * scale, r_max_frac * scale
    u = jax.random.uniform(k_r, (n, 1))
    if abs(alpha - 1.0) < 1e-6:
        r = r_min * (r_max / r_min) ** u
    else:
        # inverse-CDF of p(r) ~ r^-alpha on [r_min, r_max]
        e = 1.0 - alpha
        r = (r_min ** e + u * (r_max ** e - r_min ** e)) ** (1.0 / e)
    return _clip(domain, centers[assign] + d * r)


SCENARIOS: Dict[str, Callable[..., Array]] = {
    "uniform": sample_uniform,
    "gaussian_blob": sample_gaussian_blob,
    "two_phase": sample_two_phase,
    "power_law_cluster": sample_power_law_cluster,
}


def sample(name: str, domain: Domain, key, n: int, **knobs) -> Array:
    """Sample a named scenario (``SCENARIOS`` registry)."""
    try:
        fn = SCENARIOS[name]
    except KeyError:
        raise ValueError(f"unknown scenario {name!r}; "
                         f"have {sorted(SCENARIOS)}") from None
    return fn(domain, key, n, **knobs)
