"""Core: the paper's cell-list interaction engine (DESIGN.md §1-2).

The public front door is the plan/execute API (``core.api``):
``plan(...)`` fixes every static choice once, ``plan.execute(state)`` is the
jitted hot path, and backends ("reference" pure-JAX / "pallas" TPU kernels)
register per strategy behind one normalized signature. ``CellListEngine``
and ``compute_interactions`` are compatibility shims over it.
"""

from .domain import Domain
from .api import (ExecutionReport, InteractionPlan, ParticleState, PlanHealth,
                  active_unit_count, backend_matrix, choose_strategy,
                  clear_executor_cache, degradation_ladder, dispatch_count,
                  executor_cache_info, fallback_plan, plan, plan_health,
                  recompile_count, register_backend, reset_counters,
                  reset_health, set_executor_cache_size, suggest_max_active,
                  suggest_pair_cap, suggest_row_cap, supports_compact,
                  supports_layout)
from .binning import (CellBins, Occupancy, PackedRows, SfcClusters,
                      bin_particles, build_sfc_clusters, decode_pair_codes,
                      dense_to_particles, encode_pair_masks,
                      full_pencil_occupancy, gather_pencil_rows,
                      gather_to_particles, hilbert_decode, hilbert_encode,
                      interior_to_padded, morton_decode, morton_encode,
                      pack_rows, packed_to_particles, padded_row_counts,
                      pencil_occupancy, sfc_cluster_tables, sfc_pair_count,
                      sfc_to_particles, subbox_occupancy, unpack_scatter)
from .engine import CellListEngine, compute_interactions, suggest_m_c
from .interactions import (
    PairKernel,
    make_gravity,
    make_high_flop,
    make_lennard_jones,
    make_low_flop,
    make_sph_density,
    pair_contribution,
)
from .prefix import (
    blelloch_counts,
    exclusive_prefix_sum,
    operation_counts,
    paper_prefix_sum,
)
from .timing import time_fn
from . import autotune, scenarios, strategies, traffic
from .autotune import TuneResult, tune

__all__ = [
    "Domain", "CellBins", "Occupancy", "PackedRows", "bin_particles",
    "gather_to_particles", "gather_pencil_rows", "dense_to_particles",
    "interior_to_padded", "pack_rows", "packed_to_particles",
    "padded_row_counts", "unpack_scatter", "full_pencil_occupancy",
    "pencil_occupancy", "subbox_occupancy",
    "SfcClusters", "build_sfc_clusters", "sfc_cluster_tables",
    "sfc_pair_count", "sfc_to_particles", "encode_pair_masks",
    "decode_pair_codes", "morton_encode", "morton_decode",
    "hilbert_encode", "hilbert_decode", "suggest_pair_cap",
    "ExecutionReport", "InteractionPlan", "ParticleState", "PlanHealth",
    "plan", "register_backend",
    "backend_matrix", "choose_strategy", "clear_executor_cache",
    "degradation_ladder", "fallback_plan", "plan_health", "reset_health",
    "dispatch_count", "recompile_count", "reset_counters",
    "executor_cache_info", "set_executor_cache_size",
    "active_unit_count", "suggest_max_active",
    "suggest_row_cap", "supports_compact", "supports_layout",
    "tune", "TuneResult", "time_fn", "autotune",
    "CellListEngine", "compute_interactions", "suggest_m_c",
    "PairKernel", "make_gravity", "make_high_flop", "make_lennard_jones",
    "make_low_flop", "make_sph_density", "pair_contribution",
    "paper_prefix_sum", "exclusive_prefix_sum", "operation_counts",
    "blelloch_counts", "scenarios", "strategies", "traffic",
]
