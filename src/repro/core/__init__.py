"""Core: the paper's cell-list interaction engine (DESIGN.md §1-2)."""

from .domain import Domain
from .binning import CellBins, bin_particles, gather_to_particles
from .engine import CellListEngine, compute_interactions, suggest_m_c
from .interactions import (
    PairKernel,
    make_gravity,
    make_high_flop,
    make_lennard_jones,
    make_low_flop,
    make_sph_density,
    pair_contribution,
)
from .prefix import (
    blelloch_counts,
    exclusive_prefix_sum,
    operation_counts,
    paper_prefix_sum,
)
from . import strategies, traffic

__all__ = [
    "Domain", "CellBins", "bin_particles", "gather_to_particles",
    "CellListEngine", "compute_interactions", "suggest_m_c",
    "PairKernel", "make_gravity", "make_high_flop", "make_lennard_jones",
    "make_low_flop", "make_sph_density", "pair_contribution",
    "paper_prefix_sum", "exclusive_prefix_sum", "operation_counts",
    "blelloch_counts", "strategies", "traffic",
]
