"""Pairwise interaction kernels.

Central-force form shared by every scheduling strategy and the Pallas kernels:

    F_ij = coeff(r2) * (r_i - r_j)        (force on target i from source j)
    U_i  = sum_j potential(r2)            (per-particle potential channel)

``coeff``/``potential`` receive a *masked-safe* r2 (strategies replace the r2
of excluded pairs by 1.0 before calling, then zero the contribution), so
kernels never have to defend against r2 == 0 or inf.

The three benchmark kernels reproduce the paper's Figure 8 sweep:
  * ``low_flop``   ~5 FLOP/interaction  (paper's fake kernel: position sums)
  * ``lennard_jones`` 21 FLOP/interaction, arithmetic intensity ~0.4 FLOP/byte
  * ``high_flop``  ~168 FLOP/interaction (LJ + 150 extra FLOP)

``flops`` is bookkeeping metadata used by the benchmarks and the roofline
model (the paper's own counting convention: distance + kernel, sqrt = 1 FLOP).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class PairKernel:
    """A cutoff pair interaction. Hashable; safe to close over under jit.

    Hash/eq are *value-based* on ``(name, flops, static_params)`` rather than
    identity, so two ``make_lennard_jones()`` calls produce equal kernels and
    hit the same jit trace / ``_cached_plan`` entry instead of retracing.
    Factories must fold every behaviour-affecting argument into
    ``static_params`` — equal tuples promise equal ``coeff``/``potential``.
    """

    name: str
    coeff: Callable[[Array], Array]
    potential: Callable[[Array], Array]
    flops: int  # per-interaction FLOP count, paper's convention
    static_params: Tuple = ()  # factory args that define coeff/potential

    def __hash__(self):
        return hash((self.name, self.flops, self.static_params))

    def __eq__(self, other):
        if not isinstance(other, PairKernel):
            return NotImplemented
        return (self.name, self.flops, self.static_params) == \
            (other.name, other.flops, other.static_params)


def _lj_terms(r2: Array, sigma2: float, eps: float):
    inv = sigma2 / r2
    a6 = inv * inv * inv
    a12 = a6 * a6
    return a6, a12


def make_lennard_jones(sigma: float = 0.2, eps: float = 1.0,
                       softening: float = 1e-6) -> PairKernel:
    """Lennard-Jones 12-6 with the paper's softening against random overlaps."""
    sigma2 = sigma * sigma

    def coeff(r2):
        r2 = r2 + softening
        a6, a12 = _lj_terms(r2, sigma2, eps)
        return 24.0 * eps * (2.0 * a12 - a6) / r2

    def potential(r2):
        r2 = r2 + softening
        a6, a12 = _lj_terms(r2, sigma2, eps)
        return 4.0 * eps * (a12 - a6)

    return PairKernel("lennard_jones", coeff, potential, flops=21,
                      static_params=(sigma, eps, softening))


def make_low_flop() -> PairKernel:
    """~5 FLOP: the paper's memory-bound probe (sums, no divisions)."""

    def coeff(r2):
        return r2 * 0.5

    def potential(r2):
        return r2 + 1.0

    return PairKernel("low_flop", coeff, potential, flops=5)


def make_high_flop(extra_terms: int = 25, sigma: float = 0.2,
                   eps: float = 1.0, softening: float = 1e-6) -> PairKernel:
    """LJ + ``6 * extra_terms`` FLOP of r2-dependent polynomial work
    (25 terms -> +150 FLOP -> 168 total, matching the paper's Figure 8)."""
    lj = make_lennard_jones(sigma, eps, softening)

    def extra(r2):
        acc = r2
        for k in range(extra_terms):  # 6 FLOP per term, not foldable: uses r2
            acc = acc * 0.9999 + r2 * (1e-3 * (k + 1)) + 1e-7
            acc = acc * 1.0001
        return acc * 1e-30  # keep magnitude negligible, dependency real

    def coeff(r2):
        return lj.coeff(r2) + extra(r2)

    def potential(r2):
        return lj.potential(r2) + extra(r2)

    return PairKernel("high_flop", coeff, potential,
                      flops=21 + 6 * extra_terms,
                      static_params=(extra_terms, sigma, eps, softening))


def make_gravity(g: float = 1.0, softening: float = 1e-4) -> PairKernel:
    """Softened attractive 1/r2 (Nyland et al.'s n-body kernel, §8)."""

    def coeff(r2):
        d = r2 + softening
        return -g * jax.lax.rsqrt(d) / d

    def potential(r2):
        return -g * jax.lax.rsqrt(r2 + softening)

    return PairKernel("gravity", coeff, potential, flops=14,
                      static_params=(g, softening))


def make_sph_density(h: float) -> PairKernel:
    """Cubic-spline SPH density accumulation (potential channel = sum of W).

    W(q) = s * (1 - 3/2 q^2 + 3/4 q^3)   for 0 <= q < 1
         = s/4 * (2 - q)^3               for 1 <= q < 2,   q = r / (h/2)

    using smoothing length h/2 so the support radius equals the cell cutoff h
    (the paper's 30-40 neighbor SPH regime).
    """
    hh = h / 2.0
    s = 1.0 / (jnp.pi * hh ** 3)

    def potential(r2):
        q = jnp.sqrt(r2) / hh
        w1 = 1.0 - 1.5 * q * q + 0.75 * q ** 3
        w2 = 0.25 * (2.0 - q) ** 3
        w = jnp.where(q < 1.0, w1, jnp.where(q < 2.0, w2, 0.0))
        return s * w

    def coeff(r2):
        # grad W / r (central-force coefficient) for the pressure pipeline.
        q = jnp.sqrt(jnp.maximum(r2, 1e-12)) / hh
        g1 = -3.0 * q + 2.25 * q * q
        g2 = -0.75 * (2.0 - q) ** 2
        g = jnp.where(q < 1.0, g1, jnp.where(q < 2.0, g2, 0.0))
        r = jnp.maximum(jnp.sqrt(r2), 1e-12)
        return s * g / (hh * r)

    return PairKernel("sph_density", coeff, potential, flops=18,
                      static_params=(h,))


KERNELS: Dict[str, Callable[[], PairKernel]] = {
    "lennard_jones": make_lennard_jones,
    "low_flop": make_low_flop,
    "high_flop": make_high_flop,
    "gravity": make_gravity,
}


def pair_contribution(kernel: PairKernel, dx: Array, dy: Array, dz: Array,
                      mask: Array, cutoff2: float):
    """Masked force coefficient + potential for a batch of candidate pairs.

    Returns (fx, fy, fz, pot); excluded pairs contribute exactly 0 with no
    NaN/Inf leakage (masked-safe r2 substitution).
    """
    r2 = dx * dx + dy * dy + dz * dz
    m = mask & (r2 < cutoff2) & (r2 > 0.0)
    r2_safe = jnp.where(m, r2, 1.0)
    w = m.astype(dx.dtype)
    s = kernel.coeff(r2_safe) * w
    pot = kernel.potential(r2_safe) * w
    return s * dx, s * dy, s * dz, pot
