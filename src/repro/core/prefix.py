"""The paper's prefix-sum (Section 6 / Algorithm 6) in JAX.

Blelloch's scan builds a binary tree of sums with an upward and a downward
pass and ``2h`` barriers. The paper's variant places the final value of every
"right spine" element already during the upward pass and therefore:

  * needs ``2h - 3`` barriers instead of ``2h``  (h = ceil(log2(N + 1)));
  * performs ``N - 1`` element updates upward and ``N - h`` downward;
  * needs no temporary storage, no final swap, and half the threads.

This module is the *algorithmic reference*: the level structure below mirrors
the paper's CUDA Code 1 exactly (each ``while`` iteration is one kernel-wide
barrier; the vectorized index update inside is what all threads of the block
do between two barriers). ``repro.kernels.prefix_sum`` lowers the same
schedule to a Pallas VMEM kernel; both are tested against ``jnp.cumsum`` and
against the paper's operation/barrier counts.
"""

from __future__ import annotations

import math
from typing import Tuple

import jax.numpy as jnp

Array = jnp.ndarray


def paper_prefix_sum(x: Array) -> Array:
    """Inclusive prefix sum along the last axis, paper's schedule.

    Works for any length N (the per-level index sets below carry the same
    ``idN < N`` guard as the paper's inner loops).
    """
    n = x.shape[-1]
    if n <= 1:
        return x
    # Upward pass: level step js doubles; element js-1, 2js-1, ... absorbs the
    # partial sum js/2 positions to its left. Right-spine elements (indices
    # 2^k - 1) end up final here — the trick that removes Blelloch's swap.
    js = 2
    while js <= n:
        idx = jnp.arange(js - 1, n, js)
        x = x.at[..., idx].add(x[..., idx - js // 2])
        js *= 2
    # Downward pass: propagate each node's value to the element halfway into
    # the *next* block (paper: "each node's computed sum is added to its right
    # child, except for the last node of each level"). Start level follows the
    # paper's CUDA Code 1 (js_exit / 2) — the sequential pseudo-code's js/4
    # start skips a needed level for N that are not exact powers of two.
    js = max(4, js // 2)
    while js > 1:
        jsd2 = js // 2
        start = js + jsd2 - 1
        if start < n:
            idx = jnp.arange(start, n, js)
            x = x.at[..., idx].add(x[..., idx - jsd2])
        js = jsd2
    return x


def exclusive_prefix_sum(x: Array) -> Array:
    """Exclusive scan built from the paper's inclusive scan (binning needs the
    cell *start offsets*, cf. paper Figure 1)."""
    inc = paper_prefix_sum(x)
    zero = jnp.zeros_like(x[..., :1])
    return jnp.concatenate([zero, inc[..., :-1]], axis=-1)


def operation_counts(n: int) -> Tuple[int, int, int]:
    """(updates_upward, updates_downward, barriers) for length ``n``.

    The paper proves updates_up = N - 1, updates_down = N - h and
    barriers = 2h - 3 for N = 2^k. For general N we count the actual index
    sets (the formulas hold exactly at powers of two; tests check both).
    """
    ups = 0
    levels_up = 0
    js = 2
    while js <= n:
        ups += len(range(js - 1, n, js))
        levels_up += 1
        js *= 2
    downs = 0
    levels_down = 0
    js = max(4, js // 2)
    while js > 1:
        jsd2 = js // 2
        start = js + jsd2 - 1
        if start < n:
            downs += len(range(start, n, js))
            levels_down += 1
        js = jsd2
    return ups, downs, levels_up + levels_down


def blelloch_counts(n: int) -> Tuple[int, int, int]:
    """Classic Blelloch work/barrier counts for comparison in the benchmark:
    N-1 updates up-sweep, N-1 down-sweep, 2h barriers (h = ceil(log2 N))."""
    h = max(1, math.ceil(math.log2(n))) if n > 1 else 1
    return n - 1, n - 1, 2 * h


def paper_height(n: int) -> int:
    """h = ceil(log2(N + 1)) — the abstract-tree height used by the paper."""
    return math.ceil(math.log2(n + 1))
