"""Plan/execute interaction API — the front door to every schedule + backend.

The paper's subject is choosing among interchangeable schedules (Par-Part,
Par-Cell, X-pencil, All-in-SM) for the same cutoff interaction. This module
separates that choice (static, made once) from the traced computation (made
every step):

    state = ParticleState(positions)                        # traced pytree
    p = plan(domain, kernel, positions=positions,           # static choices
             strategy="auto", backend="pallas")
    forces, potential = p.execute(state)                    # jitted hot path
    (forces, potential), p = p.execute_or_replan(state)     # + M_C safety net

Three layers:

  ``ParticleState``    the universal traced input: positions plus optional
                       per-particle fields (velocity, mass, ...).
  ``InteractionPlan``  all static choices — domain, kernel, ``m_c``,
                       strategy, backend, batch/grid sizing — hashable, so
                       one jit trace per distinct plan. ``strategy="auto"``
                       is driven by the ``core.traffic`` cost model.
  backend registry     one normalized signature
                       ``(plan, bins, state) -> (forces (N,3), pot (N,))``
                       under which the pure-JAX references
                       (``core.strategies``) and the Pallas kernels
                       (``repro.kernels``) register per strategy name, so
                       ``backend="pallas"`` routes ``xpencil``/``allin``
                       through the same front door as their oracles.

``CellListEngine`` / ``compute_interactions`` in ``core.engine`` are thin
compatibility shims over this module.
"""

from __future__ import annotations

import collections
import dataclasses
import functools
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from . import strategies as S
from . import traffic
from .binning import (CellBins, PackedRows, SfcClusters, bin_particles,
                      build_sfc_clusters, cell_counts, dense_to_particles,
                      full_pencil_occupancy, pack_rows, packed_to_particles,
                      padded_row_counts, pencil_counts, pencil_occupancy,
                      sfc_n_clusters, sfc_pair_count, sfc_to_particles,
                      subbox_counts, subbox_occupancy)
from .domain import Domain, slab_domain
from .interactions import PairKernel, make_lennard_jones
# obs imports only its own trace/metrics modules eagerly (no core imports),
# so the dependency is acyclic: core.api -> obs.{trace,metrics}
from ..obs import metrics as _obs_metrics
from ..obs.trace import (event as _obs_event, trace as _obs_trace,
                         tracing_enabled as _tracing_enabled)

Array = jnp.ndarray

STRATEGY_NAMES = ("par_part", "cell_dense", "xpencil", "allin")


# --------------------------------------------------------------------------
# traced input
# --------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ParticleState:
    """The universal traced input: positions + optional per-particle fields.

    ``fields`` maps names ("vx", "mass", ...) to (N,) arrays that are binned
    alongside x/y/z so schedules can read them per slot. The dict's *keys*
    are static (part of the trace); the values are traced.

    ``valid`` is an optional (N,) bool mask marking padding rows (False):
    those rows are excluded from binning, interact with nothing, and every
    bound probe ignores them. This is how the serving tier
    (``repro.serve``) pads heterogeneous request sizes up to one shape
    class without perturbing a single real interaction — executing a
    padded, masked state is bit-identical (for the real rows) to executing
    the unpadded state.
    """

    positions: Array                                   # (N, 3)
    fields: Dict[str, Array] = dataclasses.field(default_factory=dict)
    valid: Optional[Array] = None                      # (N,) bool, None=all

    @property
    def n(self) -> int:
        return self.positions.shape[0]


# --------------------------------------------------------------------------
# backend registry
# --------------------------------------------------------------------------

# (backend, strategy, layout) -> fn(plan, bins, state) -> (forces, pot).
# ``layout`` is the execution layout the implementation reads: "dense"
# implementations receive a CellBins, "packed" ones a binning.PackedRows,
# "sfc" ones a binning.SfcClusters (compressed cluster-pair list).
_BACKENDS: Dict[Tuple[str, str, str], Callable] = {}

LAYOUT_NAMES = ("dense", "packed", "sfc")

# (backend, strategy, layout) triples whose implementation honours
# ``plan.compact`` (occupancy-compacted iteration). By register_backend.
_COMPACT_OK: set = set()


def register_backend(backend: str, strategy: str, compact: bool = False,
                     layout: str = "dense"):
    """Register an implementation under ``(backend, strategy, layout)``.

    The implementation receives the (static) plan, the binned layout
    (:class:`~repro.core.binning.CellBins` for ``layout="dense"``,
    :class:`~repro.core.binning.PackedRows` for ``layout="packed"``), and
    the traced state, and must return per-particle ``(forces, pot)`` — the
    one normalized signature both the reference schedules and the Pallas
    kernels conform to. ``compact=True`` declares that the implementation
    also honours ``plan.compact`` (occupancy-compacted iteration).
    """
    if layout not in LAYOUT_NAMES:
        raise ValueError(f"unknown layout {layout!r}; have {LAYOUT_NAMES}")

    def deco(fn: Callable) -> Callable:
        _BACKENDS[(backend, strategy, layout)] = fn
        if compact:
            _COMPACT_OK.add((backend, strategy, layout))
        return fn
    return deco


def supports_compact(backend: str, strategy: str,
                     layout: str = "dense") -> bool:
    """True if ``(backend, strategy, layout)`` implements the compacted
    path."""
    if backend == "pallas":
        import repro.kernels  # noqa: F401  (trigger registration)
    return (backend, strategy, layout) in _COMPACT_OK


def supports_layout(backend: str, strategy: str, layout: str) -> bool:
    """True if ``(backend, strategy)`` implements the given execution
    layout (``"dense"`` / ``"packed"``)."""
    if backend == "pallas":
        import repro.kernels  # noqa: F401  (trigger registration)
    return (backend, strategy, layout) in _BACKENDS


def get_backend(backend: str, strategy: str,
                layout: str = "dense") -> Callable:
    if backend == "pallas":
        # Pallas implementations self-register on import; make sure the
        # module ran before declaring the combination missing.
        import repro.kernels  # noqa: F401
    fn = _BACKENDS.get((backend, strategy, layout))
    if fn is None:
        import repro.kernels  # noqa: F401  (list *all* backends in the error)
        fn = _BACKENDS.get((backend, strategy, layout))
    if fn is None:
        have = sorted(set(b for b, _, _ in _BACKENDS))
        raise ValueError(
            f"no backend {backend!r} for strategy {strategy!r} with layout "
            f"{layout!r}; registered backends: {have}, triples: "
            f"{sorted(_BACKENDS)}")
    return fn


def backend_matrix() -> Dict[str, Tuple[str, ...]]:
    """backend name -> strategies it implements (docs / README helper)."""
    import repro.kernels  # noqa: F401  (trigger pallas registration)
    out: Dict[str, list] = {}
    for b, s, layout in sorted(_BACKENDS):
        if s not in out.setdefault(b, []):
            out[b].append(s)
    return {b: tuple(s) for b, s in out.items()}


# --------------------------------------------------------------------------
# the plan
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class InteractionPlan:
    """All static choices for a cutoff interaction, made once.

    Hashable: two equal plans share one jit trace. Everything traced lives
    in ``ParticleState``; everything here is compile-time constant.
    """

    domain: Domain
    kernel: PairKernel
    m_c: int
    strategy: str = "xpencil"
    backend: str = "reference"
    batch_size: int = 64
    box: Optional[Tuple[int, int, int]] = None   # allin sub-box (bx, by, bz)
    interpret: Optional[bool] = None             # pallas: None = auto
    compact: bool = False                        # occupancy-compacted path
    max_active: Optional[int] = None             # static active-unit bound
    layout: str = "dense"                 # layout: dense | packed | sfc
    row_cap: Optional[int] = None                # static packed-row bound
    pair_cap: Optional[int] = None               # static sfc pair-list bound
    # -- distributed halo execution (backend="halo"; repro.dist.engine) ----
    halo_inner: str = "reference"                # per-shard backend
    n_shards: Optional[int] = None               # Z-slabs on the mesh axis
    shard_axis: str = "halo"                     # mesh axis name
    shard_cap: Optional[int] = None              # static per-shard capacity
    mesh: Optional[object] = None                # jax Mesh; None = default

    def __post_init__(self):
        if self.strategy not in ("naive_n2", *STRATEGY_NAMES):
            raise ValueError(
                f"unknown strategy {self.strategy!r}; have "
                f"{sorted(STRATEGY_NAMES)} + ['naive_n2']")
        if self.backend == "halo":
            if self.strategy not in ("cell_dense", "xpencil", "allin"):
                raise ValueError(
                    f"backend='halo' needs a cell schedule, got "
                    f"{self.strategy!r} (the Z-slab decomposition has no "
                    "meaning for particle-parallel or O(N^2) sweeps)")
            if self.halo_inner == "halo":
                raise ValueError("halo_inner must be a concrete per-shard "
                                 "backend ('reference'/'pallas'), not "
                                 "'halo' itself")
            if not self.n_shards or self.n_shards < 1:
                raise ValueError(
                    "backend='halo' needs n_shards >= 1 "
                    "(plan(..., backend='halo') derives one from the "
                    "visible devices)")
            if self.domain.nz % self.n_shards:
                raise ValueError(
                    f"nz={self.domain.nz} not divisible by "
                    f"n_shards={self.n_shards}")
            if self.n_shards > 1 and (not self.shard_cap
                                      or self.shard_cap < 1):
                raise ValueError(
                    "a multi-shard halo plan needs a positive static "
                    "shard_cap (plan(..., positions=...) measures one)")
            if self.compact and self.strategy == "allin":
                raise ValueError(
                    "backend='halo' supports compact=True for the pencil "
                    "schedules (xpencil/cell_dense) only — the All-in-SM "
                    "sub-box occupancy is not defined per slab")
        if self.strategy == "allin" and self.box is None:
            # directly-constructed plans get the VMEM-budget sub-box too —
            # the pallas backend needs a concrete tiling at trace time.
            # Halo plans tile the *slab* each shard actually runs on.
            bdom = self.domain
            if self.backend == "halo" and self.n_shards:
                bdom = slab_domain(self.domain, self.n_shards)
            object.__setattr__(self, "box", _allin_box(bdom, self.m_c))
        if self.compact:
            if self.strategy not in ("cell_dense", "xpencil", "allin"):
                raise ValueError(
                    f"compact=True is not defined for {self.strategy!r} "
                    "(only the cell schedules have empty work units to skip)")
            if not self.max_active or self.max_active < 1:
                raise ValueError(
                    "compact=True needs a positive static max_active bound "
                    "(plan(..., positions=...) measures one)")
        if self.layout not in LAYOUT_NAMES:
            raise ValueError(
                f"unknown layout {self.layout!r}; have {LAYOUT_NAMES}")
        if self.layout == "packed":
            if self.strategy not in S.PACKED_STRATEGIES:
                raise ValueError(
                    f'layout="packed" is not defined for '
                    f"{self.strategy!r}; packed strategies: "
                    f"{sorted(S.PACKED_STRATEGIES)}")
            if not self.row_cap or self.row_cap < 1:
                raise ValueError(
                    'layout="packed" needs a positive static row_cap bound '
                    "(plan(..., positions=...) measures one)")
        if self.layout == "sfc":
            if self.strategy not in S.SFC_STRATEGIES:
                raise ValueError(
                    f'layout="sfc" is not defined for '
                    f"{self.strategy!r}; sfc strategies: "
                    f"{sorted(S.SFC_STRATEGIES)}")
            if not self.pair_cap or self.pair_cap < 1:
                raise ValueError(
                    'layout="sfc" needs a positive static pair_cap bound '
                    "(plan(..., positions=...) measures one)")

    # -- hot path ----------------------------------------------------------

    def execute(self, state: ParticleState) -> Tuple[Array, Array]:
        """-> (forces (N, 3), per-particle potential (N,)). Jitted; one
        trace per (plan, state structure). Total potential energy is
        ``0.5 * potential.sum()`` (each pair counted twice, the paper's
        convention)."""
        _count_dispatch(self)
        if not _tracing_enabled():       # zero-overhead disabled path
            return _executor(self, tuple(sorted(state.fields)))(state)
        with _obs_trace("plan.execute", backend=self.backend,
                        strategy=self.strategy, layout=self.layout,
                        n=int(state.positions.shape[0])):
            return _executor(self, tuple(sorted(state.fields)))(state)

    def execute_batch(self, states: ParticleState) -> Tuple[Array, Array]:
        """Batched hot path: one jitted vmapped call over stacked states.

        ``states`` holds B independent systems stacked on a leading axis —
        positions ``(B, N, 3)``, each field ``(B, N)`` — all sharing this
        plan's domain and ``m_c``. Binning and interaction run under one
        ``vmap`` inside a single jit trace, so B small systems (the paper's
        few-particles-per-cell regime) cost one dispatch instead of B.
        Returns ``(forces (B, N, 3), potential (B, N))``, bit-identical to
        executing each system separately."""
        _count_dispatch(self)
        if not _tracing_enabled():       # zero-overhead disabled path
            return _batch_executor(self, tuple(sorted(states.fields)))(states)
        with _obs_trace("plan.execute_batch", backend=self.backend,
                        strategy=self.strategy, layout=self.layout,
                        batch=int(states.positions.shape[0])):
            return _batch_executor(self, tuple(sorted(states.fields)))(states)

    def __call__(self, state: ParticleState) -> Tuple[Array, Array]:
        return self.execute(state)

    # -- M_C safety net ----------------------------------------------------

    def check_overflow(self, state: ParticleState) -> bool:
        """True if some static bound of this plan no longer covers these
        positions — results computed anyway would silently drop
        interactions. Which bounds exist, what each one covers and how an
        overflowed one grows is the replan contract: see :meth:`replan`
        (the canonical statement) and ARCHITECTURE.md. For halo plans the
        per-shard flags are reduced (max) across shards, keeping the
        safety contract global; everything derives from one binning
        pass. Padding rows (``state.valid`` False) are excluded — a padded
        request must never trigger a replan its real particles don't
        need."""
        return self.overflow_class(state) is not None

    def overflow_class(self, state: ParticleState) -> Optional[str]:
        """Which static bound these positions breach — ``"m_c"``,
        ``"row_cap"``, ``"shard_cap"``, ``"max_active"``, ``"injected"``
        (a chaos-forced verdict, ``repro.testing.chaos``) — or None when
        every bound holds. Same contract, one binning pass, and padding
        exclusion as :meth:`check_overflow` (which is a thin wrapper)."""
        with _obs_trace("plan.overflow_check", strategy=self.strategy,
                        layout=self.layout) as sp:
            oc = self._overflow_class(state)
            sp.set(result=oc or "ok")
        return oc

    def _overflow_class(self, state: ParticleState) -> Optional[str]:
        from ..testing import chaos
        if chaos.forced_overflow("core.binning"):
            return "injected"
        counts = _cell_counts(self.domain, state.positions, state.valid)
        if int(jnp.max(counts)) > self.m_c:
            return "m_c"
        if self.layout == "packed":
            if int(jnp.max(padded_row_counts(self.domain, counts))
                   ) > self.row_cap:
                return "row_cap"
        if self.layout == "sfc" and not self._multi_shard:
            # multi-shard sfc plans check pair_cap per shard (slab-local
            # cluster orders) inside halo_overflow_class below
            if sfc_pair_count(self.domain, counts=counts) > self.pair_cap:
                return "pair_cap"
        if self._multi_shard:
            from ..dist.engine import halo_overflow_class
            return halo_overflow_class(self, counts)
        if self.compact:
            n_act = active_unit_count(self.domain, state.positions,
                                      self.strategy, box=self.box,
                                      counts=counts)
            if n_act > self.max_active:
                return "max_active"
        return None

    @property
    def _multi_shard(self) -> bool:
        return self.backend == "halo" and (self.n_shards or 1) > 1

    def replan(self, state: ParticleState, slack: float = 1.5,
               align: int = 8) -> "InteractionPlan":
        """A new plan whose static bounds cover ``state``.

        **The replan contract** (canonical statement — ``check_overflow``,
        ``execute_or_replan``, the ``plan()`` bound arguments and the halo
        engine all defer here; prose version in ARCHITECTURE.md):

        Every static bound follows one pattern — *measure with slack,
        round up to ``align``, detect overflow, grow only what
        overflowed*. The bounds, each paired with its measuring probe:

        * ``m_c`` — max particles per cell (``suggest_m_c``),
        * ``max_active`` — active work units of a compacted plan
          (``suggest_max_active``),
        * ``row_cap`` — particles per packed pencil row of a
          ``layout="packed"`` plan (``suggest_row_cap``),
        * ``pair_cap`` — compressed cluster-pair list length of a
          ``layout="sfc"`` plan (``suggest_pair_cap``),
        * ``shard_cap`` — per-shard particle load of a multi-shard halo
          plan (``dist.halo.suggest_shard_cap``; halo plans also apply
          per-shard reductions to ``max_active``).

        Exceeding a bound makes results *silently drop* interactions, so
        bounds are never trusted blindly: ``check_overflow`` detects an
        exceeded bound from one binning pass, and this method grows
        **only the bound that actually overflowed** — re-measured with
        slack and forced strictly past the old value — so e.g. a pencil
        count outgrowing ``max_active`` does not churn ``m_c`` (and with
        it the whole slot layout) for nothing. Derived statics follow
        their inputs: the allin sub-box is recomputed whenever ``m_c``
        changes, and a compacted allin re-measures ``max_active`` against
        the new tiling. ``row_cap`` depends only on the positions, so it
        never moves when ``m_c`` does. Padding rows (``state.valid``
        False) are excluded from every measure, exactly as in
        ``check_overflow``."""
        counts = _cell_counts(self.domain, state.positions, state.valid)
        m_c = self.m_c
        mx_cell = int(jnp.max(counts))
        if mx_cell > self.m_c:
            # suggest_m_c's slack-and-align contract, applied to the
            # mask-aware counts of this one binning pass
            measured = -(-max(1, int(mx_cell * slack + 0.999)) // align
                         ) * align
            grow = -(-(self.m_c + 1) // align) * align  # aligned, > m_c
            m_c = max(measured, grow)
        box = self.box if m_c == self.m_c else None
        row_cap = self.row_cap
        if self.layout == "packed":
            mx_row = int(jnp.max(padded_row_counts(self.domain, counts)))
            if mx_row > row_cap:
                grow = -(-(row_cap + 1) // align) * align
                row_cap = max(suggest_row_cap(self.domain, state.positions,
                                              align=align, counts=counts),
                              grow)
        pair_cap = self.pair_cap
        if self.layout == "sfc":
            if self._multi_shard:
                # the bound is per shard: each slab has its own cluster
                # order, so the busiest shard's pair list sets the cap
                from ..dist.engine import shard_sfc_pairs
                n_pairs = int(max(shard_sfc_pairs(self.domain, counts,
                                                  self.n_shards)))
                suggested = -(-max(1, int(n_pairs * 1.25 + 0.999))
                              // align) * align
            else:
                n_pairs = sfc_pair_count(self.domain, counts=counts)
                suggested = suggest_pair_cap(self.domain, align=align,
                                             counts=counts)
            if n_pairs > pair_cap:
                grow = -(-(pair_cap + 1) // align) * align
                pair_cap = max(suggested, grow, n_pairs)
        max_active = self.max_active
        shard_cap = self.shard_cap
        if self._multi_shard:
            # shard-level bounds: per-shard load vs shard_cap, per-shard
            # active pencils vs max_active — grown only when exceeded
            from ..dist.engine import halo_grown_bounds
            shard_cap, max_active = halo_grown_bounds(self, state,
                                                      align=align)
        elif self.compact:
            if self.strategy == "allin" and box is None:
                # fix the new tiling first: the active-sub-box bound must
                # be measured against the grid that will actually run
                box = _allin_box(self.domain, m_c)
            n_act = active_unit_count(self.domain, state.positions,
                                      self.strategy, box=box, counts=counts)
            if n_act > max_active or box != self.box:
                suggested = suggest_max_active(self.domain, state.positions,
                                               self.strategy, box=box,
                                               align=align, counts=counts)
                max_active = max(suggested, n_act)
        grown = dataclasses.replace(self, m_c=m_c, box=box,
                                    max_active=max_active,
                                    shard_cap=shard_cap, row_cap=row_cap,
                                    pair_cap=pair_cap)
        if grown != self:                # no-op replans are not replans
            _count_replan(self)
            _obs_event("plan.replan", strategy=self.strategy,
                       layout=self.layout, m_c=grown.m_c,
                       m_c_was=self.m_c, row_cap=grown.row_cap,
                       pair_cap=grown.pair_cap,
                       max_active=grown.max_active,
                       shard_cap=grown.shard_cap)
        return grown

    def execute_or_replan(self, state: ParticleState
                          ) -> Tuple[Tuple[Array, Array], "InteractionPlan"]:
        """Overflow-safe execute: detects an exceeded static bound (outside
        jit — replanning changes statics) and re-executes under replanned
        bounds (see :meth:`replan` for the contract). Returns
        ``((forces, potential), plan)`` where ``plan`` is ``self`` when
        every bound held."""
        p: InteractionPlan = self
        while p.check_overflow(state):
            p = p.replan(state)
        return p.execute(state), p

    def execute_checked(self, state: ParticleState, *,
                        max_replans: int = 4,
                        max_retries: Optional[int] = None,
                        sleep=None
                        ) -> Tuple[Tuple[Array, Array], "ExecutionReport"]:
        """Guarded execute: never raises, always terminates, and tells you
        what happened. Returns ``((forces, potential), report)`` where the
        :class:`ExecutionReport` carries the overflow class, the
        non-finite output count (one fused ``jnp.isfinite`` reduction),
        the out-of-domain particle count, and the degradation-ladder /
        circuit-breaker trajectory; ``report.plan`` is the plan to keep
        using (replans and elastic shard shrinks applied). See
        :func:`degradation_ladder` and ARCHITECTURE.md "Resilience"."""
        return _execute_checked(self, state, max_replans=max_replans,
                                max_retries=max_retries, sleep=sleep)

    # -- fused multi-step simulation (repro.traj) --------------------------

    def trajectory(self, state, n_steps: int, dt: float, *,
                   integrator: str = "velocity_verlet",
                   skin: Optional[float] = None, **opts):
        """Run ``n_steps`` of fused bin -> force -> integrate simulation
        under one jitted ``lax.scan`` per segment, with Verlet-skin
        neighbor reuse, invariant monitors, checkpoint/rollback and
        deterministic resume. Returns a
        :class:`repro.traj.TrajectoryResult`.

        ``state`` is an ``MDState``, a ``ParticleState`` (+ optional
        ``velocities=``) or a raw ``(N, 3)`` positions array. ``skin`` is
        the Verlet margin (default: a quarter cutoff; ``0`` = re-bin
        every step, bit-identical to a per-step :meth:`execute` loop).
        Forwarded options (``checkpoint_dir``, ``checkpoint_every``,
        ``segment_len``, ``energy_budget``, ``mass``, ``gamma``/``kT``
        for the langevin integrator, ...): see
        :func:`repro.traj.engine.run_trajectory` — the engine and the
        canonical contract live there. Requires a cell schedule
        (``cell_dense`` / ``xpencil`` / ``allin``) on a single shard."""
        from ..traj.engine import run_trajectory
        return run_trajectory(self, state, n_steps, dt,
                              integrator=integrator, skin=skin, **opts)

    # -- distributed execution ---------------------------------------------

    def distribute(self, mesh=None, *, n_shards: Optional[int] = None,
                   shard_axis: Optional[str] = None,
                   positions: Optional[Array] = None,
                   shard_cap: Optional[int] = None,
                   halo_inner: Optional[str] = None) -> "InteractionPlan":
        """A halo twin of this plan: same schedule and static bounds, run
        on a device mesh (``repro.dist.engine``).

        Args:
          mesh: a ``jax.sharding.Mesh`` holding the shard axis; by default
            the engine builds a 1-D mesh over the local devices.
          n_shards: Z-slabs (must divide ``nz``); defaults to the mesh's
            shard-axis size, else the largest ``nz`` divisor that fits the
            visible devices.
          shard_axis: mesh axis name to shard along (default ``"halo"``,
            or the mesh's first axis when a mesh is given).
          positions: representative positions to measure the static
            ``shard_cap`` (and, for compacted plans, the per-shard
            ``max_active``) from; required unless ``shard_cap`` is given.
          shard_cap: explicit static per-shard particle capacity.
          halo_inner: per-shard backend; defaults to this plan's backend.
        """
        from ..dist import engine as dist_engine
        axis = shard_axis or (mesh.axis_names[0] if mesh is not None
                              else self.shard_axis)
        if mesh is not None and axis not in mesh.axis_names:
            raise ValueError(
                f"mesh has axes {mesh.axis_names}, no {axis!r} shard axis")
        if n_shards is None:
            if mesh is not None:
                n_shards = int(mesh.shape[axis])
            else:
                n_shards = dist_engine.default_n_shards(self.domain)
        inner = halo_inner or (self.halo_inner if self.backend == "halo"
                               else self.backend)
        max_active = self.max_active
        if n_shards > 1:
            if shard_cap is None:
                if positions is None:
                    raise ValueError(
                        "distribute() needs either shard_cap or positions "
                        "(to measure the per-shard capacity)")
                from ..dist.halo import suggest_shard_cap
                shard_cap = suggest_shard_cap(self.domain, positions,
                                              n_shards)
            if self.compact and positions is not None:
                from ..dist.halo import suggest_shard_max_active
                max_active = suggest_shard_max_active(self.domain,
                                                      positions, n_shards)
        box = None if self.strategy == "allin" else self.box
        return dataclasses.replace(
            self, backend="halo", halo_inner=inner, n_shards=n_shards,
            shard_axis=axis, shard_cap=shard_cap, mesh=mesh, box=box,
            max_active=max_active)

    # -- introspection -----------------------------------------------------

    def bin(self, state: ParticleState) -> CellBins:
        return bin_particles(self.domain, state.positions, state.fields,
                             m_c=self.m_c)

    def traffic_report(self, avg_ppc: float) -> "traffic.TrafficReport":
        return traffic.model(self.domain, self.m_c, avg_ppc)[self.strategy]


def plan(domain: Domain, kernel: Optional[PairKernel] = None, *,
         positions: Optional[Array] = None, m_c: Optional[int] = None,
         strategy: str = "auto", backend: str = "reference",
         batch_size: int = 64, box: Optional[Tuple[int, int, int]] = None,
         interpret: Optional[bool] = None,
         compact: bool = False, max_active: Optional[int] = None,
         layout: str = "dense", row_cap: Optional[int] = None,
         pair_cap: Optional[int] = None,
         m_c_slack: float = 1.5,
         halo_inner: str = "reference", n_shards: Optional[int] = None,
         shard_axis: str = "halo", shard_cap: Optional[int] = None,
         mesh=None) -> InteractionPlan:
    """Build an :class:`InteractionPlan` (static planning, done once).

    Every static bound taken or measured here (``m_c``, ``max_active``,
    ``row_cap``, ``shard_cap``) obeys one safety contract — measured with
    slack, overflow detectable, grown individually by
    ``execute_or_replan`` — stated once on :meth:`InteractionPlan.replan`.

    Args:
      domain: the cell grid.
      kernel: pair kernel (default Lennard-Jones).
      positions: representative positions; required when ``m_c`` is None
        (measured bound) or ``strategy="auto"`` (fill ratio for the cost
        model).
      m_c: static max-particles-per-cell bound; measured from ``positions``
        with slack + sublane alignment when omitted.
      strategy: one of ``par_part | cell_dense | xpencil | allin |
        naive_n2``; ``"auto"`` to pick the minimum modelled HBM traffic
        per interaction (``core.traffic``); or ``"autotune"`` to *measure*
        candidate schedules on ``positions`` and return the empirically
        fastest (``core.autotune``; winners persist in an on-disk cache).
      backend: ``"reference"`` (pure-JAX schedules), ``"pallas"`` (TPU
        kernels; interpret mode off-TPU), or ``"halo"`` (distributed
        Z-slab execution on a device mesh — ``repro.dist.engine``; the
        per-shard schedule runs on ``halo_inner``). With
        ``strategy="autotune"``, ``"all"`` defers to the tuner's platform
        default set (reference everywhere, plus native Pallas on TPU).
      box: All-in-SM sub-box override; sized from the VMEM budget otherwise.
      interpret: force Pallas interpret mode (None = auto by platform).
      compact: occupancy-compacted execution — iterate only work units
        (pencils / sub-boxes) that actually hold particles. Big win on
        clustered or inhomogeneous distributions; a no-op-sized overhead on
        uniform ones. ``strategy="autotune"`` explores compact candidates
        on its own and ignores this flag (and ``max_active``).
      max_active: static bound on active work units for ``compact=True``;
        measured from ``positions`` (with slack) when omitted.
      layout: slot layout the schedule reads — ``"dense"`` (every cell
        owns ``m_c`` slots), ``"packed"`` (CSR pencil rows: particles
        stored contiguously per row under ``row_cap``, bytes proportional
        to the particles instead of the padding — the few-particles-per-
        cell fix; ``xpencil`` only), or ``"sfc"`` (space-filling-curve
        cell clusters driven by a compressed cluster-pair neighbor list
        under ``pair_cap`` — the schedule itself shrinks to the occupied
        stencil pairs; ``cell_dense`` only). Composes with ``compact``
        and with ``backend="halo"``. Bit-identical to dense.
        ``strategy="autotune"`` explores packed/sfc candidates on its own
        and ignores this flag (and ``row_cap``/``pair_cap``), exactly
        like ``compact``.
      row_cap: static particles-per-packed-row bound for
        ``layout="packed"``; measured from ``positions`` (with slack)
        when omitted.
      pair_cap: static compressed-pair-list bound for ``layout="sfc"``;
        measured from ``positions`` (with slack) when omitted.
      halo_inner: per-shard backend for ``backend="halo"``
        (``"reference"``/``"pallas"``).
      n_shards: Z-slab count for ``backend="halo"`` (must divide ``nz``);
        defaults to the largest divisor of ``nz`` that fits the visible
        devices (1 on a single device — the bit-identical fallback).
      shard_axis / mesh: mesh axis name and an optional explicit
        ``jax.sharding.Mesh``; by default the engine builds a 1-D mesh
        over the local devices.
      shard_cap: static per-shard particle capacity for ``backend="halo"``;
        measured from ``positions`` (with slack) when omitted.
    """
    kernel = kernel or make_lennard_jones()
    if strategy == "autotune":
        from . import autotune
        if positions is None:
            raise ValueError('strategy="autotune" needs positions (the '
                             "tuner times real executions)")
        if backend == "halo":
            # the tuner owns the shard-count axis: fall back to the
            # platform default backends and let halo twins join the sweep
            backend = "all"
        backends = None if backend == "all" else (backend,)
        # the caller's batch_size/box join the sweep as candidates rather
        # than pinning it — the stopwatch gets the final word
        batch_sizes = tuple(dict.fromkeys(
            (batch_size, *autotune.DEFAULT_BATCH_SIZES)))
        return autotune.tune(domain, kernel, positions, m_c=m_c,
                             backends=backends, batch_sizes=batch_sizes,
                             box=box, m_c_slack=m_c_slack,
                             interpret=interpret).plan
    if m_c is None:
        if positions is None:
            raise ValueError("plan() needs either m_c or positions "
                             "(to measure the M_C bound)")
        from .engine import suggest_m_c
        m_c = suggest_m_c(domain, positions, slack=m_c_slack)
    if strategy == "auto":
        if positions is None:
            raise ValueError('strategy="auto" needs positions (the cost '
                             "model is parameterized by the fill ratio)")
        # compact=True narrows the choice to the cell schedules that have a
        # compacted path — otherwise whether auto+compact works would
        # depend on which strategy the cost model happens to pick. The halo
        # decomposition only exists for cell schedules (compacted halo:
        # pencil schedules only). layout="packed" narrows further to the
        # packed-capable schedules.
        among = (("cell_dense", "xpencil", "allin") if compact else None)
        if backend == "halo":
            among = (("cell_dense", "xpencil") if compact
                     else ("cell_dense", "xpencil", "allin"))
        if layout == "packed":
            among = tuple(S.PACKED_STRATEGIES)
        if layout == "sfc":
            among = tuple(S.SFC_STRATEGIES)
        strategy = choose_strategy(domain, m_c,
                                   positions.shape[0] / domain.n_cells,
                                   among=among)
    inner_backend = halo_inner if backend == "halo" else backend
    if backend == "halo":
        from ..dist import engine as dist_engine
        from ..dist.halo import suggest_shard_cap
        if mesh is not None and shard_axis not in mesh.axis_names:
            raise ValueError(
                f"mesh has axes {mesh.axis_names}, no {shard_axis!r} "
                "shard axis — pass shard_axis=<one of them> (or use "
                "plan.distribute(mesh), which defaults to the mesh's "
                "first axis)")
        if n_shards is None:
            if mesh is not None:
                n_shards = int(mesh.shape[shard_axis])
            else:
                n_shards = dist_engine.default_n_shards(domain)
        if n_shards > 1 and shard_cap is None:
            if positions is None:
                raise ValueError("backend='halo' needs either shard_cap or "
                                 "positions (to measure the per-shard "
                                 "capacity)")
            shard_cap = suggest_shard_cap(domain, positions, n_shards)
    if layout == "packed":
        if not supports_layout(inner_backend, strategy, "packed"):
            raise ValueError(
                f"backend {inner_backend!r} has no packed path for "
                f"strategy {strategy!r}; packed-capable pairs: "
                f"{sorted(k[:2] for k in _BACKENDS if k[2] == 'packed')}")
        if row_cap is None:
            if positions is None:
                raise ValueError('layout="packed" needs either row_cap or '
                                 "positions (to measure the packed-row "
                                 "bound)")
            row_cap = suggest_row_cap(domain, positions)
    if layout == "sfc":
        if not supports_layout(inner_backend, strategy, "sfc"):
            raise ValueError(
                f"backend {inner_backend!r} has no sfc path for "
                f"strategy {strategy!r}; sfc-capable pairs: "
                f"{sorted(k[:2] for k in _BACKENDS if k[2] == 'sfc')}")
        if pair_cap is None:
            if positions is None:
                raise ValueError('layout="sfc" needs either pair_cap or '
                                 "positions (to measure the pair-list "
                                 "bound)")
            if backend == "halo" and n_shards > 1:
                # per-shard bound: each slab has its own cluster order,
                # so the busiest shard's measured pair list sets the cap
                from ..dist.engine import shard_sfc_pairs
                counts_ = _cell_counts(domain, positions)
                n_pairs = int(max(shard_sfc_pairs(domain, counts_,
                                                  n_shards)))
                pair_cap = -(-max(1, int(n_pairs * 1.25 + 0.999)) // 8) * 8
            else:
                pair_cap = suggest_pair_cap(domain, positions)
    if compact:
        if not supports_compact(inner_backend, strategy, layout):
            raise ValueError(
                f"backend {inner_backend!r} has no compacted path for "
                f"strategy {strategy!r} (layout {layout!r}); "
                f"compact-capable triples: {sorted(_COMPACT_OK)}")
        if max_active is None:
            if positions is None:
                raise ValueError("compact=True needs either max_active or "
                                 "positions (to measure the active-unit "
                                 "bound)")
            if backend == "halo" and n_shards > 1:
                # one static bound shared by all shards: the busiest
                # shard's active pencils, not the global count
                from ..dist.halo import suggest_shard_max_active
                max_active = suggest_shard_max_active(domain, positions,
                                                      n_shards)
            else:
                mbox = box
                if strategy == "allin" and mbox is None:
                    mbox = _allin_box(domain, m_c)
                max_active = suggest_max_active(domain, positions, strategy,
                                                box=mbox)
    p = InteractionPlan(domain=domain, kernel=kernel, m_c=m_c,
                        strategy=strategy, backend=backend,
                        batch_size=batch_size, box=box, interpret=interpret,
                        compact=compact, max_active=max_active,
                        layout=layout, row_cap=row_cap, pair_cap=pair_cap,
                        halo_inner=halo_inner, n_shards=n_shards,
                        shard_axis=shard_axis, shard_cap=shard_cap,
                        mesh=mesh)
    if strategy != "naive_n2":
        # fail at plan time, not execute time (halo validates the
        # per-shard backend the slab schedule will actually dispatch to)
        get_backend(inner_backend, strategy, layout)
    return p


def choose_strategy(domain: Domain, m_c: int, avg_ppc: float,
                    among: Optional[Tuple[str, ...]] = None) -> str:
    """``strategy="auto"``: minimize modelled HBM bytes per interaction.

    The paper's Fig. 7 argument as a decision rule — the schedule that moves
    the fewest global-memory bytes per interaction wins in the memory-bound
    regime the paper targets. Ties break toward the paper's X-pencil.
    ``among`` restricts the choice (e.g. to the compact-capable schedules).
    """
    reports = traffic.model(domain, m_c, max(avg_ppc, 1e-3))
    order = {"xpencil": 0, "allin": 1, "cell_dense": 2, "par_part": 3}
    pool = [r for r in reports.values() if among is None or r.strategy in among]
    return min(pool,
               key=lambda r: (r.hbm_bytes_per_interaction,
                              order[r.strategy])).strategy


def _allin_box(domain: Domain, m_c: int) -> Tuple[int, int, int]:
    """VMEM-budget sub-box, shrunk to divisors of the grid (static)."""
    return S.shrink_to_divisors(domain, S.subbox_dims(domain, m_c))


_cell_counts = cell_counts          # binning owns the single binning pass


def _max_cell_count(domain: Domain, positions: Array) -> Array:
    return jnp.max(_cell_counts(domain, positions))


def active_unit_count(domain: Domain, positions: Array,
                      strategy: str = "xpencil",
                      box: Optional[Tuple[int, int, int]] = None,
                      counts: Optional[Array] = None) -> int:
    """Number of active work units — (z, y) pencils (``xpencil`` /
    ``cell_dense``) or sub-boxes (``allin``, for the given tiling) — that
    hold at least one particle. One-off (outside jit) occupancy probe;
    pass precomputed per-cell ``counts`` to skip the binning pass."""
    if counts is None:
        counts = _cell_counts(domain, positions)
    if strategy == "allin":
        if box is None:
            box = _allin_box(domain, 1)
        box = S.shrink_to_divisors(domain, box)
        uc = subbox_counts(domain, counts, box)
    else:
        uc = pencil_counts(domain, counts)
    return int(jnp.sum(uc > 0))


def n_units(domain: Domain, strategy: str = "xpencil",
            box: Optional[Tuple[int, int, int]] = None) -> int:
    """Total work units of a schedule (denominator of the fill fraction)."""
    if strategy == "allin":
        if box is None:
            box = _allin_box(domain, 1)
        bx, by, bz = S.shrink_to_divisors(domain, box)
        return (domain.nx // bx) * (domain.ny // by) * (domain.nz // bz)
    return domain.nz * domain.ny


def suggest_max_active(domain: Domain, positions: Array,
                       strategy: str = "xpencil",
                       box: Optional[Tuple[int, int, int]] = None,
                       slack: float = 1.25, align: int = 8,
                       counts: Optional[Array] = None) -> int:
    """One-off static ``max_active`` bound: measured active units with
    slack, rounded up to ``align``, clipped to the total unit count (a full
    bound degrades gracefully to dense coverage). The compacted-path
    counterpart of ``suggest_m_c``. Pass precomputed per-cell ``counts``
    to skip the binning pass (or to exclude masked padding rows)."""
    n_act = active_unit_count(domain, positions, strategy, box=box,
                              counts=counts)
    total = n_units(domain, strategy, box=box)
    bound = max(1, int(n_act * slack + 0.999))
    bound = -(-bound // align) * align
    return min(bound, total)


def suggest_row_cap(domain: Domain, positions: Array, slack: float = 1.25,
                    align: int = 8, counts: Optional[Array] = None) -> int:
    """One-off static ``row_cap`` bound for ``layout="packed"``: the
    fullest *padded* pencil row (interior particles plus periodic X-ghost
    copies — ``binning.padded_row_counts``) with slack, rounded up to
    ``align`` (sublane contract). The packed-layout counterpart of
    ``suggest_m_c``; obeys the replan contract
    (:meth:`InteractionPlan.replan`). Pass precomputed per-cell ``counts``
    to skip the binning pass."""
    if counts is None:
        counts = _cell_counts(domain, positions)
    mx = int(jnp.max(padded_row_counts(domain, counts)))
    cap = max(1, int(mx * slack + 0.999))
    return -(-cap // align) * align


def suggest_pair_cap(domain: Domain, positions: Optional[Array] = None,
                     slack: float = 1.25, align: int = 8,
                     counts: Optional[Array] = None) -> int:
    """One-off static ``pair_cap`` bound for ``layout="sfc"``: the measured
    compressed cluster-pair list length (``binning.sfc_pair_count``) with
    slack, rounded up to ``align``, clipped to the all-pairs total
    ``n_clusters * 27`` (the bound degrades gracefully to the dense
    stencil). The SFC-layout counterpart of ``suggest_row_cap``; obeys the
    replan contract (:meth:`InteractionPlan.replan`). Pass precomputed
    per-cell ``counts`` to skip the binning pass."""
    n_pairs = sfc_pair_count(domain, positions, counts=counts)
    cap = max(1, int(n_pairs * slack + 0.999))
    cap = -(-cap // align) * align
    return max(min(cap, sfc_n_clusters(domain) * 27), n_pairs)


# --------------------------------------------------------------------------
# execution (jitted per plan)
# --------------------------------------------------------------------------

# Dispatch accounting: incremented once per execute/execute_batch call (i.e.
# per jitted dispatch, not per traced system). Lets tests and benchmarks
# assert that the batched path really amortizes dispatch — B systems through
# ``execute_batch`` move this by 1, a Python loop moves it by B.
#
# Recompile accounting: incremented every time an executor *body* is traced
# (the Python body of a jitted function runs at trace time only, so a
# counter bump inside it counts traces, not calls). The serving tier's
# steady-state guarantee — "a warm engine never recompiles" — is asserted
# against this counter instead of scraping JAX internals.
#
# Both live in the process metrics registry (``repro.obs``), labeled by
# (backend, strategy, layout) when the caller has a plan in hand; the
# functions below are the historical unlabeled views (registry-wide sums),
# so every pre-existing assertion keeps its semantics while
# ``obs.render_prom()`` exposes the labeled families.
DISPATCH_TOTAL = "repro_dispatch_total"
RECOMPILE_TOTAL = "repro_recompile_total"
REPLAN_TOTAL = "repro_replan_total"

# live Counter instances keyed by (name, backend, strategy, layout) — a
# registry ``reset()`` zeroes them in place, so the cache never goes stale
_metric_cache: Dict[tuple, _obs_metrics.Counter] = {}


def _plan_counter(name: str,
                  p: Optional["InteractionPlan"]) -> _obs_metrics.Counter:
    key = (name,) if p is None else (name, p.backend, p.strategy, p.layout)
    c = _metric_cache.get(key)
    if c is None:
        labels = ({} if p is None else
                  {"backend": p.backend, "strategy": p.strategy,
                   "layout": p.layout})
        c = _metric_cache[key] = _obs_metrics.registry.counter(name, **labels)
    return c


def dispatch_count() -> int:
    return int(_obs_metrics.registry.total(DISPATCH_TOTAL))


def recompile_count() -> int:
    """Executor traces so far (see the accounting note above): moves only
    when a jitted executor body is (re-)traced — a new plan, a new state
    structure/shape, or an LRU-evicted executor being rebuilt."""
    return int(_obs_metrics.registry.total(RECOMPILE_TOTAL))


def replan_count() -> int:
    """Replans so far: ``plan.replan`` calls that actually grew a bound."""
    return int(_obs_metrics.registry.total(REPLAN_TOTAL))


def reset_counters() -> None:
    """Zero every steady-state counter in the metrics registry — dispatch,
    recompile, replan, *and* cross-module counters like the autotuner's
    ``timing_run_count`` — in one call (test/benchmark bookkeeping; the
    executor caches themselves are untouched). Historically this cleared
    only dispatch/recompile and silently left ``autotune.timing_run_count``
    running; routing everything through ``obs.registry.reset()`` closes
    that footgun."""
    _obs_metrics.registry.reset()


def _count_dispatch(p: Optional["InteractionPlan"] = None) -> None:
    _plan_counter(DISPATCH_TOTAL, p).inc()


def _count_recompile(p: Optional["InteractionPlan"] = None) -> None:
    _plan_counter(RECOMPILE_TOTAL, p).inc()


def _count_replan(p: Optional["InteractionPlan"] = None) -> None:
    _plan_counter(REPLAN_TOTAL, p).inc()


def _impl(p: InteractionPlan) -> Callable:
    """The traced executor body shared by the single and batched paths."""

    if p._multi_shard:
        # distributed halo execution: partition -> shard_map(bin + ghost
        # exchange + local schedule) -> scatter-back (repro.dist.engine)
        from ..dist.engine import halo_impl
        inner = halo_impl(p)

        def halo_counted(state: ParticleState) -> Tuple[Array, Array]:
            _count_recompile(p)          # runs at trace time only
            return inner(state)
        return halo_counted

    # a single-shard halo plan runs the inner backend directly — no mesh,
    # no exchange: the bit-identical single-device fallback
    backend = p.halo_inner if p.backend == "halo" else p.backend

    def impl(state: ParticleState) -> Tuple[Array, Array]:
        _count_recompile(p)              # runs at trace time only
        if p.strategy == "naive_n2":
            if state.valid is not None:
                raise ValueError(
                    "naive_n2 bypasses binning and cannot mask padded "
                    "(valid=) rows; use a cell schedule")
            fx, fy, fz, pot = S.naive_n2(p.domain, state.positions, p.kernel)
            return jnp.stack([fx, fy, fz], axis=-1), pot
        bins = bin_particles(p.domain, state.positions, state.fields,
                             m_c=p.m_c, valid=state.valid)
        if p.layout == "packed":
            packed = pack_rows(p.domain, bins, row_cap=p.row_cap)
            return get_backend(backend, p.strategy, "packed")(p, packed,
                                                              state)
        if p.layout == "sfc":
            sfc = build_sfc_clusters(p.domain, bins, pair_cap=p.pair_cap)
            return get_backend(backend, p.strategy, "sfc")(p, sfc, state)
        return get_backend(backend, p.strategy)(p, bins, state)

    return impl


_CacheInfo = collections.namedtuple(
    "CacheInfo", ["hits", "misses", "maxsize", "currsize"])


class _LRU:
    """A ``functools.lru_cache`` stand-in whose capacity can be resized.

    Same observable surface as the stdlib decorator (``cache_info()`` /
    ``cache_clear()``), plus :meth:`resize` so tests can shrink the cache
    and exercise eviction + re-admission without building 100+ plans. Kept
    bounded (not unbounded) because the autotuner times throwaway
    candidate plans by the dozen, and an unbounded cache would pin every
    one of their traces (and compiled executables) for the process
    lifetime.
    """

    def __init__(self, maxsize: int, build: Callable):
        self._build = build
        self._maxsize = maxsize
        self._data: "collections.OrderedDict" = collections.OrderedDict()
        self._hits = 0
        self._misses = 0

    def __call__(self, *key):
        if key in self._data:
            self._hits += 1
            self._data.move_to_end(key)
            return self._data[key]
        self._misses += 1
        value = self._build(*key)
        self._data[key] = value
        self._evict()
        return value

    def _evict(self) -> None:
        while len(self._data) > self._maxsize:
            self._data.popitem(last=False)

    def resize(self, maxsize: int) -> None:
        """Change the capacity; excess (least-recent) entries are evicted
        immediately. Evicting a live executor only costs a retrace on its
        next use — never correctness (tests/test_serve.py proves it)."""
        if maxsize < 1:
            raise ValueError(f"maxsize must be >= 1, got {maxsize}")
        self._maxsize = maxsize
        self._evict()

    def cache_info(self) -> "_CacheInfo":
        return _CacheInfo(self._hits, self._misses, self._maxsize,
                          len(self._data))

    def cache_clear(self) -> None:
        self._data.clear()
        self._hits = 0
        self._misses = 0


def _build_executor(p: InteractionPlan,
                    field_names: Tuple[str, ...]) -> Callable:
    """One jitted executor per (plan, state structure)."""
    return jax.jit(_impl(p))


def _build_batch_executor(p: InteractionPlan,
                          field_names: Tuple[str, ...]) -> Callable:
    """One jitted executor per (plan, state structure) for stacked states."""
    impl = _impl(p)
    if p._multi_shard:
        # vmap cannot batch through shard_map's collectives; lax.map keeps
        # the contract that matters — B systems, one jitted dispatch,
        # bit-identical to the per-state loop
        return jax.jit(lambda states: jax.lax.map(impl, states))
    return jax.jit(jax.vmap(impl))


_executor = _LRU(128, _build_executor)
_batch_executor = _LRU(32, _build_batch_executor)


def clear_executor_cache() -> None:
    """Drop every cached executor trace (single and batched)."""
    _executor.cache_clear()
    _batch_executor.cache_clear()


def set_executor_cache_size(single: Optional[int] = None,
                            batch: Optional[int] = None) -> None:
    """Resize the executor LRUs (excess entries evicted immediately).

    Serving deployments with many live shape classes can raise the bounds;
    tests shrink them to force eviction. Eviction is a latency event, never
    a correctness one — a rebuilt executor retraces the same plan."""
    if single is not None:
        _executor.resize(single)
    if batch is not None:
        _batch_executor.resize(batch)


def executor_cache_info() -> Dict[str, "_CacheInfo"]:
    """Observability hook: ``{"single": CacheInfo, "batch": CacheInfo}``
    (hits / misses / maxsize / currsize, stdlib ``lru_cache`` schema)."""
    return {"single": _executor.cache_info(),
            "batch": _batch_executor.cache_info()}


# --------------------------------------------------------------------------
# guarded execution: ExecutionReport, degradation ladder, circuit breaker
# --------------------------------------------------------------------------

# The resilience layer's core contract: ``plan.execute_checked`` never
# raises and never hangs. Failures (transient backend errors, non-finite
# outputs, injected chaos — repro.testing.chaos) are absorbed by a
# per-plan circuit breaker with hysteresis: _FAILURE_THRESHOLD consecutive
# failures step one rung DOWN the degradation ladder
# (pallas -> reference backend, then packed -> compact -> dense layout);
# _RECOVERY_THRESHOLD consecutive clean executions step one rung back UP.
# Every rung is bit-identical to the healthy path by construction (the
# repo-wide parity guarantee), so degradation costs latency, never
# answers — tests/test_chaos.py parity-checks it.

_FAILURE_THRESHOLD = 3     # consecutive failures to trip one rung down
_RECOVERY_THRESHOLD = 8    # consecutive clean calls to climb one rung up


@dataclasses.dataclass
class PlanHealth:
    """Per-plan circuit-breaker state (see the note above). ``level``
    indexes into :func:`degradation_ladder`; 0 = healthy."""

    level: int = 0
    consec_failures: int = 0
    consec_clean: int = 0
    trips: int = 0             # lifetime rung-down transitions
    recoveries: int = 0        # lifetime rung-up transitions

    def note_failure(self, n_rungs: int) -> bool:
        """Record one failed execution; True if the breaker tripped a
        rung down (hysteresis: the failure streak resets on the trip)."""
        self.consec_clean = 0
        self.consec_failures += 1
        if (self.consec_failures >= _FAILURE_THRESHOLD
                and self.level < n_rungs - 1):
            self.level += 1
            self.trips += 1
            self.consec_failures = 0
            return True
        return False

    def note_success(self) -> bool:
        """Record one clean execution; True if the breaker recovered a
        rung up (after _RECOVERY_THRESHOLD consecutive clean calls)."""
        self.consec_failures = 0
        self.consec_clean += 1
        if self.level > 0 and self.consec_clean >= _RECOVERY_THRESHOLD:
            self.level -= 1
            self.recoveries += 1
            self.consec_clean = 0
            return True
        return False


@dataclasses.dataclass
class ExecutionReport:
    """What one :meth:`InteractionPlan.execute_checked` call observed.

    ``status`` is ``"ok"`` (healthy rung, clean), ``"degraded"`` (results
    from a lower ladder rung — still bit-identical) or ``"failed"``
    (every rung exhausted; forces/potential are zeros). ``plan`` is the
    plan to keep using — replans and elastic shard shrinks applied."""

    status: str = "ok"
    plan: Optional[InteractionPlan] = None
    overflow: Optional[str] = None     # bound class that overflowed
    replans: int = 0                   # bound-growth events this call
    retries: int = 0                   # extra execution attempts
    nonfinite: int = 0                 # non-finite output elements seen
    out_of_domain: int = 0             # valid particles outside the box
    faults: List[str] = dataclasses.field(default_factory=list)
    ladder_level: int = 0              # rung that produced the result
    backend: str = ""                  # backend of that rung
    layout: str = ""                   # layout of that rung
    breaker_trips: int = 0             # rung-down transitions this call
    recovered: bool = False            # rung-up transition this call
    shard_shrinks: int = 0             # elastic mesh shrinks this call


def _health_key(p: InteractionPlan) -> Tuple:
    """Breaker identity: the plan minus its grown/derived bounds, so a
    replan (grown m_c/row_cap/...) or an elastic shard shrink keeps the
    same breaker state instead of resetting to healthy."""
    return (p.domain, p.kernel, p.strategy, p.backend, p.halo_inner,
            p.layout, p.compact, p.batch_size, p.interpret)


_health: Dict[Tuple, PlanHealth] = {}


def plan_health(p: InteractionPlan) -> PlanHealth:
    """The live circuit-breaker state for a plan (created healthy on
    first access). Observability + test hook."""
    return _health.setdefault(_health_key(p), PlanHealth())


def reset_health() -> None:
    """Forget every plan's breaker state (test bookkeeping)."""
    _health.clear()


def degradation_ladder(p: InteractionPlan) -> Tuple[InteractionPlan, ...]:
    """The rungs ``execute_checked`` steps down under repeated failure:
    the plan itself, then backend pallas -> reference, then layout
    packed/sfc -> compact -> dense. Every rung computes bit-identical
    results — only cost and code path change. Rung 0 is always ``p``;
    plans already on the reference/dense path have a one-rung ladder."""
    rungs = [p]
    q = p
    inner = q.halo_inner if q.backend == "halo" else q.backend
    if inner == "pallas":
        if q.backend == "halo":
            q = dataclasses.replace(q, halo_inner="reference")
        else:
            q = dataclasses.replace(q, backend="reference")
        rungs.append(q)
    if q.layout in ("packed", "sfc"):
        q = dataclasses.replace(q, layout="dense")
        rungs.append(q)
    if q.compact:
        q = dataclasses.replace(q, compact=False)
        rungs.append(q)
    return tuple(rungs)


def fallback_plan(p: InteractionPlan) -> InteractionPlan:
    """The most-degraded rung (reference backend, dense layout) — the
    serving tier quarantines a broken shape class onto this plan."""
    return degradation_ladder(p)[-1]


@functools.partial(jax.jit, static_argnames=("box",))
def _output_check(forces: Array, pot: Array, positions: Array,
                  valid: Optional[Array], box: Tuple[float, float, float]):
    """One fused reduction over the outputs: (non-finite force/potential
    elements, valid particles outside the domain box). Padding rows are
    excluded from both counts."""
    if valid is None:
        fmask = jnp.ones(forces.shape[:-1], bool)
    else:
        fmask = valid
    bad = (jnp.sum(jnp.where(fmask[..., None], ~jnp.isfinite(forces), False))
           + jnp.sum(jnp.where(fmask, ~jnp.isfinite(pot), False)))
    lim = jnp.asarray(box, positions.dtype)
    ood = jnp.any((positions < 0.0) | (positions > lim), axis=-1)
    ood = jnp.sum(jnp.where(fmask, ood, False))
    return bad, ood


class _NonFiniteOutput(RuntimeError):
    """Internal: an execution produced non-finite forces/potential."""

    def __init__(self, count: int):
        super().__init__(f"{count} non-finite output element(s)")
        self.count = int(count)


def _execute_checked(base: InteractionPlan, state: ParticleState, *,
                     max_replans: int = 4,
                     max_retries: Optional[int] = None,
                     sleep=None
                     ) -> Tuple[Tuple[Array, Array], "ExecutionReport"]:
    """The guarded-dispatch engine behind ``plan.execute_checked``."""
    with _obs_trace("plan.execute_checked", backend=base.backend,
                    strategy=base.strategy, layout=base.layout) as sp:
        out, report = _execute_checked_impl(base, state,
                                            max_replans=max_replans,
                                            max_retries=max_retries,
                                            sleep=sleep)
        sp.set(status=report.status, overflow=report.overflow or "none",
               replans=report.replans, retries=report.retries,
               ladder_level=report.ladder_level)
        return out, report


def _execute_checked_impl(base: InteractionPlan, state: ParticleState, *,
                          max_replans: int = 4,
                          max_retries: Optional[int] = None,
                          sleep=None
                          ) -> Tuple[Tuple[Array, Array], "ExecutionReport"]:
    from ..testing import chaos

    report = ExecutionReport(plan=base)
    p = base

    # 1. bounded replan loop — an injected overflow verdict with nothing
    # to grow must not storm (replan returns an equal plan; stop).
    for _ in range(max_replans):
        oc = p.overflow_class(state)
        if oc is None:
            break
        report.overflow = report.overflow or oc
        grown = p.replan(state)
        report.replans += 1
        if grown == p:
            break
        p = grown
    report.plan = p

    rungs = degradation_ladder(p)
    health = plan_health(p)
    level = min(health.level, len(rungs) - 1)
    if max_retries is None:
        max_retries = _FAILURE_THRESHOLD * len(rungs)
    attempts = 0

    forces = pot = None
    while True:
        rung = rungs[level]
        try:
            if sleep is None:
                chaos.maybe_delay("core.dispatch")
            else:
                chaos.maybe_delay("core.dispatch", sleep=sleep)
            if rung._multi_shard:
                chaos.maybe_raise("dist.exchange")
            chaos.maybe_raise("core.dispatch")
            f, u = rung.execute(state)
            f = chaos.corrupt("core.dispatch", f)
            bad, ood = _output_check(f, u, state.positions, state.valid,
                                     p.domain.box)
            report.out_of_domain = int(ood)
            if int(bad):
                report.nonfinite += int(bad)
                raise _NonFiniteOutput(int(bad))
            forces, pot = f, u
        except chaos.ShardLost as e:
            report.faults.append(f"shard_loss:{e}")
            if rung._multi_shard:
                # elastic shrink: rebuild at the surviving shard count and
                # re-execute — the existing replan contract re-measures
                # the per-shard bounds (dist.engine.elastic_shrink)
                from ..dist.engine import elastic_shrink
                p = elastic_shrink(p, state)
                report.plan = p
                report.shard_shrinks += 1
                _obs_event("plan.shard_shrink", n_shards=p.n_shards or 1,
                           fault=str(e))
                rungs = degradation_ladder(p)
                health = plan_health(p)      # same key: shrink-stable
                level = min(level, len(rungs) - 1)
            elif health.note_failure(len(rungs)):
                report.breaker_trips += 1
                level = health.level
                _obs_event("plan.degrade", level=level,
                           backend=rungs[level].backend,
                           layout=rungs[level].layout, fault=str(e))
        except (chaos.TransientBackendError, _NonFiniteOutput,
                RuntimeError, ValueError) as e:
            report.faults.append(f"{type(e).__name__}: {e}")
            if health.note_failure(len(rungs)):
                report.breaker_trips += 1
                level = health.level
                _obs_event("plan.degrade", level=level,
                           backend=rungs[level].backend,
                           layout=rungs[level].layout,
                           fault=type(e).__name__)
        else:
            break                              # clean execution
        attempts += 1
        report.retries = attempts
        if attempts > max_retries:
            report.status = "failed"
            report.ladder_level = level
            report.backend = rung.backend
            report.layout = rung.layout
            zeros = jnp.zeros_like(state.positions)
            return (zeros, jnp.zeros(state.positions.shape[:-1],
                                     state.positions.dtype)), report

    report.recovered = health.note_success()
    if report.recovered:
        _obs_event("plan.recover", level=level,
                   backend=rungs[level].backend)
    report.ladder_level = level
    report.backend = rungs[level].backend
    report.layout = rungs[level].layout
    report.status = "ok" if level == 0 else "degraded"
    return (forces, pot), report


# --------------------------------------------------------------------------
# reference backend: the pure-JAX schedules of core.strategies
# --------------------------------------------------------------------------

@register_backend("reference", "par_part")
def _ref_par_part(p: InteractionPlan, bins: CellBins, state: ParticleState):
    fx, fy, fz, pot = S.par_part(p.domain, bins, state.positions, p.kernel,
                                 p.batch_size)
    return jnp.stack([fx, fy, fz], axis=-1), pot


def _ref_dense(name):
    """Reference cell-schedule backend: dense sweep, or the occupancy-
    compacted variant when the plan asks for it (``plan.compact``)."""
    dense_fn = S.STRATEGIES[name]
    sparse_fn = S.SPARSE_STRATEGIES[name]

    def impl(p: InteractionPlan, bins: CellBins, state: ParticleState):
        if p.compact:
            if name == "allin":
                box = S.shrink_to_divisors(p.domain, p.box)
                occ = subbox_occupancy(p.domain, bins.counts, box,
                                       p.max_active)
                out = sparse_fn(p.domain, bins, p.kernel, occ, box,
                                batch_size=p.batch_size)
            else:
                occ = pencil_occupancy(p.domain, bins.counts, p.max_active)
                out = sparse_fn(p.domain, bins, p.kernel, occ,
                                batch_size=p.batch_size)
        else:
            kwargs = {"batch_size": p.batch_size}
            if name == "allin":
                kwargs["box"] = p.box
            out = dense_fn(p.domain, bins, p.kernel, **kwargs)
        return dense_to_particles(p.domain, bins, *out)
    return impl


register_backend("reference", "cell_dense", compact=True)(
    _ref_dense("cell_dense"))
register_backend("reference", "xpencil", compact=True)(_ref_dense("xpencil"))
register_backend("reference", "allin", compact=True)(_ref_dense("allin"))


@register_backend("reference", "xpencil", compact=True, layout="packed")
def _ref_xpencil_packed(p: InteractionPlan, packed: PackedRows,
                        state: ParticleState):
    """Packed-row reference backend: CSR rows, active-list iteration when
    the plan is compacted, identity active list otherwise."""
    occ = (pencil_occupancy(p.domain, packed.counts, p.max_active)
           if p.compact else full_pencil_occupancy(p.domain))
    out = S.xpencil_packed(p.domain, packed, p.kernel, occ,
                           batch_size=p.batch_size)
    return packed_to_particles(p.domain, packed, *out)


@register_backend("reference", "cell_dense", compact=True, layout="sfc")
def _ref_cell_sfc(p: InteractionPlan, sfc: SfcClusters,
                  state: ParticleState):
    """SFC cluster reference backend. ``compact=True`` is accepted as a
    no-op: the compressed pair list *is* the occupancy compaction (empty
    neighborhoods never enter ``codes``), so the compacted plan runs the
    same schedule and stays bit-identical by construction."""
    out = S.cell_sfc(p.domain, sfc, p.kernel, batch_size=p.batch_size)
    return sfc_to_particles(p.domain, sfc, *out)
