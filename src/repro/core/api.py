"""Plan/execute interaction API — the front door to every schedule + backend.

The paper's subject is choosing among interchangeable schedules (Par-Part,
Par-Cell, X-pencil, All-in-SM) for the same cutoff interaction. This module
separates that choice (static, made once) from the traced computation (made
every step):

    state = ParticleState(positions)                        # traced pytree
    p = plan(domain, kernel, positions=positions,           # static choices
             strategy="auto", backend="pallas")
    forces, potential = p.execute(state)                    # jitted hot path
    (forces, potential), p = p.execute_or_replan(state)     # + M_C safety net

Three layers:

  ``ParticleState``    the universal traced input: positions plus optional
                       per-particle fields (velocity, mass, ...).
  ``InteractionPlan``  all static choices — domain, kernel, ``m_c``,
                       strategy, backend, batch/grid sizing — hashable, so
                       one jit trace per distinct plan. ``strategy="auto"``
                       is driven by the ``core.traffic`` cost model.
  backend registry     one normalized signature
                       ``(plan, bins, state) -> (forces (N,3), pot (N,))``
                       under which the pure-JAX references
                       (``core.strategies``) and the Pallas kernels
                       (``repro.kernels``) register per strategy name, so
                       ``backend="pallas"`` routes ``xpencil``/``allin``
                       through the same front door as their oracles.

``CellListEngine`` / ``compute_interactions`` in ``core.engine`` are thin
compatibility shims over this module.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import strategies as S
from . import traffic
from .binning import CellBins, bin_particles, dense_to_particles
from .domain import Domain
from .interactions import PairKernel, make_lennard_jones

Array = jnp.ndarray

STRATEGY_NAMES = ("par_part", "cell_dense", "xpencil", "allin")


# --------------------------------------------------------------------------
# traced input
# --------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ParticleState:
    """The universal traced input: positions + optional per-particle fields.

    ``fields`` maps names ("vx", "mass", ...) to (N,) arrays that are binned
    alongside x/y/z so schedules can read them per slot. The dict's *keys*
    are static (part of the trace); the values are traced.
    """

    positions: Array                                   # (N, 3)
    fields: Dict[str, Array] = dataclasses.field(default_factory=dict)

    @property
    def n(self) -> int:
        return self.positions.shape[0]


# --------------------------------------------------------------------------
# backend registry
# --------------------------------------------------------------------------

# (backend, strategy) -> fn(plan, bins, state) -> (forces (N, 3), pot (N,))
_BACKENDS: Dict[Tuple[str, str], Callable] = {}


def register_backend(backend: str, strategy: str):
    """Register an implementation under ``(backend, strategy)``.

    The implementation receives the (static) plan, the binned slot layout,
    and the traced state, and must return per-particle ``(forces, pot)`` —
    the one normalized signature both the reference schedules and the Pallas
    kernels conform to.
    """
    def deco(fn: Callable) -> Callable:
        _BACKENDS[(backend, strategy)] = fn
        return fn
    return deco


def get_backend(backend: str, strategy: str) -> Callable:
    if backend == "pallas":
        # Pallas implementations self-register on import; make sure the
        # module ran before declaring the combination missing.
        import repro.kernels  # noqa: F401
    fn = _BACKENDS.get((backend, strategy))
    if fn is None:
        import repro.kernels  # noqa: F401  (list *all* backends in the error)
        fn = _BACKENDS.get((backend, strategy))
    if fn is None:
        have = sorted(set(b for b, _ in _BACKENDS))
        raise ValueError(
            f"no backend {backend!r} for strategy {strategy!r}; registered "
            f"backends: {have}, pairs: {sorted(_BACKENDS)}")
    return fn


def backend_matrix() -> Dict[str, Tuple[str, ...]]:
    """backend name -> strategies it implements (docs / README helper)."""
    import repro.kernels  # noqa: F401  (trigger pallas registration)
    out: Dict[str, list] = {}
    for b, s in sorted(_BACKENDS):
        out.setdefault(b, []).append(s)
    return {b: tuple(s) for b, s in out.items()}


# --------------------------------------------------------------------------
# the plan
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class InteractionPlan:
    """All static choices for a cutoff interaction, made once.

    Hashable: two equal plans share one jit trace. Everything traced lives
    in ``ParticleState``; everything here is compile-time constant.
    """

    domain: Domain
    kernel: PairKernel
    m_c: int
    strategy: str = "xpencil"
    backend: str = "reference"
    batch_size: int = 64
    box: Optional[Tuple[int, int, int]] = None   # allin sub-box (bx, by, bz)
    interpret: Optional[bool] = None             # pallas: None = auto

    def __post_init__(self):
        if self.strategy not in ("naive_n2", *STRATEGY_NAMES):
            raise ValueError(
                f"unknown strategy {self.strategy!r}; have "
                f"{sorted(STRATEGY_NAMES)} + ['naive_n2']")
        if self.strategy == "allin" and self.box is None:
            # directly-constructed plans get the VMEM-budget sub-box too —
            # the pallas backend needs a concrete tiling at trace time
            object.__setattr__(self, "box", _allin_box(self.domain, self.m_c))

    # -- hot path ----------------------------------------------------------

    def execute(self, state: ParticleState) -> Tuple[Array, Array]:
        """-> (forces (N, 3), per-particle potential (N,)). Jitted; one
        trace per (plan, state structure). Total potential energy is
        ``0.5 * potential.sum()`` (each pair counted twice, the paper's
        convention)."""
        _count_dispatch()
        return _executor(self, tuple(sorted(state.fields)))(state)

    def execute_batch(self, states: ParticleState) -> Tuple[Array, Array]:
        """Batched hot path: one jitted vmapped call over stacked states.

        ``states`` holds B independent systems stacked on a leading axis —
        positions ``(B, N, 3)``, each field ``(B, N)`` — all sharing this
        plan's domain and ``m_c``. Binning and interaction run under one
        ``vmap`` inside a single jit trace, so B small systems (the paper's
        few-particles-per-cell regime) cost one dispatch instead of B.
        Returns ``(forces (B, N, 3), potential (B, N))``, bit-identical to
        executing each system separately."""
        _count_dispatch()
        return _batch_executor(self, tuple(sorted(states.fields)))(states)

    def __call__(self, state: ParticleState) -> Tuple[Array, Array]:
        return self.execute(state)

    # -- M_C safety net ----------------------------------------------------

    def check_overflow(self, state: ParticleState) -> bool:
        """True if some cell holds more than ``m_c`` particles (the static
        bound no longer covers these positions and forces would be wrong)."""
        return int(_max_cell_count(self.domain, state.positions)) > self.m_c

    def replan(self, state: ParticleState, slack: float = 1.5,
               align: int = 8) -> "InteractionPlan":
        """A new plan whose ``m_c`` covers ``state`` with slack (sublane
        aligned, via ``suggest_m_c``) and strictly exceeds the current
        bound. Sub-box sizing is recomputed since it depends on ``m_c``."""
        from .engine import suggest_m_c
        measured = suggest_m_c(self.domain, state.positions, slack=slack,
                               align=align)
        grow = -(-(self.m_c + 1) // align) * align   # smallest aligned > m_c
        return dataclasses.replace(self, m_c=max(measured, grow), box=None)

    def execute_or_replan(self, state: ParticleState
                          ) -> Tuple[Tuple[Array, Array], "InteractionPlan"]:
        """Overflow-safe execute: detects an exceeded ``m_c`` bound (outside
        jit — replanning changes statics) and re-executes under a replanned
        bound. Returns ``((forces, potential), plan)`` where ``plan`` is
        ``self`` when the bound held."""
        p: InteractionPlan = self
        while p.check_overflow(state):
            p = p.replan(state)
        return p.execute(state), p

    # -- introspection -----------------------------------------------------

    def bin(self, state: ParticleState) -> CellBins:
        return bin_particles(self.domain, state.positions, state.fields,
                             m_c=self.m_c)

    def traffic_report(self, avg_ppc: float) -> "traffic.TrafficReport":
        return traffic.model(self.domain, self.m_c, avg_ppc)[self.strategy]


def plan(domain: Domain, kernel: Optional[PairKernel] = None, *,
         positions: Optional[Array] = None, m_c: Optional[int] = None,
         strategy: str = "auto", backend: str = "reference",
         batch_size: int = 64, box: Optional[Tuple[int, int, int]] = None,
         interpret: Optional[bool] = None,
         m_c_slack: float = 1.5) -> InteractionPlan:
    """Build an :class:`InteractionPlan` (static planning, done once).

    Args:
      domain: the cell grid.
      kernel: pair kernel (default Lennard-Jones).
      positions: representative positions; required when ``m_c`` is None
        (measured bound) or ``strategy="auto"`` (fill ratio for the cost
        model).
      m_c: static max-particles-per-cell bound; measured from ``positions``
        with slack + sublane alignment when omitted.
      strategy: one of ``par_part | cell_dense | xpencil | allin |
        naive_n2``; ``"auto"`` to pick the minimum modelled HBM traffic
        per interaction (``core.traffic``); or ``"autotune"`` to *measure*
        candidate schedules on ``positions`` and return the empirically
        fastest (``core.autotune``; winners persist in an on-disk cache).
      backend: ``"reference"`` (pure-JAX schedules) or ``"pallas"`` (TPU
        kernels; interpret mode off-TPU). With ``strategy="autotune"``,
        ``"all"`` defers to the tuner's platform default set (reference
        everywhere, plus native Pallas on TPU).
      box: All-in-SM sub-box override; sized from the VMEM budget otherwise.
      interpret: force Pallas interpret mode (None = auto by platform).
    """
    kernel = kernel or make_lennard_jones()
    if strategy == "autotune":
        from . import autotune
        if positions is None:
            raise ValueError('strategy="autotune" needs positions (the '
                             "tuner times real executions)")
        backends = None if backend == "all" else (backend,)
        # the caller's batch_size/box join the sweep as candidates rather
        # than pinning it — the stopwatch gets the final word
        batch_sizes = tuple(dict.fromkeys(
            (batch_size, *autotune.DEFAULT_BATCH_SIZES)))
        return autotune.tune(domain, kernel, positions, m_c=m_c,
                             backends=backends, batch_sizes=batch_sizes,
                             box=box, m_c_slack=m_c_slack,
                             interpret=interpret).plan
    if m_c is None:
        if positions is None:
            raise ValueError("plan() needs either m_c or positions "
                             "(to measure the M_C bound)")
        from .engine import suggest_m_c
        m_c = suggest_m_c(domain, positions, slack=m_c_slack)
    if strategy == "auto":
        if positions is None:
            raise ValueError('strategy="auto" needs positions (the cost '
                             "model is parameterized by the fill ratio)")
        strategy = choose_strategy(domain, m_c,
                                   positions.shape[0] / domain.n_cells)
    p = InteractionPlan(domain=domain, kernel=kernel, m_c=m_c,
                        strategy=strategy, backend=backend,
                        batch_size=batch_size, box=box, interpret=interpret)
    if strategy != "naive_n2":
        get_backend(backend, strategy)   # fail at plan time, not execute time
    return p


def choose_strategy(domain: Domain, m_c: int, avg_ppc: float) -> str:
    """``strategy="auto"``: minimize modelled HBM bytes per interaction.

    The paper's Fig. 7 argument as a decision rule — the schedule that moves
    the fewest global-memory bytes per interaction wins in the memory-bound
    regime the paper targets. Ties break toward the paper's X-pencil.
    """
    reports = traffic.model(domain, m_c, max(avg_ppc, 1e-3))
    order = {"xpencil": 0, "allin": 1, "cell_dense": 2, "par_part": 3}
    return min(reports.values(),
               key=lambda r: (r.hbm_bytes_per_interaction,
                              order[r.strategy])).strategy


def _allin_box(domain: Domain, m_c: int) -> Tuple[int, int, int]:
    """VMEM-budget sub-box, shrunk to divisors of the grid (static)."""
    return S.shrink_to_divisors(domain, S.subbox_dims(domain, m_c))


def _max_cell_count(domain: Domain, positions: Array) -> Array:
    counts = jax.ops.segment_sum(
        jnp.ones((positions.shape[0],), jnp.int32),
        domain.cell_ids(positions), num_segments=domain.n_cells)
    return jnp.max(counts)


# --------------------------------------------------------------------------
# execution (jitted per plan)
# --------------------------------------------------------------------------

# Dispatch accounting: incremented once per execute/execute_batch call (i.e.
# per jitted dispatch, not per traced system). Lets tests and benchmarks
# assert that the batched path really amortizes dispatch — B systems through
# ``execute_batch`` move this by 1, a Python loop moves it by B.
_dispatches = 0


def dispatch_count() -> int:
    return _dispatches


def _count_dispatch() -> None:
    global _dispatches
    _dispatches += 1


def _impl(p: InteractionPlan) -> Callable:
    """The traced executor body shared by the single and batched paths."""

    def impl(state: ParticleState) -> Tuple[Array, Array]:
        if p.strategy == "naive_n2":
            fx, fy, fz, pot = S.naive_n2(p.domain, state.positions, p.kernel)
            return jnp.stack([fx, fy, fz], axis=-1), pot
        bins = bin_particles(p.domain, state.positions, state.fields,
                             m_c=p.m_c)
        return get_backend(p.backend, p.strategy)(p, bins, state)

    return impl


# Bounded LRU (not unbounded): the autotuner times throwaway candidate plans
# by the dozen, and an unbounded cache would pin every one of their traces
# (and their compiled executables) for the process lifetime.
@functools.lru_cache(maxsize=128)
def _executor(p: InteractionPlan, field_names: Tuple[str, ...]) -> Callable:
    """One jitted executor per (plan, state structure)."""
    return jax.jit(_impl(p))


@functools.lru_cache(maxsize=32)
def _batch_executor(p: InteractionPlan, field_names: Tuple[str, ...]
                    ) -> Callable:
    """One jitted executor per (plan, state structure) for stacked states."""
    return jax.jit(jax.vmap(_impl(p)))


def clear_executor_cache() -> None:
    """Drop every cached executor trace (single and batched)."""
    _executor.cache_clear()
    _batch_executor.cache_clear()


# --------------------------------------------------------------------------
# reference backend: the pure-JAX schedules of core.strategies
# --------------------------------------------------------------------------

@register_backend("reference", "par_part")
def _ref_par_part(p: InteractionPlan, bins: CellBins, state: ParticleState):
    fx, fy, fz, pot = S.par_part(p.domain, bins, state.positions, p.kernel,
                                 p.batch_size)
    return jnp.stack([fx, fy, fz], axis=-1), pot


def _ref_dense(fn):
    def impl(p: InteractionPlan, bins: CellBins, state: ParticleState):
        kwargs = {"batch_size": p.batch_size}
        if fn is S.allin:
            kwargs["box"] = p.box
        fx, fy, fz, pot = fn(p.domain, bins, p.kernel, **kwargs)
        return dense_to_particles(p.domain, bins, fx, fy, fz, pot)
    return impl


register_backend("reference", "cell_dense")(_ref_dense(S.cell_dense))
register_backend("reference", "xpencil")(_ref_dense(S.xpencil))
register_backend("reference", "allin")(_ref_dense(S.allin))
