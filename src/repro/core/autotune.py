"""Measured autotuner: pick schedules by stopwatch, not by model.

The paper's central finding is that the winning schedule (X-pencil vs
All-in-SM vs Par-Part) depends on hardware and fill ratio in ways an
analytical model cannot fully predict — its own Fig. 6/7 results had to be
*measured* on three GPUs. ``strategy="auto"`` trusts the ``core.traffic``
HBM-bytes model alone; ``strategy="autotune"`` (this module) uses the model
only to *prune* the candidate space, then times the survivors with the same
compile-excluded stopwatch the benchmark figures use and returns the
empirically fastest plan.

    result = tune(domain, kernel, positions)        # enumerate -> prune ->
    forces, pot = result.plan.execute(state)        #   time -> pick winner

or through the front door::

    p = plan(domain, kernel, positions=pos, strategy="autotune")

Winners persist in an on-disk JSON cache keyed by (platform, grid shape,
m_c, ppc bucket, kernel identity, backends, candidate-space digest), so
re-tuning the same regime costs one dict lookup and zero timing runs. Point
``REPRO_AUTOTUNE_CACHE`` at a directory to relocate the cache (tests use a
tmpdir); delete the file to invalidate.
"""

from __future__ import annotations

import dataclasses
import hashlib
import itertools
import json
import math
import os
import pathlib
import sys
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import jax

from . import strategies as S
from . import traffic
from .api import (InteractionPlan, ParticleState, STRATEGY_NAMES,
                  _allin_box, _max_cell_count, get_backend)
from .domain import Domain
from .interactions import PairKernel, make_lennard_jones
from .timing import time_fn
from ..obs import metrics as _obs_metrics
from ..obs.trace import event as _obs_event, trace as _obs_trace

Array = jax.Array

# Bump when the candidate space or cache schema changes: stale entries from
# an older tuner are skipped (and overwritten), not misread.
# v2: dense-vs-compact candidate axis + occupancy bucket in the cache key.
# v3: halo shard-count candidate axis + device count in the cache key (a
#     winner tuned on an 8-device mesh must not answer a 1-device query).
# v4: dense-vs-packed layout axis (Candidate.layout/row_cap). The key's
#     ppc and occupancy buckets already separate the regimes the layout
#     decision depends on; the version bump retires v3 entries whose
#     candidate space lacked packed twins.
# v5: SFC cluster layout axis (Candidate.layout="sfc"/pair_cap): the
#     compressed cluster-pair-list twins of every sfc-capable candidate.
#     Retires v4 entries whose candidate space lacked sfc twins.
CACHE_VERSION = 5

_CACHE_ENV = "REPRO_AUTOTUNE_CACHE"
_CACHE_FILE = "autotune_cache.json"

DEFAULT_BATCH_SIZES = (32, 64, 128)
DEFAULT_TOP_K = 8

# Re-tune accounting: one bump per candidate actually timed with the
# stopwatch (cache hits bump nothing). The serving tier's steady-state
# guarantee — "a warm engine never re-times" — asserts against this
# counter, the autotune analogue of ``core.api.recompile_count``. Lives in
# the process metrics registry (``repro.obs``) next to the dispatch /
# recompile counters, so ``core.api.reset_counters()`` clears it too.
TIMING_RUNS_TOTAL = "repro_autotune_timing_runs_total"
CACHE_TOTAL = "repro_autotune_cache_total"


def timing_run_count() -> int:
    """Stopwatch candidate timings so far (0 across pure cache hits)."""
    return int(_obs_metrics.registry.total(TIMING_RUNS_TOTAL))


def reset_timing_runs() -> None:
    _obs_metrics.registry.reset(TIMING_RUNS_TOTAL)


# --------------------------------------------------------------------------
# candidates
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Candidate:
    """One point of the tuning space — exactly the static knobs of a plan."""

    strategy: str
    backend: str
    batch_size: int
    m_c: int
    box: Optional[Tuple[int, int, int]] = None   # allin sub-box
    compact: bool = False                        # occupancy-compacted path
    max_active: Optional[int] = None             # static active-unit bound
    n_shards: Optional[int] = None               # halo Z-slabs (None = 1)
    shard_cap: Optional[int] = None              # halo per-shard capacity
    layout: str = "dense"                        # layout: dense|packed|sfc
    row_cap: Optional[int] = None                # static packed-row bound
    pair_cap: Optional[int] = None               # static sfc pair-list bound

    @property
    def distributed(self) -> bool:
        return bool(self.n_shards) and self.n_shards > 1

    def plan(self, domain: Domain, kernel: PairKernel,
             interpret: Optional[bool] = None) -> InteractionPlan:
        if self.distributed:
            # the candidate's backend is the *per-shard* backend; the
            # allin slab tiling is recomputed by the plan for this shard
            # count, so the dense candidate's box is dropped
            return InteractionPlan(
                domain=domain, kernel=kernel, m_c=self.m_c,
                strategy=self.strategy, backend="halo",
                halo_inner=self.backend, batch_size=self.batch_size,
                box=None, interpret=interpret, compact=self.compact,
                max_active=self.max_active, layout=self.layout,
                row_cap=self.row_cap, pair_cap=self.pair_cap,
                n_shards=self.n_shards, shard_cap=self.shard_cap)
        return InteractionPlan(domain=domain, kernel=kernel, m_c=self.m_c,
                               strategy=self.strategy, backend=self.backend,
                               batch_size=self.batch_size, box=self.box,
                               interpret=interpret, compact=self.compact,
                               max_active=self.max_active,
                               layout=self.layout, row_cap=self.row_cap,
                               pair_cap=self.pair_cap)

    def to_json(self) -> dict:
        return {"strategy": self.strategy, "backend": self.backend,
                "batch_size": self.batch_size, "m_c": self.m_c,
                "box": list(self.box) if self.box else None,
                "compact": self.compact, "max_active": self.max_active,
                "n_shards": self.n_shards, "shard_cap": self.shard_cap,
                "layout": self.layout, "row_cap": self.row_cap,
                "pair_cap": self.pair_cap}

    @classmethod
    def from_json(cls, d: dict) -> "Candidate":
        return cls(strategy=d["strategy"], backend=d["backend"],
                   batch_size=int(d["batch_size"]), m_c=int(d["m_c"]),
                   box=tuple(d["box"]) if d.get("box") else None,
                   compact=bool(d.get("compact", False)),
                   max_active=(int(d["max_active"])
                               if d.get("max_active") else None),
                   n_shards=(int(d["n_shards"])
                             if d.get("n_shards") else None),
                   shard_cap=(int(d["shard_cap"])
                              if d.get("shard_cap") else None),
                   layout=d.get("layout", "dense"),
                   row_cap=(int(d["row_cap"])
                            if d.get("row_cap") else None),
                   pair_cap=(int(d["pair_cap"])
                             if d.get("pair_cap") else None))


def enumerate_candidates(domain: Domain, m_c_choices: Sequence[int], *,
                         backends: Sequence[str] = ("reference",),
                         batch_sizes: Sequence[int] = DEFAULT_BATCH_SIZES,
                         strategies: Sequence[str] = STRATEGY_NAMES,
                         extra_allin_boxes: Sequence[Tuple[int, int, int]]
                         = ()) -> List[Candidate]:
    """The candidate space: (strategy, backend, batch_size, m_c, allin box).

    Only (backend, strategy) pairs actually registered survive — the tuner
    can never return an unimplemented combination (``naive_n2`` is the one
    registry-free strategy: the executor special-cases it, so it is emitted
    whenever explicitly requested, once per ``m_c`` — it reads neither
    backend nor batch size). ``batch_size`` is a reference-schedule knob
    (the Pallas kernels ignore it), so Pallas candidates are emitted once
    per remaining axis, pinned to ``min(batch_sizes)`` so the candidate
    space — and the cache key derived from it — does not depend on the
    order callers list batch sizes in.
    """
    out: List[Candidate] = []
    canon_bs = min(batch_sizes)
    for backend in backends:
        for strategy in strategies:
            if strategy == "naive_n2":
                if backend != backends[0]:
                    continue
                bss: Sequence[int] = (canon_bs,)
            else:
                try:
                    get_backend(backend, strategy)
                except ValueError:
                    continue
                bss = batch_sizes if backend == "reference" else (canon_bs,)
            for m_c in dict.fromkeys(m_c_choices):
                boxes: Iterable[Optional[Tuple[int, int, int]]] = (None,)
                if strategy == "allin":
                    boxes = _allin_boxes(domain, m_c, extra_allin_boxes)
                for box in boxes:
                    for bs in dict.fromkeys(bss):
                        out.append(Candidate(strategy, backend, bs, m_c, box))
    return out


def _allin_boxes(domain: Domain, m_c: int,
                 extra: Sequence[Tuple[int, int, int]] = ()
                 ) -> List[Tuple[int, int, int]]:
    """VMEM-budget sub-box plus a small-box alternative (more parallelism,
    less reuse — the trade the paper's §5.1 occupancy discussion is about);
    user-supplied boxes are shrunk to valid grid divisors and appended."""
    boxes = [_allin_box(domain, m_c),
             S.shrink_to_divisors(domain, (2, 2, 2))]
    boxes += [S.shrink_to_divisors(domain, tuple(b)) for b in extra]
    return list(dict.fromkeys(boxes))


def _cost(domain: Domain, avg_ppc: float, c: Candidate,
          fill_for=None) -> float:
    fill = fill_for(c) if (fill_for is not None and c.compact) else 1.0
    return traffic.candidate_cost(domain, c.m_c, avg_ppc, c.strategy,
                                  subbox=c.box, compact=c.compact,
                                  fill=fill, layout=c.layout)


def _audit_pruned(domain: Domain, positions: Array,
                  pruned: Sequence[Candidate], avg_ppc: float,
                  fill_for, counts_box: list) -> None:
    """Model-vs-measured audit of every prune decision (repro.obs.audit).

    Records the "model drift" gauge for each pruned candidate — the exact
    modelled cost that pruned it vs the measured bytes/interaction from the
    real occupancy — so a wrong prune is visible in the registry instead of
    lost. Deduplicated on the model's own inputs (batch-size and backend
    variants share one score); the binning pass is reused from the tuner's
    memo. Audit failures never fail the tune."""
    from ..obs.audit import audit_candidate
    if not counts_box:
        from .binning import cell_counts
        counts_box.append(cell_counts(domain, positions))
    counts = counts_box[0]
    seen = set()
    for c in pruned:
        key = (c.strategy, c.layout, c.compact, c.m_c, c.box)
        if key in seen:
            continue
        seen.add(key)
        try:
            audit_candidate(domain, positions, strategy=c.strategy,
                            m_c=c.m_c, layout=c.layout, compact=c.compact,
                            subbox=c.box, counts=counts,
                            modelled=_cost(domain, avg_ppc, c, fill_for))
        except Exception as e:  # noqa: BLE001 — observability must not
            print(f"autotune: audit of pruned {c} failed: {e!r}",  # bite
                  file=sys.stderr)


def compact_twins(domain: Domain, positions: Array,
                  candidates: Sequence[Candidate], *, slack: float = 1.25,
                  align: int = 8) -> List[Candidate]:
    """The dense-vs-compact candidate axis: for every candidate whose
    (backend, strategy) implements the occupancy-compacted path, a twin
    with ``compact=True`` and a ``max_active`` bound measured from
    ``positions`` (the same slack-plus-alignment contract as ``m_c``)."""
    from .api import suggest_max_active, supports_compact
    twins: List[Candidate] = []
    bounds: Dict[Tuple, int] = {}
    for c in candidates:
        if c.compact or not supports_compact(c.backend, c.strategy):
            continue
        key = ("box", c.box) if c.strategy == "allin" else ("pencil",)
        if key not in bounds:
            bounds[key] = suggest_max_active(
                domain, positions, c.strategy, box=c.box,
                slack=slack, align=align)
        twins.append(dataclasses.replace(c, compact=True,
                                         max_active=bounds[key]))
    return list(dict.fromkeys(twins))


def packed_twins(domain: Domain, positions: Array,
                 candidates: Sequence[Candidate], *, slack: float = 1.25,
                 align: int = 8) -> List[Candidate]:
    """The dense-vs-packed layout axis: for every candidate whose
    (backend, strategy) implements the packed-row layout, a twin with
    ``layout="packed"`` and a ``row_cap`` bound measured from
    ``positions`` (the same slack-plus-alignment contract as ``m_c``).
    Applied after :func:`compact_twins`, so compacted candidates get
    packed twins too — the two axes compose."""
    from .api import suggest_row_cap, supports_layout
    twins: List[Candidate] = []
    bound: Optional[int] = None
    for c in candidates:
        if (c.layout != "dense"
                or not supports_layout(c.backend, c.strategy, "packed")):
            continue
        if c.compact and not _supports_packed_compact(c):
            continue
        if bound is None:
            bound = suggest_row_cap(domain, positions, slack=slack,
                                    align=align)
        twins.append(dataclasses.replace(c, layout="packed", row_cap=bound))
    return list(dict.fromkeys(twins))


def _supports_packed_compact(c: Candidate) -> bool:
    from .api import supports_compact
    return supports_compact(c.backend, c.strategy, "packed")


def sfc_twins(domain: Domain, positions: Array,
              candidates: Sequence[Candidate], *, slack: float = 1.25,
              align: int = 8) -> List[Candidate]:
    """The SFC cluster-layout axis: for every candidate whose
    (backend, strategy) implements the compressed cluster-pair list, a
    twin with ``layout="sfc"`` and a ``pair_cap`` bound measured from
    ``positions`` (the same slack-plus-alignment contract as ``m_c`` /
    ``row_cap``). Only dense, undistributed candidates get a twin: the
    pair list *is* the compaction (a compact twin would be redundant),
    and the distributed axis composes via :func:`halo_twins` afterwards."""
    from .api import suggest_pair_cap, supports_layout
    twins: List[Candidate] = []
    bound: Optional[int] = None
    for c in candidates:
        if (c.layout != "dense" or c.compact or c.distributed
                or not supports_layout(c.backend, c.strategy, "sfc")):
            continue
        if bound is None:
            bound = suggest_pair_cap(domain, positions, slack=slack,
                                     align=align)
        twins.append(dataclasses.replace(c, layout="sfc", pair_cap=bound))
    return list(dict.fromkeys(twins))


def halo_twins(domain: Domain, positions: Array,
               candidates: Sequence[Candidate],
               shard_counts: Sequence[int], *,
               device_count: Optional[int] = None,
               cap_slack: float = 1.3, align: int = 8) -> List[Candidate]:
    """The shard-count candidate axis: for every cell-schedule candidate, a
    distributed twin per viable shard count — ``backend="halo"`` with the
    candidate's backend as the per-shard inner, a ``shard_cap`` measured
    from ``positions`` (the ``m_c`` contract again), and compacted twins
    re-bounded to the *busiest shard's* active pencils. Shard counts that
    don't divide ``nz`` or exceed the visible devices are skipped."""
    from ..dist.halo import suggest_shard_cap, suggest_shard_max_active
    if device_count is None:
        device_count = jax.device_count()
    twins: List[Candidate] = []
    caps: Dict[int, int] = {}
    bounds: Dict[int, int] = {}
    for ns in dict.fromkeys(shard_counts):
        if ns < 2 or ns > device_count or domain.nz % ns:
            continue
        caps[ns] = suggest_shard_cap(domain, positions, ns,
                                     slack=cap_slack, align=align)
        for c in candidates:
            if c.distributed:
                continue
            if c.strategy not in ("cell_dense", "xpencil", "allin"):
                continue
            if c.compact and c.strategy == "allin":
                continue                 # no per-slab sub-box occupancy
            max_active = c.max_active
            if c.compact:
                if ns not in bounds:
                    bounds[ns] = suggest_shard_max_active(
                        domain, positions, ns, align=align)
                max_active = bounds[ns]
            twins.append(dataclasses.replace(
                c, n_shards=ns, shard_cap=caps[ns], box=None,
                max_active=max_active))
    return list(dict.fromkeys(twins))


def prune_candidates(domain: Domain, avg_ppc: float,
                     candidates: Sequence[Candidate],
                     top_k: int = DEFAULT_TOP_K,
                     fill_for=None
                     ) -> Tuple[List[Candidate], List[Candidate]]:
    """Model-guided pruning to ``top_k`` candidates. -> (kept, pruned).

    The ``traffic.candidate_cost`` ranking orders candidates *within* each
    strategy, and strategies are then drained round-robin (cheapest
    strategy first). The model therefore shapes the field but can never
    eliminate a whole strategy by itself — its cost is identical across
    batch-size variants, so a straight global sort would fill ``top_k``
    with duplicates of its favourite schedule and the stopwatch would
    never get to contradict it (the exact failure this tuner exists for).
    Dense and compacted variants of a strategy form separate round-robin
    queues for the same reason: the fill-scaled model must not be able to
    crowd its dense twin (or vice versa) out of the timed field — and so
    do packed-layout variants (whose gather/expand overhead the byte model
    does not see) and distributed (halo) variants per shard count, whose
    ppermute cost the model does not see at all.

    ``fill_for``: optional ``Candidate -> fill fraction`` hook used to
    score compacted candidates (measured occupancy; default 1.0).
    """
    def order_key(c: Candidate):
        return (_cost(domain, avg_ppc, c, fill_for), c.backend,
                c.batch_size, c.m_c, c.box or (), c.compact,
                c.n_shards or 1, c.layout)

    by_strategy: Dict[Tuple[str, bool, int, str], List[Candidate]] = {}
    for c in sorted(candidates, key=order_key):
        by_strategy.setdefault(
            (c.strategy, c.compact, c.n_shards or 1, c.layout),
            []).append(c)
    queues = sorted(by_strategy.values(),
                    key=lambda q: order_key(q[0]))
    interleaved = [c for round_ in itertools.zip_longest(*queues)
                   for c in round_ if c is not None]
    k = max(1, int(top_k))
    kept = interleaved[:k]
    return kept, [c for c in interleaved[k:]]


# --------------------------------------------------------------------------
# on-disk cache
# --------------------------------------------------------------------------

def cache_dir() -> pathlib.Path:
    env = os.environ.get(_CACHE_ENV)
    if env:
        return pathlib.Path(env)
    xdg = os.environ.get("XDG_CACHE_HOME",
                         os.path.join(os.path.expanduser("~"), ".cache"))
    return pathlib.Path(xdg) / "repro_autotune"


def cache_path() -> pathlib.Path:
    return cache_dir() / _CACHE_FILE


def ppc_bucket(avg_ppc: float) -> str:
    """Log2 fill-ratio bucket: nearby fill ratios share a tuning decision
    (the paper's regimes — 1, 10, 100 ppc — land in distinct buckets)."""
    return f"2^{round(math.log2(max(avg_ppc, 0.125)))}"


def occupancy_bucket(fill: float) -> str:
    """Log2 active-pencil-fill bucket for the cache key.

    Mean ppc alone cannot distinguish a uniform gas from a tight blob with
    the same particle count — but those two regimes have different winners
    (compact wins the blob, dense the gas). Bucketing the measured fill
    fraction keeps their cached decisions separate while nearby fills
    share one."""
    return f"occ2^{round(math.log2(min(max(fill, 1.0 / 4096.0), 1.0)))}"


def _kernel_id(kernel: PairKernel) -> str:
    """Stable kernel identity for the disk cache: name plus a digest of the
    value-based identity tuple ``(name, flops, static_params)`` (PairKernel's
    own hash contract), so two kernels sharing a name but differing in FLOPs
    or parameters never share a cached winner. ``hash()`` itself is unusable
    here — Python randomizes string hashes per process."""
    ident = repr((kernel.name, kernel.flops, kernel.static_params))
    return f"{kernel.name}-{hashlib.sha1(ident.encode()).hexdigest()[:10]}"


def cache_key(platform: str, domain: Domain, m_c: int, avg_ppc: float,
              kernel: PairKernel, backends: Sequence[str],
              pencil_fill: float = 1.0,
              device_count: Optional[int] = None) -> str:
    """Mesh-aware: the visible device count is part of the key — the halo
    shard-count axis makes winners mesh-shaped, so a schedule tuned on an
    8-device mesh must never answer a 1-device query (or vice versa)."""
    if device_count is None:
        device_count = jax.device_count()
    return "|".join([
        platform,
        f"dev{device_count}",
        "x".join(str(n) for n in domain.ncells),
        f"mc{m_c}",
        f"ppc{ppc_bucket(avg_ppc)}",
        occupancy_bucket(pencil_fill),
        _kernel_id(kernel),
        "+".join(sorted(backends)),
    ])


def _space_id(candidates: Sequence[Candidate]) -> str:
    """Order-independent digest of a candidate space."""
    blob = "\n".join(sorted(json.dumps(c.to_json(), sort_keys=True)
                            for c in candidates))
    return hashlib.sha1(blob.encode()).hexdigest()[:10]


def _load_cache(path: pathlib.Path) -> dict:
    try:
        with open(path) as f:
            data = json.load(f)
    except (OSError, ValueError):
        return {}
    return data if isinstance(data, dict) else {}


def _store_cache(path: pathlib.Path, key: str, entry: dict) -> None:
    """Merge one entry into the cache file.

    The tmp file is per-process and the final rename is atomic, so readers
    never see a truncated JSON. Two processes storing *concurrently* can
    still lose one another's new entry (last rename wins) — an acceptable
    cost for a cache whose entries are all re-derivable by re-tuning."""
    path.parent.mkdir(parents=True, exist_ok=True)
    data = _load_cache(path)
    data[key] = entry
    tmp = path.with_name(f"{path.name}.{os.getpid()}.tmp")
    try:
        with open(tmp, "w") as f:
            json.dump(data, f, indent=1, sort_keys=True)
        os.replace(tmp, path)
    finally:
        if tmp.exists():
            tmp.unlink()


# --------------------------------------------------------------------------
# the tuner
# --------------------------------------------------------------------------

@dataclasses.dataclass
class TuneResult:
    """Winner plan plus the evidence: what was timed, what was pruned."""

    plan: InteractionPlan
    candidate: Candidate
    timings: Dict[Candidate, float]          # measured mean seconds
    reps: Dict[Candidate, int]               # stopwatch reps per candidate
    pruned: Tuple[Candidate, ...]            # enumerated but never timed
    cache_hit: bool
    cache_file: str


def tune(domain: Domain, kernel: Optional[PairKernel] = None,
         positions: Optional[Array] = None, *,
         m_c: Optional[int] = None,
         backends: Optional[Sequence[str]] = None,
         batch_sizes: Sequence[int] = DEFAULT_BATCH_SIZES,
         strategies: Sequence[str] = STRATEGY_NAMES,
         box: Optional[Tuple[int, int, int]] = None,
         candidates: Optional[Sequence[Candidate]] = None,
         m_c_slack: float = 1.5,
         include_compact: bool = True,
         include_packed: bool = True,
         include_sfc: bool = True,
         shard_counts: Optional[Sequence[int]] = None,
         top_k: int = DEFAULT_TOP_K,
         reps: Optional[int] = None, budget_s: float = 0.5,
         interpret: Optional[bool] = None,
         use_cache: bool = True) -> TuneResult:
    """Measure candidate schedules on ``positions`` and return the fastest.

    Enumerates (strategy, backend, batch_size, m_c, allin box) candidates,
    prunes to ``top_k`` with the traffic model, times each survivor with a
    compile-excluded stopwatch (``core.timing.time_fn``), and returns the
    empirically fastest :class:`InteractionPlan`. Winners persist in the
    JSON cache (``cache_path()``), so the same regime re-tunes for free.

    Args:
      positions: representative positions — required; the tuner times real
        executions and measures the M_C bound from them.
      m_c: pin the slot bound; by default both a tight (slack=1.0) and a
        slacked (``m_c_slack``, default 1.5) sublane-aligned bound are
        candidates.
      backends: backends to tune over; default is ``("reference",)`` off-TPU
        (interpret-mode Pallas would time the interpreter, not the kernel)
        and ``("reference", "pallas")`` on TPU.
      box: extra All-in-SM sub-box to try alongside the derived candidates
        (shrunk to grid divisors).
      candidates: explicit candidate list (overrides enumeration; no
        compact twins are added to an explicit list).
      include_compact: add an occupancy-compacted twin for every
        enumerated candidate whose (backend, strategy) implements the
        compacted path — the dense-vs-compact axis of the search. The
        bound is measured from ``positions``.
      include_packed: add a packed-row-layout twin (``layout="packed"``,
        ``row_cap`` measured from ``positions``) for every candidate —
        dense *and* compacted — whose (backend, strategy) implements the
        packed layout: the dense-vs-packed axis of the search.
      include_sfc: add an SFC cluster-layout twin (``layout="sfc"``,
        ``pair_cap`` measured from ``positions``) for every dense
        candidate whose (backend, strategy) implements the compressed
        cluster-pair list: the dense-vs-sfc axis of the search.
      shard_counts: halo shard counts to sweep (the distributed axis —
        every cell-schedule candidate gets a ``backend="halo"`` twin per
        viable count). Default: the full visible device count when more
        than one device is up, nothing on a single device. Pass ``()`` to
        disable the distributed axis entirely.
      top_k: survivors after model pruning; raise it if you suspect the
        model is mis-ranking your regime.
      reps / budget_s: stopwatch controls (see ``time_fn``).
      use_cache: disable to force re-measurement (the winner still
        overwrites the cache entry).
    """
    if positions is None:
        raise ValueError("tune() needs positions (it measures real "
                         "executions, not a model)")
    kernel = kernel or make_lennard_jones()
    platform = jax.default_backend()
    if backends is None:
        backends = (("reference", "pallas") if platform == "tpu"
                    else ("reference",))

    from .api import active_unit_count, n_units
    from .engine import suggest_m_c
    max_count = int(_max_cell_count(domain, positions))
    if m_c is not None:
        m_c_choices = [m_c]
    else:
        m_c_choices = list(dict.fromkeys(
            [suggest_m_c(domain, positions, slack=1.0),
             suggest_m_c(domain, positions, slack=m_c_slack)]))
    key_m_c = min(m_c_choices)
    avg_ppc = positions.shape[0] / domain.n_cells

    # measured occupancy: how many work units are actually active. Keyed
    # per unit type (pencils; sub-boxes per tiling) and memoized — used to
    # score compacted candidates, reject too-small cached bounds, and
    # bucket the cache key (mean ppc alone cannot tell a blob from a gas).
    _occ: Dict[Tuple, Tuple[int, int]] = {}

    def occ_of(c: Candidate) -> Tuple[int, int]:     # (n_active, n_units)
        key_ = ("box", c.box) if c.strategy == "allin" else ("pencil",)
        if key_ not in _occ:
            _occ[key_] = (active_unit_count(domain, positions, c.strategy,
                                            box=c.box),
                          n_units(domain, c.strategy, box=c.box))
        return _occ[key_]

    def fill_for(c: Candidate) -> float:
        n_act, total = occ_of(c)
        return n_act / max(total, 1)

    # measured per-shard maxima, memoized per shard count — the halo
    # analogues of max_count/occ_of for the distributed candidates. The
    # per-cell counts don't depend on the shard count: one binning pass
    # serves every ns.
    _shard_measures: Dict[int, Tuple[int, int]] = {}
    _counts_box: list = []

    def shard_measures(ns: int) -> Tuple[int, int]:
        if ns not in _shard_measures:
            from .binning import (cell_counts, shard_pencil_active,
                                  shard_slab_counts)
            if not _counts_box:
                _counts_box.append(cell_counts(domain, positions))
            counts = _counts_box[0]
            _shard_measures[ns] = (
                int(shard_slab_counts(domain, counts, ns).max()),
                int(shard_pencil_active(domain, counts, ns).max()))
        return _shard_measures[ns]

    # measured packed-row maximum, memoized — the row_cap analogue of
    # max_count for the packed-layout candidates
    _row_max: list = []

    def max_row_count() -> int:
        if not _row_max:
            from .binning import cell_counts, padded_row_counts
            if not _counts_box:
                _counts_box.append(cell_counts(domain, positions))
            _row_max.append(int(jax.numpy.max(
                padded_row_counts(domain, _counts_box[0]))))
        return _row_max[0]

    # measured pair-list size, memoized — the pair_cap analogue of
    # max_row_count for the sfc-layout candidates
    _pair_max: list = []

    def max_pair_count() -> int:
        if not _pair_max:
            from .binning import cell_counts, sfc_pair_count
            if not _counts_box:
                _counts_box.append(cell_counts(domain, positions))
            _pair_max.append(int(sfc_pair_count(domain,
                                                counts=_counts_box[0])))
        return _pair_max[0]

    def active_safe(c: Candidate, strict: bool = True) -> bool:
        if c.layout == "packed":
            if c.row_cap is None:
                if strict:
                    raise ValueError(
                        f"packed candidate {c} has no row_cap bound "
                        "(repro.core.suggest_row_cap measures one)")
                return False
            if c.row_cap < max_row_count():
                return False
        if c.layout == "sfc":
            if c.pair_cap is None:
                if strict:
                    raise ValueError(
                        f"sfc candidate {c} has no pair_cap bound "
                        "(repro.core.suggest_pair_cap measures one)")
                return False
            if c.pair_cap < max_pair_count():
                return False
        if c.distributed:
            ns = c.n_shards
            if ns > jax.device_count() or domain.nz % ns:
                return False
            if c.shard_cap is None:
                if strict:
                    raise ValueError(
                        f"halo candidate {c} has no shard_cap bound "
                        "(repro.dist.halo.suggest_shard_cap measures one)")
                return False
            load, act = shard_measures(ns)
            if c.shard_cap < load:
                return False
            if c.compact:
                return c.max_active is not None and c.max_active >= act
            return True
        if not c.compact:
            return True
        if c.max_active is None:
            if strict:             # caller-supplied candidate: loud error
                raise ValueError(
                    f"compact candidate {c} has no max_active bound "
                    "(repro.core.suggest_max_active measures one)")
            return False           # malformed cache entry: just re-measure
        return c.max_active >= occ_of(c)[0]

    _occ[("pencil",)] = (active_unit_count(domain, positions, "xpencil"),
                         n_units(domain, "xpencil"))
    pencil_fill = _occ[("pencil",)][0] / max(_occ[("pencil",)][1], 1)

    key = cache_key(platform, domain, key_m_c, avg_ppc, kernel, backends,
                    pencil_fill=pencil_fill)
    cfile = cache_path()

    # build the requested candidate space first (cheap — no timing): the
    # cache is only consulted *within* it, so a restricted call
    # (strategies=..., candidates=..., pinned m_c) can never be answered
    # with a cached winner from outside its space
    if candidates is None:
        candidates = enumerate_candidates(
            domain, m_c_choices, backends=backends, batch_sizes=batch_sizes,
            strategies=strategies,
            extra_allin_boxes=(box,) if box is not None else ())
        if include_compact:
            candidates = list(candidates) + compact_twins(
                domain, positions, candidates)
        if include_packed:
            candidates = list(candidates) + packed_twins(
                domain, positions, candidates)
        if include_sfc:
            candidates = list(candidates) + sfc_twins(
                domain, positions, candidates)
        if shard_counts is None:
            # default distributed axis: the full local mesh (one extra
            # twin set), only when there is actually more than one device
            ndev = jax.device_count()
            shard_counts = (ndev,) if ndev > 1 else ()
        if shard_counts:
            candidates = list(candidates) + halo_twins(
                domain, positions, candidates, shard_counts)
    candidates = [c for c in candidates
                  if c.m_c >= max_count and active_safe(c)]
    if not candidates:
        raise ValueError(
            f"no overflow-safe candidates: max cell count {max_count} "
            f"exceeds every candidate m_c")

    # the candidate space is part of the key: a restricted call (explicit
    # strategies/candidates/batch sizes) owns its own entry instead of
    # answering from — or clobbering — the unrestricted one
    key += f"|space{_space_id(candidates)}"

    if use_cache:
        entry = _load_cache(cfile).get(key)
        if entry and entry.get("version") == CACHE_VERSION:
            cand = Candidate.from_json(entry["candidate"])
            # trust the entry only if it is overflow-safe for *these*
            # positions (bucket collisions can cache a smaller bound —
            # for m_c *and* for a compacted max_active) and inside the
            # requested space — otherwise re-measure
            if (cand.m_c >= max_count and active_safe(cand, strict=False)
                    and cand in set(candidates)):
                _obs_metrics.registry.counter(CACHE_TOTAL,
                                              result="hit").inc()
                _obs_event("autotune.cache", result="hit",
                           strategy=cand.strategy, layout=cand.layout)
                return TuneResult(
                    plan=cand.plan(domain, kernel, interpret), candidate=cand,
                    timings={}, reps={}, pruned=(), cache_hit=True,
                    cache_file=str(cfile))
    _obs_metrics.registry.counter(CACHE_TOTAL, result="miss").inc()
    _obs_event("autotune.cache", result="miss", candidates=len(candidates))
    kept, pruned = prune_candidates(domain, avg_ppc, candidates,
                                    top_k=top_k, fill_for=fill_for)
    _audit_pruned(domain, positions, pruned, avg_ppc, fill_for, _counts_box)

    state = ParticleState(positions)
    timings: Dict[Candidate, float] = {}
    nreps: Dict[Candidate, int] = {}
    for cand in kept:
        try:
            p = cand.plan(domain, kernel, interpret)
            _obs_metrics.registry.counter(
                TIMING_RUNS_TOTAL, backend=cand.backend,
                strategy=cand.strategy, layout=cand.layout).inc()
            with _obs_trace("autotune.time", backend=cand.backend,
                            strategy=cand.strategy, layout=cand.layout,
                            compact=cand.compact,
                            modelled_bpi=_cost(domain, avg_ppc, cand,
                                               fill_for)) as sp:
                secs, r = time_fn(p.execute, state, reps=reps,
                                  budget_s=budget_s)
                sp.set(seconds_per_call=secs, reps=r)
        except Exception as e:  # noqa: BLE001 — a broken candidate loses,
            print(f"autotune: candidate {cand} failed: {e!r}",  # not the run
                  file=sys.stderr)
            _obs_event("autotune.candidate_failed", backend=cand.backend,
                       strategy=cand.strategy, error=type(e).__name__)
            continue
        timings[cand] = secs
        nreps[cand] = r
    if not timings:
        raise RuntimeError(
            f"autotune: all {len(kept)} timed candidates failed (see stderr)")

    winner = min(timings, key=timings.get)
    _obs_event("autotune.winner", backend=winner.backend,
               strategy=winner.strategy, layout=winner.layout,
               compact=winner.compact,
               seconds_per_call=timings[winner])
    _store_cache(cfile, key, {
        "version": CACHE_VERSION,
        "candidate": winner.to_json(),
        "seconds": timings[winner],
        "platform": platform,
    })
    return TuneResult(plan=winner.plan(domain, kernel, interpret),
                      candidate=winner, timings=timings, reps=nreps,
                      pruned=tuple(pruned), cache_hit=False,
                      cache_file=str(cfile))
