"""The five scheduling strategies of the paper, as pure-JAX references.

Every strategy computes, for each particle, the force and potential due to
all partners within the cutoff — they differ only in *how the neighborhood is
scheduled*, which is exactly the paper's subject:

  naive_n2    O(N^2) masked all-pairs — correctness oracle (tiny boxes only).
  par_part    Par-Part-NoLoop/Loop: parallel over particles, each gathers its
              27 neighbor cells' slots from HBM (no staging, no reuse).
  cell_dense  Par-Cell(-SM): parallel over cells; the m_c targets of a cell
              interact with 27 one-cell source slabs (one-cell-at-a-time
              staging).
  xpencil     the paper's X-pencil: parallel over (z, y) pencils; the target
              pencil is staged once, the 9 (dz, dy) neighbor pencils are
              visited one at a time, and the X window of a target cell is a
              contiguous 3*m_c slice of the neighbor pencil row.
  allin       the paper's All-in-SM: parallel over sub-boxes; a halo block of
              (bz+2, by+2, bx+2) cells is staged once and all interior
              interactions are computed from it.

The Pallas kernels in ``repro.kernels`` lower ``xpencil`` / ``allin`` /
``prefix_sum`` to explicit VMEM staging; these references are their oracles
and the CPU benchmark bodies. Chunking (``batch_size``) bounds peak memory:
it plays the role of the GPU grid — how many pencils/cells are in flight.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .binning import (EMPTY_POS, CellBins, Occupancy, PackedRows,
                      SfcClusters, gather_pencil_rows, sfc_cluster_tables,
                      sfc_slot_tables)
from .domain import Domain
from .interactions import PairKernel, pair_contribution

Array = jnp.ndarray
ForceOut = Tuple[Array, Array, Array, Array]  # fx, fy, fz, potential


# --------------------------------------------------------------------------
# naive O(N^2)
# --------------------------------------------------------------------------

def naive_n2(domain: Domain, positions: Array, kernel: PairKernel,
             row_chunk: int = 1024) -> ForceOut:
    """All-pairs with cutoff mask; per-particle potential channel."""
    n = positions.shape[0]
    cut2 = domain.cutoff ** 2

    def one_row(i):
        d = positions[i][None, :] - positions
        d = domain.minimum_image(d)
        mask = jnp.arange(n) != i
        fx, fy, fz, pot = pair_contribution(
            kernel, d[:, 0], d[:, 1], d[:, 2], mask, cut2)
        return fx.sum(), fy.sum(), fz.sum(), pot.sum()

    fx, fy, fz, pot = jax.lax.map(one_row, jnp.arange(n),
                                  batch_size=min(row_chunk, n))
    return fx, fy, fz, pot


# --------------------------------------------------------------------------
# shared helpers for the cell strategies
# --------------------------------------------------------------------------

def _pencil_rows(domain: Domain, bins: CellBins, z: Array, y: Array):
    """Dynamic-slice one padded (z, y) row (length (nx+2)*m_c) per field.

    (z, y) are *interior* pencil coordinates in [0, nz) x [0, ny); the +1
    ghost offset is applied here.
    """
    row_len = (domain.nx + 2) * bins.m_c

    def row(plane, dz, dy):
        return jax.lax.dynamic_slice(
            plane, (z + 1 + dz, y + 1 + dy, 0), (1, 1, row_len))[0, 0]

    return row


def _window_indices(nx: int, m_c: int) -> Array:
    """(nx, 3*m_c) gather map: target cell x -> its contiguous source window
    [x*m_c, (x+3)*m_c) inside a padded pencil row (ghost cell at each end)."""
    return (jnp.arange(nx, dtype=jnp.int32)[:, None] * m_c
            + jnp.arange(3 * m_c, dtype=jnp.int32)[None, :])


def _pair_reduce(kernel, cut2, tx, ty, tz, tid, sx, sy, sz, sid):
    """targets (..., T) x sources (..., S) -> per-target (fx, fy, fz, pot)."""
    ddx = tx[..., :, None] - sx[..., None, :]
    ddy = ty[..., :, None] - sy[..., None, :]
    ddz = tz[..., :, None] - sz[..., None, :]
    mask = ((sid[..., None, :] != tid[..., :, None])
            & (sid[..., None, :] >= 0) & (tid[..., :, None] >= 0))
    fx, fy, fz, pot = pair_contribution(kernel, ddx, ddy, ddz, mask, cut2)
    return fx.sum(-1), fy.sum(-1), fz.sum(-1), pot.sum(-1)


# --------------------------------------------------------------------------
# Par-Part: parallel over particles, gather everything
# --------------------------------------------------------------------------

def par_part(domain: Domain, bins: CellBins, positions: Array,
             kernel: PairKernel, batch_size: int = 4096) -> ForceOut:
    """One 'thread' per particle; 27 * m_c source slots gathered per particle.

    Returns per-particle outputs directly (this schedule never builds a dense
    output plane — just like the paper's version updates v[idx] in place).
    """
    n = positions.shape[0]
    nx, ny, _ = domain.ncells
    m_c = bins.m_c
    cut2 = domain.cutoff ** 2
    row_len = (nx + 2) * m_c

    coords = domain.cell_coords(positions)            # (N, 3)
    offs = jnp.asarray(domain.neighbor_offsets())     # (27, 3)

    xf = bins.planes["x"].reshape(-1)
    yf = bins.planes["y"].reshape(-1)
    zf = bins.planes["z"].reshape(-1)
    sidf = bins.slot_id.reshape(-1)

    slot_in_cell = jnp.arange(m_c, dtype=jnp.int32)

    def one(args):
        pos, cxyz, pid = args
        # flat base index of each of the 27 neighbor cells (padded coords are
        # always in range thanks to the ghost ring).
        ncell = cxyz[None, :] + offs + 1                      # (27, 3)
        base = ((ncell[:, 2] * (ny + 2) + ncell[:, 1]) * row_len
                + ncell[:, 0] * m_c)                          # (27,)
        idx = (base[:, None] + slot_in_cell[None, :]).reshape(-1)  # (27*m_c,)
        sx, sy, sz, sid = xf[idx], yf[idx], zf[idx], sidf[idx]
        ddx, ddy, ddz = pos[0] - sx, pos[1] - sy, pos[2] - sz
        mask = (sid >= 0) & (sid != pid)
        fx, fy, fz, pot = pair_contribution(kernel, ddx, ddy, ddz, mask, cut2)
        return fx.sum(), fy.sum(), fz.sum(), pot.sum()

    pid = jnp.arange(n, dtype=jnp.int32)
    return jax.lax.map(one, (positions, coords, pid),
                       batch_size=min(batch_size, n))


# --------------------------------------------------------------------------
# Par-Cell(-SM): parallel over cells, 27 one-cell slabs
# --------------------------------------------------------------------------

def cell_dense(domain: Domain, bins: CellBins, kernel: PairKernel,
               batch_size: int = 64) -> ForceOut:
    """Per-cell schedule. Processes pencils of cells ((z,y) rows) in chunks;
    within a row, each target cell interacts with its 27 neighbor cells taken
    as 27 separate m_c-slabs (the Par-Cell staging granularity)."""
    nx, ny, nz = domain.ncells
    m_c = bins.m_c
    cut2 = domain.cutoff ** 2

    def one_pencil(zy):
        z, y = zy // ny, zy % ny
        row = _pencil_rows(domain, bins, z, y)
        # target cells of this pencil: (nx, m_c)
        tgt = {f: row(bins.planes[f], 0, 0)[m_c:(nx + 1) * m_c]
               .reshape(nx, m_c) for f in ("x", "y", "z")}
        tid = row(bins.slot_id, 0, 0)[m_c:(nx + 1) * m_c].reshape(nx, m_c)

        acc = tuple(jnp.zeros((nx, m_c), dtype=bins.planes["x"].dtype)
                    for _ in range(4))
        for dz in (-1, 0, 1):
            for dy in (-1, 0, 1):
                srow = {f: row(bins.planes[f], dz, dy)
                        for f in ("x", "y", "z")}
                sidr = row(bins.slot_id, dz, dy)
                for dx in (-1, 0, 1):
                    sl = slice((1 + dx) * m_c, (1 + dx + nx) * m_c)
                    sx = srow["x"][sl].reshape(nx, m_c)
                    sy = srow["y"][sl].reshape(nx, m_c)
                    sz = srow["z"][sl].reshape(nx, m_c)
                    sid = sidr[sl].reshape(nx, m_c)
                    out = _pair_reduce(kernel, cut2, tgt["x"], tgt["y"],
                                       tgt["z"], tid, sx, sy, sz, sid)
                    acc = tuple(a + o for a, o in zip(acc, out))
        return acc

    zy = jnp.arange(nz * ny, dtype=jnp.int32)
    fx, fy, fz, pot = jax.lax.map(one_pencil, zy,
                                  batch_size=min(batch_size, nz * ny))
    shape = (nz, ny, nx, m_c)
    return (fx.reshape(shape), fy.reshape(shape),
            fz.reshape(shape), pot.reshape(shape))


# --------------------------------------------------------------------------
# X-pencil: the paper's main contribution
# --------------------------------------------------------------------------

def xpencil(domain: Domain, bins: CellBins, kernel: PairKernel,
            batch_size: int = 64) -> ForceOut:
    """X-pencil schedule. For each (z, y) target pencil: stage the pencil,
    then visit the 9 (dz, dy) neighbor pencils; each target cell's sources
    are the contiguous 3*m_c window of the staged neighbor row.

    This is the trace-level mirror of ``repro.kernels.xpencil`` (which adds
    the explicit HBM->VMEM BlockSpec staging); both share this oracle.
    """
    nx, ny, nz = domain.ncells
    m_c = bins.m_c
    cut2 = domain.cutoff ** 2
    widx = _window_indices(nx, m_c)

    def one_pencil(zy):
        z, y = zy // ny, zy % ny
        row = _pencil_rows(domain, bins, z, y)
        tgt = {f: row(bins.planes[f], 0, 0)[m_c:(nx + 1) * m_c]
               .reshape(nx, m_c) for f in ("x", "y", "z")}
        tid = row(bins.slot_id, 0, 0)[m_c:(nx + 1) * m_c].reshape(nx, m_c)

        acc = tuple(jnp.zeros((nx, m_c), dtype=bins.planes["x"].dtype)
                    for _ in range(4))
        for dz in (-1, 0, 1):
            for dy in (-1, 0, 1):
                # stage one neighbor pencil row, window it per target cell
                sx = row(bins.planes["x"], dz, dy)[widx]   # (nx, 3*m_c)
                sy = row(bins.planes["y"], dz, dy)[widx]
                sz = row(bins.planes["z"], dz, dy)[widx]
                sid = row(bins.slot_id, dz, dy)[widx]
                out = _pair_reduce(kernel, cut2, tgt["x"], tgt["y"],
                                   tgt["z"], tid, sx, sy, sz, sid)
                acc = tuple(a + o for a, o in zip(acc, out))
        return acc

    zy = jnp.arange(nz * ny, dtype=jnp.int32)
    fx, fy, fz, pot = jax.lax.map(one_pencil, zy,
                                  batch_size=min(batch_size, nz * ny))
    shape = (nz, ny, nx, m_c)
    return (fx.reshape(shape), fy.reshape(shape),
            fz.reshape(shape), pot.reshape(shape))


# --------------------------------------------------------------------------
# All-in-SM: stage a whole sub-box + halo
# --------------------------------------------------------------------------

def subbox_dims(domain: Domain, m_c: int, fields: int = 4,
                vmem_budget_bytes: int = 8 * 2 ** 20,
                min_blocks: int = 8) -> Tuple[int, int, int]:
    """The paper's sub-box sizing (Section 5.1), with VMEM as the budget.

    max cells = budget / (m_c * fields * 4B); find the largest
    (bx+2)(by+2)(bz+2) <= max_cells with the paper's p3 search, then shrink
    (paper: "reduce the size of the sub-box to ensure enough parallelism")
    until there are at least ``min_blocks`` sub-boxes.
    """
    per_cell = m_c * fields * 4
    max_cells = max(27, vmem_budget_bytes // per_cell)
    p3 = 3
    while (p3 + 1) ** 3 <= max_cells:
        p3 += 1
    candidates = [(p3, p3, p3), (p3 + 1, p3, p3), (p3 + 1, p3 + 1, p3),
                  (p3 + 2, p3, p3)]
    best = max((c for c in candidates
                if c[0] * c[1] * c[2] <= max_cells),
               key=lambda c: c[0] * c[1] * c[2], default=(3, 3, 3))
    bx, by, bz = (max(1, b - 2) for b in best)   # interior target cells
    bx, by, bz = (min(b, n) for b, n in zip((bx, by, bz), domain.ncells))

    def n_blocks(b):
        return -(-domain.nx // b[0]) * -(-domain.ny // b[1]) * -(-domain.nz // b[2])

    while n_blocks((bx, by, bz)) < min_blocks and max(bx, by, bz) > 1:
        if bz >= by and bz >= bx:
            bz = max(1, bz // 2)
        elif by >= bx:
            by = max(1, by // 2)
        else:
            bx = max(1, bx // 2)
    return bx, by, bz


def shrink_to_divisors(domain: Domain,
                       box: Tuple[int, int, int]) -> Tuple[int, int, int]:
    """Shrink a sub-box to a divisor of each grid axis (exact tiling)."""
    def divisor_leq(n, b):
        b = min(b, n)
        while n % b:
            b -= 1
        return b

    return tuple(divisor_leq(n, b)
                 for n, b in zip(domain.ncells, box))


def _allin_box_body(domain: Domain, bins: CellBins, kernel: PairKernel,
                    box: Tuple[int, int, int]):
    """The per-sub-box closure shared by the dense and compacted All-in-SM
    paths (one body, two iteration spaces — the compaction cannot drift)."""
    m_c = bins.m_c
    cut2 = domain.cutoff ** 2
    bx, by, bz = box
    gx, gy = domain.nx // bx, domain.ny // by
    row_len_blk = (bx + 2) * m_c

    def one_box(bid):
        iz = bid // (gy * gx)
        iy = (bid // gx) % gy
        ix = bid % gx
        z0, y0, x0 = iz * bz, iy * by, ix * bx

        def stage(plane):   # halo block: (bz+2, by+2, (bx+2)*m_c)
            return jax.lax.dynamic_slice(
                plane, (z0, y0, x0 * m_c), (bz + 2, by + 2, row_len_blk))

        sxp, syp, szp = (stage(bins.planes[f]) for f in ("x", "y", "z"))
        sidp = stage(bins.slot_id)

        # interior targets of the block: (bz, by, bx, m_c)
        def inner(p):
            return p[1:bz + 1, 1:by + 1, m_c:(bx + 1) * m_c].reshape(
                bz, by, bx, m_c)

        tx, ty, tz, tid = inner(sxp), inner(syp), inner(szp), inner(sidp)

        acc = tuple(jnp.zeros((bz, by, bx, m_c),
                              dtype=bins.planes["x"].dtype)
                    for _ in range(4))
        widx = _window_indices(bx, m_c)
        for dz in (-1, 0, 1):
            for dy in (-1, 0, 1):
                sx = sxp[1 + dz:1 + dz + bz, 1 + dy:1 + dy + by][:, :, widx]
                sy = syp[1 + dz:1 + dz + bz, 1 + dy:1 + dy + by][:, :, widx]
                sz = szp[1 + dz:1 + dz + bz, 1 + dy:1 + dy + by][:, :, widx]
                sid = sidp[1 + dz:1 + dz + bz, 1 + dy:1 + dy + by][:, :, widx]
                out = _pair_reduce(kernel, cut2, tx, ty, tz, tid,
                                   sx, sy, sz, sid)
                acc = tuple(a + o for a, o in zip(acc, out))
        return acc

    return one_box


def allin(domain: Domain, bins: CellBins, kernel: PairKernel,
          box: Tuple[int, int, int] | None = None,
          batch_size: int = 8) -> ForceOut:
    """All-in-SM schedule: grid over sub-boxes, one halo block staged each.

    The grid must tile the domain exactly, so the sub-box is shrunk to a
    divisor of each axis (the ghost ring keeps out-of-domain reads valid).
    """
    nx, ny, nz = domain.ncells
    m_c = bins.m_c
    if box is None:
        box = subbox_dims(domain, m_c)

    bx, by, bz = shrink_to_divisors(domain, box)
    gx, gy, gz = nx // bx, ny // by, nz // bz
    one_box = _allin_box_body(domain, bins, kernel, (bx, by, bz))

    nb = gx * gy * gz
    outs = jax.lax.map(one_box, jnp.arange(nb, dtype=jnp.int32),
                       batch_size=min(batch_size, nb))

    # reassemble (nb, bz, by, bx, m_c) blocks -> (nz, ny, nx, m_c)
    def assemble(blocks):
        b = blocks.reshape(gz, gy, gx, bz, by, bx, m_c)
        b = jnp.transpose(b, (0, 3, 1, 4, 2, 5, 6))
        return b.reshape(nz, ny, nx, m_c)

    return tuple(assemble(o) for o in outs)


# --------------------------------------------------------------------------
# occupancy-compacted variants: iterate active work units only
# --------------------------------------------------------------------------
#
# The dense schedules above pay for every (z, y) pencil / sub-box whether or
# not it holds particles — on clustered distributions most of that work is
# masked sentinel slots. The compacted variants below iterate the occupancy
# summary's active list instead (``binning.Occupancy``): the list is padded
# to the static ``max_active`` bound with unit 0 (safe to read — its results
# are recomputed redundantly and dropped on the write side), and the compact
# results are scattered back into the dense output planes so everything
# downstream (``dense_to_particles``) is unchanged. Each variant shares its
# per-unit body with the dense schedule, so compaction cannot change a
# single computed value — only which units are visited.


def _chunked_active(occ: Occupancy, batch_size: int):
    """Pad the active list to a whole number of ``batch_size`` chunks.

    Returns ``(chunks (n_chunks, chunk), scatter_idx (n_chunks * chunk,))``
    — scatter_idx routes every padding slot (list padding *and* chunk
    padding) out of range so a ``mode='drop'`` scatter discards it.
    """
    chunk = max(1, min(batch_size, occ.max_active))
    n_chunks = -(-occ.max_active // chunk)
    total = n_chunks * chunk
    act = jnp.concatenate(
        [occ.active,
         jnp.zeros((total - occ.max_active,), jnp.int32)])
    scatter_idx = jnp.concatenate(
        [occ.scatter_indices(),                       # list padding dropped
         jnp.full((total - occ.max_active,), occ.n_units,
                  jnp.int32)])                        # chunk padding dropped
    return act.reshape(n_chunks, chunk), scatter_idx


def _sparse_pencil_run(domain: Domain, bins: CellBins,
                       occ: Occupancy, batch_size: int,
                       pencil_fn) -> ForceOut:
    """Run a per-pencil-chunk body over active pencils, scatter back dense."""
    nx, ny, nz = domain.ncells
    m_c = bins.m_c
    chunks, scatter_idx = _chunked_active(occ, batch_size)

    outs = jax.lax.map(pencil_fn, chunks)    # 4 x (n_chunks, chunk, nx, m_c)

    def scatter(o):
        compact = o.reshape(-1, nx, m_c)
        dense = jnp.zeros((nz * ny, nx, m_c), o.dtype)
        dense = dense.at[scatter_idx].set(compact, mode="drop")
        return dense.reshape(nz, ny, nx, m_c)

    return tuple(scatter(o) for o in outs)


def xpencil_sparse(domain: Domain, bins: CellBins, kernel: PairKernel,
                   occ: Occupancy, batch_size: int = 64) -> ForceOut:
    """Occupancy-compacted X-pencil: stage only active (z, y) pencils.

    Uses the compacted pencil-row gather (``binning.gather_pencil_rows``):
    one vectorized gather per (dz, dy) neighbor per chunk, instead of the
    dense schedule's sweep over all nz*ny pencils. Empty pencils cost
    nothing; results land in the same dense (nz, ny, nx, m_c) planes.
    """
    nx, ny, _ = domain.ncells
    m_c = bins.m_c
    cut2 = domain.cutoff ** 2
    widx = _window_indices(nx, m_c)
    dt = bins.planes["x"].dtype

    def one_chunk(zy):                       # (chunk,) active pencil ids
        chunk = zy.shape[0]
        tgt = {f: gather_pencil_rows(bins.planes[f], zy, ny)
               [:, m_c:(nx + 1) * m_c].reshape(chunk, nx, m_c)
               for f in ("x", "y", "z")}
        tid = gather_pencil_rows(bins.slot_id, zy, ny)[
            :, m_c:(nx + 1) * m_c].reshape(chunk, nx, m_c)

        acc = tuple(jnp.zeros((chunk, nx, m_c), dtype=dt) for _ in range(4))
        for dz in (-1, 0, 1):
            for dy in (-1, 0, 1):
                sx = gather_pencil_rows(bins.planes["x"], zy, ny, dz, dy)[:, widx]
                sy = gather_pencil_rows(bins.planes["y"], zy, ny, dz, dy)[:, widx]
                sz = gather_pencil_rows(bins.planes["z"], zy, ny, dz, dy)[:, widx]
                sid = gather_pencil_rows(bins.slot_id, zy, ny, dz, dy)[:, widx]
                out = _pair_reduce(kernel, cut2, tgt["x"], tgt["y"],
                                   tgt["z"], tid, sx, sy, sz, sid)
                acc = tuple(a + o for a, o in zip(acc, out))
        return acc

    return _sparse_pencil_run(domain, bins, occ, batch_size,
                              one_chunk)


def cell_dense_sparse(domain: Domain, bins: CellBins, kernel: PairKernel,
                      occ: Occupancy, batch_size: int = 64) -> ForceOut:
    """Occupancy-compacted Par-Cell: only pencils of active cells are
    visited; within a pencil the staging granularity stays the Par-Cell
    one-cell-at-a-time slab."""
    nx, ny, _ = domain.ncells
    m_c = bins.m_c
    cut2 = domain.cutoff ** 2
    dt = bins.planes["x"].dtype

    def one_chunk(zy):
        chunk = zy.shape[0]
        tgt = {f: gather_pencil_rows(bins.planes[f], zy, ny)
               [:, m_c:(nx + 1) * m_c].reshape(chunk, nx, m_c)
               for f in ("x", "y", "z")}
        tid = gather_pencil_rows(bins.slot_id, zy, ny)[
            :, m_c:(nx + 1) * m_c].reshape(chunk, nx, m_c)

        acc = tuple(jnp.zeros((chunk, nx, m_c), dtype=dt) for _ in range(4))
        for dz in (-1, 0, 1):
            for dy in (-1, 0, 1):
                srow = {f: gather_pencil_rows(bins.planes[f], zy, ny, dz, dy)
                        for f in ("x", "y", "z")}
                sidr = gather_pencil_rows(bins.slot_id, zy, ny, dz, dy)
                for dx in (-1, 0, 1):
                    sl = slice((1 + dx) * m_c, (1 + dx + nx) * m_c)
                    sx = srow["x"][:, sl].reshape(chunk, nx, m_c)
                    sy = srow["y"][:, sl].reshape(chunk, nx, m_c)
                    sz = srow["z"][:, sl].reshape(chunk, nx, m_c)
                    sid = sidr[:, sl].reshape(chunk, nx, m_c)
                    out = _pair_reduce(kernel, cut2, tgt["x"], tgt["y"],
                                       tgt["z"], tid, sx, sy, sz, sid)
                    acc = tuple(a + o for a, o in zip(acc, out))
        return acc

    return _sparse_pencil_run(domain, bins, occ, batch_size,
                              one_chunk)


def allin_sparse(domain: Domain, bins: CellBins, kernel: PairKernel,
                 occ: Occupancy, box: Tuple[int, int, int],
                 batch_size: int = 8) -> ForceOut:
    """Occupancy-compacted All-in-SM: fully-empty sub-boxes are skipped.

    ``box`` must already be shrunk to grid divisors and match the tiling
    ``occ`` was built with (``binning.subbox_occupancy``); the per-box body
    is the dense schedule's own.
    """
    nx, ny, nz = domain.ncells
    m_c = bins.m_c
    bx, by, bz = box
    gx, gy, gz = nx // bx, ny // by, nz // bz
    one_box = _allin_box_body(domain, bins, kernel, box)

    chunks, scatter_idx = _chunked_active(occ, batch_size)
    outs = jax.lax.map(jax.vmap(one_box), chunks)

    def scatter(blocks):                 # (n_chunks, chunk, bz, by, bx, m_c)
        compact = blocks.reshape(-1, bz, by, bx, m_c)
        dense = jnp.zeros((gz * gy * gx, bz, by, bx, m_c), blocks.dtype)
        dense = dense.at[scatter_idx].set(compact, mode="drop")
        b = dense.reshape(gz, gy, gx, bz, by, bx, m_c)
        b = jnp.transpose(b, (0, 3, 1, 4, 2, 5, 6))
        return b.reshape(nz, ny, nx, m_c)

    return tuple(scatter(o) for o in outs)


# --------------------------------------------------------------------------
# packed-row (CSR) X-pencil: dense windows re-expanded from packed rows
# --------------------------------------------------------------------------
#
# The occupancy-compacted variants above still move every active pencil's
# full (nx+2)*m_c dense row; the packed variant reads the CSR layout
# (``binning.PackedRows``) instead — row_cap slots per row, bytes
# proportional to the particles, not to m_c. Each target slot's 3-cell
# X-window is re-expanded to the *dense* (3*m_c,) shape by offset/length
# (invalid ranks read the sentinel), so every pair contribution, mask and
# last-axis reduction is elementwise identical to the dense schedule's —
# packing changes where bytes live, never a computed value.


def _packed_window(off: Array, rows: dict, scell: Array, tcell: Array,
                   nx: int, m_c: int):
    """Expand packed source rows into per-target dense 3-cell windows.

    Two stages, so the per-element dynamic indexing stays proportional to
    the *particles*, not to the window tensor: first each packed source
    row is scatter-reconstructed into its dense ``(nx+2)*m_c`` row (every
    packed slot knows its dense position ``cell * m_c + rank``; untouched
    slots keep the sentinel — bit-equal to the row the dense layout
    stores), then windows come from the dense schedule's own static
    ``_window_indices`` view and each target slot row-gathers its cell's
    window (contiguous rows, cheap). One dynamic scatter of ``row_cap``
    values per row per field replaces a ``row_cap * 3 * m_c`` gather.

    Args:
      off: (chunk, nx+3) per-source-row cell offsets (prefix + total).
      rows: field name -> (chunk, row_cap) packed source rows ("id" is
        the slot-id row; ids >= 0 mark real particles).
      scell: (chunk, row_cap) the source rows' packed slot cells.
      tcell: (chunk, row_cap) target padded cell, pre-clipped to [1, nx].
    Returns:
      field name -> (chunk, row_cap, 3*m_c) window values per target slot
      — elementwise equal to the dense layout's
      ``row[(c-1)*m_c:(c+2)*m_c]`` per target cell.
    """
    chunk, row_cap = scell.shape
    row_len = (nx + 2) * m_c
    start = jnp.take_along_axis(off, scell, axis=-1)
    rank = jnp.arange(row_cap, dtype=jnp.int32) - start
    valid = rows["id"] >= 0
    dest = jnp.where(valid, scell * m_c + rank, row_len)    # pads dropped
    flat = (jnp.arange(chunk, dtype=jnp.int32)[:, None] * (row_len + 1)
            + dest).reshape(-1)
    total = chunk * (row_len + 1)

    widx = _window_indices(nx, m_c)
    sel = jnp.broadcast_to((tcell - 1)[..., None],
                           (chunk, row_cap, 3 * m_c))
    out = {}
    for name, row in rows.items():
        fill = jnp.asarray(-1 if name == "id" else EMPTY_POS, row.dtype)
        dense = jnp.full((total,), fill, row.dtype)
        dense = dense.at[flat].set(row.reshape(-1), mode="drop")
        dense = dense.reshape(chunk, row_len + 1)[:, :row_len]
        out[name] = jnp.take_along_axis(dense[:, widx], sel, axis=-2)
    return out


def xpencil_packed(domain: Domain, packed: PackedRows, kernel: PairKernel,
                   occ: Occupancy, batch_size: int = 64) -> ForceOut:
    """Packed-row X-pencil schedule over the (active) pencil rows.

    Iterates the occupancy summary's active list (pass
    ``binning.full_pencil_occupancy`` for every row) in chunks; per chunk,
    the 9 (dz, dy) neighbor rows are gathered as packed ``row_cap`` rows
    plus their offset rows, windows are re-expanded per target slot, and
    the shared masked pair reduction runs. Returns packed
    ``(nz * ny, row_cap)`` planes (pencil-id order) for
    :func:`binning.packed_to_particles`.
    """
    nx, ny, nz = domain.ncells
    m_c, row_cap = packed.m_c, packed.row_cap
    cut2 = domain.cutoff ** 2
    dt = packed.planes["x"].dtype

    def one_chunk(zy):                       # (chunk,) active pencil ids
        tx = gather_pencil_rows(packed.planes["x"], zy, ny)
        ty = gather_pencil_rows(packed.planes["y"], zy, ny)
        tz = gather_pencil_rows(packed.planes["z"], zy, ny)
        tid = gather_pencil_rows(packed.slot_id, zy, ny)
        tc = gather_pencil_rows(packed.slot_cell, zy, ny)
        tcell = jnp.clip(tc, 1, nx)          # ghost/pad targets never unpack

        acc = tuple(jnp.zeros(tx.shape, dtype=dt) for _ in range(4))
        for dz in (-1, 0, 1):
            for dy in (-1, 0, 1):
                off = gather_pencil_rows(packed.cell_offsets, zy, ny, dz, dy)
                rows = {f: gather_pencil_rows(packed.planes[f], zy, ny,
                                              dz, dy)
                        for f in ("x", "y", "z")}
                rows["id"] = gather_pencil_rows(packed.slot_id, zy, ny,
                                                dz, dy)
                scell = gather_pencil_rows(packed.slot_cell, zy, ny, dz, dy)
                w = _packed_window(off, rows, scell, tcell, nx, m_c)
                sid, txe = w["id"], tx[..., None]
                mask = ((sid != tid[..., None]) & (sid >= 0)
                        & (tid[..., None] >= 0))
                fx, fy, fz, pot = pair_contribution(
                    kernel, txe - w["x"], ty[..., None] - w["y"],
                    tz[..., None] - w["z"], mask, cut2)
                out = (fx.sum(-1), fy.sum(-1), fz.sum(-1), pot.sum(-1))
                acc = tuple(a + o for a, o in zip(acc, out))
        return acc

    chunks, scatter_idx = _chunked_active(occ, batch_size)
    outs = jax.lax.map(one_chunk, chunks)    # 4 x (n_chunks, chunk, row_cap)

    def scatter(o):
        compact = o.reshape(-1, row_cap)
        dense = jnp.zeros((nz * ny, row_cap), o.dtype)
        return dense.at[scatter_idx].set(compact, mode="drop")

    return tuple(scatter(o) for o in outs)


# --------------------------------------------------------------------------
# SFC cluster schedule: compressed cluster-pair list over curve clusters
# --------------------------------------------------------------------------
#
# The cluster-vs-stencil-slot runner behind layout="sfc"
# (``binning.SfcClusters``). Bit-identity with ``cell_dense`` is by
# construction: for every interior cell, slot k of the dense sweep reduces
# the same m_c x m_c masked tile against the same padded source slab, and
# the per-cell accumulator adds the 27 slot terms in ascending k — here the
# kept-k loop runs in the same ascending order and a dropped k contributes
# the exact float the dense sweep adds for an empty slab (an all-masked
# ``pair_contribution`` reduces each row to the same signed zero), so the
# per-cell float sums associate identically. The only way to lose a pair is
# ``pair_cap`` truncation, which is detected and replanned, never silent.


def cell_sfc(domain: Domain, sfc: SfcClusters, kernel: PairKernel,
             batch_size: int = 64) -> ForceOut:
    """Reference SFC cluster schedule -> (n_clusters, csize*m_c) tiles.

    ``batch_size`` is accepted for signature parity with the other
    schedules but unused — the pair list itself is the work compaction
    (the 27-slot python loop is the static stencil, not a chunk axis).
    """
    del batch_size
    m_c, csize = sfc.bins.m_c, sfc.csize
    t = sfc_cluster_tables(domain, csize, sfc.curve)
    tgt_base, src_base = sfc_slot_tables(domain, m_c, csize, sfc.curve)
    n_clusters = t.n_clusters
    cut2 = domain.cutoff ** 2
    dt = sfc.bins.planes["x"].dtype

    # kept-pair bitmask recovered from the codes; the sentinel decodes to
    # cluster n_clusters and is dropped. Kept codes are unique, so the
    # integer scatter-add is an exact bitwise OR.
    kept = jnp.zeros((n_clusters,), jnp.int32).at[sfc.codes >> 5].add(
        jnp.int32(1) << (sfc.codes & 31), mode="drop")

    # flat padded planes + one appended sentinel cell (always empty)
    def ext(plane, fill):
        return jnp.concatenate(
            [plane.reshape(-1),
             jnp.full((m_c,), fill, plane.dtype)])

    xs = ext(sfc.bins.planes["x"], EMPTY_POS)
    ys = ext(sfc.bins.planes["y"], EMPTY_POS)
    zs = ext(sfc.bins.planes["z"], EMPTY_POS)
    ids = ext(sfc.bins.slot_id, -1)

    rank = jnp.arange(m_c, dtype=jnp.int32)
    tidx = jnp.asarray(tgt_base)[:, :, None] + rank     # (n_cl, csize, m_c)
    tx, ty, tz = xs[tidx], ys[tidx], zs[tidx]
    tid = ids[tidx]

    src_base = jnp.asarray(src_base)
    acc = tuple(jnp.zeros((n_clusters, csize, m_c), dtype=dt)
                for _ in range(4))
    for k in range(27):
        sidx = src_base[:, k, :, None] + rank           # (n_cl, csize, m_c)
        sx, sy, sz, sid = xs[sidx], ys[sidx], zs[sidx], ids[sidx]
        use = ((kept >> k) & 1).astype(bool)
        ddx = tx[..., :, None] - sx[..., None, :]
        ddy = ty[..., :, None] - sy[..., None, :]
        ddz = tz[..., :, None] - sz[..., None, :]
        mask = ((sid[..., None, :] != tid[..., :, None])
                & (sid[..., None, :] >= 0) & (tid[..., :, None] >= 0)
                & use[:, None, None, None])
        fx, fy, fz, pot = pair_contribution(kernel, ddx, ddy, ddz, mask,
                                            cut2)
        out = (fx.sum(-1), fy.sum(-1), fz.sum(-1), pot.sum(-1))
        acc = tuple(a + o for a, o in zip(acc, out))
    return tuple(a.reshape(n_clusters, csize * m_c) for a in acc)


STRATEGIES = {
    "par_part": par_part,
    "cell_dense": cell_dense,
    "xpencil": xpencil,
    "allin": allin,
}

SPARSE_STRATEGIES = {
    "cell_dense": cell_dense_sparse,
    "xpencil": xpencil_sparse,
    "allin": allin_sparse,
}

PACKED_STRATEGIES = {
    "xpencil": xpencil_packed,
}

SFC_STRATEGIES = {
    "cell_dense": cell_sfc,
}
