"""Stopwatch utilities shared by the autotuner and the benchmark suite.

Timing convention (paper §7.1): jit + warm-up call first so compilation is
excluded, then ``reps`` timed calls, report the mean. The paper uses 200
async calls on real GPUs; on a 1-core CPU container reps are adaptive (big
cases get few reps, small get many) and are returned so every record is
self-describing.

This lives in the library (not ``benchmarks/``) because the measured
autotuner (``core.autotune``) is a user-facing feature, not a benchmark:
``plan(..., strategy="autotune")`` needs the same compile-excluded stopwatch
the figures use.
"""

from __future__ import annotations

import time
from typing import Callable, Tuple

import jax


def time_fn(fn: Callable, *args, reps: int | None = None,
            budget_s: float = 3.0) -> Tuple[float, int]:
    """-> (mean_seconds, reps). First call compiles (excluded)."""
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    out = fn(*args)
    jax.block_until_ready(out)
    once = time.perf_counter() - t0
    if reps is None:
        reps = max(2, min(50, int(budget_s / max(once, 1e-6))))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps, reps
