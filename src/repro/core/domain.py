"""Simulation domain and uniform cell grid.

The paper's setting: a 3-D box divided into a regular grid whose cell width is
at least the cutoff radius ``r_c``, so every interaction partner of a particle
lives in the particle's own cell or one of its 26 neighbors. Cells are
linearized X-fastest (the paper's layout, and the property the X-pencil
strategy exploits: a pencil of cells along X is contiguous in memory).

Nothing here touches devices; it is static geometry shared by every strategy,
the Pallas kernels, and the distributed domain decomposition.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp
import numpy as np

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class Domain:
    """A rectangular simulation box with a uniform cell grid.

    Attributes:
      box: physical box lengths ``(Lx, Ly, Lz)``.
      ncells: grid shape ``(nx, ny, nz)``; cell width = L / n >= cutoff.
      cutoff: interaction cutoff radius ``r_c``.
      periodic: wrap neighbor lookups (minimum-image). The paper uses open
        boundaries (border cells simply have fewer neighbors); periodic is
        provided for the MD/SPH examples.
    """

    box: Tuple[float, float, float]
    ncells: Tuple[int, int, int]
    cutoff: float
    periodic: bool | Tuple[bool, bool, bool] = False

    def __post_init__(self):
        for length, n in zip(self.box, self.ncells):
            width = length / n
            if width + 1e-9 < self.cutoff:
                raise ValueError(
                    f"cell width {width} < cutoff {self.cutoff}; the 27-cell "
                    "neighborhood would miss interactions"
                )

    @property
    def periodic_axes(self) -> Tuple[bool, bool, bool]:
        if isinstance(self.periodic, tuple):
            return self.periodic
        return (bool(self.periodic),) * 3

    @property
    def any_periodic(self) -> bool:
        return any(self.periodic_axes)

    # -- static geometry ----------------------------------------------------

    @property
    def nx(self) -> int:
        return self.ncells[0]

    @property
    def ny(self) -> int:
        return self.ncells[1]

    @property
    def nz(self) -> int:
        return self.ncells[2]

    @property
    def n_cells(self) -> int:
        return self.nx * self.ny * self.nz

    @property
    def cell_width(self) -> Tuple[float, float, float]:
        return tuple(l / n for l, n in zip(self.box, self.ncells))

    @classmethod
    def cubic(cls, division: int, cutoff: float = 1.0, periodic: bool = False) -> "Domain":
        """The paper's benchmark geometry: a cube of ``division**3`` cells whose
        width equals the cutoff (box side = division * cutoff)."""
        side = division * cutoff
        return cls(box=(side,) * 3, ncells=(division,) * 3, cutoff=cutoff,
                   periodic=periodic)

    # -- indexing ------------------------------------------------------------

    def cell_coords(self, positions: Array) -> Array:
        """(N, 3) positions -> (N, 3) integer cell coordinates (ix, iy, iz)."""
        widths = jnp.asarray(self.cell_width, dtype=positions.dtype)
        coords = jnp.floor(positions / widths).astype(jnp.int32)
        ns = jnp.asarray(self.ncells, dtype=jnp.int32)
        wrapped = jnp.mod(coords, ns)
        clipped = jnp.clip(coords, 0, ns - 1)
        per = jnp.asarray(self.periodic_axes)
        return jnp.where(per, wrapped, clipped)

    def linearize(self, coords: Array) -> Array:
        """(..., 3) cell coords -> linear index, X fastest (paper layout)."""
        ix, iy, iz = coords[..., 0], coords[..., 1], coords[..., 2]
        return (iz * self.ny + iy) * self.nx + ix

    def cell_ids(self, positions: Array) -> Array:
        return self.linearize(self.cell_coords(positions))

    def neighbor_offsets(self) -> np.ndarray:
        """The (27, 3) stencil of neighbor cell offsets, X fastest ordering."""
        offs = [(dx, dy, dz)
                for dz in (-1, 0, 1) for dy in (-1, 0, 1) for dx in (-1, 0, 1)]
        return np.asarray(offs, dtype=np.int32)

    def minimum_image(self, delta: Array) -> Array:
        """Wrap a displacement vector into the minimum image (periodic axes)."""
        if not self.any_periodic:
            return delta
        box = jnp.asarray(self.box, dtype=delta.dtype)
        per = jnp.asarray(self.periodic_axes)
        return delta - jnp.where(per, box * jnp.round(delta / box), 0.0)

    def sample_uniform(self, key, n: int, dtype=jnp.float32) -> Array:
        """Uniformly distributed particles (the paper's benchmark input)."""
        import jax

        box = jnp.asarray(self.box, dtype=dtype)
        return jax.random.uniform(key, (n, 3), dtype=dtype) * box


def skin_domain(domain: Domain, skin: float) -> Domain:
    """The Verlet-skin twin of a domain: same box, cutoff and periodicity,
    but a grid coarse enough that every cell width is at least
    ``cutoff + skin`` (``repro.traj``).

    With that margin, bins built once remain *pair-complete* for the true
    cutoff while every particle has drifted less than ``skin / 2`` from
    its binned position — two particles within ``cutoff`` of each other
    now were within ``cutoff + skin`` at bin time, which the 27-cell
    neighborhood of the coarser grid still covers. The trajectory engine
    re-bins only when the max displacement predicate crosses ``skin / 2``
    (:func:`repro.core.binning.max_displacement`).

    The realizable margin is a property of the returned geometry, not the
    request: ``effective_skin`` reads it back (an axis shorter than
    ``cutoff + skin`` caps the margin at what its single cell provides).
    ``skin=0`` returns the domain unchanged — the always-rebin limit.
    """
    if skin < 0:
        raise ValueError(f"skin must be >= 0, got {skin}")
    if skin == 0:
        return domain
    width = domain.cutoff + skin
    ncells = tuple(max(1, int(length / width + 1e-9))
                   for length in domain.box)
    return Domain(box=domain.box, ncells=ncells, cutoff=domain.cutoff,
                  periodic=domain.periodic)


def effective_skin(domain: Domain) -> float:
    """The Verlet-skin margin a domain's grid actually provides:
    ``min(cell_width) - cutoff`` (>= 0 by the Domain validation). The
    trajectory engine's rebin predicate and skin-violation monitor are
    parameterized by this measured value, never the requested one."""
    return max(0.0, min(domain.cell_width) - domain.cutoff)


def slab_domain(domain: Domain, n_shards: int) -> Domain:
    """The Z-slab subdomain one halo shard owns (``repro.dist``).

    The global (nx, ny, nz) grid split into ``n_shards`` equal slabs along
    Z: same X/Y geometry, ``nz / n_shards`` planes, and Z forced
    *non-periodic* — a shard's Z ghost planes are filled by the halo
    exchange (wrapped neighbours under a periodic global Z, empty planes at
    the open boundaries), never by local wrapping.
    """
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    if domain.nz % n_shards:
        raise ValueError(
            f"nz={domain.nz} not divisible by n_shards={n_shards}")
    px, py, _ = domain.periodic_axes
    return Domain(
        box=(domain.box[0], domain.box[1], domain.box[2] / n_shards),
        ncells=(domain.nx, domain.ny, domain.nz // n_shards),
        cutoff=domain.cutoff, periodic=(px, py, False))
