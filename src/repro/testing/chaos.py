"""Seeded, deterministic fault injection for the particle path.

Every static bound in the fixed-capacity cell layout (``m_c``,
``max_active``, ``row_cap``, ``shard_cap``) is a latent failure mode, and a
production serving tier additionally faces non-finite kernel outputs,
transient backend errors, stragglers, and lost shards. None of that can be
*tested* without a way to make those faults happen on demand — this module
is that way.

Production code declares **fault points**: named sites threaded through
binning (``core.binning``), kernel dispatch (``core.dispatch``), serving
dispatch (``serve.dispatch``), the halo path (``dist.exchange``), and the
trajectory engine's segment boundaries (``traj.step`` — error/delay/
nonfinite between committed segments, ``traj.rebin`` — forced static-bound
overflow at the rebin check, ``traj.checkpoint`` and ``ckpt.save`` —
failures around the checkpoint commit, the latter emulating a crash
*before* the atomic rename so the kill-mid-save contract is testable). With
no active injection context every point is a cheap no-op (one global
``None`` check), so the fault-free hot path is untouched — the guarantee
``tests/test_chaos.py`` asserts bit-for-bit. Inside an
:func:`inject` context, registered :class:`FaultSpec`\\ s fire
deterministically: each spec draws from its own PRNG stream seeded from
``(seed, site, kind, index)``, so the same seed replays the same fault
schedule regardless of unrelated code running in between.

Fault kinds (the injectable failure modes of the ISSUE/ROADMAP):

========== ==============================================================
``error``     a transient backend exception (:class:`TransientBackendError`)
``nonfinite`` poison the outputs with a non-finite value (NaN by default)
``delay``     artificial latency — an emulated straggler (``param`` seconds)
``overflow``  force the overflow verdict — an emulated static-bound breach
``shard_loss`` a lost shard (:class:`ShardLost`) — the halo engine reacts
               with an elastic shrink (``dist.engine.elastic_shrink``)
========== ==============================================================

All fault points live at the *Python* dispatch boundary, never inside a
jitted body — a trace-time fault would be baked into the executor forever,
which is the opposite of a transient fault.
"""

from __future__ import annotations

import contextlib
import dataclasses
import math
import time as _time
import zlib
from typing import Dict, List, Optional, Tuple

import numpy as np

__all__ = [
    "FAULT_KINDS", "FaultSpec", "TransientBackendError", "ShardLost",
    "ChaosState", "inject", "active", "fire", "maybe_raise", "maybe_delay",
    "corrupt", "forced_overflow", "state", "snapshot",
]

FAULT_KINDS = ("error", "nonfinite", "delay", "overflow", "shard_loss")


class TransientBackendError(RuntimeError):
    """An injected (or real) transient executor failure — retryable."""


class ShardLost(RuntimeError):
    """A shard of a multi-device halo plan is gone (emulated). The
    resilience layer reacts by rebuilding at the surviving shard count
    (``dist.engine.elastic_shrink``) and re-executing."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One injectable fault: *where* (``site``), *what* (``kind``), and a
    deterministic firing schedule.

    A visit to a matching fault point fires the spec when (a) at least
    ``after`` earlier visits have been skipped, (b) fewer than
    ``max_fires`` firings have happened, and (c) a draw from the spec's
    seeded PRNG stream lands under ``p``. ``param`` is kind-specific:
    delay seconds for ``delay``, the poison value for ``nonfinite``
    (NaN when left at the default), ignored otherwise."""

    site: str
    kind: str
    p: float = 1.0                     # per-visit firing probability
    after: int = 0                     # skip the first ``after`` visits
    max_fires: Optional[int] = None    # stop firing after this many
    param: float = math.nan            # kind-specific knob

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; have {FAULT_KINDS}")
        if not 0.0 <= self.p <= 1.0:
            raise ValueError(f"p must be in [0, 1], got {self.p}")


class ChaosState:
    """The live registry of an :func:`inject` context: specs, per-spec
    PRNG streams, visit/fire counters, and the firing log."""

    def __init__(self, specs: Tuple[FaultSpec, ...], seed: int):
        self.specs = tuple(specs)
        self.seed = int(seed)
        self._rngs = [
            np.random.default_rng(
                zlib.crc32(f"{seed}:{s.site}:{s.kind}:{i}".encode()))
            for i, s in enumerate(self.specs)]
        self._visits: List[int] = [0] * len(self.specs)
        self._fires: List[int] = [0] * len(self.specs)
        self.log: List[Tuple[str, str, int]] = []   # (site, kind, visit)

    def fire(self, site: str, kind: str) -> Optional[FaultSpec]:
        """Visit the ``(site, kind)`` fault point; the first spec whose
        schedule fires wins (and is logged). None = no fault."""
        hit = None
        for i, s in enumerate(self.specs):
            if s.site != site or s.kind != kind:
                continue
            self._visits[i] += 1
            if hit is not None:
                continue                       # a spec already fired
            if self._visits[i] <= s.after:
                continue
            if s.max_fires is not None and self._fires[i] >= s.max_fires:
                continue
            if s.p < 1.0 and self._rngs[i].random() >= s.p:
                continue
            self._fires[i] += 1
            self.log.append((site, kind, self._visits[i]))
            hit = s
        return hit

    def fire_count(self, site: Optional[str] = None,
                   kind: Optional[str] = None) -> int:
        return sum(n for s, n in zip(self.specs, self._fires)
                   if (site is None or s.site == site)
                   and (kind is None or s.kind == kind))

    def snapshot(self) -> Dict[str, object]:
        """JSON-able fault-counter record (the chaos-smoke CI artifact)."""
        per_point: Dict[str, int] = {}
        for s, n in zip(self.specs, self._fires):
            key = f"{s.site}/{s.kind}"
            per_point[key] = per_point.get(key, 0) + n
        return {"seed": self.seed, "fires": per_point,
                "total_fires": sum(self._fires),
                "total_visits": sum(self._visits)}


# The active context. Module-global on purpose: fault points are called
# from deep inside the dispatch layers where no injection handle exists,
# and the whole point of the no-fault fast path is one ``is None`` check.
_ACTIVE: Optional[ChaosState] = None


@contextlib.contextmanager
def inject(*specs: FaultSpec, seed: int = 0):
    """Activate a fault schedule for the dynamic extent of the block.

    Yields the live :class:`ChaosState` (counters + firing log). Contexts
    nest; the previous schedule is restored on exit, and with no active
    context every fault point is a no-op."""
    global _ACTIVE
    prev = _ACTIVE
    st = ChaosState(specs, seed)
    _ACTIVE = st
    try:
        yield st
    finally:
        _ACTIVE = prev


def active() -> bool:
    """True inside an :func:`inject` context (the fast-path check every
    fault point performs first)."""
    return _ACTIVE is not None


def state() -> Optional[ChaosState]:
    """The live ChaosState, or None outside any injection context."""
    return _ACTIVE


def snapshot() -> Dict[str, object]:
    """The active context's fault counters (empty record when inactive)."""
    if _ACTIVE is None:
        return {"seed": None, "fires": {}, "total_fires": 0,
                "total_visits": 0}
    return _ACTIVE.snapshot()


def fire(site: str, kind: str) -> Optional[FaultSpec]:
    """Visit a fault point: the firing spec, or None (always None when no
    context is active)."""
    if _ACTIVE is None:
        return None
    return _ACTIVE.fire(site, kind)


def maybe_raise(site: str) -> None:
    """The exception-kind fault point: raises
    :class:`TransientBackendError` (kind ``error``) or :class:`ShardLost`
    (kind ``shard_loss``) when a matching spec fires."""
    if _ACTIVE is None:
        return
    if _ACTIVE.fire(site, "shard_loss") is not None:
        raise ShardLost(f"injected shard loss at {site!r}")
    if _ACTIVE.fire(site, "error") is not None:
        raise TransientBackendError(f"injected transient error at {site!r}")


def maybe_delay(site: str, sleep=_time.sleep) -> float:
    """The straggler fault point: sleeps ``spec.param`` seconds (via the
    injectable ``sleep``) and returns the delay (0.0 = no fault). Callers
    on a VirtualClock pass ``sleep=clock.advance`` so injected latency is
    simulated, not burned."""
    if _ACTIVE is None:
        return 0.0
    spec = _ACTIVE.fire(site, "delay")
    if spec is None:
        return 0.0
    dt = 0.0 if math.isnan(spec.param) else float(spec.param)
    if dt > 0.0:
        sleep(dt)
    return dt


def corrupt(site: str, *arrays):
    """The non-finite fault point: when a ``nonfinite`` spec fires, the
    first array comes back with its first element poisoned (NaN, or
    ``spec.param`` when set). Operates at the Python boundary on concrete
    outputs — the trace itself is never corrupted."""
    if _ACTIVE is None:
        return arrays if len(arrays) != 1 else arrays[0]
    spec = _ACTIVE.fire(site, "nonfinite")
    if spec is not None and arrays:
        first = arrays[0]
        poison = spec.param          # NaN by default
        flat = first.reshape(-1).at[0].set(poison)
        arrays = (flat.reshape(first.shape),) + tuple(arrays[1:])
    return arrays if len(arrays) != 1 else arrays[0]


def forced_overflow(site: str) -> bool:
    """The overflow fault point: True when an ``overflow`` spec fires —
    the caller must behave exactly as if a static bound had been measured
    as exceeded (emulating a skewed distribution breaching ``m_c`` /
    ``row_cap`` / ``max_active`` / ``shard_cap``)."""
    if _ACTIVE is None:
        return False
    return _ACTIVE.fire(site, "overflow") is not None
