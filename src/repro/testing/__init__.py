"""Test-time machinery that ships with the library.

``repro.testing.chaos`` is the seeded fault-injection registry the
resilience layer is tested against (tests/test_chaos.py, the chaos-smoke
CI job, and ``benchmarks/fig_serve.py --chaos``). Production code paths
call its fault points unconditionally; with no active injection context
every point is a near-zero-cost no-op.
"""

from . import chaos

__all__ = ["chaos"]
