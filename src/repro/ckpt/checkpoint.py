"""Sharded checkpointing with atomic commit and restore-time resharding.

Layout: ``<dir>/step_<N>/`` containing one ``.npy`` per pytree leaf (flat
key = '/'-joined path) + ``manifest.json`` (tree structure, dtypes, step,
data-pipeline cursor). A checkpoint directory is written under a temp name
and atomically renamed — a crashed writer never leaves a half checkpoint
that restore would accept (fault-tolerance contract, tested).

Atomicity audit (kill-mid-save contract, ``tests/test_traj.py``):

* the temp dir name carries the writer's pid (``.tmp_<pid>_...``); temp
  dirs of *dead* writers are swept on the next ``save`` — a hard kill can
  leak at most one temp dir, and only until the next save. ``latest_step``
  / ``restore`` never look at dotted names, so a leaked temp dir is
  invisible to readers.
* overwriting an existing ``step_<N>`` never deletes it before the new
  data is committed: the old dir is moved aside to ``.old_<pid>_<N>``,
  the temp dir is renamed in (atomic), and only then is the old copy
  removed. A kill in the move-aside window is repaired by the sweep: a
  dead writer's ``.old`` dir is renamed back when ``step_<N>`` is
  missing, discarded when the rename-in did commit.
* a kill at *any* other instant leaves either no ``step_<N>`` or a fully
  committed one — ``os.replace`` is the only publication point.


Restore is resharding-agnostic: leaves come back as host arrays and are
``jax.device_put`` against whatever sharding the *new* mesh prescribes —
this is what makes elastic re-mesh restarts (dist.fault) work.
"""

from __future__ import annotations

import json
import os
import pathlib
import shutil
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

PyTree = Any


class CheckpointCorrupt(RuntimeError):
    """A checkpoint directory exists but is not loadable (truncated
    manifest, missing leaf file). Subclasses ``RuntimeError`` so the
    restart driver (``dist.fault.run_with_restarts``) treats it like any
    other recoverable failure."""


def _corruption(d: pathlib.Path) -> Optional[str]:
    """Why ``step_<N>`` directory ``d`` is not restorable, or None if it
    looks intact (manifest parses, every manifest key's leaf file
    exists)."""
    mpath = d / "manifest.json"
    if not mpath.exists():
        return "missing manifest.json"
    try:
        manifest = json.loads(mpath.read_text())
    except (json.JSONDecodeError, OSError, UnicodeDecodeError) as e:
        return f"unreadable manifest.json ({e})"
    keys = manifest.get("keys")
    if not isinstance(keys, list):
        return "manifest.json has no 'keys' list"
    for key in keys:
        if not (d / (str(key).replace("/", "__") + ".npy")).exists():
            return f"missing leaf file for key {key!r}"
    return None


def is_intact(step_dir: str | pathlib.Path) -> bool:
    """True if ``step_dir`` is a restorable checkpoint (see module
    docstring for the commit contract this verifies)."""
    return _corruption(pathlib.Path(step_dir)) is None


def _flatten(tree: PyTree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf
    return flat


def _pid_alive(pid: int) -> bool:
    try:
        os.kill(pid, 0)
    except ProcessLookupError:
        return False
    except PermissionError:
        return True     # exists, owned by another user: alive
    except OSError:
        return False
    return True


def _writer_pid(name: str) -> Optional[int]:
    """pid embedded in a ``.tmp_<pid>_...`` / ``.old_<pid>_<step>`` name,
    or None for legacy / foreign dotted names."""
    parts = name.split("_")
    if len(parts) >= 3 and parts[0] in (".tmp", ".old"):
        try:
            return int(parts[1])
        except ValueError:
            return None
    return None


def sweep_stale(ckpt_dir: str | pathlib.Path) -> int:
    """Clean up after killed writers: delete ``.tmp`` dirs whose writer
    pid is dead, and repair ``.old`` dirs — renamed back to their
    ``step_<N>`` when the kill happened in the move-aside window (the new
    save never committed), deleted when the commit did land. Returns the
    number of entries handled. Called by every ``save``; idempotent."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    if not ckpt_dir.exists():
        return 0
    handled = 0
    for d in ckpt_dir.iterdir():
        pid = _writer_pid(d.name)
        if pid is None or _pid_alive(pid):
            continue
        if d.name.startswith(".tmp_"):
            shutil.rmtree(d, ignore_errors=True)
            handled += 1
        elif d.name.startswith(".old_"):
            step_name = "step_" + d.name.split("_", 2)[2]
            final = ckpt_dir / step_name
            if final.exists():
                shutil.rmtree(d, ignore_errors=True)
            else:
                os.replace(d, final)    # the new save never committed
            handled += 1
    return handled


def save(ckpt_dir: str | pathlib.Path, step: int, tree: PyTree,
         extra: Optional[Dict] = None) -> pathlib.Path:
    """Write ``step_<N>``; atomic rename commit (see the atomicity audit
    in the module docstring). Returns the final path."""
    from ..testing import chaos

    ckpt_dir = pathlib.Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    sweep_stale(ckpt_dir)
    pid = os.getpid()
    final = ckpt_dir / f"step_{step:08d}"
    tmp = pathlib.Path(tempfile.mkdtemp(dir=ckpt_dir,
                                        prefix=f".tmp_{pid}_"))
    flat = _flatten(tree)
    manifest = {"step": step, "keys": sorted(flat), "extra": extra or {}}
    old = ckpt_dir / f".old_{pid}_{step:08d}"
    moved_aside = False
    try:
        for key, leaf in flat.items():
            arr = np.asarray(jax.device_get(leaf))
            np.save(tmp / (key.replace("/", "__") + ".npy"), arr)
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        chaos.maybe_raise("ckpt.save")   # emulated crash before commit
        if final.exists():
            # a stale .old from an earlier partial cleanup (or pid reuse)
            # would make os.replace fail with ENOTEMPTY
            shutil.rmtree(old, ignore_errors=True)
            os.replace(final, old)       # move aside, never delete first
            moved_aside = True
        os.replace(tmp, final)           # atomic commit
        if moved_aside:
            shutil.rmtree(old, ignore_errors=True)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        if moved_aside and not final.exists():
            os.replace(old, final)       # undo the move-aside
        raise
    return final


def latest_step(ckpt_dir: str | pathlib.Path) -> Optional[int]:
    """Newest *intact* committed step, or None. A corrupt newest
    checkpoint (truncated manifest, missing leaf) is skipped so restarts
    fall back to the last restorable one instead of crash-looping on it."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = []
    for d in ckpt_dir.iterdir():
        if d.name.startswith("step_") and _corruption(d) is None:
            steps.append(int(d.name.split("_")[1]))
    return max(steps) if steps else None


def read_extra(ckpt_dir: str | pathlib.Path, step: int) -> Dict:
    """The ``extra`` dict of a committed step's manifest, without loading
    any leaves — how a resuming trajectory learns the grown static bounds
    it must rebuild its restore template with (``repro.traj``)."""
    d = pathlib.Path(ckpt_dir) / f"step_{step:08d}"
    why = _corruption(d)
    if why is not None:
        raise CheckpointCorrupt(f"checkpoint {d} is corrupt: {why}")
    return json.loads((d / "manifest.json").read_text()).get("extra", {})


def restore(ckpt_dir: str | pathlib.Path, tree_like: PyTree,
            step: Optional[int] = None,
            shardings: Optional[PyTree] = None) -> Tuple[PyTree, Dict]:
    """Load into the structure of ``tree_like``; device_put against
    ``shardings`` when given (elastic re-mesh restore path)."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = ckpt_dir / f"step_{step:08d}"
    why = _corruption(d) if d.exists() else None
    if why is not None:
        raise CheckpointCorrupt(f"checkpoint {d} is corrupt: {why}")
    manifest = json.loads((d / "manifest.json").read_text())

    flat_spec = _flatten(tree_like)
    flat_shard = _flatten(shardings) if shardings is not None else None
    loaded = {}
    for key in flat_spec:
        arr = np.load(d / (key.replace("/", "__") + ".npy"))
        if flat_shard is not None:
            loaded[key] = jax.device_put(arr, flat_shard[key])
        else:
            loaded[key] = jax.numpy.asarray(arr)

    leaves_with_path = jax.tree_util.tree_flatten_with_path(tree_like)
    treedef = leaves_with_path[1]
    ordered = []
    for path, _ in leaves_with_path[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        ordered.append(loaded[key])
    return jax.tree_util.tree_unflatten(treedef, ordered), manifest["extra"]
