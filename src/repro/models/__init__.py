"""LM substrate: layers, attention, MoE, SSM, and per-family assembly."""

from . import attention, layers, model, moe, ssm

__all__ = ["attention", "layers", "model", "moe", "ssm"]
