"""Attention: chunked-flash (custom VJP), pencil-window, and decode paths.

Three implementations, chosen by shape/kind (all pure XLA so the multi-pod
dry-run compiles on any backend; ``kernels/window_attn.py`` is the Pallas
version of the window path for real TPUs):

  flash_attention   full causal attention as a double scan over (q, kv)
                    chunks with online softmax and a custom VJP that
                    recomputes per block — no S^2 residuals, which is what
                    makes prefill_32k / train_4k fit.
  window_attention_blocked
                    sliding-window attention via the paper's pencil trick
                    (DESIGN.md §4): tokens are regrouped into window-sized
                    blocks and each block attends to (previous, self) only —
                    compute and memory are O(S * window), never O(S^2).
  decode_attention  one-token-vs-cache masked einsum (serve_step).

All paths support GQA natively (no KV repetition) and gemma2's logit softcap.
Scores/accumulators are fp32 regardless of input dtype.
"""

from __future__ import annotations

import functools
import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jnp.ndarray
NEG_INF = -1.0e30


def attention(q: Array, k: Array, v: Array, causal: bool, softcap: float,
              q_chunk: int, k_chunk: int) -> Array:
    """Production path: chunked flash. REPRO_DENSE_ATTN=1 (roofline cost
    runs only) switches to a dense masked einsum so XLA's cost analysis sees
    every FLOP — the flash scans are while-loops that HloCostAnalysis counts
    once (launch/costrun.py)."""
    if os.environ.get("REPRO_DENSE_ATTN"):
        return _dense_attention(q, k, v, causal, softcap)
    return flash_attention(q, k, v, causal, softcap, q_chunk, k_chunk)


def _dense_attention(q: Array, k: Array, v: Array, causal: bool,
                     softcap: float) -> Array:
    b, h, sq, d = q.shape
    kh, skv = k.shape[1], k.shape[2]
    qg = _split_gqa(q, kh).astype(jnp.float32) * (d ** -0.5)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qg, k.astype(jnp.float32))
    s = _softcap(s, softcap)
    if causal:
        qp = jax.lax.broadcasted_iota(jnp.int32, (sq, skv), 0)
        kp = jax.lax.broadcasted_iota(jnp.int32, (sq, skv), 1)
        s = jnp.where(qp >= kp, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", p, v.astype(jnp.float32))
    return o.reshape(b, h, sq, d).astype(q.dtype)


def _softcap(s: Array, cap: float) -> Array:
    return cap * jnp.tanh(s / cap) if cap > 0.0 else s


def _softcap_grad(s_capped: Array, cap: float) -> Array:
    """d softcap / d s, expressed from the *capped* value (recompute-free)."""
    if cap <= 0.0:
        return jnp.ones_like(s_capped)
    t = s_capped / cap
    return 1.0 - t * t


def _split_gqa(q: Array, kh: int) -> Array:
    b, h, s, d = q.shape
    return q.reshape(b, kh, h // kh, s, d)


def _chunk_for(s: int, target: int) -> int:
    """Largest divisor of s that is <= target (vlm prefixes make S odd-sized)."""
    c = min(target, s)
    while s % c:
        c -= 1
    return c


def _scores(q: Array, k: Array, softcap: float) -> Array:
    """q (b, kh, g, qc, d) x k (b, kh, kc, d) -> fp32 (b, kh, g, qc, kc)."""
    s = jnp.einsum("bkgqd,bksd->bkgqs", q, k,
                   preferred_element_type=jnp.float32)
    return _softcap(s, softcap)


# ---------------------------------------------------------------------------
# full causal flash (double chunk scan, custom VJP)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention(q: Array, k: Array, v: Array, causal: bool = True,
                    softcap: float = 0.0, q_chunk: int = 512,
                    k_chunk: int = 512) -> Array:
    """Memory-efficient attention. q (B,H,Sq,D); k,v (B,KH,Skv,D)."""
    out, _ = _flash_fwd(q, k, v, causal, softcap, q_chunk, k_chunk)
    return out


def _flash_fwd(q, k, v, causal, softcap, q_chunk, k_chunk):
    b, h, sq, d = q.shape
    kh, skv = k.shape[1], k.shape[2]
    g = h // kh
    qc, kc = _chunk_for(sq, q_chunk), _chunk_for(skv, k_chunk)
    nq, nk = sq // qc, skv // kc

    scale = d ** -0.5
    qg = (_split_gqa(q, kh) * scale).reshape(b, kh, g, nq, qc, d)
    kc_ = k.reshape(b, kh, nk, kc, d)
    vc_ = v.reshape(b, kh, nk, kc, d)

    def q_step(_, qi):
        qblk = qg[:, :, :, qi]                      # (b, kh, g, qc, d)

        def kv_step(carry, ki):
            m_prev, l_prev, acc = carry
            s = _scores(qblk, kc_[:, :, ki], softcap)
            if causal:
                qpos = qi * qc + jax.lax.broadcasted_iota(
                    jnp.int32, (qc, kc), 0)
                kpos = ki * kc + jax.lax.broadcasted_iota(
                    jnp.int32, (qc, kc), 1)
                s = jnp.where(qpos >= kpos, s, NEG_INF)
            m_cur = jnp.max(s, axis=-1, keepdims=True)
            m_new = jnp.maximum(m_prev, m_cur)
            p = jnp.exp(s - m_new)
            alpha = jnp.exp(m_prev - m_new)
            l_new = l_prev * alpha + p.sum(-1, keepdims=True)
            acc = acc * alpha + jnp.einsum(
                "bkgqs,bksd->bkgqd", p, vc_[:, :, ki],
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc), None

        init = (jnp.full((b, kh, g, qc, 1), NEG_INF, jnp.float32),
                jnp.zeros((b, kh, g, qc, 1), jnp.float32),
                jnp.zeros((b, kh, g, qc, d), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(kv_step, init, jnp.arange(nk))
        l = jnp.maximum(l, 1e-30)
        lse = (m + jnp.log(l))[..., 0]              # (b, kh, g, qc)
        return None, (acc / l, lse)

    _, (outs, lses) = jax.lax.scan(q_step, None, jnp.arange(nq))
    # outs: (nq, b, kh, g, qc, d) -> (b, h, sq, d)
    out = jnp.moveaxis(outs, 0, 3).reshape(b, kh, g, sq, d)
    out = out.reshape(b, h, sq, d).astype(q.dtype)
    lse = jnp.moveaxis(lses, 0, 3).reshape(b, kh, g, sq)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, softcap, q_chunk, k_chunk, res, dout):
    q, k, v, out, lse = res
    b, h, sq, d = q.shape
    kh, skv = k.shape[1], k.shape[2]
    g = h // kh
    qc, kc = _chunk_for(sq, q_chunk), _chunk_for(skv, k_chunk)
    nq, nk = sq // qc, skv // kc
    scale = d ** -0.5

    qg = (_split_gqa(q, kh) * scale).reshape(b, kh, g, nq, qc, d)
    kc_ = k.reshape(b, kh, nk, kc, d)
    vc_ = v.reshape(b, kh, nk, kc, d)
    do = _split_gqa(dout.astype(jnp.float32), kh).reshape(b, kh, g, nq, qc, d)
    og = _split_gqa(out.astype(jnp.float32), kh).reshape(b, kh, g, nq, qc, d)
    lse_c = lse.reshape(b, kh, g, nq, qc)
    delta = jnp.sum(do * og, axis=-1)               # (b, kh, g, nq, qc)

    def q_step(carry, qi):
        dk_acc, dv_acc = carry
        qblk, doblk, dblk = qg[:, :, :, qi], do[:, :, :, qi], delta[:, :, :, qi]
        lseblk = lse_c[:, :, :, qi]

        def kv_step(inner, ki):
            dq_blk, dk_acc, dv_acc = inner
            s = _scores(qblk, kc_[:, :, ki], softcap)
            if causal:
                qpos = qi * qc + jax.lax.broadcasted_iota(
                    jnp.int32, (qc, kc), 0)
                kpos = ki * kc + jax.lax.broadcasted_iota(
                    jnp.int32, (qc, kc), 1)
                s = jnp.where(qpos >= kpos, s, NEG_INF)
            p = jnp.exp(s - lseblk[..., None])      # (b, kh, g, qc, kc)
            dv_c = jnp.einsum("bkgqs,bkgqd->bksd", p, doblk,
                              preferred_element_type=jnp.float32)
            dp = jnp.einsum("bkgqd,bksd->bkgqs", doblk, vc_[:, :, ki],
                            preferred_element_type=jnp.float32)
            ds = p * (dp - dblk[..., None])
            if softcap > 0.0:
                # s already holds the capped value; clip absorbs the masked
                # NEG_INF entries (p == 0 there, so any finite grad works).
                t = jnp.clip(s / softcap, -1.0, 1.0)
                ds = ds * (1.0 - t * t)
            dq_blk = dq_blk + jnp.einsum(
                "bkgqs,bksd->bkgqd", ds, kc_[:, :, ki],
                preferred_element_type=jnp.float32)
            dk_c = jnp.einsum("bkgqs,bkgqd->bksd", ds, qblk,
                              preferred_element_type=jnp.float32)
            dk_acc = dk_acc.at[:, :, ki].add(dk_c)
            dv_acc = dv_acc.at[:, :, ki].add(dv_c)
            return (dq_blk, dk_acc, dv_acc), None

        dq0 = jnp.zeros((b, kh, g, qc, d), jnp.float32)
        (dq_blk, dk_acc, dv_acc), _ = jax.lax.scan(
            kv_step, (dq0, dk_acc, dv_acc), jnp.arange(nk))
        return (dk_acc, dv_acc), dq_blk * scale

    zeros_kv = jnp.zeros((b, kh, nk, kc, d), jnp.float32)
    (dk, dv), dqs = jax.lax.scan(q_step, (zeros_kv, zeros_kv),
                                 jnp.arange(nq))
    dq = jnp.moveaxis(dqs, 0, 3).reshape(b, kh, g, sq, d).reshape(b, h, sq, d)
    return (dq.astype(q.dtype),
            dk.reshape(b, kh, skv, d).astype(k.dtype),
            dv.reshape(b, kh, skv, d).astype(v.dtype))


flash_attention.defvjp(_flash_fwd, _flash_bwd)


# ---------------------------------------------------------------------------
# pencil-window attention (the paper's cutoff transferred; O(S * window))
# ---------------------------------------------------------------------------

def window_attention_blocked(q: Array, k: Array, v: Array, *, window: int,
                             softcap: float = 0.0) -> Array:
    """Causal sliding-window attention via two-block pencils.

    Tokens are grouped into blocks of ``window``; block i attends to blocks
    (i-1, i) with the exact (q - k < window, k <= q) mask — the 1-D causal
    version of the X-pencil's contiguous 3-cell window. Out-of-window keys
    are never materialized. Requires S % window == 0 (configs satisfy this;
    the serving path pads otherwise).
    """
    b, h, s, d = q.shape
    kh = k.shape[1]
    g = h // kh
    assert s % window == 0, (s, window)
    nb = s // window
    scale = d ** -0.5

    qb = _split_gqa(q, kh).reshape(b, kh, g, nb, window, d) * scale
    kb = k.reshape(b, kh, nb, window, d)
    vb = v.reshape(b, kh, nb, window, d)
    # previous block (pencil neighbor): shift right, zero-pad block -1
    k_prev = jnp.pad(kb[:, :, :-1], ((0, 0), (0, 0), (1, 0), (0, 0), (0, 0)))
    v_prev = jnp.pad(vb[:, :, :-1], ((0, 0), (0, 0), (1, 0), (0, 0), (0, 0)))
    k2 = jnp.concatenate([k_prev, kb], axis=3)       # (b, kh, nb, 2w, d)
    v2 = jnp.concatenate([v_prev, vb], axis=3)

    sc = jnp.einsum("bkgnqd,bknsd->bkgnqs", qb, k2,
                    preferred_element_type=jnp.float32)
    sc = _softcap(sc, softcap)
    qpos = jax.lax.broadcasted_iota(jnp.int32, (window, 2 * window), 0) + window
    kpos = jax.lax.broadcasted_iota(jnp.int32, (window, 2 * window), 1)
    mask = (kpos <= qpos) & (qpos - kpos < window)
    first = jax.lax.broadcasted_iota(jnp.int32, (nb, 1, 1), 0) > 0
    mask = mask[None, :, :] & (first | (kpos[None] >= window))
    sc = jnp.where(mask[None, None, None], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bkgnqs,bknsd->bkgnqd", p, v2,
                   preferred_element_type=jnp.float32)
    return o.reshape(b, h, s, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# decode: one new token vs cache
# ---------------------------------------------------------------------------

def decode_attention(q: Array, k_cache: Array, v_cache: Array,
                     cache_index: Array, *, window: int = 0,
                     softcap: float = 0.0,
                     window_flag: Optional[Array] = None) -> Array:
    """q (B,H,1,D) vs cache (B,KH,S,D); positions > cache_index are masked
    (and positions <= cache_index - window when window > 0). ``window_flag``
    (traced bool) gates the window mask at runtime — gemma2's local/global
    alternation inside a layer scan."""
    b, h, _, d = q.shape
    kh, s = k_cache.shape[1], k_cache.shape[2]
    qg = _split_gqa(q, kh) * (d ** -0.5)             # (b, kh, g, 1, d)
    sc = jnp.einsum("bkgqd,bksd->bkgqs", qg, k_cache,
                    preferred_element_type=jnp.float32)
    sc = _softcap(sc, softcap)
    kpos = jnp.arange(s, dtype=jnp.int32)
    valid = kpos <= cache_index
    if window > 0:
        in_window = kpos > cache_index - window
        if window_flag is None:
            valid = valid & in_window
        else:
            valid = valid & (in_window | ~window_flag)
    sc = jnp.where(valid[None, None, None, None, :], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", p, v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(b, h, 1, d).astype(q.dtype)
