"""Model assembly: init / forward / prefill / decode for all 10 families.

Functional style: ``init_params(cfg, key) -> params`` (nested dict, layer
weights stacked over the leading L dim) and pure apply functions. Layers run
under ``lax.scan`` with optional remat — this keeps the HLO size independent
of depth, which is what makes 314B/480B configs lowerable and compilable on
the 512-device dry-run mesh.

Modes:
  forward      full-sequence logits (train loss / prefill scoring)
  prefill      full sequence -> (logits, decode cache)
  decode_step  one token + cache -> (logits, updated cache)

Family wiring:
  dense / moe / vlm : decoder-only transformer (MoE swaps the MLP)
  ssm               : mamba2 stack (attention-free)
  hybrid            : mamba2 stack + one *shared* attn+MLP block every
                      ``hybrid_attn_every`` layers (zamba2; weights shared,
                      caches per invocation)
  audio             : enc-dec (whisper); conv frontend stubbed by
                      ``frame_embeds`` inputs per the assignment
  vlm               : decoder with stub ``patch_embeds`` prepended
"""

from __future__ import annotations

import os
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..dist.sharding import constrain

from ..configs.base import ModelConfig
from .attention import (attention, decode_attention,
                        window_attention_blocked)
from .layers import (apply_norm, embed_tokens, init_attn, init_embed,
                     init_mlp, init_norm, mlp, out_project, qkv_project,
                     rope, sinusoidal_positions)
from .moe import init_moe, moe_mlp
from .ssm import (init_mamba2, mamba2_block, mamba2_decode)

Array = jnp.ndarray
Params = Dict[str, Any]

# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_layer(cfg: ModelConfig, key, dtype) -> Params:
    """One decoder layer's params (unstacked)."""
    ks = jax.random.split(key, 4)
    p: Params = {"norm1": init_norm(cfg.d_model, cfg.norm, dtype),
                 "norm2": init_norm(cfg.d_model, cfg.norm, dtype)}
    if cfg.post_norms:
        p["post_norm1"] = init_norm(cfg.d_model, cfg.norm, dtype)
        p["post_norm2"] = init_norm(cfg.d_model, cfg.norm, dtype)
    if cfg.family in ("ssm", "hybrid"):
        p["mamba"] = init_mamba2(ks[0], cfg.d_model, cfg.d_inner,
                                 cfg.ssm_heads, cfg.ssm_state, cfg.ssm_conv,
                                 dtype)
        return p
    p["attn"] = init_attn(ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                          cfg.head_dim, dtype, bias=cfg.qkv_bias)
    if cfg.n_experts:
        p["moe"] = init_moe(ks[1], cfg.d_model, cfg.d_ff, cfg.n_experts,
                            dtype)
        if cfg.moe_dense_residual:
            p["mlp"] = init_mlp(ks[2], cfg.d_model, cfg.d_ff, dtype,
                                cfg.mlp_gated)
    else:
        p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype,
                            cfg.mlp_gated)
    return p


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_params(cfg: ModelConfig, key) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, cfg.n_layers + 8)
    params: Params = {
        "embed": init_embed(keys[0], cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": init_norm(cfg.d_model, cfg.norm, dtype),
        "layers": _stack([_init_layer(cfg, keys[1 + i], dtype)
                          for i in range(cfg.n_layers)]),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init_embed(keys[-1], cfg.vocab_size,
                                       cfg.d_model, dtype).T
    if cfg.family == "hybrid" and cfg.hybrid_attn_every:
        params["shared_attn"] = {
            "norm1": init_norm(cfg.d_model, cfg.norm, dtype),
            "norm2": init_norm(cfg.d_model, cfg.norm, dtype),
            "attn": init_attn(keys[-2], cfg.d_model, cfg.n_heads,
                              cfg.n_kv_heads, cfg.head_dim, dtype),
            "mlp": init_mlp(keys[-3], cfg.d_model, cfg.d_ff, dtype,
                            cfg.mlp_gated),
        }
    if cfg.n_enc_layers:
        enc_keys = jax.random.split(keys[-4], cfg.n_enc_layers)
        params["enc_layers"] = _stack([
            {"norm1": init_norm(cfg.d_model, cfg.norm, dtype),
             "norm2": init_norm(cfg.d_model, cfg.norm, dtype),
             "attn": init_attn(k, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                               cfg.head_dim, dtype),
             "mlp": init_mlp(jax.random.fold_in(k, 1), cfg.d_model,
                             cfg.d_ff, dtype, cfg.mlp_gated)}
            for k in enc_keys])
        params["enc_final_norm"] = init_norm(cfg.d_model, cfg.norm, dtype)
        xkeys = jax.random.split(keys[-5], cfg.n_layers)
        params["cross_attn"] = _stack([
            {"norm": init_norm(cfg.d_model, cfg.norm, dtype),
             "attn": init_attn(k, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                               cfg.head_dim, dtype)}
            for k in xkeys])
    return params


# ---------------------------------------------------------------------------
# building blocks
# ---------------------------------------------------------------------------


def _self_attention(cfg: ModelConfig, p: Params, x: Array, positions: Array,
                    is_local: bool) -> Tuple[Array, Array, Array]:
    """-> (projected output, k, v) — k/v reused by prefill cache building."""
    q, k, v = qkv_project(x, p, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim)
    q = constrain(q, "dp", "tp", None, None)
    k = constrain(k, "dp", "tp", None, None)
    v = constrain(v, "dp", "tp", None, None)
    if cfg.use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    if is_local and cfg.window < x.shape[1]:
        o = window_attention_blocked(q, k, v, window=cfg.window,
                                     softcap=cfg.attn_softcap)
    else:
        o = attention(q, k, v, True, cfg.attn_softcap,
                      cfg.attn_q_chunk, cfg.attn_k_chunk)
    return out_project(o, p), k, v


_FSDP_GATHER_RULES = {
    # leaf name -> spec roles with the fsdp (weight-resting) axis dropped.
    # Applying these inside the layer body makes GSPMD all-gather each
    # layer's weights just in time (ZeRO-3) instead of keeping them
    # stationary and all-reducing activation partials over the data axis —
    # measured 64.3 -> ~2 GB/device collective on qwen train_4k (§Perf).
    "wq": (None, "tp"), "wk": (None, "tp"), "wv": (None, "tp"),
    "wo": ("tp", None),
    "w_gate": (None, "tp"), "w_up": (None, "tp"), "w_down": ("tp", None),
    "in_proj": (None, "tp"), "out_proj": ("tp", None),
    "router": (None, None),
}

_FSDP_GATHER_RULES_MOE_EP = {
    "w_gate": ("tp", None, None), "w_up": ("tp", None, None),
    "w_down": ("tp", None, None), "router": (None, None),
}

_FSDP_GATHER_RULES_MOE_TP = {
    "w_gate": (None, None, "tp"), "w_up": (None, None, "tp"),
    "w_down": (None, "tp", None), "router": (None, None),
}


def _gather_fsdp(p: Params, moe_ep: Optional[bool] = None) -> Params:
    from ..models.moe import _ep

    def one(path, leaf):
        name = getattr(path[-1], "key", "")
        names = [getattr(k, "key", "") for k in path]
        if "moe" in names and name in _FSDP_GATHER_RULES_MOE_EP:
            rules = _FSDP_GATHER_RULES_MOE_EP if _ep(leaf.shape[0]) \
                else _FSDP_GATHER_RULES_MOE_TP
            return constrain(leaf, *rules[name])
        if name in _FSDP_GATHER_RULES and leaf.ndim == len(
                _FSDP_GATHER_RULES[name]):
            return constrain(leaf, *_FSDP_GATHER_RULES[name])
        return leaf

    return jax.tree_util.tree_map_with_path(one, p)


def _maybe_post(cfg: ModelConfig, p: Params, name: str, h: Array) -> Array:
    if cfg.post_norms:
        return apply_norm(h, p[name], cfg.norm)
    return h


def _mlp_or_moe(cfg: ModelConfig, p: Params, x: Array) -> Tuple[Array, Array]:
    if cfg.n_experts:
        out, aux = moe_mlp(x, p["moe"], top_k=cfg.top_k,
                           capacity_factor=cfg.capacity_factor, act=cfg.act)
        if cfg.moe_dense_residual:
            out = out + mlp(x, p["mlp"], cfg.act)
        return out, aux
    return mlp(x, p["mlp"], cfg.act), jnp.zeros((), jnp.float32)


def _decoder_layer(cfg: ModelConfig, p: Params, x: Array, positions: Array,
                   is_local: bool) -> Tuple[Array, Array, Array, Array]:
    """-> (x, aux_loss, k, v).

    The residual stream is sequence-sharded over the TP axis between blocks
    (Megatron-SP): the scan carry and the per-layer remat residual shrink by
    the TP degree — 51 GiB -> 3.2 GiB on grok-1 train_4k (§Perf)."""
    x = constrain(x, "dp", "tp", None)
    p = _gather_fsdp(p)
    h, k, v = _self_attention(cfg, p["attn"],
                              apply_norm(x, p["norm1"], cfg.norm),
                              positions, is_local)
    x = x + _maybe_post(cfg, p, "post_norm1", h)
    h, aux = _mlp_or_moe(cfg, p, apply_norm(x, p["norm2"], cfg.norm))
    x = x + _maybe_post(cfg, p, "post_norm2", h)
    return x, aux, k, v


def _mamba_layer(cfg: ModelConfig, p: Params, x: Array) -> Array:
    x = constrain(x, "dp", "tp", None)     # sequence-sharded residual (SP)
    p = _gather_fsdp(p)
    h = mamba2_block(apply_norm(x, p["norm1"], cfg.norm), p["mamba"],
                     d_inner=cfg.d_inner, state=cfg.ssm_state,
                     n_heads=cfg.ssm_heads, headdim=cfg.ssm_headdim,
                     chunk=cfg.ssm_chunk)
    return x + h


def _remat(fn, enabled: bool = True):
    if not enabled:
        return fn
    return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)


def _scan(body, carry, xs):
    """lax.scan with an env-controlled unroll.

    REPRO_SCAN_UNROLL=full makes the roofline dry-run unroll layer loops so
    ``cost_analysis`` counts every layer (XLA's HloCostAnalysis visits a
    while-body exactly once — measured 24x FLOP undercount on the default
    scan path; EXPERIMENTS.md §Roofline methodology)."""
    unroll = os.environ.get("REPRO_SCAN_UNROLL", "1")
    if unroll == "full":
        return jax.lax.scan(body, carry, xs, unroll=True)
    return jax.lax.scan(body, carry, xs, unroll=int(unroll))


# ---------------------------------------------------------------------------
# stacks
# ---------------------------------------------------------------------------


def _run_decoder_stack(cfg: ModelConfig, params: Params, x: Array,
                       positions: Array, remat: bool,
                       enc_h: Optional[Array] = None,
                       collect_kv: bool = False):
    """Scan over stacked decoder layers -> (x, aux_loss, kv or None)."""
    zero = jnp.zeros((), jnp.float32)
    if cfg.family in ("ssm", "hybrid"):
        return _run_mamba_stack(cfg, params, x, positions, remat), zero, None

    if cfg.local_global:
        # gemma2: scan over (local, global) layer pairs — no lax.cond, so
        # compiled FLOPs reflect the real local/global split.
        pairs = jax.tree.map(
            lambda a: a.reshape(cfg.n_layers // 2, 2, *a.shape[1:]),
            params["layers"])

        # aux rides in ys, not the carry: a mixed bf16/f32 carry makes the
        # scan AD save an f32 copy of the whole residual stack (§Perf).
        def pair_body(h, lp):
            h, a1, k1, v1 = _decoder_layer(
                cfg, jax.tree.map(lambda a: a[0], lp), h, positions, True)
            h, a2, k2, v2 = _decoder_layer(
                cfg, jax.tree.map(lambda a: a[1], lp), h, positions, False)
            kv = (jnp.stack([k1, k2]), jnp.stack([v1, v2])) \
                if collect_kv else None
            return h, (a1 + a2, kv)

        x, (auxs, kvs) = _scan(_remat(pair_body, remat), x, pairs)
        if collect_kv:
            ks, vs = kvs
            ks = ks.reshape(cfg.n_layers, *ks.shape[2:])
            vs = vs.reshape(cfg.n_layers, *vs.shape[2:])
            return x, auxs.sum(), (ks, vs)
        return x, auxs.sum(), None

    def body(h, inp):
        if enc_h is None:
            lp = inp
            h, a, k, v = _decoder_layer(cfg, lp, h, positions, False)
        else:
            lp, xp = inp
            h, a, k, v = _decoder_layer(cfg, lp, h, positions, False)
            h = h + _cross_attention(cfg, xp, h, enc_h)
        return h, (a, (k, v) if collect_kv else None)

    xs = params["layers"]
    if enc_h is not None:
        xs = (params["layers"], params["cross_attn"])
    x, (auxs, kvs) = _scan(_remat(body, remat), x, xs)
    return x, auxs.sum(), kvs


def _cross_attention(cfg: ModelConfig, xp: Params, h: Array,
                     enc_h: Array) -> Array:
    hq = apply_norm(h, xp["norm"], cfg.norm)
    q, _, _ = qkv_project(hq, xp["attn"], cfg.n_heads, cfg.n_kv_heads,
                          cfg.head_dim)
    b, se, _ = enc_h.shape
    kx = (enc_h @ xp["attn"]["wk"]).reshape(
        b, se, cfg.n_kv_heads, cfg.head_dim).transpose(0, 2, 1, 3)
    vx = (enc_h @ xp["attn"]["wv"]).reshape(
        b, se, cfg.n_kv_heads, cfg.head_dim).transpose(0, 2, 1, 3)
    o = attention(q, kx, vx, False, 0.0, cfg.attn_q_chunk,
                  cfg.attn_k_chunk)
    return out_project(o, xp["attn"])


def _run_mamba_stack(cfg: ModelConfig, params: Params, x: Array,
                     positions: Array, remat: bool) -> Array:
    def body(h, lp):
        return _mamba_layer(cfg, lp, h), None

    every = cfg.hybrid_attn_every
    if cfg.family == "ssm" or not every:
        x, _ = _scan(_remat(body, remat), x, params["layers"])
        return x

    # zamba2: groups of ``every`` mamba layers, the shared attn+MLP block
    # (one weight set, applied at several depths) between groups.
    n_groups = -(-cfg.n_layers // every)
    for g in range(n_groups):
        lo, hi = g * every, min((g + 1) * every, cfg.n_layers)
        group = jax.tree.map(lambda a: a[lo:hi], params["layers"])
        x, _ = _scan(_remat(body, remat), x, group)
        if hi < cfg.n_layers or cfg.n_layers % every == 0:
            sp = params["shared_attn"]
            h, _, _ = _self_attention(cfg, sp["attn"],
                                      apply_norm(x, sp["norm1"], cfg.norm),
                                      positions, False)
            x = x + h
            x = x + mlp(apply_norm(x, sp["norm2"], cfg.norm), sp["mlp"],
                        cfg.act)
    return x


def _run_encoder(cfg: ModelConfig, params: Params, frames: Array,
                 remat: bool) -> Array:
    """Whisper encoder over stub frame embeddings (bidirectional)."""
    pos_table = sinusoidal_positions(frames.shape[1], cfg.d_model,
                                     frames.dtype)
    x = frames + pos_table[None]

    def body(h, lp):
        hn = apply_norm(h, lp["norm1"], cfg.norm)
        q, k, v = qkv_project(hn, lp["attn"], cfg.n_heads, cfg.n_kv_heads,
                              cfg.head_dim)
        a = attention(q, k, v, False, 0.0, cfg.attn_q_chunk,
                      cfg.attn_k_chunk)
        h = h + out_project(a, lp["attn"])
        h = h + mlp(apply_norm(h, lp["norm2"], cfg.norm), lp["mlp"], cfg.act)
        return h, None

    x, _ = _scan(_remat(body, remat), x, params["enc_layers"])
    return apply_norm(x, params["enc_final_norm"], cfg.norm)


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------


def _embed_inputs(cfg: ModelConfig, params: Params, tokens: Array,
                  extras: Dict[str, Array]) -> Tuple[Array, Array]:
    x = embed_tokens(params["embed"], tokens, scale=cfg.scale_embed)
    x = constrain(x, "dp", None, None)
    if cfg.family == "vlm" and "patch_embeds" in extras:
        x = jnp.concatenate([extras["patch_embeds"].astype(x.dtype), x],
                            axis=1)
    if cfg.n_enc_layers:   # whisper decoder: sinusoidal, no rope
        x = x + sinusoidal_positions(x.shape[1], cfg.d_model, x.dtype)[None]
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    return x, positions


def _logits(cfg: ModelConfig, params: Params, x: Array) -> Array:
    x = apply_norm(x, params["final_norm"], cfg.norm)
    x = constrain(x, "dp", None, None)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    logits = constrain(logits, "dp", None, "tp")
    if cfg.logit_softcap > 0.0:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return logits


def forward(cfg: ModelConfig, params: Params, tokens: Array,
            remat: bool = True, **extras) -> Tuple[Array, Array]:
    """Full-sequence logits. Returns (logits (B, S', V), aux_loss)."""
    x, aux = forward_hidden(cfg, params, tokens, remat=remat, **extras)
    logits = jnp.einsum("bsd,dv->bsv", x, lm_head(cfg, params))
    logits = constrain(logits, "dp", None, "tp")
    return logits_transform(cfg)(logits), aux


def forward_hidden(cfg: ModelConfig, params: Params, tokens: Array,
                   remat: bool = True, **extras) -> Tuple[Array, Array]:
    """Final-norm hidden states (B, S', d) — the train loss applies the LM
    head chunk-by-chunk so the full (B, S, V) logits never materialize."""
    x, positions = _embed_inputs(cfg, params, tokens, extras)
    enc_h = (_run_encoder(cfg, params, extras["frame_embeds"], remat)
             if cfg.n_enc_layers else None)
    x, aux, _ = _run_decoder_stack(cfg, params, x, positions, remat, enc_h)
    return apply_norm(x, params["final_norm"], cfg.norm), aux


def lm_head(cfg: ModelConfig, params: Params) -> Array:
    return params["embed"].T if cfg.tie_embeddings else params["lm_head"]


def logits_transform(cfg: ModelConfig):
    if cfg.logit_softcap > 0.0:
        return lambda l: cfg.logit_softcap * jnp.tanh(l / cfg.logit_softcap)
    return lambda l: l


def prefill(cfg: ModelConfig, params: Params, tokens: Array,
            max_len: Optional[int] = None, **extras
            ) -> Tuple[Array, Dict[str, Any]]:
    """Score the prompt and build the decode cache (serving prefill)."""
    b, s = tokens.shape[0], tokens.shape[1]
    max_len = max_len or s
    x, positions = _embed_inputs(cfg, params, tokens, extras)
    enc_h = (_run_encoder(cfg, params, extras["frame_embeds"], False)
             if cfg.n_enc_layers else None)

    if cfg.family in ("ssm", "hybrid"):
        # SSD terminal states are cheap to rebuild at decode start; the
        # dry-run cell exposes the logits + zeroed cache shapes.
        x2, aux, _ = _run_decoder_stack(cfg, params, x, positions, False,
                                        enc_h)
        return _logits(cfg, params, x2), init_cache(cfg, b, max_len)

    x2, aux, kvs = _run_decoder_stack(cfg, params, x, positions, False,
                                      enc_h, collect_kv=True)
    ks, vs = kvs
    pad = max_len - ks.shape[3]
    if pad > 0:
        ks = jnp.pad(ks, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0)))
        vs = jnp.pad(vs, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0)))
    cache: Dict[str, Any] = {"k": ks, "v": vs}
    if cfg.n_enc_layers:
        # cross-attention K/V are fixed after prefill
        def xkv(xp):
            b_, se, _ = enc_h.shape
            kx = (enc_h @ xp["attn"]["wk"]).reshape(
                b_, se, cfg.n_kv_heads, cfg.head_dim).transpose(0, 2, 1, 3)
            vx = (enc_h @ xp["attn"]["wv"]).reshape(
                b_, se, cfg.n_kv_heads, cfg.head_dim).transpose(0, 2, 1, 3)
            return kx, vx

        kxs, vxs = jax.vmap(xkv)(params["cross_attn"])
        cache["cross_k"], cache["cross_v"] = kxs, vxs
    return _logits(cfg, params, x2), cache


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def cache_spec(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, Any]:
    """ShapeDtypeStructs of the decode cache (dry-run inputs)."""
    dtype = jnp.dtype(cfg.dtype)
    sd = jax.ShapeDtypeStruct
    if cfg.family == "ssm":
        return _mamba_cache_spec(cfg, batch, cfg.n_layers)
    if cfg.family == "hybrid":
        spec = _mamba_cache_spec(cfg, batch, cfg.n_layers)
        n_inv = (cfg.n_layers // cfg.hybrid_attn_every
                 if cfg.hybrid_attn_every else 0)
        kv = (n_inv, batch, cfg.n_kv_heads, max_len, cfg.head_dim)
        spec["shared_k"] = sd(kv, dtype)
        spec["shared_v"] = sd(kv, dtype)
        return spec
    kv = (cfg.n_layers, batch, cfg.n_kv_heads, max_len, cfg.head_dim)
    spec = {"k": sd(kv, dtype), "v": sd(kv, dtype)}
    if cfg.n_enc_layers and cfg.enc_seq:
        xkv = (cfg.n_layers, batch, cfg.n_kv_heads, cfg.enc_seq,
               cfg.head_dim)
        spec["cross_k"] = sd(xkv, dtype)
        spec["cross_v"] = sd(xkv, dtype)
    return spec


def _mamba_cache_spec(cfg, batch, n_layers):
    sd = jax.ShapeDtypeStruct
    dtype = jnp.dtype(cfg.dtype)
    conv_ch = cfg.d_inner + 2 * cfg.ssm_state
    return {
        "conv": sd((n_layers, batch, cfg.ssm_conv - 1, conv_ch), dtype),
        "ssm": sd((n_layers, batch, cfg.ssm_heads, cfg.ssm_headdim,
                   cfg.ssm_state), jnp.float32),
    }


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, Any]:
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_spec(cfg, batch, max_len))


def decode_step(cfg: ModelConfig, params: Params, cache: Dict[str, Any],
                tokens: Array, cache_index: Array
                ) -> Tuple[Array, Dict[str, Any]]:
    """One decoding step. tokens (B, 1); cache_index = current length."""
    x = embed_tokens(params["embed"], tokens, scale=cfg.scale_embed)
    positions = cache_index[None].astype(jnp.int32)
    if cfg.n_enc_layers:
        pos_t = sinusoidal_positions(cache["k"].shape[3], cfg.d_model,
                                     x.dtype)
        x = x + jax.lax.dynamic_slice_in_dim(pos_t, cache_index, 1)[None]

    if cfg.family in ("ssm", "hybrid"):
        x, cache = _decode_mamba(cfg, params, cache, x, cache_index)
        return _logits(cfg, params, x), cache

    def attn_decode(h, lp, kc, vc, is_local: bool):
        """One decode attention sublayer; returns (h, kc, vc)."""
        lp = _gather_fsdp(lp)
        hn = apply_norm(h, lp["norm1"], cfg.norm)
        q, k, v = qkv_project(hn, lp["attn"], cfg.n_heads, cfg.n_kv_heads,
                              cfg.head_dim)
        if cfg.use_rope:
            q = rope(q, positions, cfg.rope_theta)
            k = rope(k, positions, cfg.rope_theta)
        if not os.environ.get("REPRO_NO_CACHE_UPDATE"):
            # measurement-only switch: HloCostAnalysis charges a DUS as a
            # full-buffer copy; on TPU the donated cache updates in place.
            kc = jax.lax.dynamic_update_slice_in_dim(kc, k, cache_index,
                                                     axis=2)
            vc = jax.lax.dynamic_update_slice_in_dim(vc, v, cache_index,
                                                     axis=2)
        if is_local and cfg.window < kc.shape[2]:
            # the paper's cutoff applied to the cache: only the window pencil
            # is *read* (dynamic slice), not the whole 32k cache — out-of-
            # cutoff bytes are never loaded (DESIGN.md §4, §Perf gemma cell).
            w = cfg.window
            start = jnp.clip(cache_index - w + 1, 0, kc.shape[2] - w)
            kwin = jax.lax.dynamic_slice_in_dim(kc, start, w, axis=2)
            vwin = jax.lax.dynamic_slice_in_dim(vc, start, w, axis=2)
            o = decode_attention(q, kwin, vwin, cache_index - start,
                                 softcap=cfg.attn_softcap)
        else:
            o = decode_attention(q, kc, vc, cache_index,
                                 softcap=cfg.attn_softcap)
        h = h + _maybe_post(cfg, lp, "post_norm1", out_project(o, lp["attn"]))
        m, _ = _mlp_or_moe(cfg, lp, apply_norm(h, lp["norm2"], cfg.norm))
        h = h + _maybe_post(cfg, lp, "post_norm2", m)
        return h, kc, vc

    if cfg.local_global:
        # scan over (local, global) pairs so the window slicing is static
        pairs = jax.tree.map(
            lambda a: a.reshape(cfg.n_layers // 2, 2, *a.shape[1:]),
            params["layers"])
        kc2 = cache["k"].reshape(cfg.n_layers // 2, 2, *cache["k"].shape[1:])
        vc2 = cache["v"].reshape(cfg.n_layers // 2, 2, *cache["v"].shape[1:])

        def pair_body(h, inp):
            lp, kc, vc = inp
            h, kl, vl = attn_decode(h, jax.tree.map(lambda a: a[0], lp),
                                    kc[0], vc[0], True)
            h, kg, vg = attn_decode(h, jax.tree.map(lambda a: a[1], lp),
                                    kc[1], vc[1], False)
            return h, (jnp.stack([kl, kg]), jnp.stack([vl, vg]))

        x, (nk, nv) = _scan(pair_body, x, (pairs, kc2, vc2))
        cache = dict(cache)
        cache["k"] = nk.reshape(cfg.n_layers, *nk.shape[2:])
        cache["v"] = nv.reshape(cfg.n_layers, *nv.shape[2:])
        return _logits(cfg, params, x), cache

    def body(h, inp):
        if cfg.n_enc_layers:
            lp, kc, vc, xp, xk, xv = inp
        else:
            lp, kc, vc = inp
        h, kc, vc = attn_decode_body(h, lp, kc, vc)
        if cfg.n_enc_layers:
            hq = apply_norm(h, xp["norm"], cfg.norm)
            q2, _, _ = qkv_project(hq, xp["attn"], cfg.n_heads,
                                   cfg.n_kv_heads, cfg.head_dim)
            o2 = decode_attention(q2, xk, xv, jnp.int32(xk.shape[2] - 1))
            h = h + out_project(o2, xp["attn"])
        return h, (kc, vc)

    def attn_decode_body(h, lp, kc, vc):
        lp = _gather_fsdp(lp)
        hn = apply_norm(h, lp["norm1"], cfg.norm)
        q, k, v = qkv_project(hn, lp["attn"], cfg.n_heads, cfg.n_kv_heads,
                              cfg.head_dim)
        if cfg.use_rope:
            q = rope(q, positions, cfg.rope_theta)
            k = rope(k, positions, cfg.rope_theta)
        if not os.environ.get("REPRO_NO_CACHE_UPDATE"):
            kc = jax.lax.dynamic_update_slice_in_dim(kc, k, cache_index,
                                                     axis=2)
            vc = jax.lax.dynamic_update_slice_in_dim(vc, v, cache_index,
                                                     axis=2)
        o = decode_attention(q, kc, vc, cache_index, softcap=cfg.attn_softcap)
        h = h + _maybe_post(cfg, lp, "post_norm1", out_project(o, lp["attn"]))
        # mlp/moe handled here so enc-dec cross-attn (in ``body``) slots
        # between attention and the MLP exactly as in forward
        m, _ = _mlp_or_moe(cfg, lp, apply_norm(h, lp["norm2"], cfg.norm))
        h = h + _maybe_post(cfg, lp, "post_norm2", m)
        return h, kc, vc

    if cfg.n_enc_layers:
        xs = (params["layers"], cache["k"], cache["v"],
              params["cross_attn"], cache["cross_k"], cache["cross_v"])
    else:
        xs = (params["layers"], cache["k"], cache["v"])
    x, (new_k, new_v) = _scan(body, x, xs)
    cache = dict(cache)
    cache["k"], cache["v"] = new_k, new_v
    return _logits(cfg, params, x), cache


def _decode_mamba(cfg, params, cache, x, cache_index):
    def body(h, inp):
        lp, conv_c, ssm_c = inp
        hn = apply_norm(h, lp["norm1"], cfg.norm)
        y, new = mamba2_decode(hn, lp["mamba"],
                               {"conv": conv_c, "ssm": ssm_c},
                               d_inner=cfg.d_inner, state=cfg.ssm_state,
                               n_heads=cfg.ssm_heads,
                               headdim=cfg.ssm_headdim)
        return h + y, (new["conv"], new["ssm"])

    every = cfg.hybrid_attn_every
    cache = dict(cache)
    if cfg.family == "ssm" or not every:
        x, (nc, ns) = _scan(
            body, x, (params["layers"], cache["conv"], cache["ssm"]))
        cache["conv"], cache["ssm"] = nc, ns
        return x, cache

    positions = cache_index[None].astype(jnp.int32)
    n_groups = -(-cfg.n_layers // every)
    new_conv, new_ssm, new_sk, new_sv = [], [], [], []
    inv = 0
    for g in range(n_groups):
        lo, hi = g * every, min((g + 1) * every, cfg.n_layers)
        x, (nc, ns) = _scan(
            body, x, (jax.tree.map(lambda a: a[lo:hi], params["layers"]),
                      cache["conv"][lo:hi], cache["ssm"][lo:hi]))
        new_conv.append(nc)
        new_ssm.append(ns)
        if hi < cfg.n_layers or cfg.n_layers % every == 0:
            sp = params["shared_attn"]
            hn = apply_norm(x, sp["norm1"], cfg.norm)
            q, k, v = qkv_project(hn, sp["attn"], cfg.n_heads,
                                  cfg.n_kv_heads, cfg.head_dim)
            q = rope(q, positions, cfg.rope_theta)
            k = rope(k, positions, cfg.rope_theta)
            kc = jax.lax.dynamic_update_slice_in_dim(
                cache["shared_k"][inv], k, cache_index, axis=2)
            vc = jax.lax.dynamic_update_slice_in_dim(
                cache["shared_v"][inv], v, cache_index, axis=2)
            new_sk.append(kc)
            new_sv.append(vc)
            o = decode_attention(q, kc, vc, cache_index)
            x = x + out_project(o, sp["attn"])
            x = x + mlp(apply_norm(x, sp["norm2"], cfg.norm), sp["mlp"],
                        cfg.act)
            inv += 1
    cache["conv"] = jnp.concatenate(new_conv)
    cache["ssm"] = jnp.concatenate(new_ssm)
    if new_sk:
        cache["shared_k"] = jnp.stack(new_sk)
        cache["shared_v"] = jnp.stack(new_sv)
    return x, cache
