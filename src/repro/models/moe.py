"""Top-k MoE with sort-based dispatch — the paper's binning pipeline reused.

Token dispatch to expert-capacity buffers is *exactly* the paper's particle
binning problem: experts are cells, capacity is M_C, and the pipeline is
count -> prefix sum -> rank-in-cell -> dense slot scatter. We reuse the
paper's §6 prefix sum (``core.prefix``) for the expert offsets, which makes
the paper's contribution a first-class substrate of the MoE layer
(DESIGN.md §4), and keeps dispatch free of (T, E, C) one-hot tensors
(GShard-style dispatch einsums OOM at assigned scale).

Capacity overflow drops tokens (they pass through the residual), standard
GShard semantics. Expert weights are (E, d, f) so EP shards the leading dim
when E divides the model axis, and TP shards f otherwise (dist.sharding).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..core.prefix import exclusive_prefix_sum
from ..dist.sharding import constrain
from .layers import _act

Array = jnp.ndarray


def init_moe(key, d: int, f: int, n_experts: int, dtype) -> Dict[str, Array]:
    kg, k1, k2, k3 = jax.random.split(key, 4)
    s_in, s_out = d ** -0.5, f ** -0.5
    return {
        "router": (jax.random.normal(kg, (d, n_experts)) * s_in
                   ).astype(jnp.float32),
        "w_gate": (jax.random.normal(k1, (n_experts, d, f)) * s_in
                   ).astype(dtype),
        "w_up": (jax.random.normal(k2, (n_experts, d, f)) * s_in
                 ).astype(dtype),
        "w_down": (jax.random.normal(k3, (n_experts, f, d)) * s_out
                   ).astype(dtype),
    }


def moe_capacity(n_tokens: int, n_experts: int, top_k: int,
                 capacity_factor: float) -> int:
    cap = int(n_tokens * top_k * capacity_factor / n_experts) + 1
    return max(8, -(-cap // 8) * 8)   # pad to sublane multiple


def _ep(n_experts: int) -> bool:
    """True when the ambient mesh can shard the expert dim (EP)."""
    from ..dist.sharding import current_mesh
    mesh = current_mesh()
    if mesh is None or "model" not in getattr(mesh, "axis_names", ()):
        return False
    return n_experts % mesh.shape["model"] == 0


def _dp_groups() -> int:
    """Number of data-parallel shards in the ambient mesh (1 when unset)."""
    from ..dist.sharding import current_mesh
    mesh = current_mesh()
    if mesh is None:
        return 1
    g = 1
    for a in ("pod", "data"):
        if a in getattr(mesh, "axis_names", ()):
            g *= mesh.shape[a]
    return g


def moe_mlp(x: Array, p: Dict[str, Array], *, top_k: int,
            capacity_factor: float, act: str = "silu") -> Tuple[Array, Array]:
    """x (B, S, d) -> (out (B, S, d), aux_loss scalar).

    Dispatch is *grouped*: tokens are viewed as (G, T/G) with G = the number
    of data-parallel shards, and the whole binning pipeline (count -> paper
    §6 prefix sum -> rank-in-expert -> dense slot scatter) runs per group —
    sorts and scatters never cross a DP shard, and the expert einsum's
    (G <-> E) resharding is the EP all-to-all, inserted by GSPMD. This is
    the production GShard/DeepSpeed-MoE layout; the global-sort variant
    measured +130 GiB/device on grok train_4k (EXPERIMENTS.md §Perf).

    aux_loss is the standard load-balancing loss (Switch §2.2).
    """
    b, s, d = x.shape
    t = b * s
    e = p["router"].shape[-1]
    g = _dp_groups()
    if t % g:
        g = 1
    tl = t // g                                            # tokens per group
    cap = moe_capacity(tl, e, top_k, capacity_factor)

    xt = constrain(x.reshape(g, tl, d), "dp", None, None)
    logits = xt.astype(jnp.float32) @ p["router"]          # (G, TL, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)      # (G, TL, k)
    gate_vals = gate_vals / jnp.clip(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # ---- per-group binning over TL*k assignments (cells = experts) ----
    a = tl * top_k
    flat_e = gate_idx.reshape(g, a)
    flat_w = gate_vals.reshape(g, a)
    flat_tok = jnp.tile(
        jnp.repeat(jnp.arange(tl, dtype=jnp.int32), top_k)[None], (g, 1))

    one = jnp.ones((g, a), jnp.int32)
    counts = jax.vmap(
        lambda ee, oo: jax.ops.segment_sum(oo, ee, num_segments=e)
    )(flat_e, one)                                         # (G, E)
    offsets = exclusive_prefix_sum(counts)                 # paper §6 scan
    order = jnp.argsort(flat_e, axis=-1, stable=True)      # (G, A)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    rank = (jnp.arange(a, dtype=jnp.int32)[None]
            - jnp.take_along_axis(offsets, sorted_e, axis=-1))
    slot = sorted_e * cap + rank
    slot = jnp.where(rank < cap, slot, e * cap)            # overflow -> drop

    tok_sorted = constrain(jnp.take_along_axis(flat_tok, order, axis=-1),
                           "dp", None)
    slot = constrain(slot, "dp", None)
    # row gather via vmapped take — take_along_axis would broadcast a u32
    # (A, d) index tensor (measured 3.75 GiB/buffer on grok; §Perf)
    x_sorted = jax.vmap(lambda xg, ig: jnp.take(xg, ig, axis=0))(
        xt, tok_sorted)
    # keep the whole dispatch chain DP-sharded: unconstrained, GSPMD
    # replicates these (G, A, d) tensors and all-reduces their gather
    # cotangents — measured 7.5 GB/layer + 2x FLOPs on arctic (§Perf)
    x_sorted = constrain(x_sorted, "dp", None, None)

    def scatter_one(slots, vals):
        # add == set here (slots are unique by construction) and its VJP is a
        # plain gather — scatter-set's VJP materializes element-level u32 id
        # maps (measured 3.75 GiB u32 buffers on grok; §Perf)
        return jnp.zeros((e * cap, d), x.dtype).at[slots].add(
            vals, mode="drop")

    xbuf = jax.vmap(scatter_one)(slot, x_sorted).reshape(g, e, cap, d)
    xbuf = constrain(xbuf, "dp", "tp", None, None)   # (G dp, E ep, cap, d)

    h = _act(jnp.einsum("gecd,edf->gecf", xbuf, p["w_gate"]), act)
    h = h * jnp.einsum("gecd,edf->gecf", xbuf, p["w_up"])
    h = constrain(h, "dp", "tp", None, None) if _ep(e) else \
        constrain(h, "dp", None, None, "tp")         # TP-within-expert (grok)
    ybuf = jnp.einsum("gecf,efd->gecd", h, p["w_down"])    # (G, E, cap, d)
    ybuf = constrain(ybuf, "dp", "tp", None, None)

    # combine: gather each assignment's expert output, weight, segment-sum
    yb = constrain(ybuf.reshape(g, e * cap, d), "dp", None, None)
    w_sorted = jnp.take_along_axis(flat_w, order, axis=-1)
    y_assign = jax.vmap(lambda yg, sg: jnp.take(yg, sg, axis=0))(
        yb, jnp.minimum(slot, e * cap - 1))
    y_assign = constrain(y_assign, "dp", None, None)
    y_assign = y_assign * ((rank < cap) * w_sorted)[..., None]
    out = jax.vmap(
        lambda ya, tt: jax.ops.segment_sum(ya, tt, num_segments=tl)
    )(y_assign, tok_sorted)                                # (G, TL, d)
    out = constrain(out, "dp", None, None)

    frac_tokens = counts.astype(jnp.float32).sum(0) / (t * top_k)
    mean_probs = probs.mean(axis=(0, 1))
    aux = e * jnp.sum(frac_tokens * mean_probs)

    return out.reshape(b, s, d).astype(x.dtype), aux
