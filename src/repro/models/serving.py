"""LM serving helpers: batched prefill + decode loop (inference dry-run).

``make_prefill_step`` / ``make_decode_step`` are the lowered entry points for
the prefill_32k / decode_32k / long_500k cells; ``generate`` is the runnable
greedy loop used by examples and tests (CPU, small configs).

Lives under ``models/`` because it is model-shaped plumbing: the particle
serving tier (``repro.serve``) owns the interaction front door, and this
module's old home ``repro.train.serve`` remains as a deprecation shim.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import model as M

Array = jnp.ndarray


def make_prefill_step(cfg: ModelConfig, max_len: Optional[int] = None
                      ) -> Callable:
    def prefill_step(params, batch: Dict[str, Array]):
        extras = {k: v for k, v in batch.items() if k != "tokens"}
        logits, cache = M.prefill(cfg, params, batch["tokens"],
                                  max_len=max_len, **extras)
        return logits[:, -1:], cache
    return prefill_step


def make_decode_step(cfg: ModelConfig) -> Callable:
    def step(params, cache, tokens: Array, cache_index: Array):
        return M.decode_step(cfg, params, cache, tokens, cache_index)
    return step


def generate(cfg: ModelConfig, params, prompt: Array, n_tokens: int,
             max_len: Optional[int] = None, **extras
             ) -> Tuple[Array, Array]:
    """Greedy generation. prompt (B, S) -> (tokens (B, n_tokens), logits)."""
    b, s = prompt.shape
    max_len = max_len or (s + n_tokens)
    logits, cache = M.prefill(cfg, params, prompt, max_len=max_len, **extras)
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    decode = jax.jit(make_decode_step(cfg))

    if cfg.family in ("ssm", "hybrid"):
        # state caches start empty: replay the prompt through decode steps
        # (cheap: O(1) per token) so the state reflects the prefix.
        cache = M.init_cache(cfg, b, max_len)
        for t in range(s):
            lg, cache = decode(params, cache, prompt[:, t:t + 1],
                               jnp.int32(t))
        tok = jnp.argmax(lg, axis=-1).astype(jnp.int32)

    outs = [tok]
    idx = s
    for _ in range(n_tokens - 1):
        lg, cache = decode(params, cache, tok, jnp.int32(idx))
        tok = jnp.argmax(lg, axis=-1).astype(jnp.int32)
        outs.append(tok)
        idx += 1
    return jnp.concatenate(outs, axis=1), logits
