"""Shared transformer layers: norms, RoPE, MLP, projections, embedding.

Parameters are plain nested dicts of jnp arrays; every init function takes an
explicit PRNG key and dtype. Layer weights are created *stacked* over the
layer dimension by the model assembler (scan-over-layers keeps the HLO — and
therefore the 512-device dry-run compile — small).
"""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

Array = jnp.ndarray


# -- norms -------------------------------------------------------------------

def rms_norm(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype)


def layer_norm(x: Array, scale: Array, bias: Array, eps: float = 1e-5) -> Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(x.dtype)


def apply_norm(x: Array, p: Dict[str, Array], kind: str) -> Array:
    if kind == "rms":
        return rms_norm(x, p["scale"])
    return layer_norm(x, p["scale"], p["bias"])


def init_norm(d: int, kind: str, dtype) -> Dict[str, Array]:
    if kind == "rms":
        return {"scale": jnp.zeros((d,), dtype)}        # (1 + scale) form
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


# -- rotary position embedding ------------------------------------------------

def rope(x: Array, positions: Array, theta: float) -> Array:
    """x (..., S, D) with D even; positions (..., S) or (S,)."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs   # (..., S, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    xr1 = x1 * cos - x2 * sin
    xr2 = x2 * cos + x1 * sin
    return jnp.concatenate([xr1, xr2], axis=-1).astype(x.dtype)


def sinusoidal_positions(s: int, d: int, dtype) -> Array:
    """Whisper-style fixed sinusoidal table (S, D)."""
    half = d // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32)
                    * (jnp.log(10000.0) / max(half - 1, 1)))
    ang = jnp.arange(s, dtype=jnp.float32)[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


# -- dense / GLU MLP -----------------------------------------------------------

def _act(x: Array, kind: str) -> Array:
    return jax.nn.silu(x) if kind == "silu" else jax.nn.gelu(x)


def mlp(x: Array, p: Dict[str, Array], act: str) -> Array:
    from ..dist.sharding import constrain
    if "w_gate" not in p:            # plain 2-matrix MLP (starcoder2/whisper)
        h = _act(constrain(x @ p["w_up"], "dp", None, "tp"), act)
        return h @ p["w_down"]
    gate = _act(constrain(x @ p["w_gate"], "dp", None, "tp"), act)
    return (gate * (x @ p["w_up"])) @ p["w_down"]


def init_mlp(key, d: int, f: int, dtype, gated: bool = True
             ) -> Dict[str, Array]:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in, s_out = d ** -0.5, f ** -0.5
    p = {
        "w_up": (jax.random.normal(k2, (d, f)) * s_in).astype(dtype),
        "w_down": (jax.random.normal(k3, (f, d)) * s_out).astype(dtype),
    }
    if gated:
        p["w_gate"] = (jax.random.normal(k1, (d, f)) * s_in).astype(dtype)
    return p


# -- attention projections -----------------------------------------------------

def init_attn(key, d: int, n_heads: int, n_kv: int, head_dim: int,
              dtype, bias: bool = False) -> Dict[str, Array]:
    kq, kk, kv, ko = jax.random.split(key, 4)
    s = d ** -0.5
    p = {
        "wq": (jax.random.normal(kq, (d, n_heads * head_dim)) * s).astype(dtype),
        "wk": (jax.random.normal(kk, (d, n_kv * head_dim)) * s).astype(dtype),
        "wv": (jax.random.normal(kv, (d, n_kv * head_dim)) * s).astype(dtype),
        "wo": (jax.random.normal(ko, (n_heads * head_dim, d))
               * (n_heads * head_dim) ** -0.5).astype(dtype),
    }
    if bias:
        p["bq"] = jnp.zeros((n_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((n_kv * head_dim,), dtype)
        p["bv"] = jnp.zeros((n_kv * head_dim,), dtype)
    return p


def qkv_project(x: Array, p: Dict[str, Array], n_heads: int, n_kv: int,
                head_dim: int):
    """x (B, S, d) -> q (B, H, S, Dh), k/v (B, KH, S, Dh)."""
    b, s, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, n_heads, head_dim).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, n_kv, head_dim).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, n_kv, head_dim).transpose(0, 2, 1, 3)
    return q, k, v


def out_project(o: Array, p: Dict[str, Array]) -> Array:
    """(B, H, S, Dh) -> (B, S, d)."""
    b, h, s, dh = o.shape
    return o.transpose(0, 2, 1, 3).reshape(b, s, h * dh) @ p["wo"]


# -- embedding -----------------------------------------------------------------

def init_embed(key, vocab: int, d: int, dtype) -> Array:
    return (jax.random.normal(key, (vocab, d)) * (d ** -0.5)).astype(dtype)


def embed_tokens(table: Array, tokens: Array, scale: bool = False) -> Array:
    x = jnp.take(table, tokens, axis=0)
    if scale:
        x = x * (table.shape[-1] ** 0.5)
    return x
