"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) block.

Chunked SSD: within-chunk attention-like einsums + inter-chunk linear
recurrence, the standard minimal-SSD formulation. Chunking plays the same
role as the paper's pencils: the quadratic part is confined to a staged
block, the cross-block coupling is a cheap carried state. Decode is a
single-token state update (O(1) per token — why mamba2/zamba2 are the
long_500k-eligible archs).

Shapes: d_inner = expand * d_model, heads H = d_inner / headdim P, single
B/C group (G=1), state size N = cfg.ssm_state.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .layers import rms_norm

Array = jnp.ndarray


def init_mamba2(key, d: int, d_inner: int, n_heads: int, state: int,
                conv: int, dtype) -> Dict[str, Array]:
    keys = jax.random.split(key, 4)
    conv_ch = d_inner + 2 * state       # x, B, C run through the conv
    proj_out = 2 * d_inner + 2 * state + n_heads   # z, x, B, C, dt
    return {
        "in_proj": (jax.random.normal(keys[0], (d, proj_out))
                    * d ** -0.5).astype(dtype),
        "conv_w": (jax.random.normal(keys[1], (conv, conv_ch))
                   * conv ** -0.5).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "a_log": jnp.zeros((n_heads,), jnp.float32),       # A = -exp(a_log)
        "d_skip": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "norm_scale": jnp.zeros((d_inner,), dtype),
        "out_proj": (jax.random.normal(keys[2], (d_inner, d))
                     * d_inner ** -0.5).astype(dtype),
    }


def _split_proj(zxbcdt: Array, d_inner: int, state: int, n_heads: int):
    z = zxbcdt[..., :d_inner]
    x = zxbcdt[..., d_inner:2 * d_inner]
    bm = zxbcdt[..., 2 * d_inner:2 * d_inner + state]
    cm = zxbcdt[..., 2 * d_inner + state:2 * d_inner + 2 * state]
    dt = zxbcdt[..., 2 * d_inner + 2 * state:]
    return z, x, bm, cm, dt


def _causal_conv(u: Array, w: Array, b: Array) -> Array:
    """Depthwise causal conv: u (B, S, C), w (K, C) -> (B, S, C)."""
    k = w.shape[0]
    pad = jnp.pad(u, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(u)
    for i in range(k):           # K static (4): unrolled shifted adds
        out = out + pad[:, i:i + u.shape[1], :] * w[i]
    return out + b


def _segsum(x: Array) -> Array:
    """x (..., Q) -> (..., Q, Q): sum_{k=j+1..i} x[k] for i >= j, -inf else."""
    q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    i = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    j = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    return jnp.where(i >= j, seg, -jnp.inf)


def ssd_chunked(x: Array, dt: Array, a: Array, bm: Array, cm: Array,
                chunk: int) -> Array:
    """SSD scan. x (B,S,H,P), dt (B,S,H) >0, a (H,) <0, bm/cm (B,S,N).

    Returns y (B,S,H,P). fp32 internally.
    """
    b, s, h, p = x.shape
    n = bm.shape[-1]
    q = min(chunk, s)
    pad = (-s) % q
    if pad:
        # dt = 0 rows are exact no-ops (decay exp(0)=1, zero state injection)
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bm = jnp.pad(bm, ((0, 0), (0, pad), (0, 0)))
        cm = jnp.pad(cm, ((0, 0), (0, pad), (0, 0)))
        out = ssd_chunked(x, dt, a, bm, cm, chunk)
        return out[:, :s]
    nc = s // q

    xf = (x * dt[..., None]).astype(jnp.float32).reshape(b, nc, q, h, p)
    da = (dt * a).astype(jnp.float32).reshape(b, nc, q, h)
    da = jnp.moveaxis(da, -1, 1)                   # (b, h, nc, q)
    bmf = bm.astype(jnp.float32).reshape(b, nc, q, n)
    cmf = cm.astype(jnp.float32).reshape(b, nc, q, n)

    da_cs = jnp.cumsum(da, axis=-1)                # (b, h, nc, q)

    # 1) intra-chunk (the "attention-like" quadratic part, staged per chunk)
    ell = jnp.exp(_segsum(da))                     # (b, h, nc, q, q)
    y_diag = jnp.einsum("bcln,bcsn,bhcls,bcshp->bclhp",
                        cmf, bmf, ell, xf)

    # 2) per-chunk terminal states
    decay_states = jnp.exp(da_cs[..., -1:] - da_cs)        # (b, h, nc, q)
    states = jnp.einsum("bcln,bhcl,bclhp->bchpn", bmf, decay_states, xf)

    # 3) inter-chunk recurrence (linear scan over chunk boundaries)
    def chunk_step(carry, inp):
        st, decay = inp                            # (b,h,p,n), (b,h)
        new = carry * jnp.exp(decay)[..., None, None] + st
        return new, carry                          # emit the *previous* state

    chunk_decay = da_cs[..., -1]                   # (b, h, nc)
    init = jnp.zeros((b, h, p, n), jnp.float32)
    _, prev_states = jax.lax.scan(
        chunk_step, init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, -1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # (b, nc, h, p, n)

    # 4) contribution of carried state to each position
    state_decay = jnp.exp(da_cs)                   # (b, h, nc, q)
    y_off = jnp.einsum("bcln,bchpn,bhcl->bclhp",
                       cmf, prev_states, state_decay)

    return (y_diag + y_off).reshape(b, s, h, p)


def mamba2_block(x: Array, p: Dict[str, Array], *, d_inner: int, state: int,
                 n_heads: int, headdim: int, chunk: int) -> Array:
    """Full Mamba-2 mixer (train/prefill path). x (B, S, d) -> (B, S, d)."""
    z, xs, bm, cm, dt = _split_proj(x @ p["in_proj"], d_inner, state, n_heads)
    conv_in = jnp.concatenate([xs, bm, cm], axis=-1)
    conv_out = jax.nn.silu(_causal_conv(conv_in, p["conv_w"], p["conv_b"]))
    xs = conv_out[..., :d_inner]
    bm = conv_out[..., d_inner:d_inner + state]
    cm = conv_out[..., d_inner + state:]

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    xh = xs.reshape(*xs.shape[:-1], n_heads, headdim)
    y = ssd_chunked(xh, dt, a, bm, cm, chunk)
    y = y + xh.astype(jnp.float32) * p["d_skip"][:, None]
    y = y.reshape(*xs.shape).astype(x.dtype)

    y = rms_norm(y * jax.nn.silu(z), p["norm_scale"])
    return y @ p["out_proj"]


def mamba2_decode(x: Array, p: Dict[str, Array], cache: Dict[str, Array], *,
                  d_inner: int, state: int, n_heads: int, headdim: int
                  ) -> Tuple[Array, Dict[str, Array]]:
    """Single-token step. x (B, 1, d); cache = {"conv": (B, K-1, C),
    "ssm": (B, H, P, N)}. Returns (y (B, 1, d), new cache)."""
    z, xs, bm, cm, dt = _split_proj(x @ p["in_proj"], d_inner, state, n_heads)
    conv_in = jnp.concatenate([xs, bm, cm], axis=-1)       # (B, 1, C)
    k = p["conv_w"].shape[0]
    window = jnp.concatenate([cache["conv"], conv_in], axis=1)  # (B, K, C)
    conv_out = jax.nn.silu(
        jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"]
    )[:, None, :]
    new_conv = window[:, 1:]

    xs = conv_out[..., :d_inner]
    bm = conv_out[..., d_inner:d_inner + state]
    cm = conv_out[..., d_inner + state:]

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])[:, 0]  # (B,H)
    a = -jnp.exp(p["a_log"])
    xh = xs.reshape(-1, n_heads, headdim).astype(jnp.float32)          # (B,H,P)
    decay = jnp.exp(dt * a)                                            # (B,H)
    bmf = bm[:, 0].astype(jnp.float32)                                 # (B,N)
    cmf = cm[:, 0].astype(jnp.float32)
    dx = xh * dt[..., None]                                            # (B,H,P)
    h_new = (cache["ssm"] * decay[..., None, None]
             + jnp.einsum("bhp,bn->bhpn", dx, bmf))
    y = jnp.einsum("bhpn,bn->bhp", h_new, cmf) + xh * p["d_skip"][:, None]
    y = y.reshape(-1, 1, d_inner).astype(x.dtype)

    y = rms_norm(y * jax.nn.silu(z), p["norm_scale"])
    return y @ p["out_proj"], {"conv": new_conv, "ssm": h_new}


def init_mamba_cache(batch: int, d_inner: int, state: int, n_heads: int,
                     headdim: int, conv: int, dtype) -> Dict[str, Array]:
    return {
        "conv": jnp.zeros((batch, conv - 1, d_inner + 2 * state), dtype),
        "ssm": jnp.zeros((batch, n_heads, headdim, state), jnp.float32),
    }
