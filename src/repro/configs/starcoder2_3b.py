"""starcoder2-3b [dense] — 30L d3072 24H (GQA kv=2) d_ff=12288 vocab=49152,
GQA + RoPE, LayerNorm + GELU. [arXiv:2402.19173; hf]

The 3b config is full-attention by default; the starcoder2 family's sliding
window variant is exposed via ``window`` (DESIGN.md §4)."""

from .base import ModelConfig

def config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-3b", family="dense",
        n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2, d_ff=12288,
        vocab_size=49152, head_dim=128, norm="ln", act="gelu",
        rope_theta=1_000_000.0, tie_embeddings=True,
        mlp_gated=False,
    )

def smoke() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-3b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=256, head_dim=16, norm="ln", act="gelu",
        tie_embeddings=True, mlp_gated=False, dtype="float32")
