"""Config schema for the assigned architectures + the paper's own configs.

One frozen dataclass covers all 10 families; family-specific fields default
to "off". Exact assigned values live in one module per arch
(``configs/<id>.py``); every arch also exposes ``smoke()`` — a reduced config
of the same family for CPU tests — and ``input_specs`` builds the
ShapeDtypeStruct stand-ins for the dry-run.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: Optional[int] = None   # default d_model // n_heads
    qkv_bias: bool = False           # qwen1.5
    norm: str = "rms"                # rms | ln
    act: str = "silu"                # silu | gelu
    mlp_gated: bool = True           # GLU (3 mats) vs plain MLP (2 mats)
    rope_theta: float = 10_000.0
    use_rope: bool = True            # whisper: sinusoidal instead
    tie_embeddings: bool = False
    attn_q_chunk: int = 512          # flash-attention chunking (perf knobs)
    attn_k_chunk: int = 512

    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_dense_residual: bool = False  # arctic: dense MLP in parallel with MoE
    capacity_factor: float = 1.25

    # SSM (mamba2 / zamba2)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 128
    hybrid_attn_every: int = 0       # zamba2: shared attn block period

    # gemma2
    local_global: bool = False       # alternate local/global attention
    window: int = 4096
    attn_softcap: float = 0.0
    logit_softcap: float = 0.0
    post_norms: bool = False         # gemma2 sandwich norms
    scale_embed: bool = False        # gemma2 sqrt(d) embed scaling

    # enc-dec (whisper)
    n_enc_layers: int = 0
    enc_seq: int = 0                 # stub frontend: frames fed pre-embedded

    # vlm (phi-3-vision): stub frontend feeds patch embeddings
    n_img_tokens: int = 0

    # numerics
    dtype: str = "bfloat16"
    moment_dtype: str = "float32"    # adam moments; bf16 for the giants
    dryrun_microbatches: int = 1     # grad-accumulation for the train cell
    pure_dp: bool = False            # small models: model axis joins DP

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (SSM / hybrid only, per assignment)."""
        return self.family in ("ssm", "hybrid")

    @property
    def d_inner(self) -> int:        # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def param_count(self) -> int:
        """Analytic parameter count (embedding + layers), for roofline math."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.head_dim
        attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
            + self.n_heads * hd * d
        mlp_dense = (3 if self.mlp_gated else 2) * d * f
        per_layer = 0
        if self.family == "ssm":
            per_layer = self._mamba_params()
        elif self.family == "hybrid":
            per_layer = self._mamba_params()
        else:
            per_layer = attn
            if self.n_experts:
                per_layer += self.n_experts * 3 * d * f + d * self.n_experts
                if self.moe_dense_residual:
                    per_layer += mlp_dense
            else:
                per_layer += mlp_dense
        total = self.n_layers * per_layer + v * d
        if self.family == "hybrid" and self.hybrid_attn_every:
            total += attn + mlp_dense                       # one shared block
        if self.n_enc_layers:
            total += self.n_enc_layers * (attn + mlp_dense)
            total += self.n_layers * attn                   # cross attention
        if not self.tie_embeddings:
            total += v * d
        return total

    def _mamba_params(self) -> int:
        d, di, n = self.d_model, self.d_inner, self.ssm_state
        g_bc = 2 * n                       # single-group B and C
        in_proj = d * (2 * di + g_bc + self.ssm_heads)
        return in_proj + di * d + self.ssm_conv * (di + g_bc) + 2 * di

    def active_param_count(self) -> int:
        """MoE: params touched per token (top_k experts)."""
        if not self.n_experts:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        inactive = self.n_layers * (self.n_experts - self.top_k) * 3 * d * f
        return self.param_count() - inactive


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One assigned (shape) cell: what gets lowered in the dry-run."""
    name: str                        # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


SHAPES: Tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", 4_096, 256, "train"),
    ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    ShapeCell("decode_32k", 32_768, 128, "decode"),
    ShapeCell("long_500k", 524_288, 1, "decode"),
)


def shape_by_name(name: str) -> ShapeCell:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


def cell_is_runnable(cfg: ModelConfig, shape: ShapeCell) -> Tuple[bool, str]:
    """Assignment skip rules. Returns (runnable, reason-if-not)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("long_500k needs sub-quadratic attention; "
                       f"{cfg.name} is {cfg.family} (full attention)")
    return True, ""


def input_specs(cfg: ModelConfig, shape: ShapeCell) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    No allocation: these feed jit(...).lower() directly (dry-run contract).
    """
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    sd = jax.ShapeDtypeStruct
    act = jnp.dtype(cfg.dtype)

    if shape.kind == "train":
        specs = {"tokens": sd((b, s), i32), "labels": sd((b, s), i32)}
    elif shape.kind == "prefill":
        specs = {"tokens": sd((b, s), i32)}
    else:  # decode: one new token against a cache of length s
        specs = {"tokens": sd((b, 1), i32), "cache_index": sd((), i32)}

    if cfg.family == "vlm" and cfg.n_img_tokens and shape.kind != "decode":
        specs["patch_embeds"] = sd((b, cfg.n_img_tokens, cfg.d_model), act)
    if cfg.n_enc_layers and cfg.enc_seq:
        # audio stub: precomputed frame embeddings for the encoder
        if shape.kind == "train" or shape.kind == "prefill":
            specs["frame_embeds"] = sd((b, cfg.enc_seq, cfg.d_model), act)
    return specs
