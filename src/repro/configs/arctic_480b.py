"""arctic-480b [moe] — 35L d7168 56H (GQA kv=8) d_ff=4864 vocab=32000,
MoE 128 experts top-2 + dense residual MLP. [hf:Snowflake/snowflake-arctic-base; hf]"""

from .base import ModelConfig

def config() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b", family="moe",
        n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=4864,
        vocab_size=32000, head_dim=128,
        n_experts=128, top_k=2, moe_dense_residual=True,
        moment_dtype="bfloat16",
        dryrun_microbatches=8,
    )

def smoke() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96,
        vocab_size=256, head_dim=16, n_experts=8, top_k=2,
        moe_dense_residual=True, dtype="float32")
