"""qwen1.5-0.5b [dense] — 24L d1024 16H (kv=16) d_ff=2816 vocab=151936,
QKV bias. [hf:Qwen/Qwen1.5-0.5B; hf]"""

from .base import ModelConfig

def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-0.5b", family="dense",
        n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=2816,
        vocab_size=151936, head_dim=64, qkv_bias=True,
        tie_embeddings=True,
        pure_dp=True,   # 0.5B on a 16-wide TP axis: pure DP wins (§Perf)
    )

def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-0.5b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=256, head_dim=16, qkv_bias=True, tie_embeddings=True,
        dtype="float32")
