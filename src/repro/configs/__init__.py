"""Config registry: ``--arch <id>`` resolution for launcher/dry-run/tests."""

from __future__ import annotations

import importlib
from typing import Dict, List

from .base import (ModelConfig, SHAPES, ShapeCell, cell_is_runnable,
                   input_specs, shape_by_name)

_ARCH_MODULES: Dict[str, str] = {
    "grok-1-314b": "grok_1_314b",
    "arctic-480b": "arctic_480b",
    "zamba2-1.2b": "zamba2_1_2b",
    "mamba2-130m": "mamba2_130m",
    "codeqwen1.5-7b": "codeqwen1_5_7b",
    "starcoder2-3b": "starcoder2_3b",
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "gemma2-2b": "gemma2_2b",
    "phi-3-vision-4.2b": "phi_3_vision_4_2b",
    "whisper-base": "whisper_base",
}

ARCH_IDS: List[str] = list(_ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; have {ARCH_IDS}")
    mod = importlib.import_module(f".{_ARCH_MODULES[arch]}", __package__)
    return mod.config()


def get_smoke_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f".{_ARCH_MODULES[arch]}", __package__)
    return mod.smoke()


__all__ = ["ModelConfig", "SHAPES", "ShapeCell", "ARCH_IDS", "get_config",
           "get_smoke_config", "cell_is_runnable", "input_specs",
           "shape_by_name"]
