"""whisper-base [audio] — 6L enc + 6L dec, d512 8H d_ff=2048 vocab=51865,
enc-dec; conv frontend STUB (input_specs provides precomputed frame
embeddings; enc_seq padded 1500 -> 1536 for chunked attention).
[arXiv:2212.04356; unverified]"""

from .base import ModelConfig

def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-base", family="audio",
        n_layers=6, d_model=512, n_heads=8, n_kv_heads=8, d_ff=2048,
        vocab_size=51865, head_dim=64, norm="ln", act="gelu",
        use_rope=False, n_enc_layers=6, enc_seq=1536, tie_embeddings=True,
        mlp_gated=False,
    )

def smoke() -> ModelConfig:
    return ModelConfig(
        name="whisper-base-smoke", family="audio",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=256, head_dim=16, norm="ln", act="gelu",
        use_rope=False, n_enc_layers=2, enc_seq=16, tie_embeddings=True,
        mlp_gated=False, dtype="float32")
