"""phi-3-vision-4.2b [vlm] — 32L d3072 32H (kv=32) d_ff=8192 vocab=32064,
phi3-mini backbone + CLIP frontend (STUB: input_specs provides precomputed
patch embeddings per the assignment). [hf:microsoft/Phi-3-vision-128k-instruct; hf]"""

from .base import ModelConfig

def config() -> ModelConfig:
    return ModelConfig(
        name="phi-3-vision-4.2b", family="vlm",
        n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32, d_ff=8192,
        vocab_size=32064, head_dim=96, n_img_tokens=64,
    )

def smoke() -> ModelConfig:
    return ModelConfig(
        name="phi-3-vision-4.2b-smoke", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=256, head_dim=16, n_img_tokens=8, dtype="float32")
