"""codeqwen1.5-7b [dense] — 32L d4096 32H (kv=32) d_ff=13440 vocab=92416,
qwen1.5 arch (QKV bias). [hf:Qwen/CodeQwen1.5-7B; hf]"""

from .base import ModelConfig

def config() -> ModelConfig:
    return ModelConfig(
        name="codeqwen1.5-7b", family="dense",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32, d_ff=13440,
        vocab_size=92416, head_dim=128, qkv_bias=True,
        rope_theta=1_000_000.0,
    )

def smoke() -> ModelConfig:
    return ModelConfig(
        name="codeqwen1.5-7b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=256, head_dim=16, qkv_bias=True, dtype="float32")
