"""gemma2-2b [dense] — 26L d2304 8H (GQA kv=4) d_ff=9216 vocab=256000,
local/global alternating (window 4096), attn softcap 50, final logit
softcap 30, sandwich norms, GeGLU. [arXiv:2408.00118; hf]

This is the arch where the paper's technique applies directly: local layers
run pencil-window attention (DESIGN.md §4)."""

from .base import ModelConfig

def config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-2b", family="dense",
        n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4, d_ff=9216,
        vocab_size=256000, head_dim=256, act="gelu",
        local_global=True, window=4096, attn_softcap=50.0,
        logit_softcap=30.0, post_norms=True, scale_embed=True,
        tie_embeddings=True,
    )

def smoke() -> ModelConfig:
    return ModelConfig(
        name="gemma2-2b-smoke", family="dense",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=256, head_dim=16, act="gelu",
        local_global=True, window=8, attn_softcap=50.0, logit_softcap=30.0,
        post_norms=True, scale_embed=True, tie_embeddings=True,
        dtype="float32")
