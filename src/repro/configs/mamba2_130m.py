"""mamba2-130m [ssm] — 24L d768 attn-free SSD, ssm_state=128 vocab=50280.
[arXiv:2405.21060; unverified]"""

from .base import ModelConfig

def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-130m", family="ssm",
        n_layers=24, d_model=768, n_heads=12, n_kv_heads=12, d_ff=0,
        vocab_size=50280, head_dim=64,
        ssm_state=128, ssm_headdim=64, ssm_expand=2, tie_embeddings=True,
    )

def smoke() -> ModelConfig:
    return ModelConfig(
        name="mamba2-130m-smoke", family="ssm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=0,
        vocab_size=256, head_dim=16,
        ssm_state=16, ssm_headdim=16, ssm_expand=2, ssm_chunk=8,
        tie_embeddings=True, dtype="float32")
