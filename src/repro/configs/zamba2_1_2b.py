"""zamba2-1.2b [hybrid] — 38L d2048 Mamba2 backbone + shared attn block
(32H kv=32, d_ff=8192), ssm_state=64 vocab=32000. [arXiv:2411.15242; hf]

Simplification vs. the public checkpoint (DESIGN.md §4): one shared
attn+MLP block applied after every 6th mamba layer (the real model
interleaves two shared blocks with per-invocation LoRA deltas)."""

from .base import ModelConfig

def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b", family="hybrid",
        n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192,
        vocab_size=32000, head_dim=64,
        ssm_state=64, ssm_headdim=64, ssm_expand=2, hybrid_attn_every=6,
    )

def smoke() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b-smoke", family="hybrid",
        n_layers=5, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=256, head_dim=16,
        ssm_state=16, ssm_headdim=16, ssm_expand=2, hybrid_attn_every=2,
        ssm_chunk=8, dtype="float32")
